"""L1 Pallas fused dequant-matmul kernels (the quantized-inference hot path).

Paper hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA-style
"keep 2/4-bit codes in HBM, dequantize per threadblock into shared memory,
feed tensor cores" becomes "keep packed codes in HBM, stage one packed block
per grid step through VMEM via BlockSpec, unpack + dequantize in-register,
feed the MXU with an f32 (bf16-ready) tile".

Packing convention (must match ref.pack_codes and rust quant::pack):
  * codes are b-bit (b ∈ {2,4}), packed along the K (reduction) axis,
    little-endian within each byte: packed[r, n] holds rows r*per..r*per+per-1
    where per = 8 // b.
  * scale/zero are per (group, column), groups of size `group` along K.
  * dequant:  w[k, n] = (code[k, n] - zero[k//g, n]) * scale[k//g, n]

Block constraint: bk (the K block) must be a multiple of both `group` and
`per` so every block is self-contained (own scales, whole bytes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _pick_block


def _dequant_mm_kernel(x_ref, p_ref, s_ref, z_ref, o_ref, *, bits: int,
                       group: int):
    """One (i, j, k) grid step of x @ dequant(packed)."""
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    per = 8 // bits
    mask = jnp.uint8(2**bits - 1)
    packed = p_ref[...]                      # [bk//per, bn]
    rows = [(packed >> (bits * i)) & mask for i in range(per)]
    # [bk//per, per, bn] -> [bk, bn]
    codes = jnp.stack(rows, axis=1).reshape(packed.shape[0] * per,
                                            packed.shape[1])
    s = jnp.repeat(s_ref[...], group, axis=0)   # [bk, bn]
    z = jnp.repeat(z_ref[...], group, axis=0)
    w = (codes.astype(jnp.float32) - z) * s
    o_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


def dequant_matmul(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
                   zero: jnp.ndarray, *, bits: int, group: int,
                   bm: int = 128, bn: int = 128,
                   bk: int = 256) -> jnp.ndarray:
    """x [M,K] f32 @ dequant(packed [K*bits/8, N] u8) -> [M,N] f32."""
    m, k = x.shape
    per = 8 // bits
    kp, n = packed.shape
    assert kp * per == k, (x.shape, packed.shape, bits)
    assert k % group == 0 and scale.shape == (k // group, n)
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    # bk: multiple of lcm(group, per); choose the largest divisor of k that
    # is a multiple of group (group is itself a multiple of per for our
    # configs, enforced below) and <= want.
    assert group % per == 0, (group, per)
    n_groups = k // group
    bg = _pick_block(n_groups, max(1, bk // group))
    bk = bg * group
    return pl.pallas_call(
        functools.partial(_dequant_mm_kernel, bits=bits, group=group),
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // per, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, packed, scale, zero)


def vmem_bytes(bm: int, bn: int, bk: int, bits: int, group: int) -> int:
    """Estimated VMEM footprint of one grid step (f32 = 4B, u8 codes).

    Used by EXPERIMENTS.md §Perf to pick block shapes that fit a ~16 MiB
    TPU VMEM budget with double buffering (×2 on the streamed inputs).
    """
    per = 8 // bits
    x_b = bm * bk * 4
    p_b = (bk // per) * bn
    sz_b = 2 * (bk // group) * bn * 4
    o_b = bm * bn * 4
    unpacked = bk * bn * 4  # in-register dequantized tile
    return 2 * (x_b + p_b + sz_b) + o_b + unpacked
