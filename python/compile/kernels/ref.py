"""Pure-jnp reference oracles for every Pallas kernel (L1 correctness signal).

Each function here is the mathematical specification that the corresponding
Pallas kernel in `matmul.py` / `dequant.py` / `quant.py` must match to within
float tolerance. pytest (python/tests/) asserts `assert_allclose(kernel, ref)`
over hypothesis-generated shape/dtype/group-size sweeps.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain f32 matmul: [M,K] @ [K,N] -> [M,N]."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Group-wise affine quantization (RTN), groups along the K (input) axis.
# Weight W [K, N]; group size g divides K. Per (group, column): scale, zero.
# code = clip(round(W/s + z), 0, 2^b - 1);  deq = (code - z) * s.
# This mirrors HQQ's parameterization (zero-point formulation) so the rust
# backends and the kernels agree on one convention.
# ---------------------------------------------------------------------------

def rtn_params(w: jnp.ndarray, bits: int, group: int):
    """Min/max affine quantization params. Returns (scale, zero) [K//g, N]."""
    k, n = w.shape
    assert k % group == 0, (k, group)
    wg = w.reshape(k // group, group, n)
    lo = wg.min(axis=1)
    hi = wg.max(axis=1)
    qmax = float(2**bits - 1)
    scale = (hi - lo) / qmax
    # Guard degenerate (constant) groups.
    scale = jnp.where(scale <= 1e-12, 1.0, scale)
    zero = -lo / scale
    return scale, zero


def rtn_quantize(w: jnp.ndarray, bits: int, group: int):
    """Returns (codes u8 [K,N], scale [K//g,N], zero [K//g,N])."""
    k, n = w.shape
    scale, zero = rtn_params(w, bits, group)
    s = jnp.repeat(scale, group, axis=0)
    z = jnp.repeat(zero, group, axis=0)
    qmax = float(2**bits - 1)
    codes = jnp.clip(jnp.round(w / s + z), 0.0, qmax).astype(jnp.uint8)
    return codes, scale, zero


def dequantize(codes: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray,
               group: int) -> jnp.ndarray:
    """codes u8 [K,N] -> f32 [K,N]."""
    s = jnp.repeat(scale, group, axis=0)
    z = jnp.repeat(zero, group, axis=0)
    return (codes.astype(jnp.float32) - z) * s


def pack_codes(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack b-bit codes along K into u8: [K,N] -> [K*bits//8, N].

    Layout: u8 row r holds codes for rows r*(8//bits) .. r*(8//bits)+per-1,
    lowest bits = first row (little-endian within the byte).
    """
    assert bits in (2, 4)
    per = 8 // bits
    k, n = codes.shape
    assert k % per == 0
    c = codes.reshape(k // per, per, n).astype(jnp.uint8)
    out = jnp.zeros((k // per, n), dtype=jnp.uint8)
    for i in range(per):
        out = out | (c[:, i, :] << (bits * i))
    return out


def unpack_codes(packed: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Inverse of pack_codes: [K*bits//8, N] -> u8 [K,N]."""
    assert bits in (2, 4)
    per = 8 // bits
    mask = jnp.uint8(2**bits - 1)
    rows = [(packed >> (bits * i)) & mask for i in range(per)]
    return jnp.stack(rows, axis=1).reshape(packed.shape[0] * per,
                                           packed.shape[1])


def dequant_matmul(x: jnp.ndarray, packed: jnp.ndarray, scale: jnp.ndarray,
                   zero: jnp.ndarray, bits: int, group: int) -> jnp.ndarray:
    """Fused reference: x [M,K] @ dequant(packed codes) [K,N] -> [M,N]."""
    codes = unpack_codes(packed, bits)
    w = dequantize(codes, scale, zero, group)
    return matmul(x, w)


def kurtosis(w: jnp.ndarray) -> jnp.ndarray:
    """Excess kurtosis of the flattened tensor (paper Eq. 5)."""
    v = w.reshape(-1).astype(jnp.float32)
    mu = v.mean()
    c = v - mu
    m2 = (c**2).mean()
    m4 = (c**4).mean()
    return m4 / (m2**2 + 1e-24) - 3.0
