"""L1 Pallas fused causal attention kernel (flash-attention-shaped).

One grid step owns one (batch, head) pair and a query-row block; keys and
values stream through VMEM in blocks along the sequence axis while an
online-softmax accumulator (running max m, running normalizer l, running
weighted sum acc) keeps the full attention matrix out of memory — the
standard flash-attention recurrence re-expressed with BlockSpec instead
of CUDA threadblocks/shared memory.

Not wired into the AOT model executable (the zoo's S=64 attention fits
VMEM whole and jnp einsum lowers to the same contraction); this kernel is
the scalable-S path, verified against `ref_attention` by hypothesis
sweeps in python/tests/test_attention.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _pick_block

NEG_INF = -1e30


def ref_attention(q: jnp.ndarray, k: jnp.ndarray,
                  v: jnp.ndarray) -> jnp.ndarray:
    """Oracle: causal softmax(QKᵀ/√d)V; q,k,v [B,H,S,dh]."""
    s = q.shape[2]
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (q.shape[-1] ** 0.5)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask[None, None], att, NEG_INF)
    att = jax.nn.softmax(att, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", att, v)


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int,
                 n_k: int, scale: float):
    """Grid: (B*H, S/bq, S/bk); k-axis innermost (revisited output block
    holds the online-softmax state packed alongside the accumulator)."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    # o_ref layout: [bq, dh + 2] — columns [0:dh] accumulate the weighted
    # sum, column dh holds the running max m, column dh+1 the running
    # normalizer l. Packing the state into the revisited output block
    # avoids scratch-shape APIs that differ across pallas versions.
    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        o_ref[:, -2] = jnp.full((bq,), NEG_INF, jnp.float32)

    q = q_ref[0]                       # [bq, dh]
    k = k_ref[0]                       # [bk, dh]
    v = v_ref[0]                       # [bk, dh]
    scores = (q @ k.T) * scale         # [bq, bk]
    # Causal mask between absolute positions.
    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)[:, None]
    k_pos = ki * bk + jax.lax.iota(jnp.int32, bk)[None, :]
    scores = jnp.where(q_pos >= k_pos, scores, NEG_INF)

    m_prev = o_ref[:, -2]
    l_prev = o_ref[:, -1]
    acc_prev = o_ref[:, :-2]

    m_cur = jnp.maximum(m_prev, scores.max(axis=1))
    # Guard fully-masked rows (m stays NEG_INF): exp(NEG_INF - NEG_INF)
    # would be exp(0)=1; force alpha/p to 0 there instead.
    valid = m_cur > NEG_INF / 2
    alpha = jnp.where(valid, jnp.exp(m_prev - m_cur), 0.0)
    p = jnp.where(valid[:, None], jnp.exp(scores - m_cur[:, None]), 0.0)
    l_cur = l_prev * alpha + p.sum(axis=1)
    acc = acc_prev * alpha[:, None] + p @ v

    o_ref[:, :-2] = acc
    o_ref[:, -2] = m_cur
    o_ref[:, -1] = l_cur

    @pl.when(ki == n_k - 1)
    def _finalize():
        l_fin = o_ref[:, -1]
        denom = jnp.where(l_fin > 0.0, l_fin, 1.0)
        o_ref[:, :-2] = o_ref[:, :-2] / denom[:, None]


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    bq: int = 64, bk: int = 64) -> jnp.ndarray:
    """Causal attention; q,k,v [B,H,S,dh] -> [B,H,S,dh]."""
    b, h, s, dh = q.shape
    assert k.shape == v.shape == (b, h, s, dh)
    bq = _pick_block(s, bq)
    bk = _pick_block(s, bk)
    n_k = s // bk
    scale = 1.0 / (dh ** 0.5)
    qf = q.reshape(b * h, s, dh)
    kf = k.reshape(b * h, s, dh)
    vf = v.reshape(b * h, s, dh)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, bq=bq, bk=bk, n_k=n_k,
                          scale=scale),
        grid=(b * h, s // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda g, qi, ki: (g, qi, 0)),
            pl.BlockSpec((1, bk, dh), lambda g, qi, ki: (g, ki, 0)),
            pl.BlockSpec((1, bk, dh), lambda g, qi, ki: (g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((bq, dh + 2), lambda g, qi, ki: (
            g * (s // bq) + qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h * s, dh + 2), jnp.float32),
        interpret=True,
    )(qf, kf, vf)
    # Strip the packed (m, l) state columns.
    return out[:, :dh].reshape(b, h, s, dh)
