"""L1 Pallas group-wise RTN quantization kernel.

Quantizes a weight matrix W [K, N] to b-bit codes with per-(group, column)
affine params, entirely on device: each grid step owns a [bk, bn] block
(bk a multiple of the group size), computes group min/max, derives
scale/zero, and emits rounded codes. This is the "quantize" half of the
serving path (the coordinator calls it when admitting a new model variant);
the fused dequant side lives in `dequant.py`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _pick_block


def _rtn_kernel(w_ref, c_ref, s_ref, z_ref, *, bits: int, group: int):
    w = w_ref[...]                           # [bk, bn]
    bk, bn = w.shape
    wg = w.reshape(bk // group, group, bn)
    lo = wg.min(axis=1)                      # [bk//g, bn]
    hi = wg.max(axis=1)
    qmax = float(2**bits - 1)
    scale = (hi - lo) / qmax
    scale = jnp.where(scale <= 1e-12, 1.0, scale)
    zero = -lo / scale
    s_ref[...] = scale
    z_ref[...] = zero
    s_full = jnp.repeat(scale, group, axis=0)
    z_full = jnp.repeat(zero, group, axis=0)
    c_ref[...] = jnp.clip(jnp.round(w / s_full + z_full), 0.0,
                          qmax).astype(jnp.uint8)


def rtn_quantize(w: jnp.ndarray, *, bits: int, group: int, bn: int = 256,
                 bk: int = 512):
    """W [K,N] f32 -> (codes u8 [K,N], scale [K//g,N], zero [K//g,N])."""
    k, n = w.shape
    assert k % group == 0, (k, group)
    bn = _pick_block(n, bn)
    bg = _pick_block(k // group, max(1, bk // group))
    bk = bg * group
    return pl.pallas_call(
        functools.partial(_rtn_kernel, bits=bits, group=group),
        grid=(k // bk, n // bn),
        in_specs=[pl.BlockSpec((bk, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, n), jnp.uint8),
            jax.ShapeDtypeStruct((k // group, n), jnp.float32),
            jax.ShapeDtypeStruct((k // group, n), jnp.float32),
        ],
        interpret=True,
    )(w)
