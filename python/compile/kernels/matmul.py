"""L1 Pallas tiled matmul kernel.

This is the dense-matmul hot spot used inside the L2 transformer forward
(`model.py`). It is written TPU-style: the grid walks (M/bm, N/bn, K/bk)
tiles, each grid step stages an x-tile and a w-tile through VMEM via
BlockSpec and accumulates into the revisited output tile — the HBM↔VMEM
schedule a CUDA kernel would express with threadblocks + shared memory.

interpret=True is mandatory on this image (CPU PJRT cannot execute Mosaic
custom-calls); real-TPU perf is estimated from the block shapes in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (i, j, k) grid step: o += x_tile @ w_tile (o zeroed at k == 0).

    The output BlockSpec maps every k to the same (i, j) tile, so the tile
    stays resident in VMEM across the whole K loop (grid iterates k fastest)
    and acts as the accumulator.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block(dim: int, want: int) -> int:
    """Largest divisor of `dim` that is <= want (keeps the grid exact)."""
    b = max(1, min(dim, want))
    while dim % b != 0:
        b -= 1
    return b


def pallas_matmul(x: jnp.ndarray, w: jnp.ndarray, *, bm: int = 128,
                  bn: int = 128, bk: int = 128) -> jnp.ndarray:
    """[M,K] f32 @ [K,N] f32 -> [M,N] f32 via the tiled Pallas kernel."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def matmul_3d(x: jnp.ndarray, w: jnp.ndarray, **kw) -> jnp.ndarray:
    """Batched wrapper: [B,S,K] @ [K,N] -> [B,S,N] (flattens the batch)."""
    b, s, k = x.shape
    out = pallas_matmul(x.reshape(b * s, k), w, **kw)
    return out.reshape(b, s, -1)
