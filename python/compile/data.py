"""Synthetic corpus + reasoning-task generator (the data substrate).

Substitutes the paper's WikiText-2 / C4 / Pile / six reasoning benchmarks
(none of which are available offline) with a deterministic "nano-language":
a fixed world of entities with attributes, rendered through sentence
templates. A byte-level LM trained on the corpus acquires real skill
(fact recall, arithmetic, pattern copying), so quantization-induced
degradation is measurable and allocation methods can be discriminated —
exactly the role the paper's benchmarks play. See DESIGN.md "Substitutions".

Everything is keyed by a single seed so `make artifacts` is reproducible.

Tokenization: raw bytes (vocab 256).
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Tuple

import numpy as np

NAMES = ["alice", "bob", "carol", "david", "erin", "frank", "grace", "henry",
         "iris", "jack", "karen", "leo", "mona", "nina", "oscar", "paula"]
ANIMALS = ["cat", "dog", "fox", "owl", "bat", "pig", "hen", "rat"]
COLORS = ["red", "blue", "green", "black", "white", "brown", "gray", "pink"]
DRINKS = ["tea", "milk", "juice", "cocoa", "water", "soda", "cider", "mead"]
PLACES = ["rome", "oslo", "cairo", "lima", "kyoto", "quito", "delhi", "bonn"]
LETTERS = list("abcdefghijklmnopqrstuvwxyz")


@dataclasses.dataclass
class World:
    """Fixed entity->attribute facts (the 'knowledge' the LM learns)."""
    animal: Dict[str, str]
    color: Dict[str, str]
    drink: Dict[str, str]
    place: Dict[str, str]


def make_world(seed: int) -> World:
    rng = random.Random(seed)
    return World(
        animal={n: rng.choice(ANIMALS) for n in NAMES},
        color={n: rng.choice(COLORS) for n in NAMES},
        drink={n: rng.choice(DRINKS) for n in NAMES},
        place={n: rng.choice(PLACES) for n in NAMES},
    )


# --------------------------------------------------------------------------
# Sentence renderers. Two surface-form families: the "wiki" family (used for
# training + the wiki_like eval split) and the "c4" family (same facts,
# shifted templates — the domain-shift eval split).
# --------------------------------------------------------------------------

def _fact_sentences_wiki(w: World, rng: random.Random) -> List[str]:
    n = rng.choice(NAMES)
    return [
        f"{n} has a {w.color[n]} {w.animal[n]} . ",
        f"{n} likes {w.drink[n]} . ",
        f"{n} lives in {w.place[n]} . ",
        f"the {w.animal[n]} of {n} is {w.color[n]} . ",
    ]


def _fact_sentences_c4(w: World, rng: random.Random) -> List[str]:
    n = rng.choice(NAMES)
    return [
        f"in {w.place[n]} lives {n} . ",
        f"{n} drinks {w.drink[n]} every day . ",
        f"a {w.color[n]} {w.animal[n]} belongs to {n} . ",
    ]


def _arith_sentence(rng: random.Random) -> str:
    i = rng.randint(0, 9)
    j = rng.randint(0, 9 - i)
    return f"{i} + {j} = {i + j} . "


def _qa_sentence(w: World, rng: random.Random) -> str:
    n = rng.choice(NAMES)
    if rng.random() < 0.5:
        d = w.drink[n]
        ans = "yes"
    else:
        d = rng.choice([x for x in DRINKS if x != w.drink[n]])
        ans = "no"
    return f"question : does {n} like {d} ? answer : {ans} . "


def _pattern_sentence(rng: random.Random) -> str:
    a, b = rng.sample(LETTERS, 2)
    unit = f"{a} {b} "
    return unit * rng.randint(3, 5) + ". "


def gen_corpus(seed: int, n_tokens: int, family: str = "wiki") -> np.ndarray:
    """Byte-token corpus of at least n_tokens tokens (i32)."""
    w = make_world(seed)
    rng = random.Random(seed * 7919 + hash(family) % 1000)
    parts: List[str] = []
    total = 0
    while total < n_tokens:
        r = rng.random()
        if r < 0.55:
            s = rng.choice(
                _fact_sentences_wiki(w, rng) if family == "wiki"
                else _fact_sentences_c4(w, rng))
        elif r < 0.70:
            s = _arith_sentence(rng)
        elif r < 0.85:
            s = _qa_sentence(w, rng)
        else:
            s = _pattern_sentence(rng)
        parts.append(s)
        total += len(s)
    text = "".join(parts)[:n_tokens]
    return np.frombuffer(text.encode("ascii"), dtype=np.uint8).astype(np.int32)


# --------------------------------------------------------------------------
# Reasoning tasks (analogs of ARC-C / HellaSwag / PIQA / BoolQ / WinoGrande /
# TruthfulQA). Each item: prompt + k choices, gold index. Scored by the rust
# eval harness with length-normalized continuation log-likelihood — the same
# mechanism lm-eval-harness uses for the paper's benchmarks.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Task:
    name: str
    k: int
    # tokens [n*k, seq] i32 (prompt+choice, zero-padded)
    tokens: np.ndarray
    prompt_len: np.ndarray   # [n*k] i32
    total_len: np.ndarray    # [n*k] i32
    gold: np.ndarray         # [n] i32


def _mk_items(items: List[Tuple[str, List[str], int]], seq: int,
              name: str) -> Task:
    k = len(items[0][1])
    toks = np.zeros((len(items) * k, seq), np.int32)
    p_len = np.zeros(len(items) * k, np.int32)
    t_len = np.zeros(len(items) * k, np.int32)
    gold = np.zeros(len(items), np.int32)
    for i, (prompt, choices, g) in enumerate(items):
        assert len(choices) == k
        gold[i] = g
        for j, ch in enumerate(choices):
            row = i * k + j
            s = (prompt + ch).encode("ascii")[:seq]
            toks[row, :len(s)] = np.frombuffer(s, np.uint8)
            p_len[row] = min(len(prompt), seq)
            t_len[row] = len(s)
    return Task(name, k, toks, p_len, t_len, gold)


def _choices(gold: str, pool: List[str], rng: random.Random, k: int):
    wrong = rng.sample([p for p in pool if p != gold], k - 1)
    opts = wrong + [gold]
    rng.shuffle(opts)
    return opts, opts.index(gold)


def gen_tasks(seed: int, seq: int, n_items: int = 32) -> List[Task]:
    w = make_world(seed)
    rng = random.Random(seed * 31337)
    tasks = []

    # 1. copy (ARC-C analog): continue the repeating pattern.
    items = []
    for _ in range(n_items):
        a, b = rng.sample(LETTERS, 2)
        prompt = f"{a} {b} " * 3 + a
        opts, g = _choices(f" {b}", [f" {c}" for c in LETTERS[:8]] + [f" {b}"],
                           rng, 4)
        items.append((prompt, opts, g))
    tasks.append(_mk_items(items, seq, "copy"))

    # 2. continuation (HellaSwag analog): which animal does the entity own?
    items = []
    for _ in range(n_items):
        n = rng.choice(NAMES)
        prompt = f"{n} has a {w.color[n]}"
        opts, g = _choices(f" {w.animal[n]} .", [f" {a} ." for a in ANIMALS],
                           rng, 4)
        items.append((prompt, opts, g))
    tasks.append(_mk_items(items, seq, "continuation"))

    # 3. arithmetic (PIQA analog).
    items = []
    for _ in range(n_items):
        i = rng.randint(0, 9)
        j = rng.randint(0, 9 - i)
        prompt = f"{i} + {j} ="
        opts, g = _choices(f" {i + j}", [f" {d}" for d in range(10)], rng, 4)
        items.append((prompt, opts, g))
    tasks.append(_mk_items(items, seq, "arithmetic"))

    # 4. boolq analog: yes/no drink questions.
    items = []
    for _ in range(n_items):
        n = rng.choice(NAMES)
        if rng.random() < 0.5:
            d, gold_txt = w.drink[n], " yes"
        else:
            d = rng.choice([x for x in DRINKS if x != w.drink[n]])
            gold_txt = " no"
        prompt = f"question : does {n} like {d} ? answer :"
        opts = [" yes", " no"]
        items.append((prompt, opts, opts.index(gold_txt)))
    tasks.append(_mk_items(items, seq, "boolq"))

    # 5. agreement (WinoGrande analog): color of the entity's animal.
    items = []
    for _ in range(n_items):
        n = rng.choice(NAMES)
        prompt = f"the {w.animal[n]} of {n} is"
        opts, g = _choices(f" {w.color[n]} .", [f" {c} ." for c in COLORS],
                           rng, 4)
        items.append((prompt, opts, g))
    tasks.append(_mk_items(items, seq, "agreement"))

    # 6. truth (TruthfulQA analog): place facts vs plausible distractors
    #    (places other entities actually live in).
    items = []
    for _ in range(n_items):
        n = rng.choice(NAMES)
        prompt = f"{n} lives in"
        pool = [f" {w.place[m]} ." for m in NAMES]
        gold_txt = f" {w.place[n]} ."
        opts, g = _choices(gold_txt, list(dict.fromkeys(pool)), rng, 4)
        items.append((prompt, opts, g))
    tasks.append(_mk_items(items, seq, "truth"))

    return tasks
