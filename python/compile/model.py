"""L2: llama-style transformer in JAX (RMSNorm + RoPE + GQA + SwiGLU).

Three lowering variants, all over STACKED per-layer weights scanned with
`lax.scan` so one compiled executable serves every quantized weight variant
(weights are runtime inputs fed by the rust coordinator):

  * `forward`        — tokens -> logits; weight matmuls go through the L1
                       Pallas tiled-matmul kernel (the served hot path).
  * `forward_probe`  — additionally returns every activation the
                       calibration-based baselines / GPTQ need (residual
                       stream per layer, normed projection inputs, attention
                       context, FFN intermediate). Pure-jnp matmuls.
  * `loss_and_grads` — next-token cross-entropy + grads w.r.t. all stacked
                       weights (for the LLM-MQ baseline). Pure-jnp (Pallas
                       interpret kernels are not reverse-mode differentiable).

Weight set (all f32):
  embed   [V, D]          unembed [D, V]        lnf [D]
  wq [L, D, H*dh]  wk [L, D, KV*dh]  wv [L, D, KV*dh]  wo [L, H*dh, D]
  wgate [L, D, F]  wup [L, D, F]     wdown [L, F, D]
  ln1 [L, D]       ln2 [L, D]
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul_3d

WEIGHT_NAMES = [
    "embed", "unembed", "lnf",
    "wq", "wk", "wv", "wo", "wgate", "wup", "wdown", "ln1", "ln2",
]
# The 2-D projection weights that get quantized (per layer slices of these).
QUANT_WEIGHTS = ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ffn: int
    n_layers: int
    seq: int

    @property
    def weight_shapes(self) -> Dict[str, tuple]:
        c = self
        hd = c.n_heads * c.d_head
        kvd = c.n_kv * c.d_head
        lyr = c.n_layers
        return {
            "embed": (c.vocab, c.d_model),
            "unembed": (c.d_model, c.vocab),
            "lnf": (c.d_model,),
            "wq": (lyr, c.d_model, hd),
            "wk": (lyr, c.d_model, kvd),
            "wv": (lyr, c.d_model, kvd),
            "wo": (lyr, hd, c.d_model),
            "wgate": (lyr, c.d_model, c.d_ffn),
            "wup": (lyr, c.d_model, c.d_ffn),
            "wdown": (lyr, c.d_ffn, c.d_model),
            "ln1": (lyr, c.d_model),
            "ln2": (lyr, c.d_model),
        }

    def param_count(self) -> int:
        import math
        return sum(math.prod(s) for s in self.weight_shapes.values())


# Reference model zoo (synthetic analogs of the paper's four LLMs; see
# DESIGN.md "Substitutions").
MODEL_ZOO = {
    "llama-s": ModelConfig("llama-s", 256, 64, 4, 2, 16, 192, 8, 64),
    "qwen-s": ModelConfig("qwen-s", 256, 64, 8, 4, 8, 256, 8, 64),
    "llama-m": ModelConfig("llama-m", 256, 96, 6, 6, 16, 256, 12, 64),
    "qwen-m": ModelConfig("qwen-m", 256, 96, 8, 4, 12, 288, 12, 64),
}


def init_weights(cfg: ModelConfig, key: jax.Array) -> Dict[str, jnp.ndarray]:
    """Scaled-gaussian init (the 'untrained' reference the LieQ baseline
    compares against)."""
    ws = {}
    shapes = cfg.weight_shapes
    keys = jax.random.split(key, len(shapes))
    for (name, shape), k in zip(sorted(shapes.items()), keys):
        if name in ("ln1", "ln2", "lnf"):
            ws[name] = jnp.ones(shape, jnp.float32)
        elif name == "embed":
            ws[name] = 0.02 * jax.random.normal(k, shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[0]
            std = (2.0 / (fan_in + shape[-1])) ** 0.5
            ws[name] = std * jax.random.normal(k, shape, jnp.float32)
    return ws


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def rope(x: jnp.ndarray, base: float = 10000.0) -> jnp.ndarray:
    """x [B, S, H, dh] -> rotary-embedded (half-split convention)."""
    b, s, h, dh = x.shape
    half = dh // 2
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    inv = base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * inv                              # [S, half]
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


def _mm(x: jnp.ndarray, w: jnp.ndarray, use_kernel: bool) -> jnp.ndarray:
    """[B,S,K] @ [K,N]: Pallas tiled kernel on the served path, jnp else."""
    if use_kernel:
        return matmul_3d(x, w)
    return jnp.einsum("bsk,kn->bsn", x, w)


def _layer(cfg: ModelConfig, h: jnp.ndarray, lw: Dict[str, jnp.ndarray],
           use_kernel: bool):
    """One transformer block. Returns (new_resid, probes dict)."""
    b, s, d = h.shape
    nh, nkv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    x1 = rmsnorm(h, lw["ln1"])
    q = _mm(x1, lw["wq"], use_kernel).reshape(b, s, nh, dh)
    k = _mm(x1, lw["wk"], use_kernel).reshape(b, s, nkv, dh)
    v = _mm(x1, lw["wv"], use_kernel).reshape(b, s, nkv, dh)
    q = rope(q)
    k = rope(k)
    # GQA: broadcast each kv head over its query group.
    rep = nh // nkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (dh ** 0.5)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, nh * dh)
    attn_out = _mm(ctx, lw["wo"], use_kernel)
    h = h + attn_out
    x2 = rmsnorm(h, lw["ln2"])
    gate = _mm(x2, lw["wgate"], use_kernel)
    up = _mm(x2, lw["wup"], use_kernel)
    mid = jax.nn.silu(gate) * up
    down = _mm(mid, lw["wdown"], use_kernel)
    h = h + down
    probes = {"x_ln1": x1, "x_ln2": x2, "attn_ctx": ctx, "ffn_mid": mid}
    return h, probes


def _run(cfg: ModelConfig, tokens: jnp.ndarray, ws: Dict[str, jnp.ndarray],
         use_kernel: bool, collect: bool):
    h = ws["embed"][tokens]                       # [B, S, D]
    stacked = {k: ws[k] for k in
               ("wq", "wk", "wv", "wo", "wgate", "wup", "wdown",
                "ln1", "ln2")}

    def step(carry, lw):
        new_h, probes = _layer(cfg, carry, lw, use_kernel)
        out = {"resid_in": carry, **probes} if collect else None
        return new_h, out

    h, ys = jax.lax.scan(step, h, stacked)
    hf = rmsnorm(h, ws["lnf"])
    logits = _mm(hf, ws["unembed"], use_kernel)
    return logits, h, ys


def forward(cfg: ModelConfig, tokens: jnp.ndarray,
            ws: Dict[str, jnp.ndarray], use_kernel: bool = True):
    """tokens i32 [B,S] -> logits f32 [B,S,V] (served path, Pallas matmuls)."""
    logits, _, _ = _run(cfg, tokens, ws, use_kernel, collect=False)
    return (logits,)


def forward_probe(cfg: ModelConfig, tokens: jnp.ndarray,
                  ws: Dict[str, jnp.ndarray]):
    """Returns (logits, resid_in [L,B,S,D], final_resid [B,S,D],
    x_ln1, x_ln2 [L,B,S,D], attn_ctx [L,B,S,H*dh], ffn_mid [L,B,S,F])."""
    logits, h, ys = _run(cfg, tokens, ws, use_kernel=False, collect=True)
    return (logits, ys["resid_in"], h, ys["x_ln1"], ys["x_ln2"],
            ys["attn_ctx"], ys["ffn_mid"])


def nll_loss(cfg: ModelConfig, tokens: jnp.ndarray,
             ws: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Mean next-token cross-entropy over [B, S-1]."""
    logits, _, _ = _run(cfg, tokens, ws, use_kernel=False, collect=False)
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean()


def loss_and_grads(cfg: ModelConfig, tokens: jnp.ndarray,
                   ws: Dict[str, jnp.ndarray]):
    """(loss, grads for the 7 quantizable stacked weights) — LLM-MQ input."""
    def f(qws, rest):
        return nll_loss(cfg, tokens, {**rest, **qws})

    qws = {k: ws[k] for k in QUANT_WEIGHTS}
    rest = {k: v for k, v in ws.items() if k not in QUANT_WEIGHTS}
    loss, grads = jax.value_and_grad(f)(qws, rest)
    return (loss,) + tuple(grads[k] for k in QUANT_WEIGHTS)
