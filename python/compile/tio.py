"""`.tz` tensor container: the python<->rust weight/corpus interchange format.

Layout (little-endian):
  magic  b"NSDT"
  u32    version (1)
  u32    tensor count
  per tensor:
    u32    name length, then name bytes (utf-8)
    u8     dtype: 0 = f32, 1 = i32, 2 = u8
    u32    ndim, then ndim × u64 dims
    raw    data (C order)

Kept deliberately trivial so the rust reader (`rust/src/util/tz.rs`) is a
few dozen lines and testable by round-trip.
"""

from __future__ import annotations

import struct
from typing import Dict

import numpy as np

MAGIC = b"NSDT"
_DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1,
           np.dtype(np.uint8): 2}
_INV = {v: k for k, v in _DTYPES.items()}


def write_tz(path: str, tensors: Dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _DTYPES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", _DTYPES[arr.dtype]))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def read_tz(path: str) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, path
        ver, count = struct.unpack("<II", f.read(8))
        assert ver == 1
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            (dt,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim))
            dtype = _INV[dt]
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(n * dtype.itemsize), dtype=dtype)
            out[name] = data.reshape(dims).copy()
    return out
