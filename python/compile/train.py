"""Build-time training of the synthetic model zoo.

Each model in `model.MODEL_ZOO` is trained with Adam on the nano-language
corpus until next-token loss is far below the uniform baseline (ln 256 ≈
5.55). Training happens ONCE inside `make artifacts`; the rust system only
ever sees the exported `.tz` weights and the AOT HLO.

Training uses the pure-jnp model variant (Pallas interpret kernels are not
reverse-mode differentiable); pytest asserts the kernel and jnp paths agree
on the forward, so the served artifact is numerically the trained model.
"""

from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


def adam_init(ws):
    z = lambda: {k: jnp.zeros_like(v) for k, v in ws.items()}
    return {"m": z(), "v": z(), "t": jnp.zeros((), jnp.int32)}


def adam_update(ws, grads, state, lr=3e-3, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in ws}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in ws}
    mh = {k: m[k] / (1 - b1 ** t) for k in ws}
    vh = {k: v[k] / (1 - b2 ** t) for k in ws}
    new = {k: ws[k] - lr * mh[k] / (jnp.sqrt(vh[k]) + eps) for k in ws}
    return new, {"m": m, "v": v, "t": t}


def batches(corpus: np.ndarray, bs: int, seq: int, seed: int):
    """Numpy-side batch sampler (one device_put per step, not bs of them)."""
    rng = np.random.default_rng(seed)
    n = corpus.shape[0] - seq - 1
    # Strided view: row i = corpus[i : i+seq].
    windows = np.lib.stride_tricks.sliding_window_view(corpus, seq)
    while True:
        idx = rng.integers(0, n, bs)
        yield jnp.asarray(windows[idx])


def train_model(cfg: M.ModelConfig, corpus: np.ndarray, *, steps: int = 600,
                bs: int = 16, lr: float = 3e-3, seed: int = 0,
                log_every: int = 100) -> Tuple[Dict, Dict, list]:
    """Returns (trained weights, init weights, loss log [(step, loss)])."""
    key = jax.random.PRNGKey(seed)
    init_ws = M.init_weights(cfg, key)
    ws = init_ws
    opt = adam_init(ws)

    @jax.jit
    def step_fn(ws, opt, toks):
        loss, grads = jax.value_and_grad(
            lambda w: M.nll_loss(cfg, toks, w))(ws)
        ws, opt = adam_update(ws, grads, opt, lr=lr)
        return ws, opt, loss

    gen = batches(corpus, bs, cfg.seq, seed + 1)
    log = []
    t0 = time.time()
    for i in range(steps):
        toks = next(gen)
        ws, opt, loss = step_fn(ws, opt, toks)
        if i % log_every == 0 or i == steps - 1:
            l = float(loss)
            log.append((i, l))
            print(f"[train {cfg.name}] step {i:4d} loss {l:.4f} "
                  f"({time.time() - t0:.1f}s)")
    return ws, init_ws, log
