"""AOT driver: python runs ONCE here (`make artifacts`), never at runtime.

Produces, under artifacts/:
  corpus.tz                       train / wiki_like / c4_like token streams
  tasks.tz                        six reasoning-task tensors
  weights_<model>.tz              trained weights (the FP16 reference)
  init_<model>.tz                 untrained weights (LieQ baseline input)
  fwd_<model>.hlo.txt             tokens+weights -> logits  (Pallas kernels)
  probe_<model>.hlo.txt           + per-layer activations   (calibration)
  grad_<model>.hlo.txt            loss + grads              (LLM-MQ)
  dequant_mm4.hlo.txt / dequant_mm2.hlo.txt / quant_rtn.hlo.txt
                                  standalone L1 kernel executables (serving
                                  demo + kernel benches)
  manifest.json                   configs, shapes, file index, train logs

Interchange is HLO TEXT (not serialized protos): xla_extension 0.5.1
rejects jax>=0.5's 64-bit instruction ids; the text parser reassigns ids.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import model as M
from . import train as T
from . import tio
from .kernels import ref
from .kernels.dequant import dequant_matmul
from .kernels.quant import rtn_quantize

SEED = 20260710
EVAL_BATCH = 8          # fixed B of every model executable
TRAIN_TOKENS = 160_000
EVAL_TOKENS = 16_384

# Standalone kernel demo shapes (serving path). K=256 with group 64.
KM, KK, KN, KGROUP = 64, 256, 256, 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def ws_args(cfg: M.ModelConfig):
    """Stable weight argument order shared with the rust runtime."""
    shapes = cfg.weight_shapes
    return [jax.ShapeDtypeStruct(shapes[n], jnp.float32)
            for n in M.WEIGHT_NAMES]


def lower_model(cfg: M.ModelConfig, variant: str) -> str:
    toks = jax.ShapeDtypeStruct((EVAL_BATCH, cfg.seq), jnp.int32)

    def as_dict(args):
        return dict(zip(M.WEIGHT_NAMES, args))

    if variant == "fwd":
        fn = lambda t, *w: M.forward(cfg, t, as_dict(w), use_kernel=True)
    elif variant == "probe":
        fn = lambda t, *w: M.forward_probe(cfg, t, as_dict(w))
    elif variant == "grad":
        fn = lambda t, *w: M.loss_and_grads(cfg, t, as_dict(w))
    else:
        raise ValueError(variant)
    lowered = jax.jit(fn).lower(toks, *ws_args(cfg))
    return to_hlo_text(lowered)


def lower_kernels(out_dir: str, manifest: dict) -> None:
    x = jax.ShapeDtypeStruct((KM, KK), jnp.float32)
    w = jax.ShapeDtypeStruct((KK, KN), jnp.float32)
    sz = jax.ShapeDtypeStruct((KK // KGROUP, KN), jnp.float32)
    for bits in (4, 2):
        per = 8 // bits
        p = jax.ShapeDtypeStruct((KK // per, KN), jnp.uint8)
        fn = lambda xx, pp, ss, zz, b=bits: (dequant_matmul(
            xx, pp, ss, zz, bits=b, group=KGROUP),)
        txt = to_hlo_text(jax.jit(fn).lower(x, p, sz, sz))
        fname = f"dequant_mm{bits}.hlo.txt"
        open(os.path.join(out_dir, fname), "w").write(txt)
        manifest["kernels"][f"dequant_mm{bits}"] = {
            "file": fname, "m": KM, "k": KK, "n": KN, "group": KGROUP,
            "bits": bits}
    fnq = lambda ww: rtn_quantize(ww, bits=4, group=KGROUP)
    txt = to_hlo_text(jax.jit(fnq).lower(w))
    open(os.path.join(out_dir, "quant_rtn.hlo.txt"), "w").write(txt)
    manifest["kernels"]["quant_rtn"] = {
        "file": "quant_rtn.hlo.txt", "k": KK, "n": KN, "group": KGROUP,
        "bits": 4}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="llama-s,qwen-s,llama-m,qwen-m")
    ap.add_argument("--steps", type=int, default=700)
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    manifest: dict = {"seed": SEED, "eval_batch": EVAL_BATCH,
                      "models": {}, "kernels": {}, "weight_order":
                      M.WEIGHT_NAMES, "quant_weights": M.QUANT_WEIGHTS}

    # ---- data ------------------------------------------------------------
    print("== corpus ==")
    train = D.gen_corpus(SEED, TRAIN_TOKENS, "wiki")
    wiki = D.gen_corpus(SEED + 1, EVAL_TOKENS, "wiki")
    c4 = D.gen_corpus(SEED + 2, EVAL_TOKENS, "c4")
    tio.write_tz(os.path.join(out, "corpus.tz"),
                 {"train": train, "wiki_like": wiki, "c4_like": c4})
    manifest["corpus"] = {"file": "corpus.tz",
                          "train_tokens": int(train.shape[0]),
                          "eval_tokens": int(wiki.shape[0])}

    print("== tasks ==")
    seq = 64
    tasks = D.gen_tasks(SEED, seq)
    tz = {}
    tmeta = []
    for t in tasks:
        tz[f"{t.name}.tokens"] = t.tokens
        tz[f"{t.name}.prompt_len"] = t.prompt_len
        tz[f"{t.name}.total_len"] = t.total_len
        tz[f"{t.name}.gold"] = t.gold
        tmeta.append({"name": t.name, "k": t.k,
                      "n": int(t.gold.shape[0])})
    tio.write_tz(os.path.join(out, "tasks.tz"), tz)
    manifest["tasks"] = {"file": "tasks.tz", "list": tmeta, "seq": seq}

    # ---- models ----------------------------------------------------------
    for name in args.models.split(","):
        cfg = M.MODEL_ZOO[name]
        print(f"== model {name} ({cfg.param_count():,} params) ==")
        ws, init_ws, log = T.train_model(cfg, train, steps=args.steps,
                                         seed=SEED)
        tio.write_tz(os.path.join(out, f"weights_{name}.tz"),
                     {k: np.asarray(v) for k, v in ws.items()})
        tio.write_tz(os.path.join(out, f"init_{name}.tz"),
                     {k: np.asarray(v) for k, v in init_ws.items()})
        files = {}
        for variant in ("fwd", "probe", "grad"):
            print(f"   lowering {variant} ...")
            txt = lower_model(cfg, variant)
            fname = f"{variant}_{name}.hlo.txt"
            open(os.path.join(out, fname), "w").write(txt)
            files[variant] = fname
        manifest["models"][name] = {
            "config": {k: getattr(cfg, k) for k in
                       ("vocab", "d_model", "n_heads", "n_kv", "d_head",
                        "d_ffn", "n_layers", "seq")},
            "params": cfg.param_count(),
            "weights": f"weights_{name}.tz",
            "init_weights": f"init_{name}.tz",
            "hlo": files,
            "train_log": log,
        }

    # ---- standalone kernels ----------------------------------------------
    print("== kernels ==")
    lower_kernels(out, manifest)

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {out}/manifest.json")


if __name__ == "__main__":
    main()
