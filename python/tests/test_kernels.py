"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes / bit-widths / group sizes — the CORE correctness
signal for the compute layer (the rust side re-verifies the same packing
convention independently).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dequant import dequant_matmul, vmem_bytes
from compile.kernels.matmul import pallas_matmul
from compile.kernels.quant import rtn_quantize

SETTINGS = dict(max_examples=12, deadline=None)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 64),
    n=st.integers(1, 40),
    bm=st.sampled_from([8, 16, 128]),
)
def test_matmul_matches_ref(m, k, n, bm):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((m, k), dtype=np.float32)
    w = rng.standard_normal((k, n), dtype=np.float32)
    out = pallas_matmul(jnp.array(x), jnp.array(w), bm=bm, bn=bm, bk=bm)
    np.testing.assert_allclose(np.array(out), np.array(ref.matmul(x, w)),
                               rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(
    bits=st.sampled_from([2, 4]),
    groups=st.integers(1, 6),
    group=st.sampled_from([4, 8, 16]),
    n=st.integers(1, 24),
)
def test_rtn_kernel_matches_ref(bits, groups, group, n):
    k = groups * group
    rng = np.random.default_rng(1)
    w = rng.standard_normal((k, n), dtype=np.float32)
    c_k, s_k, z_k = rtn_quantize(jnp.array(w), bits=bits, group=group,
                                 bn=16, bk=group)
    c_r, s_r, z_r = ref.rtn_quantize(w, bits, group)
    np.testing.assert_array_equal(np.array(c_k), np.array(c_r))
    np.testing.assert_allclose(np.array(s_k), np.array(s_r), rtol=1e-6)
    np.testing.assert_allclose(np.array(z_k), np.array(z_r), rtol=1e-5,
                               atol=1e-5)


@settings(**SETTINGS)
@given(
    bits=st.sampled_from([2, 4]),
    groups=st.integers(1, 4),
    group=st.sampled_from([8, 16]),
    m=st.integers(1, 16),
    n=st.integers(1, 24),
)
def test_dequant_matmul_matches_ref(bits, groups, group, m, n):
    k = groups * group
    rng = np.random.default_rng(2)
    w = rng.standard_normal((k, n), dtype=np.float32)
    x = rng.standard_normal((m, k), dtype=np.float32)
    codes, scale, zero = ref.rtn_quantize(w, bits, group)
    packed = ref.pack_codes(codes, bits)
    out = dequant_matmul(jnp.array(x), jnp.array(packed), scale, zero,
                         bits=bits, group=group, bm=8, bn=16, bk=group)
    want = ref.dequant_matmul(x, packed, scale, zero, bits, group)
    np.testing.assert_allclose(np.array(out), np.array(want), rtol=2e-4,
                               atol=2e-4)


@settings(**SETTINGS)
@given(bits=st.sampled_from([2, 4]), k=st.integers(1, 8),
       n=st.integers(1, 12))
def test_pack_unpack_roundtrip(bits, k, n):
    per = 8 // bits
    rows = k * per
    rng = np.random.default_rng(3)
    codes = rng.integers(0, 2**bits, (rows, n)).astype(np.uint8)
    packed = ref.pack_codes(codes, bits)
    assert packed.shape == (rows // per, n)
    back = ref.unpack_codes(jnp.array(packed), bits)
    np.testing.assert_array_equal(np.array(back), codes)


def test_dequantize_error_bounded():
    rng = np.random.default_rng(4)
    w = rng.standard_normal((64, 16), dtype=np.float32)
    for bits in (2, 4):
        codes, scale, zero = ref.rtn_quantize(w, bits, 16)
        deq = np.array(ref.dequantize(codes, scale, zero, 16))
        step = np.repeat(np.array(scale), 16, axis=0)
        assert (np.abs(w - deq) <= 0.5 * step + 1e-6).all()


def test_kurtosis_reference():
    rng = np.random.default_rng(5)
    g = rng.standard_normal(200_000).astype(np.float32)
    assert abs(float(ref.kurtosis(jnp.array(g)))) < 0.1
    lap = rng.laplace(size=200_000).astype(np.float32)
    assert abs(float(ref.kurtosis(jnp.array(lap))) - 3.0) < 0.3


def test_vmem_estimate_monotone():
    # Doubling the N block must grow the footprint; used by the §Perf
    # block-shape selection.
    a = vmem_bytes(64, 128, 256, 4, 64)
    b = vmem_bytes(64, 256, 256, 4, 64)
    assert b > a


@pytest.mark.parametrize("bits,group", [(4, 64), (2, 64)])
def test_kernel_at_serving_shape(bits, group):
    """The exact shape the AOT dequant kernels are lowered at."""
    rng = np.random.default_rng(6)
    k, n, m = 256, 256, 64
    w = rng.standard_normal((k, n), dtype=np.float32)
    x = rng.standard_normal((m, k), dtype=np.float32)
    codes, scale, zero = ref.rtn_quantize(w, bits, group)
    packed = ref.pack_codes(codes, bits)
    out = dequant_matmul(jnp.array(x), jnp.array(packed), scale, zero,
                         bits=bits, group=group)
    want = ref.dequant_matmul(x, packed, scale, zero, bits, group)
    np.testing.assert_allclose(np.array(out), np.array(want), rtol=2e-4,
                               atol=2e-4)
