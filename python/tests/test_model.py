"""L2 model correctness: shapes, kernel-vs-jnp agreement, probe/grad
variants, training step sanity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import train as T


CFG = M.ModelConfig("tiny", vocab=64, d_model=16, n_heads=4, n_kv=2,
                    d_head=4, d_ffn=32, n_layers=3, seq=12)


@pytest.fixture(scope="module")
def ws():
    return M.init_weights(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def toks():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, CFG.vocab, (2, CFG.seq),
                                    dtype=np.int32))


def test_forward_shapes(ws, toks):
    (logits,) = M.forward(CFG, toks, ws, use_kernel=False)
    assert logits.shape == (2, CFG.seq, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_kernel_and_jnp_paths_agree(ws, toks):
    (lk,) = M.forward(CFG, toks, ws, use_kernel=True)
    (lj,) = M.forward(CFG, toks, ws, use_kernel=False)
    np.testing.assert_allclose(np.array(lk), np.array(lj), rtol=1e-4,
                               atol=1e-4)


def test_probe_outputs(ws, toks):
    out = M.forward_probe(CFG, toks, ws)
    logits, resid_in, final, x1, x2, ctx, mid = out
    L, B, S, D = CFG.n_layers, 2, CFG.seq, CFG.d_model
    assert resid_in.shape == (L, B, S, D)
    assert final.shape == (B, S, D)
    assert x1.shape == (L, B, S, D)
    assert ctx.shape == (L, B, S, CFG.n_heads * CFG.d_head)
    assert mid.shape == (L, B, S, CFG.d_ffn)
    # Residual stream chains: resid_in[l+1] = resid_in[l] + attn + ffn;
    # at minimum the layers must differ (information flows).
    assert float(jnp.abs(resid_in[1] - resid_in[0]).max()) > 1e-6
    np.testing.assert_allclose(np.array(logits)[..., 0].shape, (B, S))


def test_grads_shapes_and_nonzero(ws, toks):
    out = M.loss_and_grads(CFG, toks, ws)
    loss = out[0]
    assert loss.shape == ()
    assert float(loss) > 0
    for name, g in zip(M.QUANT_WEIGHTS, out[1:]):
        assert g.shape == tuple(CFG.weight_shapes[name]), name
        assert float(jnp.abs(g).max()) > 0, f"zero grad for {name}"


def test_gqa_broadcast_consistency(toks):
    # With n_kv == n_heads the model must behave like standard MHA: check
    # it runs and differs from the GQA variant (different shapes).
    cfg_mha = M.ModelConfig("mha", 64, 16, 4, 4, 4, 32, 2, 12)
    ws = M.init_weights(cfg_mha, jax.random.PRNGKey(1))
    (logits,) = M.forward(cfg_mha, toks, ws, use_kernel=False)
    assert logits.shape == (2, 12, 64)


def test_rope_rotates_by_position():
    # RoPE must rotate identical head vectors differently per position
    # while preserving their norm.
    x = jnp.ones((1, 8, 2, 4), jnp.float32)
    r = M.rope(x)
    assert float(jnp.abs(r[0, 0] - r[0, 5]).max()) > 1e-3
    norms = jnp.linalg.norm(r, axis=-1)
    np.testing.assert_allclose(np.array(norms), 2.0, rtol=1e-5)


def test_order_dependence_via_rope(ws):
    # Swapping two earlier tokens must change the last position's logits
    # (pure bag-of-words models would not).
    rng = np.random.default_rng(9)
    t1 = rng.integers(0, CFG.vocab, (1, CFG.seq), dtype=np.int32)
    t2 = t1.copy()
    t2[0, 0], t2[0, 1] = t1[0, 1], t1[0, 0]
    if t1[0, 0] == t1[0, 1]:
        t2[0, 0] = (t2[0, 0] + 1) % CFG.vocab
    (l1,) = M.forward(CFG, jnp.asarray(t1), ws, use_kernel=False)
    (l2,) = M.forward(CFG, jnp.asarray(t2), ws, use_kernel=False)
    assert float(jnp.abs(l1[0, -1] - l2[0, -1]).max()) > 1e-6


def test_causality(ws):
    # Changing a future token must not change past logits.
    rng = np.random.default_rng(3)
    t1 = rng.integers(0, CFG.vocab, (1, CFG.seq), dtype=np.int32)
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 1) % CFG.vocab
    (l1,) = M.forward(CFG, jnp.asarray(t1), ws, use_kernel=False)
    (l2,) = M.forward(CFG, jnp.asarray(t2), ws, use_kernel=False)
    np.testing.assert_allclose(np.array(l1)[0, : CFG.seq - 1],
                               np.array(l2)[0, : CFG.seq - 1],
                               rtol=1e-5, atol=1e-5)


def test_train_reduces_loss():
    from compile import data as D
    # vocab must cover the byte-level corpus (ascii < 128).
    cfg = M.ModelConfig("tiny128", vocab=128, d_model=16, n_heads=4,
                        n_kv=2, d_head=4, d_ffn=32, n_layers=3, seq=12)
    corpus = D.gen_corpus(99, 6000, "wiki")
    ws, init_ws, log = T.train_model(cfg, corpus, steps=40, bs=8,
                                     log_every=39, seed=0)
    assert log[-1][1] < log[0][1] * 0.8, log
    # init weights preserved separately
    assert not np.allclose(np.array(ws["wq"]), np.array(init_ws["wq"]))
