"""AOT path tests: HLO text generation is parseable and the artifact
directory (when present) is internally consistent with the manifest."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.aot import lower_model, to_hlo_text, ws_args

TINY = M.ModelConfig("tiny", vocab=32, d_model=16, n_heads=2, n_kv=1,
                     d_head=8, d_ffn=24, n_layers=2, seq=8)


def test_lower_tiny_fwd_produces_hlo_text():
    M.MODEL_ZOO["tiny"] = TINY
    try:
        txt = lower_model(TINY, "fwd")
    finally:
        del M.MODEL_ZOO["tiny"]
    assert txt.startswith("HloModule"), txt[:60]
    assert "ENTRY" in txt


def test_ws_args_order_matches_weight_names():
    args = ws_args(TINY)
    assert len(args) == len(M.WEIGHT_NAMES)
    assert args[0].shape == tuple(TINY.weight_shapes["embed"])
    assert args[1].shape == tuple(TINY.weight_shapes["unembed"])


def test_to_hlo_text_simple_fn():
    f = lambda x: (x * 2.0 + 1.0,)
    lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    txt = to_hlo_text(lowered)
    assert "HloModule" in txt


ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_consistent_with_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["weight_order"] == M.WEIGHT_NAMES
    for name, entry in man["models"].items():
        cfg = M.MODEL_ZOO[name]
        assert entry["params"] == cfg.param_count()
        for fname in entry["hlo"].values():
            assert os.path.exists(os.path.join(ART, fname)), fname
        assert os.path.exists(os.path.join(ART, entry["weights"]))
        # training reached well below the uniform baseline ln(256)≈5.55
        assert entry["train_log"][-1][1] < 1.5


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_exported_weights_match_config_shapes():
    from compile import tio
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    name = next(iter(man["models"]))
    cfg = M.MODEL_ZOO[name]
    ws = tio.read_tz(os.path.join(ART, man["models"][name]["weights"]))
    for wname, shape in cfg.weight_shapes.items():
        assert ws[wname].shape == tuple(shape), wname
