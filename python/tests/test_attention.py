"""Fused causal-attention Pallas kernel vs the jnp oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import flash_attention, ref_attention

SETTINGS = dict(max_examples=10, deadline=None)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 3),
    s_blocks=st.integers(1, 4),
    dh=st.sampled_from([4, 8, 16]),
    bq=st.sampled_from([4, 8, 64]),
    bk=st.sampled_from([4, 16, 64]),
)
def test_flash_matches_ref(b, h, s_blocks, dh, bq, bk):
    s = 16 * s_blocks
    rng = np.random.default_rng(0)
    q = jnp.array(rng.standard_normal((b, h, s, dh), dtype=np.float32))
    k = jnp.array(rng.standard_normal((b, h, s, dh), dtype=np.float32))
    v = jnp.array(rng.standard_normal((b, h, s, dh), dtype=np.float32))
    out = flash_attention(q, k, v, bq=bq, bk=bk)
    want = ref_attention(q, k, v)
    np.testing.assert_allclose(np.array(out), np.array(want), rtol=3e-4,
                               atol=3e-4)


def test_causality_of_kernel():
    # Changing the last key/value must not affect earlier outputs.
    rng = np.random.default_rng(1)
    b, h, s, dh = 1, 2, 32, 8
    q = jnp.array(rng.standard_normal((b, h, s, dh), dtype=np.float32))
    k1 = rng.standard_normal((b, h, s, dh)).astype(np.float32)
    v1 = rng.standard_normal((b, h, s, dh)).astype(np.float32)
    k2 = k1.copy()
    v2 = v1.copy()
    k2[..., -1, :] += 5.0
    v2[..., -1, :] -= 5.0
    o1 = np.array(flash_attention(q, jnp.array(k1), jnp.array(v1), bq=8,
                                  bk=8))
    o2 = np.array(flash_attention(q, jnp.array(k2), jnp.array(v2), bq=8,
                                  bk=8))
    np.testing.assert_allclose(o1[..., : s - 1, :], o2[..., : s - 1, :],
                               rtol=1e-6, atol=1e-6)
    assert np.abs(o1[..., -1, :] - o2[..., -1, :]).max() > 1e-3


def test_online_softmax_extreme_scores():
    # Large score magnitudes must not overflow the online softmax.
    rng = np.random.default_rng(2)
    b, h, s, dh = 1, 1, 32, 8
    q = jnp.array(30.0 * rng.standard_normal((b, h, s, dh),
                                             dtype=np.float32))
    k = jnp.array(30.0 * rng.standard_normal((b, h, s, dh),
                                             dtype=np.float32))
    v = jnp.array(rng.standard_normal((b, h, s, dh), dtype=np.float32))
    out = np.array(flash_attention(q, k, v, bq=8, bk=8))
    assert np.isfinite(out).all()
    want = np.array(ref_attention(q, k, v))
    np.testing.assert_allclose(out, want, rtol=1e-3, atol=1e-3)
