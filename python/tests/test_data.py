"""Data substrate tests: corpus determinism, task well-formedness, the
.tz container round-trip."""

import os
import tempfile

import numpy as np

from compile import data as D
from compile import tio


def test_corpus_deterministic():
    a = D.gen_corpus(7, 5000, "wiki")
    b = D.gen_corpus(7, 5000, "wiki")
    assert np.array_equal(a, b)
    c = D.gen_corpus(8, 5000, "wiki")
    assert not np.array_equal(a, c)


def test_corpus_ascii_bytes():
    a = D.gen_corpus(1, 3000, "c4")
    assert a.dtype == np.int32
    assert a.min() >= 0 and a.max() < 128  # plain ascii


def test_families_share_facts():
    # Same seed -> same world; the c4 family must mention the same
    # entity-attribute pairs.
    w = D.make_world(3)
    text_wiki = bytes(D.gen_corpus(3, 40_000, "wiki").astype(np.uint8))
    text_c4 = bytes(D.gen_corpus(3, 40_000, "c4").astype(np.uint8))
    name = D.NAMES[0]
    drink = w.drink[name]
    assert f"{name} likes {drink}".encode() in text_wiki
    assert f"{name} drinks {drink}".encode() in text_c4


def test_tasks_well_formed():
    tasks = D.gen_tasks(5, seq=64, n_items=16)
    assert len(tasks) == 6
    names = {t.name for t in tasks}
    assert names == {"copy", "continuation", "arithmetic", "boolq",
                     "agreement", "truth"}
    for t in tasks:
        n = t.gold.shape[0]
        assert t.tokens.shape == (n * t.k, 64)
        assert (t.gold >= 0).all() and (t.gold < t.k).all()
        assert (t.prompt_len < t.total_len).all(), t.name
        assert (t.total_len <= 64).all()
        # Every choice row shares the item's prompt prefix.
        for i in range(n):
            p = t.prompt_len[i * t.k]
            base = t.tokens[i * t.k, :p]
            for j in range(1, t.k):
                assert np.array_equal(t.tokens[i * t.k + j, :p], base)


def test_task_gold_is_correct_fact():
    # agreement task: gold choice must be the world's color fact.
    w = D.make_world(5)
    tasks = {t.name: t for t in D.gen_tasks(5, seq=64, n_items=8)}
    t = tasks["agreement"]
    for i in range(t.gold.shape[0]):
        row = t.tokens[i * t.k + t.gold[i]]
        text = bytes(row[: t.total_len[i * t.k + t.gold[i]]]
                     .astype(np.uint8)).decode()
        # "the {animal} of {name} is {color} ."
        name = text.split(" of ")[1].split(" is ")[0]
        color = text.split(" is ")[1].split(" .")[0].strip()
        assert w.color[name] == color, text


def test_tio_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.tz")
        tensors = {
            "f": np.arange(12, dtype=np.float32).reshape(3, 4),
            "i": np.array([-1, 2, 3], dtype=np.int32),
            "u": np.array([[7, 255]], dtype=np.uint8),
        }
        tio.write_tz(path, tensors)
        back = tio.read_tz(path)
        for k, v in tensors.items():
            assert np.array_equal(back[k], v), k
            assert back[k].dtype == v.dtype
