//! Telemetry acceptance: (1) histogram quantile estimates land in the
//! same log-linear bucket as the exact nearest-rank sample quantile
//! (the ≤12.5% error bound, as a property over random magnitudes);
//! (2) the step tracer reconstructs correct per-request timelines from
//! interleaved multi-slot engine traffic and stays bounded when the
//! ring wraps; (3) end to end, the serve loop's latency histograms
//! agree with per-request `GenStats` ground truth — same integers, no
//! float round trip — and the live snapshot survives the versioned
//! JSON round trip (the ISSUE's acceptance criterion).

use nsds::coordinator::server::{serve, Client, ServedWeights,
                                ServerQueue};
use nsds::infer::{BatchEngine, GenConfig, ModelRef, NativeEngine,
                  PAGE_SIZE};
use nsds::model::{ModelConfig, Weights};
use nsds::prop_ensure;
use nsds::runtime::ModelEntry;
use nsds::telemetry::registry::bucket_index;
use nsds::telemetry::{snapshot_from_json, snapshot_to_json, Ev,
                      MetricsRegistry};
use nsds::util::json::Json;
use nsds::util::prop::check;
use nsds::util::rng::Rng;

fn tiny_model(seed: u64) -> (ModelEntry, Weights) {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(seed);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    (entry, w)
}

/// Exact nearest-rank sample quantile with the same rank formula the
/// histogram uses, so the comparison isolates bucketing error only.
fn exact_quantile(sorted: &[u64], q: f64) -> u64 {
    let rank = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank]
}

#[test]
fn histogram_quantile_lands_in_the_exact_quantiles_bucket() {
    check("hist quantile within one bucket", 60, |rng| {
        let n = 1 + rng.below(300);
        // Log-uniform magnitudes across ~16 orders (kept under 2^52 so
        // the running sum cannot wrap and stays exactly comparable).
        let mut vals: Vec<u64> = (0..n)
            .map(|_| rng.next_u64() >> (12 + rng.below(52)))
            .collect();
        let reg = MetricsRegistry::new();
        let h = reg.histogram("h");
        for &v in &vals {
            h.record(v);
        }
        vals.sort_unstable();
        let s = h.snapshot();
        prop_ensure!(s.count == n as u64, "count {} != {n}", s.count);
        let sum: u64 = vals.iter().sum();
        prop_ensure!(s.sum == sum, "sum lossy: {} != {sum}", s.sum);
        prop_ensure!(s.max == *vals.last().unwrap(), "max wrong");
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            let exact = exact_quantile(&vals, q);
            let est = s.quantile(q).expect("non-empty");
            prop_ensure!(
                bucket_index(est) == bucket_index(exact),
                "q={q}: estimate {est} (bucket {}) vs exact {exact} \
                 (bucket {}) over n={n}",
                bucket_index(est), bucket_index(exact));
            prop_ensure!(est <= s.max, "q={q}: {est} above max");
        }
        Ok(())
    });
}

/// Distinct-first-token prompts: no common prefix, so admissions never
/// share pages and every prompt token is the request's own prefill.
fn distinct_requests(rng: &mut Rng, n: usize, vocab: usize)
    -> Vec<(Vec<i32>, GenConfig)> {
    (0..n)
        .map(|i| {
            let plen = 3 + rng.below(2 * PAGE_SIZE);
            let mut prompt: Vec<i32> = (0..plen)
                .map(|_| rng.below(vocab) as i32)
                .collect();
            prompt[0] = i as i32;
            let gc = GenConfig {
                max_new: 2 + i % 4,
                seed: 50 + i as u64,
                ..GenConfig::default()
            };
            (prompt, gc)
        })
        .collect()
}

#[test]
fn tracer_timelines_reconstruct_interleaved_multi_slot_traffic() {
    let (entry, w) = tiny_model(40);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    let model = ModelRef::Dense(&w);
    let mut rng = Rng::new(41);
    let reqs = distinct_requests(&mut rng, 5, cfg.vocab);

    // 5 requests over 2 slots, the last 3 submitted mid-flight so
    // admissions interleave with running decodes and slots get reused.
    let mut engine: BatchEngine<usize> = BatchEngine::new(&cfg, 2);
    engine.enable_trace(4096);
    for i in 0..2 {
        engine.submit(i, reqs[i].0.clone(), reqs[i].1.clone()).unwrap();
    }
    let mut done = Vec::new();
    done.extend(engine.step(&exec, &entry, model).unwrap());
    done.extend(engine.step(&exec, &entry, model).unwrap());
    for i in 2..5 {
        engine.submit(i, reqs[i].0.clone(), reqs[i].1.clone()).unwrap();
    }
    while !engine.is_idle() {
        done.extend(engine.step(&exec, &entry, model).unwrap());
    }
    assert_eq!(done.len(), 5);
    assert!(engine.steps() > 0);

    let tracer = engine.tracer().expect("tracing enabled");
    // Nothing dropped at this capacity: the ring holds every event.
    assert_eq!(tracer.total(), tracer.len() as u64);

    for (tag, g) in &done {
        // rid == submit order == tag here.
        let tl = tracer.timeline(*tag as u64);
        assert!(!tl.is_empty(), "request {tag}: empty timeline");
        let plen = reqs[*tag].0.len();
        match tl[0].ev {
            Ev::Admit { rid, prompt, shared, .. } => {
                assert_eq!(rid, *tag as u64);
                assert_eq!(prompt, plen);
                assert_eq!(shared, 0,
                           "distinct prompts must not share pages");
            }
            ref e => panic!("request {tag}: timeline starts with {e:?}"),
        }
        match tl.last().unwrap().ev {
            Ev::Retire { rid, gen_tokens, .. } => {
                assert_eq!(rid, *tag as u64);
                assert_eq!(gen_tokens, g.tokens.len());
            }
            ref e => panic!("request {tag}: timeline ends with {e:?}"),
        }
        // Steps never run backwards within one request's life.
        for pair in tl.windows(2) {
            assert!(pair[0].step <= pair[1].step,
                    "request {tag}: step went backwards");
        }
        // Prefill chunks are contiguous from position 0 and cover the
        // prompt except possibly its final token (which may ride the
        // shared decode batch instead of a dedicated chunk).
        let mut next_pos = 0usize;
        let mut covered = 0usize;
        let mut decodes = 0usize;
        for e in &tl {
            match e.ev {
                Ev::PrefillChunk { pos, len, .. } => {
                    assert_eq!(pos, next_pos,
                               "request {tag}: chunk gap at {pos}");
                    next_pos = pos + len;
                    covered += len;
                }
                Ev::Decode { batch, slots_mask } => {
                    assert!(batch >= 1 && slots_mask != 0);
                    decodes += 1;
                }
                _ => {}
            }
        }
        assert!(covered == plen || covered + 1 == plen,
                "request {tag}: chunks covered {covered} of {plen}");
        // Each decode participation produced exactly one sampled token;
        // the first token may come from the final chunk's logits
        // instead, so participations are gen or gen - 1.
        let gen = g.tokens.len();
        assert!(decodes == gen || decodes + 1 == gen,
                "request {tag}: {decodes} decode participations for \
                 {gen} generated tokens");
    }
}

#[test]
fn tracer_ring_wraps_and_stays_bounded_under_long_traffic() {
    let (entry, w) = tiny_model(44);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    let model = ModelRef::Dense(&w);
    let mut rng = Rng::new(45);
    let reqs = distinct_requests(&mut rng, 4, cfg.vocab);

    let mut engine: BatchEngine<usize> = BatchEngine::new(&cfg, 2);
    engine.enable_trace(8); // far fewer than the traffic's events
    for (i, (p, gc)) in reqs.iter().enumerate() {
        engine.submit(i, p.clone(), gc.clone()).unwrap();
    }
    let done = engine.run(&exec, &entry, model).unwrap();
    assert_eq!(done.len(), 4);

    let tracer = engine.disable_trace().expect("tracing was on");
    assert_eq!(tracer.capacity(), 8);
    assert!(tracer.len() <= 8, "ring exceeded capacity");
    assert_eq!(tracer.events().len(), tracer.len());
    assert!(tracer.total() > 8,
            "traffic too small to wrap the ring ({})", tracer.total());
    assert!(engine.tracer().is_none(), "disable_trace must detach");
}

#[test]
fn served_latency_histograms_match_genstats_ground_truth() {
    let (entry, w) = tiny_model(42);
    let cfg = entry.config.clone();
    let queue = ServerQueue::new(16);
    let client = Client::new(queue.clone(), cfg.seq);

    let vocab = cfg.vocab;
    let client2 = client.clone();
    let t = std::thread::spawn(move || -> anyhow::Result<
        Vec<(u64, u64, u64)>,
    > {
        let mut rng = Rng::new(43);
        let mut out = Vec::new();
        for i in 0..12usize {
            let plen = 2 + rng.below(10);
            let prompt: Vec<i32> = (0..plen)
                .map(|_| rng.below(vocab) as i32)
                .collect();
            let gc = GenConfig {
                max_new: 2 + i % 5,
                seed: 100 + i as u64,
                ..GenConfig::default()
            };
            let g = client2.generate(prompt, gc)?;
            out.push((g.stats.prefill_ns, g.stats.ttft_ns,
                      g.stats.decode_ns));
        }
        client2.stop();
        Ok(out)
    });
    let exec = NativeEngine::with_workers(1);
    serve(&exec, &entry, 2, ServedWeights::Dense(w.clone()), &queue)
        .unwrap();
    let samples = t.join().unwrap().unwrap();

    // The server recorded the SAME integer nanoseconds each client got
    // back in its GenStats: counts, sums and maxima match exactly, and
    // histogram quantiles land in the exact sample quantile's bucket.
    let snap = queue.metrics().snapshot();
    for (name, pick) in [
        ("serve.gen.prefill_ns",
         (|s: &(u64, u64, u64)| s.0) as fn(&(u64, u64, u64)) -> u64),
        ("serve.gen.ttft_ns", |s| s.1),
        ("serve.gen.decode_ns", |s| s.2),
    ] {
        let h = snap.histograms.get(name)
            .unwrap_or_else(|| panic!("{name} not in snapshot"));
        let mut vals: Vec<u64> = samples.iter().map(pick).collect();
        vals.sort_unstable();
        assert_eq!(h.count, vals.len() as u64, "{name} count");
        assert_eq!(h.sum, vals.iter().sum::<u64>(),
                   "{name}: sum went through a lossy conversion");
        assert_eq!(h.max, *vals.last().unwrap(), "{name} max");
        for q in [0.5, 0.99] {
            let exact = exact_quantile(&vals, q);
            let est = h.quantile(q).unwrap();
            assert_eq!(
                bucket_index(est), bucket_index(exact),
                "{name} p{}: histogram {est} vs GenStats {exact} \
                 disagree beyond one bucket", (q * 100.0) as u32);
        }
    }
    assert_eq!(snap.counters["serve.gen.requests"], 12);
    let step_h = &snap.histograms["serve.engine.step_ns"];
    assert!(step_h.count > 0, "no engine steps timed");

    // The live snapshot round-trips through the versioned JSON schema,
    // and a future schema version is refused rather than misread.
    let j = snapshot_to_json(&snap);
    let back = snapshot_from_json(&Json::parse(&j.to_string()).unwrap())
        .unwrap();
    assert_eq!(back, snap);
    let mut bumped = j.clone();
    if let Json::Obj(m) = &mut bumped {
        m.insert("schema_version".into(), Json::Num(99.0));
    }
    assert!(snapshot_from_json(&bumped).is_err());
}
