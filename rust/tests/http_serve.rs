//! HTTP/SSE front-end integration: endpoints over a real TCP socket
//! against a live serve loop — streamed `/v1/generate` tokens
//! bit-identical to direct generation, `/metrics` JSON round-trips
//! through `snapshot_from_json`, and a client that disconnects
//! mid-stream gets its generation CANCELLED (the serve scheduler frees
//! the slot; `serve.gen.cancelled` counts it) instead of decoding to
//! completion.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use nsds::coordinator::http::HttpServer;
use nsds::coordinator::http::parse_sse;
use nsds::coordinator::server::{serve, Client, ServedWeights,
                                ServerQueue};
use nsds::infer::{generate, GenConfig, ModelRef, NativeEngine};
use nsds::model::ModelConfig;
use nsds::runtime::ModelEntry;
use nsds::telemetry::snapshot_from_json;
use nsds::util::json::Json;
use nsds::util::rng::Rng;

struct TestStack {
    http: HttpServer,
    queue: Arc<ServerQueue>,
    client: Client,
    serve_handle: Option<std::thread::JoinHandle<anyhow::Result<()>>>,
}

/// Serve loop on its own thread + HTTP front end on an ephemeral port.
fn stack(seed: u64) -> (TestStack, ModelEntry,
                        nsds::model::Weights) {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(seed);
    let w = nsds::model::Weights::synth(&cfg, &mut rng, &[], &[]);
    let queue = ServerQueue::new(8);
    let client = Client::new(queue.clone(), cfg.seq);
    let serve_handle = {
        let queue = queue.clone();
        let entry = entry.clone();
        let w = w.clone();
        std::thread::spawn(move || {
            let exec = NativeEngine::with_workers(1);
            serve(&exec, &entry, 2, ServedWeights::Dense(w), &queue)
        })
    };
    let http = HttpServer::bind("127.0.0.1:0", client.clone(),
                                queue.clone())
        .unwrap();
    (TestStack { http, queue, client,
                 serve_handle: Some(serve_handle) },
     entry, w)
}

impl TestStack {
    fn teardown(mut self) {
        self.client.stop();
        self.serve_handle.take().unwrap().join().unwrap().unwrap();
        self.http.shutdown();
    }
}

/// One full request/response over a fresh connection; the server
/// always closes after responding, so read-to-end terminates. Returns
/// (status line, body).
fn http_request(stack: &TestStack, req: &str) -> (String, String) {
    let mut s = TcpStream::connect(stack.http.addr()).unwrap();
    s.write_all(req.as_bytes()).unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    let (head, body) = resp.split_once("\r\n\r\n").expect("header end");
    let status = head.lines().next().unwrap().to_string();
    (status, body.to_string())
}

fn get(stack: &TestStack, path: &str) -> (String, String) {
    http_request(stack,
                 &format!("GET {path} HTTP/1.1\r\n\
                           Host: t\r\n\r\n"))
}

fn post(stack: &TestStack, path: &str, body: &str) -> (String, String) {
    http_request(stack,
                 &format!("POST {path} HTTP/1.1\r\nHost: t\r\n\
                           Content-Length: {}\r\n\r\n{body}",
                          body.len()))
}

#[test]
fn healthz_metrics_and_routing() {
    let (stack, _entry, _w) = stack(50);
    let (status, body) = get(&stack, "/healthz");
    assert!(status.contains("200"), "healthz: {status}");
    assert_eq!(body, "ok\n");

    // /metrics must serve the versioned telemetry envelope that
    // snapshot_from_json accepts — the machine-readable contract.
    let (status, body) = get(&stack, "/metrics");
    assert!(status.contains("200"), "metrics: {status}");
    let snap = snapshot_from_json(&Json::parse(&body).unwrap())
        .expect("metrics JSON must round-trip");
    assert!(snap.counters.contains_key("serve.gen.cancelled"),
            "cancel counter missing from exported metrics");
    assert!(snap.counters.contains_key("serve.dropped_replies"));

    let (status, _) = get(&stack, "/nope");
    assert!(status.contains("404"), "unknown route: {status}");
    let (status, body) = post(&stack, "/v1/generate", "{not json");
    assert!(status.contains("400"), "bad JSON: {status}");
    assert!(body.contains("error"));
    let (status, _) =
        post(&stack, "/v1/generate", r#"{"max_new": 3}"#);
    assert!(status.contains("400"), "missing prompt: {status}");
    stack.teardown();
}

#[test]
fn generate_endpoint_streams_bit_identical_tokens() {
    let (stack, entry, w) = stack(51);
    let gc = GenConfig { max_new: 6, ..GenConfig::default() };
    let exec = NativeEngine::with_workers(1);
    let direct = generate(&exec, &entry, ModelRef::Dense(&w),
                          &[1, 2, 3], &gc)
        .unwrap();

    let (status, body) = post(
        &stack, "/v1/generate",
        r#"{"prompt": [1, 2, 3], "max_new": 6}"#);
    assert!(status.contains("200"), "generate: {status}");
    let frames = parse_sse(&body).unwrap();
    let streamed: Vec<i32> = frames
        .iter()
        .filter(|(name, _)| name == "token")
        .map(|(_, d)| d.get("token").unwrap().as_f64().unwrap() as i32)
        .collect();
    assert_eq!(streamed, direct.tokens,
               "SSE tokens diverged from direct generation");
    let (name, done) = frames.last().expect("terminal frame");
    assert_eq!(name, "done");
    let done_tokens: Vec<i32> = done
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as i32)
        .collect();
    assert_eq!(done_tokens, direct.tokens);
    assert_eq!(done.get("stopped").unwrap().as_str(),
               Some("max_new"));
    assert_eq!(done.get("gen_tokens").unwrap().as_usize(),
               Some(direct.tokens.len()));
    assert_eq!(stack.queue.gen_cancelled(), 0);
    stack.teardown();
}

#[test]
fn disconnecting_client_cancels_its_generation() {
    let (stack, _entry, _w) = stack(52);
    // A generation far too long to finish fast: if cancel-on-disconnect
    // regressed, this test times out on the counter below (the request
    // decodes tens of thousands of tokens to completion) instead of
    // passing quickly.
    let body = r#"{"prompt": [1, 2, 3], "max_new": 50000}"#;
    let mut s = TcpStream::connect(stack.http.addr()).unwrap();
    write!(s, "POST /v1/generate HTTP/1.1\r\nHost: t\r\n\
               Content-Length: {}\r\n\r\n{body}", body.len())
        .unwrap();
    // Read until the first SSE frame boundary (proof the stream is
    // live and the slot is held), then hang up mid-stream.
    let mut seen = String::new();
    let mut buf = [0u8; 256];
    while !seen.contains("\n\n") {
        let n = s.read(&mut buf).unwrap();
        assert!(n > 0, "stream ended before the first token");
        seen.push_str(std::str::from_utf8(&buf[..n]).unwrap());
    }
    drop(s);

    // The conn thread's next frame write fails, dropping the GenEvents
    // receiver; the serve scheduler cancels within one step of
    // noticing. Poll the counter rather than sleeping a fixed time.
    let t0 = Instant::now();
    while stack.queue.gen_cancelled() == 0 {
        assert!(t0.elapsed() < Duration::from_secs(30),
                "disconnect never cancelled the generation");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(stack.queue.gen_cancelled(), 1);
    // The cancelled request must not count as served.
    let (gen_served, _) = stack.queue.gen_stats();
    assert_eq!(gen_served, 0);
    stack.teardown();
}
