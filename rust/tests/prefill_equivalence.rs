//! Prefill-equivalence acceptance harness: chunked prefill
//! (`Executor::prefill_chunk`) must be BIT-IDENTICAL — `assert_eq!` on
//! f32 slices, not within tolerance — to feeding the same prompt one
//! token at a time through the batched decode path. Chunking is the
//! only way prompts enter the paged pool now, so this harness carries
//! the correctness of the whole prompt-ingestion path:
//!
//! * random ragged-GQA shapes, dense AND fused-packed weights;
//! * random chunk splits whose boundaries straddle page boundaries
//!   (nothing in the kernel may depend on alignment — alignment is a
//!   scheduler optimization, not a correctness requirement);
//! * shared-prefix tails (`admit_shared` + chunked tail prefill, donors
//!   untouched);
//! * eviction-inducing overlong prompts (chunks wrap the ring through
//!   the per-row append→attend regime);
//! * mixed prefill+decode engine steps (chunked prefill admitted
//!   mid-stream, trajectories identical to solo runs);
//!
//! with `KvCachePool::check_page_accounting` asserted at every step and
//! zero pages in use after retiring everything.

use nsds::infer::{generate, BatchEngine, GenConfig, KvCachePool,
                  ModelRef, NativeEngine, QuantizedModel, Sampling,
                  PAGE_SIZE, PREFILL_CHUNK};
use nsds::model::{ModelConfig, Weights};
use nsds::prop_ensure;
use nsds::quant::Backend;
use nsds::runtime::ModelEntry;
use nsds::util::prop::check;
use nsds::util::rng::Rng;

/// Random tiny model shape covering MHA, grouped and ragged GQA; K dims
/// stay multiples of 4 (the 2-bit packing granularity) so the same
/// shapes serve packed.
fn random_config(rng: &mut Rng) -> ModelConfig {
    let n_heads = 1 + rng.below(6);
    let n_kv = 1 + rng.below(n_heads);
    ModelConfig {
        name: "prefill-prop".into(),
        vocab: 16 + rng.below(32),
        d_model: 8 + 4 * rng.below(5),
        n_heads,
        n_kv,
        d_head: 4 * (1 + rng.below(2)),
        d_ffn: 8 * (1 + rng.below(4)),
        n_layers: 1 + rng.below(3),
        seq: 4 + rng.below(9),
    }
}

fn random_tokens(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(vocab) as i32).collect()
}

/// Random packed 2/4-bit variant of `w`.
fn random_quantized(rng: &mut Rng, cfg: &ModelConfig, w: &Weights)
    -> QuantizedModel {
    let bits: Vec<u8> = (0..cfg.n_layers)
        .map(|_| if rng.f64() < 0.5 { 2 } else { 4 })
        .collect();
    let backend =
        if rng.f64() < 0.5 { Backend::Rtn } else { Backend::Hqq };
    QuantizedModel::quantize(cfg, w, &bits, 8, backend, None, 1)
}

/// Ground truth: the prompt fed ONE token per `decode_batch` step into
/// a private pool. Returns per-position logits rows.
fn per_token_logits(exec: &NativeEngine, entry: &ModelEntry,
                    model: ModelRef, prompt: &[i32], cap: usize)
                    -> Vec<Vec<f32>> {
    let mut pool = KvCachePool::for_model(&entry.config, 1);
    let s = pool.admit(cap).unwrap();
    prompt
        .iter()
        .map(|&t| {
            model
                .decode_batch(exec, entry, &mut pool, &[(s, t)])
                .unwrap()
                .into_data()
        })
        .collect()
}

/// Random chunk split of `len` positions with sizes in `1..=limit`:
/// boundaries land anywhere, straddling page boundaries at will.
fn random_chunks(rng: &mut Rng, len: usize, limit: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut left = len;
    while left > 0 {
        let n = (1 + rng.below(limit)).min(left);
        out.push(n);
        left -= n;
    }
    out
}

/// Drive chunked prefill over `splits` and compare every logits row —
/// and a few post-prefill decode steps — bitwise against the per-token
/// reference, with page accounting checked after every chunk.
fn assert_chunked_matches(exec: &NativeEngine, entry: &ModelEntry,
                          model: ModelRef, stream: &[i32],
                          prompt_len: usize, cap: usize,
                          splits: &[usize]) -> Result<(), String> {
    let reference =
        per_token_logits(exec, entry, model, stream, cap);
    let mut pool = KvCachePool::for_model(&entry.config, 1);
    let s = pool.admit(cap).unwrap();
    let mut off = 0usize;
    for &n in splits {
        let logits = model
            .prefill_chunk(exec, entry, &mut pool, s,
                           &stream[off..off + n])
            .map_err(|e| e.to_string())?;
        for i in 0..n {
            prop_ensure!(logits.row(i) == reference[off + i].as_slice(),
                         "chunk row {} (chunk at {off}, len {n}) \
                          diverged from per-token prefill", off + i);
        }
        off += n;
        prop_ensure!(pool.pos(s) == off, "pos {} != fed {off}",
                     pool.pos(s));
        pool.check_page_accounting()?;
    }
    assert_eq!(off, prompt_len, "splits must cover the prompt");
    // The cache state chunked prefill leaves behind must decode the
    // continuation identically too.
    for (i, &t) in stream.iter().enumerate().skip(prompt_len) {
        let l = model
            .decode_batch(exec, entry, &mut pool, &[(s, t)])
            .map_err(|e| e.to_string())?;
        prop_ensure!(l.data() == reference[i].as_slice(),
                     "post-prefill decode step {i} diverged");
        pool.check_page_accounting()?;
    }
    pool.retire(s);
    pool.check_page_accounting()?;
    prop_ensure!(pool.pages_in_use() == 0,
                 "pages leaked after retire: {}", pool.pages_in_use());
    Ok(())
}

#[test]
fn chunked_prefill_bit_identical_dense() {
    check("chunked == per-token prefill (dense)", 8, |rng| {
        let cfg = random_config(rng);
        let entry = ModelEntry::synthetic(cfg.clone());
        let w = Weights::synth(&cfg, rng, &[], &[]);
        let exec = NativeEngine::with_workers(1 + rng.below(3));
        // Prompt spans several pages; a short decode tail follows.
        let prompt_len = PAGE_SIZE + 1 + rng.below(2 * PAGE_SIZE + 8);
        let stream =
            random_tokens(rng, prompt_len + 3, cfg.vocab);
        let cap = stream.len() + rng.below(PAGE_SIZE);
        // Chunk sizes up to ~1.5 pages: boundaries straddle pages.
        let splits = random_chunks(rng, prompt_len,
                                   PAGE_SIZE + PAGE_SIZE / 2);
        assert_chunked_matches(&exec, &entry, ModelRef::Dense(&w),
                               &stream, prompt_len, cap, &splits)
    });
}

#[test]
fn chunked_prefill_bit_identical_packed() {
    check("chunked == per-token prefill (packed)", 5, |rng| {
        let cfg = random_config(rng);
        let entry = ModelEntry::synthetic(cfg.clone());
        let w = Weights::synth(&cfg, rng, &[], &[]);
        let qm = random_quantized(rng, &cfg, &w);
        let exec = NativeEngine::with_workers(1 + rng.below(3));
        let prompt_len = PAGE_SIZE + 1 + rng.below(2 * PAGE_SIZE + 8);
        let stream =
            random_tokens(rng, prompt_len + 3, cfg.vocab);
        let cap = stream.len() + rng.below(PAGE_SIZE);
        // Include chunks above the small-GEMM threshold (>16 rows) so
        // the packed path exercises all three fused kernels.
        let splits =
            random_chunks(rng, prompt_len, PREFILL_CHUNK);
        assert_chunked_matches(&exec, &entry, ModelRef::Packed(&qm),
                               &stream, prompt_len, cap, &splits)
    });
}

#[test]
fn chunked_prefill_overlong_prompt_evicts_identically() {
    // Prompt longer than the ring: chunks wrap, old blocks recycle in
    // place, and the evicting per-row append→attend regime must still
    // be bit-identical to per-token prefill.
    check("chunked == per-token prefill (evicting)", 6, |rng| {
        let cfg = random_config(rng);
        let entry = ModelEntry::synthetic(cfg.clone());
        let w = Weights::synth(&cfg, rng, &[], &[]);
        let exec = NativeEngine::with_workers(1);
        // A cap that is NOT page-aligned half the time, smaller than
        // the prompt, so prefill wraps the ring at least once.
        let cap = PAGE_SIZE / 2 + rng.below(2 * PAGE_SIZE);
        let prompt_len = cap + 1 + rng.below(2 * cap);
        let stream = random_tokens(rng, prompt_len + 2, cfg.vocab);
        // Chunks may not exceed the ring; sizes still random.
        let splits = random_chunks(rng, prompt_len, cap);
        if rng.f64() < 0.5 {
            assert_chunked_matches(&exec, &entry, ModelRef::Dense(&w),
                                   &stream, prompt_len, cap, &splits)
        } else {
            let qm = random_quantized(rng, &cfg, &w);
            assert_chunked_matches(&exec, &entry,
                                   ModelRef::Packed(&qm), &stream,
                                   prompt_len, cap, &splits)
        }
    });
}

#[test]
fn shared_prefix_tail_prefills_as_one_chunk() {
    // A sharer admitted from a resident donor prefills ONLY its tail,
    // in one chunk — logits bit-identical to prefilling the whole
    // prompt alone, donor pages untouched (no copy-on-write from tail
    // writes), page accounting clean throughout.
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(80);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let exec = NativeEngine::with_workers(1);
    let model = ModelRef::Dense(&w);
    let prompt_len = 2 * PAGE_SIZE + 5;
    let shared = PAGE_SIZE + 3; // one full shared page + copied tail
    let cap = prompt_len + 4;
    let prompt = random_tokens(&mut rng, prompt_len, cfg.vocab);

    let reference =
        per_token_logits(&exec, &entry, model, &prompt, cap);

    let mut pool = KvCachePool::for_model(&cfg, 2);
    let donor = pool.admit(cap).unwrap();
    // Donor prefills its whole prompt in aligned chunks.
    let mut off = 0;
    while off < prompt_len {
        let n = PREFILL_CHUNK.min(prompt_len - off);
        let l = model
            .prefill_chunk(&exec, &entry, &mut pool, donor,
                           &prompt[off..off + n])
            .unwrap();
        for i in 0..n {
            assert_eq!(l.row(i), reference[off + i].as_slice(),
                       "donor chunk row {}", off + i);
        }
        off += n;
    }
    // Sharer references the donor's full page(s) and copies the tail.
    let sharer = pool.admit_shared(cap, donor, shared).unwrap();
    assert_eq!(pool.pos(sharer), shared);
    assert_eq!(pool.shared_page_count(donor), 1);
    pool.check_page_accounting().unwrap();
    let before = pool.pages_in_use();
    // The whole un-shared tail is ONE chunk.
    let tail = model
        .prefill_chunk(&exec, &entry, &mut pool, sharer,
                       &prompt[shared..])
        .unwrap();
    for i in 0..prompt_len - shared {
        assert_eq!(tail.row(i), reference[shared + i].as_slice(),
                   "sharer tail row {} diverged", shared + i);
    }
    // Tail writes landed in the copied tail page + fresh pages: the
    // donor's shared page stayed shared (no copy-on-write), so the
    // donor is untouched.
    assert_eq!(pool.shared_page_count(donor), 1,
               "tail prefill must not copy the donor's shared page");
    assert!(pool.pages_in_use() > before);
    pool.check_page_accounting().unwrap();
    pool.retire(donor);
    pool.check_page_accounting().unwrap();
    pool.retire(sharer);
    assert_eq!(pool.pages_in_use(), 0);
}

/// Engine-level mixed load: chunked prefills and in-flight decodes
/// share steps (long prompts submitted while short ones decode, one
/// evicting cap, one pair of identical prompts driving shared-prefix
/// admission of a chunked tail) — every request's tokens must equal its
/// solo `generate` run, with page accounting checked every step.
#[test]
fn mixed_prefill_decode_engine_matches_solo() {
    check("mixed prefill+decode == solo", 4, |rng| {
        let cfg = random_config(rng);
        let entry = ModelEntry::synthetic(cfg.clone());
        let w = Weights::synth(&cfg, rng, &[], &[]);
        let exec = NativeEngine::with_workers(1);
        let model = ModelRef::Dense(&w);
        let long = PREFILL_CHUNK + 1 + rng.below(PREFILL_CHUNK);
        let shared_prompt =
            random_tokens(rng, PAGE_SIZE + 2 + rng.below(8), cfg.vocab);
        let mut reqs: Vec<(Vec<i32>, GenConfig)> = Vec::new();
        for i in 0..5 {
            let prompt = match i {
                // Two identical prompts: defer + shared-tail chunk.
                0 | 1 => shared_prompt.clone(),
                // A multi-chunk long prompt.
                2 => random_tokens(rng, long, cfg.vocab),
                _ => random_tokens(rng, 1 + rng.below(6), cfg.vocab),
            };
            let gc = GenConfig {
                max_new: 2 + rng.below(5),
                sampling: if i % 2 == 0 {
                    Sampling::Greedy
                } else {
                    Sampling::TopK { k: 4, temperature: 1.1 }
                },
                seed: 300 + i as u64,
                stop: Vec::new(),
                // Request 3 decodes (and prefills) in the evicted
                // regime: its ring is smaller than prompt + max_new.
                cap: if i == 3 { 3 } else { 0 },
                spec: None,
            };
            reqs.push((prompt, gc));
        }
        let solo: Vec<Vec<i32>> = reqs
            .iter()
            .map(|(p, gc)| {
                generate(&exec, &entry, model, p, gc).unwrap().tokens
            })
            .collect();

        let mut engine: BatchEngine<usize> = BatchEngine::new(&cfg, 2);
        // Three up front (more requests than slots), the rest join
        // mid-stream while earlier ones are prefilling/decoding.
        for (i, (p, gc)) in reqs.iter().take(3).enumerate() {
            engine.submit(i, p.clone(), gc.clone()).unwrap();
        }
        let mut submitted = 3;
        let mut done = Vec::new();
        let mut steps = 0usize;
        while !engine.is_idle() {
            done.extend(
                engine.step(&exec, &entry, model)
                    .map_err(|e| e.to_string())?);
            engine.pool().check_page_accounting()?;
            steps += 1;
            if steps == 2 && submitted < reqs.len() {
                for (i, (p, gc)) in
                    reqs.iter().enumerate().skip(submitted)
                {
                    engine.submit(i, p.clone(), gc.clone()).unwrap();
                }
                submitted = reqs.len();
            }
            prop_ensure!(steps < 10_000, "engine failed to drain");
        }
        prop_ensure!(done.len() == reqs.len(),
                     "finished {} of {}", done.len(), reqs.len());
        for (i, g) in &done {
            prop_ensure!(g.tokens == solo[*i],
                         "request {i} diverged under mixed \
                          prefill+decode batching");
            prop_ensure!(g.stats.ttft_ns >= g.stats.prefill_ns,
                         "request {i}: ttft {}ns < own prefill work \
                          {}ns",
                         g.stats.ttft_ns, g.stats.prefill_ns);
            prop_ensure!(g.stats.prompt_tokens == reqs[*i].0.len(),
                         "request {i}: prompt token count");
        }
        prop_ensure!(engine.pool().pages_in_use() == 0,
                     "pages left after drain: {}",
                     engine.pool().pages_in_use());
        Ok(())
    });
}
