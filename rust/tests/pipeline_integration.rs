//! Integration: full coordinator pipeline over real artifacts
//! (skips gracefully when `make artifacts` hasn't run).

use nsds::baselines::Method;
use nsds::coordinator::Pipeline;
use nsds::eval::EvalOptions;
use nsds::quant::Backend;
use nsds::sensitivity::Ablation;

fn pipeline() -> Option<Pipeline> {
    if !nsds::runtime::Manifest::default_dir()
        .join("manifest.json")
        .exists()
    {
        eprintln!("skipping: no artifacts/manifest.json (run `make \
                   artifacts`)");
        return None;
    }
    Some(Pipeline::new().unwrap())
}

#[test]
fn all_method_scores_are_layer_shaped_and_deterministic() {
    let Some(p) = pipeline() else { return };
    let model = "llama-s";
    let nl = p.entry(model).unwrap().config.n_layers;
    let mut methods = Method::table1();
    methods.extend(Method::fig5());
    // LLM-MQ needs loss gradients, an optional executor capability.
    if p.calibration(model).unwrap().grads.is_none() {
        eprintln!("executor has no grad collection; skipping LLM-MQ");
        methods.retain(|m| *m != Method::LlmMq);
    }
    for m in methods {
        let a = p.scores(m, model).unwrap();
        let b = p.scores(m, model).unwrap();
        assert_eq!(a.len(), nl, "{}", m.label());
        assert_eq!(a, b, "{} not deterministic", m.label());
        assert!(a.iter().all(|x| x.is_finite()), "{}: {a:?}", m.label());
        // A useful metric must discriminate: not all equal.
        let spread = a.iter().cloned().fold(f64::MIN, f64::max)
            - a.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 0.0, "{} is constant", m.label());
    }
}

#[test]
fn allocations_meet_budget_for_every_method() {
    let Some(p) = pipeline() else { return };
    let model = "qwen-s";
    let nl = p.entry(model).unwrap().config.n_layers as f64;
    for m in Method::table1() {
        for budget in [2.0, 2.5, 3.0, 3.5, 4.0] {
            let bits = p.allocate(m, model, budget).unwrap();
            let avg: f64 =
                bits.iter().map(|&b| b as f64).sum::<f64>() / nl;
            assert!(
                (avg - budget).abs() <= 1.0 / nl + 1e-9,
                "{} b̄={budget}: got {avg}",
                m.label()
            );
        }
    }
}

#[test]
fn nsds_budget_endpoints_ordered() {
    // What is actually guaranteed: the b̄=4 endpoint (uniform 4-bit) must
    // beat the b̄=2 endpoint (uniform 2-bit), and every intermediate
    // allocation stays finite and between sane bounds.
    //
    // Two *empirical negative results* deliberately NOT asserted (both
    // analysed in EXPERIMENTS.md §Divergences):
    //  * NSDS beats its anti-allocation — false here (layer 0 dominates
    //    the true sensitivity; the SE term prefers late layers);
    //  * PPL is monotone in pointwise precision — false here: raising
    //    layers 4–7 to 4-bit over uniform 2-bit *worsened* avg PPL
    //    (7.78 vs 7.51), i.e. downstream 2-bit layers partially
    //    compensate upstream quantization error, and precision unmasks
    //    it (error-compensation effect).
    let Some(p) = pipeline() else { return };
    let model = "llama-s";
    let opts = EvalOptions { max_ppl_batches: 8, max_task_items: 8,
                             gen_windows: 0 };
    let mut ppls = Vec::new();
    for budget in [2.0, 3.0, 4.0] {
        let bits = p
            .allocate(Method::Nsds(Ablation::Full), model, budget)
            .unwrap();
        let qw = p.quantize(model, &bits, Backend::Hqq).unwrap();
        let e = p.eval(model, &qw, &opts).unwrap();
        let ppl = e.avg_ppl();
        eprintln!("b̄={budget}: avg ppl {ppl:.3}");
        assert!(ppl.is_finite() && ppl > 1.0 && ppl < 256.0);
        ppls.push(ppl);
    }
    assert!(ppls[2] < ppls[0],
            "uniform 4-bit {} !< uniform 2-bit {}", ppls[2], ppls[0]);
    // And the intermediate allocation must not be wildly outside the
    // endpoint bracket (allows the compensation effect above).
    assert!(ppls[1] < ppls[0] * 1.25, "b̄=3 pathological: {ppls:?}");
}

#[test]
fn calibration_shapes_consistent() {
    let Some(p) = pipeline() else { return };
    let model = "llama-s";
    let cfg = p.entry(model).unwrap().config.clone();
    let c = p.calibration(model).unwrap();
    assert_eq!(c.resid.len(), cfg.n_layers + 1);
    assert_eq!(c.x_ln1.len(), cfg.n_layers);
    let rows = c.resid[0].rows();
    assert_eq!(rows, nsds::coordinator::CALIB_BATCHES
               * p.man.eval_batch * cfg.seq);
    assert_eq!(c.x_ln1[0].cols(), cfg.d_model);
    assert_eq!(c.attn_ctx[0].cols(), cfg.n_heads * cfg.d_head);
    assert_eq!(c.ffn_mid[0].cols(), cfg.d_ffn);
    // When the executor collects grads, every quantizable weight has a
    // correctly-shaped stacked gradient.
    if let Some(grads) = &c.grads {
        for name in nsds::model::QUANT_WEIGHTS {
            assert_eq!(grads[name].dims(),
                       cfg.weight_dims(name).as_slice());
        }
    } else {
        eprintln!("executor has no grad collection; grads are None");
    }
    assert!(c.loss.is_finite() && c.loss > 0.0);
}

#[test]
fn gptq_backend_beats_rtn_end_to_end() {
    let Some(p) = pipeline() else { return };
    let model = "llama-s";
    let opts = EvalOptions { max_ppl_batches: 8, max_task_items: 4,
                             gen_windows: 0 };
    let bits = p
        .allocate(Method::Nsds(Ablation::Full), model, 3.0)
        .unwrap();
    let q_rtn = p.quantize(model, &bits, Backend::Rtn).unwrap();
    let q_gptq = p.quantize(model, &bits, Backend::Gptq).unwrap();
    let e_rtn = p.eval(model, &q_rtn, &opts).unwrap();
    let e_gptq = p.eval(model, &q_gptq, &opts).unwrap();
    eprintln!("rtn ppl {:.3} vs gptq ppl {:.3}", e_rtn.avg_ppl(),
              e_gptq.avg_ppl());
    // GPTQ minimizes output reconstruction error; on PPL it should not be
    // meaningfully worse.
    assert!(e_gptq.avg_ppl() < e_rtn.avg_ppl() * 1.10,
            "gptq {} vs rtn {}", e_gptq.avg_ppl(), e_rtn.avg_ppl());
}
