//! Mixed-precision KV-cache acceptance: quantized K/V pages must be a
//! pure MEMORY optimization in the all-f32 mode and a gated, bounded
//! approximation in the 8/4-bit modes. Pinned here:
//!
//! * An all-16 `kv_bits` plan (explicit or `None`) is bit-identical to
//!   the pre-quantization engine token-for-token over random
//!   ragged-GQA shapes, ring caps included.
//! * Int8/int4 KV logits stay within a step-derived tolerance of the
//!   f32-KV logits on chunk-prefilled windows, and greedy agreement
//!   between quantized-KV and f32-KV engines clears a conservative
//!   floor.
//! * The paged-pool property suite — accounting, CoW sharing,
//!   divergence, `truncate`, retire-to-empty — holds verbatim under
//!   mixed per-layer bit widths, with dequantized read-back within
//!   half a quantization step of what was appended.
//! * Speculative decoding stays bit-identical to plain decode when
//!   target AND verify share one quantized pool.
//! * An NSDS-allocated plan at the bench geometry shrinks page bytes
//!   >= 3x and serves deterministically end-to-end.

use nsds::allocate::{allocate_kv_bits, average_bits};
use nsds::eval::kv::kv_greedy_agreement;
use nsds::infer::{generate_batch, generate_batch_spec, Executor,
                  GenConfig, KvCachePool, ModelRef, NativeEngine,
                  Sampling, SpecDecode};
use nsds::model::{ModelConfig, Weights};
use nsds::prop_ensure;
use nsds::runtime::ModelEntry;
use nsds::sensitivity::{nsds_layer_scores, NsdsOptions};
use nsds::util::prop::check;
use nsds::util::rng::Rng;

/// Random tiny model shape (same generator family as
/// `spec_decode.rs`): head counts drawn independently to cover MHA,
/// grouped and ragged GQA. `d_head` stays a multiple of 4 — even, as
/// int4 packing requires.
fn random_config(rng: &mut Rng) -> ModelConfig {
    let n_heads = 1 + rng.below(6);
    let n_kv = 1 + rng.below(n_heads);
    ModelConfig {
        name: "kv-prop".into(),
        vocab: 16 + rng.below(32),
        d_model: 8 + 4 * rng.below(5),
        n_heads,
        n_kv,
        d_head: 4 * (1 + rng.below(2)),
        d_ffn: 8 * (1 + rng.below(4)),
        n_layers: 1 + rng.below(3),
        seq: 8 + rng.below(9),
    }
}

fn random_tokens(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(vocab) as i32).collect()
}

fn greedy(max_new: usize, cap: usize) -> GenConfig {
    GenConfig {
        max_new,
        sampling: Sampling::Greedy,
        seed: 0,
        stop: Vec::new(),
        cap,
        spec: None,
    }
}

/// The compatibility contract: an explicit all-16 plan and `None` run
/// the IDENTICAL float operations as each other and as the
/// pre-quantization engine — token-for-token, stop-for-stop, across
/// random shapes, ragged batches, and an eviction-regime ring cap.
#[test]
fn all_f32_kv_plan_is_bit_identical_through_generate() {
    let exec = NativeEngine::with_workers(2);
    check("all-16 kv_bits == default engine", 6, |rng| {
        let cfg = random_config(rng);
        let w = Weights::synth(&cfg, rng, &[], &[]);
        let base = ModelEntry::synthetic(cfg.clone());
        let all16 = base
            .clone()
            .with_kv_bits(vec![16u8; cfg.n_layers]);
        let mut reqs = Vec::new();
        for i in 0..3 {
            let plen = 1 + rng.below(cfg.seq / 2);
            let max_new = 1 + rng.below(cfg.seq - plen);
            // One request per round decodes in the eviction regime.
            let cap = if i == 2 { plen.max(4) } else { 0 };
            reqs.push((random_tokens(rng, plen, cfg.vocab),
                       greedy(max_new, cap)));
        }
        let a = generate_batch(&exec, &base, ModelRef::Dense(&w),
                               &reqs, 2)
            .map_err(|e| e.to_string())?;
        let b = generate_batch(&exec, &all16, ModelRef::Dense(&w),
                               &reqs, 2)
            .map_err(|e| e.to_string())?;
        for (ga, gb) in a.iter().zip(&b) {
            prop_ensure!(ga.tokens == gb.tokens,
                         "tokens diverged: {:?} vs {:?}", ga.tokens,
                         gb.tokens);
            prop_ensure!(ga.stopped == gb.stopped, "stop diverged");
        }
        Ok(())
    });
}

/// Chunk-prefill a window through a pool of each precision and bound
/// the logit error. One layer, so the only approximation between the
/// two runs is the KV storage itself; tolerances are deliberately
/// loose multiples of the f32 logit spread (int8's step is ~0.4% of a
/// segment's range, int4's ~6.7% — catastrophic storage bugs miss by
/// orders of magnitude).
#[test]
fn quantized_kv_logits_stay_within_tolerance() {
    let exec = NativeEngine::with_workers(2);
    check("int8/int4 KV logits near f32", 6, |rng| {
        let mut cfg = random_config(rng);
        cfg.n_layers = 1;
        let w = Weights::synth(&cfg, rng, &[], &[]);
        let entry = ModelEntry::synthetic(cfg.clone());
        let v = cfg.vocab;
        let n = 4 + rng.below(cfg.seq - 4);
        let tokens = random_tokens(rng, n, v);
        let run = |bits: Option<u8>| -> Result<Vec<f32>, String> {
            let mut pool = match bits {
                Some(b) => KvCachePool::for_model_with_bits(
                    &cfg, 1, &vec![b; cfg.n_layers]),
                None => KvCachePool::for_model(&cfg, 1),
            };
            let slot = pool.admit(n).expect("fresh pool");
            let logits = exec
                .prefill_chunk(&entry, &mut pool, slot, &tokens, &w)
                .map_err(|e| e.to_string())?;
            Ok(logits.data().to_vec())
        };
        let lf = run(None)?;
        let spread = lf.iter().cloned().fold(f32::MIN, f32::max)
            - lf.iter().cloned().fold(f32::MAX, f32::min);
        for (b, frac) in [(8u8, 0.35f32), (4u8, 0.8f32)] {
            let lq = run(Some(b))?;
            let tol = frac * spread + 1e-4;
            for (i, (a, q)) in lf.iter().zip(&lq).enumerate() {
                prop_ensure!(
                    (a - q).abs() <= tol,
                    "int{b} logit {i}: {a} vs {q} (tol {tol})"
                );
            }
        }
        Ok(())
    });
}

/// Greedy agreement between quantized-KV and f32-KV engines on the
/// same model clears a conservative floor. Floors are far below what
/// int8/int4 actually achieve (synthetic near-uniform logits are the
/// WORST case for argmax stability — chance level is ~1/vocab ≈ 3%),
/// so a miss means structural corruption, not rounding.
#[test]
fn quantized_kv_greedy_agreement_clears_floor() {
    let exec = NativeEngine::with_workers(2);
    let mut rng = Rng::new(71);
    let mut cfg = random_config(&mut rng);
    cfg.n_layers = 2;
    cfg.seq = 16;
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let entry = ModelEntry::synthetic(cfg.clone());
    let corpus = random_tokens(&mut rng, 96, cfg.vocab);
    for (bits, floor) in [(8u8, 0.5f64), (4u8, 0.15f64)] {
        let agree = kv_greedy_agreement(
            &exec, &entry, ModelRef::Dense(&w),
            &vec![bits; cfg.n_layers], &corpus, 6, 4, 4)
            .unwrap();
        assert!(agree >= floor,
                "int{bits} agreement {agree} under floor {floor}");
    }
}

/// The paged property suite under mixed per-layer widths: bulk + per
/// row appends, CoW prefix sharing, divergence isolation, truncate
/// rollback, retire-to-empty — accounting intact after every step and
/// dequantized read-back within half a step of what was appended.
#[test]
fn page_accounting_cow_truncate_under_mixed_bits() {
    check("paged invariants, mixed kv_bits", 12, |rng| {
        let n_layers = 1 + rng.below(3);
        let nkv = 1 + rng.below(3);
        let dh = 4 * (1 + rng.below(2));
        let w = nkv * dh;
        let bits: Vec<u8> = (0..n_layers)
            .map(|_| [4u8, 8, 16][rng.below(3)])
            .collect();
        let mut pool =
            KvCachePool::with_kv_bits(n_layers, nkv, dh, 3, &bits);
        let cap = 16 + rng.below(33);
        let a = pool.admit(cap).expect("empty pool");
        // Appended rows, kept for read-back: appended[pos][layer].
        let mut appended: Vec<Vec<(Vec<f32>, Vec<f32>)>> = Vec::new();
        let rows = 1 + rng.below(cap);
        for _ in 0..rows {
            let mut per_layer = Vec::new();
            for l in 0..n_layers {
                let kr: Vec<f32> =
                    (0..w).map(|_| rng.f64() as f32 * 2.0 - 1.0)
                        .collect();
                let vr: Vec<f32> =
                    (0..w).map(|_| rng.f64() as f32 * 2.0 - 1.0)
                        .collect();
                pool.append(a, l, &kr, &vr);
                per_layer.push((kr, vr));
            }
            pool.advance(a);
            appended.push(per_layer);
        }
        pool.check_page_accounting()?;
        readback_ok(&pool, a, &bits, &appended, nkv, dh)?;

        // CoW share, then diverge the sharer by one append.
        let shared = 1 + rng.below(rows);
        let b = pool.admit_shared(cap, a, shared).expect("slot free");
        pool.check_page_accounting()?;
        for l in 0..n_layers {
            let kr = vec![0.25f32; w];
            let vr = vec![-0.5f32; w];
            pool.append(b, l, &kr, &vr);
        }
        pool.advance(b);
        pool.check_page_accounting()?;
        // The donor's rows are untouched by the sharer's divergence.
        readback_ok(&pool, a, &bits, &appended, nkv, dh)?;

        // Truncate the donor (unwrapped regime by construction).
        let new_pos = rng.below(rows);
        pool.truncate(a, new_pos);
        pool.check_page_accounting()?;
        readback_ok(&pool, a, &bits, &appended[..new_pos], nkv, dh)?;

        pool.retire(a);
        pool.retire(b);
        pool.check_page_accounting()?;
        prop_ensure!(pool.pages_in_use() == 0,
                     "pages leaked: {}", pool.pages_in_use());
        Ok(())
    });
}

/// Every appended row of every layer reads back (dequantized) within
/// half a quantization step per head segment; f32 layers exactly.
fn readback_ok(pool: &KvCachePool, slot: usize, bits: &[u8],
               appended: &[Vec<(Vec<f32>, Vec<f32>)>], nkv: usize,
               dh: usize) -> Result<(), String> {
    for (pos, per_layer) in appended.iter().enumerate() {
        for (l, (kr, vr)) in per_layer.iter().enumerate() {
            let view = pool.layer_view(l, slot);
            let loc = view.offset(pos);
            let kq = view.k_row_dequant(loc);
            let vq = view.v_row_dequant(loc);
            for h in 0..nkv {
                for (orig, got) in
                    [(kr, &kq), (vr, &vq)]
                {
                    let seg = &orig[h * dh..(h + 1) * dh];
                    let lo =
                        seg.iter().cloned().fold(f32::MAX, f32::min);
                    let hi =
                        seg.iter().cloned().fold(f32::MIN, f32::max);
                    let tol = match bits[l] {
                        16 => 0.0,
                        8 => (hi - lo) / 255.0 * 0.5 + 1e-6,
                        _ => (hi - lo) / 15.0 * 0.5 + 1e-6,
                    };
                    for i in 0..dh {
                        let g = got[h * dh + i];
                        if (seg[i] - g).abs() > tol {
                            return Err(format!(
                                "layer {l} pos {pos} head {h} elem \
                                 {i}: {} vs {g} (tol {tol})",
                                seg[i]));
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// Speculative decoding under a quantized target pool: draft, verify
/// and plain decode all read the SAME pool, so exact greedy acceptance
/// still guarantees spec == target-only token-for-token — KV precision
/// changes the tokens both paths agree on, never their agreement.
#[test]
fn spec_decode_bit_identical_under_quantized_kv() {
    let exec = NativeEngine::with_workers(2);
    let mut rng = Rng::new(72);
    for trial in 0..3 {
        let cfg = random_config(&mut rng);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let bits: Vec<u8> = (0..cfg.n_layers)
            .map(|l| [4u8, 8, 16][(l + trial) % 3])
            .collect();
        let entry =
            ModelEntry::synthetic(cfg.clone()).with_kv_bits(bits);
        let mut reqs = Vec::new();
        for _ in 0..3 {
            let plen = 1 + rng.below(cfg.seq / 2);
            let max_new = 1 + rng.below(cfg.seq - plen);
            let mut gc = greedy(max_new, 0);
            gc.spec = Some(SpecDecode { k: 1 + rng.below(4) });
            reqs.push((random_tokens(&mut rng, plen, cfg.vocab), gc));
        }
        let plain = generate_batch(&exec, &entry, ModelRef::Dense(&w),
                                   &reqs, 2)
            .unwrap();
        let spec = generate_batch_spec(&exec, &entry,
                                       ModelRef::Dense(&w),
                                       ModelRef::Dense(&w), &reqs, 2)
            .unwrap();
        for (gp, gs) in plain.iter().zip(&spec) {
            assert_eq!(gp.tokens, gs.tokens,
                       "spec diverged under quantized KV");
        }
    }
}

/// NSDS scores -> `allocate_kv_bits` -> pool layout, at a bench-like
/// KV geometry (d_head 32): the allocated plan's resident page bytes
/// shrink >= 3x vs all-f32, and the full entry-to-engine path serves
/// deterministically with the plan attached.
#[test]
fn nsds_allocated_plan_shrinks_bytes_and_serves() {
    // Layout arithmetic at the bench geometry, budget 6 bits/elem:
    // per head segment f32 = 128 B; kv8 = 32 + 8 = 40 B; kv4 = 16 + 8
    // = 24 B. A 4-layer 8/8/4/4 split gives 512/128 = 4x.
    let scores = vec![0.9, 0.7, 0.4, 0.2];
    let bits = allocate_kv_bits(&scores, 6.0);
    assert_eq!(bits, vec![8, 8, 4, 4]);
    assert_eq!(average_bits(&bits), 6.0);
    let f32_pool = KvCachePool::new(4, 2, 32, 2);
    let mixed = KvCachePool::with_kv_bits(4, 2, 32, 2, &bits);
    assert!(f32_pool.page_bytes() >= 3 * mixed.page_bytes(),
            "page bytes {} vs {}", f32_pool.page_bytes(),
            mixed.page_bytes());

    // End-to-end: score the real test model, allocate, serve twice.
    let exec = NativeEngine::with_workers(2);
    let cfg = ModelConfig::test_config();
    let mut rng = Rng::new(73);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let opts = NsdsOptions { workers: 2, ..NsdsOptions::default() };
    let scores = nsds_layer_scores(&cfg, &w, &opts);
    assert_eq!(scores.len(), cfg.n_layers);
    let plan = allocate_kv_bits(&scores, 8.0);
    let entry = ModelEntry::synthetic(cfg.clone()).with_kv_bits(plan);
    let reqs = vec![
        (random_tokens(&mut rng, 6, cfg.vocab), greedy(6, 0)),
        (random_tokens(&mut rng, 3, cfg.vocab), greedy(8, 0)),
    ];
    let a = generate_batch(&exec, &entry, ModelRef::Dense(&w), &reqs, 2)
        .unwrap();
    let b = generate_batch(&exec, &entry, ModelRef::Dense(&w), &reqs, 2)
        .unwrap();
    for (ga, gb) in a.iter().zip(&b) {
        assert!(!ga.tokens.is_empty());
        assert_eq!(ga.tokens, gb.tokens, "non-deterministic serving");
    }
}
