//! Speculative-decoding acceptance: self-speculation from the
//! quantized zoo must be a pure TARGET-PASS optimization — under
//! greedy acceptance the committed tokens are bit-identical to
//! target-only decode, whatever the drafter proposes. Pinned here:
//!
//! * `generate_batch_spec` == `generate_batch` token-for-token over
//!   random ragged-GQA shapes, dense and packed targets, a 2-bit
//!   drafter of the same weights, K ∈ {1,2,4,8}, mixed spec /
//!   non-spec requests, stop tokens and an eviction-regime cap.
//! * An identical drafter (drafter == target) accepts every draft:
//!   K + 1 tokens per verify pass, so the whole generation finishes
//!   in far fewer target passes than it has tokens.
//! * An adversarial drafter (negated unembedding — its argmax is the
//!   target's argmin) accepts nothing, commits exactly one token per
//!   verify pass, and still leaves the output bit-identical.
//! * Sequences whose ring cannot hold a verify window (eviction
//!   regime) fall back to plain decode — permanently, exactly.
//! * `SpecCounters` and the `Ev::Draft`/`Ev::Verify` trace agree
//!   with hand counts of the same run.

use nsds::infer::{generate, generate_batch, generate_batch_spec,
                  BatchEngine, GenConfig, ModelRef, NativeEngine,
                  QuantizedModel, Sampling, SpecDecode};
use nsds::model::{ModelConfig, Weights};
use nsds::prop_ensure;
use nsds::quant::Backend;
use nsds::runtime::ModelEntry;
use nsds::telemetry::Ev;
use nsds::util::prop::check;
use nsds::util::rng::Rng;

/// Random tiny model shape (same generator family as
/// `batch_decode.rs`): head counts drawn independently to cover MHA,
/// grouped and ragged GQA; K dims stay multiples of 4 so the same
/// shapes quantize to packed 2/4-bit.
fn random_config(rng: &mut Rng) -> ModelConfig {
    let n_heads = 1 + rng.below(6);
    let n_kv = 1 + rng.below(n_heads);
    ModelConfig {
        name: "spec-prop".into(),
        vocab: 16 + rng.below(32),
        d_model: 8 + 4 * rng.below(5),
        n_heads,
        n_kv,
        d_head: 4 * (1 + rng.below(2)),
        d_ffn: 8 * (1 + rng.below(4)),
        n_layers: 1 + rng.below(3),
        seq: 4 + rng.below(9),
    }
}

fn random_tokens(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(vocab) as i32).collect()
}

/// The drafter that can never agree: same weights with the
/// unembedding negated, so its argmax is the target's argmin — a
/// guaranteed-divergent proposal stream (random float logits never
/// tie argmax against argmin).
fn adversarial(w: &Weights) -> Weights {
    let mut aw = w.clone();
    let t = aw
        .tensors
        .get_mut("unembed")
        .expect("model has an unembedding");
    for v in t.data_mut() {
        *v = -*v;
    }
    aw
}

/// Bit-identity under speculation, as a property over random shapes:
/// the SAME requests through `generate_batch` (target only) and
/// `generate_batch_spec` (2-bit drafter of the same weights) must
/// produce identical tokens and stop reasons — across K ∈ {1,2,4,8},
/// spec and non-spec requests co-batched, a stop token, and one
/// eviction-regime cap that forces the spec fallback.
#[test]
fn spec_decode_is_bit_identical_to_target_only_greedy() {
    check("spec == target-only greedy", 6, |rng| {
        let cfg = random_config(rng);
        let entry = ModelEntry::synthetic(cfg.clone());
        let w = Weights::synth(&cfg, rng, &[], &[]);
        let q2 = QuantizedModel::quantize(&cfg, &w,
                                          &vec![2u8; cfg.n_layers], 8,
                                          Backend::Rtn, None, 1);
        let q4 = QuantizedModel::quantize(&cfg, &w,
                                          &vec![4u8; cfg.n_layers], 8,
                                          Backend::Rtn, None, 1);
        let drafter = ModelRef::Packed(&q2);
        let target = if rng.f64() < 0.5 {
            ModelRef::Dense(&w)
        } else {
            ModelRef::Packed(&q4)
        };
        let ks = [1usize, 2, 4, 8];
        let reqs: Vec<(Vec<i32>, GenConfig)> = (0..6)
            .map(|i| {
                let plen = 1 + rng.below(cfg.seq);
                let prompt = random_tokens(rng, plen, cfg.vocab);
                let gc = GenConfig {
                    max_new: 3 + rng.below(8),
                    sampling: Sampling::Greedy,
                    seed: 0,
                    stop: if i == 1 { vec![2] } else { Vec::new() },
                    // One request's ring is too small for any verify
                    // window: it must fall back to plain decode and
                    // STILL match the target-only run (which evicts
                    // identically).
                    cap: if i == 4 { 3 } else { 0 },
                    // Two requests decode plain alongside the
                    // speculating ones.
                    spec: if i % 3 == 2 {
                        None
                    } else {
                        Some(SpecDecode { k: ks[i % ks.len()] })
                    },
                };
                (prompt, gc)
            })
            .collect();
        let exec = NativeEngine::with_workers(1 + rng.below(3));
        let plain = generate_batch(&exec, &entry, target, &reqs, 3)
            .map_err(|e| e.to_string())?;
        let spec = generate_batch_spec(&exec, &entry, target, drafter,
                                       &reqs, 3)
            .map_err(|e| e.to_string())?;
        prop_ensure!(plain.len() == spec.len(), "result count");
        for (i, (p, s)) in plain.iter().zip(&spec).enumerate() {
            prop_ensure!(p.tokens == s.tokens,
                         "request {i}: speculation changed tokens \
                          ({:?} vs {:?}; k={:?}, nh={} nkv={} L={})",
                         p.tokens, s.tokens, reqs[i].1.spec,
                         cfg.n_heads, cfg.n_kv, cfg.n_layers);
            prop_ensure!(p.stopped == s.stopped,
                         "request {i}: stop reason drifted");
        }
        Ok(())
    });
}

/// The acceptance ceiling: with drafter == target every draft agrees,
/// so each verify pass commits exactly k + 1 tokens — including the
/// very first pass, whose row 0 samples the 1-token prompt's first
/// output. With `max_new = n·(k+1)` the whole run is exactly n
/// verify passes and nothing else: the counters come out in closed
/// form, and the engine takes max_new/(k+1) target passes for
/// max_new tokens (the tokens-per-target-step > 1 claim).
#[test]
fn identical_drafter_accepts_k_plus_one_per_verify() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(90);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let exec = NativeEngine::with_workers(1);
    let model = ModelRef::Dense(&w);
    let (k, n) = (4usize, 3u64);
    let gc = GenConfig {
        max_new: n as usize * (k + 1),
        spec: Some(SpecDecode { k }),
        ..GenConfig::default()
    };
    let prompt = vec![3i32];
    let direct =
        generate(&exec, &entry, model, &prompt, &gc).unwrap();

    let mut e: BatchEngine<usize> = BatchEngine::new(&cfg, 1);
    e.submit(0, prompt, gc.clone()).unwrap();
    // Drafter == target: self-speculation's upper bound.
    let done = e.run_spec(&exec, &entry, model, Some(model)).unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].1.tokens, direct.tokens,
               "full acceptance still must not change tokens");

    let sc = e.spec_counters();
    assert_eq!(sc.verify_steps, n);
    assert_eq!(sc.drafted, n * k as u64);
    assert_eq!(sc.accepted, n * k as u64,
               "an identical drafter must accept every draft");
    assert_eq!(sc.emitted, n * (k as u64 + 1));
    assert_eq!(sc.accept_rate(), 1.0);
    assert_eq!(sc.tokens_per_verify(), (k + 1) as f64);
    // n engine steps — one target pass each — for n·(k+1) tokens:
    // > 1 token per target pass, by exactly the k + 1 ceiling.
    assert_eq!(e.steps(), n);
    assert!(e.steps() < gc.max_new as u64);
    // Both pools drained their pages.
    assert_eq!(e.pool().pages_in_use(), 0);
    let dp = e.drafter_pool().expect("speculation engaged");
    dp.check_page_accounting().unwrap();
    assert_eq!(dp.pages_in_use(), 0);
}

/// The rejection floor: a drafter whose argmax is the target's argmin
/// never agrees — every verify pass commits exactly its one bonus
/// token (spec degrades to plain-decode pacing) and the output stays
/// bit-identical to target-only decode.
#[test]
fn adversarial_drafter_accepts_nothing_and_stays_exact() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(91);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let aw = adversarial(&w);
    let exec = NativeEngine::with_workers(1);
    let model = ModelRef::Dense(&w);
    let gc = GenConfig {
        max_new: 9,
        spec: Some(SpecDecode { k: 3 }),
        ..GenConfig::default()
    };
    let prompt = vec![1i32, 4];
    let direct =
        generate(&exec, &entry, model, &prompt, &gc).unwrap();

    let mut e: BatchEngine<usize> = BatchEngine::new(&cfg, 1);
    e.submit(0, prompt, gc).unwrap();
    let done = e
        .run_spec(&exec, &entry, model,
                  Some(ModelRef::Dense(&aw)))
        .unwrap();
    assert_eq!(done[0].1.tokens, direct.tokens,
               "total rejection still must not change tokens");
    let sc = e.spec_counters();
    assert!(sc.verify_steps > 0, "speculation never engaged");
    assert_eq!(sc.accepted, 0,
               "argmin proposals can never match the target argmax");
    assert_eq!(sc.emitted, sc.verify_steps,
               "each all-rejected pass commits exactly one token");
    assert_eq!(sc.tokens_per_verify(), 1.0);
    assert_eq!(e.pool().pages_in_use(), 0);
    assert_eq!(e.drafter_pool().unwrap().pages_in_use(), 0);
}

/// Eviction-regime fallback, both flavors: a ring that can never hold
/// a verify window keeps speculation off from the start (the drafter
/// pool is never even allocated), and a ring that fits windows early
/// but not forever turns speculation off mid-run and retires the
/// drafter slot — with tokens bit-identical to plain decode through
/// the ring-wrap regime either way.
#[test]
fn eviction_regime_falls_back_to_plain_decode() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(92);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let exec = NativeEngine::with_workers(1);
    let model = ModelRef::Dense(&w);

    // cap 4 < fed + k + 1 from the first eligible step: never spec.
    let gc = GenConfig {
        max_new: 6,
        cap: 4,
        spec: Some(SpecDecode { k: 4 }),
        ..GenConfig::default()
    };
    let prompt = random_tokens(&mut rng, 3, cfg.vocab);
    let direct = generate(&exec, &entry, model, &prompt, &gc).unwrap();
    let mut e: BatchEngine<usize> = BatchEngine::new(&cfg, 1);
    e.submit(0, prompt, gc).unwrap();
    let done = e.run_spec(&exec, &entry, model, Some(model)).unwrap();
    assert_eq!(done[0].1.tokens, direct.tokens);
    assert_eq!(e.spec_counters().verify_steps, 0,
               "a 4-slot ring cannot hold a 5-row verify window");
    assert!(e.drafter_pool().is_none(),
            "no eligible sequence, no drafter pool");

    // cap 8 fits windows while fed ≤ 5, then the gate trips: some
    // verify passes run, then plain decode wraps the ring.
    let gc = GenConfig {
        max_new: 12,
        cap: 8,
        spec: Some(SpecDecode { k: 2 }),
        ..GenConfig::default()
    };
    let prompt = random_tokens(&mut rng, 2, cfg.vocab);
    let direct = generate(&exec, &entry, model, &prompt, &gc).unwrap();
    let mut e: BatchEngine<usize> = BatchEngine::new(&cfg, 1);
    e.submit(0, prompt, gc).unwrap();
    let done = e.run_spec(&exec, &entry, model, Some(model)).unwrap();
    assert_eq!(done[0].1.tokens, direct.tokens,
               "mid-run fallback changed tokens");
    let sc = e.spec_counters();
    assert!(sc.verify_steps > 0,
            "speculation never ran before the gate tripped");
    let dp = e.drafter_pool().expect("speculation engaged");
    dp.check_page_accounting().unwrap();
    assert_eq!(dp.pages_in_use(), 0,
               "mid-run fallback leaked the drafter slot");
}

/// Mixed load through ONE engine: speculating requests (varied K),
/// plain greedy, seeded top-k and an eviction-regime cap co-batched
/// over scarce slots — every request must come out token-identical
/// to its solo `generate`, and the accounting of both pools must be
/// clean after the run.
#[test]
fn mixed_spec_and_plain_requests_share_one_engine() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(93);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let q2 = QuantizedModel::quantize(&cfg, &w,
                                      &vec![2u8; cfg.n_layers], 8,
                                      Backend::Hqq, None, 1);
    let exec = NativeEngine::with_workers(2);
    let target = ModelRef::Dense(&w);
    let ks = [1usize, 2, 4, 8];
    let reqs: Vec<(Vec<i32>, GenConfig)> = (0..7)
        .map(|i| {
            let plen = 1 + rng.below(5);
            let prompt = random_tokens(&mut rng, plen, cfg.vocab);
            let spec = (i % 2 == 0)
                .then(|| SpecDecode { k: ks[(i / 2) % ks.len()] });
            let gc = GenConfig {
                max_new: 4 + rng.below(6),
                // Speculation is greedy-only; the plain riders also
                // exercise seeded sampling next to it.
                sampling: if spec.is_some() || i == 1 {
                    Sampling::Greedy
                } else {
                    Sampling::TopK { k: 3, temperature: 0.9 }
                },
                seed: 60 + i as u64,
                stop: Vec::new(),
                cap: if i == 5 { 2 } else { 0 },
                spec,
            };
            (prompt, gc)
        })
        .collect();
    let direct: Vec<_> = reqs
        .iter()
        .map(|(p, gc)| generate(&exec, &entry, target, p, gc).unwrap())
        .collect();

    // 3 slots for 7 requests: admissions wait for retirements, so
    // spec sequences engage and retire drafter slots continuously.
    let mut e: BatchEngine<usize> = BatchEngine::new(&cfg, 3);
    for (i, (p, gc)) in reqs.iter().enumerate() {
        e.submit(i, p.clone(), gc.clone()).unwrap();
    }
    let mut done = Vec::new();
    while !e.is_idle() {
        done.extend(
            e.step_spec(&exec, &entry, target,
                        Some(ModelRef::Packed(&q2)))
                .unwrap());
        e.pool().check_page_accounting().unwrap();
        if let Some(dp) = e.drafter_pool() {
            dp.check_page_accounting().unwrap();
        }
    }
    assert_eq!(done.len(), reqs.len());
    done.sort_unstable_by_key(|(i, _)| *i);
    for ((i, g), d) in done.iter().zip(&direct) {
        assert_eq!(g.tokens, d.tokens,
                   "request {i} diverged in the mixed batch");
        assert_eq!(g.stopped, d.stopped, "request {i}: stop reason");
    }
    assert_eq!(e.pool().pages_in_use(), 0);
    assert_eq!(e.drafter_pool().unwrap().pages_in_use(), 0);
}

/// Telemetry ground truth: the `Ev::Draft`/`Ev::Verify` trace stream
/// and `SpecCounters` are two views of the same run — per-event sums
/// must reproduce the counters exactly, acceptance per verify is
/// bounded by its draft count, and the emitted total accounts for
/// every token the run committed through verify rows.
#[test]
fn spec_telemetry_matches_hand_counts() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(94);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let q2 = QuantizedModel::quantize(&cfg, &w,
                                      &vec![2u8; cfg.n_layers], 8,
                                      Backend::Rtn, None, 1);
    let exec = NativeEngine::with_workers(1);
    let target = ModelRef::Dense(&w);
    let mut e: BatchEngine<usize> = BatchEngine::new(&cfg, 2);
    e.enable_trace(4096);
    for i in 0..3usize {
        let prompt = random_tokens(&mut rng, 1 + rng.below(4),
                                   cfg.vocab);
        let gc = GenConfig {
            max_new: 8,
            spec: Some(SpecDecode { k: 2 + 2 * (i % 2) }),
            ..GenConfig::default()
        };
        e.submit(i, prompt, gc).unwrap();
    }
    let done = e
        .run_spec(&exec, &entry, target,
                  Some(ModelRef::Packed(&q2)))
        .unwrap();
    assert_eq!(done.len(), 3);

    let sc = e.spec_counters();
    let (mut drafts, mut verifies) = (0u64, 0u64);
    let (mut drafted, mut accepted) = (0u64, 0u64);
    for te in e.tracer().unwrap().events() {
        match te.ev {
            Ev::Draft { k, .. } => {
                drafts += 1;
                // Draft events carry the same k the verify scores.
                assert!(k > 0, "drafted an empty window");
            }
            Ev::Verify { drafted: d, accepted: a, .. } => {
                verifies += 1;
                drafted += d as u64;
                accepted += a as u64;
                assert!(a <= d, "accepted more than was drafted");
            }
            _ => {}
        }
    }
    assert!(verifies > 0, "run never speculated");
    assert_eq!(drafts, verifies,
               "every draft event pairs with one verify event");
    assert_eq!(verifies, sc.verify_steps);
    assert_eq!(drafted, sc.drafted);
    assert_eq!(accepted, sc.accepted);
    // Every committed token is either a plain-decode/prefill sample
    // or a verify-row commit; the verify share is what `emitted`
    // counts, and each pass commits at least its bonus token.
    assert!(sc.emitted >= sc.verify_steps);
    assert!(sc.emitted <= done.iter()
        .map(|(_, g)| g.tokens.len() as u64)
        .sum::<u64>());
    assert!(sc.accepted <= sc.emitted,
            "accepted drafts all arrive through verify rows");
    assert!(sc.emitted - sc.accepted <= sc.verify_steps,
            "at most one bonus token per verify pass");
}
