//! Integration: executor x real artifacts. Skips gracefully (with a
//! printed notice) when `artifacts/manifest.json` is absent — the
//! artifact-independent native-engine coverage lives in
//! `native_engine.rs`.

use nsds::infer::{default_executor, Executor};
use nsds::model::Weights;
use nsds::runtime::Manifest;
use nsds::util::pool::default_workers;

fn setup() -> Option<(Box<dyn Executor>, Manifest)> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {dir:?} (run `make \
                   artifacts`)");
        return None;
    }
    let m = Manifest::load(&dir).unwrap();
    let e = default_executor(&dir, default_workers()).unwrap();
    Some((e, m))
}

#[test]
fn forward_produces_finite_logits_and_low_ppl() {
    let Some((engine, man)) = setup() else { return };
    let entry = man.model("llama-s").unwrap();
    let w = Weights::load(&man.dir.join(&entry.weights_file),
                          &entry.config).unwrap();
    // First eval batch from the wiki_like corpus.
    let corpus = nsds::util::tz::read_tz(&man.dir.join(&man.corpus_file))
        .unwrap();
    let (_, wiki) = corpus["wiki_like"].as_i32().unwrap();
    let b = man.eval_batch;
    let s = entry.config.seq;
    let tokens: Vec<i32> = wiki[..b * s].to_vec();
    let logits = engine.forward(entry, &tokens, b, &w).unwrap();
    assert_eq!(logits.dims(), &[b, s, entry.config.vocab]);
    assert!(logits.data().iter().all(|x| x.is_finite()));
    // PPL of the trained model on held-out same-distribution text must be
    // far below uniform (256) — training reached ~0.35 nats on train.
    let nll = nsds::eval::ppl::batch_nll(&logits, &tokens, b, s);
    let ppl = (nll.0 / nll.1 as f64).exp();
    eprintln!("llama-s wiki_like first-batch ppl = {ppl:.3}");
    assert!(ppl < 3.0, "trained model ppl {ppl}");
}

#[test]
fn quantized_forward_degrades_gracefully() {
    let Some((engine, man)) = setup() else { return };
    let entry = man.model("llama-s").unwrap();
    let cfg = &entry.config;
    let w = Weights::load(&man.dir.join(&entry.weights_file), cfg).unwrap();
    let corpus = nsds::util::tz::read_tz(&man.dir.join(&man.corpus_file))
        .unwrap();
    let (_, wiki) = corpus["wiki_like"].as_i32().unwrap();
    let b = man.eval_batch;
    let s = cfg.seq;
    let tokens: Vec<i32> = wiki[..b * s].to_vec();

    let ppl_of = |weights: &Weights| {
        let logits = engine.forward(entry, &tokens, b, weights).unwrap();
        let (nll, n) = nsds::eval::ppl::batch_nll(&logits, &tokens, b, s);
        (nll / n as f64).exp()
    };
    let ppl_fp = ppl_of(&w);
    let q4 = nsds::quant::quantize_model(
        cfg, &w, &vec![4u8; cfg.n_layers], 32,
        nsds::quant::Backend::Hqq, None, 1);
    let ppl4 = ppl_of(&q4);
    let q2 = nsds::quant::quantize_model(
        cfg, &w, &vec![2u8; cfg.n_layers], 32,
        nsds::quant::Backend::Hqq, None, 1);
    let ppl2 = ppl_of(&q2);
    eprintln!("ppl fp={ppl_fp:.3} 4bit={ppl4:.3} 2bit={ppl2:.3}");
    assert!(ppl4 < ppl2, "4-bit must beat 2-bit");
    assert!(ppl_fp <= ppl4 * 1.05, "fp must be ~best");
}

/// Packed fused serving of real trained weights must match the
/// dequantize-then-dense forward on the native engine.
#[test]
fn packed_forward_matches_dense_on_real_weights() {
    let Some((_, man)) = setup() else { return };
    let entry = man.model("llama-s").unwrap();
    let cfg = &entry.config;
    let w = Weights::load(&man.dir.join(&entry.weights_file), cfg).unwrap();
    let native = nsds::infer::NativeEngine::new();
    let b = man.eval_batch;
    let tokens: Vec<i32> =
        (0..b * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();
    let bits: Vec<u8> = (0..cfg.n_layers)
        .map(|l| if l % 2 == 0 { 4 } else { 2 })
        .collect();
    let qm = nsds::infer::QuantizedModel::quantize(
        cfg, &w, &bits, 32, nsds::quant::Backend::Hqq, None, 2);
    let fused = native.forward_packed(entry, &tokens, b, &qm).unwrap();
    let dense = native
        .forward(entry, &tokens, b, &qm.dequantized_weights())
        .unwrap();
    let err = fused.sub(&dense).frob_norm()
        / dense.frob_norm().max(1e-9);
    eprintln!("packed-vs-dense rel err on real weights: {err:.2e}");
    assert!(err < 1e-4, "rel err {err}");
}

/// The standalone Pallas kernel artifacts compile, and the fused
/// dequant kernels agree numerically with the rust dequantize
/// reference (PJRT only).
#[cfg(feature = "xla")]
#[test]
fn standalone_kernel_artifacts_execute() {
    use nsds::quant::{pack, rtn, QuantSpec};
    use nsds::runtime::Input;
    use nsds::tensor::Tensor;
    use nsds::util::rng::Rng;

    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let man = Manifest::load(&dir).unwrap();
    let engine = nsds::runtime::Engine::cpu(&dir).unwrap();
    let mut rng = Rng::new(123);
    for k in &man.kernels {
        engine.load(&k.file).unwrap_or_else(|e| {
            panic!("kernel {} failed to compile: {e}", k.file)
        });
        if !k.file.starts_with("dequant") {
            continue;
        }
        let w = Tensor::randn(vec![k.k, k.n], &mut rng).scale(0.05);
        let x = Tensor::randn(vec![k.m, k.k], &mut rng);
        let q = rtn::quantize(&w, QuantSpec::new(k.bits, k.group));
        let packed = pack::pack(&q.codes, k.k, k.n, k.bits);
        let scale = Tensor::new(q.scale.clone(), vec![k.k / k.group, k.n]);
        let zero = Tensor::new(q.zero.clone(), vec![k.k / k.group, k.n]);
        let out = engine
            .execute(&k.file, &[
                Input::F32(&x),
                Input::U8(&packed, vec![k.k * k.bits as usize / 8, k.n]),
                Input::F32(&scale),
                Input::F32(&zero),
            ])
            .unwrap();
        let yref = nsds::tensor::matmul::matmul(&x, &q.dequantize());
        let err = out[0].sub(&yref).frob_norm() / yref.frob_norm();
        eprintln!("kernel {}: rel-err {err:.2e}", k.file);
        assert!(err < 1e-4, "kernel {} mismatch: {err}", k.file);
    }
}
