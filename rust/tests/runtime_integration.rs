//! Integration: PJRT engine x real artifacts (skips if artifacts missing).
use std::path::Path;

use nsds::model::Weights;
use nsds::runtime::{run_forward, Engine, Manifest};

fn setup() -> Option<(Engine, Manifest)> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return None;
    }
    let m = Manifest::load(&dir).unwrap();
    let e = Engine::cpu(&dir).unwrap();
    Some((e, m))
}

#[test]
fn forward_produces_finite_logits_and_low_ppl() {
    let Some((engine, man)) = setup() else { return };
    let entry = man.model("llama-s").unwrap();
    let w = Weights::load(&man.dir.join(&entry.weights_file),
                          &entry.config).unwrap();
    // First eval batch from the wiki_like corpus.
    let corpus = nsds::util::tz::read_tz(&man.dir.join(&man.corpus_file))
        .unwrap();
    let (_, wiki) = corpus["wiki_like"].as_i32().unwrap();
    let b = man.eval_batch;
    let s = entry.config.seq;
    let tokens: Vec<i32> = wiki[..b * s].to_vec();
    let logits = run_forward(&engine, entry, &tokens, b, &w).unwrap();
    assert_eq!(logits.dims(), &[b, s, entry.config.vocab]);
    assert!(logits.data().iter().all(|x| x.is_finite()));
    // PPL of the trained model on held-out same-distribution text must be
    // far below uniform (256) — training reached ~0.35 nats on train.
    let nll = nsds::eval::ppl::batch_nll(&logits, &tokens, b, s);
    let ppl = (nll.0 / nll.1 as f64).exp();
    eprintln!("llama-s wiki_like first-batch ppl = {ppl:.3}");
    assert!(ppl < 3.0, "trained model ppl {ppl}");
}

#[test]
fn quantized_forward_degrades_gracefully() {
    let Some((engine, man)) = setup() else { return };
    let entry = man.model("llama-s").unwrap();
    let cfg = &entry.config;
    let w = Weights::load(&man.dir.join(&entry.weights_file), cfg).unwrap();
    let corpus = nsds::util::tz::read_tz(&man.dir.join(&man.corpus_file))
        .unwrap();
    let (_, wiki) = corpus["wiki_like"].as_i32().unwrap();
    let b = man.eval_batch;
    let s = cfg.seq;
    let tokens: Vec<i32> = wiki[..b * s].to_vec();

    let ppl_of = |weights: &Weights| {
        let logits = run_forward(&engine, entry, &tokens, b, weights)
            .unwrap();
        let (nll, n) = nsds::eval::ppl::batch_nll(&logits, &tokens, b, s);
        (nll / n as f64).exp()
    };
    let ppl_fp = ppl_of(&w);
    let q4 = nsds::quant::quantize_model(
        cfg, &w, &vec![4u8; cfg.n_layers], 32,
        nsds::quant::Backend::Hqq, None, 1);
    let ppl4 = ppl_of(&q4);
    let q2 = nsds::quant::quantize_model(
        cfg, &w, &vec![2u8; cfg.n_layers], 32,
        nsds::quant::Backend::Hqq, None, 1);
    let ppl2 = ppl_of(&q2);
    eprintln!("ppl fp={ppl_fp:.3} 4bit={ppl4:.3} 2bit={ppl2:.3}");
    assert!(ppl4 < ppl2, "4-bit must beat 2-bit");
    assert!(ppl_fp <= ppl4 * 1.05, "fp must be ~best");
}

#[test]
fn standalone_kernel_artifacts_execute() {
    let Some((engine, man)) = setup() else { return };
    for k in &man.kernels {
        engine.load(&k.file).unwrap_or_else(|e| {
            panic!("kernel {} failed to compile: {e}", k.file)
        });
    }
    let _ = Path::new(".");
}
