//! Continuous-batching acceptance property: batched decode over a
//! multi-sequence cache pool must produce, per sequence and per step,
//! the same logits as running `decode_step` on a private single-sequence
//! cache — within 1e-4 — on the dense AND fused-packed paths, on random
//! ragged-GQA shapes, with staggered admission/retirement (sequences
//! join and leave mid-stream, slots are reused) and ring eviction
//! triggered in at least one slot. Plus the generation-level property:
//! `generate_batch` returns token-for-token what sequential `generate`
//! returns for each request, regardless of co-batching.
//!
//! Paged-pool acceptance rides on the same drivers (the pool IS paged
//! now — every equivalence case also exercises block tables, lazy page
//! allocation and eviction-as-block-recycle), plus dedicated coverage:
//! a block-accounting property (after ANY interleaving of
//! admit/admit_shared/append/evict/reset/retire, every page is
//! referenced exactly `refcount` times and free-listed iff refcount 0),
//! a shared-prefix decode test (two sequences admitted from one prompt
//! share prefix pages — refcount > 1 — until the first divergent write
//! copies, with outputs IDENTICAL to unshared decoding), and the
//! engine-level prefix-aware admission test.

use nsds::infer::{generate, generate_batch, BatchEngine, GenConfig,
                  KvCache, KvCachePool, ModelRef, NativeEngine,
                  QuantizedModel, Sampling, PAGE_SIZE};
use nsds::model::{ModelConfig, Weights};
use nsds::prop_ensure;
use nsds::quant::Backend;
use nsds::runtime::ModelEntry;
use nsds::util::prop::check;
use nsds::util::rng::Rng;

/// Random tiny model shape; the head counts are drawn independently so
/// the cases cover MHA (nkv == nh), grouped (nkv | nh) and ragged GQA.
/// Every projection's K dim stays a multiple of 4, the 2-bit packing
/// granularity, so the same shapes serve packed.
fn random_config(rng: &mut Rng) -> ModelConfig {
    let n_heads = 1 + rng.below(6);
    let n_kv = 1 + rng.below(n_heads);
    ModelConfig {
        name: "prop".into(),
        vocab: 16 + rng.below(32),
        d_model: 8 + 4 * rng.below(5),
        n_heads,
        n_kv,
        d_head: 4 * (1 + rng.below(2)),
        d_ffn: 8 * (1 + rng.below(4)),
        n_layers: 1 + rng.below(3),
        seq: 4 + rng.below(9),
    }
}

fn random_tokens(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(vocab) as i32).collect()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// One decoding request: a token stream and its ring capacity (caps
/// smaller than the stream trigger sliding-window eviction — in BOTH
/// drivers, which must agree on the evicted regime too).
struct Stream {
    tokens: Vec<i32>,
    cap: usize,
}

/// Ground truth: each stream decoded alone through `decode_step` on its
/// own single-sequence cache. Returns per-stream, per-step logits.
fn sequential_logits(exec: &NativeEngine, entry: &ModelEntry,
                     model: ModelRef, streams: &[Stream])
                     -> anyhow::Result<Vec<Vec<Vec<f32>>>> {
    let cfg = &entry.config;
    let mut out = Vec::with_capacity(streams.len());
    for s in streams {
        let mut cache = KvCache::new(cfg.n_layers, cfg.n_kv, cfg.d_head,
                                     s.cap);
        let mut rows = Vec::with_capacity(s.tokens.len());
        for &t in &s.tokens {
            let l = model.decode_step(exec, entry, &mut cache, t)?;
            rows.push(l.into_data());
        }
        out.push(rows);
    }
    Ok(out)
}

/// The batched driver: a pool with FEWER slots than streams, admission
/// staggered by `stagger` steps, retirement as each stream ends — so
/// sequences join and leave mid-stream and freed slots are reused by
/// later admissions while survivors keep decoding uninterrupted.
fn batched_logits(exec: &NativeEngine, entry: &ModelEntry,
                  model: ModelRef, streams: &[Stream], max_slots: usize,
                  stagger: usize)
                  -> anyhow::Result<Vec<Vec<Vec<f32>>>> {
    let cfg = &entry.config;
    let v = cfg.vocab;
    let mut pool = KvCachePool::for_model(cfg, max_slots);
    let mut out: Vec<Vec<Vec<f32>>> =
        streams.iter().map(|_| Vec::new()).collect();
    // (stream index, slot, tokens fed so far)
    let mut active: Vec<(usize, usize, usize)> = Vec::new();
    let mut next_admit = 0usize;
    let mut step = 0usize;
    let mut saw_mixed_batch = false;
    while next_admit < streams.len() || !active.is_empty() {
        while next_admit < streams.len()
            && step >= next_admit * stagger
            && pool.free_count() > 0
        {
            let slot = pool.admit(streams[next_admit].cap).unwrap();
            active.push((next_admit, slot, 0));
            next_admit += 1;
        }
        step += 1;
        if active.is_empty() {
            continue; // stagger gap before the next admission is due
        }
        saw_mixed_batch |= active.len() > 1;
        let batch: Vec<(usize, i32)> = active
            .iter()
            .map(|&(si, slot, fed)| (slot, streams[si].tokens[fed]))
            .collect();
        let logits = model.decode_batch(exec, entry, &mut pool, &batch)?;
        assert_eq!(logits.dims(), &[batch.len(), v]);
        let mut keep = Vec::with_capacity(active.len());
        for (ri, (si, slot, fed)) in active.drain(..).enumerate() {
            out[si].push(logits.row(ri).to_vec());
            if fed + 1 == streams[si].tokens.len() {
                pool.retire(slot); // leave mid-stream; slot is reusable
            } else {
                keep.push((si, slot, fed + 1));
            }
        }
        active = keep;
        // The paged pool's block accounting must hold at every step of
        // the interleaving, not just at the end.
        pool.check_page_accounting()
            .map_err(|e| anyhow::anyhow!("page accounting: {e}"))?;
    }
    assert!(saw_mixed_batch || streams.len() == 1,
            "driver never batched >1 sequence");
    assert_eq!(pool.active_count(), 0);
    assert_eq!(pool.pages_in_use(), 0,
               "retiring every slot must release every page");
    Ok(out)
}

/// Random streams: varied lengths, slots scarcer than streams, and
/// stream 0 capped below its length so its ring evicts mid-run.
fn random_streams(rng: &mut Rng, cfg: &ModelConfig) -> Vec<Stream> {
    let n = 3 + rng.below(3); // 3..=5 sequences over 2 slots
    (0..n)
        .map(|i| {
            let len = cfg.seq + rng.below(cfg.seq.max(2));
            let tokens = random_tokens(rng, len, cfg.vocab);
            // Eviction in at least one slot; exact decode in the rest.
            let cap = if i == 0 { (len / 2).max(1) } else { len };
            Stream { tokens, cap }
        })
        .collect()
}

fn compare(seq: &[Vec<Vec<f32>>], bat: &[Vec<Vec<f32>>]) -> f32 {
    let mut worst = 0.0f32;
    for (s, b) in seq.iter().zip(bat) {
        assert_eq!(s.len(), b.len(), "step-count mismatch");
        for (srow, brow) in s.iter().zip(b) {
            worst = worst.max(max_abs_diff(srow, brow));
        }
    }
    worst
}

#[test]
fn batched_decode_matches_sequential_dense() {
    check("batched == sequential decode (dense)", 10, |rng| {
        let cfg = random_config(rng);
        let entry = ModelEntry::synthetic(cfg.clone());
        let w = Weights::synth(&cfg, rng, &[], &[]);
        let exec = NativeEngine::with_workers(1 + rng.below(3));
        let streams = random_streams(rng, &cfg);
        let stagger = 1 + rng.below(3);
        let seq = sequential_logits(&exec, &entry, ModelRef::Dense(&w),
                                    &streams)
            .map_err(|e| e.to_string())?;
        let bat = batched_logits(&exec, &entry, ModelRef::Dense(&w),
                                 &streams, 2, stagger)
            .map_err(|e| e.to_string())?;
        let worst = compare(&seq, &bat);
        prop_ensure!(worst < 1e-4,
                     "dense batched decode diverged: {worst} \
                      (nh={} nkv={} dh={} L={} streams={} stagger={})",
                     cfg.n_heads, cfg.n_kv, cfg.d_head, cfg.n_layers,
                     streams.len(), stagger);
        Ok(())
    });
}

#[test]
fn batched_decode_matches_sequential_packed() {
    check("batched == sequential decode (packed)", 6, |rng| {
        let cfg = random_config(rng);
        let entry = ModelEntry::synthetic(cfg.clone());
        let w = Weights::synth(&cfg, rng, &[], &[]);
        let bits: Vec<u8> = (0..cfg.n_layers)
            .map(|_| if rng.f64() < 0.5 { 2 } else { 4 })
            .collect();
        let backend =
            if rng.f64() < 0.5 { Backend::Rtn } else { Backend::Hqq };
        let qm = QuantizedModel::quantize(&cfg, &w, &bits, 8, backend,
                                          None, 1);
        let exec = NativeEngine::with_workers(1 + rng.below(3));
        let streams = random_streams(rng, &cfg);
        let stagger = 1 + rng.below(3);
        let seq = sequential_logits(&exec, &entry, ModelRef::Packed(&qm),
                                    &streams)
            .map_err(|e| e.to_string())?;
        let bat = batched_logits(&exec, &entry, ModelRef::Packed(&qm),
                                 &streams, 2, stagger)
            .map_err(|e| e.to_string())?;
        let worst = compare(&seq, &bat);
        prop_ensure!(worst < 1e-4,
                     "packed batched decode diverged: {worst} \
                      (bits {bits:?}, nh={} nkv={} dh={} stagger={})",
                     cfg.n_heads, cfg.n_kv, cfg.d_head, stagger);
        Ok(())
    });
}

/// Generation-level: a continuous batch with more requests than slots
/// (mixed greedy / seeded top-k, a stop token, an evicting cap) must
/// reproduce each request's sequential `generate` output exactly.
#[test]
fn generate_batch_matches_sequential_generate() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(70);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let qm = QuantizedModel::quantize(&cfg, &w,
                                      &vec![4u8; cfg.n_layers], 8,
                                      Backend::Hqq, None, 1);
    let exec = NativeEngine::with_workers(2);
    for model in [ModelRef::Dense(&w), ModelRef::Packed(&qm)] {
        let reqs: Vec<(Vec<i32>, GenConfig)> = (0..7)
            .map(|i| {
                let plen = 1 + rng.below(5);
                let prompt = random_tokens(&mut rng, plen, cfg.vocab);
                let sampling = if i % 2 == 0 {
                    Sampling::Greedy
                } else {
                    Sampling::TopK { k: 4, temperature: 1.1 }
                };
                let gc = GenConfig {
                    max_new: 3 + rng.below(6),
                    sampling,
                    seed: 40 + i as u64,
                    stop: if i == 2 { vec![1] } else { Vec::new() },
                    // One request decodes in the evicted regime.
                    cap: if i == 3 { 2 } else { 0 },
                    spec: None,
                };
                (prompt, gc)
            })
            .collect();
        let direct: Vec<_> = reqs
            .iter()
            .map(|(p, gc)| generate(&exec, &entry, model, p, gc).unwrap())
            .collect();
        // 3 slots for 7 requests: admissions wait for retirements.
        let batched =
            generate_batch(&exec, &entry, model, &reqs, 3).unwrap();
        assert_eq!(batched.len(), direct.len());
        for (i, (b, d)) in batched.iter().zip(&direct).enumerate() {
            assert_eq!(b.tokens, d.tokens,
                       "request {i}: batched generation diverged");
            assert_eq!(b.stopped, d.stopped, "request {i}: stop reason");
            assert_eq!(b.stats.prompt_tokens, d.stats.prompt_tokens);
            assert_eq!(b.stats.gen_tokens, d.stats.gen_tokens);
        }
    }
}

/// Block accounting: after ANY interleaving of admit / shared admit /
/// append-bursts (driving lazy allocation, ring eviction and
/// copy-on-write) / reset / retire, every page is referenced by block
/// tables exactly `refcount` times and sits on the free list iff its
/// refcount is 0 — no leaks, no double frees — and retiring every slot
/// returns every page.
#[test]
fn paged_block_accounting_over_random_interleavings() {
    check("page accounting invariant", 12, |rng| {
        let n_layers = 1 + rng.below(3);
        let nkv = 1 + rng.below(2);
        let dh = 2 * (1 + rng.below(2));
        let max_slots = 2 + rng.below(3);
        let mut pool = KvCachePool::new(n_layers, nkv, dh, max_slots);
        let w = nkv * dh;
        let mut held: Vec<usize> = Vec::new();
        for _ in 0..200 {
            match rng.below(10) {
                0 | 1 => {
                    let cap = 1 + rng.below(3 * PAGE_SIZE);
                    if let Some(s) = pool.admit(cap) {
                        held.push(s);
                    }
                }
                2 | 3 => {
                    // Shared admission from a random eligible donor.
                    if !held.is_empty() {
                        let donor = held[rng.below(held.len())];
                        let dpos = pool.pos(donor);
                        if dpos > 0 && dpos <= pool.capacity(donor) {
                            let shared = 1 + rng.below(dpos);
                            let cap = shared + rng.below(2 * PAGE_SIZE);
                            if let Some(s) =
                                pool.admit_shared(cap, donor, shared)
                            {
                                held.push(s);
                            }
                        }
                    }
                }
                4 => {
                    if !held.is_empty() {
                        let i = rng.below(held.len());
                        pool.retire(held.swap_remove(i));
                    }
                }
                5 => {
                    if !held.is_empty() {
                        pool.reset(held[rng.below(held.len())]);
                    }
                }
                _ => {
                    // Append burst: drives lazy page allocation, wraps
                    // small rings (eviction = block recycle) and forces
                    // copy-on-write into shared pages.
                    if !held.is_empty() {
                        let s = held[rng.below(held.len())];
                        for _ in 0..1 + rng.below(PAGE_SIZE) {
                            for l in 0..n_layers {
                                pool.append(s, l, &vec![1.0; w],
                                            &vec![2.0; w]);
                            }
                            pool.advance(s);
                        }
                    }
                }
            }
            pool.check_page_accounting()?;
        }
        for s in held {
            pool.retire(s);
        }
        pool.check_page_accounting()?;
        prop_ensure!(pool.pages_in_use() == 0,
                     "pages leaked after retiring every slot: {}",
                     pool.pages_in_use());
        Ok(())
    });
}

/// Shared-prefix acceptance: stream B admitted from stream A's resident
/// prompt prefix must (1) reference A's full prefix pages (refcount >
/// 1) until the first divergent write, (2) copy on that write leaving
/// A's rows intact, and (3) produce logits IDENTICAL — bitwise, not
/// just within tolerance — to decoding B in its own unshared pool,
/// through the tail AND through the ring-wrap/eviction regime.
#[test]
fn shared_prefix_decode_identical_to_unshared() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(72);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let exec = NativeEngine::with_workers(1);
    let model = ModelRef::Dense(&w);
    let prefix_len = PAGE_SIZE + 4; // one full shared page + a tail
    let cap = prefix_len + 8; // tails push past cap → wrap → CoW
    let tail_len = 10;
    let prefix = random_tokens(&mut rng, prefix_len, cfg.vocab);
    let tails: Vec<Vec<i32>> = (0..2)
        .map(|_| random_tokens(&mut rng, tail_len, cfg.vocab))
        .collect();

    // Unshared references: each stream decoded alone in its own pool.
    let mut refs: Vec<Vec<Vec<f32>>> = Vec::new();
    for tail in &tails {
        let mut pool = KvCachePool::for_model(&cfg, 1);
        let s = pool.admit(cap).unwrap();
        let mut rows = Vec::new();
        for &t in prefix.iter().chain(tail) {
            let l = model
                .decode_batch(&exec, &entry, &mut pool, &[(s, t)])
                .unwrap();
            rows.push(l.into_data());
        }
        refs.push(rows);
    }

    // Shared: decode A through the prefix, fork B from A's pages.
    let mut pool = KvCachePool::for_model(&cfg, 2);
    let a = pool.admit(cap).unwrap();
    for (i, &t) in prefix.iter().enumerate() {
        let l = model
            .decode_batch(&exec, &entry, &mut pool, &[(a, t)])
            .unwrap();
        assert_eq!(l.row(0), refs[0][i].as_slice(), "prefill step {i}");
    }
    let b = pool.admit_shared(cap, a, prefix_len).unwrap();
    assert_eq!(pool.pos(b), prefix_len);
    assert_eq!(pool.shared_page_count(a), 1,
               "the full prefix page must be shared");
    assert_eq!(pool.shared_page_count(b), 1);
    // One full page shared + donor tail + copied tail = 3 pages, vs 4
    // for two unshared prefixes.
    assert_eq!(pool.pages_in_use(), 3);
    pool.check_page_accounting().unwrap();

    let mut saw_cow = false;
    for step in 0..tail_len {
        let active = [(a, tails[0][step]), (b, tails[1][step])];
        let l = model
            .decode_batch(&exec, &entry, &mut pool, &active)
            .unwrap();
        for (ri, r) in refs.iter().enumerate() {
            assert_eq!(l.row(ri), r[prefix_len + step].as_slice(),
                       "stream {ri} diverged at tail step {step}");
        }
        pool.check_page_accounting().unwrap();
        // Once a ring wraps into the shared page, copy-on-write must
        // have split it.
        if pool.pos(a) > cap {
            saw_cow = true;
            assert_eq!(pool.shared_page_count(a), 0,
                       "divergent write left the page shared");
        }
    }
    assert!(saw_cow, "test never exercised the copy-on-write wrap");
    pool.retire(a);
    pool.check_page_accounting().unwrap();
    pool.retire(b);
    assert_eq!(pool.pages_in_use(), 0);
}

/// Engine-level prefix-aware admission: two requests with the same
/// prompt through one `BatchEngine` must share prefix pages (the second
/// admits by reference after the first prefills), save at least a
/// page's worth of prefill, and still generate token-for-token what
/// each request generates alone.
#[test]
fn batch_engine_shared_prefix_admission_matches_solo() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(73);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let exec = NativeEngine::with_workers(1);
    let model = ModelRef::Dense(&w);
    // Longer than one page, so the engine defers the second request
    // until the first has the shared prefix resident, then admits it by
    // page reference.
    let prompt = random_tokens(&mut rng, PAGE_SIZE + 6, cfg.vocab);
    let mk = |seed: u64| GenConfig {
        max_new: 5,
        sampling: Sampling::TopK { k: 3, temperature: 0.9 },
        seed,
        ..GenConfig::default()
    };
    let direct: Vec<_> = [11u64, 12]
        .iter()
        .map(|&s| {
            generate(&exec, &entry, model, &prompt, &mk(s)).unwrap()
        })
        .collect();

    let mut engine: BatchEngine<usize> = BatchEngine::new(&cfg, 2);
    engine.submit(0, prompt.clone(), mk(11)).unwrap();
    engine.submit(1, prompt.clone(), mk(12)).unwrap();
    let mut saw_shared_pages = false;
    let mut done = Vec::new();
    while !engine.is_idle() {
        done.extend(engine.step(&exec, &entry, model).unwrap());
        let pool = engine.pool();
        saw_shared_pages |= (0..pool.max_slots()).any(|s| {
            pool.is_active(s) && pool.shared_page_count(s) > 0
        });
        pool.check_page_accounting().unwrap();
    }
    assert!(saw_shared_pages, "identical prompts never shared a page");
    assert!(engine.shared_prefix_tokens() as usize >= PAGE_SIZE,
            "only {} prompt tokens admitted by reference",
            engine.shared_prefix_tokens());
    done.sort_unstable_by_key(|(i, _)| *i);
    assert_eq!(done.len(), 2);
    for ((i, g), d) in done.iter().zip(&direct) {
        assert_eq!(g.tokens, d.tokens,
                   "request {i} diverged under prefix sharing");
        assert_eq!(g.stopped, d.stopped, "request {i} stop reason");
    }
    assert_eq!(engine.pool().pages_in_use(), 0);
}

/// Mixed-load scheduling: a long prompt submitted while other
/// sequences are mid-decode is admitted as a CHUNKED prefill (whole
/// windows per step, finishing in far fewer steps than it has tokens)
/// — and the in-flight decode trajectories stay token-identical to
/// solo runs, as does the late-joining long request itself.
#[test]
fn chunked_prefill_mid_stream_leaves_decoders_token_identical() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(74);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let exec = NativeEngine::with_workers(1);
    let model = ModelRef::Dense(&w);

    let mk = |seed: u64, prompt: Vec<i32>, max_new: usize| {
        (prompt, GenConfig {
            max_new,
            sampling: Sampling::TopK { k: 3, temperature: 0.9 },
            seed,
            ..GenConfig::default()
        })
    };
    // Two short decoders in flight, then a 3-page prompt joins.
    let long_len = 3 * PAGE_SIZE + 5;
    let reqs = [
        mk(21, random_tokens(&mut rng, 3, cfg.vocab), 12),
        mk(22, random_tokens(&mut rng, 4, cfg.vocab), 12),
        mk(23, random_tokens(&mut rng, long_len, cfg.vocab), 4),
    ];
    let direct: Vec<_> = reqs
        .iter()
        .map(|(p, gc)| generate(&exec, &entry, model, p, gc).unwrap())
        .collect();

    let mut engine: BatchEngine<usize> = BatchEngine::new(&cfg, 3);
    engine.submit(0, reqs[0].0.clone(), reqs[0].1.clone()).unwrap();
    engine.submit(1, reqs[1].0.clone(), reqs[1].1.clone()).unwrap();
    let mut finished = Vec::new();
    for _ in 0..3 {
        finished.extend(engine.step(&exec, &entry, model).unwrap());
        engine.pool().check_page_accounting().unwrap();
    }
    // Both short requests are decoding when the long prompt arrives.
    engine.submit(2, reqs[2].0.clone(), reqs[2].1.clone()).unwrap();
    let mut steps = 3usize;
    while !engine.is_idle() {
        finished.extend(engine.step(&exec, &entry, model).unwrap());
        engine.pool().check_page_accounting().unwrap();
        steps += 1;
        assert!(steps < 1000, "engine failed to drain");
    }
    // Chunked prefill: the whole run takes far fewer steps than the
    // long prompt has tokens (per-token prefill alone would need
    // `long_len` steps).
    assert!(steps < long_len,
            "{steps} steps for a {long_len}-token prompt — prefill \
             fell back to per-token pacing");
    assert_eq!(finished.len(), 3);
    finished.sort_unstable_by_key(|(i, _)| *i);
    for ((i, g), d) in finished.iter().zip(&direct) {
        assert_eq!(g.tokens, d.tokens,
                   "request {i} diverged under mixed prefill+decode");
        assert_eq!(g.stopped, d.stopped, "request {i} stop reason");
        assert!(g.stats.ttft_ns >= g.stats.prefill_ns,
                "request {i}: ttft below own prefill work");
    }
    assert_eq!(engine.pool().pages_in_use(), 0);
}

/// The engine surface the server schedules through: submissions while
/// the engine is mid-stream are admitted as slots free up, outputs are
/// unaffected by what co-batches, and bad prompts are rejected upfront.
#[test]
fn batch_engine_mid_stream_submission_and_validation() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(71);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let exec = NativeEngine::with_workers(1);
    let model = ModelRef::Dense(&w);

    let mk = |seed: u64, plen: usize, rng: &mut Rng| {
        let prompt = random_tokens(rng, plen, cfg.vocab);
        let gc = GenConfig {
            max_new: 5,
            sampling: Sampling::TopK { k: 3, temperature: 0.9 },
            seed,
            ..GenConfig::default()
        };
        (prompt, gc)
    };
    let a = mk(1, 3, &mut rng);
    let b = mk(2, 5, &mut rng);
    let c = mk(3, 2, &mut rng);
    let direct: Vec<_> = [&a, &b, &c]
        .iter()
        .map(|(p, gc)| generate(&exec, &entry, model, p, gc).unwrap())
        .collect();

    let mut engine: BatchEngine<&'static str> =
        BatchEngine::new(&cfg, 2);
    assert!(engine.check(&[]).is_err());
    assert!(engine.check(&[cfg.vocab as i32]).is_err());
    assert!(engine
        .submit("bad", vec![-1], GenConfig::default())
        .is_err());
    assert!(engine.is_idle());

    engine.submit("a", a.0.clone(), a.1.clone()).unwrap();
    engine.submit("b", b.0.clone(), b.1.clone()).unwrap();
    let mut finished = Vec::new();
    // Run a few steps with both slots occupied, then submit c
    // mid-stream — it must wait for a retirement, then join.
    for _ in 0..3 {
        finished.extend(engine.step(&exec, &entry, model).unwrap());
    }
    assert_eq!(engine.in_flight(), 2);
    engine.submit("c", c.0.clone(), c.1.clone()).unwrap();
    assert_eq!(engine.in_flight(), 3);
    while !engine.is_idle() {
        finished.extend(engine.step(&exec, &entry, model).unwrap());
    }
    assert_eq!(finished.len(), 3);
    for (tag, gen) in finished {
        let want = match tag {
            "a" => &direct[0],
            "b" => &direct[1],
            "c" => &direct[2],
            _ => unreachable!(),
        };
        assert_eq!(gen.tokens, want.tokens, "request {tag}");
        assert_eq!(gen.stopped, want.stopped, "request {tag}");
    }
    // Idle engine steps are no-ops.
    assert!(engine.step(&exec, &entry, model).unwrap().is_empty());
}

/// Tracing is observation only: the SAME requests through two
/// identically-configured engines — one with the flight recorder on,
/// one without — produce bit-identical tokens and stop reasons, even
/// with top-k sampling, shared-prefix deferral and slot reuse in play.
/// This pins the "near-zero cost when disabled / zero interference when
/// enabled" telemetry contract.
#[test]
fn enabling_tracing_leaves_generation_bit_identical() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(81);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let exec = NativeEngine::with_workers(1);
    let model = ModelRef::Dense(&w);

    // Two identical long prompts (forces defer + shared-prefix + CoW)
    // plus two distinct short ones, 4 requests over 2 slots.
    let long = random_tokens(&mut rng, PAGE_SIZE + 6, cfg.vocab);
    let mk = |seed: u64, prompt: &[i32]| {
        (prompt.to_vec(), GenConfig {
            max_new: 5,
            sampling: Sampling::TopK { k: 3, temperature: 0.9 },
            seed,
            ..GenConfig::default()
        })
    };
    let reqs = [
        mk(31, &long),
        mk(32, &long),
        mk(33, &random_tokens(&mut rng, 3, cfg.vocab)),
        mk(34, &random_tokens(&mut rng, 7, cfg.vocab)),
    ];

    let run = |trace: bool| {
        let mut engine: BatchEngine<usize> = BatchEngine::new(&cfg, 2);
        if trace {
            engine.enable_trace(1024);
        }
        for (i, (p, gc)) in reqs.iter().enumerate() {
            engine.submit(i, p.clone(), gc.clone()).unwrap();
        }
        let mut done = engine.run(&exec, &entry, model).unwrap();
        done.sort_unstable_by_key(|(i, _)| *i);
        let events = engine
            .tracer()
            .map(|t| t.total())
            .unwrap_or(0);
        (done, events)
    };

    let (plain, ev_off) = run(false);
    let (traced, ev_on) = run(true);
    assert_eq!(ev_off, 0, "disabled tracer recorded events");
    assert!(ev_on > 0, "enabled tracer recorded nothing");
    assert_eq!(plain.len(), traced.len());
    for ((i, a), (_, b)) in plain.iter().zip(&traced) {
        assert_eq!(a.tokens, b.tokens,
                   "request {i}: tracing changed generated tokens");
        assert_eq!(a.stopped, b.stopped,
                   "request {i}: tracing changed the stop reason");
        assert_eq!(a.stats.prompt_tokens, b.stats.prompt_tokens);
        assert_eq!(a.stats.gen_tokens, b.stats.gen_tokens);
    }
}
