//! Continuous-batching acceptance property: batched decode over a
//! multi-sequence cache pool must produce, per sequence and per step,
//! the same logits as running `decode_step` on a private single-sequence
//! cache — within 1e-4 — on the dense AND fused-packed paths, on random
//! ragged-GQA shapes, with staggered admission/retirement (sequences
//! join and leave mid-stream, slots are reused) and ring eviction
//! triggered in at least one slot. Plus the generation-level property:
//! `generate_batch` returns token-for-token what sequential `generate`
//! returns for each request, regardless of co-batching.

use nsds::infer::{generate, generate_batch, BatchEngine, GenConfig,
                  KvCache, KvCachePool, ModelRef, NativeEngine,
                  QuantizedModel, Sampling};
use nsds::model::{ModelConfig, Weights};
use nsds::prop_ensure;
use nsds::quant::Backend;
use nsds::runtime::ModelEntry;
use nsds::util::prop::check;
use nsds::util::rng::Rng;

/// Random tiny model shape; the head counts are drawn independently so
/// the cases cover MHA (nkv == nh), grouped (nkv | nh) and ragged GQA.
/// Every projection's K dim stays a multiple of 4, the 2-bit packing
/// granularity, so the same shapes serve packed.
fn random_config(rng: &mut Rng) -> ModelConfig {
    let n_heads = 1 + rng.below(6);
    let n_kv = 1 + rng.below(n_heads);
    ModelConfig {
        name: "prop".into(),
        vocab: 16 + rng.below(32),
        d_model: 8 + 4 * rng.below(5),
        n_heads,
        n_kv,
        d_head: 4 * (1 + rng.below(2)),
        d_ffn: 8 * (1 + rng.below(4)),
        n_layers: 1 + rng.below(3),
        seq: 4 + rng.below(9),
    }
}

fn random_tokens(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(vocab) as i32).collect()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// One decoding request: a token stream and its ring capacity (caps
/// smaller than the stream trigger sliding-window eviction — in BOTH
/// drivers, which must agree on the evicted regime too).
struct Stream {
    tokens: Vec<i32>,
    cap: usize,
}

/// Ground truth: each stream decoded alone through `decode_step` on its
/// own single-sequence cache. Returns per-stream, per-step logits.
fn sequential_logits(exec: &NativeEngine, entry: &ModelEntry,
                     model: ModelRef, streams: &[Stream])
                     -> anyhow::Result<Vec<Vec<Vec<f32>>>> {
    let cfg = &entry.config;
    let mut out = Vec::with_capacity(streams.len());
    for s in streams {
        let mut cache = KvCache::new(cfg.n_layers, cfg.n_kv, cfg.d_head,
                                     s.cap);
        let mut rows = Vec::with_capacity(s.tokens.len());
        for &t in &s.tokens {
            let l = model.decode_step(exec, entry, &mut cache, t)?;
            rows.push(l.into_data());
        }
        out.push(rows);
    }
    Ok(out)
}

/// The batched driver: a pool with FEWER slots than streams, admission
/// staggered by `stagger` steps, retirement as each stream ends — so
/// sequences join and leave mid-stream and freed slots are reused by
/// later admissions while survivors keep decoding uninterrupted.
fn batched_logits(exec: &NativeEngine, entry: &ModelEntry,
                  model: ModelRef, streams: &[Stream], max_slots: usize,
                  stagger: usize)
                  -> anyhow::Result<Vec<Vec<Vec<f32>>>> {
    let cfg = &entry.config;
    let v = cfg.vocab;
    let mut pool = KvCachePool::for_model(cfg, max_slots);
    let mut out: Vec<Vec<Vec<f32>>> =
        streams.iter().map(|_| Vec::new()).collect();
    // (stream index, slot, tokens fed so far)
    let mut active: Vec<(usize, usize, usize)> = Vec::new();
    let mut next_admit = 0usize;
    let mut step = 0usize;
    let mut saw_mixed_batch = false;
    while next_admit < streams.len() || !active.is_empty() {
        while next_admit < streams.len()
            && step >= next_admit * stagger
            && pool.free_count() > 0
        {
            let slot = pool.admit(streams[next_admit].cap).unwrap();
            active.push((next_admit, slot, 0));
            next_admit += 1;
        }
        step += 1;
        if active.is_empty() {
            continue; // stagger gap before the next admission is due
        }
        saw_mixed_batch |= active.len() > 1;
        let batch: Vec<(usize, i32)> = active
            .iter()
            .map(|&(si, slot, fed)| (slot, streams[si].tokens[fed]))
            .collect();
        let logits = model.decode_batch(exec, entry, &mut pool, &batch)?;
        assert_eq!(logits.dims(), &[batch.len(), v]);
        let mut keep = Vec::with_capacity(active.len());
        for (ri, (si, slot, fed)) in active.drain(..).enumerate() {
            out[si].push(logits.row(ri).to_vec());
            if fed + 1 == streams[si].tokens.len() {
                pool.retire(slot); // leave mid-stream; slot is reusable
            } else {
                keep.push((si, slot, fed + 1));
            }
        }
        active = keep;
    }
    assert!(saw_mixed_batch || streams.len() == 1,
            "driver never batched >1 sequence");
    assert_eq!(pool.active_count(), 0);
    Ok(out)
}

/// Random streams: varied lengths, slots scarcer than streams, and
/// stream 0 capped below its length so its ring evicts mid-run.
fn random_streams(rng: &mut Rng, cfg: &ModelConfig) -> Vec<Stream> {
    let n = 3 + rng.below(3); // 3..=5 sequences over 2 slots
    (0..n)
        .map(|i| {
            let len = cfg.seq + rng.below(cfg.seq.max(2));
            let tokens = random_tokens(rng, len, cfg.vocab);
            // Eviction in at least one slot; exact decode in the rest.
            let cap = if i == 0 { (len / 2).max(1) } else { len };
            Stream { tokens, cap }
        })
        .collect()
}

fn compare(seq: &[Vec<Vec<f32>>], bat: &[Vec<Vec<f32>>]) -> f32 {
    let mut worst = 0.0f32;
    for (s, b) in seq.iter().zip(bat) {
        assert_eq!(s.len(), b.len(), "step-count mismatch");
        for (srow, brow) in s.iter().zip(b) {
            worst = worst.max(max_abs_diff(srow, brow));
        }
    }
    worst
}

#[test]
fn batched_decode_matches_sequential_dense() {
    check("batched == sequential decode (dense)", 10, |rng| {
        let cfg = random_config(rng);
        let entry = ModelEntry::synthetic(cfg.clone());
        let w = Weights::synth(&cfg, rng, &[], &[]);
        let exec = NativeEngine::with_workers(1 + rng.below(3));
        let streams = random_streams(rng, &cfg);
        let stagger = 1 + rng.below(3);
        let seq = sequential_logits(&exec, &entry, ModelRef::Dense(&w),
                                    &streams)
            .map_err(|e| e.to_string())?;
        let bat = batched_logits(&exec, &entry, ModelRef::Dense(&w),
                                 &streams, 2, stagger)
            .map_err(|e| e.to_string())?;
        let worst = compare(&seq, &bat);
        prop_ensure!(worst < 1e-4,
                     "dense batched decode diverged: {worst} \
                      (nh={} nkv={} dh={} L={} streams={} stagger={})",
                     cfg.n_heads, cfg.n_kv, cfg.d_head, cfg.n_layers,
                     streams.len(), stagger);
        Ok(())
    });
}

#[test]
fn batched_decode_matches_sequential_packed() {
    check("batched == sequential decode (packed)", 6, |rng| {
        let cfg = random_config(rng);
        let entry = ModelEntry::synthetic(cfg.clone());
        let w = Weights::synth(&cfg, rng, &[], &[]);
        let bits: Vec<u8> = (0..cfg.n_layers)
            .map(|_| if rng.f64() < 0.5 { 2 } else { 4 })
            .collect();
        let backend =
            if rng.f64() < 0.5 { Backend::Rtn } else { Backend::Hqq };
        let qm = QuantizedModel::quantize(&cfg, &w, &bits, 8, backend,
                                          None, 1);
        let exec = NativeEngine::with_workers(1 + rng.below(3));
        let streams = random_streams(rng, &cfg);
        let stagger = 1 + rng.below(3);
        let seq = sequential_logits(&exec, &entry, ModelRef::Packed(&qm),
                                    &streams)
            .map_err(|e| e.to_string())?;
        let bat = batched_logits(&exec, &entry, ModelRef::Packed(&qm),
                                 &streams, 2, stagger)
            .map_err(|e| e.to_string())?;
        let worst = compare(&seq, &bat);
        prop_ensure!(worst < 1e-4,
                     "packed batched decode diverged: {worst} \
                      (bits {bits:?}, nh={} nkv={} dh={} stagger={})",
                     cfg.n_heads, cfg.n_kv, cfg.d_head, stagger);
        Ok(())
    });
}

/// Generation-level: a continuous batch with more requests than slots
/// (mixed greedy / seeded top-k, a stop token, an evicting cap) must
/// reproduce each request's sequential `generate` output exactly.
#[test]
fn generate_batch_matches_sequential_generate() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(70);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let qm = QuantizedModel::quantize(&cfg, &w,
                                      &vec![4u8; cfg.n_layers], 8,
                                      Backend::Hqq, None, 1);
    let exec = NativeEngine::with_workers(2);
    for model in [ModelRef::Dense(&w), ModelRef::Packed(&qm)] {
        let reqs: Vec<(Vec<i32>, GenConfig)> = (0..7)
            .map(|i| {
                let plen = 1 + rng.below(5);
                let prompt = random_tokens(&mut rng, plen, cfg.vocab);
                let sampling = if i % 2 == 0 {
                    Sampling::Greedy
                } else {
                    Sampling::TopK { k: 4, temperature: 1.1 }
                };
                let gc = GenConfig {
                    max_new: 3 + rng.below(6),
                    sampling,
                    seed: 40 + i as u64,
                    stop: if i == 2 { vec![1] } else { Vec::new() },
                    // One request decodes in the evicted regime.
                    cap: if i == 3 { 2 } else { 0 },
                };
                (prompt, gc)
            })
            .collect();
        let direct: Vec<_> = reqs
            .iter()
            .map(|(p, gc)| generate(&exec, &entry, model, p, gc).unwrap())
            .collect();
        // 3 slots for 7 requests: admissions wait for retirements.
        let batched =
            generate_batch(&exec, &entry, model, &reqs, 3).unwrap();
        assert_eq!(batched.len(), direct.len());
        for (i, (b, d)) in batched.iter().zip(&direct).enumerate() {
            assert_eq!(b.tokens, d.tokens,
                       "request {i}: batched generation diverged");
            assert_eq!(b.stopped, d.stopped, "request {i}: stop reason");
            assert_eq!(b.stats.prompt_tokens, d.stats.prompt_tokens);
            assert_eq!(b.stats.gen_tokens, d.stats.gen_tokens);
        }
    }
}

/// The engine surface the server schedules through: submissions while
/// the engine is mid-stream are admitted as slots free up, outputs are
/// unaffected by what co-batches, and bad prompts are rejected upfront.
#[test]
fn batch_engine_mid_stream_submission_and_validation() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(71);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let exec = NativeEngine::with_workers(1);
    let model = ModelRef::Dense(&w);

    let mk = |seed: u64, plen: usize, rng: &mut Rng| {
        let prompt = random_tokens(rng, plen, cfg.vocab);
        let gc = GenConfig {
            max_new: 5,
            sampling: Sampling::TopK { k: 3, temperature: 0.9 },
            seed,
            ..GenConfig::default()
        };
        (prompt, gc)
    };
    let a = mk(1, 3, &mut rng);
    let b = mk(2, 5, &mut rng);
    let c = mk(3, 2, &mut rng);
    let direct: Vec<_> = [&a, &b, &c]
        .iter()
        .map(|(p, gc)| generate(&exec, &entry, model, p, gc).unwrap())
        .collect();

    let mut engine: BatchEngine<&'static str> =
        BatchEngine::new(&cfg, 2);
    assert!(engine.check(&[]).is_err());
    assert!(engine.check(&[cfg.vocab as i32]).is_err());
    assert!(engine
        .submit("bad", vec![-1], GenConfig::default())
        .is_err());
    assert!(engine.is_idle());

    engine.submit("a", a.0.clone(), a.1.clone()).unwrap();
    engine.submit("b", b.0.clone(), b.1.clone()).unwrap();
    let mut finished = Vec::new();
    // Run a few steps with both slots occupied, then submit c
    // mid-stream — it must wait for a retirement, then join.
    for _ in 0..3 {
        finished.extend(engine.step(&exec, &entry, model).unwrap());
    }
    assert_eq!(engine.in_flight(), 2);
    engine.submit("c", c.0.clone(), c.1.clone()).unwrap();
    assert_eq!(engine.in_flight(), 3);
    while !engine.is_idle() {
        finished.extend(engine.step(&exec, &entry, model).unwrap());
    }
    assert_eq!(finished.len(), 3);
    for (tag, gen) in finished {
        let want = match tag {
            "a" => &direct[0],
            "b" => &direct[1],
            "c" => &direct[2],
            _ => unreachable!(),
        };
        assert_eq!(gen.tokens, want.tokens, "request {tag}");
        assert_eq!(gen.stopped, want.stopped, "request {tag}");
    }
    // Idle engine steps are no-ops.
    assert!(engine.step(&exec, &entry, model).unwrap().is_empty());
}
