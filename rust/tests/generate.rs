//! Generation-path coverage: deterministic-seed greedy/top-k golden
//! tests over the KV-cached decode loop, generation-based eval scoring,
//! and concurrent generation requests through `server::serve` (results
//! identical to direct single-threaded generation — no interleaving
//! corruption — and server stats consistent).

use std::collections::BTreeMap;

use nsds::coordinator::server::{serve, Client, ServedWeights,
                                ServerQueue};
use nsds::infer::{generate, Executor, GenConfig, Generation, KvCache,
                  ModelRef, NativeEngine, QuantizedModel, Sampling,
                  StopReason, PAGE_SIZE};
use nsds::model::{ModelConfig, Weights, WEIGHT_NAMES};
use nsds::quant::Backend;
use nsds::runtime::ModelEntry;
use nsds::util::rng::Rng;

fn tiny_model(seed: u64) -> (ModelEntry, Weights) {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(seed);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    (entry, w)
}

/// Identity embed/unembed with zero projections: the model predicts
/// "repeat the last token" (see native_engine.rs golden test).
fn repeat_model() -> (ModelEntry, Weights) {
    let cfg = ModelConfig {
        name: "ident".into(),
        vocab: 8,
        d_model: 8,
        n_heads: 2,
        n_kv: 2,
        d_head: 2,
        d_ffn: 8,
        n_layers: 1,
        seq: 8,
    };
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut tensors = BTreeMap::new();
    for name in WEIGHT_NAMES {
        let dims = cfg.weight_dims(name);
        let n: usize = dims.iter().product();
        let t = match name {
            "embed" | "unembed" => {
                let scale = if name == "embed" { 5.0 } else { 20.0 };
                let mut m = nsds::tensor::Tensor::zeros(dims);
                for i in 0..cfg.vocab {
                    m.set(i, i, scale);
                }
                m
            }
            "lnf" | "ln1" | "ln2" => {
                nsds::tensor::Tensor::new(vec![1.0; n], dims)
            }
            _ => nsds::tensor::Tensor::zeros(dims),
        };
        tensors.insert(name.to_string(), t);
    }
    (entry, Weights { tensors })
}

#[test]
fn greedy_repeats_on_the_repeat_model() {
    let (entry, w) = repeat_model();
    let exec = NativeEngine::with_workers(1);
    let gc = GenConfig { max_new: 6, ..GenConfig::default() };
    let g = generate(&exec, &entry, ModelRef::Dense(&w), &[3, 3], &gc)
        .unwrap();
    assert_eq!(g.tokens, vec![3; 6]);
    assert_eq!(g.stopped, StopReason::MaxNew);
    assert_eq!(g.stats.prompt_tokens, 2);
    assert_eq!(g.stats.gen_tokens, 6);
}

#[test]
fn greedy_first_token_matches_decode_argmax() {
    let (entry, w) = tiny_model(90);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    let prompt: Vec<i32> = vec![1, 4, 2, 7];
    // Expected: argmax of the last prompt position's decode logits.
    let mut cache = KvCache::for_model(&cfg, prompt.len() + 1);
    let mut last = None;
    for &t in &prompt {
        last = Some(exec.decode_step(&entry, &mut cache, t, &w).unwrap());
    }
    let logits = last.unwrap();
    let expect = logits
        .data()
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as i32;
    let gc = GenConfig { max_new: 1, ..GenConfig::default() };
    let g = generate(&exec, &entry, ModelRef::Dense(&w), &prompt, &gc)
        .unwrap();
    assert_eq!(g.tokens, vec![expect]);
}

#[test]
fn generation_is_seed_deterministic_and_seed_sensitive() {
    let (entry, w) = tiny_model(91);
    let exec = NativeEngine::with_workers(2);
    let prompt = vec![0i32, 5, 9];
    let gen = |seed: u64| -> Generation {
        let gc = GenConfig {
            max_new: 12,
            sampling: Sampling::TopK { k: 6, temperature: 1.2 },
            seed,
            ..GenConfig::default()
        };
        generate(&exec, &entry, ModelRef::Dense(&w), &prompt, &gc)
            .unwrap()
    };
    let a = gen(7);
    let b = gen(7);
    assert_eq!(a.tokens, b.tokens, "same seed must reproduce exactly");
    let c = gen(8);
    // With k=6 over 12 draws, two seeds agreeing everywhere is
    // vanishingly unlikely — and would indicate the seed is ignored.
    assert_ne!(a.tokens, c.tokens, "different seeds never diverged");
}

#[test]
fn stop_token_and_max_new_conditions() {
    let (entry, w) = repeat_model();
    let exec = NativeEngine::with_workers(1);
    // The repeat model emits 3 forever: stopping on 3 ends immediately.
    let gc = GenConfig {
        max_new: 10,
        stop: vec![3],
        ..GenConfig::default()
    };
    let g = generate(&exec, &entry, ModelRef::Dense(&w), &[3, 3], &gc)
        .unwrap();
    assert_eq!(g.tokens, vec![3]);
    assert_eq!(g.stopped, StopReason::StopToken(3));
    // A stop token the model never emits: runs to max_new.
    let gc2 = GenConfig {
        max_new: 4,
        stop: vec![5],
        ..GenConfig::default()
    };
    let g2 = generate(&exec, &entry, ModelRef::Dense(&w), &[3], &gc2)
        .unwrap();
    assert_eq!(g2.tokens.len(), 4);
    assert_eq!(g2.stopped, StopReason::MaxNew);
    // Stats sanity: TTFT covers the request's own prefill work (its
    // chunks all run inside the admission → first-token window), in
    // integer nanoseconds end-to-end.
    assert!(g2.stats.ttft_ns >= g2.stats.prefill_ns);
    assert!(g2.stats.total_ns() >= g2.stats.decode_ns);
    assert_eq!(g2.stats.total_ns(),
               g2.stats.ttft_ns + g2.stats.decode_ns);
    assert!(g2.stats.ttft_s() >= g2.stats.prefill_s());
    assert!(g2.stats.decode_tok_per_s() >= 0.0);
}

#[test]
fn packed_and_dense_variants_generate_identically_here() {
    // 4-bit HQQ on the tiny model is accurate enough that greedy
    // decoding follows the FP32 trajectory — the generation-level check
    // that packed serving preserves behavior, plus eval::gen coverage.
    let (entry, w) = tiny_model(92);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    let qm = QuantizedModel::quantize(&cfg, &w,
                                      &vec![4u8; cfg.n_layers], 8,
                                      Backend::Hqq, None, 1);
    let mut rng = Rng::new(5);
    let corpus: Vec<i32> = (0..8 * cfg.seq)
        .map(|_| rng.below(cfg.vocab) as i32)
        .collect();
    let agree = nsds::eval::gen::greedy_agreement(
        &exec, &entry, ModelRef::Dense(&w), ModelRef::Packed(&qm),
        &corpus, 6, 4, 6)
    .unwrap();
    assert!(agree > 0.5, "4-bit greedy agreement only {agree}");
    let cm = nsds::eval::gen::continuation_match(
        &exec, &entry, ModelRef::Dense(&w), &corpus, 6, 4, 6)
    .unwrap();
    assert!((0.0..=1.0).contains(&cm));
}

#[test]
fn in_context_scoring_matches_plain_with_empty_context() {
    let (entry, w) = tiny_model(96);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    let mut rng = Rng::new(7);
    let corpus: Vec<i32> = (0..8 * cfg.seq)
        .map(|_| rng.below(cfg.vocab) as i32)
        .collect();
    let plain = nsds::eval::gen::continuation_match(
        &exec, &entry, ModelRef::Dense(&w), &corpus, 6, 4, 6)
    .unwrap();
    let empty_ctx = nsds::eval::gen::continuation_match_in_context(
        &exec, &entry, ModelRef::Dense(&w), &[], &corpus, 6, 4, 6)
    .unwrap();
    assert_eq!(plain, empty_ctx);
    // A real shared context (longer than one page, so the batched
    // engine keeps it resident once and shares its pages — the page
    // mechanics themselves are pinned in batch_decode.rs): the metric
    // stays a valid fraction, and a variant always agrees with itself.
    let ctx: Vec<i32> = (0..PAGE_SIZE + 4)
        .map(|_| rng.below(cfg.vocab) as i32)
        .collect();
    let with_ctx = nsds::eval::gen::continuation_match_in_context(
        &exec, &entry, ModelRef::Dense(&w), &ctx, &corpus, 6, 4, 6)
    .unwrap();
    assert!((0.0..=1.0).contains(&with_ctx));
    let ga = nsds::eval::gen::greedy_agreement_in_context(
        &exec, &entry, ModelRef::Dense(&w), ModelRef::Dense(&w), &ctx,
        &corpus, 6, 4, 4)
    .unwrap();
    assert_eq!(ga, 1.0, "a variant must agree with itself in context");
}

#[test]
fn server_shares_prefix_pages_across_identical_prompts() {
    // Two identical prompts queued before the serve loop starts: the
    // scheduler admits the first, defers the second until the shared
    // prefix is resident, then admits it by page reference — outputs
    // unchanged, and the saved prefill shows up in gen_shared().
    let (entry, w) = tiny_model(97);
    let cfg = entry.config.clone();
    let queue = ServerQueue::new(8);
    let client = Client::new(queue.clone(), cfg.seq);
    let prompt: Vec<i32> = (0..PAGE_SIZE + 6)
        .map(|i| ((i * 3) % cfg.vocab) as i32)
        .collect();
    let gc = GenConfig { max_new: 4, ..GenConfig::default() };
    let exec = NativeEngine::with_workers(1);
    let direct = generate(&exec, &entry, ModelRef::Dense(&w), &prompt,
                          &gc)
        .unwrap()
        .tokens;
    let rx1 = client.submit_generate(prompt.clone(), gc.clone()).unwrap();
    let rx2 = client.submit_generate(prompt.clone(), gc.clone()).unwrap();
    client.stop();
    serve(&exec, &entry, 2, ServedWeights::Dense(w.clone()), &queue)
        .unwrap();
    let g1 = rx1.recv().unwrap().unwrap();
    let g2 = rx2.recv().unwrap().unwrap();
    assert_eq!(g1.tokens, direct);
    assert_eq!(g2.tokens, direct,
               "prefix sharing changed a served generation");
    assert!(queue.gen_shared() as usize >= PAGE_SIZE,
            "server admitted only {} prompt tokens by page reference",
            queue.gen_shared());
}

#[test]
fn concurrent_generation_through_server_matches_direct() {
    let (entry, w) = tiny_model(93);
    let cfg = entry.config.clone();
    let qm = QuantizedModel::quantize(&cfg, &w, &[4, 2, 4], 8,
                                      Backend::Hqq, None, 2);
    let exec = NativeEngine::with_workers(2);

    // 9 requests: distinct prompts, mixed greedy/top-k, distinct seeds.
    let mut rng = Rng::new(6);
    let reqs: Vec<(Vec<i32>, GenConfig)> = (0..9)
        .map(|i| {
            let plen = 2 + rng.below(5);
            let prompt: Vec<i32> = (0..plen)
                .map(|_| rng.below(cfg.vocab) as i32)
                .collect();
            let sampling = if i % 2 == 0 {
                Sampling::Greedy
            } else {
                Sampling::TopK { k: 5, temperature: 1.0 }
            };
            let gc = GenConfig {
                max_new: 6,
                sampling,
                seed: 100 + i as u64,
                ..GenConfig::default()
            };
            (prompt, gc)
        })
        .collect();

    // Ground truth: direct, sequential generation.
    let expected: Vec<Vec<i32>> = reqs
        .iter()
        .map(|(p, gc)| {
            generate(&exec, &entry, ModelRef::Packed(&qm), p, gc)
                .unwrap()
                .tokens
        })
        .collect();

    // Same requests through the serve loop, from 3 client threads, with
    // NLL requests interleaved to exercise mixed batching.
    let queue = ServerQueue::new(6);
    let handles: Vec<_> = (0..3)
        .map(|t| {
            let client = Client::new(queue.clone(), cfg.seq);
            let my: Vec<(usize, (Vec<i32>, GenConfig))> = reqs
                .iter()
                .cloned()
                .enumerate()
                .filter(|(i, _)| i % 3 == t)
                .collect();
            let seq = cfg.seq;
            std::thread::spawn(move || -> anyhow::Result<
                Vec<(usize, Vec<i32>)>,
            > {
                let mut out = Vec::new();
                for (i, (prompt, gc)) in my {
                    let g = client.generate(prompt, gc)?;
                    assert_eq!(g.stats.gen_tokens, g.tokens.len());
                    out.push((i, g.tokens));
                    // Interleave an NLL request on the same variant.
                    let (nll, n) = client.nll(vec![1i32; seq])?;
                    assert!(n > 0 && nll.is_finite());
                }
                Ok(out)
            })
        })
        .collect();

    let stopper = Client::new(queue.clone(), cfg.seq);
    let qm_served = qm.clone();
    let serve_handle = {
        let queue = queue.clone();
        let entry = entry.clone();
        std::thread::spawn(move || {
            let exec = NativeEngine::with_workers(2);
            serve(&exec, &entry, 2, ServedWeights::Packed(qm_served),
                  &queue)
        })
    };

    let mut got: Vec<(usize, Vec<i32>)> = Vec::new();
    for h in handles {
        got.extend(h.join().unwrap().unwrap());
    }
    stopper.stop();
    serve_handle.join().unwrap().unwrap();

    assert_eq!(got.len(), reqs.len());
    for (i, tokens) in got {
        assert_eq!(tokens, expected[i],
                   "request {i}: served generation diverged from \
                    direct generation");
    }
    let (gen_served, gen_tokens) = queue.gen_stats();
    assert_eq!(gen_served, reqs.len() as u64);
    let total: u64 = expected.iter().map(|t| t.len() as u64).sum();
    assert_eq!(gen_tokens, total);
    // TTFT/prefill stats: every request did some prefill work of its
    // own, and its observed time-to-first-token covers it.
    let (prefill_s, ttft_s) = queue.gen_latency();
    assert!(prefill_s > 0.0, "no prefill work recorded");
    assert!(ttft_s >= prefill_s,
            "ttft {ttft_s}s below summed prefill work {prefill_s}s");
    let (nll_served, batches, _) = queue.stats();
    assert_eq!(nll_served, reqs.len() as u64);
    assert!(batches > 0);
}

#[test]
fn fatal_serve_error_fails_clients_loudly_instead_of_hanging() {
    // Swapping in a malformed packed model (missing projection) makes
    // the next decode step fail. serve must return the error, resolve
    // every scheduled generation's reply with an error (not leave it
    // hanging), and mark the queue stopped so later submissions error.
    let (entry, w) = tiny_model(95);
    let cfg = entry.config.clone();
    let mut bad = QuantizedModel::quantize(&cfg, &w, &[4, 4, 4], 8,
                                           Backend::Rtn, None, 1);
    bad.mats[0].remove("wq");
    let queue = ServerQueue::new(4);
    let client = Client::new(queue.clone(), cfg.seq);

    let bad2 = bad.clone();
    let client2 = client.clone();
    let t = std::thread::spawn(move || {
        client2.swap_packed(bad2);
        let res = client2.generate(vec![1, 2, 3], GenConfig::default());
        assert!(res.is_err(), "generation on a malformed variant must \
                               fail, not hang");
        res.unwrap_err().to_string()
    });

    let exec = NativeEngine::with_workers(1);
    let serve_res = serve(&exec, &entry, 2,
                          ServedWeights::Dense(w.clone()), &queue);
    assert!(serve_res.is_err(), "serve must surface the fatal error");
    let client_err = t.join().unwrap();
    assert!(client_err.contains("server failed")
                || client_err.contains("server dropped request"),
            "unexpected client error: {client_err}");
    // The queue is stopped: new submissions fail fast.
    assert!(client.submit(vec![0; cfg.seq]).is_err());
    assert!(client
        .submit_generate(vec![0], GenConfig::default())
        .is_err());
}

#[test]
fn server_rejects_empty_prompt_and_swaps_apply_to_generation() {
    let (entry, w) = tiny_model(94);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    let queue = ServerQueue::new(4);
    let client = Client::new(queue.clone(), cfg.seq);
    assert!(client
        .submit_generate(vec![], GenConfig::default())
        .is_err());

    // Swap dense -> packed between two identical greedy requests; the
    // second must match direct packed generation.
    let qm = QuantizedModel::quantize(&cfg, &w, &[2, 2, 2], 8,
                                      Backend::Rtn, None, 1);
    let gc = GenConfig { max_new: 5, ..GenConfig::default() };
    let prompt = vec![2i32, 8, 4];
    let dense_direct =
        generate(&exec, &entry, ModelRef::Dense(&w), &prompt, &gc)
            .unwrap()
            .tokens;
    let packed_direct =
        generate(&exec, &entry, ModelRef::Packed(&qm), &prompt, &gc)
            .unwrap()
            .tokens;

    let qm2 = qm.clone();
    let (p2, gc2) = (prompt.clone(), gc.clone());
    let client2 = client.clone();
    let t = std::thread::spawn(move || -> anyhow::Result<
        (Vec<i32>, Vec<i32>),
    > {
        let a = client2.generate(p2.clone(), gc2.clone())?.tokens;
        client2.swap_packed(qm2);
        let b = client2.generate(p2, gc2)?.tokens;
        client2.stop();
        Ok((a, b))
    });
    serve(&exec, &entry, 2, ServedWeights::Dense(w.clone()), &queue)
        .unwrap();
    let (a, b) = t.join().unwrap().unwrap();
    assert_eq!(a, dense_direct);
    assert_eq!(b, packed_direct);
}
