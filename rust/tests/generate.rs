//! Generation-path coverage: deterministic-seed greedy/top-k golden
//! tests over the KV-cached decode loop, generation-based eval scoring,
//! concurrent generation requests through `server::serve` (results
//! identical to direct single-threaded generation — no interleaving
//! corruption — and server stats consistent), per-token streaming
//! (events bit-identical to the batch result), and cancel-on-disconnect
//! (a dropped receiver frees its KV slot — target and drafter pools —
//! within one scheduler step).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use nsds::coordinator::server::{serve, Client, ServedWeights,
                                ServerQueue};
use nsds::infer::{generate, BatchEngine, Executor, GenConfig, GenEvent,
                  GenSink, Generation, KvCache, ModelRef, NativeEngine,
                  QuantizedModel, Sampling, SpecDecode, StopReason,
                  PAGE_SIZE};
use nsds::model::{ModelConfig, Weights, WEIGHT_NAMES};
use nsds::quant::Backend;
use nsds::runtime::ModelEntry;
use nsds::telemetry::Ev;
use nsds::util::rng::Rng;

/// Test sink: records every event and exposes a disconnect switch —
/// the engine-level stand-in for the server's `GenStream`.
#[derive(Clone)]
struct CollectSink {
    events: Arc<Mutex<Vec<GenEvent>>>,
    connected: Arc<AtomicBool>,
}

impl CollectSink {
    fn new() -> Self {
        CollectSink {
            events: Arc::new(Mutex::new(Vec::new())),
            connected: Arc::new(AtomicBool::new(true)),
        }
    }

    fn disconnect(&self) {
        self.connected.store(false, Ordering::Release);
    }

    /// The streamed token sequence, in emission order.
    fn tokens(&self) -> Vec<i32> {
        self.events
            .lock()
            .unwrap()
            .iter()
            .filter_map(|e| match e {
                GenEvent::Token { token, .. } => Some(*token),
                _ => None,
            })
            .collect()
    }

    /// The `pos` fields of the streamed tokens, in emission order.
    fn positions(&self) -> Vec<usize> {
        self.events
            .lock()
            .unwrap()
            .iter()
            .filter_map(|e| match e {
                GenEvent::Token { pos, .. } => Some(*pos),
                _ => None,
            })
            .collect()
    }

    fn done(&self) -> Option<Generation> {
        self.events.lock().unwrap().iter().find_map(|e| match e {
            GenEvent::Done(g) => Some(g.clone()),
            _ => None,
        })
    }
}

impl GenSink for CollectSink {
    fn emit(&self, ev: GenEvent) -> bool {
        if !self.connected.load(Ordering::Acquire) {
            return false;
        }
        self.events.lock().unwrap().push(ev);
        true
    }

    fn is_connected(&self) -> bool {
        self.connected.load(Ordering::Acquire)
    }
}

fn tiny_model(seed: u64) -> (ModelEntry, Weights) {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(seed);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    (entry, w)
}

/// Identity embed/unembed with zero projections: the model predicts
/// "repeat the last token" (see native_engine.rs golden test).
fn repeat_model() -> (ModelEntry, Weights) {
    let cfg = ModelConfig {
        name: "ident".into(),
        vocab: 8,
        d_model: 8,
        n_heads: 2,
        n_kv: 2,
        d_head: 2,
        d_ffn: 8,
        n_layers: 1,
        seq: 8,
    };
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut tensors = BTreeMap::new();
    for name in WEIGHT_NAMES {
        let dims = cfg.weight_dims(name);
        let n: usize = dims.iter().product();
        let t = match name {
            "embed" | "unembed" => {
                let scale = if name == "embed" { 5.0 } else { 20.0 };
                let mut m = nsds::tensor::Tensor::zeros(dims);
                for i in 0..cfg.vocab {
                    m.set(i, i, scale);
                }
                m
            }
            "lnf" | "ln1" | "ln2" => {
                nsds::tensor::Tensor::new(vec![1.0; n], dims)
            }
            _ => nsds::tensor::Tensor::zeros(dims),
        };
        tensors.insert(name.to_string(), t);
    }
    (entry, Weights { tensors })
}

#[test]
fn greedy_repeats_on_the_repeat_model() {
    let (entry, w) = repeat_model();
    let exec = NativeEngine::with_workers(1);
    let gc = GenConfig { max_new: 6, ..GenConfig::default() };
    let g = generate(&exec, &entry, ModelRef::Dense(&w), &[3, 3], &gc)
        .unwrap();
    assert_eq!(g.tokens, vec![3; 6]);
    assert_eq!(g.stopped, StopReason::MaxNew);
    assert_eq!(g.stats.prompt_tokens, 2);
    assert_eq!(g.stats.gen_tokens, 6);
}

#[test]
fn greedy_first_token_matches_decode_argmax() {
    let (entry, w) = tiny_model(90);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    let prompt: Vec<i32> = vec![1, 4, 2, 7];
    // Expected: argmax of the last prompt position's decode logits.
    let mut cache = KvCache::for_model(&cfg, prompt.len() + 1);
    let mut last = None;
    for &t in &prompt {
        last = Some(exec.decode_step(&entry, &mut cache, t, &w).unwrap());
    }
    let logits = last.unwrap();
    let expect = logits
        .data()
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as i32;
    let gc = GenConfig { max_new: 1, ..GenConfig::default() };
    let g = generate(&exec, &entry, ModelRef::Dense(&w), &prompt, &gc)
        .unwrap();
    assert_eq!(g.tokens, vec![expect]);
}

#[test]
fn generation_is_seed_deterministic_and_seed_sensitive() {
    let (entry, w) = tiny_model(91);
    let exec = NativeEngine::with_workers(2);
    let prompt = vec![0i32, 5, 9];
    let gen = |seed: u64| -> Generation {
        let gc = GenConfig {
            max_new: 12,
            sampling: Sampling::TopK { k: 6, temperature: 1.2 },
            seed,
            ..GenConfig::default()
        };
        generate(&exec, &entry, ModelRef::Dense(&w), &prompt, &gc)
            .unwrap()
    };
    let a = gen(7);
    let b = gen(7);
    assert_eq!(a.tokens, b.tokens, "same seed must reproduce exactly");
    let c = gen(8);
    // With k=6 over 12 draws, two seeds agreeing everywhere is
    // vanishingly unlikely — and would indicate the seed is ignored.
    assert_ne!(a.tokens, c.tokens, "different seeds never diverged");
}

#[test]
fn stop_token_and_max_new_conditions() {
    let (entry, w) = repeat_model();
    let exec = NativeEngine::with_workers(1);
    // The repeat model emits 3 forever: stopping on 3 ends immediately.
    let gc = GenConfig {
        max_new: 10,
        stop: vec![3],
        ..GenConfig::default()
    };
    let g = generate(&exec, &entry, ModelRef::Dense(&w), &[3, 3], &gc)
        .unwrap();
    assert_eq!(g.tokens, vec![3]);
    assert_eq!(g.stopped, StopReason::StopToken(3));
    // A stop token the model never emits: runs to max_new.
    let gc2 = GenConfig {
        max_new: 4,
        stop: vec![5],
        ..GenConfig::default()
    };
    let g2 = generate(&exec, &entry, ModelRef::Dense(&w), &[3], &gc2)
        .unwrap();
    assert_eq!(g2.tokens.len(), 4);
    assert_eq!(g2.stopped, StopReason::MaxNew);
    // Stats sanity: TTFT covers the request's own prefill work (its
    // chunks all run inside the admission → first-token window), in
    // integer nanoseconds end-to-end.
    assert!(g2.stats.ttft_ns >= g2.stats.prefill_ns);
    assert!(g2.stats.total_ns() >= g2.stats.decode_ns);
    assert_eq!(g2.stats.total_ns(),
               g2.stats.ttft_ns + g2.stats.decode_ns);
    assert!(g2.stats.ttft_s() >= g2.stats.prefill_s());
    assert!(g2.stats.decode_tok_per_s() >= 0.0);
}

#[test]
fn packed_and_dense_variants_generate_identically_here() {
    // 4-bit HQQ on the tiny model is accurate enough that greedy
    // decoding follows the FP32 trajectory — the generation-level check
    // that packed serving preserves behavior, plus eval::gen coverage.
    let (entry, w) = tiny_model(92);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    let qm = QuantizedModel::quantize(&cfg, &w,
                                      &vec![4u8; cfg.n_layers], 8,
                                      Backend::Hqq, None, 1);
    let mut rng = Rng::new(5);
    let corpus: Vec<i32> = (0..8 * cfg.seq)
        .map(|_| rng.below(cfg.vocab) as i32)
        .collect();
    let agree = nsds::eval::gen::greedy_agreement(
        &exec, &entry, ModelRef::Dense(&w), ModelRef::Packed(&qm),
        &corpus, 6, 4, 6)
    .unwrap();
    assert!(agree > 0.5, "4-bit greedy agreement only {agree}");
    let cm = nsds::eval::gen::continuation_match(
        &exec, &entry, ModelRef::Dense(&w), &corpus, 6, 4, 6)
    .unwrap();
    assert!((0.0..=1.0).contains(&cm));
}

#[test]
fn in_context_scoring_matches_plain_with_empty_context() {
    let (entry, w) = tiny_model(96);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    let mut rng = Rng::new(7);
    let corpus: Vec<i32> = (0..8 * cfg.seq)
        .map(|_| rng.below(cfg.vocab) as i32)
        .collect();
    let plain = nsds::eval::gen::continuation_match(
        &exec, &entry, ModelRef::Dense(&w), &corpus, 6, 4, 6)
    .unwrap();
    let empty_ctx = nsds::eval::gen::continuation_match_in_context(
        &exec, &entry, ModelRef::Dense(&w), &[], &corpus, 6, 4, 6)
    .unwrap();
    assert_eq!(plain, empty_ctx);
    // A real shared context (longer than one page, so the batched
    // engine keeps it resident once and shares its pages — the page
    // mechanics themselves are pinned in batch_decode.rs): the metric
    // stays a valid fraction, and a variant always agrees with itself.
    let ctx: Vec<i32> = (0..PAGE_SIZE + 4)
        .map(|_| rng.below(cfg.vocab) as i32)
        .collect();
    let with_ctx = nsds::eval::gen::continuation_match_in_context(
        &exec, &entry, ModelRef::Dense(&w), &ctx, &corpus, 6, 4, 6)
    .unwrap();
    assert!((0.0..=1.0).contains(&with_ctx));
    let ga = nsds::eval::gen::greedy_agreement_in_context(
        &exec, &entry, ModelRef::Dense(&w), ModelRef::Dense(&w), &ctx,
        &corpus, 6, 4, 4)
    .unwrap();
    assert_eq!(ga, 1.0, "a variant must agree with itself in context");
}

#[test]
fn server_shares_prefix_pages_across_identical_prompts() {
    // Two identical prompts queued before the serve loop starts: the
    // scheduler admits the first, defers the second until the shared
    // prefix is resident, then admits it by page reference — outputs
    // unchanged, and the saved prefill shows up in gen_shared().
    let (entry, w) = tiny_model(97);
    let cfg = entry.config.clone();
    let queue = ServerQueue::new(8);
    let client = Client::new(queue.clone(), cfg.seq);
    let prompt: Vec<i32> = (0..PAGE_SIZE + 6)
        .map(|i| ((i * 3) % cfg.vocab) as i32)
        .collect();
    let gc = GenConfig { max_new: 4, ..GenConfig::default() };
    let exec = NativeEngine::with_workers(1);
    let direct = generate(&exec, &entry, ModelRef::Dense(&w), &prompt,
                          &gc)
        .unwrap()
        .tokens;
    let rx1 = client.submit_generate(prompt.clone(), gc.clone()).unwrap();
    let rx2 = client.submit_generate(prompt.clone(), gc.clone()).unwrap();
    client.stop();
    serve(&exec, &entry, 2, ServedWeights::Dense(w.clone()), &queue)
        .unwrap();
    let g1 = rx1.wait().unwrap();
    let g2 = rx2.wait().unwrap();
    assert_eq!(g1.tokens, direct);
    assert_eq!(g2.tokens, direct,
               "prefix sharing changed a served generation");
    assert!(queue.gen_shared() as usize >= PAGE_SIZE,
            "server admitted only {} prompt tokens by page reference",
            queue.gen_shared());
}

#[test]
fn concurrent_generation_through_server_matches_direct() {
    let (entry, w) = tiny_model(93);
    let cfg = entry.config.clone();
    let qm = QuantizedModel::quantize(&cfg, &w, &[4, 2, 4], 8,
                                      Backend::Hqq, None, 2);
    let exec = NativeEngine::with_workers(2);

    // 9 requests: distinct prompts, mixed greedy/top-k, distinct seeds.
    let mut rng = Rng::new(6);
    let reqs: Vec<(Vec<i32>, GenConfig)> = (0..9)
        .map(|i| {
            let plen = 2 + rng.below(5);
            let prompt: Vec<i32> = (0..plen)
                .map(|_| rng.below(cfg.vocab) as i32)
                .collect();
            let sampling = if i % 2 == 0 {
                Sampling::Greedy
            } else {
                Sampling::TopK { k: 5, temperature: 1.0 }
            };
            let gc = GenConfig {
                max_new: 6,
                sampling,
                seed: 100 + i as u64,
                ..GenConfig::default()
            };
            (prompt, gc)
        })
        .collect();

    // Ground truth: direct, sequential generation.
    let expected: Vec<Vec<i32>> = reqs
        .iter()
        .map(|(p, gc)| {
            generate(&exec, &entry, ModelRef::Packed(&qm), p, gc)
                .unwrap()
                .tokens
        })
        .collect();

    // Same requests through the serve loop, from 3 client threads, with
    // NLL requests interleaved to exercise mixed batching.
    let queue = ServerQueue::new(6);
    let handles: Vec<_> = (0..3)
        .map(|t| {
            let client = Client::new(queue.clone(), cfg.seq);
            let my: Vec<(usize, (Vec<i32>, GenConfig))> = reqs
                .iter()
                .cloned()
                .enumerate()
                .filter(|(i, _)| i % 3 == t)
                .collect();
            let seq = cfg.seq;
            std::thread::spawn(move || -> anyhow::Result<
                Vec<(usize, Vec<i32>)>,
            > {
                let mut out = Vec::new();
                for (i, (prompt, gc)) in my {
                    let g = client.generate(prompt, gc)?;
                    assert_eq!(g.stats.gen_tokens, g.tokens.len());
                    out.push((i, g.tokens));
                    // Interleave an NLL request on the same variant.
                    let (nll, n) = client.nll(vec![1i32; seq])?;
                    assert!(n > 0 && nll.is_finite());
                }
                Ok(out)
            })
        })
        .collect();

    let stopper = Client::new(queue.clone(), cfg.seq);
    let qm_served = qm.clone();
    let serve_handle = {
        let queue = queue.clone();
        let entry = entry.clone();
        std::thread::spawn(move || {
            let exec = NativeEngine::with_workers(2);
            serve(&exec, &entry, 2, ServedWeights::Packed(qm_served),
                  &queue)
        })
    };

    let mut got: Vec<(usize, Vec<i32>)> = Vec::new();
    for h in handles {
        got.extend(h.join().unwrap().unwrap());
    }
    stopper.stop();
    serve_handle.join().unwrap().unwrap();

    assert_eq!(got.len(), reqs.len());
    for (i, tokens) in got {
        assert_eq!(tokens, expected[i],
                   "request {i}: served generation diverged from \
                    direct generation");
    }
    let (gen_served, gen_tokens) = queue.gen_stats();
    assert_eq!(gen_served, reqs.len() as u64);
    let total: u64 = expected.iter().map(|t| t.len() as u64).sum();
    assert_eq!(gen_tokens, total);
    // TTFT/prefill stats: every request did some prefill work of its
    // own, and its observed time-to-first-token covers it.
    let (prefill_s, ttft_s) = queue.gen_latency();
    assert!(prefill_s > 0.0, "no prefill work recorded");
    assert!(ttft_s >= prefill_s,
            "ttft {ttft_s}s below summed prefill work {prefill_s}s");
    let (nll_served, batches, _) = queue.stats();
    assert_eq!(nll_served, reqs.len() as u64);
    assert!(batches > 0);
}

#[test]
fn fatal_serve_error_fails_clients_loudly_instead_of_hanging() {
    // Swapping in a malformed packed model (missing projection) makes
    // the next decode step fail. serve must return the error, resolve
    // every scheduled generation's reply with an error (not leave it
    // hanging), and mark the queue stopped so later submissions error.
    let (entry, w) = tiny_model(95);
    let cfg = entry.config.clone();
    let mut bad = QuantizedModel::quantize(&cfg, &w, &[4, 4, 4], 8,
                                           Backend::Rtn, None, 1);
    bad.mats[0].remove("wq");
    let queue = ServerQueue::new(4);
    let client = Client::new(queue.clone(), cfg.seq);

    let bad2 = bad.clone();
    let client2 = client.clone();
    let t = std::thread::spawn(move || {
        client2.swap_packed(bad2);
        let res = client2.generate(vec![1, 2, 3], GenConfig::default());
        assert!(res.is_err(), "generation on a malformed variant must \
                               fail, not hang");
        res.unwrap_err().to_string()
    });

    let exec = NativeEngine::with_workers(1);
    let serve_res = serve(&exec, &entry, 2,
                          ServedWeights::Dense(w.clone()), &queue);
    assert!(serve_res.is_err(), "serve must surface the fatal error");
    let client_err = t.join().unwrap();
    assert!(client_err.contains("server failed")
                || client_err.contains("server dropped request"),
            "unexpected client error: {client_err}");
    // The queue is stopped: new submissions fail fast.
    assert!(client.submit(vec![0; cfg.seq]).is_err());
    assert!(client
        .submit_generate(vec![0], GenConfig::default())
        .is_err());
}

#[test]
fn server_rejects_empty_prompt_and_swaps_apply_to_generation() {
    let (entry, w) = tiny_model(94);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    let queue = ServerQueue::new(4);
    let client = Client::new(queue.clone(), cfg.seq);
    assert!(client
        .submit_generate(vec![], GenConfig::default())
        .is_err());

    // Swap dense -> packed between two identical greedy requests; the
    // second must match direct packed generation.
    let qm = QuantizedModel::quantize(&cfg, &w, &[2, 2, 2], 8,
                                      Backend::Rtn, None, 1);
    let gc = GenConfig { max_new: 5, ..GenConfig::default() };
    let prompt = vec![2i32, 8, 4];
    let dense_direct =
        generate(&exec, &entry, ModelRef::Dense(&w), &prompt, &gc)
            .unwrap()
            .tokens;
    let packed_direct =
        generate(&exec, &entry, ModelRef::Packed(&qm), &prompt, &gc)
            .unwrap()
            .tokens;

    let qm2 = qm.clone();
    let (p2, gc2) = (prompt.clone(), gc.clone());
    let client2 = client.clone();
    let t = std::thread::spawn(move || -> anyhow::Result<
        (Vec<i32>, Vec<i32>),
    > {
        let a = client2.generate(p2.clone(), gc2.clone())?.tokens;
        client2.swap_packed(qm2);
        let b = client2.generate(p2, gc2)?.tokens;
        client2.stop();
        Ok((a, b))
    });
    serve(&exec, &entry, 2, ServedWeights::Dense(w.clone()), &queue)
        .unwrap();
    let (a, b) = t.join().unwrap().unwrap();
    assert_eq!(a, dense_direct);
    assert_eq!(b, packed_direct);
}

#[test]
fn streamed_events_are_bit_identical_to_batch_results() {
    // Every committed token flows through one emission point
    // (`consume_row`), so the streamed sequence must equal the batch
    // result exactly — dense and packed, greedy and top-k, plain and
    // speculative.
    let (entry, w) = tiny_model(40);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    let qm = QuantizedModel::quantize(&cfg, &w,
                                      &vec![4u8; cfg.n_layers], 8,
                                      Backend::Hqq, None, 1);
    let reqs: Vec<(Vec<i32>, GenConfig)> = vec![
        (vec![1, 2, 3], GenConfig { max_new: 7,
                                    ..GenConfig::default() }),
        (vec![9, 4], GenConfig {
            max_new: 9,
            sampling: Sampling::TopK { k: 5, temperature: 1.1 },
            seed: 21,
            ..GenConfig::default()
        }),
        (vec![6, 6, 1, 0], GenConfig { max_new: 5,
                                       ..GenConfig::default() }),
    ];
    for model in [ModelRef::Dense(&w), ModelRef::Packed(&qm)] {
        let mut engine: BatchEngine<CollectSink> =
            BatchEngine::new(&cfg, 2);
        let sinks: Vec<CollectSink> =
            reqs.iter().map(|_| CollectSink::new()).collect();
        for (sink, (p, gc)) in sinks.iter().zip(&reqs) {
            engine.submit(sink.clone(), p.clone(), gc.clone())
                .unwrap();
        }
        let done = engine.run(&exec, &entry, model).unwrap();
        assert_eq!(done.len(), reqs.len());
        for (i, ((p, gc), sink)) in
            reqs.iter().zip(&sinks).enumerate()
        {
            let direct =
                generate(&exec, &entry, model, p, gc).unwrap();
            let streamed = sink.tokens();
            assert_eq!(streamed, direct.tokens,
                       "request {i}: streamed tokens diverged from \
                        direct generation");
            assert_eq!(sink.positions(),
                       (0..streamed.len()).collect::<Vec<_>>(),
                       "request {i}: stream positions not 0..n");
            let done_gen = sink.done().expect("Done event");
            assert_eq!(done_gen.tokens, direct.tokens,
                       "request {i}: Done payload diverged");
            let (_, batch_gen) = done
                .iter()
                .find(|(tag, _)| {
                    Arc::ptr_eq(&tag.events, &sink.events)
                })
                .expect("batch result for request");
            assert_eq!(batch_gen.tokens, direct.tokens,
                       "request {i}: batch result diverged");
        }
    }

    // Speculative path: identical drafter, greedy — verify-accepts
    // stream through the same path, tokens bit-identical.
    let gc = GenConfig {
        max_new: 10,
        spec: Some(SpecDecode { k: 3 }),
        ..GenConfig::default()
    };
    let prompt = vec![2i32, 7, 5];
    let plain = GenConfig { spec: None, ..gc.clone() };
    let direct =
        generate(&exec, &entry, ModelRef::Dense(&w), &prompt, &plain)
            .unwrap();
    let mut engine: BatchEngine<CollectSink> = BatchEngine::new(&cfg, 1);
    let sink = CollectSink::new();
    engine.submit(sink.clone(), prompt.clone(), gc).unwrap();
    let done = engine
        .run_spec(&exec, &entry, ModelRef::Dense(&w),
                  Some(ModelRef::Dense(&w)))
        .unwrap();
    assert_eq!(done[0].1.tokens, direct.tokens,
               "spec batch result diverged from plain decode");
    assert_eq!(sink.tokens(), direct.tokens,
               "spec streamed tokens diverged from plain decode");
    let sc = engine.spec_counters();
    assert!(sc.verify_steps > 0, "spec path never engaged");
}

#[test]
fn dropped_receiver_frees_slot_within_one_step() {
    let (entry, w) = tiny_model(41);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    let gc = GenConfig { max_new: 12, ..GenConfig::default() };
    let prompt_a = vec![1i32, 2, 3, 4];
    let prompt_b = vec![5i32, 6, 7];
    let direct_a =
        generate(&exec, &entry, ModelRef::Dense(&w), &prompt_a, &gc)
            .unwrap()
            .tokens;
    let solo_b =
        generate(&exec, &entry, ModelRef::Dense(&w), &prompt_b, &gc)
            .unwrap()
            .tokens;

    let mut engine: BatchEngine<CollectSink> = BatchEngine::new(&cfg, 2);
    engine.enable_trace(128);
    let base = engine.pool().pages_in_use();
    let a = CollectSink::new();
    let b = CollectSink::new();
    engine.submit(a.clone(), prompt_a, gc.clone()).unwrap();
    engine.submit(b.clone(), prompt_b, gc.clone()).unwrap();
    // Step 1: both prefill (single chunk) and sample their first token.
    let mut done =
        engine.step(&exec, &entry, ModelRef::Dense(&w)).unwrap();
    assert!(done.is_empty());
    // prompt + max_new ≤ PAGE_SIZE for both, so each holds EXACTLY one
    // page for its whole life — page accounting is exact, not fuzzy.
    assert_eq!(engine.pool().pages_in_use(), base + 2);
    assert_eq!(a.tokens(), direct_a[..1],
               "first streamed token diverged before the disconnect");

    a.disconnect();
    // ONE step later the cancelled request's slot is back in the pool.
    done.extend(
        engine.step(&exec, &entry, ModelRef::Dense(&w)).unwrap());
    assert_eq!(engine.cancelled_total(), 1);
    assert_eq!(engine.pool().pages_in_use(), base + 1,
               "cancelled slot not freed within one step");
    assert_eq!(engine.in_flight(), 1);
    let cancels: Vec<_> = engine
        .tracer()
        .unwrap()
        .events()
        .into_iter()
        .filter(|e| matches!(e.ev, Ev::Cancel { .. }))
        .collect();
    assert_eq!(cancels.len(), 1);
    assert!(matches!(cancels[0].ev, Ev::Cancel { slot: Some(_), .. }),
            "an in-flight cancel must report the freed slot");
    // The dead sink received nothing after the disconnect.
    assert_eq!(a.tokens().len(), 1);
    assert!(a.done().is_none(),
            "cancelled request must not produce a Generation");

    // The co-batched survivor is unaffected: identical to its solo run.
    while !engine.is_idle() {
        done.extend(
            engine.step(&exec, &entry, ModelRef::Dense(&w)).unwrap());
    }
    assert_eq!(done.len(), 1, "only the survivor finishes");
    assert_eq!(done[0].1.tokens, solo_b,
               "survivor diverged from its solo generation");
    assert_eq!(b.tokens(), solo_b);
    assert_eq!(engine.pool().pages_in_use(), base,
               "page accounting not restored after drain");
}

#[test]
fn disconnect_during_prefill_and_pending_frees_everything() {
    let (entry, w) = tiny_model(42);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    // Prompt longer than one PREFILL_CHUNK (2 pages): prefill spans
    // at least two steps, so the disconnect lands mid-prefill, before
    // any token has streamed.
    let long: Vec<i32> = (0..(2 * PAGE_SIZE + 8))
        .map(|i| (i % cfg.vocab) as i32)
        .collect();
    let gc = GenConfig { max_new: 6, ..GenConfig::default() };
    let mut engine: BatchEngine<CollectSink> = BatchEngine::new(&cfg, 1);
    engine.enable_trace(64);
    let base = engine.pool().pages_in_use();
    let pre = CollectSink::new();
    let pend = CollectSink::new();
    engine.submit(pre.clone(), long.clone(), gc.clone()).unwrap();
    // Second request queues behind the single slot: cancelled while
    // PENDING it must vanish without ever holding pages.
    engine.submit(pend.clone(), vec![1, 2], gc.clone()).unwrap();
    engine.step(&exec, &entry, ModelRef::Dense(&w)).unwrap();
    assert!(engine.pool().pages_in_use() > base);
    assert!(pre.tokens().is_empty(), "still prefilling, no tokens");

    pend.disconnect();
    pre.disconnect();
    engine.step(&exec, &entry, ModelRef::Dense(&w)).unwrap();
    assert_eq!(engine.cancelled_total(), 2);
    assert!(engine.is_idle());
    assert_eq!(engine.pool().pages_in_use(), base,
               "mid-prefill cancel leaked pages");
    let cancels: Vec<_> = engine
        .tracer()
        .unwrap()
        .events()
        .into_iter()
        .filter_map(|e| match e.ev {
            Ev::Cancel { slot, .. } => Some(slot),
            _ => None,
        })
        .collect();
    assert_eq!(cancels.len(), 2);
    assert!(cancels.contains(&None),
            "pending cancel must carry slot None");
    assert!(cancels.iter().any(Option::is_some),
            "in-flight cancel must carry its freed slot");
    assert!(pre.tokens().is_empty() && pend.tokens().is_empty());
}

#[test]
fn dropped_receiver_frees_drafter_slot_too() {
    let (entry, w) = tiny_model(43);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    let gc = GenConfig {
        max_new: 12,
        spec: Some(SpecDecode { k: 2 }),
        ..GenConfig::default()
    };
    let mut engine: BatchEngine<CollectSink> = BatchEngine::new(&cfg, 1);
    let sink = CollectSink::new();
    engine.submit(sink.clone(), vec![3, 1, 4], gc).unwrap();
    // Run until the drafter slot is engaged (prefill, catch-up, then
    // draft+verify — a handful of steps).
    for _ in 0..4 {
        engine
            .step_spec(&exec, &entry, ModelRef::Dense(&w),
                       Some(ModelRef::Dense(&w)))
            .unwrap();
    }
    let dpool = engine.drafter_pool().expect("spec engaged");
    assert!(dpool.pages_in_use() > 0, "drafter never engaged");
    assert!(engine.spec_counters().verify_steps > 0);

    sink.disconnect();
    engine
        .step_spec(&exec, &entry, ModelRef::Dense(&w),
                   Some(ModelRef::Dense(&w)))
        .unwrap();
    assert_eq!(engine.cancelled_total(), 1);
    assert!(engine.is_idle());
    assert_eq!(engine.pool().pages_in_use(), 0,
               "target slot leaked on spec cancel");
    assert_eq!(engine.drafter_pool().unwrap().pages_in_use(), 0,
               "drafter slot leaked on spec cancel");
}

#[test]
fn server_cancels_dropped_streams_and_counts_them() {
    // End to end through serve: drop one GenEvents receiver mid-flight;
    // the serve loop must cancel it (serve.gen.cancelled), finish the
    // co-batched survivor with tokens identical to a direct call, and
    // report zero in gen_stats for the cancelled request.
    let (entry, w) = tiny_model(44);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    let gc = GenConfig { max_new: 24, ..GenConfig::default() };
    let survivor_prompt = vec![4i32, 9, 2];
    let direct = generate(&exec, &entry, ModelRef::Dense(&w),
                          &survivor_prompt, &gc)
        .unwrap()
        .tokens;

    let queue = ServerQueue::new(8);
    let client = Client::new(queue.clone(), cfg.seq);
    let doomed = client
        .submit_generate(vec![1i32, 2, 3], gc.clone())
        .unwrap();
    let survivor = client
        .submit_generate(survivor_prompt, gc.clone())
        .unwrap();
    let serve_handle = {
        let queue = queue.clone();
        let entry = entry.clone();
        let w2 = w.clone();
        std::thread::spawn(move || {
            let exec = NativeEngine::with_workers(1);
            serve(&exec, &entry, 2, ServedWeights::Dense(w2), &queue)
        })
    };
    // Wait for the doomed request's first token so the drop lands
    // mid-generation (slot held), then disconnect.
    let first = doomed.next_event();
    assert!(matches!(first, Some(GenEvent::Token { .. })),
            "expected a first streamed token, got {first:?}");
    drop(doomed);

    let g = survivor.wait().unwrap();
    client.stop();
    serve_handle.join().unwrap().unwrap();
    assert_eq!(g.tokens, direct,
               "survivor diverged after co-batched cancel");
    assert_eq!(queue.gen_cancelled(), 1,
               "serve.gen.cancelled missed the dropped stream");
    let (gen_served, gen_tokens) = queue.gen_stats();
    assert_eq!(gen_served, 1,
               "cancelled request must not count as served");
    assert_eq!(gen_tokens, g.tokens.len() as u64);
}

#[test]
fn streaming_through_server_matches_wait() {
    let (entry, w) = tiny_model(45);
    let cfg = entry.config.clone();
    let exec = NativeEngine::with_workers(1);
    let gc = GenConfig { max_new: 8, ..GenConfig::default() };
    let prompt = vec![7i32, 3];
    let direct =
        generate(&exec, &entry, ModelRef::Dense(&w), &prompt, &gc)
            .unwrap()
            .tokens;
    let queue = ServerQueue::new(4);
    let client = Client::new(queue.clone(), cfg.seq);
    let events = client.generate_streaming(prompt, gc).unwrap();
    client.stop();
    serve(&exec, &entry, 1, ServedWeights::Dense(w.clone()), &queue)
        .unwrap();
    let mut streamed = Vec::new();
    let mut done = None;
    for ev in events {
        match ev {
            GenEvent::Token { token, pos } => {
                assert_eq!(pos, streamed.len(),
                           "stream positions out of order");
                streamed.push(token);
            }
            GenEvent::Done(g) => {
                done = Some(g);
                break;
            }
            GenEvent::Failed(e) => panic!("stream failed: {e}"),
        }
    }
    let done = done.expect("terminal Done event");
    assert_eq!(streamed, direct,
               "served stream diverged from direct generation");
    assert_eq!(done.tokens, direct);
    assert_eq!(queue.dropped_replies(), 0);
    assert_eq!(queue.gen_cancelled(), 0);
}
