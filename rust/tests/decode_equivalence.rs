//! The tentpole acceptance property: KV-cached token-by-token decoding
//! must produce the same logits as the full-sequence `forward`, within
//! 1e-4, on randomly shaped tiny models — for the dense path and the
//! fused-packed (2/4-bit) path. Shapes deliberately sweep the GQA
//! space, including kv_heads < heads with a non-divisible group tail.

use nsds::infer::{Executor, KvCache, ModelRef, NativeEngine,
                  QuantizedModel};
use nsds::model::{ModelConfig, Weights};
use nsds::prop_ensure;
use nsds::quant::Backend;
use nsds::runtime::ModelEntry;
use nsds::util::prop::check;
use nsds::util::rng::Rng;

/// Random tiny model shape; the head counts are drawn independently so
/// the cases cover MHA (nkv == nh), grouped (nkv | nh) and ragged GQA.
/// Every projection's K dim (d_model, nh·dh, d_ffn) stays a multiple of
/// 4, the 2-bit packing granularity, so the same shapes serve packed.
fn random_config(rng: &mut Rng) -> ModelConfig {
    let n_heads = 1 + rng.below(6);
    let n_kv = 1 + rng.below(n_heads);
    ModelConfig {
        name: "prop".into(),
        vocab: 16 + rng.below(32),
        d_model: 8 + 4 * rng.below(5),
        n_heads,
        n_kv,
        d_head: 4 * (1 + rng.below(2)),
        d_ffn: 8 * (1 + rng.below(4)),
        n_layers: 1 + rng.below(3),
        seq: 4 + rng.below(9),
    }
}

fn random_tokens(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(vocab) as i32).collect()
}

/// Max |a-b| over matching positions, relative to the max magnitude.
fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Run the full forward and the incremental decode over the same tokens
/// and return the largest per-position logit deviation.
fn decode_vs_forward(exec: &NativeEngine, entry: &ModelEntry,
                     model: ModelRef, tokens: &[i32])
                     -> anyhow::Result<f32> {
    let cfg = &entry.config;
    let full = model.forward(exec, entry, tokens, 1)?;
    let mut cache = KvCache::for_model(cfg, cfg.seq);
    let v = cfg.vocab;
    let mut worst = 0.0f32;
    for (si, &t) in tokens.iter().enumerate() {
        let step = model.decode_step(exec, entry, &mut cache, t)?;
        assert_eq!(step.dims(), &[v]);
        let frow = &full.data()[si * v..(si + 1) * v];
        worst = worst.max(max_abs_diff(step.data(), frow));
    }
    Ok(worst)
}

#[test]
fn dense_decode_matches_forward() {
    check("dense decode == forward", 14, |rng| {
        let cfg = random_config(rng);
        let entry = ModelEntry::synthetic(cfg.clone());
        let w = Weights::synth(&cfg, rng, &[], &[]);
        let exec = NativeEngine::with_workers(1 + rng.below(3));
        let tokens = random_tokens(rng, cfg.seq, cfg.vocab);
        let worst = decode_vs_forward(&exec, &entry,
                                      ModelRef::Dense(&w), &tokens)
            .map_err(|e| e.to_string())?;
        prop_ensure!(worst < 1e-4,
                     "dense decode diverged: {worst} \
                      (nh={} nkv={} dh={} L={} seq={})",
                     cfg.n_heads, cfg.n_kv, cfg.d_head, cfg.n_layers,
                     cfg.seq);
        Ok(())
    });
}

#[test]
fn packed_decode_matches_packed_forward() {
    check("packed decode == forward_packed", 8, |rng| {
        let cfg = random_config(rng);
        let entry = ModelEntry::synthetic(cfg.clone());
        let w = Weights::synth(&cfg, rng, &[], &[]);
        let bits: Vec<u8> = (0..cfg.n_layers)
            .map(|_| if rng.f64() < 0.5 { 2 } else { 4 })
            .collect();
        let backend =
            if rng.f64() < 0.5 { Backend::Rtn } else { Backend::Hqq };
        let qm = QuantizedModel::quantize(&cfg, &w, &bits, 8, backend,
                                          None, 1);
        let exec = NativeEngine::with_workers(1 + rng.below(3));
        let tokens = random_tokens(rng, cfg.seq, cfg.vocab);
        let worst = decode_vs_forward(&exec, &entry,
                                      ModelRef::Packed(&qm), &tokens)
            .map_err(|e| e.to_string())?;
        prop_ensure!(worst < 1e-4,
                     "packed decode diverged: {worst} (bits {bits:?}, \
                      nh={} nkv={} dh={})",
                     cfg.n_heads, cfg.n_kv, cfg.d_head);
        Ok(())
    });
}

/// The same property through the trait-object surface the serving stack
/// uses (`&dyn Executor`), at a fixed divisible-GQA shape.
#[test]
fn decode_through_dyn_executor() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(80);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let engine = NativeEngine::with_workers(2);
    let exec: &dyn Executor = &engine;
    assert!(exec.supports_decode());
    let tokens: Vec<i32> = (0..cfg.seq)
        .map(|_| rng.below(cfg.vocab) as i32)
        .collect();
    let full = exec.forward(&entry, &tokens, 1, &w).unwrap();
    let mut cache = KvCache::for_model(&cfg, cfg.seq);
    for (si, &t) in tokens.iter().enumerate() {
        let step = exec.decode_step(&entry, &mut cache, t, &w).unwrap();
        let frow =
            &full.data()[si * cfg.vocab..(si + 1) * cfg.vocab];
        assert!(max_abs_diff(step.data(), frow) < 1e-4, "pos {si}");
    }
}

/// Ring eviction: decoding past the cache capacity must keep producing
/// finite logits (sliding-window attention), and the positions BEFORE
/// any eviction still match the full forward exactly.
#[test]
fn ring_eviction_is_finite_and_exact_before_wrap() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(81);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let exec = NativeEngine::with_workers(1);
    let cap = cfg.seq / 2;
    let mut cache = KvCache::for_model(&cfg, cap);
    let tokens: Vec<i32> = (0..2 * cfg.seq)
        .map(|_| rng.below(cfg.vocab) as i32)
        .collect();
    let full = exec.forward(&entry, &tokens[..cfg.seq], 1, &w).unwrap();
    for (si, &t) in tokens.iter().enumerate() {
        let step = exec.decode_step(&entry, &mut cache, t, &w).unwrap();
        assert!(step.data().iter().all(|x| x.is_finite()),
                "non-finite logits at pos {si}");
        if si < cap {
            let frow =
                &full.data()[si * cfg.vocab..(si + 1) * cfg.vocab];
            assert!(max_abs_diff(step.data(), frow) < 1e-4,
                    "pre-wrap pos {si} diverged");
        }
    }
    assert_eq!(cache.pos(), 2 * cfg.seq);
}
