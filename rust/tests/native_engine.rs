//! Native-engine coverage that needs NO artifacts: golden-value forward
//! tests, an independent naive-reference cross-check, the fused
//! packed-matmul property test, and the end-to-end offline serving path
//! (quantize → fused packed forward → NLL through
//! `coordinator::server::serve`).

use std::collections::BTreeMap;

use nsds::coordinator::server::{serve, Client, ServedWeights,
                                ServerQueue};
use nsds::eval::ppl::batch_nll;
use nsds::infer::{fused_matmul, Executor, NativeEngine, PackedMatrix,
                  QuantizedModel};
use nsds::model::{ModelConfig, Weights, QUANT_WEIGHTS, WEIGHT_NAMES};
use nsds::quant::{fit_group, pack, rtn, Backend, QuantSpec};
use nsds::runtime::ModelEntry;
use nsds::tensor::matmul::matmul;
use nsds::tensor::Tensor;
use nsds::util::rng::Rng;

fn rel_err(a: &Tensor, b: &Tensor) -> f32 {
    a.sub(b).frob_norm() / b.frob_norm().max(1e-9)
}

/// Zero-knowledge golden value: with every projection AND the unembed
/// zeroed, logits are exactly zero, so the model is uniform and PPL
/// equals the vocabulary size.
#[test]
fn golden_zero_model_is_uniform() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(70);
    let mut w = Weights::synth(&cfg, &mut rng, &[], &[]);
    for name in QUANT_WEIGHTS {
        let dims = cfg.weight_dims(name);
        w.tensors.insert(name.to_string(), Tensor::zeros(dims));
    }
    w.tensors.insert("unembed".to_string(),
                     Tensor::zeros(cfg.weight_dims("unembed")));
    let e = NativeEngine::with_workers(2);
    let b = 2;
    let tokens: Vec<i32> = (0..b * cfg.seq)
        .map(|i| ((i * 11) % cfg.vocab) as i32)
        .collect();
    let logits = e.forward(&entry, &tokens, b, &w).unwrap();
    assert!(logits.data().iter().all(|&x| x == 0.0));
    let (nll, n) = batch_nll(&logits, &tokens, b, cfg.seq);
    let ppl = (nll / n as f64).exp();
    assert!((ppl - cfg.vocab as f64).abs() < 1e-6,
            "uniform ppl {ppl} != vocab {}", cfg.vocab);
}

/// Golden value on a hand-built 1-layer model: identity embed/unembed
/// with zero projections makes the model predict "repeat the last
/// token", so a constant stream scores ~zero NLL.
#[test]
fn golden_identity_model_repeats_last_token() {
    let cfg = ModelConfig {
        name: "ident".into(),
        vocab: 8,
        d_model: 8,
        n_heads: 2,
        n_kv: 2,
        d_head: 2,
        d_ffn: 8,
        n_layers: 1,
        seq: 8,
    };
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut tensors = BTreeMap::new();
    for name in WEIGHT_NAMES {
        let dims = cfg.weight_dims(name);
        let n: usize = dims.iter().product();
        let t = match name {
            "embed" | "unembed" => {
                let scale = if name == "embed" { 5.0 } else { 20.0 };
                let mut m = Tensor::zeros(dims);
                for i in 0..cfg.vocab {
                    m.set(i, i, scale);
                }
                m
            }
            "lnf" | "ln1" | "ln2" => Tensor::new(vec![1.0; n], dims),
            _ => Tensor::zeros(dims),
        };
        tensors.insert(name.to_string(), t);
    }
    let w = Weights { tensors };
    let e = NativeEngine::with_workers(1);
    let tokens = vec![3i32; cfg.seq];
    let logits = e.forward(&entry, &tokens, 1, &w).unwrap();
    // Position-0 logit at token 3: 20·√8·5/√25 ≈ 56.6.
    assert!(logits.data()[3] > 50.0, "{}", logits.data()[3]);
    let (nll, n) = batch_nll(&logits, &tokens, 1, cfg.seq);
    assert_eq!(n, cfg.seq - 1);
    assert!(nll / n as f64 < 1e-3, "repeat-NLL {}", nll / n as f64);
}

/// Independent naive reference forward (straight per-position loops, no
/// blocking, no pools) must agree with the engine on random weights —
/// exercises RoPE, GQA head mapping, causal softmax and SwiGLU.
#[test]
fn forward_matches_naive_reference() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(71);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let e = NativeEngine::with_workers(2);
    let b = 2;
    let tokens: Vec<i32> = (0..b * cfg.seq)
        .map(|_| rng.below(cfg.vocab) as i32)
        .collect();
    let logits = e.forward(&entry, &tokens, b, &w).unwrap();
    for bi in 0..b {
        let naive = naive_forward(&cfg, &w,
                                  &tokens[bi * cfg.seq..(bi + 1) * cfg.seq]);
        let got = Tensor::new(
            logits.data()[bi * cfg.seq * cfg.vocab
                          ..(bi + 1) * cfg.seq * cfg.vocab].to_vec(),
            vec![cfg.seq, cfg.vocab]);
        let want = Tensor::new(naive, vec![cfg.seq, cfg.vocab]);
        let err = rel_err(&got, &want);
        assert!(err < 1e-4, "batch row {bi}: rel err {err}");
    }
}

/// Property: fused packed-code matmul == unpack-then-`tensor::matmul`
/// within 1e-5 (the satellite acceptance bound).
#[test]
fn fused_packed_matmul_matches_unpack_then_matmul() {
    let mut rng = Rng::new(72);
    for case in 0..20 {
        let bits = if case % 2 == 0 { 2u8 } else { 4u8 };
        let k = 8 * (1 + rng.below(24));
        let n = 1 + rng.below(40);
        let m = 1 + rng.below(20);
        let g = fit_group(k, 32);
        let w = Tensor::randn(vec![k, n], &mut rng);
        let x = Tensor::randn(vec![m, k], &mut rng);
        let q = rtn::quantize(&w, QuantSpec::new(bits, g));
        let pm = PackedMatrix::from_quantized(&q);
        // Reference: explicitly unpack codes, dequantize, dense matmul.
        let codes = pack::unpack(&pm.packed, k, n, bits);
        let mut deq = vec![0.0f32; k * n];
        for r in 0..k {
            for c in 0..n {
                let gr = r / g;
                deq[r * n + c] = pm.scale[gr * n + c]
                    * (codes[r * n + c] as f32 - pm.zero[gr * n + c]);
            }
        }
        let reference = matmul(&x, &Tensor::new(deq, vec![k, n]));
        let fused = fused_matmul(&x, &pm, 1 + case % 3);
        let err = rel_err(&fused, &reference);
        assert!(err < 1e-5,
                "case {case} ({m}x{k}x{n}@{bits}b g={g}): rel err {err}");
    }
}

/// The acceptance path: quantize → fused packed forward → NLL through
/// `coordinator::server::serve`, artifact-free, on the native engine.
#[test]
fn serve_packed_end_to_end() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(73);
    let fp = Weights::synth(&cfg, &mut rng, &[], &[]);
    let bits = vec![4u8, 2, 4];
    let qm = QuantizedModel::quantize(&cfg, &fp, &bits, 8,
                                      Backend::Hqq, None, 2);
    let exec = NativeEngine::with_workers(2);

    // Expected NLLs via a direct fused forward, outside the server.
    let n_requests = 6;
    let requests: Vec<Vec<i32>> = (0..n_requests)
        .map(|_| {
            (0..cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect()
        })
        .collect();
    let mut expected = Vec::new();
    for toks in &requests {
        let logits = exec.forward_packed(&entry, toks, 1, &qm).unwrap();
        let (nll, n) = batch_nll(&logits, toks, 1, cfg.seq);
        expected.push(nll / n as f64);
    }

    // Same requests through the batching serve loop.
    let batch = 2;
    let queue = ServerQueue::new(8);
    let client = Client::new(queue.clone(), cfg.seq);
    let reqs = requests.clone();
    let handle = std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
        let mut got = Vec::new();
        for toks in reqs {
            let (nll, n) = client.nll(toks)?;
            got.push(nll / n as f64);
        }
        client.stop();
        Ok(got)
    });
    serve(&exec, &entry, batch, ServedWeights::Packed(qm.clone()),
          &queue).unwrap();
    let got = handle.join().unwrap().unwrap();

    assert_eq!(got.len(), expected.len());
    for (g, e) in got.iter().zip(&expected) {
        assert!((g - e).abs() < 1e-9,
                "served NLL {g} != direct fused NLL {e}");
        assert!(g.is_finite() && *g > 0.0);
    }
    let (served, batches, _) = queue.stats();
    assert_eq!(served, n_requests as u64);
    assert!(batches >= (n_requests / batch) as u64);

    // Mid-stream swap parity: packed serving must equal serving the
    // dequantized weights densely.
    let queue2 = ServerQueue::new(8);
    let client2 = Client::new(queue2.clone(), cfg.seq);
    let toks = requests[0].clone();
    let dq = qm.dequantized_weights();
    let handle2 =
        std::thread::spawn(move || -> anyhow::Result<(f64, f64)> {
            let (a, na) = client2.nll(toks.clone())?;
            client2.swap_weights(dq);
            let (b, nb) = client2.nll(toks)?;
            client2.stop();
            Ok((a / na as f64, b / nb as f64))
        });
    serve(&exec, &entry, batch, ServedWeights::Packed(qm), &queue2)
        .unwrap();
    let (packed_nll, dense_nll) = handle2.join().unwrap().unwrap();
    assert!((packed_nll - dense_nll).abs() < 1e-4,
            "packed {packed_nll} vs dense {dense_nll}");
}

/// GQA shape edge case: kv_heads < heads with a NON-divisible group
/// tail (nh=5, nkv=3 → query-head groups of sizes 2, 2, 1). The engine
/// must agree with the independent naive reference, stay causal, and
/// its KV-cached decode must match the full forward — the shapes the
/// original `hi / (nh/nkv)` mapping indexed out of bounds on.
#[test]
fn gqa_non_divisible_group_tail() {
    let cfg = ModelConfig {
        name: "gqa-ragged".into(),
        vocab: 24,
        d_model: 20,
        n_heads: 5,
        n_kv: 3,
        d_head: 4,
        d_ffn: 16,
        n_layers: 2,
        seq: 10,
    };
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(75);
    let w = Weights::synth(&cfg, &mut rng, &[], &[]);
    let e = NativeEngine::with_workers(2);
    let b = 2;
    let tokens: Vec<i32> = (0..b * cfg.seq)
        .map(|_| rng.below(cfg.vocab) as i32)
        .collect();
    let logits = e.forward(&entry, &tokens, b, &w).unwrap();
    assert!(logits.data().iter().all(|x| x.is_finite()));
    for bi in 0..b {
        let naive = naive_forward(&cfg, &w,
                                  &tokens[bi * cfg.seq..(bi + 1) * cfg.seq]);
        let got = Tensor::new(
            logits.data()[bi * cfg.seq * cfg.vocab
                          ..(bi + 1) * cfg.seq * cfg.vocab].to_vec(),
            vec![cfg.seq, cfg.vocab]);
        let want = Tensor::new(naive, vec![cfg.seq, cfg.vocab]);
        let err = rel_err(&got, &want);
        assert!(err < 1e-4, "batch row {bi}: rel err {err}");
    }
    // Incremental decode agrees on the ragged shape too.
    let mut cache = nsds::infer::KvCache::for_model(&cfg, cfg.seq);
    for (si, &t) in tokens[..cfg.seq].iter().enumerate() {
        let step = e.decode_step(&entry, &mut cache, t, &w).unwrap();
        let frow = &logits.data()[si * cfg.vocab..(si + 1) * cfg.vocab];
        let mx = step
            .data()
            .iter()
            .zip(frow)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(mx < 1e-4, "decode pos {si}: max abs diff {mx}");
    }
    // Divisible tail still maps exactly like the reference grouping
    // (nh=6, nkv=3 → hi/2): spot-check group boundaries via causality.
    let cfg2 = ModelConfig { n_heads: 6, name: "gqa-even".into(), ..cfg };
    let entry2 = ModelEntry::synthetic(cfg2.clone());
    let w2 = Weights::synth(&cfg2, &mut rng, &[], &[]);
    let mut a: Vec<i32> = (0..cfg2.seq)
        .map(|i| (i % cfg2.vocab) as i32)
        .collect();
    let la = e.forward(&entry2, &a, 1, &w2).unwrap();
    a[cfg2.seq - 1] = (a[cfg2.seq - 1] + 1) % cfg2.vocab as i32;
    let lb = e.forward(&entry2, &a, 1, &w2).unwrap();
    let prefix = (cfg2.seq - 1) * cfg2.vocab;
    assert_eq!(la.data()[..prefix], lb.data()[..prefix]);
}

/// Fused packed forward parity against the dense engine on the
/// dequantized weights (whole-model version of the matmul property).
#[test]
fn packed_forward_matches_dequantized_dense_forward() {
    let cfg = ModelConfig::test_config();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(74);
    let fp = Weights::synth(&cfg, &mut rng, &[], &[]);
    let exec = NativeEngine::with_workers(2);
    let b = 2;
    let tokens: Vec<i32> = (0..b * cfg.seq)
        .map(|_| rng.below(cfg.vocab) as i32)
        .collect();
    for backend in [Backend::Rtn, Backend::Hqq] {
        let qm = QuantizedModel::quantize(&cfg, &fp, &[2, 4, 2], 8,
                                          backend, None, 1);
        let fused =
            exec.forward_packed(&entry, &tokens, b, &qm).unwrap();
        let dense = exec
            .forward(&entry, &tokens, b, &qm.dequantized_weights())
            .unwrap();
        let err = rel_err(&fused, &dense);
        assert!(err < 1e-4, "{backend:?}: rel err {err}");
    }
}

// ---------------------------------------------------------------------
// Naive reference implementation (deliberately structured differently
// from infer::native: per-position vectors, no blocking, no buffers).
// ---------------------------------------------------------------------

fn naive_forward(cfg: &ModelConfig, w: &Weights, tokens: &[i32])
    -> Vec<f32> {
    let (s, v) = (cfg.seq, cfg.vocab);
    let (nh, nkv, dh) = (cfg.n_heads, cfg.n_kv, cfg.d_head);
    assert_eq!(tokens.len(), s);
    let embed = w.get("embed");
    let mut h: Vec<Vec<f32>> = tokens
        .iter()
        .map(|&t| embed.row(t as usize).to_vec())
        .collect();

    for l in 0..cfg.n_layers {
        let ln1 = w.get("ln1").slice0(l);
        let ln2 = w.get("ln2").slice0(l);
        let wq = w.layer_matrix("wq", l);
        let wk = w.layer_matrix("wk", l);
        let wv = w.layer_matrix("wv", l);
        let wo = w.layer_matrix("wo", l);
        let wgate = w.layer_matrix("wgate", l);
        let wup = w.layer_matrix("wup", l);
        let wdown = w.layer_matrix("wdown", l);

        // Attention.
        let x1: Vec<Vec<f32>> =
            h.iter().map(|r| naive_rmsnorm(r, ln1.data())).collect();
        let mut q: Vec<Vec<f32>> =
            x1.iter().map(|r| naive_vecmat(r, &wq)).collect();
        let mut kk: Vec<Vec<f32>> =
            x1.iter().map(|r| naive_vecmat(r, &wk)).collect();
        let vv: Vec<Vec<f32>> =
            x1.iter().map(|r| naive_vecmat(r, &wv)).collect();
        for (pos, row) in q.iter_mut().enumerate() {
            for hi in 0..nh {
                naive_rope(&mut row[hi * dh..(hi + 1) * dh], pos);
            }
        }
        for (pos, row) in kk.iter_mut().enumerate() {
            for hi in 0..nkv {
                naive_rope(&mut row[hi * dh..(hi + 1) * dh], pos);
            }
        }
        let mut ctx: Vec<Vec<f32>> = vec![vec![0.0; nh * dh]; s];
        for i in 0..s {
            for hi in 0..nh {
                // Same generalized GQA mapping as the engine: identical
                // to hi / (nh/nkv) when nkv divides nh, well-defined for
                // a ragged tail otherwise.
                let kv = hi * nkv / nh;
                let qh = &q[i][hi * dh..(hi + 1) * dh];
                let raw: Vec<f32> = (0..=i)
                    .map(|j| {
                        let kh = &kk[j][kv * dh..(kv + 1) * dh];
                        qh.iter().zip(kh).map(|(a, b)| a * b)
                            .sum::<f32>()
                            / (dh as f32).sqrt()
                    })
                    .collect();
                let mx =
                    raw.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let exps: Vec<f32> =
                    raw.iter().map(|x| (x - mx).exp()).collect();
                let denom: f32 = exps.iter().sum();
                for (j, ex) in exps.iter().enumerate() {
                    let wgt = ex / denom;
                    let vh = &vv[j][kv * dh..(kv + 1) * dh];
                    for (c, val) in ctx[i][hi * dh..(hi + 1) * dh]
                        .iter_mut()
                        .zip(vh)
                    {
                        *c += wgt * val;
                    }
                }
            }
        }
        for i in 0..s {
            let attn_out = naive_vecmat(&ctx[i], &wo);
            for (hv, a) in h[i].iter_mut().zip(&attn_out) {
                *hv += a;
            }
        }

        // FFN.
        for i in 0..s {
            let x2 = naive_rmsnorm(&h[i], ln2.data());
            let gate = naive_vecmat(&x2, &wgate);
            let up = naive_vecmat(&x2, &wup);
            let mid: Vec<f32> = gate
                .iter()
                .zip(&up)
                .map(|(g, u)| g / (1.0 + (-g).exp()) * u)
                .collect();
            let down = naive_vecmat(&mid, &wdown);
            for (hv, dn) in h[i].iter_mut().zip(&down) {
                *hv += dn;
            }
        }
    }

    let lnf = w.get("lnf");
    let unembed = w.get("unembed");
    let mut out = Vec::with_capacity(s * v);
    for row in &h {
        let hf = naive_rmsnorm(row, lnf.data());
        out.extend(naive_vecmat(&hf, unembed));
    }
    out
}

fn naive_rmsnorm(x: &[f32], g: &[f32]) -> Vec<f32> {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + 1e-5).sqrt();
    x.iter().zip(g).map(|(v, gv)| v * inv * gv).collect()
}

fn naive_vecmat(x: &[f32], w: &Tensor) -> Vec<f32> {
    let (k, n) = (w.rows(), w.cols());
    assert_eq!(x.len(), k);
    (0..n)
        .map(|c| (0..k).map(|r| x[r] * w.at(r, c)).sum())
        .collect()
}

fn naive_rope(x: &mut [f32], pos: usize) {
    let dh = x.len();
    let half = dh / 2;
    for j in 0..half {
        let inv = 10000f32.powf(-(j as f32) / half as f32);
        let ang = pos as f32 * inv;
        let (a, b) = (x[j], x[j + half]);
        x[j] = a * ang.cos() - b * ang.sin();
        x[j + half] = a * ang.sin() + b * ang.cos();
    }
}
