//! Failure-injection and robustness tests for the IO + eval substrates
//! (artifact-independent — always run).

use nsds::tensor::Tensor;
use nsds::util::json::Json;
use nsds::util::tz;

#[test]
fn tz_truncated_file_rejected_not_panicking() {
    let dir = std::env::temp_dir().join("nsds_robust");
    std::fs::create_dir_all(&dir).unwrap();
    // Write a valid file, then truncate at every prefix length: the
    // reader must return Err (never panic, never loop).
    let path = dir.join("full.tz");
    let mut m = tz::TzMap::new();
    m.insert("w".into(),
             tz::RawTensor::F32(Tensor::new(vec![1.0; 12], vec![3, 4])));
    m.insert("g".into(),
             tz::RawTensor::I32 { dims: vec![2], data: vec![5, 6] });
    tz::write_tz(&path, &m).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    for cut in [0, 3, 4, 8, 12, 13, 20, bytes.len() - 1] {
        let p = dir.join(format!("cut{cut}.tz"));
        std::fs::write(&p, &bytes[..cut]).unwrap();
        assert!(tz::read_tz(&p).is_err(), "cut at {cut} accepted");
    }
    // The intact file still reads.
    assert_eq!(tz::read_tz(&path).unwrap().len(), 2);
}

#[test]
fn tz_corrupt_dtype_rejected() {
    let dir = std::env::temp_dir().join("nsds_robust2");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("x.tz");
    let mut m = tz::TzMap::new();
    m.insert("w".into(),
             tz::RawTensor::U8 { dims: vec![2], data: vec![1, 2] });
    tz::write_tz(&path, &m).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // dtype byte sits right after magic+version+count+namelen+name.
    let dtype_pos = 4 + 4 + 4 + 4 + 1;
    bytes[dtype_pos] = 42;
    let p = dir.join("bad_dtype.tz");
    std::fs::write(&p, &bytes).unwrap();
    assert!(tz::read_tz(&p).is_err());
}

#[test]
fn json_fuzz_never_panics() {
    // Deterministic mutation fuzz over a seed document: parser must
    // return Ok or Err, never panic or hang.
    let seed = r#"{"models":{"a":{"hlo":["f","g"],"n":1.5e3}},"ok":true}"#;
    let mut rng = nsds::util::rng::Rng::new(99);
    for _ in 0..2000 {
        let mut b = seed.as_bytes().to_vec();
        let flips = 1 + rng.below(4);
        for _ in 0..flips {
            let i = rng.below(b.len());
            b[i] = (rng.below(127) as u8).max(1);
        }
        if let Ok(s) = String::from_utf8(b) {
            let _ = Json::parse(&s);
        }
    }
}

#[test]
fn batch_nll_handles_single_token_rows() {
    // S=1 means zero predictions — must not panic or divide by zero.
    let logits = Tensor::zeros(vec![2, 1, 4]);
    let tokens = vec![0, 1];
    let (nll, n) = nsds::eval::ppl::batch_nll(&logits, &tokens, 2, 1);
    assert_eq!(n, 0);
    assert_eq!(nll, 0.0);
}

#[test]
fn quantize_extreme_values_stay_finite() {
    // Denormals, huge magnitudes and constant groups must all survive
    // every backend without NaN/inf.
    let mut data = vec![0.0f32; 64];
    data[0] = 1e30;
    data[1] = -1e30;
    data[2] = 1e-38;
    for d in data.iter_mut().skip(32) {
        *d = 7.0; // constant group
    }
    let w = Tensor::new(data, vec![64, 1]);
    for backend in [nsds::quant::Backend::Rtn, nsds::quant::Backend::Hqq,
                    nsds::quant::Backend::Gptq] {
        let q = nsds::quant::quantize_matrix(
            &w, nsds::quant::QuantSpec::new(2, 32), backend, None);
        let d = q.dequantize();
        assert!(d.data().iter().all(|x| x.is_finite()),
                "{backend:?} produced non-finite dequant");
    }
}

#[test]
fn svd_degenerate_inputs() {
    // Zero matrix, rank-0, single column/row — all must return finite
    // factors with non-negative sigma.
    for t in [Tensor::zeros(vec![5, 3]), Tensor::zeros(vec![1, 1]),
              Tensor::new(vec![2.0], vec![1, 1]),
              Tensor::new(vec![1.0, 2.0, 3.0], vec![3, 1])] {
        let s = nsds::tensor::svd::svd(&t);
        assert!(s.sigma.iter().all(|x| x.is_finite() && *x >= 0.0));
        let rec = s.reconstruct();
        assert!((rec.frob_norm() - t.frob_norm()).abs() < 1e-4);
    }
}
