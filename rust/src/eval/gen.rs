//! Generation-level scoring: instead of teacher-forced perplexity, run
//! the KV-cached decode path and score what the model actually *emits* —
//! the regime a served deployment is judged by, and the evaluation axis
//! generation-level LMPQ baselines report. Two metrics:
//!
//! * `continuation_match` — greedy-decode held-out corpus windows and
//!   count exact matches against the true continuation (free-running vs
//!   ground truth).
//! * `greedy_agreement` — token-level agreement between two deployed
//!   variants (e.g. FP32 vs a packed 2/4-bit model) on the same prompts;
//!   the data-free check that an NSDS allocation preserves downstream
//!   generation behavior, not just logit closeness.
//!
//! Both have `*_in_context` variants that condition every window on one
//! shared context (e.g. a few-shot preamble): the windows decode as one
//! batched stream, and the engine's prefix-aware admission over the
//! paged KV pool means the context is prefilled ONCE and resident ONCE —
//! later windows reference the first window's context pages
//! copy-on-write instead of re-prefilling and re-storing them. That is
//! the cheap-repeated-forward-pass regime data-free sensitivity sweeps
//! (many scoring windows over one context) live in.

use anyhow::{ensure, Result};

use crate::infer::{generate_batch, generate_batch_spec, Executor,
                   GenConfig, Generation, ModelRef, Sampling,
                   SpecDecode};
use crate::runtime::ModelEntry;

/// Concurrent sequences per scoring stream: windows decode as one
/// continuous batch (weight reads shared across windows) instead of N
/// serial generations. Greedy decoding is batch-invariant, so the
/// metrics are identical to the sequential values.
const SCORE_SLOTS: usize = 8;

/// Greedy-decode every window's prompt — prefixed by the shared
/// `context`, which the batched engine's prefix-aware admission keeps
/// resident as ONE set of pages — in one batched stream.
pub(super) fn batch_greedy(exec: &dyn Executor, entry: &ModelEntry,
                           model: ModelRef, context: &[i32],
                           wins: &[(&[i32], &[i32])], gen_len: usize)
                           -> Result<Vec<Generation>> {
    let cfg = greedy_cfg(gen_len);
    let reqs: Vec<(Vec<i32>, GenConfig)> = wins
        .iter()
        .map(|(p, _)| {
            let mut prompt = Vec::with_capacity(context.len() + p.len());
            prompt.extend_from_slice(context);
            prompt.extend_from_slice(p);
            (prompt, cfg.clone())
        })
        .collect();
    generate_batch(exec, entry, model, &reqs,
                   SCORE_SLOTS.min(reqs.len().max(1)))
}

/// Cut `corpus` into non-overlapping (prompt, continuation) windows.
pub(super) fn windows(corpus: &[i32], prompt_len: usize, gen_len: usize,
                      max_prompts: usize) -> Vec<(&[i32], &[i32])> {
    let w = prompt_len + gen_len;
    corpus
        .chunks_exact(w)
        .take(max_prompts)
        .map(|c| (&c[..prompt_len], &c[prompt_len..]))
        .collect()
}

fn greedy_cfg(gen_len: usize) -> GenConfig {
    GenConfig {
        max_new: gen_len,
        sampling: Sampling::Greedy,
        seed: 0,
        stop: Vec::new(),
        cap: 0,
        spec: None,
    }
}

/// Fraction of greedily generated tokens that exactly match the held-out
/// continuation, over up to `max_prompts` corpus windows.
pub fn continuation_match(exec: &dyn Executor, entry: &ModelEntry,
                          model: ModelRef, corpus: &[i32],
                          prompt_len: usize, gen_len: usize,
                          max_prompts: usize) -> Result<f64> {
    continuation_match_in_context(exec, entry, model, &[], corpus,
                                  prompt_len, gen_len, max_prompts)
}

/// `continuation_match` with every window conditioned on one shared
/// `context` prefix. The context's KV pages are prefilled once and
/// shared across all windows (copy-on-write), so scoring cost scales
/// with the windows, not windows × context.
#[allow(clippy::too_many_arguments)]
pub fn continuation_match_in_context(
    exec: &dyn Executor, entry: &ModelEntry, model: ModelRef,
    context: &[i32], corpus: &[i32], prompt_len: usize, gen_len: usize,
    max_prompts: usize) -> Result<f64> {
    ensure!(prompt_len > 0 && gen_len > 0, "empty window");
    let wins = windows(corpus, prompt_len, gen_len, max_prompts);
    ensure!(!wins.is_empty(),
            "corpus too short for a {prompt_len}+{gen_len} window");
    let gens = batch_greedy(exec, entry, model, context, &wins, gen_len)?;
    let mut hits = 0usize;
    let mut total = 0usize;
    for (g, (_, truth)) in gens.iter().zip(&wins) {
        hits += g
            .tokens
            .iter()
            .zip(*truth)
            .filter(|(a, b)| a == b)
            .count();
        total += truth.len();
    }
    Ok(hits as f64 / total as f64)
}

/// `continuation_match`, decoded speculatively: every window drafts
/// `k` tokens per step with the cheaper `drafter` variant and verifies
/// them in one multi-row `target` pass. Greedy acceptance is exact, so
/// the score is bit-identical to `continuation_match(target)` — what
/// changes is the number of target forward passes, not the tokens.
/// This is the scoring path a spec-decode deployment is judged by: it
/// proves the (target, drafter) pair's accept rate on real corpus
/// windows without ever risking the metric itself.
#[allow(clippy::too_many_arguments)]
pub fn continuation_match_spec(
    exec: &dyn Executor, entry: &ModelEntry, target: ModelRef,
    drafter: ModelRef, k: usize, corpus: &[i32], prompt_len: usize,
    gen_len: usize, max_prompts: usize) -> Result<f64> {
    ensure!(prompt_len > 0 && gen_len > 0, "empty window");
    let wins = windows(corpus, prompt_len, gen_len, max_prompts);
    ensure!(!wins.is_empty(),
            "corpus too short for a {prompt_len}+{gen_len} window");
    let mut cfg = greedy_cfg(gen_len);
    cfg.spec = Some(SpecDecode { k });
    let reqs: Vec<(Vec<i32>, GenConfig)> = wins
        .iter()
        .map(|(p, _)| (p.to_vec(), cfg.clone()))
        .collect();
    let gens = generate_batch_spec(exec, entry, target, drafter, &reqs,
                                   SCORE_SLOTS.min(reqs.len().max(1)))?;
    let mut hits = 0usize;
    let mut total = 0usize;
    for (g, (_, truth)) in gens.iter().zip(&wins) {
        hits += g
            .tokens
            .iter()
            .zip(*truth)
            .filter(|(a, b)| a == b)
            .count();
        total += truth.len();
    }
    Ok(hits as f64 / total as f64)
}

/// Token-level agreement between two variants' greedy generations on the
/// same corpus prompts (1.0 = identical decoding behavior).
#[allow(clippy::too_many_arguments)]
pub fn greedy_agreement(exec: &dyn Executor, entry: &ModelEntry,
                        a: ModelRef, b: ModelRef, corpus: &[i32],
                        prompt_len: usize, gen_len: usize,
                        max_prompts: usize) -> Result<f64> {
    greedy_agreement_in_context(exec, entry, a, b, &[], corpus,
                                prompt_len, gen_len, max_prompts)
}

/// `greedy_agreement` with every window conditioned on one shared
/// `context` prefix (prefilled once per variant, pages shared across
/// that variant's windows).
#[allow(clippy::too_many_arguments)]
pub fn greedy_agreement_in_context(
    exec: &dyn Executor, entry: &ModelEntry, a: ModelRef, b: ModelRef,
    context: &[i32], corpus: &[i32], prompt_len: usize, gen_len: usize,
    max_prompts: usize) -> Result<f64> {
    ensure!(prompt_len > 0 && gen_len > 0, "empty window");
    let wins = windows(corpus, prompt_len, gen_len, max_prompts);
    ensure!(!wins.is_empty(),
            "corpus too short for a {prompt_len}+{gen_len} window");
    let gens_a = batch_greedy(exec, entry, a, context, &wins, gen_len)?;
    let gens_b = batch_greedy(exec, entry, b, context, &wins, gen_len)?;
    let mut agree = 0usize;
    let mut total = 0usize;
    for (ga, gb) in gens_a.iter().zip(&gens_b) {
        agree += ga
            .tokens
            .iter()
            .zip(&gb.tokens)
            .filter(|(x, y)| x == y)
            .count();
        total += ga.tokens.len().max(gb.tokens.len());
    }
    ensure!(total > 0, "no tokens generated");
    Ok(agree as f64 / total as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_are_disjoint_and_sized() {
        let corpus: Vec<i32> = (0..40).collect();
        let w = windows(&corpus, 6, 2, 3);
        assert_eq!(w.len(), 3);
        assert_eq!(w[0].0, &corpus[..6]);
        assert_eq!(w[0].1, &corpus[6..8]);
        assert_eq!(w[1].0, &corpus[8..14]);
        // Truncated by max_prompts even though more fit.
        assert_eq!(windows(&corpus, 6, 2, 100).len(), 5);
        // Too-short corpus yields nothing.
        assert!(windows(&corpus[..5], 6, 2, 3).is_empty());
    }
}
