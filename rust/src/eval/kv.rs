//! Quality gate for mixed-precision KV-cache deployments: before a
//! `kv_bits` plan (usually `allocate::allocate_kv_bits` over NSDS layer
//! scores) ships, measure what quantized K/V storage does to the tokens
//! a deployment actually emits — against the same model with all-f32 KV,
//! the only thing that changes between the two runs being the cache
//! precision. Two axes, mirroring the weight-quantization gate in
//! `eval::gen`:
//!
//! * greedy agreement — token-level match between the quantized-KV and
//!   f32-KV engines' greedy generations on held-out corpus windows
//!   (1.0 = KV quantization never flips a token);
//! * decode-path perplexity — teacher-forced NLL computed from the
//!   chunked-prefill logits THROUGH the paged pool, so later positions
//!   attend to quantized K/V rows exactly as serving does (the
//!   teacher-forced `eval::ppl` path never touches the cache and cannot
//!   see KV error).
//!
//! `gate_kv_bits` bundles both with the resident-bytes ratio into a
//! `KvGate` report; `KvGate::pass` is the shippable check.

use anyhow::{ensure, Result};

use super::gen::{batch_greedy, windows};
use crate::infer::{Executor, KvCachePool, ModelRef, PAGE_SIZE};
use crate::runtime::ModelEntry;

/// Gate report for one `kv_bits` plan (see module docs).
#[derive(Clone, Debug)]
pub struct KvGate {
    /// Token-level greedy agreement, quantized-KV vs f32-KV engine.
    pub agreement: f64,
    /// Decode-path mean NLL per token, all-f32 KV.
    pub nll_f32: f64,
    /// Decode-path mean NLL per token, quantized KV.
    pub nll_kv: f64,
    /// Resident bytes per page, all-f32 KV.
    pub page_bytes_f32: usize,
    /// Resident bytes per page under the plan.
    pub page_bytes_kv: usize,
}

impl KvGate {
    pub fn ppl_f32(&self) -> f64 {
        self.nll_f32.exp()
    }

    pub fn ppl_kv(&self) -> f64 {
        self.nll_kv.exp()
    }

    /// Relative perplexity increase over the f32-KV baseline
    /// (0.01 = +1%; negative means the quantized run scored better,
    /// which at these tolerances is noise, not signal).
    pub fn ppl_delta(&self) -> f64 {
        self.ppl_kv() / self.ppl_f32() - 1.0
    }

    /// Resident-KV shrink factor (pages are fixed-size per plan, so
    /// the page ratio IS the resident ratio at any occupancy).
    pub fn bytes_ratio(&self) -> f64 {
        self.page_bytes_f32 as f64 / self.page_bytes_kv as f64
    }

    /// The deployment check: agreement at or above `min_agreement` AND
    /// relative perplexity increase at or below `max_ppl_delta`.
    pub fn pass(&self, min_agreement: f64, max_ppl_delta: f64) -> bool {
        self.agreement >= min_agreement
            && self.ppl_delta() <= max_ppl_delta
    }
}

/// Token-level greedy agreement between `entry`-with-`kv_bits` and
/// `entry`-with-f32-KV engines decoding the same corpus windows with
/// the same `model` weights. The two runs differ ONLY in cache
/// precision: same executor, same greedy config, same batch layout.
#[allow(clippy::too_many_arguments)]
pub fn kv_greedy_agreement(exec: &dyn Executor, entry: &ModelEntry,
                           model: ModelRef, kv_bits: &[u8],
                           corpus: &[i32], prompt_len: usize,
                           gen_len: usize, max_prompts: usize)
                           -> Result<f64> {
    ensure!(prompt_len > 0 && gen_len > 0, "empty window");
    let wins = windows(corpus, prompt_len, gen_len, max_prompts);
    ensure!(!wins.is_empty(),
            "corpus too short for a {prompt_len}+{gen_len} window");
    let mut base = entry.clone();
    base.kv_bits = None;
    let quant = base.clone().with_kv_bits(kv_bits.to_vec());
    let gens_f = batch_greedy(exec, &base, model, &[], &wins, gen_len)?;
    let gens_q = batch_greedy(exec, &quant, model, &[], &wins, gen_len)?;
    let mut agree = 0usize;
    let mut total = 0usize;
    for (gf, gq) in gens_f.iter().zip(&gens_q) {
        agree += gf
            .tokens
            .iter()
            .zip(&gq.tokens)
            .filter(|(x, y)| x == y)
            .count();
        total += gf.tokens.len().max(gq.tokens.len());
    }
    ensure!(total > 0, "no tokens generated");
    Ok(agree as f64 / total as f64)
}

/// Teacher-forced mean NLL per next-token prediction, computed from
/// chunked-prefill logits through a paged pool built to `entry`'s
/// `kv_bits` plan. Each window prefills in `PAGE_SIZE`-aligned chunks,
/// so every position past the first chunk attends to K/V rows read
/// back from (possibly quantized) cache storage — the serving regime.
pub fn decode_path_nll(exec: &dyn Executor, entry: &ModelEntry,
                       model: ModelRef, corpus: &[i32],
                       window_len: usize, max_windows: usize)
                       -> Result<f64> {
    ensure!(window_len >= 2, "window needs at least one prediction");
    let cfg = &entry.config;
    let v = cfg.vocab;
    let mut pool = match &entry.kv_bits {
        Some(bits) => KvCachePool::for_model_with_bits(cfg, 1, bits),
        None => KvCachePool::for_model(cfg, 1),
    };
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for win in corpus.chunks_exact(window_len).take(max_windows) {
        let slot = pool.admit(window_len).expect("1-slot pool is free");
        let mut pos = 0usize;
        while pos < win.len() {
            let n = (win.len() - pos).min(PAGE_SIZE);
            let chunk = &win[pos..pos + n];
            let logits =
                model.prefill_chunk(exec, entry, &mut pool, slot, chunk)?;
            let data = logits.data();
            for i in 0..n {
                let t = pos + i;
                if t + 1 >= win.len() {
                    break;
                }
                let row = &data[i * v..(i + 1) * v];
                let mx = row.iter().cloned().fold(f32::MIN, f32::max);
                let lse: f64 = row
                    .iter()
                    .map(|&x| ((x - mx) as f64).exp())
                    .sum::<f64>()
                    .ln()
                    + mx as f64;
                nll += lse - row[win[t + 1] as usize] as f64;
                count += 1;
            }
            pos += n;
        }
        pool.retire(slot);
    }
    ensure!(count > 0, "corpus too short for a {window_len} window");
    Ok(nll / count as f64)
}

/// Full gate for one `kv_bits` plan: greedy agreement + decode-path
/// NLL on both precisions + the resident-bytes ratio, over the same
/// corpus windows (prompt/continuation split for agreement, whole
/// windows for NLL).
#[allow(clippy::too_many_arguments)]
pub fn gate_kv_bits(exec: &dyn Executor, entry: &ModelEntry,
                    model: ModelRef, kv_bits: &[u8], corpus: &[i32],
                    prompt_len: usize, gen_len: usize,
                    max_prompts: usize) -> Result<KvGate> {
    let agreement =
        kv_greedy_agreement(exec, entry, model, kv_bits, corpus,
                            prompt_len, gen_len, max_prompts)?;
    let mut base = entry.clone();
    base.kv_bits = None;
    let quant = base.clone().with_kv_bits(kv_bits.to_vec());
    let wl = prompt_len + gen_len;
    let nll_f32 =
        decode_path_nll(exec, &base, model, corpus, wl, max_prompts)?;
    let nll_kv =
        decode_path_nll(exec, &quant, model, corpus, wl, max_prompts)?;
    let pb_f32 = KvCachePool::for_model(&entry.config, 1).page_bytes();
    let pb_kv =
        KvCachePool::for_model_with_bits(&entry.config, 1, kv_bits)
            .page_bytes();
    Ok(KvGate {
        agreement,
        nll_f32,
        nll_kv,
        page_bytes_f32: pb_f32,
        page_bytes_kv: pb_kv,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::NativeEngine;
    use crate::model::{ModelConfig, Weights};
    use crate::runtime::ModelEntry;
    use crate::util::rng::Rng;

    /// All-16 `kv_bits` is the compatibility mode: the gate must report
    /// exact agreement and a zero perplexity delta, because the f32 arm
    /// runs the identical float ops.
    #[test]
    fn all_f32_plan_gates_clean() {
        let cfg = ModelConfig::test_config();
        let entry = ModelEntry::synthetic(cfg.clone());
        let mut rng = Rng::new(17);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let corpus: Vec<i32> =
            (0..160).map(|_| rng.below(cfg.vocab) as i32).collect();
        let exec = NativeEngine::with_workers(2);
        let bits = vec![16u8; cfg.n_layers];
        let g = gate_kv_bits(&exec, &entry, ModelRef::Dense(&w), &bits,
                             &corpus, 8, 4, 3)
            .unwrap();
        assert_eq!(g.agreement, 1.0);
        assert_eq!(g.nll_f32, g.nll_kv);
        assert_eq!(g.bytes_ratio(), 1.0);
        assert!(g.pass(1.0, 0.0));
    }

    /// Int8 KV on the tiny test model: the gate runs end-to-end, the
    /// bytes ratio matches the layout arithmetic, and the NLL stays
    /// finite. At `test_config`'s d_head = 4 the per-segment (scale,
    /// zero) metadata dominates — ratio 4·dh/(dh+8) = 4/3 exactly; the
    /// ≥3× shrink claim lives at realistic head dims (cache.rs unit
    /// tests at d_head = 32 and the bench geometry).
    #[test]
    fn int8_plan_reports_shrink_and_finite_quality() {
        let cfg = ModelConfig::test_config();
        let entry = ModelEntry::synthetic(cfg.clone());
        let mut rng = Rng::new(18);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let corpus: Vec<i32> =
            (0..160).map(|_| rng.below(cfg.vocab) as i32).collect();
        let exec = NativeEngine::with_workers(2);
        let bits = vec![8u8; cfg.n_layers];
        let g = gate_kv_bits(&exec, &entry, ModelRef::Dense(&w), &bits,
                             &corpus, 8, 4, 3)
            .unwrap();
        assert!((g.bytes_ratio() - 4.0 / 3.0).abs() < 1e-12,
                "ratio {}", g.bytes_ratio());
        assert!(g.nll_kv.is_finite() && g.nll_f32.is_finite());
        assert!((0.0..=1.0).contains(&g.agreement));
    }
}
