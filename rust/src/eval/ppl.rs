//! Perplexity evaluation through any `infer::Executor` forward.
//!
//! The corpus is cut into non-overlapping [batch, seq] windows; the
//! executor returns logits and rust computes next-token NLL with a
//! numerically stable log-softmax. exp(mean NLL) is the reported PPL —
//! the same protocol as the paper's WikiText-2 / C4 numbers.

use anyhow::Result;

use crate::infer::Executor;
use crate::model::Weights;
use crate::runtime::{Manifest, ModelEntry};
use crate::tensor::Tensor;
use crate::util::tz;

pub struct Corpora {
    pub train: Vec<i32>,
    pub wiki_like: Vec<i32>,
    pub c4_like: Vec<i32>,
}

pub fn load_corpora(man: &Manifest) -> Result<Corpora> {
    let raw = tz::read_tz(&man.dir.join(&man.corpus_file))?;
    let get = |k: &str| -> Result<Vec<i32>> {
        Ok(raw[k].as_i32()?.1.to_vec())
    };
    Ok(Corpora {
        train: get("train")?,
        wiki_like: get("wiki_like")?,
        c4_like: get("c4_like")?,
    })
}

/// Sum NLL + predicted-token count for one logits batch.
/// logits [B, S, V] predicting tokens[b, s+1].
pub fn batch_nll(logits: &Tensor, tokens: &[i32], b: usize, s: usize)
    -> (f64, usize) {
    let v = logits.dims()[2];
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for bi in 0..b {
        for si in 0..s - 1 {
            let row = &logits.data()
                [(bi * s + si) * v..(bi * s + si + 1) * v];
            let target = tokens[bi * s + si + 1] as usize;
            nll -= log_softmax_at(row, target);
            count += 1;
        }
    }
    (nll, count)
}

/// log p(target) under a stable log-softmax of `row`.
pub fn log_softmax_at(row: &[f32], target: usize) -> f64 {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
    let lse: f64 = row.iter().map(|&x| ((x as f64) - mx).exp()).sum();
    (row[target] as f64 - mx) - lse.ln()
}

/// Perplexity of `weights` on a token stream, using at most `max_batches`
/// non-overlapping [eval_batch, seq] windows.
pub fn perplexity(exec: &dyn Executor, man: &Manifest, entry: &ModelEntry,
                  weights: &Weights, tokens: &[i32], max_batches: usize)
                  -> Result<f64> {
    let b = man.eval_batch;
    let s = entry.config.seq;
    let per = b * s;
    let n_batches = (tokens.len() / per).min(max_batches).max(1);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for i in 0..n_batches {
        let chunk = &tokens[i * per..(i + 1) * per];
        let logits = exec.forward(entry, chunk, b, weights)?;
        let (n, c) = batch_nll(&logits, chunk, b, s);
        nll += n;
        count += c;
    }
    Ok((nll / count as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_sums_to_one() {
        let row = vec![1.0f32, 2.0, 3.0, -1.0];
        let total: f64 = (0..4).map(|t| log_softmax_at(&row, t).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9, "{total}");
    }

    #[test]
    fn batch_nll_counts_predictions() {
        // B=2, S=3, V=4, uniform logits -> nll = ln 4 per prediction.
        let logits = Tensor::zeros(vec![2, 3, 4]);
        let tokens = vec![0, 1, 2, 3, 0, 1];
        let (nll, n) = batch_nll(&logits, &tokens, 2, 3);
        assert_eq!(n, 4); // (S-1) per row
        assert!((nll / n as f64 - 4f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn perfect_prediction_zero_nll() {
        // Put a huge logit on the true next token.
        let tokens = vec![0, 1, 2, 3];
        let mut logits = Tensor::zeros(vec![1, 4, 8]);
        for si in 0..3 {
            let tgt = tokens[si + 1] as usize;
            logits.data_mut()[si * 8 + tgt] = 100.0;
        }
        let (nll, n) = batch_nll(&logits, &tokens, 1, 4);
        assert_eq!(n, 3);
        assert!(nll < 1e-6);
    }
}
