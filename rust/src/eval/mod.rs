//! Evaluation harness: perplexity on the held-out corpora, the six
//! reasoning tasks, and (optionally) generation-level scoring through
//! the KV-cached decode path — all executed THROUGH an `infer::Executor`
//! (the same path a production deployment serves — native engine by
//! default, PJRT behind the `xla` feature).

pub mod gen;
pub mod kv;
pub mod ppl;
pub mod tasks;

use anyhow::Result;

use crate::infer::{Executor, ModelRef};
use crate::model::Weights;
use crate::runtime::{Manifest, ModelEntry};

/// Full evaluation result for one (model, weight-variant).
#[derive(Clone, Debug)]
pub struct EvalResult {
    /// (corpus name, perplexity)
    pub ppl: Vec<(String, f64)>,
    /// (task name, accuracy %)
    pub acc: Vec<(String, f64)>,
}

impl EvalResult {
    pub fn avg_ppl(&self) -> f64 {
        self.ppl.iter().map(|(_, p)| p).sum::<f64>()
            / self.ppl.len().max(1) as f64
    }

    pub fn avg_acc(&self) -> f64 {
        self.acc.iter().map(|(_, a)| a).sum::<f64>()
            / self.acc.len().max(1) as f64
    }

    pub fn ppl_for(&self, name: &str) -> Option<f64> {
        self.ppl.iter().find(|(n, _)| n == name).map(|(_, p)| *p)
    }

    pub fn acc_for(&self, name: &str) -> Option<f64> {
        self.acc.iter().find(|(n, _)| n == name).map(|(_, a)| *a)
    }
}

/// Evaluation workload knobs (the experiment harnesses shrink these for
/// sweeps; defaults reproduce the headline tables).
#[derive(Clone, Copy, Debug)]
pub struct EvalOptions {
    /// Max eval batches per corpus (each batch = eval_batch × seq tokens).
    pub max_ppl_batches: usize,
    /// Max items per reasoning task.
    pub max_task_items: usize,
    /// Corpus windows for generation-level scoring through the KV-cached
    /// decode path (`eval::gen::continuation_match` on wiki_like, greedy,
    /// prompt = seq/2, continuation = seq/4). 0 disables it — the
    /// teacher-forced default workload.
    pub gen_windows: usize,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions {
            max_ppl_batches: 16,
            max_task_items: 32,
            gen_windows: 0,
        }
    }
}

impl EvalOptions {
    /// Reduced workload for wide parameter sweeps (Fig. 3).
    pub fn fast() -> Self {
        EvalOptions {
            max_ppl_batches: 6,
            max_task_items: 16,
            gen_windows: 0,
        }
    }

    /// Enable generation-level scoring over `n` corpus windows.
    pub fn with_gen_windows(mut self, n: usize) -> Self {
        self.gen_windows = n;
        self
    }
}

/// Evaluate a weight variant on both corpora and all six tasks.
pub fn evaluate(exec: &dyn Executor, man: &Manifest, entry: &ModelEntry,
                weights: &Weights, opts: &EvalOptions) -> Result<EvalResult> {
    let corpora = ppl::load_corpora(man)?;
    let mut ppl_rows = Vec::new();
    for (name, tokens) in [("wikitext2_like", &corpora.wiki_like),
                           ("c4_like", &corpora.c4_like)] {
        let p = ppl::perplexity(exec, man, entry, weights, tokens,
                                opts.max_ppl_batches)?;
        ppl_rows.push((name.to_string(), p));
    }
    let task_set = tasks::load_tasks(man)?;
    let mut acc_rows = Vec::new();
    for t in &task_set {
        let a = tasks::accuracy(exec, man, entry, weights, t,
                                opts.max_task_items)?;
        acc_rows.push((t.name.clone(), a));
    }
    if opts.gen_windows > 0 {
        let s = entry.config.seq;
        let m = gen::continuation_match(
            exec, entry, ModelRef::Dense(weights), &corpora.wiki_like,
            (s / 2).max(1), (s / 4).max(1), opts.gen_windows)?;
        acc_rows.push(("gen_match".to_string(), 100.0 * m));
    }
    Ok(EvalResult { ppl: ppl_rows, acc: acc_rows })
}
