//! Reasoning-task evaluation (the paper's six benchmarks → our six
//! synthetic analogs; see DESIGN.md "Substitutions").
//!
//! Protocol = lm-eval-harness choice scoring: for each item, score every
//! choice continuation by its length-normalized log-likelihood given the
//! prompt, predict the argmax, report accuracy. All forwards go through
//! the configured `infer::Executor` in batches of `eval_batch` rows.

use anyhow::{Context, Result};

use crate::eval::ppl::log_softmax_at;
use crate::infer::Executor;
use crate::model::Weights;
use crate::runtime::{Manifest, ModelEntry};
use crate::util::tz;

#[derive(Clone, Debug)]
pub struct TaskData {
    pub name: String,
    pub k: usize,
    /// [n·k, seq] zero-padded prompt+choice token rows.
    pub tokens: Vec<i32>,
    pub seq: usize,
    pub prompt_len: Vec<i32>,
    pub total_len: Vec<i32>,
    pub gold: Vec<i32>,
}

pub fn load_tasks(man: &Manifest) -> Result<Vec<TaskData>> {
    let raw = tz::read_tz(&man.dir.join(&man.tasks_file))?;
    man.tasks
        .iter()
        .map(|meta| {
            let get = |suffix: &str| -> Result<(Vec<usize>, Vec<i32>)> {
                let t = raw
                    .get(&format!("{}.{suffix}", meta.name))
                    .with_context(|| format!("{}.{suffix}", meta.name))?;
                let (dims, data) = t.as_i32()?;
                Ok((dims.to_vec(), data.to_vec()))
            };
            let (tdims, tokens) = get("tokens")?;
            Ok(TaskData {
                name: meta.name.clone(),
                k: meta.k,
                seq: tdims[1],
                tokens,
                prompt_len: get("prompt_len")?.1,
                total_len: get("total_len")?.1,
                gold: get("gold")?.1,
            })
        })
        .collect()
}

/// Length-normalized continuation log-likelihood of row `r` given logits.
fn row_score(logits_row: &[f32], tokens_row: &[i32], v: usize,
             prompt_len: usize, total_len: usize) -> f64 {
    let mut lp = 0.0f64;
    let mut n = 0usize;
    // predict tokens at positions prompt_len..total_len from the logits at
    // the preceding position.
    for pos in prompt_len..total_len {
        let prev = pos - 1;
        let row = &logits_row[prev * v..(prev + 1) * v];
        lp += log_softmax_at(row, tokens_row[pos] as usize);
        n += 1;
    }
    if n == 0 {
        f64::NEG_INFINITY
    } else {
        lp / n as f64
    }
}

/// Accuracy (%) of `weights` on one task, using at most `max_items` items.
pub fn accuracy(exec: &dyn Executor, man: &Manifest, entry: &ModelEntry,
                weights: &Weights, task: &TaskData, max_items: usize)
                -> Result<f64> {
    let b = man.eval_batch;
    let s = task.seq;
    assert_eq!(s, entry.config.seq, "task/model seq mismatch");
    let v = entry.config.vocab;
    let n_items = task.gold.len().min(max_items);
    let n_rows = n_items * task.k;

    // Score all rows in eval_batch-sized chunks (zero-pad the tail).
    let mut scores = vec![0.0f64; n_rows];
    let mut r0 = 0usize;
    while r0 < n_rows {
        let rows = (n_rows - r0).min(b);
        let mut chunk = vec![0i32; b * s];
        chunk[..rows * s].copy_from_slice(
            &task.tokens[r0 * s..(r0 + rows) * s]);
        let logits = exec.forward(entry, &chunk, b, weights)?;
        for r in 0..rows {
            let gi = r0 + r;
            scores[gi] = row_score(
                &logits.data()[r * s * v..(r + 1) * s * v],
                &task.tokens[gi * s..(gi + 1) * s],
                v,
                task.prompt_len[gi] as usize,
                task.total_len[gi] as usize,
            );
        }
        r0 += rows;
    }

    let mut correct = 0usize;
    for i in 0..n_items {
        let base = i * task.k;
        let pred = (0..task.k)
            .max_by(|&a, &b| {
                scores[base + a].total_cmp(&scores[base + b])
            })
            .unwrap();
        if pred as i32 == task.gold[i] {
            correct += 1;
        }
    }
    Ok(100.0 * correct as f64 / n_items as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_score_prefers_likely_continuation() {
        // V=4, seq=4, prompt_len=2, total=4. Continuation tokens: [2, 3].
        let v = 4;
        let tokens = vec![0i32, 1, 2, 3];
        let mut logits = vec![0.0f32; 4 * v];
        // position 1 predicts token 2; position 2 predicts token 3.
        logits[v + 2] = 5.0;
        logits[2 * v + 3] = 5.0;
        let good = row_score(&logits, &tokens, v, 2, 4);
        let bad_tokens = vec![0i32, 1, 0, 0];
        let bad = row_score(&logits, &bad_tokens, v, 2, 4);
        assert!(good > bad, "good {good} bad {bad}");
    }

    #[test]
    fn length_normalization() {
        // Same per-token logprob, different lengths -> equal scores.
        let v = 2;
        let tokens3 = vec![0i32, 0, 0];
        let logits = vec![0.0f32; 3 * v];
        let s2 = row_score(&logits, &tokens3, v, 1, 2);
        let s3 = row_score(&logits, &tokens3, v, 1, 3);
        assert!((s2 - s3).abs() < 1e-12);
    }
}
