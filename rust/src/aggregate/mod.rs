//! Score aggregation (paper §2.3): MAD-Sigmoid robust normalization and
//! the Soft-OR operator with the n-th-root saturation guard (footnote 4).

use crate::tensor::stats::{mad, median};

/// Scale factor making MAD comparable to a standard deviation under
/// normality (paper Eq. 10).
pub const MAD_SIGMA: f64 = 1.4826;

/// Paper's ε in Eq. 10.
pub const EPS: f64 = 1e-12;

/// Robust z-scores: (r − Median) / (1.4826 · MAD + ε).  (Eq. 10)
pub fn mad_z(raw: &[f64]) -> Vec<f64> {
    let med = median(raw);
    let m = mad(raw);
    let denom = MAD_SIGMA * m + EPS;
    raw.iter().map(|r| (r - med) / denom).collect()
}

/// Sigmoid squashing of a z-score into (0, 1).
pub fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

/// MAD-Sigmoid: Eq. 10 + sigmoid, the full robust normalizer.
pub fn mad_sigmoid(raw: &[f64]) -> Vec<f64> {
    mad_z(raw).into_iter().map(sigmoid).collect()
}

/// Soft-OR over n probabilities with the saturation guard
/// (footnote 4): 1 − Π (1 − pᵢ)^(1/n).
pub fn soft_or(ps: &[f64]) -> f64 {
    if ps.is_empty() {
        return 0.0;
    }
    let n = ps.len() as f64;
    let mut prod = 1.0f64;
    for &p in ps {
        prod *= (1.0 - p.clamp(0.0, 1.0)).powf(1.0 / n);
    }
    1.0 - prod
}

/// Two-term Soft-OR without the root guard (paper Eq. 12 / Algorithm 1
/// line 22): p₁ + p₂ − p₁p₂.
pub fn soft_or2(p1: f64, p2: f64) -> f64 {
    p1 + p2 - p1 * p2
}

/// Non-robust baseline aggregation used by the "w/o MAD-Sigmoid & Soft-OR"
/// ablation (Fig. 4): plain (mean, std) z-score + arithmetic mean.
pub fn plain_z(raw: &[f64]) -> Vec<f64> {
    let n = raw.len().max(1) as f64;
    let mean = raw.iter().sum::<f64>() / n;
    let var = raw.iter().map(|r| (r - mean).powi(2)).sum::<f64>() / n;
    let sd = var.sqrt() + EPS;
    raw.iter().map(|r| (r - mean) / sd).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::util::prop::check;

    #[test]
    fn mad_sigmoid_range_and_monotone() {
        check("mad-sigmoid", 20, |rng| {
            let n = 4 + rng.below(30);
            let mut raw: Vec<f64> = (0..n).map(|_| rng.normal() * 10.0).collect();
            raw.sort_by(|a, b| a.total_cmp(b));
            let p = mad_sigmoid(&raw);
            for v in &p {
                prop_ensure!((0.0..=1.0).contains(v), "p out of range {v}");
            }
            for w in p.windows(2) {
                prop_ensure!(w[1] >= w[0] - 1e-12, "not monotone");
            }
            Ok(())
        });
    }

    #[test]
    fn mad_sigmoid_outlier_robust() {
        // An extreme outlier must not crush the spread of the others.
        let mut raw: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let p_clean = mad_sigmoid(&raw);
        raw.push(1e9);
        let p_dirty = mad_sigmoid(&raw);
        let spread = |p: &[f64]| {
            p.iter().cloned().fold(f64::MIN, f64::max)
                - p.iter().cloned().fold(f64::MAX, f64::min)
        };
        assert!(
            (spread(&p_clean) - spread(&p_dirty[..20])).abs() < 0.05,
            "outlier destroyed the scale"
        );
    }

    #[test]
    fn soft_or_properties() {
        check("soft-or", 30, |rng| {
            let n = 1 + rng.below(6);
            let ps: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let s = soft_or(&ps);
            prop_ensure!((0.0..=1.0).contains(&s), "range {s}");
            // ≥ any soft-or of a subset with one term reduced
            let mut lower = ps.clone();
            lower[0] *= 0.5;
            prop_ensure!(
                soft_or(&lower) <= s + 1e-12,
                "not monotone in arguments"
            );
            // permutation invariant
            let mut rev = ps.clone();
            rev.reverse();
            prop_ensure!((soft_or(&rev) - s).abs() < 1e-12, "not symmetric");
            Ok(())
        });
    }

    #[test]
    fn soft_or_emphasizes_max() {
        // One hot component keeps the OR high even if others are cold.
        let hot = soft_or(&[0.95, 0.05, 0.05, 0.05]);
        let avg = (0.95 + 0.05 * 3.0) / 4.0;
        assert!(hot > avg, "soft-or {hot} should exceed mean {avg}");
    }

    #[test]
    fn soft_or2_matches_formula() {
        check("soft-or2", 20, |rng| {
            let a = rng.f64();
            let b = rng.f64();
            let s = soft_or2(a, b);
            prop_ensure!((s - (a + b - a * b)).abs() < 1e-15, "formula");
            prop_ensure!(s >= a.max(b) - 1e-15, "or >= max");
            Ok(())
        });
    }

    #[test]
    fn soft_or_saturation_guard() {
        // With many medium components the guarded form stays < 1 while the
        // naive product form saturates.
        let ps = vec![0.9; 16];
        let naive = 1.0 - ps.iter().map(|p| 1.0 - p).product::<f64>();
        let guarded = soft_or(&ps);
        assert!(naive > 0.999_999_999);
        assert!(guarded < 0.95, "guard failed: {guarded}");
    }
}
