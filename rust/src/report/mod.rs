//! Reporting: ASCII tables for the terminal + TSV series under `results/`
//! (one file per paper exhibit, so figures can be re-plotted).

pub mod paper;

use std::fs;
use std::io::Write;
use std::path::Path;

/// Fixed-width ASCII table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    s.push_str(&format!("{:<w$}", c, w = widths[i]));
                } else {
                    s.push_str(&format!("  {:>w$}", c, w = widths[i]));
                }
            }
            s
        };
        println!("{}", line(&self.headers));
        println!("{}", "-".repeat(
            widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }

    /// Write as TSV (headers + rows).
    pub fn write_tsv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut f = fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join("\t"))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join("\t"))?;
        }
        Ok(())
    }
}

pub fn fmt2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn fmt3(v: f64) -> String {
    format!("{v:.3}")
}

/// results/ directory (configurable for tests).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("NSDS_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("results"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into(), "1.5".into()]);
        let dir = std::env::temp_dir().join("nsds_report_test");
        let p = dir.join("t.tsv");
        t.write_tsv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "a\tb\nx\t1.5\n");
        t.print();
    }
}
