//! One harness per paper exhibit (DESIGN.md "Experiment index").
//! Every function prints the paper's rows/series and writes a TSV under
//! `results/`. Shapes (who wins, by roughly what factor) are compared to
//! the paper in EXPERIMENTS.md — absolute numbers differ by design (our
//! substrate is the synthetic trained model zoo).

use anyhow::Result;

use crate::baselines::Method;
use crate::coordinator::Pipeline;
use crate::eval::EvalOptions;
use crate::quant::Backend;
use crate::report::{fmt2, fmt3, results_dir, Table};
use crate::sensitivity::{self, Ablation, NsdsOptions};

pub const SMALL_MODELS: [&str; 2] = ["llama-s", "qwen-s"];
pub const LARGE_MODELS: [&str; 2] = ["llama-m", "qwen-m"];
pub const ALL_MODELS: [&str; 4] = ["llama-s", "qwen-s", "llama-m", "qwen-m"];
pub const BUDGET: f64 = 3.0;

fn task_headers(p: &Pipeline) -> Vec<String> {
    p.man.tasks.iter().map(|t| t.name.clone()).collect()
}

/// Table 1: calibration-free methods × all benchmarks on the small models,
/// b̄ = 3, HQQ backend.
pub fn table1(p: &Pipeline, opts: &EvalOptions) -> Result<()> {
    let mut headers = vec!["model".to_string(), "method".to_string()];
    headers.extend(task_headers(p));
    headers.push("wikitext2_like".into());
    headers.push("c4_like".into());
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);

    for model in SMALL_MODELS {
        let fp = p.eval_fp(model, opts)?;
        let mut row = vec![model.to_string(), "FP32".to_string()];
        row.extend(fp.acc.iter().map(|(_, a)| fmt2(*a)));
        row.extend(fp.ppl.iter().map(|(_, v)| fmt3(*v)));
        t.row(row);
        for method in Method::table1() {
            let r = p.run(method, model, BUDGET, Backend::Hqq, opts)?;
            let mut row =
                vec![model.to_string(), method.label().to_string()];
            row.extend(r.eval.acc.iter().map(|(_, a)| fmt2(*a)));
            row.extend(r.eval.ppl.iter().map(|(_, v)| fmt3(*v)));
            t.row(row);
        }
    }
    println!("\n== Table 1: calibration-free LMPQ @ b̄=3 (HQQ) ==");
    t.print();
    t.write_tsv(&results_dir().join("table1.tsv"))?;
    Ok(())
}

/// Table 2 (+ detailed Table 3): larger models, avg acc + avg PPL.
pub fn table2(p: &Pipeline, opts: &EvalOptions) -> Result<()> {
    let mut headers = vec!["model".to_string(), "method".to_string(),
                           "avg_acc".to_string(), "avg_ppl".to_string()];
    headers.extend(task_headers(p));
    headers.push("wikitext2_like".into());
    headers.push("c4_like".into());
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    for model in LARGE_MODELS {
        let fp = p.eval_fp(model, opts)?;
        let mut row = vec![model.to_string(), "FP32".into(),
                           fmt2(fp.avg_acc()), fmt3(fp.avg_ppl())];
        row.extend(fp.acc.iter().map(|(_, a)| fmt2(*a)));
        row.extend(fp.ppl.iter().map(|(_, v)| fmt3(*v)));
        t.row(row);
        for method in Method::table1() {
            let r = p.run(method, model, BUDGET, Backend::Hqq, opts)?;
            let mut row = vec![model.to_string(),
                               method.label().to_string(),
                               fmt2(r.eval.avg_acc()),
                               fmt3(r.eval.avg_ppl())];
            row.extend(r.eval.acc.iter().map(|(_, a)| fmt2(*a)));
            row.extend(r.eval.ppl.iter().map(|(_, v)| fmt3(*v)));
            t.row(row);
        }
    }
    println!("\n== Table 2/3: larger-scale models @ b̄=3 (HQQ) ==");
    t.print();
    t.write_tsv(&results_dir().join("table2.tsv"))?;
    Ok(())
}

/// Fig. 1: per-layer NV / SE scores vs ΔPPL when quantizing only that
/// layer to 2-bit (the motivation scatter).
pub fn fig1(p: &Pipeline, opts: &EvalOptions) -> Result<()> {
    let mut t = Table::new(&["model", "layer", "NV", "SE", "NSDS",
                             "dPPL_2bit"]);
    for model in SMALL_MODELS {
        let entry = p.entry(model)?;
        let w = p.weights(model)?;
        let nsds_opts = NsdsOptions::default();
        let raw = sensitivity::raw_scores(&entry.config, &w, &nsds_opts);
        let (nv, se) = sensitivity::nv_se_layer_scores(&raw);
        let nsds =
            sensitivity::aggregate_scores(&raw, Ablation::Full);
        let fp = p.eval_fp(model, opts)?;
        let fp_ppl = fp.ppl_for("wikitext2_like").unwrap();
        let corpora = crate::eval::ppl::load_corpora(&p.man)?;
        for l in 0..entry.config.n_layers {
            // Quantize ONLY layer l to 2-bit, leave everything else FP.
            let mut qw = w.clone();
            for name in crate::model::QUANT_WEIGHTS {
                let m = w.layer_matrix(name, l);
                let g = crate::quant::fit_group(
                    m.rows(), crate::quant::DEFAULT_GROUP);
                let q = crate::quant::quantize_matrix(
                    &m, crate::quant::QuantSpec::new(2, g),
                    Backend::Hqq, None);
                qw.set_layer_matrix(name, l, &q.dequantize());
            }
            let ppl = crate::eval::ppl::perplexity(
                p.exec(), &p.man, entry, &qw, &corpora.wiki_like,
                opts.max_ppl_batches)?;
            t.row(vec![model.to_string(), l.to_string(), fmt3(nv[l]),
                       fmt3(se[l]), fmt3(nsds[l]), fmt3(ppl - fp_ppl)]);
        }
    }
    println!("\n== Fig. 1: layer sensitivity (NV / SE) vs single-layer \
              2-bit ΔPPL ==");
    t.print();
    t.write_tsv(&results_dir().join("fig1.tsv"))?;
    Ok(())
}

/// Fig. 3: average accuracy vs bit budget for every calibration-free
/// method on the small models.
pub fn fig3(p: &Pipeline, opts: &EvalOptions) -> Result<()> {
    let budgets = [2.25, 2.5, 2.75, 3.0, 3.25, 3.5, 3.75];
    let mut t = Table::new(&["model", "method", "budget", "avg_acc",
                             "avg_ppl"]);
    for model in SMALL_MODELS {
        for method in Method::table1() {
            for &b in &budgets {
                let r = p.run(method, model, b, Backend::Hqq, opts)?;
                t.row(vec![model.to_string(),
                           method.label().to_string(), format!("{b}"),
                           fmt2(r.eval.avg_acc()),
                           fmt3(r.eval.avg_ppl())]);
            }
        }
    }
    println!("\n== Fig. 3: accuracy vs bit budget ==");
    t.print();
    t.write_tsv(&results_dir().join("fig3.tsv"))?;
    Ok(())
}

/// Fig. 4 (+ Fig. 8): ablation analysis on all models.
pub fn fig4(p: &Pipeline, opts: &EvalOptions) -> Result<()> {
    let variants = [Ablation::Full, Ablation::NoNv, Ablation::NoSe,
                    Ablation::NoBeta, Ablation::NoAgg];
    let mut t = Table::new(&["model", "variant", "avg_acc", "avg_ppl"]);
    for model in ALL_MODELS {
        for &v in &variants {
            let r = p.run(Method::Nsds(v), model, BUDGET, Backend::Hqq,
                          opts)?;
            t.row(vec![model.to_string(),
                       Method::Nsds(v).label().to_string(),
                       fmt2(r.eval.avg_acc()), fmt3(r.eval.avg_ppl())]);
        }
    }
    println!("\n== Fig. 4/8: NSDS ablations @ b̄=3 (HQQ) ==");
    t.print();
    t.write_tsv(&results_dir().join("fig4.tsv"))?;
    Ok(())
}

/// Fig. 5 (+ Fig. 9): NSDS vs calibration-based metrics on all models.
pub fn fig5(p: &Pipeline, opts: &EvalOptions) -> Result<()> {
    let mut t = Table::new(&["model", "method", "avg_acc", "avg_ppl"]);
    for model in ALL_MODELS {
        let mut methods = Method::fig5();
        // LLM-MQ needs loss gradients, an optional executor capability
        // (the native engine has no reverse mode).
        if p.calibration(model)?.grads.is_none() {
            eprintln!("[fig5] {model}: executor collects no gradients; \
                       skipping LLM-MQ");
            methods.retain(|m| *m != Method::LlmMq);
        }
        for method in methods {
            let r = p.run(method, model, BUDGET, Backend::Hqq, opts)?;
            t.row(vec![model.to_string(), method.label().to_string(),
                       fmt2(r.eval.avg_acc()), fmt3(r.eval.avg_ppl())]);
        }
    }
    println!("\n== Fig. 5/9: vs calibration-based metrics @ b̄=3 (HQQ) ==");
    t.print();
    t.write_tsv(&results_dir().join("fig5.tsv"))?;
    Ok(())
}

/// Fig. 6 (+ Fig. 10): PTQ-backend orthogonality — NSDS+HQQ vs NSDS+GPTQ
/// vs SliM-LLM (group-wise, GPTQ-based).
pub fn fig6(p: &Pipeline, opts: &EvalOptions) -> Result<()> {
    let nsds = Method::Nsds(Ablation::Full);
    let mut t = Table::new(&["model", "system", "avg_acc", "avg_ppl"]);
    for model in ALL_MODELS {
        let r = p.run(nsds, model, BUDGET, Backend::Hqq, opts)?;
        t.row(vec![model.to_string(), "NSDS+HQQ".into(),
                   fmt2(r.eval.avg_acc()), fmt3(r.eval.avg_ppl())]);
        let r = p.run(nsds, model, BUDGET, Backend::Gptq, opts)?;
        t.row(vec![model.to_string(), "NSDS+GPTQ".into(),
                   fmt2(r.eval.avg_acc()), fmt3(r.eval.avg_ppl())]);
        let r = p.run_slim(model, BUDGET, opts)?;
        t.row(vec![model.to_string(), "SliM-LLM".into(),
                   fmt2(r.eval.avg_acc()), fmt3(r.eval.avg_ppl())]);
    }
    println!("\n== Fig. 6/10: PTQ backend comparison @ b̄=3 ==");
    t.print();
    t.write_tsv(&results_dir().join("fig6.tsv"))?;
    Ok(())
}

/// Fig. 7: NV / SE / NSDS per-layer score heatmap (text form).
pub fn fig7(p: &Pipeline) -> Result<()> {
    let mut t = Table::new(&["model", "layer", "NV", "SE", "NSDS",
                             "bar"]);
    for model in SMALL_MODELS {
        let entry = p.entry(model)?;
        let w = p.weights(model)?;
        let raw = sensitivity::raw_scores(&entry.config, &w,
                                          &NsdsOptions::default());
        let (nv, se) = sensitivity::nv_se_layer_scores(&raw);
        let nsds = sensitivity::aggregate_scores(&raw, Ablation::Full);
        for l in 0..entry.config.n_layers {
            let bar = "#".repeat((nsds[l] * 30.0) as usize);
            t.row(vec![model.to_string(), l.to_string(), fmt3(nv[l]),
                       fmt3(se[l]), fmt3(nsds[l]), bar]);
        }
    }
    println!("\n== Fig. 7: NV/SE/NSDS score map ==");
    t.print();
    t.write_tsv(&results_dir().join("fig7.tsv"))?;
    Ok(())
}
