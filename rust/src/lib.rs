//! NSDS: data-free layer-wise mixed-precision quantization (paper repro).
//!
//! Layer map (see DESIGN.md):
//!   tensor/, util/      — numeric + infra substrates
//!   model/              — configs, weights, mechanistic decomposition
//!   sensitivity/, aggregate/, allocate — the paper's NSDS metric
//!   quant/              — RTN / HQQ / GPTQ backends + bit packing
//!   baselines/          — the paper's comparison metrics
//!   infer/              — Executor trait + native engine (dense and
//!                         fused packed 2/4-bit forward)
//!   runtime/            — artifact registry; PJRT executor behind the
//!                         off-by-default `xla` feature
//!   eval/               — perplexity + reasoning-task harness
//!   coordinator/        — end-to-end pipeline + experiment drivers
//!   report/             — tables/series for every paper exhibit
//!   telemetry/          — metrics registry, step tracer, snapshot +
//!                         bench JSON schema
#![allow(clippy::needless_range_loop)]

pub mod aggregate;
pub mod allocate;
pub mod baselines;
pub mod coordinator;
pub mod eval;
pub mod infer;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sensitivity;
pub mod telemetry;
pub mod tensor;
pub mod util;
