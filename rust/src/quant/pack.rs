//! 2/4-bit code packing along K — the storage layout the Pallas fused
//! dequant-matmul kernels consume (identical to `ref.pack_codes`):
//! byte row r holds code rows r·per .. r·per+per−1, little-endian nibbles.

/// Pack b-bit codes [K, N] (row-major) into u8 [K·b/8, N].
pub fn pack(codes: &[u8], k: usize, n: usize, bits: u8) -> Vec<u8> {
    assert!(bits == 2 || bits == 4, "bits {bits}");
    let per = (8 / bits) as usize;
    assert_eq!(k % per, 0, "K={k} not a multiple of {per}");
    let rows = k / per;
    let mut out = vec![0u8; rows * n];
    for r in 0..rows {
        for i in 0..per {
            let src = &codes[(r * per + i) * n..(r * per + i + 1) * n];
            let shift = bits as usize * i;
            for (c, &v) in src.iter().enumerate() {
                debug_assert!(v < (1 << bits), "code {v} out of range");
                out[r * n + c] |= v << shift;
            }
        }
    }
    out
}

/// Inverse of `pack`.
pub fn unpack(packed: &[u8], k: usize, n: usize, bits: u8) -> Vec<u8> {
    assert!(bits == 2 || bits == 4);
    let per = (8 / bits) as usize;
    let rows = k / per;
    assert_eq!(packed.len(), rows * n);
    let mask = (1u8 << bits) - 1;
    let mut out = vec![0u8; k * n];
    for r in 0..rows {
        for i in 0..per {
            let shift = bits as usize * i;
            let dst = &mut out[(r * per + i) * n..(r * per + i + 1) * n];
            for (c, d) in dst.iter_mut().enumerate() {
                *d = (packed[r * n + c] >> shift) & mask;
            }
        }
    }
    out
}

/// Packed byte size of a [K, N] matrix at `bits` (memory-saving metric
/// reported by the serving example).
pub fn packed_bytes(k: usize, n: usize, bits: u8, group: usize) -> usize {
    let code_bytes = k * n * bits as usize / 8;
    // f32 scale + f32 zero per (group, column); a ragged tail group still
    // carries full metadata, so the group count rounds UP.
    let meta = k.div_ceil(group) * n * 8;
    code_bytes + meta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::util::prop::check;

    #[test]
    fn roundtrip_property() {
        check("pack/unpack roundtrip", 30, |rng| {
            let bits = if rng.f64() < 0.5 { 2u8 } else { 4u8 };
            let per = (8 / bits) as usize;
            let k = per * (1 + rng.below(16));
            let n = 1 + rng.below(20);
            let codes: Vec<u8> = (0..k * n)
                .map(|_| (rng.below(1 << bits)) as u8)
                .collect();
            let p = pack(&codes, k, n, bits);
            prop_ensure!(p.len() == k * n * bits as usize / 8, "size");
            let u = unpack(&p, k, n, bits);
            prop_ensure!(u == codes, "roundtrip mismatch");
            Ok(())
        });
    }

    #[test]
    fn known_layout_4bit() {
        // codes column-0: rows [1, 2] -> byte 0x21 (low nibble = row 0).
        let codes = vec![1u8, 2u8];
        let p = pack(&codes, 2, 1, 4);
        assert_eq!(p, vec![0x21]);
    }

    #[test]
    fn known_layout_2bit() {
        // rows [3, 0, 1, 2] -> 3 | 0<<2 | 1<<4 | 2<<6 = 0b10_01_00_11.
        let codes = vec![3u8, 0, 1, 2];
        let p = pack(&codes, 4, 1, 2);
        assert_eq!(p, vec![0b1001_0011]);
    }

    #[test]
    fn memory_savings() {
        // 4-bit packing of a 256x256 matrix with g=64: codes are 8x
        // smaller; scale/zero metadata brings the total to ~6.4x.
        let fp = 256 * 256 * 4;
        let q4 = packed_bytes(256, 256, 4, 64);
        assert!(fp as f64 / q4 as f64 > 6.0);
        let q2 = packed_bytes(256, 256, 2, 64);
        assert!(q2 < q4);
    }

    #[test]
    fn ragged_group_metadata_rounds_up() {
        // K=96, group=64 -> 2 groups (64 + ragged 32), not 96/64 = 1.
        let b = packed_bytes(96, 10, 4, 64);
        assert_eq!(b, 96 * 10 * 4 / 8 + 2 * 10 * 8);
        // Exact division unchanged.
        assert_eq!(packed_bytes(128, 10, 4, 64),
                   128 * 10 * 4 / 8 + 2 * 10 * 8);
    }
}
