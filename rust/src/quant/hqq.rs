//! HQQ — Half-Quadratic Quantization (Badri & Shaji 2023; paper App. F).
//!
//! The paper's default calibration-free backend. Fixes the min/max scale
//! and optimizes the zero-point by minimizing a sparsity-promoting
//! ℓ_{p<1} norm of the quantization error via half-quadratic splitting:
//!
//!   min_{z, Wₑ} φ(Wₑ) + β/2 ‖Wₑ − (W − Q_z⁻¹(Q_z(W)))‖²
//!
//! alternating (1) the generalized soft-threshold shrinkage for Wₑ and
//! (2) the closed-form group-mean update for z, with β annealed upward.
//! Calibration-free: touches only the weights.

use super::{rtn, QuantSpec, QuantizedMatrix};
use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug)]
pub struct HqqOptions {
    /// ℓ_p exponent (p < 1 models the heavy-tailed error distribution).
    pub p: f64,
    /// Initial half-quadratic penalty.
    pub beta: f64,
    /// Per-iteration growth of β.
    pub kappa: f64,
    pub iters: usize,
}

impl Default for HqqOptions {
    fn default() -> Self {
        HqqOptions { p: 0.7, beta: 10.0, kappa: 1.01, iters: 20 }
    }
}

/// Generalized soft-threshold (the prox of the ℓ_p quasi-norm):
/// shrink(x) = sign(x) · relu(|x| − p·|x|^{p−1} / β).
#[inline]
fn shrink(x: f32, p: f64, beta: f64) -> f32 {
    let ax = x.abs() as f64;
    if ax < 1e-12 {
        return 0.0;
    }
    let thresh = p * ax.powf(p - 1.0) / beta;
    let mag = (ax - thresh).max(0.0);
    (x.signum() as f64 * mag) as f32
}

/// HQQ quantization of a [K, N] matrix.
pub fn quantize(w: &Tensor, spec: QuantSpec, opts: &HqqOptions)
    -> QuantizedMatrix {
    let (k, n) = (w.rows(), w.cols());
    let g = spec.group;
    let ng = k / g;
    let qmax = spec.qmax();
    let (scale, mut zero) = rtn::params(w, spec);
    let mut beta = opts.beta;
    let wd = w.data();

    // Iterate: codes -> error -> shrink -> zero update, fused into one
    // pass per iteration (quantize + accumulate together; §Perf).
    let mut acc = vec![0.0f64; ng * n];
    for _ in 0..opts.iters {
        acc.iter_mut().for_each(|a| *a = 0.0);
        for r in 0..k {
            let gr = r / g;
            let srow = &scale[gr * n..(gr + 1) * n];
            let zrow = &zero[gr * n..(gr + 1) * n];
            let wrow = &wd[r * n..(r + 1) * n];
            let arow = &mut acc[gr * n..(gr + 1) * n];
            for c in 0..n {
                let s = srow[c];
                let z = zrow[c];
                // 1) quantize with current (scale, zero)
                let q = (wrow[c] / s + z).round().clamp(0.0, qmax);
                // 2) zero-point contribution:
                //    z_g = mean_g( q − (w − wₑ)/s ), wₑ = shrink(w − deq).
                let deq = s * (q - z);
                let we = shrink(wrow[c] - deq, opts.p, beta);
                arow[c] += (q as f64) - ((wrow[c] - we) / s) as f64;
            }
        }
        for (zi, a) in zero.iter_mut().zip(&acc) {
            *zi = (*a / g as f64) as f32;
        }
        beta *= opts.kappa;
    }
    rtn::quantize_with(w, spec, &scale, &zero)
}

/// ℓ_p^p error of a quant-dequant reconstruction (the objective HQQ
/// minimizes; used by the tests to verify it beats RTN).
pub fn lp_error(w: &Tensor, q: &QuantizedMatrix, p: f64) -> f64 {
    let d = q.dequantize();
    w.data()
        .iter()
        .zip(d.data())
        .map(|(a, b)| ((a - b).abs() as f64).powf(p))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    /// Heavy-tailed test matrix: gaussian with sparse large outliers —
    /// exactly the regime HQQ's ℓ_{p<1} objective targets.
    fn heavy(rng: &mut Rng, k: usize, n: usize) -> Tensor {
        let mut t = Tensor::randn(vec![k, n], rng).scale(0.05);
        let outliers = (k * n / 50).max(1);
        for _ in 0..outliers {
            let i = rng.below(k * n);
            t.data_mut()[i] *= 20.0;
        }
        t
    }

    #[test]
    fn beats_rtn_on_lp_objective() {
        check("hqq < rtn (lp)", 8, |rng| {
            let w = heavy(rng, 64, 16);
            let spec = QuantSpec::new(2, 16);
            let q_rtn = rtn::quantize(&w, spec);
            let q_hqq = quantize(&w, spec, &HqqOptions::default());
            let e_rtn = lp_error(&w, &q_rtn, 0.7);
            let e_hqq = lp_error(&w, &q_hqq, 0.7);
            prop_ensure!(
                e_hqq <= e_rtn * 1.001,
                "hqq {e_hqq} vs rtn {e_rtn}"
            );
            Ok(())
        });
    }

    #[test]
    fn shrink_is_contraction() {
        check("shrink", 20, |rng| {
            let x = (rng.normal() * 3.0) as f32;
            let y = shrink(x, 0.7, 10.0);
            prop_ensure!(y.abs() <= x.abs() + 1e-7, "expansion {x}->{y}");
            prop_ensure!(
                y == 0.0 || y.signum() == x.signum(),
                "sign flip"
            );
            Ok(())
        });
    }

    #[test]
    fn codes_in_range_and_deterministic() {
        let mut rng = Rng::new(9);
        let w = heavy(&mut rng, 32, 8);
        let spec = QuantSpec::new(4, 8);
        let a = quantize(&w, spec, &HqqOptions::default());
        let b = quantize(&w, spec, &HqqOptions::default());
        assert_eq!(a.codes, b.codes);
        assert!(a.codes.iter().all(|&c| c <= 15));
    }
}
