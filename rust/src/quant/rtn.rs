//! Round-to-nearest group-wise affine quantization — the baseline backend
//! and the starting point HQQ/GPTQ refine. Mirrors `ref.rtn_quantize`.

use super::{QuantSpec, QuantizedMatrix};
use crate::tensor::Tensor;

/// Min/max affine parameters per (group, column).
pub fn params(w: &Tensor, spec: QuantSpec) -> (Vec<f32>, Vec<f32>) {
    let (k, n) = (w.rows(), w.cols());
    let g = spec.group;
    assert_eq!(k % g, 0, "group {g} must divide K={k}");
    let ng = k / g;
    let qmax = spec.qmax();
    let mut scale = vec![0.0f32; ng * n];
    let mut zero = vec![0.0f32; ng * n];
    for gi in 0..ng {
        for c in 0..n {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for r in gi * g..(gi + 1) * g {
                let v = w.at(r, c);
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let mut s = (hi - lo) / qmax;
            if s <= 1e-12 {
                s = 1.0;
            }
            scale[gi * n + c] = s;
            zero[gi * n + c] = -lo / s;
        }
    }
    (scale, zero)
}

/// Quantize with given params (shared by HQQ's inner loop).
pub fn quantize_with(w: &Tensor, spec: QuantSpec, scale: &[f32],
                     zero: &[f32]) -> QuantizedMatrix {
    let (k, n) = (w.rows(), w.cols());
    let g = spec.group;
    let qmax = spec.qmax();
    let mut codes = vec![0u8; k * n];
    for r in 0..k {
        let gr = r / g;
        for c in 0..n {
            let s = scale[gr * n + c];
            let z = zero[gr * n + c];
            let q = (w.at(r, c) / s + z).round().clamp(0.0, qmax);
            codes[r * n + c] = q as u8;
        }
    }
    QuantizedMatrix {
        spec,
        codes,
        k,
        n,
        scale: scale.to_vec(),
        zero: zero.to_vec(),
    }
}

/// Full RTN: derive params, then round.
pub fn quantize(w: &Tensor, spec: QuantSpec) -> QuantizedMatrix {
    let (scale, zero) = params(w, spec);
    quantize_with(w, spec, &scale, &zero)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::util::prop::check;

    #[test]
    fn error_bounded_by_half_step() {
        check("rtn half-step bound", 20, |rng| {
            let k = 8 * (1 + rng.below(4));
            let n = 1 + rng.below(12);
            let w = Tensor::randn(vec![k, n], rng);
            let spec = QuantSpec::new(4, 8);
            let q = quantize(&w, spec);
            let d = q.dequantize();
            for r in 0..k {
                let gr = r / 8;
                for c in 0..n {
                    let s = q.scale[gr * n + c];
                    let err = (w.at(r, c) - d.at(r, c)).abs();
                    prop_ensure!(
                        err <= 0.5 * s + 1e-6,
                        "err {err} > s/2 {}",
                        0.5 * s
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn range_endpoints_exact() {
        // Group min and max must be representable exactly (codes 0 / qmax).
        let vals: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let w = Tensor::new(vals, vec![8, 1]);
        let q = quantize(&w, QuantSpec::new(2, 8));
        let d = q.dequantize();
        assert!((d.at(0, 0) - 0.0).abs() < 1e-6);
        assert!((d.at(7, 0) - 7.0).abs() < 1e-6);
    }

    #[test]
    fn more_bits_less_error() {
        check("bits monotone", 10, |rng| {
            let w = Tensor::randn(vec![32, 8], rng);
            let e2 = crate::quant::recon_error(
                &w, QuantSpec::new(2, 8), crate::quant::Backend::Rtn);
            let e4 = crate::quant::recon_error(
                &w, QuantSpec::new(4, 8), crate::quant::Backend::Rtn);
            let e8 = crate::quant::recon_error(
                &w, QuantSpec::new(8, 8), crate::quant::Backend::Rtn);
            prop_ensure!(e4 < e2, "e4 {e4} !< e2 {e2}");
            prop_ensure!(e8 < e4, "e8 {e8} !< e4 {e4}");
            Ok(())
        });
    }

    #[test]
    fn constant_group_safe() {
        let w = Tensor::new(vec![2.5; 16], vec![16, 1]);
        let q = quantize(&w, QuantSpec::new(4, 8));
        let d = q.dequantize();
        for v in d.data() {
            assert!((v - 2.5).abs() < 1e-6);
        }
    }
}
