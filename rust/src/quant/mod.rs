//! Weight-only quantization backends (paper §3.1 + App. F): RTN, HQQ
//! (the default calibration-free backend), GPTQ (the stronger
//! calibration-based backend of Fig. 6), plus 2/4-bit packing shared with
//! the Pallas serving kernels.
//!
//! Shared convention (identical to `python/compile/kernels/ref.py`):
//! groups of size `group` along the K (input) axis of a [K, N] weight;
//! `code = clip(round(w/s + z), 0, 2^b − 1)`, `deq = s·(code − z)`.

pub mod gptq;
pub mod hqq;
pub mod pack;
pub mod rtn;

use crate::model::{ModelConfig, Weights, QUANT_WEIGHTS};
use crate::tensor::Tensor;
use crate::util::pool::parallel_map;

/// Quantization spec for one matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantSpec {
    pub bits: u8,
    pub group: usize,
}

impl QuantSpec {
    pub fn new(bits: u8, group: usize) -> Self {
        assert!(matches!(bits, 2 | 3 | 4 | 8), "unsupported bits {bits}");
        QuantSpec { bits, group }
    }

    pub fn qmax(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }
}

/// Default group size: divides every K dim in the model zoo (64/96/192/
/// 256/288) and matches the Pallas kernel constraint (multiple of 4).
pub const DEFAULT_GROUP: usize = 32;

/// Largest divisor of `k` that is ≤ `want` — lets callers use
/// DEFAULT_GROUP against arbitrary (e.g. test) matrix shapes.
pub fn fit_group(k: usize, want: usize) -> usize {
    let mut g = want.clamp(1, k);
    while k % g != 0 {
        g -= 1;
    }
    g
}

/// Quantized representation of one [K, N] matrix.
#[derive(Clone, Debug)]
pub struct QuantizedMatrix {
    pub spec: QuantSpec,
    /// codes u8 [K, N] (unpacked; `pack::pack` for the serving layout).
    pub codes: Vec<u8>,
    pub k: usize,
    pub n: usize,
    /// scale/zero per (group, column): [K/group, N].
    pub scale: Vec<f32>,
    pub zero: Vec<f32>,
}

impl QuantizedMatrix {
    pub fn dequantize(&self) -> Tensor {
        let (k, n, g) = (self.k, self.n, self.spec.group);
        let mut out = vec![0.0f32; k * n];
        for r in 0..k {
            let gr = r / g;
            for c in 0..n {
                let s = self.scale[gr * n + c];
                let z = self.zero[gr * n + c];
                out[r * n + c] = s * (self.codes[r * n + c] as f32 - z);
            }
        }
        Tensor::new(out, vec![k, n])
    }

    /// Bits actually stored per weight element (codes only).
    pub fn code_bits(&self) -> f64 {
        self.spec.bits as f64
    }
}

/// Backend selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Rtn,
    Hqq,
    /// GPTQ needs the Hessian of the layer inputs; without one it falls
    /// back to RTN behaviour (identity Hessian).
    Gptq,
}

impl Backend {
    pub fn label(self) -> &'static str {
        match self {
            Backend::Rtn => "RTN",
            Backend::Hqq => "HQQ",
            Backend::Gptq => "GPTQ",
        }
    }
}

/// Quantize one matrix with the chosen backend. `hessian` is only
/// consulted by GPTQ ([K, K] = XᵀX of that projection's inputs).
pub fn quantize_matrix(w: &Tensor, spec: QuantSpec, backend: Backend,
                       hessian: Option<&Tensor>) -> QuantizedMatrix {
    match backend {
        Backend::Rtn => rtn::quantize(w, spec),
        Backend::Hqq => hqq::quantize(w, spec, &hqq::HqqOptions::default()),
        Backend::Gptq => gptq::quantize(w, spec, hessian),
    }
}

/// Hessians for GPTQ, keyed by (layer, weight-name). Built by the
/// coordinator from probe-artifact activations.
pub type HessianMap =
    std::collections::BTreeMap<(usize, String), Tensor>;

/// Quantize-dequantize every projection of every layer at the allocated
/// bit width, returning a full weight set ready for the PJRT executor.
/// Embed/unembed/norms stay FP (standard practice, matches the paper's
/// layer-wise scheme which quantizes transformer blocks).
pub fn quantize_model(cfg: &ModelConfig, w: &Weights, bits: &[u8],
                      group: usize, backend: Backend,
                      hessians: Option<&HessianMap>, workers: usize)
                      -> Weights {
    assert_eq!(bits.len(), cfg.n_layers);
    let jobs: Vec<(usize, &str)> = (0..cfg.n_layers)
        .flat_map(|l| QUANT_WEIGHTS.iter().map(move |n| (l, *n)))
        .collect();
    let done: Vec<(usize, &str, Tensor)> =
        parallel_map(jobs.len(), workers, |j| {
            let (l, name) = jobs[j];
            let m = w.layer_matrix(name, l);
            let spec = QuantSpec::new(bits[l], group);
            let h = hessians
                .and_then(|hm| hm.get(&(l, name.to_string())));
            let q = quantize_matrix(&m, spec, backend, h);
            (l, name, q.dequantize())
        });
    let mut out = w.clone();
    for (l, name, dq) in done {
        out.set_layer_matrix(name, l, &dq);
    }
    out
}

/// Frobenius reconstruction error ‖W − deq(quant(W))‖²_F (MSE baseline
/// building block and a general diagnostic).
pub fn recon_error(w: &Tensor, spec: QuantSpec, backend: Backend) -> f64 {
    let q = quantize_matrix(w, spec, backend, None);
    let d = q.dequantize();
    let e = w.sub(&d);
    e.data().iter().map(|&x| (x as f64) * (x as f64)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn quantize_model_respects_allocation() {
        let cfg = ModelConfig::test_config();
        let mut rng = Rng::new(5);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let bits = vec![4u8, 2, 4];
        let qw = quantize_model(&cfg, &w, &bits, 8, Backend::Rtn, None, 1);
        // 4-bit layers must reconstruct better than 2-bit layers.
        let err = |l: usize| {
            let a = w.layer_matrix("wup", l);
            let b = qw.layer_matrix("wup", l);
            (a.sub(&b).frob_norm() / a.frob_norm()) as f64
        };
        assert!(err(0) < err(1), "4-bit {} vs 2-bit {}", err(0), err(1));
        assert!(err(2) < err(1));
        // Non-quantized weights untouched.
        assert_eq!(qw.get("embed"), w.get("embed"));
        assert_eq!(qw.get("ln1"), w.get("ln1"));
    }

    #[test]
    fn backends_all_produce_valid_codes() {
        let mut rng = Rng::new(6);
        let w = Tensor::randn(vec![16, 12], &mut rng);
        for backend in [Backend::Rtn, Backend::Hqq, Backend::Gptq] {
            let q = quantize_matrix(&w, QuantSpec::new(2, 8), backend, None);
            for &c in &q.codes {
                assert!(c <= 3, "{backend:?} emitted code {c}");
            }
            let d = q.dequantize();
            assert_eq!(d.dims(), w.dims());
        }
    }
}
