//! GPTQ (Frantar et al. 2023; paper App. F) — calibration-based backend.
//!
//! Layer-wise reconstruction: minimize ‖XW − XŴ‖² by quantizing W row by
//! row along the input dimension K and redistributing each row's
//! quantization error onto the not-yet-quantized rows via the inverse
//! Hessian H⁻¹ = (2XᵀX + λI)⁻¹ (column-blocked OBQ). Group params are
//! frozen when the sweep enters each group, from the *current* residual.

use super::{rtn, QuantSpec, QuantizedMatrix};
use crate::tensor::linalg::spd_inverse;
use crate::tensor::Tensor;

/// Relative damping added to the Hessian diagonal (GPTQ's `percdamp`).
pub const PERC_DAMP: f64 = 0.01;

/// Build the GPTQ Hessian from calibration inputs X [n_samples, K].
pub fn hessian_from_inputs(x: &Tensor) -> Tensor {
    let mut h = crate::tensor::matmul::gram(x); // XᵀX
    let k = h.rows();
    // 2·XᵀX as in the paper; constant factor is irrelevant after damping
    // normalization but kept for fidelity.
    for v in h.data_mut() {
        *v *= 2.0;
    }
    let mean_diag: f64 = (0..k).map(|i| h.at(i, i) as f64).sum::<f64>()
        / k as f64;
    let damp = (PERC_DAMP * mean_diag).max(1e-8) as f32;
    for i in 0..k {
        let v = h.at(i, i) + damp;
        h.set(i, i, v);
    }
    h
}

/// GPTQ quantization of W [K, N]. Without a Hessian, uses the identity
/// (which reduces exactly to RTN — verified by test).
pub fn quantize(w: &Tensor, spec: QuantSpec, hessian: Option<&Tensor>)
    -> QuantizedMatrix {
    let (k, n) = (w.rows(), w.cols());
    let g = spec.group;
    let qmax = spec.qmax();
    let hinv = match hessian {
        Some(h) => {
            assert_eq!(h.rows(), k, "hessian K mismatch");
            match spd_inverse(h) {
                Some(inv) => inv,
                None => {
                    // Raise damping until PD (rare; extreme collinearity).
                    let mut h2 = h.clone();
                    let mut damp = 0.1
                        * (0..k).map(|i| h.at(i, i) as f64).sum::<f64>()
                        / k as f64;
                    loop {
                        for i in 0..k {
                            let v = h2.at(i, i) + damp as f32;
                            h2.set(i, i, v);
                        }
                        if let Some(inv) = spd_inverse(&h2) {
                            break inv;
                        }
                        damp *= 10.0;
                    }
                }
            }
        }
        None => {
            let mut eye = Tensor::zeros(vec![k, k]);
            for i in 0..k {
                eye.set(i, i, 1.0);
            }
            eye
        }
    };

    let mut wr = w.clone(); // residual weights, updated in place
    let mut codes = vec![0u8; k * n];
    let ng = k / g;
    let mut scale = vec![0.0f32; ng * n];
    let mut zero = vec![0.0f32; ng * n];

    for r in 0..k {
        let gr = r / g;
        if r % g == 0 {
            // Freeze group params from the current residual rows.
            let block = wr.rows_range(gr * g, (gr + 1) * g);
            let (s_blk, z_blk) =
                rtn::params(&block, QuantSpec::new(spec.bits, g));
            scale[gr * n..(gr + 1) * n].copy_from_slice(&s_blk);
            zero[gr * n..(gr + 1) * n].copy_from_slice(&z_blk);
        }
        let d = hinv.at(r, r).max(1e-10);
        // Quantize row r, compute scaled error, propagate to rows > r.
        let mut err = vec![0.0f32; n];
        for c in 0..n {
            let s = scale[gr * n + c];
            let z = zero[gr * n + c];
            let v = wr.at(r, c);
            let q = (v / s + z).round().clamp(0.0, qmax);
            codes[r * n + c] = q as u8;
            let deq = s * (q as f32 - z);
            err[c] = (v - deq) / d;
        }
        for rr in (r + 1)..k {
            let hval = hinv.at(rr, r);
            if hval == 0.0 {
                continue;
            }
            let row = wr.row_mut(rr);
            for (c, e) in err.iter().enumerate() {
                row[c] -= hval * e;
            }
        }
    }
    QuantizedMatrix { spec, codes, k, n, scale, zero }
}

/// Output reconstruction error ‖XW − XŴ‖²_F — the objective GPTQ
/// minimizes (diagnostics + tests).
pub fn output_error(x: &Tensor, w: &Tensor, q: &QuantizedMatrix) -> f64 {
    let d = q.dequantize();
    let y1 = crate::tensor::matmul::matmul(x, w);
    let y2 = crate::tensor::matmul::matmul(x, &d);
    let e = y1.sub(&y2);
    e.data().iter().map(|&v| (v as f64) * (v as f64)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::quant::Backend;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn identity_hessian_equals_rtn() {
        let mut rng = Rng::new(10);
        let w = Tensor::randn(vec![24, 8], &mut rng);
        let spec = QuantSpec::new(4, 8);
        let q_g = quantize(&w, spec, None);
        let q_r = rtn::quantize(&w, spec);
        assert_eq!(q_g.codes, q_r.codes, "identity-H GPTQ must match RTN");
    }

    #[test]
    fn beats_rtn_on_output_error() {
        check("gptq < rtn on ‖XΔW‖", 6, |rng| {
            let k = 32;
            let nsamp = 128;
            // Correlated inputs (realistic activations) make error
            // propagation matter.
            let base = Tensor::randn(vec![nsamp, 8], rng);
            let mix = Tensor::randn(vec![8, k], rng);
            let x = crate::tensor::matmul::matmul(&base, &mix);
            let w = Tensor::randn(vec![k, 12], rng);
            let spec = QuantSpec::new(2, 16);
            let h = hessian_from_inputs(&x);
            let q_gptq = quantize(&w, spec, Some(&h));
            let q_rtn = rtn::quantize(&w, spec);
            let e_g = output_error(&x, &w, &q_gptq);
            let e_r = output_error(&x, &w, &q_rtn);
            prop_ensure!(e_g < e_r, "gptq {e_g} !< rtn {e_r}");
            Ok(())
        });
    }

    #[test]
    fn hessian_is_spd_and_damped() {
        let mut rng = Rng::new(11);
        let x = Tensor::randn(vec![64, 16], &mut rng);
        let h = hessian_from_inputs(&x);
        assert!(crate::tensor::linalg::cholesky(&h).is_some());
        // diagonal strictly positive
        for i in 0..16 {
            assert!(h.at(i, i) > 0.0);
        }
    }

    #[test]
    fn via_backend_dispatch() {
        let mut rng = Rng::new(12);
        let w = Tensor::randn(vec![16, 4], &mut rng);
        let q = crate::quant::quantize_matrix(
            &w, QuantSpec::new(4, 8), Backend::Gptq, None);
        assert!(q.codes.iter().all(|&c| c <= 15));
    }
}
