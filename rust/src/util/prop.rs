//! Tiny property-test harness (proptest is unreachable offline).
//!
//! `check(name, cases, |rng| ...)` runs a seeded-random property `cases`
//! times; on failure it reports the case seed so the exact input can be
//! replayed with `check_one`. Used by the tensor / quant / aggregate /
//! allocate invariant tests.

use crate::util::rng::Rng;

/// Run `prop` for `cases` deterministic seeds; panic with the failing seed.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5eed_0000 + case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at seed {seed:#x}: {msg}");
        }
    }
}

/// Replay a single failing case.
pub fn check_one<F>(name: &str, seed: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed at seed {seed:#x}: {msg}");
    }
}

/// Assert helper: `ensure!(cond, "msg {}", x)` inside properties.
#[macro_export]
macro_rules! prop_ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial() {
        check("trivial", 10, |rng| {
            let x = rng.f64();
            prop_ensure!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn reports_failure() {
        check("fails", 5, |rng| {
            let x = rng.f64();
            prop_ensure!(x < 0.0, "x={x}");
            Ok(())
        });
    }
}
