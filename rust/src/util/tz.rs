//! `.tz` tensor container reader/writer — the python↔rust interchange
//! format for weights, corpora and task tensors. Mirrors
//! `python/compile/tio.py`; the format is round-trip tested on both sides.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"NSDT";

/// A raw tensor as stored in a `.tz` file.
#[derive(Clone, Debug)]
pub enum RawTensor {
    F32(Tensor),
    I32 { dims: Vec<usize>, data: Vec<i32> },
    U8 { dims: Vec<usize>, data: Vec<u8> },
}

impl RawTensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            RawTensor::F32(t) => t.dims(),
            RawTensor::I32 { dims, .. } => dims,
            RawTensor::U8 { dims, .. } => dims,
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            RawTensor::F32(t) => Ok(t),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<(&[usize], &[i32])> {
        match self {
            RawTensor::I32 { dims, data } => Ok((dims, data)),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_u8(&self) -> Result<(&[usize], &[u8])> {
        match self {
            RawTensor::U8 { dims, data } => Ok((dims, data)),
            _ => bail!("tensor is not u8"),
        }
    }
}

pub type TzMap = BTreeMap<String, RawTensor>;

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Load every tensor in a `.tz` file.
pub fn read_tz(path: &Path) -> Result<TzMap> {
    let f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: bad magic {magic:?}");
    }
    let version = read_u32(&mut r)?;
    if version != 1 {
        bail!("{path:?}: unsupported version {version}");
    }
    let count = read_u32(&mut r)? as usize;
    let mut out = TzMap::new();
    for _ in 0..count {
        let nlen = read_u32(&mut r)? as usize;
        let mut nb = vec![0u8; nlen];
        r.read_exact(&mut nb)?;
        let name = String::from_utf8(nb)?;
        let mut dt = [0u8; 1];
        r.read_exact(&mut dt)?;
        let ndim = read_u32(&mut r)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u64(&mut r)? as usize);
        }
        let n: usize = dims.iter().product::<usize>().max(1);
        let t = match dt[0] {
            0 => {
                let mut buf = vec![0u8; n * 4];
                r.read_exact(&mut buf)?;
                let data: Vec<f32> = buf
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                RawTensor::F32(Tensor::new(data, dims))
            }
            1 => {
                let mut buf = vec![0u8; n * 4];
                r.read_exact(&mut buf)?;
                let data: Vec<i32> = buf
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                RawTensor::I32 { dims, data }
            }
            2 => {
                let mut data = vec![0u8; n];
                r.read_exact(&mut data)?;
                RawTensor::U8 { dims, data }
            }
            d => bail!("{path:?}: unknown dtype {d}"),
        };
        out.insert(name, t);
    }
    Ok(out)
}

/// Write a `.tz` file (used by tests and by result snapshots).
pub fn write_tz(path: &Path, tensors: &TzMap) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&1u32.to_le_bytes())?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        let (code, dims): (u8, &[usize]) = match t {
            RawTensor::F32(x) => (0, x.dims()),
            RawTensor::I32 { dims, .. } => (1, dims),
            RawTensor::U8 { dims, .. } => (2, dims),
        };
        w.write_all(&[code])?;
        w.write_all(&(dims.len() as u32).to_le_bytes())?;
        for d in dims {
            w.write_all(&(*d as u64).to_le_bytes())?;
        }
        match t {
            RawTensor::F32(x) => {
                for v in x.data() {
                    w.write_all(&v.to_le_bytes())?;
                }
            }
            RawTensor::I32 { data, .. } => {
                for v in data {
                    w.write_all(&v.to_le_bytes())?;
                }
            }
            RawTensor::U8 { data, .. } => w.write_all(data)?,
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("nsds_tz_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.tz");
        let mut m = TzMap::new();
        m.insert(
            "a".into(),
            RawTensor::F32(Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2])),
        );
        m.insert(
            "b".into(),
            RawTensor::I32 { dims: vec![3], data: vec![-1, 0, 7] },
        );
        m.insert(
            "c".into(),
            RawTensor::U8 { dims: vec![2, 1], data: vec![9, 255] },
        );
        write_tz(&path, &m).unwrap();
        let back = read_tz(&path).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back["a"].as_f32().unwrap().data(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(back["b"].as_i32().unwrap().1, &[-1, 0, 7]);
        assert_eq!(back["c"].as_u8().unwrap().1, &[9, 255]);
        assert_eq!(back["c"].dims(), &[2, 1]);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("nsds_tz_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.tz");
        std::fs::write(&path, b"XXXX0000").unwrap();
        assert!(read_tz(&path).is_err());
    }
}
