//! Infrastructure substrates the offline environment forces us to own:
//! JSON, the `.tz` tensor container, a PRNG, a scoped thread pool and a
//! property-test harness (no serde / rand / rayon / proptest crates are
//! reachable — see DESIGN.md "Environment deviations").

pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod tz;
