//! Minimal JSON: enough to parse `artifacts/manifest.json` and to serialize
//! result rows for the report module. Hand-rolled because serde is not
//! reachable offline (DESIGN.md "Environment deviations").

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `j.path(&["models", "llama-s", "weights"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.obj(),
            Some(b'[') => self.arr(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.num(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn num(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )
                            .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u")?;
                            self.i += 4;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // Copy the raw utf-8 byte run.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn arr(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn obj(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => {
                            write!(f, "\\u{:04x}", c as u32)?
                        }
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap().as_str(),
            Some("a\nb")
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(
            r#"{"models": {"llama-s": {"params": 12345, "hlo": ["a", "b"]}},
                "list": [1, 2, 3]}"#,
        )
        .unwrap();
        assert_eq!(
            j.path(&["models", "llama-s", "params"]).unwrap().as_usize(),
            Some(12345)
        );
        assert_eq!(j.get("list").unwrap().idx(2).unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2,{"b":"x \"y\""}],"c":null,"d":false}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap().as_str(),
            Some("A")
        );
    }
}
