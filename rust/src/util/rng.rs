//! Deterministic PRNG (xoshiro256**) + gaussian sampling.
//!
//! Used by the synthetic-model zoo, the property-test harness and the
//! calibration sampler. Seeded everywhere so every experiment is exactly
//! reproducible.

/// xoshiro256** — fast, high-quality, tiny; seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second gaussian from the Box-Muller pair
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s1.wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.s = s;
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * m);
                return u * m;
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(1);
        let idx = r.sample_indices(50, 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }
}
