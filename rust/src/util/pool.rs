//! Scoped worker pool over std threads (no tokio/rayon offline — DESIGN.md).
//!
//! The coordinator uses this to score layers / quantize matrices in
//! parallel. On this single-core image it degrades to near-sequential
//! execution, but the structure (and the tests) are what a multi-core
//! deployment runs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(i)` for every `i in 0..n` on up to `workers` threads, collecting
/// results in index order. Panics in workers propagate.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.clamp(1, n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<T>>> =
        Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                out.lock().unwrap()[i] = Some(v);
            });
        }
    });
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|v| v.expect("worker skipped an index"))
        .collect()
}

/// Default worker count: available parallelism (>= 1).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Split `0..n` into at most `parts` contiguous near-equal ranges,
/// dropping empties — the row / column-block splits the fused kernels
/// hand to `parallel_map` (one range per worker, index order).
pub fn chunk_ranges(n: usize, parts: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let per = n.div_ceil(parts);
    (0..parts)
        .map(|p| (p * per, ((p + 1) * per).min(n)))
        .filter(|(a, b)| a < b)
        .collect()
}

/// Work-size floor (in f32 mul-adds) below which a kernel call runs
/// single-threaded: scoped spawn + join costs on the order of tens of
/// microseconds, which only amortizes once the split sides carry ~a
/// million mul-adds each.
pub const MIN_PAR_WORK: usize = 1 << 20;

/// Gate a caller's worker budget by the call's work size: collapses to
/// 1 below `MIN_PAR_WORK`, otherwise passes `workers` through (>= 1).
pub fn workers_for(workers: usize, work: usize) -> usize {
    if work < MIN_PAR_WORK {
        1
    } else {
        workers.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let v = parallel_map(100, 4, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(parallel_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
        assert_eq!(parallel_map(0, 8, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn more_workers_than_items() {
        assert_eq!(parallel_map(2, 16, |i| i + 1), vec![1, 2]);
    }

    #[test]
    fn chunk_ranges_cover_exactly_once() {
        for n in [0usize, 1, 2, 7, 64, 100] {
            for parts in [1usize, 2, 3, 8, 200] {
                let r = chunk_ranges(n, parts);
                assert!(r.len() <= parts.max(1));
                // Contiguous, non-empty, covering 0..n in order.
                let mut at = 0;
                for (a, b) in &r {
                    assert_eq!(*a, at);
                    assert!(a < b);
                    at = *b;
                }
                assert_eq!(at, n, "n={n} parts={parts}");
            }
        }
    }

    #[test]
    fn workers_for_gates_small_work() {
        assert_eq!(workers_for(8, 0), 1);
        assert_eq!(workers_for(8, MIN_PAR_WORK - 1), 1);
        assert_eq!(workers_for(8, MIN_PAR_WORK), 8);
        assert_eq!(workers_for(0, MIN_PAR_WORK), 1);
    }
}
