//! Scoped worker pool over std threads (no tokio/rayon offline — DESIGN.md).
//!
//! The coordinator uses this to score layers / quantize matrices in
//! parallel. On this single-core image it degrades to near-sequential
//! execution, but the structure (and the tests) are what a multi-core
//! deployment runs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(i)` for every `i in 0..n` on up to `workers` threads, collecting
/// results in index order. Panics in workers propagate.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.clamp(1, n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<T>>> =
        Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                out.lock().unwrap()[i] = Some(v);
            });
        }
    });
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|v| v.expect("worker skipped an index"))
        .collect()
}

/// Default worker count: available parallelism (>= 1).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let v = parallel_map(100, 4, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(parallel_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
        assert_eq!(parallel_map(0, 8, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn more_workers_than_items() {
        assert_eq!(parallel_map(2, 16, |i| i + 1), vec![1, 2]);
    }
}
