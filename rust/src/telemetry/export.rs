//! Snapshot + bench-registry schema: versioned JSON rendering of
//! `RegistrySnapshot`s and bench results over the repo's hand-rolled
//! `util::json` (serde is unreachable offline — DESIGN.md "Environment
//! deviations"), plus the human summary the examples print.
//!
//! Versioning rules (DESIGN.md "Observability"): every document carries
//! `schema_version` + `kind`. Adding fields is allowed WITHIN a
//! version (readers ignore unknown keys); removing or re-typing a field
//! bumps `SCHEMA_VERSION`, and readers reject versions they don't
//! know (`!= SCHEMA_VERSION`) instead of misreading them. JSON numbers
//! are f64, so u64 values above 2^53 (≈104 days of summed
//! nanoseconds) round in the export — fine for the latency/throughput
//! magnitudes recorded here.

use std::collections::BTreeMap;

use crate::telemetry::registry::{HistSnapshot, RegistrySnapshot};
use crate::util::json::Json;

/// Version of BOTH document kinds below (they evolve together with the
/// registry types).
pub const SCHEMA_VERSION: u32 = 1;
/// `kind` of a metrics-registry snapshot document.
pub const KIND_METRICS: &str = "nsds.metrics";
/// `kind` of a bench-results document (`BENCH_*.json`).
pub const KIND_BENCH: &str = "nsds.bench";

fn num_map<T: Copy + Into<f64>>(m: &BTreeMap<String, T>) -> Json {
    Json::Obj(
        m.iter()
            .map(|(k, v)| (k.clone(), Json::Num((*v).into())))
            .collect(),
    )
}

fn u64_json(v: u64) -> Json {
    Json::Num(v as f64)
}

/// Render a registry snapshot as a versioned JSON document.
pub fn snapshot_to_json(s: &RegistrySnapshot) -> Json {
    let mut hists = BTreeMap::new();
    for (name, h) in &s.histograms {
        let mut o = BTreeMap::new();
        o.insert("count".into(), u64_json(h.count));
        o.insert("sum".into(), u64_json(h.sum));
        o.insert("max".into(), u64_json(h.max));
        o.insert(
            "buckets".into(),
            Json::Arr(
                h.buckets
                    .iter()
                    .map(|&(lo, hi, n)| {
                        Json::Arr(vec![u64_json(lo), u64_json(hi),
                                       u64_json(n)])
                    })
                    .collect(),
            ),
        );
        hists.insert(name.clone(), Json::Obj(o));
    }
    let mut doc = BTreeMap::new();
    doc.insert("schema_version".into(),
               Json::Num(SCHEMA_VERSION as f64));
    doc.insert("kind".into(), Json::Str(KIND_METRICS.into()));
    doc.insert("counters".into(),
               num_map(&s.counters.iter()
                   .map(|(k, &v)| (k.clone(), v as f64))
                   .collect()));
    doc.insert("gauges".into(),
               num_map(&s.gauges.iter()
                   .map(|(k, &v)| (k.clone(), v as f64))
                   .collect()));
    doc.insert("histograms".into(), Json::Obj(hists));
    Json::Obj(doc)
}

/// Check a document's envelope: `kind` matches and `schema_version`
/// is one this reader knows.
fn check_envelope(j: &Json, kind: &str) -> Result<(), String> {
    let k = j.get("kind").and_then(Json::as_str)
        .ok_or("missing `kind`")?;
    if k != kind {
        return Err(format!("kind {k:?}, expected {kind:?}"));
    }
    let v = j.get("schema_version").and_then(Json::as_f64)
        .ok_or("missing `schema_version`")? as u32;
    if v != SCHEMA_VERSION {
        return Err(format!(
            "schema_version {v} not supported (reader knows \
             {SCHEMA_VERSION}); refusing to misread"));
    }
    Ok(())
}

fn parse_u64_map(j: Option<&Json>, what: &str)
    -> Result<BTreeMap<String, u64>, String> {
    let obj = j.and_then(Json::as_obj)
        .ok_or_else(|| format!("missing `{what}` object"))?;
    obj.iter()
        .map(|(k, v)| {
            v.as_f64()
                .map(|n| (k.clone(), n as u64))
                .ok_or_else(|| format!("{what}.{k} not a number"))
        })
        .collect()
}

/// Parse a snapshot document back (round-trip of `snapshot_to_json`,
/// modulo f64 rounding above 2^53).
pub fn snapshot_from_json(j: &Json)
    -> Result<RegistrySnapshot, String> {
    check_envelope(j, KIND_METRICS)?;
    let counters = parse_u64_map(j.get("counters"), "counters")?;
    let gauges = parse_u64_map(j.get("gauges"), "gauges")?;
    let mut histograms = BTreeMap::new();
    let hs = j.get("histograms").and_then(Json::as_obj)
        .ok_or("missing `histograms` object")?;
    for (name, h) in hs {
        let f = |k: &str| -> Result<u64, String> {
            h.get(k).and_then(Json::as_f64).map(|n| n as u64)
                .ok_or_else(|| format!("histograms.{name}.{k} missing"))
        };
        let mut buckets = Vec::new();
        for (i, b) in h.get("buckets").and_then(Json::as_arr)
            .ok_or_else(|| format!("histograms.{name}.buckets missing"))?
            .iter().enumerate() {
            let g = |k: usize| -> Result<u64, String> {
                b.idx(k).and_then(Json::as_f64).map(|n| n as u64)
                    .ok_or_else(|| format!(
                        "histograms.{name}.buckets[{i}] malformed"))
            };
            buckets.push((g(0)?, g(1)?, g(2)?));
        }
        histograms.insert(name.clone(), HistSnapshot {
            count: f("count")?,
            sum: f("sum")?,
            max: f("max")?,
            buckets,
        });
    }
    Ok(RegistrySnapshot { counters, gauges, histograms })
}

/// One bench measurement destined for a `BENCH_*.json` document.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchEntry {
    /// Which bench section produced it (e.g. "prefill").
    pub section: String,
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
}

/// Render bench results as a versioned document, sections in
/// first-seen order (an array, not an object — order is the bench
/// program's narrative).
pub fn bench_report(bench: &str, entries: &[BenchEntry]) -> Json {
    let mut order: Vec<&str> = Vec::new();
    for e in entries {
        if !order.contains(&e.section.as_str()) {
            order.push(&e.section);
        }
    }
    let sections = order
        .iter()
        .map(|&sec| {
            let rows = entries
                .iter()
                .filter(|e| e.section == sec)
                .map(|e| {
                    let mut o = BTreeMap::new();
                    o.insert("name".into(), Json::Str(e.name.clone()));
                    o.insert("iters".into(), u64_json(e.iters));
                    o.insert("median_ns".into(), Json::Num(e.median_ns));
                    o.insert("mean_ns".into(), Json::Num(e.mean_ns));
                    o.insert("p95_ns".into(), Json::Num(e.p95_ns));
                    Json::Obj(o)
                })
                .collect();
            let mut o = BTreeMap::new();
            o.insert("name".into(), Json::Str(sec.into()));
            o.insert("entries".into(), Json::Arr(rows));
            Json::Obj(o)
        })
        .collect();
    let mut doc = BTreeMap::new();
    doc.insert("schema_version".into(),
               Json::Num(SCHEMA_VERSION as f64));
    doc.insert("kind".into(), Json::Str(KIND_BENCH.into()));
    doc.insert("bench".into(), Json::Str(bench.into()));
    doc.insert("sections".into(), Json::Arr(sections));
    Json::Obj(doc)
}

/// Validate a bench document against the schema: envelope, a non-empty
/// `sections` array, and well-typed entry rows. This is the CI gate
/// (`bench_runtime --json` re-reads what it wrote through this before
/// exiting 0).
pub fn validate_bench_report(j: &Json) -> Result<(), String> {
    check_envelope(j, KIND_BENCH)?;
    j.get("bench").and_then(Json::as_str)
        .ok_or("missing `bench` name")?;
    let sections = j.get("sections").and_then(Json::as_arr)
        .ok_or("missing `sections` array")?;
    if sections.is_empty() {
        return Err("empty `sections`".into());
    }
    for (i, s) in sections.iter().enumerate() {
        let name = s.get("name").and_then(Json::as_str)
            .ok_or_else(|| format!("sections[{i}] missing name"))?;
        let entries = s.get("entries").and_then(Json::as_arr)
            .ok_or_else(|| {
                format!("section {name:?} missing entries array")
            })?;
        for (k, e) in entries.iter().enumerate() {
            e.get("name").and_then(Json::as_str).ok_or_else(|| {
                format!("{name}[{k}] missing name")
            })?;
            for field in ["iters", "median_ns", "mean_ns", "p95_ns"] {
                let v = e.get(field).and_then(Json::as_f64)
                    .ok_or_else(|| format!(
                        "{name}[{k}] missing numeric {field}"))?;
                if !(v >= 0.0) {
                    return Err(format!(
                        "{name}[{k}].{field} = {v} out of range"));
                }
            }
        }
    }
    Ok(())
}

/// Parse a bench document back into flat entries (round-trip of
/// `bench_report`) — how the bench binary reads a committed baseline
/// `BENCH_*.json` to diff a fresh run against. Validates first, so a
/// corrupt or foreign-versioned baseline is an error, not a silent
/// empty diff.
pub fn bench_entries_from_json(j: &Json)
    -> Result<Vec<BenchEntry>, String> {
    validate_bench_report(j)?;
    let mut out = Vec::new();
    for s in j.get("sections").and_then(Json::as_arr).unwrap() {
        let section = s.get("name").and_then(Json::as_str).unwrap();
        for e in s.get("entries").and_then(Json::as_arr).unwrap() {
            let f = |k: &str| e.get(k).and_then(Json::as_f64).unwrap();
            out.push(BenchEntry {
                section: section.to_string(),
                name: e.get("name").and_then(Json::as_str).unwrap()
                    .to_string(),
                iters: f("iters") as u64,
                median_ns: f("median_ns"),
                mean_ns: f("mean_ns"),
                p95_ns: f("p95_ns"),
            });
        }
    }
    Ok(out)
}

/// Humanize a value for display: nanosecond metrics (name suffix
/// `_ns`) get time units, the rest plain integers.
fn fmt_val(name: &str, v: f64) -> String {
    if !name.ends_with("_ns") {
        return format!("{v:.0}");
    }
    if v < 1e3 {
        format!("{v:.0}ns")
    } else if v < 1e6 {
        format!("{:.2}µs", v / 1e3)
    } else if v < 1e9 {
        format!("{:.2}ms", v / 1e6)
    } else {
        format!("{:.3}s", v / 1e9)
    }
}

/// Human summary of a snapshot — what `serve_quantized`/`router_demo`
/// print. Same data as `snapshot_to_json`, rendered for eyes.
pub fn render_summary(s: &RegistrySnapshot) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(out, "telemetry snapshot (schema v{SCHEMA_VERSION})");
    if !s.counters.is_empty() {
        let _ = writeln!(out, "  counters:");
        for (k, v) in &s.counters {
            let _ = writeln!(out, "    {k:<40} {v:>12}");
        }
    }
    if !s.gauges.is_empty() {
        let _ = writeln!(out, "  gauges:");
        for (k, v) in &s.gauges {
            let _ = writeln!(out, "    {k:<40} {v:>12}");
        }
    }
    if !s.histograms.is_empty() {
        let _ = writeln!(
            out,
            "  {:<30} {:>8} {:>10} {:>10} {:>10} {:>10} {:>10}",
            "histograms", "count", "p50", "p90", "p99", "max", "mean");
        for (k, h) in &s.histograms {
            let q = |p: f64| {
                h.quantile(p)
                    .map(|v| fmt_val(k, v as f64))
                    .unwrap_or_else(|| "-".into())
            };
            let _ = writeln!(
                out,
                "  {:<30} {:>8} {:>10} {:>10} {:>10} {:>10} {:>10}",
                k, h.count, q(0.5), q(0.9), q(0.99),
                fmt_val(k, h.max as f64), fmt_val(k, h.mean()));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::MetricsRegistry;

    fn sample_snapshot() -> RegistrySnapshot {
        let reg = MetricsRegistry::new();
        reg.counter("serve.gen.requests").add(5);
        reg.gauge("serve.gen.shared_prefix_tokens").set(48);
        let h = reg.histogram("serve.gen.ttft_ns");
        for v in [900u64, 1_200, 35_000, 35_500, 2_000_000] {
            h.record(v);
        }
        reg.snapshot()
    }

    #[test]
    fn snapshot_json_round_trips() {
        let s = sample_snapshot();
        let j = snapshot_to_json(&s);
        let text = j.to_string();
        let back = snapshot_from_json(&Json::parse(&text).unwrap())
            .unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn unknown_schema_version_is_rejected() {
        let s = sample_snapshot();
        let mut j = snapshot_to_json(&s);
        if let Json::Obj(m) = &mut j {
            m.insert("schema_version".into(),
                     Json::Num((SCHEMA_VERSION + 1) as f64));
        }
        let err = snapshot_from_json(&j).unwrap_err();
        assert!(err.contains("not supported"), "{err}");
        // Wrong kind is rejected too.
        let mut j2 = snapshot_to_json(&s);
        if let Json::Obj(m) = &mut j2 {
            m.insert("kind".into(), Json::Str("nsds.other".into()));
        }
        assert!(snapshot_from_json(&j2).is_err());
    }

    #[test]
    fn bench_report_validates_and_rejects_corruption() {
        let entries = vec![
            BenchEntry {
                section: "native".into(),
                name: "fused 4bit".into(),
                iters: 100,
                median_ns: 1.5e6,
                mean_ns: 1.6e6,
                p95_ns: 2.0e6,
            },
            BenchEntry {
                section: "prefill".into(),
                name: "chunked len=256".into(),
                iters: 12,
                median_ns: 3.0e7,
                mean_ns: 3.1e7,
                p95_ns: 3.5e7,
            },
        ];
        let j = bench_report("bench_runtime", &entries);
        validate_bench_report(&j).unwrap();
        // Round-trip through text, as CI consumes it.
        let parsed = Json::parse(&j.to_string()).unwrap();
        validate_bench_report(&parsed).unwrap();
        // Full entry round-trip (what the --baseline diff reads).
        assert_eq!(bench_entries_from_json(&parsed).unwrap(), entries);
        assert!(bench_entries_from_json(&Json::Num(3.0)).is_err());
        // Section order is first-seen, not alphabetical.
        let names: Vec<&str> = parsed.get("sections").unwrap()
            .as_arr().unwrap().iter()
            .map(|s| s.get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["native", "prefill"]);
        // Corruptions fail loudly.
        let mut bad = bench_report("bench_runtime", &entries);
        if let Json::Obj(m) = &mut bad {
            m.remove("sections");
        }
        assert!(validate_bench_report(&bad).is_err());
        let bad2 = Json::parse(
            r#"{"schema_version":1,"kind":"nsds.bench","bench":"b",
                "sections":[{"name":"s","entries":[{"name":"x",
                "iters":-1,"median_ns":1,"mean_ns":1,"p95_ns":1}]}]}"#,
        ).unwrap();
        assert!(validate_bench_report(&bad2).is_err());
        assert!(validate_bench_report(&Json::Num(3.0)).is_err());
    }

    #[test]
    fn summary_renders_every_metric_kind() {
        let s = sample_snapshot();
        let text = render_summary(&s);
        assert!(text.contains("serve.gen.requests"));
        assert!(text.contains("serve.gen.shared_prefix_tokens"));
        assert!(text.contains("serve.gen.ttft_ns"));
        assert!(text.contains("p99"));
    }
}
