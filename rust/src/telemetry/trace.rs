//! Step tracer: a bounded ring buffer of per-step engine events — the
//! flight recorder behind scheduler/fairness debugging. The engine
//! pushes one small `Copy` record per scheduling decision (admission,
//! shared-prefix admit/defer, prefill chunk placement, decode batch
//! composition, CoW splits, eviction recycle, retirement,
//! disconnect cancellation); the ring
//! overwrites the oldest record past capacity, so memory is O(capacity)
//! — `capacity · size_of::<TraceEvent>()` — no matter how long the
//! engine runs. Tracing is opt-in per engine: when disabled the whole
//! feature costs one `Option` branch per emission site and allocates
//! nothing (pinned by `rust/tests/batch_decode.rs`: enabling tracing
//! leaves generated tokens bit-identical, because the tracer only
//! observes — it never touches RNG streams, admission order, or
//! kernels).
//!
//! Request identity: the engine stamps each submission with a `rid`
//! (monotone from 0 in submit order, engine-local), carried on every
//! event about that request. `timeline(rid)` reconstructs one request's
//! life — admit → chunks → decode participation → retire — from the
//! interleaved stream; decode steps are batch-level events carrying a
//! slot bitmask, so a request's decode participation is recovered by
//! masking its slot between its admit and retire events (slots ≥ 64
//! fall outside the mask and are attributed by rid events only).

/// What happened, step-stamped. `step` is the engine's step counter at
/// emission (admissions and deferrals carry the step being set up).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub step: u64,
    pub ev: Ev,
}

/// Event taxonomy (see DESIGN.md "Observability" for the contract).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ev {
    /// Request `rid` admitted into `slot`; `shared` of its `prompt`
    /// tokens were admitted by shared-prefix page reference
    /// (`shared > 0` is a shared-prefix admission).
    Admit { rid: u64, slot: usize, prompt: usize, shared: usize },
    /// Request `rid` deferred (kept pending) because a donor is still
    /// appending a `committed`-token common prefix worth waiting for.
    Defer { rid: u64, committed: usize },
    /// One chunked-prefill call for `rid` in `slot`: prompt window
    /// `[pos, pos + len)`.
    PrefillChunk { rid: u64, slot: usize, pos: usize, len: usize },
    /// One batched decode of `batch` rows; bit `s` of `slots_mask` is
    /// set when slot `s < 64` was in the batch.
    Decode { batch: usize, slots_mask: u64 },
    /// `n` copy-on-write page splits this step (pool-level aggregate).
    CowSplit { n: u64 },
    /// `rows` ring rows evicted (their blocks recycled in place) this
    /// step (pool-level aggregate).
    Recycle { rows: usize },
    /// Request `rid` retired from `slot` after emitting `gen_tokens`.
    Retire { rid: u64, slot: usize, gen_tokens: usize },
    /// Request `rid` cancelled (its receiver disconnected) and retired
    /// WITHOUT producing a generation. `slot` is the target slot it
    /// freed; `None` when the request was still pending — it never
    /// held one.
    Cancel { rid: u64, slot: Option<usize> },
    /// The drafter proposed `k` speculative tokens for `rid` this
    /// step (one batched drafter pass per draft depth, shared across
    /// spec requests; `slot` is the request's TARGET slot).
    Draft { rid: u64, slot: usize, k: usize },
    /// One multi-row target verify pass for `rid`: `drafted`
    /// proposals scored, `accepted` committed by exact greedy
    /// agreement (the pass also commits one bonus token from its last
    /// consumed row, so tokens emitted ≥ accepted + 1 except when a
    /// stop condition cut the window short).
    Verify { rid: u64, slot: usize, drafted: usize, accepted: usize },
}

impl Ev {
    /// The request this event is about, when it is about one.
    pub fn rid(&self) -> Option<u64> {
        match *self {
            Ev::Admit { rid, .. }
            | Ev::Defer { rid, .. }
            | Ev::PrefillChunk { rid, .. }
            | Ev::Retire { rid, .. }
            | Ev::Cancel { rid, .. }
            | Ev::Draft { rid, .. }
            | Ev::Verify { rid, .. } => Some(rid),
            Ev::Decode { .. } | Ev::CowSplit { .. }
            | Ev::Recycle { .. } => None,
        }
    }
}

/// Fixed-capacity event ring. All storage is allocated at construction
/// (`Vec::with_capacity`), pushes never allocate, and the ring
/// overwrites oldest-first past capacity.
pub struct StepTracer {
    buf: Vec<TraceEvent>,
    head: usize,
    /// Events ever pushed; `total - len()` is how many the ring dropped.
    total: u64,
}

impl StepTracer {
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        StepTracer {
            buf: Vec::with_capacity(capacity),
            head: 0,
            total: 0,
        }
    }

    #[inline]
    pub fn push(&mut self, ev: TraceEvent) {
        if self.buf.len() < self.buf.capacity() {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.buf.len();
        }
        self.total += 1;
    }

    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events ever pushed (kept + overwritten).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Held events oldest → newest.
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }

    /// One request's timeline, oldest → newest: its own events (admit /
    /// defer / chunks / retire) plus the batch-level decode events its
    /// slot participated in between its admit and retire. If the
    /// admission already fell off the ring, decode participation cannot
    /// be attributed (slot unknown) and only rid-stamped events return.
    pub fn timeline(&self, rid: u64) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        let mut slot: Option<usize> = None;
        for e in self.events() {
            match e.ev {
                Ev::Admit { rid: r, slot: s, .. } if r == rid => {
                    slot = Some(s);
                    out.push(e);
                }
                Ev::Retire { rid: r, .. } if r == rid => {
                    slot = None;
                    out.push(e);
                }
                // Cancellation ends slot attribution exactly like
                // retirement: the slot is free for another request.
                Ev::Cancel { rid: r, .. } if r == rid => {
                    slot = None;
                    out.push(e);
                }
                Ev::Decode { slots_mask, .. } => {
                    if let Some(s) = slot {
                        if s < 64 && slots_mask & (1u64 << s) != 0 {
                            out.push(e);
                        }
                    }
                }
                ev if ev.rid() == Some(rid) => out.push(e),
                _ => {}
            }
        }
        out
    }

    pub fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.total = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(step: u64, rid: u64) -> TraceEvent {
        TraceEvent { step, ev: Ev::Defer { rid, committed: 0 } }
    }

    #[test]
    fn ring_wraps_oldest_first_and_stays_bounded() {
        let mut t = StepTracer::new(4);
        for i in 0..11u64 {
            t.push(ev(i, i));
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.capacity(), 4);
        assert_eq!(t.total(), 11);
        let steps: Vec<u64> =
            t.events().iter().map(|e| e.step).collect();
        assert_eq!(steps, vec![7, 8, 9, 10]);
    }

    #[test]
    fn zero_capacity_is_clamped() {
        let mut t = StepTracer::new(0);
        t.push(ev(1, 1));
        t.push(ev(2, 2));
        assert_eq!(t.len(), 1);
        assert_eq!(t.events()[0].step, 2);
    }

    #[test]
    fn timeline_masks_decode_to_the_requests_slot_window() {
        let mut t = StepTracer::new(64);
        t.push(TraceEvent {
            step: 0,
            ev: Ev::Admit { rid: 0, slot: 1, prompt: 4, shared: 0 },
        });
        // Decode with slot 1 in the batch: part of rid 0's life.
        t.push(TraceEvent {
            step: 1,
            ev: Ev::Decode { batch: 2, slots_mask: 0b11 },
        });
        t.push(TraceEvent {
            step: 1,
            ev: Ev::Retire { rid: 0, slot: 1, gen_tokens: 2 },
        });
        // Slot 1 reused by rid 7 afterwards: not rid 0's decode.
        t.push(TraceEvent {
            step: 2,
            ev: Ev::Admit { rid: 7, slot: 1, prompt: 2, shared: 0 },
        });
        t.push(TraceEvent {
            step: 3,
            ev: Ev::Decode { batch: 1, slots_mask: 0b10 },
        });
        let tl = t.timeline(0);
        assert_eq!(tl.len(), 3);
        assert!(matches!(tl[0].ev, Ev::Admit { rid: 0, .. }));
        assert!(matches!(tl[1].ev, Ev::Decode { .. }));
        assert!(matches!(tl[2].ev, Ev::Retire { rid: 0, .. }));
        let tl7 = t.timeline(7);
        assert_eq!(tl7.len(), 2); // its admit + its decode
    }

    #[test]
    fn cancel_ends_slot_attribution_like_retire() {
        let mut t = StepTracer::new(16);
        t.push(TraceEvent {
            step: 0,
            ev: Ev::Admit { rid: 2, slot: 3, prompt: 4, shared: 0 },
        });
        t.push(TraceEvent {
            step: 1,
            ev: Ev::Decode { batch: 1, slots_mask: 0b1000 },
        });
        t.push(TraceEvent {
            step: 1,
            ev: Ev::Cancel { rid: 2, slot: Some(3) },
        });
        // Slot 3 reused after the cancel: not rid 2's decode.
        t.push(TraceEvent {
            step: 2,
            ev: Ev::Decode { batch: 1, slots_mask: 0b1000 },
        });
        assert_eq!((Ev::Cancel { rid: 2, slot: None }).rid(), Some(2));
        let tl = t.timeline(2);
        assert_eq!(tl.len(), 3);
        assert!(matches!(tl[2].ev, Ev::Cancel { rid: 2, .. }));
    }

    #[test]
    fn spec_events_carry_rid_and_join_timelines() {
        let mut t = StepTracer::new(16);
        t.push(TraceEvent {
            step: 0,
            ev: Ev::Admit { rid: 3, slot: 0, prompt: 2, shared: 0 },
        });
        t.push(TraceEvent {
            step: 1,
            ev: Ev::Draft { rid: 3, slot: 0, k: 4 },
        });
        t.push(TraceEvent {
            step: 1,
            ev: Ev::Verify { rid: 3, slot: 0, drafted: 4, accepted: 2 },
        });
        assert_eq!((Ev::Draft { rid: 3, slot: 0, k: 4 }).rid(), Some(3));
        assert_eq!(
            (Ev::Verify { rid: 3, slot: 0, drafted: 4, accepted: 2 })
                .rid(),
            Some(3));
        let tl = t.timeline(3);
        assert_eq!(tl.len(), 3);
        assert!(matches!(tl[1].ev, Ev::Draft { k: 4, .. }));
        assert!(matches!(tl[2].ev, Ev::Verify { accepted: 2, .. }));
    }
}
