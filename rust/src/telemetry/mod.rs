//! Engine-wide telemetry: the observability layer between the serving
//! core and everything that wants to judge it (benches, CI, the future
//! HTTP `/metrics` front end, and NSDS variant comparisons — for a
//! calibration-free method, runtime telemetry is the only empirical
//! signal about a bit allocation's quality).
//!
//! Three pieces, one contract (DESIGN.md "Observability"):
//!
//! * [`registry`] — process- or instance-scoped [`MetricsRegistry`] of
//!   named counters, gauges, and log-bucketed latency histograms.
//!   Registration takes a lock once (cold); recording through the
//!   returned handles is relaxed atomics only — no locks, no
//!   allocation on the hot path.
//! * [`trace`] — [`StepTracer`], a bounded ring of per-step engine
//!   events (admit/defer/chunk/decode/CoW/recycle/retire) with a
//!   per-request timeline view. O(capacity) memory, opt-in per
//!   engine, observes without perturbing (tokens stay bit-identical).
//! * [`export`] — the versioned JSON schema for registry snapshots
//!   (`nsds.metrics`) and bench results (`nsds.bench`, the
//!   `BENCH_runtime.json` perf trajectory), plus the human summary
//!   renderer the examples print.

pub mod export;
pub mod registry;
pub mod trace;

pub use export::{
    bench_entries_from_json, bench_report, render_summary,
    snapshot_from_json, snapshot_to_json, validate_bench_report,
    BenchEntry, SCHEMA_VERSION,
};
pub use registry::{
    Counter, Gauge, HistSnapshot, Histogram, MetricsRegistry,
    RegistrySnapshot,
};
pub use trace::{Ev, StepTracer, TraceEvent};
