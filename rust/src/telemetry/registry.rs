//! Process-wide metrics registry: named counters, gauges, and
//! log-linear latency histograms, recorded through cheap cloneable
//! handles so hot paths (`BatchEngine::step`, the serve loop) touch
//! nothing but atomics — no lock, no allocation, no formatting.
//!
//! Registration (name → cell) takes a mutex; it happens once per metric
//! at wiring time. Recording goes through a handle that owns an `Arc`
//! to the cell, so the hot path is one or two relaxed atomic RMW ops.
//! `snapshot()` reads every cell without stopping writers — the result
//! is a per-cell-consistent (not globally atomic) view, which is the
//! standard contract for serving metrics.
//!
//! Histogram buckets are log-linear (HDR-style): values below
//! `HIST_SUB` get exact unit buckets; above, each power-of-two octave
//! splits into `HIST_SUB` equal sub-buckets, so a bucket's width is at
//! most 1/`HIST_SUB` = 12.5% of its lower bound. Quantiles estimated
//! from a snapshot therefore land in the SAME bucket as the exact
//! nearest-rank sample quantile — a ≤12.5% relative error bound, with
//! fixed memory (`HIST_BUCKETS` u64 cells) per histogram regardless of
//! sample count. See DESIGN.md "Observability".

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Sub-buckets per power-of-two octave (and the bound below which
/// values get exact unit buckets). Must be a power of two.
pub const HIST_SUB: u64 = 8;
const HIST_SUB_BITS: u32 = HIST_SUB.trailing_zeros();

/// Total fixed bucket count: `HIST_SUB` unit buckets for values in
/// `[0, HIST_SUB)`, then `HIST_SUB` sub-buckets for each of the
/// `64 - HIST_SUB_BITS` octaves a u64 can occupy.
pub const HIST_BUCKETS: usize =
    HIST_SUB as usize + (64 - HIST_SUB_BITS as usize) * HIST_SUB as usize;

/// Bucket index of a recorded value. Monotone in `v`; exact for
/// `v < HIST_SUB`, ≤12.5%-wide log-linear buckets above.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < HIST_SUB {
        return v as usize;
    }
    let e = 63 - v.leading_zeros(); // e >= HIST_SUB_BITS
    let sub = (v >> (e - HIST_SUB_BITS)) - HIST_SUB; // 0..HIST_SUB
    ((e - HIST_SUB_BITS + 1) as u64 * HIST_SUB + sub) as usize
}

/// `[lo, hi)` value range of bucket `i` (inverse of `bucket_index`).
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    let i = i as u64;
    if i < HIST_SUB {
        return (i, i + 1);
    }
    let g = i / HIST_SUB - 1; // octave above the unit range
    let sub = i % HIST_SUB;
    let lo = (HIST_SUB + sub) << g;
    let width = 1u64 << g;
    (lo, lo.saturating_add(width))
}

/// One histogram's storage: fixed bucket array + running aggregates.
struct HistCell {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl HistCell {
    fn new() -> Self {
        let buckets: Vec<AtomicU64> =
            (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect();
        HistCell {
            buckets: buckets.into_boxed_slice(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

/// Monotone counter handle. Clone freely; all clones share the cell.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge handle (a level, not a rate).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Histogram handle: `record` is bucket + count + sum + max atomics.
#[derive(Clone)]
pub struct Histogram(Arc<HistCell>);

impl Histogram {
    #[inline]
    pub fn record(&self, v: u64) {
        let c = &self.0;
        c.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        c.count.fetch_add(1, Ordering::Relaxed);
        c.sum.fetch_add(v, Ordering::Relaxed);
        c.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded values (e.g. total nanoseconds).
    pub fn sum(&self) -> u64 {
        self.0.sum.load(Ordering::Relaxed)
    }

    /// Snapshot just this histogram (the registry-wide `snapshot` is
    /// the usual route; this serves local registries and tests).
    pub fn snapshot(&self) -> HistSnapshot {
        let c = &self.0;
        let buckets = c
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let n = b.load(Ordering::Relaxed);
                if n == 0 {
                    return None;
                }
                let (lo, hi) = bucket_bounds(i);
                Some((lo, hi, n))
            })
            .collect();
        HistSnapshot {
            count: c.count.load(Ordering::Relaxed),
            sum: c.sum.load(Ordering::Relaxed),
            max: c.max.load(Ordering::Relaxed),
            buckets,
        }
    }
}

/// Point-in-time view of one histogram: only non-empty buckets, as
/// `(lo, hi, count)` with `lo` inclusive and `hi` exclusive, ascending.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    pub buckets: Vec<(u64, u64, u64)>,
}

impl HistSnapshot {
    /// Nearest-rank quantile estimate: the bucket holding the sample of
    /// rank `round(q·(count-1))`, reported as that bucket's midpoint
    /// (clamped to the observed max). `None` when empty. The estimate
    /// lies in the same bucket as the exact sample quantile, so it is
    /// within one bucket width (≤12.5% of the value) of it.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = (q * (self.count - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for &(lo, hi, n) in &self.buckets {
            seen += n;
            if seen > rank {
                let mid = lo + (hi - 1 - lo) / 2;
                // Clamp into the observed range but never out of the
                // bucket (the max guard matters only for the bucket
                // that holds the max itself).
                return Some(mid.min(self.max).max(lo));
            }
        }
        // Unreachable when bucket counts sum to `count`; be safe under
        // a torn concurrent snapshot.
        Some(self.max)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// Point-in-time view of a whole registry (see `MetricsRegistry`).
#[derive(Clone, Debug, PartialEq)]
pub struct RegistrySnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    pub histograms: BTreeMap<String, HistSnapshot>,
}

#[derive(Default)]
struct Cells {
    counters: BTreeMap<String, Arc<AtomicU64>>,
    gauges: BTreeMap<String, Arc<AtomicU64>>,
    histograms: BTreeMap<String, Arc<HistCell>>,
}

/// Named-metric registry. Components take handles once at wiring time
/// (`counter`/`gauge`/`histogram` get-or-create by name, so two callers
/// naming the same metric share one cell) and record through them;
/// `snapshot` renders the whole registry for export or display.
///
/// Scoping: `MetricsRegistry::global()` is the process-wide instance
/// for single-deployment binaries; components that can be instantiated
/// many times in one process (e.g. a `ServerQueue` per test) default to
/// a private registry so concurrent instances never mix streams, and
/// accept a shared one where aggregation is wanted.
#[derive(Default)]
pub struct MetricsRegistry {
    cells: Mutex<Cells>,
}

impl MetricsRegistry {
    pub fn new() -> Arc<Self> {
        Arc::new(MetricsRegistry::default())
    }

    /// The process-wide registry.
    pub fn global() -> &'static MetricsRegistry {
        static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
        GLOBAL.get_or_init(MetricsRegistry::default)
    }

    pub fn counter(&self, name: &str) -> Counter {
        let mut c = self.cells.lock().unwrap();
        Counter(Arc::clone(
            c.counters
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(AtomicU64::new(0))),
        ))
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        let mut c = self.cells.lock().unwrap();
        Gauge(Arc::clone(
            c.gauges
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(AtomicU64::new(0))),
        ))
    }

    pub fn histogram(&self, name: &str) -> Histogram {
        let mut c = self.cells.lock().unwrap();
        Histogram(Arc::clone(
            c.histograms
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(HistCell::new())),
        ))
    }

    /// Render every registered metric. Writers are not paused: each
    /// cell is read atomically, but cells read at slightly different
    /// instants (the usual metrics-endpoint contract).
    pub fn snapshot(&self) -> RegistrySnapshot {
        let c = self.cells.lock().unwrap();
        RegistrySnapshot {
            counters: c
                .counters
                .iter()
                .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
                .collect(),
            gauges: c
                .gauges
                .iter()
                .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
                .collect(),
            histograms: c
                .histograms
                .iter()
                .map(|(k, v)| {
                    (k.clone(), Histogram(Arc::clone(v)).snapshot())
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_bounds_invert() {
        let mut prev = 0usize;
        for &v in &[0u64, 1, 7, 8, 9, 15, 16, 31, 100, 1_000, 65_535,
                    1 << 20, (1 << 40) + 12345, u64::MAX / 2, u64::MAX] {
            let i = bucket_index(v);
            assert!(i >= prev || v == 0, "index not monotone at {v}");
            prev = i.max(prev);
            let (lo, hi) = bucket_bounds(i);
            assert!(lo <= v && v < hi || hi == u64::MAX && v >= lo,
                    "v={v} outside bucket {i} = [{lo},{hi})");
            assert!(i < HIST_BUCKETS);
        }
    }

    #[test]
    fn bucket_width_bound_holds() {
        for i in HIST_SUB as usize..HIST_BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            if hi == u64::MAX {
                continue; // saturated top bucket
            }
            assert!((hi - lo) * HIST_SUB <= lo,
                    "bucket {i} wider than lo/{HIST_SUB}: [{lo},{hi})");
        }
    }

    #[test]
    fn counter_gauge_histogram_roundtrip() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("c");
        c.add(3);
        reg.counter("c").inc(); // same cell by name
        assert_eq!(c.get(), 4);
        let g = reg.gauge("g");
        g.set(7);
        g.set(5);
        assert_eq!(reg.gauge("g").get(), 5);
        let h = reg.histogram("h");
        for v in [1u64, 10, 100, 1000] {
            h.record(v);
        }
        let s = reg.snapshot();
        assert_eq!(s.counters["c"], 4);
        assert_eq!(s.gauges["g"], 5);
        let hs = &s.histograms["h"];
        assert_eq!((hs.count, hs.sum, hs.max), (4, 1111, 1000));
        let total: u64 = hs.buckets.iter().map(|b| b.2).sum();
        assert_eq!(total, 4);
    }

    #[test]
    fn quantile_of_empty_is_none_and_of_singleton_is_it() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("h");
        assert_eq!(h.snapshot().quantile(0.5), None);
        h.record(42);
        let q = h.snapshot().quantile(0.5).unwrap();
        assert_eq!(bucket_index(q), bucket_index(42));
    }
}
