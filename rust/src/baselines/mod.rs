//! The paper's comparison layer-sensitivity metrics (Appendix E).
//!
//! All scorers return one f64 per layer, oriented so that **higher =
//! more sensitive = quantize at higher precision** (metrics whose paper
//! formulation is inverted, e.g. ZD, are negated here once so every
//! allocation call site is uniform).

pub mod calibrated;
pub mod free;
pub mod search;
pub mod slimllm;

use crate::coordinator::calib::Calibration;
use crate::model::{ModelConfig, Weights};

/// Every layer-ranking method in the paper's experiments.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Nsds(crate::sensitivity::Ablation),
    Mse,
    Ewq,
    Zd,
    KurtBoost,
    Lim,
    Lsaq,
    LlmMq,
    LieQ,
}

impl Method {
    pub fn label(self) -> &'static str {
        use crate::sensitivity::Ablation::*;
        match self {
            Method::Nsds(Full) => "NSDS",
            Method::Nsds(NoNv) => "NSDS w/o NV",
            Method::Nsds(NoSe) => "NSDS w/o SE",
            Method::Nsds(NoBeta) => "NSDS w/o beta",
            Method::Nsds(NoAgg) => "NSDS w/o MAD-Sigmoid & Soft-OR",
            Method::Mse => "MSE",
            Method::Ewq => "EWQ",
            Method::Zd => "ZD",
            Method::KurtBoost => "KurtBoost",
            Method::Lim => "LIM",
            Method::Lsaq => "LSAQ",
            Method::LlmMq => "LLM-MQ",
            Method::LieQ => "LieQ",
        }
    }

    pub fn needs_calibration(self) -> bool {
        matches!(self, Method::Lim | Method::Lsaq | Method::LlmMq
                 | Method::LieQ)
    }

    /// The calibration-free lineup of Table 1.
    pub fn table1() -> Vec<Method> {
        vec![Method::Mse, Method::Ewq, Method::Zd, Method::KurtBoost,
             Method::Nsds(crate::sensitivity::Ablation::Full)]
    }

    /// The calibration-based lineup of Fig. 5.
    pub fn fig5() -> Vec<Method> {
        vec![Method::Lim, Method::Lsaq, Method::LlmMq, Method::LieQ,
             Method::Nsds(crate::sensitivity::Ablation::Full)]
    }
}

/// Score all layers with a method. `calib`/`init` are required only by the
/// calibration-based methods (panics otherwise — the coordinator enforces
/// availability).
pub fn layer_scores(method: Method, cfg: &ModelConfig, w: &Weights,
                    calib: Option<&Calibration>, init: Option<&Weights>,
                    workers: usize) -> Vec<f64> {
    match method {
        Method::Nsds(ablation) => {
            let opts = crate::sensitivity::NsdsOptions {
                ablation,
                workers,
                ..Default::default()
            };
            crate::sensitivity::nsds_layer_scores(cfg, w, &opts)
        }
        Method::Mse => free::mse(cfg, w, workers),
        Method::Ewq => free::ewq(cfg, w, workers),
        Method::Zd => free::zd(cfg, w, workers),
        Method::KurtBoost => free::kurtboost_scores(cfg, w, workers).0,
        Method::Lim => calibrated::lim(cfg, calib.expect("LIM needs calib")),
        Method::Lsaq => calibrated::lsaq(
            cfg, w, calib.expect("LSAQ needs calib")),
        Method::LlmMq => calibrated::llm_mq(
            cfg, w, calib.expect("LLM-MQ needs calib")),
        Method::LieQ => calibrated::lieq(
            cfg, w, init.expect("LieQ needs init weights"),
            calib.expect("LieQ needs calib")),
    }
}

/// Bit allocation for a method (KurtBoost adds its outlier-priority rule).
pub fn allocate(method: Method, cfg: &ModelConfig, w: &Weights,
                calib: Option<&Calibration>, init: Option<&Weights>,
                budget: f64, workers: usize) -> Vec<u8> {
    if method == Method::KurtBoost {
        let (scores, forced) = free::kurtboost_scores(cfg, w, workers);
        return crate::allocate::allocate_with_priority(&scores, budget,
                                                       &forced);
    }
    let scores = layer_scores(method, cfg, w, calib, init, workers);
    crate::allocate::allocate_bits(&scores, budget)
}
