//! SliM-LLM (App. E.3): salience-driven **group-wise** mixed precision on
//! a GPTQ substrate — the strongest calibration-based comparator (Fig. 6).
//!
//! Salience of element (i,j):  δ ≈ (w_{ij} · ‖x_j‖₂)²  (activation-aware,
//! like AWQ/SliM). Salience-Determined Bit Allocation: within each weight
//! matrix, groups (along K) are ranked by mean salience and the top ρ
//! fraction get 4-bit while the rest get 2-bit, meeting the same average
//! budget the layer-wise methods get — but *inside every layer* (the
//! less hardware-friendly scheme the paper contrasts against).
//! Quantization then runs a GPTQ sweep with the per-group bit widths.
//!
//! Simplification vs the original (documented in DESIGN.md): bit ladder is
//! {2, 4} (not {2, 3}) to match our packing substrate, and group bits are
//! chosen by salience ranking rather than KL search — the salience
//! ordering is the paper's own SBA criterion; the KL refinement is noted
//! as future work.

use crate::model::{ModelConfig, Weights, QUANT_WEIGHTS};
use crate::quant::{rtn, HessianMap, QuantSpec, QuantizedMatrix};
use crate::tensor::linalg::spd_inverse;
use crate::tensor::Tensor;

/// Mean salience per K-group of W [K, N], given per-input-channel
/// activation norms ‖x_k‖ (length K).
pub fn group_salience(w: &Tensor, act_norm: &[f32], group: usize)
    -> Vec<f64> {
    let (k, n) = (w.rows(), w.cols());
    assert_eq!(act_norm.len(), k);
    let ng = k / group;
    let mut out = vec![0.0f64; ng];
    for r in 0..k {
        let a = act_norm[r] as f64;
        let row = w.row(r);
        let s: f64 = row.iter().map(|&v| {
            let d = v as f64 * a;
            d * d
        }).sum();
        out[r / group] += s / (group * n) as f64;
    }
    out
}

/// Per-group bit widths meeting the average budget within one matrix.
pub fn allocate_group_bits(salience: &[f64], budget: f64) -> Vec<u8> {
    crate::allocate::allocate_bits(salience, budget)
}

/// GPTQ sweep with heterogeneous per-group bits.
pub fn gptq_mixed(w: &Tensor, group: usize, group_bits: &[u8],
                  hessian: Option<&Tensor>) -> QuantizedMatrix {
    let (k, n) = (w.rows(), w.cols());
    assert_eq!(group_bits.len(), k / group);
    let hinv = hessian
        .and_then(spd_inverse)
        .unwrap_or_else(|| {
            let mut eye = Tensor::zeros(vec![k, k]);
            for i in 0..k {
                eye.set(i, i, 1.0);
            }
            eye
        });
    let mut wr = w.clone();
    let mut codes = vec![0u8; k * n];
    let ng = k / group;
    let mut scale = vec![0.0f32; ng * n];
    let mut zero = vec![0.0f32; ng * n];
    for r in 0..k {
        let gr = r / group;
        let bits = group_bits[gr];
        let qmax = ((1u32 << bits) - 1) as f32;
        if r % group == 0 {
            let block = wr.rows_range(gr * group, (gr + 1) * group);
            let (s_blk, z_blk) =
                rtn::params(&block, QuantSpec::new(bits, group));
            scale[gr * n..(gr + 1) * n].copy_from_slice(&s_blk);
            zero[gr * n..(gr + 1) * n].copy_from_slice(&z_blk);
        }
        let d = hinv.at(r, r).max(1e-10);
        let mut err = vec![0.0f32; n];
        for c in 0..n {
            let s = scale[gr * n + c];
            let z = zero[gr * n + c];
            let v = wr.at(r, c);
            let q = (v / s + z).round().clamp(0.0, qmax);
            codes[r * n + c] = q as u8;
            err[c] = (v - s * (q - z)) / d;
        }
        for rr in (r + 1)..k {
            let hval = hinv.at(rr, r);
            if hval == 0.0 {
                continue;
            }
            let row = wr.row_mut(rr);
            for (c, e) in err.iter().enumerate() {
                row[c] -= hval * e;
            }
        }
    }
    // spec.bits is nominal (mixed); dequantize only uses scale/zero/codes.
    QuantizedMatrix { spec: QuantSpec::new(4, group), codes, k, n, scale,
                      zero }
}

/// Full SliM-LLM model quantization at an average budget: every layer is
/// quantized group-wise mixed-precision (no layer ranking involved).
pub fn quantize_model(cfg: &ModelConfig, w: &Weights,
                      calib: &crate::coordinator::calib::Calibration,
                      budget: f64, group: usize) -> Weights {
    let hessians: HessianMap = calib.hessians(cfg.n_layers);
    let mut out = w.clone();
    for l in 0..cfg.n_layers {
        for name in QUANT_WEIGHTS {
            let m = w.layer_matrix(name, l);
            let x = calib.inputs_for(name, l);
            // per-input-channel L2 norms of the activations
            let k = m.rows();
            let mut norms = vec![0.0f32; k];
            for r in 0..x.rows() {
                let row = x.row(r);
                for (c, &v) in row.iter().enumerate() {
                    norms[c] += v * v;
                }
            }
            for v in norms.iter_mut() {
                *v = v.sqrt();
            }
            let sal = group_salience(&m, &norms, group);
            let gbits = allocate_group_bits(&sal, budget);
            let h = hessians.get(&(l, name.to_string()));
            let q = gptq_mixed(&m, group, &gbits, h);
            out.set_layer_matrix(name, l, &q.dequantize());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn salience_tracks_activation_norms() {
        let mut rng = Rng::new(41);
        let w = Tensor::randn(vec![16, 8], &mut rng);
        // group 1 (rows 8..16) sees 10x activations
        let mut norms = vec![1.0f32; 16];
        for n in norms[8..].iter_mut() {
            *n = 10.0;
        }
        let s = group_salience(&w, &norms, 8);
        assert!(s[1] > s[0] * 10.0, "{s:?}");
    }

    #[test]
    fn mixed_budget_average_is_met() {
        let sal = vec![0.9, 0.1, 0.5, 0.2];
        let bits = allocate_group_bits(&sal, 3.0);
        let avg: f64 =
            bits.iter().map(|&b| b as f64).sum::<f64>() / bits.len() as f64;
        assert_eq!(avg, 3.0);
        assert_eq!(bits[0], 4);
        assert_eq!(bits[2], 4);
    }

    #[test]
    fn mixed_gptq_protects_salient_groups() {
        let mut rng = Rng::new(42);
        let w = Tensor::randn(vec![32, 8], &mut rng);
        let gbits = vec![4u8, 2, 4, 2];
        let q = gptq_mixed(&w, 8, &gbits, None);
        let d = q.dequantize();
        let err_group = |g: usize| {
            let a = w.rows_range(g * 8, (g + 1) * 8);
            let b = d.rows_range(g * 8, (g + 1) * 8);
            a.sub(&b).frob_norm()
        };
        assert!(err_group(0) < err_group(1), "4-bit group must be cleaner");
        assert!(err_group(2) < err_group(3));
    }
}
