//! Calibration-based baselines (paper App. E.2): LIM, LSAQ, LLM-MQ, LieQ.
//! All consume the probe/grad activations collected by
//! `coordinator::calib` through the PJRT probe executable.

use std::collections::BTreeSet;

use crate::coordinator::calib::Calibration;
use crate::model::{ModelConfig, Weights, QUANT_WEIGHTS};
use crate::quant::{rtn, QuantSpec, DEFAULT_GROUP};
use crate::tensor::matmul::{dot, matmul};
use crate::tensor::stats::entropy;
use crate::tensor::svd::svd;
use crate::tensor::Tensor;

/// LIM (Eq. 22): 1 − cos(X_in, X_out) per token, averaged over the
/// calibration rows. Higher = bigger transformation = more sensitive.
pub fn lim(cfg: &ModelConfig, calib: &Calibration) -> Vec<f64> {
    (0..cfg.n_layers)
        .map(|l| {
            let x_in = &calib.resid[l];
            let x_out = &calib.resid[l + 1];
            let rows = x_in.rows();
            let mut acc = 0.0f64;
            for r in 0..rows {
                let a = x_in.row(r);
                let b = x_out.row(r);
                let na = dot(a, a).sqrt().max(1e-12);
                let nb = dot(b, b).sqrt().max(1e-12);
                acc += 1.0 - (dot(a, b) / (na * nb)) as f64;
            }
            acc / rows as f64
        })
        .collect()
}

/// LSAQ (Eqs. 23–24): project layer input/output hidden states onto the
/// vocabulary (logit lens), compare top-k decoded token sets via Jaccard.
/// Higher (1 − Jaccard) = more semantic transformation = more sensitive.
pub fn lsaq(cfg: &ModelConfig, w: &Weights, calib: &Calibration)
    -> Vec<f64> {
    let wu = w.get("unembed"); // [D, V]
    let k = 8;
    let max_rows = 128; // logit-lens projection is the costly part
    (0..cfg.n_layers)
        .map(|l| {
            let x_in = Calibration::subsample(&calib.resid[l], max_rows);
            let x_out = Calibration::subsample(&calib.resid[l + 1],
                                               max_rows);
            let p_in = matmul(&x_in, wu);
            let p_out = matmul(&x_out, wu);
            let rows = p_in.rows();
            let mut acc = 0.0f64;
            for r in 0..rows {
                let a = top_k_set(p_in.row(r), k);
                let b = top_k_set(p_out.row(r), k);
                let inter = a.intersection(&b).count() as f64;
                let union = (a.len() + b.len()) as f64 - inter;
                acc += 1.0 - inter / union;
            }
            acc / rows as f64
        })
        .collect()
}

fn top_k_set(row: &[f32], k: usize) -> BTreeSet<usize> {
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]));
    idx.into_iter().take(k).collect()
}

/// LLM-MQ (Eqs. 25–26): first-order loss perturbation
/// |Σ G ⊙ (W − Q_b(W))| at the low bit width, averaged over the layer's
/// matrices. Higher = more sensitive.
pub fn llm_mq(cfg: &ModelConfig, w: &Weights, calib: &Calibration)
    -> Vec<f64> {
    let grads = calib.grads.as_ref().expect(
        "LLM-MQ needs loss gradients, which this executor did not \
         collect (enable the `xla` feature's grad artifact)");
    (0..cfg.n_layers)
        .map(|l| {
            let mut acc = 0.0f64;
            for name in QUANT_WEIGHTS {
                let wm = w.layer_matrix(name, l);
                let gm = grads[name].slice0(l);
                let g = crate::quant::fit_group(wm.rows(), DEFAULT_GROUP);
                let q = rtn::quantize(&wm, QuantSpec::new(2, g));
                let dq = q.dequantize();
                let mut s = 0.0f64;
                for ((wv, dv), gv) in
                    wm.data().iter().zip(dq.data()).zip(gm.data())
                {
                    s += (*gv as f64) * ((*wv - *dv) as f64);
                }
                acc += s.abs();
            }
            acc / QUANT_WEIGHTS.len() as f64
        })
        .collect()
}

/// Representational compactness (Eq. 27): exp(H(σ(Z))) of the projected
/// activations — the effective rank of Z.
pub fn compactness(z: &Tensor) -> f64 {
    let sv = svd(z).sigma;
    let total: f64 = sv.iter().sum();
    if total <= 0.0 {
        return 1.0;
    }
    let p: Vec<f64> = sv.iter().map(|s| s / total).collect();
    entropy(&p).exp()
}

/// LieQ (Eq. 28): relative compactness reduction of trained vs untrained
/// projections, averaged over the layer's matrices. Higher = the layer
/// concentrated information during training = more sensitive.
pub fn lieq(cfg: &ModelConfig, w: &Weights, init: &Weights,
            calib: &Calibration) -> Vec<f64> {
    let max_rows = 96; // SVD cost control; documented in DESIGN.md
    (0..cfg.n_layers)
        .map(|l| {
            let mut acc = 0.0f64;
            for name in QUANT_WEIGHTS {
                let x = Calibration::subsample(calib.inputs_for(name, l),
                                               max_rows);
                let z = matmul(&x, &w.layer_matrix(name, l));
                let z0 = matmul(&x, &init.layer_matrix(name, l));
                let c = compactness(&z);
                let c0 = compactness(&z0).max(1e-9);
                acc += (c0 - c) / c0;
            }
            acc / QUANT_WEIGHTS.len() as f64
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Hand-built calibration where layer 1 transforms the stream hard and
    /// layer 0 is a near-identity.
    fn fake_calib(cfg: &ModelConfig, rng: &mut Rng) -> Calibration {
        let rows = 40;
        let d = cfg.d_model;
        let x0 = Tensor::randn(vec![rows, d], rng);
        let x1 = x0.add(&Tensor::randn(vec![rows, d], rng).scale(0.01));
        let x2 = Tensor::randn(vec![rows, d], rng); // decorrelated
        let x3 = x2.add(&Tensor::randn(vec![rows, d], rng).scale(0.01));
        let mut mk = |dim: usize| {
            (0..cfg.n_layers)
                .map(|_| Tensor::randn(vec![rows, dim], rng))
                .collect::<Vec<_>>()
        };
        let mut grads = std::collections::BTreeMap::new();
        for name in QUANT_WEIGHTS {
            grads.insert(name.to_string(),
                         Tensor::zeros(cfg.weight_dims(name)));
        }
        Calibration {
            resid: vec![x0, x1, x2, x3],
            x_ln1: mk(d),
            x_ln2: mk(d),
            attn_ctx: mk(cfg.n_heads * cfg.d_head),
            ffn_mid: mk(cfg.d_ffn),
            grads: Some(grads),
            loss: 1.0,
        }
    }

    #[test]
    fn lim_detects_transforming_layer() {
        let cfg = ModelConfig::test_config();
        let mut rng = Rng::new(31);
        let calib = fake_calib(&cfg, &mut rng);
        let s = lim(&cfg, &calib);
        // layer 1 (x1 -> x2) decorrelates; layers 0 and 2 are identity-ish.
        assert!(s[1] > s[0] * 5.0, "{s:?}");
        assert!(s[1] > s[2] * 5.0, "{s:?}");
    }

    #[test]
    fn lsaq_detects_semantic_shift() {
        let cfg = ModelConfig::test_config();
        let mut rng = Rng::new(32);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let calib = fake_calib(&cfg, &mut rng);
        let s = lsaq(&cfg, &w, &calib);
        assert!(s[1] > s[0], "{s:?}");
    }

    #[test]
    fn compactness_rank_sensitivity() {
        let mut rng = Rng::new(33);
        // Full-rank gaussian vs rank-1: compactness must collapse.
        let full = Tensor::randn(vec![30, 10], &mut rng);
        let u = rng.normal_vec(30);
        let v = rng.normal_vec(10);
        let mut r1 = Tensor::zeros(vec![30, 10]);
        for i in 0..30 {
            for j in 0..10 {
                r1.set(i, j, u[i] as f32 * v[j] as f32);
            }
        }
        assert!(compactness(&full) > 5.0 * compactness(&r1));
    }

    #[test]
    fn llm_mq_zero_gradient_zero_score() {
        let cfg = ModelConfig::test_config();
        let mut rng = Rng::new(34);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let calib = fake_calib(&cfg, &mut rng); // zero grads
        let s = llm_mq(&cfg, &w, &calib);
        assert!(s.iter().all(|&x| x.abs() < 1e-12), "{s:?}");
    }
}
