//! Calibration-free baselines (paper App. E.1): MSE, ZD, EWQ, KurtBoost.

use crate::model::{ModelConfig, Weights, QUANT_WEIGHTS};
use crate::quant::{recon_error, Backend, QuantSpec, DEFAULT_GROUP};
use crate::tensor::stats;
use crate::util::pool::parallel_map;

/// MSE (Eq. 15): total ‖W − Ŵ‖²_F over the layer's matrices at the low
/// bit width (2-bit — the precision a mis-ranked layer would suffer).
/// Higher = more sensitive.
pub fn mse(cfg: &ModelConfig, w: &Weights, workers: usize) -> Vec<f64> {
    parallel_map(cfg.n_layers, workers, |l| {
        QUANT_WEIGHTS
            .iter()
            .map(|name| {
                let m = w.layer_matrix(name, l);
                let g = crate::quant::fit_group(m.rows(), DEFAULT_GROUP);
                recon_error(&m, QuantSpec::new(2, g), Backend::Rtn)
            })
            .sum()
    })
}

/// ZD (Eqs. 16–17): fraction of weights with z-score strictly above 1.
/// The paper orients it inversely ("smaller ZD ⇒ higher sensitivity"), so
/// we negate once here. Statistics are pooled over the whole layer.
pub fn zd(cfg: &ModelConfig, w: &Weights, workers: usize) -> Vec<f64> {
    parallel_map(cfg.n_layers, workers, |l| {
        let mut all: Vec<f32> = Vec::new();
        for name in QUANT_WEIGHTS {
            all.extend_from_slice(w.layer_matrix(name, l).data());
        }
        let mu = stats::mean(&all);
        let sd = stats::std_dev(&all).max(1e-12);
        let frac = all
            .iter()
            .filter(|&&x| ((x as f64) - mu) / sd > 1.0)
            .count() as f64
            / all.len() as f64;
        -frac
    })
}

/// EWQ (Eqs. 18–19): parameter-weighted softmax entropy of each matrix,
/// ε = 0.01 inside the log as in the paper. Higher = more sensitive.
pub fn ewq(cfg: &ModelConfig, w: &Weights, workers: usize) -> Vec<f64> {
    parallel_map(cfg.n_layers, workers, |l| {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for name in QUANT_WEIGHTS {
            let m = w.layer_matrix(name, l);
            let h = stats::softmax_entropy(m.data(), 0.01);
            num += m.len() as f64 * h;
            den += m.len() as f64;
        }
        num / den
    })
}

/// KurtBoost (Eqs. 20–21): layer score = mean raw kurtosis of its
/// matrices; layers whose adjacent-difference z-score exceeds 3 are
/// flagged as outliers and force-prioritized during allocation.
/// Returns (scores, forced layer indices).
pub fn kurtboost_scores(cfg: &ModelConfig, w: &Weights, workers: usize)
    -> (Vec<f64>, Vec<usize>) {
    let scores: Vec<f64> = parallel_map(cfg.n_layers, workers, |l| {
        let ks: Vec<f64> = QUANT_WEIGHTS
            .iter()
            .map(|name| stats::raw_kurtosis(w.layer_matrix(name, l).data()))
            .collect();
        ks.iter().sum::<f64>() / ks.len() as f64
    });
    // Difference sequence d_l = k_{l+1} − k_l; outliers at |d−μ|/σ > 3.
    let diffs: Vec<f64> =
        scores.windows(2).map(|p| p[1] - p[0]).collect();
    let n = diffs.len().max(1) as f64;
    let mu = diffs.iter().sum::<f64>() / n;
    let sd = (diffs.iter().map(|d| (d - mu).powi(2)).sum::<f64>() / n)
        .sqrt()
        .max(1e-12);
    let mut forced = Vec::new();
    for (i, d) in diffs.iter().enumerate() {
        if ((d - mu) / sd).abs() > 3.0 {
            // A jump between layers i and i+1 flags the higher-kurtosis
            // side as the outlier layer.
            let flag = if scores[i + 1] > scores[i] { i + 1 } else { i };
            if !forced.contains(&flag) {
                forced.push(flag);
            }
        }
    }
    (scores, forced)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup() -> (ModelConfig, Weights) {
        let cfg = ModelConfig::test_config();
        let mut rng = Rng::new(21);
        // layer 2 heavy-tailed
        let w = Weights::synth(&cfg, &mut rng, &[0.0, 0.0, 5.0], &[]);
        (cfg, w)
    }

    #[test]
    fn mse_flags_wide_range_layers() {
        let (cfg, w) = setup();
        let s = mse(&cfg, &w, 1);
        assert_eq!(s.len(), 3);
        // Heavy tails stretch the quantization range -> larger 2-bit error.
        assert!(s[2] > s[0], "{s:?}");
    }

    #[test]
    fn kurtboost_ranks_heavy_tail_highest() {
        let (cfg, w) = setup();
        let (s, _forced) = kurtboost_scores(&cfg, &w, 1);
        let top = s.iter().enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
        assert_eq!(top, 2, "{s:?}");
    }

    #[test]
    fn zd_negated_orientation() {
        let (cfg, w) = setup();
        let s = zd(&cfg, &w, 1);
        // scores are negations of fractions in [0,1]
        assert!(s.iter().all(|&x| (-1.0..=0.0).contains(&x)), "{s:?}");
    }

    #[test]
    fn ewq_finite_and_layer_shaped() {
        let (cfg, w) = setup();
        let s = ewq(&cfg, &w, 1);
        assert_eq!(s.len(), cfg.n_layers);
        assert!(s.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn kurtboost_forces_extreme_jump() {
        // Note: with L layers the max attainable |z| of the adjacent-diff
        // sequence is ~sqrt(L-2), so the paper's z>3 rule only ever fires
        // on deep stacks — we test with 24 layers and one violent spike.
        let cfg = ModelConfig { n_layers: 24, ..ModelConfig::test_config() };
        let mut rng = Rng::new(22);
        let mut tb = vec![0.0; 24];
        tb[13] = 25.0;
        let w = Weights::synth(&cfg, &mut rng, &tb, &[]);
        let (_s, forced) = kurtboost_scores(&cfg, &w, 1);
        assert!(forced.contains(&13), "forced={forced:?}");
    }
}
