//! Search-based LMPQ baseline (the family the paper's intro dismisses as
//! "computationally prohibitive" — HAQ/BP-NAS style, reduced to the
//! 2-vs-4-bit layer-assignment space).
//!
//! Greedy forward selection: start from uniform 2-bit; repeatedly promote
//! to 4-bit the layer whose promotion lowers evaluated PPL the most,
//! until the budget's L₄ promotions are spent. Each candidate evaluation
//! is a *real* quantize+PPL run through the PJRT executor, so the cost is
//! O(L²) evaluations vs O(0) for criterion-based methods — the
//! cost/quality trade-off `nsds search-vs-criterion` quantifies.

use anyhow::Result;

use crate::coordinator::Pipeline;
use crate::quant::Backend;

/// Greedy search result.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub bits: Vec<u8>,
    /// PPL after each greedy promotion (monitoring curve).
    pub curve: Vec<f64>,
    /// Number of full quantize+eval calls spent.
    pub evals: usize,
}

/// Greedy ΔPPL search under an average-bit budget.
/// `ppl_batches` controls the fidelity (and cost) of each probe eval.
pub fn greedy_allocate(p: &Pipeline, model: &str, budget: f64,
                       backend: Backend, ppl_batches: usize)
                       -> Result<SearchResult> {
    let entry = p.entry(model)?;
    let nl = entry.config.n_layers;
    let rho = ((budget - 2.0) / 2.0).clamp(0.0, 1.0);
    let l4 = (rho * nl as f64).round() as usize;
    let corpora = crate::eval::ppl::load_corpora(&p.man)?;

    let eval_bits = |bits: &[u8], evals: &mut usize| -> Result<f64> {
        *evals += 1;
        let qw = p.quantize(model, bits, backend)?;
        crate::eval::ppl::perplexity(p.exec(), &p.man, entry, &qw,
                                     &corpora.wiki_like, ppl_batches)
    };

    let mut bits = vec![2u8; nl];
    let mut evals = 0usize;
    let mut curve = vec![eval_bits(&bits, &mut evals)?];
    for _ in 0..l4 {
        let mut best: Option<(usize, f64)> = None;
        for l in 0..nl {
            if bits[l] == 4 {
                continue;
            }
            let mut cand = bits.clone();
            cand[l] = 4;
            let ppl = eval_bits(&cand, &mut evals)?;
            if best.map(|(_, b)| ppl < b).unwrap_or(true) {
                best = Some((l, ppl));
            }
        }
        let (l, ppl) = best.expect("budget exceeds layer count");
        bits[l] = 4;
        curve.push(ppl);
    }
    Ok(SearchResult { bits, curve, evals })
}

#[cfg(test)]
mod tests {
    //! Pure-logic tests; the end-to-end greedy path is exercised by the
    //! `search_beats_or_matches_criterion` integration test (needs
    //! artifacts) and the `nsds search-vs-criterion` CLI.

    #[test]
    fn promotion_count_matches_budget() {
        // round((b−2)/2·L) promotions at b̄=3, L=8 → 4.
        let rho = (3.0f64 - 2.0) / 2.0;
        assert_eq!((rho * 8.0).round() as usize, 4);
    }
}
