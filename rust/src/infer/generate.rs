//! Autoregressive generation over the batched KV-cached decode path:
//! `BatchEngine` is a step-driven continuous-batching scheduler — each
//! step admits pending requests into free cache-pool slots, feeds every
//! active sequence one token through `Executor::decode_batch`, samples
//! per slot (greedy or seeded temperature/top-k via `util::rng`, fully
//! deterministic per request seed), and retires finished sequences
//! without stalling the rest. Admission is prefix-aware over the paged
//! pool: a prompt sharing a tokenized prefix with a resident sequence
//! references that sequence's pages copy-on-write and prefills only the
//! tail. `generate` is the B=1 case; `generate_batch` runs a whole
//! request set through one engine. Executor- and variant-generic: a
//! `ModelRef` dispatches to the dense or fused-packed decode path, so
//! the same engine generates from FP32 weights and from packed 2/4-bit
//! `QuantizedModel`s.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{ensure, Result};

use super::cache::PAGE_SIZE;
use super::{Executor, KvCachePool, ModelRef};
use crate::model::ModelConfig;
use crate::runtime::ModelEntry;
use crate::util::rng::Rng;

/// Next-token selection rule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampling {
    /// Argmax (ties → lowest token id). Deterministic, ignores the seed.
    Greedy,
    /// Sample from the softmax of the `k` highest logits at the given
    /// temperature (k is clamped to the vocabulary; temperature to a
    /// small positive floor).
    TopK { k: usize, temperature: f32 },
}

/// Generation request knobs.
#[derive(Clone, Debug)]
pub struct GenConfig {
    /// Maximum number of new tokens to emit.
    pub max_new: usize,
    pub sampling: Sampling,
    /// PRNG seed for `TopK` (ignored by `Greedy`). Same seed + same
    /// model ⇒ same output, regardless of thread or batching.
    pub seed: u64,
    /// Emitting any of these tokens ends the generation (the stop token
    /// is included in the output).
    pub stop: Vec<i32>,
    /// KV-cache capacity; 0 sizes it to `prompt.len() + max_new`, which
    /// keeps incremental decode exact (no ring eviction).
    pub cap: usize,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            max_new: 16,
            sampling: Sampling::Greedy,
            seed: 0,
            stop: Vec::new(),
            cap: 0,
        }
    }
}

/// Why a generation ended.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StopReason {
    MaxNew,
    StopToken(i32),
}

/// Per-request timing/throughput counters.
///
/// Times are wall-clock spans of the request's life inside its engine
/// (admission → last prompt token → retirement). In a B=1 engine
/// (`generate`) that is the dedicated per-request cost, as before; in a
/// shared continuous batch (`generate_batch`, the server scheduler) the
/// spans include co-batched sequences' work and anything else the serve
/// loop interleaves, so they measure observed latency, not isolated
/// decode cost. Aggregate throughput across a batch is what improves.
#[derive(Clone, Debug)]
pub struct GenStats {
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    /// Wall time consuming the prompt (cache build-up).
    pub prefill_s: f64,
    /// Wall time of the new-token decode loop.
    pub decode_s: f64,
}

impl GenStats {
    pub fn total_s(&self) -> f64 {
        self.prefill_s + self.decode_s
    }

    /// New tokens per second over the decode loop.
    pub fn decode_tok_per_s(&self) -> f64 {
        if self.decode_s > 0.0 {
            self.gen_tokens as f64 / self.decode_s
        } else {
            0.0
        }
    }
}

/// One finished generation.
#[derive(Clone, Debug)]
pub struct Generation {
    /// The newly generated tokens (prompt not included).
    pub tokens: Vec<i32>,
    pub stats: GenStats,
    pub stopped: StopReason,
}

/// Pick the next token from a logits row.
pub fn sample(logits: &[f32], sampling: &Sampling, rng: &mut Rng) -> i32 {
    match *sampling {
        Sampling::Greedy => argmax(logits),
        Sampling::TopK { k, temperature } => {
            let k = k.clamp(1, logits.len());
            if k == 1 {
                return argmax(logits);
            }
            let temp = temperature.max(1e-6);
            // Indices of the k largest logits (desc by logit, ties asc by
            // id — a total order, so the selection is deterministic).
            // O(V) partition first; only the k winners get sorted.
            let cmp = |a: &usize, b: &usize| {
                logits[*b]
                    .partial_cmp(&logits[*a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(b))
            };
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            if k < idx.len() {
                idx.select_nth_unstable_by(k - 1, cmp);
                idx.truncate(k);
            }
            idx.sort_unstable_by(cmp);
            let mx = logits[idx[0]];
            let ws: Vec<f64> = idx
                .iter()
                .map(|&i| (((logits[i] - mx) / temp) as f64).exp())
                .collect();
            let total: f64 = ws.iter().sum();
            let mut r = rng.f64() * total;
            for (&i, w) in idx.iter().zip(&ws) {
                r -= w;
                if r <= 0.0 {
                    return i as i32;
                }
            }
            idx[k - 1] as i32 // fp slack: fall back to the least likely
        }
    }
}

fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// A request queued in a `BatchEngine`, waiting for a free cache slot.
struct Pending<T> {
    tag: T,
    prompt: Vec<i32>,
    gc: GenConfig,
}

/// Token at index `i` of a request's consumed stream: prompt tokens
/// first, then the fed-back samples.
fn stream_token(prompt: &[i32], tokens: &[i32], i: usize) -> i32 {
    if i < prompt.len() {
        prompt[i]
    } else {
        tokens[i - prompt.len()]
    }
}

/// Longest shared prefix between `prompt` and a donor's committed
/// stream (its prompt plus already-sampled tokens), capped at `limit`.
fn common_prefix(prompt: &[i32], d_prompt: &[i32], d_tokens: &[i32],
                 limit: usize) -> usize {
    let committed = d_prompt.len() + d_tokens.len();
    let mut n = 0;
    while n < limit.min(prompt.len()).min(committed) {
        if stream_token(d_prompt, d_tokens, n) != prompt[n] {
            break;
        }
        n += 1;
    }
    n
}

/// One admitted sequence: its slot, sampling state, and timings.
struct Active<T> {
    tag: T,
    slot: usize,
    prompt: Vec<i32>,
    gc: GenConfig,
    rng: Rng,
    /// Tokens the model has consumed so far (prompt, then fed-back
    /// samples). The token fed at step `fed` is `prompt[fed]` while
    /// `fed < prompt.len()`, else `tokens[fed - prompt.len()]`.
    fed: usize,
    /// Sampled new tokens (the generation output).
    tokens: Vec<i32>,
    t_admit: Instant,
    t_prefill_done: Option<Instant>,
}

/// Step-driven continuous-batching generation engine over one
/// `Executor::decode_batch` stream. Submit any number of requests; each
/// `step` admits pending requests into free slots, decodes ONE token for
/// every active sequence in a single batched call, samples per slot with
/// that request's own seeded RNG, and retires finished sequences (freeing
/// their slots for the next admission) without stalling the rest.
///
/// Determinism: a request's trajectory depends only on the model and its
/// own `GenConfig` — batched decode rows are bit-identical to
/// single-sequence `decode_step` and each request samples from its own
/// `Rng::new(seed)` — so outputs are independent of what else shares the
/// batch, of admission timing, and of slot placement. The serving
/// scheduler (`coordinator::server`) relies on this to keep batched
/// serving reproducible.
///
/// Prefix sharing preserves this: when a prompt admits by referencing a
/// resident sequence's prefix pages (`KvCachePool::admit_shared`), the
/// referenced K/V rows were produced by the SAME deterministic decode
/// for the SAME tokens at the SAME absolute positions under an unwrapped
/// ring, so they are bit-identical to what the request's own prefill
/// would have appended — sharing changes memory and prefill work, never
/// tokens (pinned by `rust/tests/batch_decode.rs` shared-prefix tests).
///
/// `T` is an opaque per-request tag returned with the finished
/// `Generation` (an index for `generate_batch`, a reply channel for the
/// server).
pub struct BatchEngine<T> {
    cfg: ModelConfig,
    pool: KvCachePool,
    pending: VecDeque<Pending<T>>,
    active: Vec<Active<T>>,
    shared_tokens: u64,
}

impl<T> BatchEngine<T> {
    /// An engine decoding up to `slots` concurrent sequences of `cfg`'s
    /// geometry.
    pub fn new(cfg: &ModelConfig, slots: usize) -> Self {
        assert!(slots > 0, "BatchEngine needs at least one slot");
        BatchEngine {
            cfg: cfg.clone(),
            pool: KvCachePool::for_model(cfg, slots),
            pending: VecDeque::new(),
            active: Vec::new(),
            shared_tokens: 0,
        }
    }

    /// The engine's paged cache pool (read-only: page/sharing state for
    /// stats and tests).
    pub fn pool(&self) -> &KvCachePool {
        &self.pool
    }

    /// Prompt tokens admitted by shared-prefix page reference instead
    /// of prefill, cumulative over the engine's life.
    pub fn shared_prefix_tokens(&self) -> u64 {
        self.shared_tokens
    }

    /// Validate a prompt without submitting it (the server routes a bad
    /// prompt's error to its reply channel instead of poisoning the
    /// shared batch).
    pub fn check(&self, prompt: &[i32]) -> Result<()> {
        ensure!(!prompt.is_empty(), "generate: empty prompt");
        let v = self.cfg.vocab;
        ensure!(prompt.iter().all(|&t| t >= 0 && (t as usize) < v),
                "generate: prompt token out of range (vocab {v})");
        Ok(())
    }

    /// Queue a request. It is admitted into a cache slot by a later
    /// `step` as capacity frees up. On a rejected prompt the tag comes
    /// back with the error, so the server can fail that request's reply
    /// channel rather than silently dropping it.
    pub fn submit(&mut self, tag: T, prompt: Vec<i32>, gc: GenConfig)
        -> Result<(), (T, anyhow::Error)> {
        if let Err(e) = self.check(&prompt) {
            return Err((tag, e));
        }
        self.pending.push_back(Pending { tag, prompt, gc });
        Ok(())
    }

    /// No requests pending or in flight.
    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.active.is_empty()
    }

    /// Requests submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.pending.len() + self.active.len()
    }

    pub fn slots(&self) -> usize {
        self.pool.max_slots()
    }

    /// One engine step: admit, batch-decode one token per active
    /// sequence, sample, retire. Returns the requests that finished this
    /// step (possibly empty). A no-op returning `[]` when idle.
    pub fn step(&mut self, exec: &dyn Executor, entry: &ModelEntry,
                model: ModelRef) -> Result<Vec<(T, Generation)>> {
        // Admit pending requests into free slots. Per-request cache
        // capacity mirrors the single-sequence policy: `gc.cap`, or
        // prompt + max_new (exact decode, no ring eviction) when 0.
        //
        // Admission is prefix-aware: a prompt sharing a tokenized
        // prefix with a resident sequence admits by referencing that
        // sequence's pages (`admit_shared`, copy-on-write) and starts
        // prefilling at the first un-shared position. When a resident
        // donor has committed (prompt + sampled) a common prefix of at
        // least one full page that it has not finished APPENDING yet,
        // the request is DEFERRED (kept pending, in order): the donor
        // appends one position per step, so waiting a few steps turns
        // the whole prefix into referenced pages instead of re-prefill.
        // Progress is guaranteed — the appended prefix grows every step
        // until it covers the committed one, and a retired donor simply
        // drops out of consideration next step. Sub-page overlaps never
        // defer (they admit at once, sharing whatever is resident).
        // Sharing never changes outputs: shared rows are bit-identical
        // to what the request's own prefill would append (see the
        // determinism note below).
        let mut deferred: Vec<Pending<T>> = Vec::new();
        while self.pool.free_count() > 0 {
            let Some(p) = self.pending.pop_front() else { break };
            let cap = if p.gc.cap > 0 {
                p.gc.cap
            } else {
                p.prompt.len() + p.gc.max_new
            }
            .max(1);
            // Shareable length: leave at least the last prompt token to
            // feed (its logits seed sampling) and fit the new ring.
            let limit = (p.prompt.len() - 1).min(cap);
            let mut best: Option<(usize, usize)> = None; // (slot, now)
            let mut best_later = 0usize;
            for a in &self.active {
                // A wrapped donor has evicted its own prefix.
                if self.pool.pos(a.slot) > self.pool.capacity(a.slot) {
                    continue;
                }
                let committed = common_prefix(
                    &p.prompt, &a.prompt, &a.tokens,
                    limit.min(self.pool.capacity(a.slot)));
                let now = committed.min(a.fed);
                best_later = best_later.max(committed);
                if now > best.map_or(0, |(_, s)| s) {
                    best = Some((a.slot, now));
                }
            }
            let now = best.map_or(0, |(_, s)| s);
            if best_later >= PAGE_SIZE && best_later > now {
                deferred.push(p);
                continue;
            }
            let (slot, shared) = match best {
                Some((donor, s)) if s > 0 => {
                    let slot = self
                        .pool
                        .admit_shared(cap, donor, s)
                        .expect("free slot checked");
                    (slot, s)
                }
                _ => (self.pool.admit(cap).expect("free slot checked"),
                      0),
            };
            self.shared_tokens += shared as u64;
            let rng = Rng::new(p.gc.seed);
            self.active.push(Active {
                tag: p.tag,
                slot,
                prompt: p.prompt,
                gc: p.gc,
                rng,
                fed: shared,
                tokens: Vec::new(),
                t_admit: Instant::now(),
                t_prefill_done: None,
            });
        }
        // Deferred requests keep their original queue position.
        for p in deferred.into_iter().rev() {
            self.pending.push_front(p);
        }
        if self.active.is_empty() {
            return Ok(Vec::new());
        }

        // One token per active sequence, in one batched decode.
        let batch: Vec<(usize, i32)> = self
            .active
            .iter()
            .map(|a| {
                let t = if a.fed < a.prompt.len() {
                    a.prompt[a.fed]
                } else {
                    a.tokens[a.fed - a.prompt.len()]
                };
                (a.slot, t)
            })
            .collect();
        let logits =
            model.decode_batch(exec, entry, &mut self.pool, &batch)?;
        let v = self.cfg.vocab;

        // Sample / retire per row.
        let mut done = Vec::new();
        let mut keep = Vec::with_capacity(self.active.len());
        for (ri, mut a) in
            std::mem::take(&mut self.active).into_iter().enumerate()
        {
            a.fed += 1;
            if a.fed < a.prompt.len() {
                keep.push(a); // still prefilling
                continue;
            }
            if a.fed == a.prompt.len() {
                a.t_prefill_done = Some(Instant::now());
            }
            let mut stopped = None;
            if a.gc.max_new == 0 {
                // Nothing to sample; the prefill itself was the request.
                stopped = Some(StopReason::MaxNew);
            } else {
                let row = &logits.data()[ri * v..(ri + 1) * v];
                let next = sample(row, &a.gc.sampling, &mut a.rng);
                a.tokens.push(next);
                if a.gc.stop.contains(&next) {
                    stopped = Some(StopReason::StopToken(next));
                } else if a.tokens.len() >= a.gc.max_new {
                    stopped = Some(StopReason::MaxNew);
                }
            }
            match stopped {
                None => keep.push(a),
                Some(stopped) => {
                    self.pool.retire(a.slot);
                    let t_pre =
                        a.t_prefill_done.expect("set at prefill end");
                    done.push((a.tag, Generation {
                        stats: GenStats {
                            prompt_tokens: a.prompt.len(),
                            gen_tokens: a.tokens.len(),
                            prefill_s: (t_pre - a.t_admit)
                                .as_secs_f64(),
                            decode_s: t_pre.elapsed().as_secs_f64(),
                        },
                        tokens: a.tokens,
                        stopped,
                    }));
                }
            }
        }
        self.active = keep;
        Ok(done)
    }

    /// Abort every pending and in-flight request, freeing all slots,
    /// and return their tags — the server fails their reply channels
    /// loudly when a fatal error ends the serve loop.
    pub fn abort_all(&mut self) -> Vec<T> {
        let mut tags: Vec<T> =
            self.pending.drain(..).map(|p| p.tag).collect();
        for a in self.active.drain(..) {
            self.pool.retire(a.slot);
            tags.push(a.tag);
        }
        tags
    }

    /// Step until every submitted request has finished.
    pub fn run(&mut self, exec: &dyn Executor, entry: &ModelEntry,
               model: ModelRef) -> Result<Vec<(T, Generation)>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step(exec, entry, model)?);
        }
        Ok(out)
    }
}

/// Run a set of requests through one continuous-batching engine with up
/// to `slots` concurrent sequences; results come back in request order.
/// Each request's output is identical to what `generate` returns for it
/// alone (see `BatchEngine` on determinism) — batching changes
/// throughput, never tokens.
pub fn generate_batch(exec: &dyn Executor, entry: &ModelEntry,
                      model: ModelRef, reqs: &[(Vec<i32>, GenConfig)],
                      slots: usize) -> Result<Vec<Generation>> {
    let mut engine: BatchEngine<usize> =
        BatchEngine::new(&entry.config, slots.max(1));
    for (i, (prompt, gc)) in reqs.iter().enumerate() {
        engine
            .submit(i, prompt.clone(), gc.clone())
            .map_err(|(_, e)| e)?;
    }
    let mut done = engine.run(exec, entry, model)?;
    debug_assert_eq!(done.len(), reqs.len());
    done.sort_unstable_by_key(|(i, _)| *i);
    Ok(done.into_iter().map(|(_, g)| g).collect())
}

/// Generate up to `gc.max_new` tokens after `prompt` through any
/// executor's KV-cached batched decode path — the B=1 case of
/// `generate_batch`: the prompt is fed token by token into a fresh cache
/// slot (same per-token cost as cached decode), then the decode loop
/// samples and feeds back until a stop condition.
pub fn generate(exec: &dyn Executor, entry: &ModelEntry, model: ModelRef,
                prompt: &[i32], gc: &GenConfig) -> Result<Generation> {
    let reqs = [(prompt.to_vec(), gc.clone())];
    let mut out = generate_batch(exec, entry, model, &reqs, 1)?;
    Ok(out.pop().expect("one request in, one generation out"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_ties_pick_lowest_id() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn top1_equals_greedy() {
        let logits = vec![0.1f32, 2.0, -0.5, 1.9];
        let mut rng = Rng::new(9);
        for _ in 0..10 {
            let s = Sampling::TopK { k: 1, temperature: 1.0 };
            assert_eq!(sample(&logits, &s, &mut rng), 1);
        }
    }

    #[test]
    fn topk_only_emits_topk_tokens() {
        let logits = vec![5.0f32, 4.0, -10.0, 3.0, -20.0];
        let mut rng = Rng::new(11);
        let s = Sampling::TopK { k: 3, temperature: 1.0 };
        for _ in 0..200 {
            let t = sample(&logits, &s, &mut rng);
            assert!(matches!(t, 0 | 1 | 3), "sampled non-top-k token {t}");
        }
    }

    #[test]
    fn low_temperature_concentrates_on_argmax() {
        let logits = vec![1.0f32, 1.5, 0.5, 1.4];
        let mut rng = Rng::new(13);
        let s = Sampling::TopK { k: 4, temperature: 1e-4 };
        for _ in 0..50 {
            assert_eq!(sample(&logits, &s, &mut rng), 1);
        }
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let logits = vec![0.3f32, 0.1, 0.2, 0.35, 0.05];
        let s = Sampling::TopK { k: 4, temperature: 0.8 };
        let seq = |seed: u64| -> Vec<i32> {
            let mut rng = Rng::new(seed);
            (0..32).map(|_| sample(&logits, &s, &mut rng)).collect()
        };
        assert_eq!(seq(42), seq(42));
        // Different seeds should (for this spread) disagree somewhere.
        assert_ne!(seq(42), seq(43));
    }
}
