//! Autoregressive generation over `Executor::decode_step`: greedy and
//! temperature/top-k sampling (seeded `util::rng`, fully deterministic),
//! stop conditions, and per-request `GenStats` (prefill vs decode time,
//! tokens/sec). Executor- and variant-generic: a `ModelRef` dispatches to
//! the dense or fused-packed decode path, so the same loop generates from
//! FP32 weights and from packed 2/4-bit `QuantizedModel`s.

use std::time::Instant;

use anyhow::{ensure, Result};

use super::{Executor, KvCache, ModelRef};
use crate::runtime::ModelEntry;
use crate::util::rng::Rng;

/// Next-token selection rule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampling {
    /// Argmax (ties → lowest token id). Deterministic, ignores the seed.
    Greedy,
    /// Sample from the softmax of the `k` highest logits at the given
    /// temperature (k is clamped to the vocabulary; temperature to a
    /// small positive floor).
    TopK { k: usize, temperature: f32 },
}

/// Generation request knobs.
#[derive(Clone, Debug)]
pub struct GenConfig {
    /// Maximum number of new tokens to emit.
    pub max_new: usize,
    pub sampling: Sampling,
    /// PRNG seed for `TopK` (ignored by `Greedy`). Same seed + same
    /// model ⇒ same output, regardless of thread or batching.
    pub seed: u64,
    /// Emitting any of these tokens ends the generation (the stop token
    /// is included in the output).
    pub stop: Vec<i32>,
    /// KV-cache capacity; 0 sizes it to `prompt.len() + max_new`, which
    /// keeps incremental decode exact (no ring eviction).
    pub cap: usize,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            max_new: 16,
            sampling: Sampling::Greedy,
            seed: 0,
            stop: Vec::new(),
            cap: 0,
        }
    }
}

/// Why a generation ended.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StopReason {
    MaxNew,
    StopToken(i32),
}

/// Per-request timing/throughput counters.
#[derive(Clone, Debug)]
pub struct GenStats {
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    /// Wall time consuming the prompt (cache build-up).
    pub prefill_s: f64,
    /// Wall time of the new-token decode loop.
    pub decode_s: f64,
}

impl GenStats {
    pub fn total_s(&self) -> f64 {
        self.prefill_s + self.decode_s
    }

    /// New tokens per second over the decode loop.
    pub fn decode_tok_per_s(&self) -> f64 {
        if self.decode_s > 0.0 {
            self.gen_tokens as f64 / self.decode_s
        } else {
            0.0
        }
    }
}

/// One finished generation.
#[derive(Clone, Debug)]
pub struct Generation {
    /// The newly generated tokens (prompt not included).
    pub tokens: Vec<i32>,
    pub stats: GenStats,
    pub stopped: StopReason,
}

/// Pick the next token from a logits row.
pub fn sample(logits: &[f32], sampling: &Sampling, rng: &mut Rng) -> i32 {
    match *sampling {
        Sampling::Greedy => argmax(logits),
        Sampling::TopK { k, temperature } => {
            let k = k.clamp(1, logits.len());
            if k == 1 {
                return argmax(logits);
            }
            let temp = temperature.max(1e-6);
            // Indices of the k largest logits (desc by logit, ties asc by
            // id — a total order, so the selection is deterministic).
            // O(V) partition first; only the k winners get sorted.
            let cmp = |a: &usize, b: &usize| {
                logits[*b]
                    .partial_cmp(&logits[*a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(b))
            };
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            if k < idx.len() {
                idx.select_nth_unstable_by(k - 1, cmp);
                idx.truncate(k);
            }
            idx.sort_unstable_by(cmp);
            let mx = logits[idx[0]];
            let ws: Vec<f64> = idx
                .iter()
                .map(|&i| (((logits[i] - mx) / temp) as f64).exp())
                .collect();
            let total: f64 = ws.iter().sum();
            let mut r = rng.f64() * total;
            for (&i, w) in idx.iter().zip(&ws) {
                r -= w;
                if r <= 0.0 {
                    return i as i32;
                }
            }
            idx[k - 1] as i32 // fp slack: fall back to the least likely
        }
    }
}

fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// Generate up to `gc.max_new` tokens after `prompt` through any
/// executor's KV-cached decode path. The prompt is prefetched token by
/// token into a fresh cache (same per-token cost as cached decode), then
/// the decode loop samples and feeds back until a stop condition.
pub fn generate(exec: &dyn Executor, entry: &ModelEntry, model: ModelRef,
                prompt: &[i32], gc: &GenConfig) -> Result<Generation> {
    ensure!(!prompt.is_empty(), "generate: empty prompt");
    let cfg = &entry.config;
    let cap = if gc.cap > 0 {
        gc.cap
    } else {
        prompt.len() + gc.max_new
    };
    let mut cache = KvCache::for_model(cfg, cap);
    let mut rng = Rng::new(gc.seed);

    let t0 = Instant::now();
    let mut last = model.decode_step(exec, entry, &mut cache, prompt[0])?;
    for &t in &prompt[1..] {
        last = model.decode_step(exec, entry, &mut cache, t)?;
    }
    let prefill_s = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let mut tokens = Vec::with_capacity(gc.max_new);
    let mut stopped = StopReason::MaxNew;
    while tokens.len() < gc.max_new {
        let next = sample(last.data(), &gc.sampling, &mut rng);
        tokens.push(next);
        if gc.stop.contains(&next) {
            stopped = StopReason::StopToken(next);
            break;
        }
        if tokens.len() == gc.max_new {
            break; // final logits would be unused
        }
        last = model.decode_step(exec, entry, &mut cache, next)?;
    }
    let decode_s = t1.elapsed().as_secs_f64();

    Ok(Generation {
        stats: GenStats {
            prompt_tokens: prompt.len(),
            gen_tokens: tokens.len(),
            prefill_s,
            decode_s,
        },
        tokens,
        stopped,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_ties_pick_lowest_id() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn top1_equals_greedy() {
        let logits = vec![0.1f32, 2.0, -0.5, 1.9];
        let mut rng = Rng::new(9);
        for _ in 0..10 {
            let s = Sampling::TopK { k: 1, temperature: 1.0 };
            assert_eq!(sample(&logits, &s, &mut rng), 1);
        }
    }

    #[test]
    fn topk_only_emits_topk_tokens() {
        let logits = vec![5.0f32, 4.0, -10.0, 3.0, -20.0];
        let mut rng = Rng::new(11);
        let s = Sampling::TopK { k: 3, temperature: 1.0 };
        for _ in 0..200 {
            let t = sample(&logits, &s, &mut rng);
            assert!(matches!(t, 0 | 1 | 3), "sampled non-top-k token {t}");
        }
    }

    #[test]
    fn low_temperature_concentrates_on_argmax() {
        let logits = vec![1.0f32, 1.5, 0.5, 1.4];
        let mut rng = Rng::new(13);
        let s = Sampling::TopK { k: 4, temperature: 1e-4 };
        for _ in 0..50 {
            assert_eq!(sample(&logits, &s, &mut rng), 1);
        }
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let logits = vec![0.3f32, 0.1, 0.2, 0.35, 0.05];
        let s = Sampling::TopK { k: 4, temperature: 0.8 };
        let seq = |seed: u64| -> Vec<i32> {
            let mut rng = Rng::new(seed);
            (0..32).map(|_| sample(&logits, &s, &mut rng)).collect()
        };
        assert_eq!(seq(42), seq(42));
        // Different seeds should (for this spread) disagree somewhere.
        assert_ne!(seq(42), seq(43));
    }
}
