//! Autoregressive generation over the batched KV-cached decode path:
//! `BatchEngine` is a step-driven continuous-batching scheduler — each
//! step admits pending requests into free cache-pool slots, pushes one
//! PAGE_SIZE-aligned chunk of every still-prefilling prompt through
//! `Executor::prefill_chunk` (whole windows per step, not one token),
//! feeds every decoding sequence one token through
//! `Executor::decode_batch`, samples per slot (greedy or seeded
//! temperature/top-k via `util::rng`, fully deterministic per request
//! seed), and retires finished sequences without stalling the rest.
//! Admission is prefix-aware over the paged pool: a prompt sharing a
//! tokenized prefix with a resident sequence references that sequence's
//! pages copy-on-write and chunk-prefills only the tail. `generate` is
//! the B=1 case; `generate_batch` runs a whole request set through one
//! engine. Executor- and variant-generic: a `ModelRef` dispatches to
//! the dense or fused-packed path, so the same engine generates from
//! FP32 weights and from packed 2/4-bit `QuantizedModel`s.
//!
//! Streaming + cancellation: the per-request tag doubles as a
//! `GenSink` — every committed token (decode, chunk completion, or
//! spec verify-accept) is emitted as a `GenEvent::Token` through ONE
//! code path (`Active::consume_row`), so a streamed token sequence is
//! bit-identical to the batch result. A sink that reports its receiver
//! gone (failed `emit` or `is_connected() == false`) cancels the
//! request: the engine retires its target and drafter slots through
//! the normal refcount-correct paths at the end of the current step
//! and traces a rid-stamped `Ev::Cancel` — a dead client never holds
//! a decode slot past the step that notices it.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{ensure, Result};

use super::cache::PAGE_SIZE;
use super::{Executor, KvCachePool, ModelRef};
use crate::model::ModelConfig;
use crate::runtime::ModelEntry;
use crate::telemetry::trace::{Ev, StepTracer, TraceEvent};
use crate::util::rng::Rng;

/// Next-token selection rule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sampling {
    /// Argmax (ties → lowest token id). Deterministic, ignores the seed.
    Greedy,
    /// Sample from the softmax of the `k` highest logits at the given
    /// temperature (k is clamped to the vocabulary; temperature to a
    /// small positive floor).
    TopK { k: usize, temperature: f32 },
}

/// Speculative-decode opt-in: per engine step, a cheaper drafter
/// variant proposes `k` tokens which the target then scores in ONE
/// multi-row verify pass (`Executor::verify_chunk`), committing the
/// longest agreeing prefix plus the bonus token from the last accepted
/// row. Greedy-only: under argmax acceptance the committed tokens are
/// bit-identical to target-only decode (verify rows ARE the per-token
/// decode logits), so speculation changes target-pass count, never
/// output. Requests opt in via `GenConfig::spec`; the engine also
/// needs a drafter (`BatchEngine::step_spec` / `run_spec`), otherwise
/// the request decodes plain.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpecDecode {
    /// Draft tokens proposed per verify step (≥ 1). Each verify costs
    /// one multi-row target pass over `k + 1` positions and commits
    /// between 1 and `k + 1` tokens.
    pub k: usize,
}

/// Generation request knobs.
#[derive(Clone, Debug)]
pub struct GenConfig {
    /// Maximum number of new tokens to emit.
    pub max_new: usize,
    pub sampling: Sampling,
    /// PRNG seed for `TopK` (ignored by `Greedy`). Same seed + same
    /// model ⇒ same output, regardless of thread or batching.
    pub seed: u64,
    /// Emitting any of these tokens ends the generation (the stop token
    /// is included in the output).
    pub stop: Vec<i32>,
    /// KV-cache capacity; 0 sizes it to `prompt.len() + max_new`, which
    /// keeps incremental decode exact (no ring eviction).
    pub cap: usize,
    /// Speculative decoding (greedy-only; rejected with other
    /// sampling). `None` decodes one token per target pass.
    pub spec: Option<SpecDecode>,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            max_new: 16,
            sampling: Sampling::Greedy,
            seed: 0,
            stop: Vec::new(),
            cap: 0,
            spec: None,
        }
    }
}

/// Why a generation ended.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StopReason {
    MaxNew,
    StopToken(i32),
}

/// Per-request timing/throughput counters, recorded as INTEGER
/// nanoseconds (`Instant::elapsed().as_nanos()`) — the same unit the
/// telemetry histograms bucket (`serve.gen.*_ns`), so a server
/// histogram quantile and a per-request `GenStats` value never disagree
/// through a float round trip. Use the `*_s()` views for display.
///
/// `prefill_ns` is the request's OWN prefill cost: each chunked-prefill
/// call serves exactly one request, so summing those spans excludes
/// co-batched decode work and scheduler waiting. Prompt tokens that
/// cost the request nothing attributable contribute nothing: tokens
/// admitted by shared-prefix page reference, and a lone final prompt
/// token that rides the shared decode batch (so a 1-token prompt, or a
/// sharer whose whole tail is one token, reports `prefill_ns == 0`).
/// `ttft_ns` and `decode_ns` are wall-clock spans of the request's life
/// inside its engine: in a B=1 engine (`generate`) they are dedicated
/// per-request cost; in a shared continuous batch (`generate_batch`,
/// the server scheduler) they include co-batched sequences' work and
/// anything else the serve loop interleaves — observed latency, not
/// isolated decode cost. Aggregate throughput across a batch is what
/// improves.
#[derive(Clone, Debug)]
pub struct GenStats {
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    /// Nanoseconds spent in this request's own prefill chunks (cache
    /// build-up work actually spent on this prompt; see struct docs).
    pub prefill_ns: u64,
    /// Time-to-first-token: nanoseconds from SUBMISSION to the engine
    /// to the first sampled token (prefill end when `max_new == 0`) —
    /// queueing for a slot, deferral for a prefix donor, and co-batched
    /// steps all included; this is the latency a caller observes before
    /// output starts. (The server submits when its serve loop drains
    /// the queue, so bounded-queue wait upstream of the scheduler adds
    /// on top.)
    pub ttft_ns: u64,
    /// Nanoseconds in the new-token decode loop (prefill end →
    /// retirement).
    pub decode_ns: u64,
}

impl GenStats {
    /// Seconds view of `prefill_ns`.
    pub fn prefill_s(&self) -> f64 {
        self.prefill_ns as f64 / 1e9
    }

    /// Seconds view of `ttft_ns`.
    pub fn ttft_s(&self) -> f64 {
        self.ttft_ns as f64 / 1e9
    }

    /// Seconds view of `decode_ns`.
    pub fn decode_s(&self) -> f64 {
        self.decode_ns as f64 / 1e9
    }

    /// Observed request latency: submission → retirement.
    pub fn total_ns(&self) -> u64 {
        self.ttft_ns + self.decode_ns
    }

    /// Seconds view of `total_ns`.
    pub fn total_s(&self) -> f64 {
        self.total_ns() as f64 / 1e9
    }

    /// New tokens per second over the decode loop.
    pub fn decode_tok_per_s(&self) -> f64 {
        if self.decode_ns > 0 {
            self.gen_tokens as f64 * 1e9 / self.decode_ns as f64
        } else {
            0.0
        }
    }
}

/// One finished generation.
#[derive(Clone, Debug)]
pub struct Generation {
    /// The newly generated tokens (prompt not included).
    pub tokens: Vec<i32>,
    pub stats: GenStats,
    pub stopped: StopReason,
}

/// One event on a request's stream. Every committed token — from the
/// plain decode batch, a chunk-completion sample, or a `step_spec`
/// verify-accept — flows through `Active::consume_row`, the single
/// emission point, so the streamed token sequence is bit-identical to
/// the `Generation::tokens` the batch path returns (pinned by
/// `rust/tests/generate.rs`). Speculative rollback never retracts an
/// event: `consume_row` only runs for rows the engine commits; rejected
/// draft rows are discarded before sampling.
#[derive(Clone, Debug)]
pub enum GenEvent {
    /// One committed token; `pos` is its index among the NEW tokens
    /// (`Generation::tokens[pos] == token`).
    Token { token: i32, pos: usize },
    /// Terminal: the finished generation (same value the batch API
    /// returns for this request).
    Done(Generation),
    /// Terminal: the request failed (bad prompt, fatal engine error).
    Failed(String),
}

/// Per-request event sink, implemented by the engine's tag type. The
/// defaults make any tag a no-op sink (`generate_batch`'s `usize`
/// index, test labels), so only streaming callers — the server's
/// `GenStream` — pay for delivery.
///
/// The two methods are the whole cancel-on-disconnect contract:
/// `emit` returning `false` (delivery failed: receiver gone) and
/// `is_connected` returning `false` (liveness probe — catches a
/// receiver dropped while the request is prefilling or pending, when
/// no tokens flow) both mark the request cancelled. The engine then
/// retires it at the END of the current step through the same
/// refcount-correct `retire`/`truncate` paths as normal completion —
/// target slot and drafter slot both — emitting a rid-stamped
/// `Ev::Cancel` instead of building a `Generation`.
pub trait GenSink {
    /// Deliver one event. `false` means the receiver is gone; the
    /// engine treats the request as cancelled.
    fn emit(&self, ev: GenEvent) -> bool {
        let _ = ev;
        true
    }

    /// Cheap liveness probe, polled once per request per step.
    fn is_connected(&self) -> bool {
        true
    }
}

/// Index tags (`generate_batch`, benches) don't stream.
impl GenSink for usize {}
/// Label tags (tests) don't stream.
impl GenSink for &str {}
impl GenSink for () {}

/// Cumulative speculative-decode counters for one engine. The accept
/// rate is `accepted / drafted`; the latency multiplier speculation
/// buys is `emitted / verify_steps` — tokens committed per multi-row
/// target pass, versus exactly 1 for plain decode (an identical
/// drafter makes it `k + 1`; a fully adversarial one, 1).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SpecCounters {
    /// Draft tokens proposed (k per verify pass).
    pub drafted: u64,
    /// Drafts committed by exact greedy agreement with the target.
    pub accepted: u64,
    /// Multi-row verify passes run.
    pub verify_steps: u64,
    /// Tokens committed by verify rows: accepted drafts plus each
    /// pass's bonus token from its last consumed row.
    pub emitted: u64,
}

impl SpecCounters {
    /// Tokens committed per target verify pass (the speculative
    /// speedup measure; 0 when no verify has run).
    pub fn tokens_per_verify(&self) -> f64 {
        if self.verify_steps > 0 {
            self.emitted as f64 / self.verify_steps as f64
        } else {
            0.0
        }
    }

    /// Fraction of drafts the target agreed with (0 when none).
    pub fn accept_rate(&self) -> f64 {
        if self.drafted > 0 {
            self.accepted as f64 / self.drafted as f64
        } else {
            0.0
        }
    }
}

/// Pick the next token from a logits row.
pub fn sample(logits: &[f32], sampling: &Sampling, rng: &mut Rng) -> i32 {
    match *sampling {
        Sampling::Greedy => argmax(logits),
        Sampling::TopK { k, temperature } => {
            let k = k.clamp(1, logits.len());
            if k == 1 {
                return argmax(logits);
            }
            let temp = temperature.max(1e-6);
            // Indices of the k largest logits (desc by logit, ties asc by
            // id — a total order, so the selection is deterministic).
            // O(V) partition first; only the k winners get sorted.
            let cmp = |a: &usize, b: &usize| {
                logits[*b]
                    .partial_cmp(&logits[*a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(b))
            };
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            if k < idx.len() {
                idx.select_nth_unstable_by(k - 1, cmp);
                idx.truncate(k);
            }
            idx.sort_unstable_by(cmp);
            let mx = logits[idx[0]];
            let ws: Vec<f64> = idx
                .iter()
                .map(|&i| (((logits[i] - mx) / temp) as f64).exp())
                .collect();
            let total: f64 = ws.iter().sum();
            let mut r = rng.f64() * total;
            for (&i, w) in idx.iter().zip(&ws) {
                r -= w;
                if r <= 0.0 {
                    return i as i32;
                }
            }
            idx[k - 1] as i32 // fp slack: fall back to the least likely
        }
    }
}

fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// Most prompt positions one engine step prefills per sequence — the
/// chunk-size trade: a bigger chunk amortizes each weight read (and, on
/// the packed path, each dequant) over more prompt rows and finishes
/// prefill in fewer steps, but a step's in-flight decoders wait for the
/// whole chunk, so it bounds the per-step latency a long prompt can
/// impose on co-batched decode traffic. Two pages keeps the chunk GEMMs
/// comfortably multi-row while a step stays a small multiple of a
/// decode step.
pub const PREFILL_CHUNK: usize = 2 * PAGE_SIZE;

/// Length of the next prefill chunk for a slot whose next position is
/// `pos` with `remaining` prompt tokens left. Chunks end on
/// PAGE_SIZE-aligned absolute positions (so bulk appends fill whole
/// pages and a misaligned shared-tail start realigns after one chunk),
/// are capped at `PREFILL_CHUNK`, and never exceed the ring capacity —
/// an overlong prompt prefills through the evicting regime chunk by
/// chunk. The final chunk takes whatever remains.
fn chunk_len(pos: usize, remaining: usize, cap: usize) -> usize {
    debug_assert!(remaining > 0);
    let max = PREFILL_CHUNK.min(cap).max(1);
    if remaining <= max {
        return remaining;
    }
    let to_boundary = PAGE_SIZE - pos % PAGE_SIZE;
    if max < to_boundary {
        max
    } else {
        to_boundary + (max - to_boundary) / PAGE_SIZE * PAGE_SIZE
    }
}

/// A request queued in a `BatchEngine`, waiting for a free cache slot.
struct Pending<T> {
    tag: T,
    /// Engine-local request id: monotone from 0 in submit order — the
    /// identity trace events carry (`StepTracer::timeline`).
    rid: u64,
    prompt: Vec<i32>,
    gc: GenConfig,
    /// When the request entered the engine — time-to-first-token counts
    /// from here, so slot queueing and prefix-donor deferral are part
    /// of the reported latency.
    t_submit: Instant,
}

/// Token at index `i` of a request's consumed stream: prompt tokens
/// first, then the fed-back samples.
fn stream_token(prompt: &[i32], tokens: &[i32], i: usize) -> i32 {
    if i < prompt.len() {
        prompt[i]
    } else {
        tokens[i - prompt.len()]
    }
}

/// Longest shared prefix between `prompt` and a donor's committed
/// stream (its prompt plus already-sampled tokens), capped at `limit`.
fn common_prefix(prompt: &[i32], d_prompt: &[i32], d_tokens: &[i32],
                 limit: usize) -> usize {
    let committed = d_prompt.len() + d_tokens.len();
    let mut n = 0;
    while n < limit.min(prompt.len()).min(committed) {
        if stream_token(d_prompt, d_tokens, n) != prompt[n] {
            break;
        }
        n += 1;
    }
    n
}

/// Per-sequence speculative-decode state.
#[derive(Clone, Copy, Debug, PartialEq)]
enum SpecSlot {
    /// Spec requested but no drafter KV slot yet: engages lazily on
    /// the first step that has a drafter AND the sequence past its
    /// prompt (a drafter deployed mid-generation via server swap
    /// picks existing requests up here).
    Pending,
    /// Drafting. `dslot` is this sequence's slot in the engine's
    /// drafter pool; `dfed` is the number of stream tokens the
    /// drafter has consumed (its cache position) — at most one behind
    /// the target between steps, further behind only while a freshly
    /// engaged drafter replays the committed stream in catch-up
    /// chunks.
    On { dslot: usize, dfed: usize },
    /// Permanently plain: spec never requested, or the ring can no
    /// longer hold a verify window (`fed + k + 1 > cap`) — the
    /// eviction regime recycles rows in place, where rollback is
    /// impossible, so the sequence falls back to one-token decode.
    Off,
}

/// One admitted sequence: its slot, sampling state, and timings.
struct Active<T> {
    tag: T,
    /// Carried from `Pending`: trace identity.
    rid: u64,
    slot: usize,
    prompt: Vec<i32>,
    gc: GenConfig,
    rng: Rng,
    /// Speculative-decode state (`SpecSlot::Off` when not requested).
    spec: SpecSlot,
    /// Tokens the model has consumed so far (prompt, then fed-back
    /// samples) — always equal to the slot's cache position. While
    /// `fed < prompt.len()` the sequence is prefilling (in chunks);
    /// after that, the token fed at step `fed` is
    /// `tokens[fed - prompt.len()]`.
    fed: usize,
    /// Sampled new tokens (the generation output).
    tokens: Vec<i32>,
    /// Carried from `Pending`: when the request entered the engine.
    t_submit: Instant,
    t_prefill_done: Option<Instant>,
    /// Nanoseconds spent in THIS request's own prefill chunks.
    prefill_work_ns: u64,
    /// Submission → first sampled token, nanoseconds (set when prefill
    /// completes).
    ttft_ns: u64,
    /// Stop decision made during the current step; the sequence retires
    /// at the end of the step.
    finished: Option<StopReason>,
    /// Receiver gone (failed `GenSink::emit` or a false
    /// `is_connected` probe): the sequence does no further work and
    /// retires at the end of the step WITHOUT building a `Generation`
    /// (its `t_prefill_done` may never have been stamped).
    cancelled: bool,
}

impl<T: GenSink> Active<T> {
    /// Consume one logits row for this sequence: sample the next token,
    /// emit it on the request's stream, record any stop condition, and
    /// — when `first` marks the step that consumed the last prompt
    /// token (from a chunk's final row or a decode-batch rider row
    /// alike) — stamp prefill-done and TTFT. `max_new == 0` on that
    /// step means there is nothing to sample: the prefill itself was
    /// the request. ONE body for the chunk-completion, decode, and
    /// verify-accept paths, so stop/TTFT/streaming semantics cannot
    /// drift between them.
    fn consume_row(&mut self, row: &[f32], first: bool) {
        if first {
            self.t_prefill_done = Some(Instant::now());
        }
        if first && self.gc.max_new == 0 {
            self.finished = Some(StopReason::MaxNew);
        } else {
            let next = sample(row, &self.gc.sampling, &mut self.rng);
            self.tokens.push(next);
            if !self.tag.emit(GenEvent::Token {
                token: next,
                pos: self.tokens.len() - 1,
            }) {
                self.cancelled = true;
            }
            if self.gc.stop.contains(&next) {
                self.finished = Some(StopReason::StopToken(next));
            } else if self.tokens.len() >= self.gc.max_new {
                self.finished = Some(StopReason::MaxNew);
            }
        }
        if first {
            self.ttft_ns = self.t_submit.elapsed().as_nanos() as u64;
        }
    }
}

/// Step-driven continuous-batching generation engine over one
/// `Executor::decode_batch` stream. Submit any number of requests; each
/// `step` admits pending requests into free slots, prefills ONE
/// PAGE_SIZE-aligned chunk for every sequence with a multi-token prompt
/// window left (`Executor::prefill_chunk` — whole windows per step, the
/// time-to-first-token lever for long prompts), feeds everything else —
/// decoders and lone final prompt tokens — one token in a single
/// batched `decode_batch` call, samples per slot with that request's
/// own seeded RNG, and retires finished sequences (freeing their slots
/// for the next admission) without stalling the rest.
///
/// Determinism: a request's trajectory depends only on the model and its
/// own `GenConfig` — batched decode rows are bit-identical to
/// single-sequence `decode_step` and each request samples from its own
/// `Rng::new(seed)` — so outputs are independent of what else shares the
/// batch, of admission timing, and of slot placement. The serving
/// scheduler (`coordinator::server`) relies on this to keep batched
/// serving reproducible.
///
/// Prefix sharing preserves this: when a prompt admits by referencing a
/// resident sequence's prefix pages (`KvCachePool::admit_shared`), the
/// referenced K/V rows were produced by the SAME deterministic decode
/// for the SAME tokens at the SAME absolute positions under an unwrapped
/// ring, so they are bit-identical to what the request's own prefill
/// would have appended — sharing changes memory and prefill work, never
/// tokens (pinned by `rust/tests/batch_decode.rs` shared-prefix tests).
///
/// `T` is an opaque per-request tag returned with the finished
/// `Generation` (an index for `generate_batch`, a reply channel for the
/// server).
pub struct BatchEngine<T> {
    cfg: ModelConfig,
    pool: KvCachePool,
    /// KV pool for the drafter variant, created lazily on the first
    /// speculative step (an engine that never specs allocates
    /// nothing). Slot-for-slot paired with spec sequences: a
    /// sequence's `SpecSlot::On { dslot }` lives here with the same
    /// ring capacity as its target slot, so the drafter pool can
    /// always mirror every admitted sequence.
    drafter_pool: Option<KvCachePool>,
    /// Per-layer storage widths for the lazily-built drafter pool.
    /// `None` keeps the drafter's KV at f32: greedy-exact acceptance
    /// never depends on drafter precision, but the identical-drafter
    /// acceptance-ceiling guarantee does, so narrow drafter KV is
    /// opt-in (`set_drafter_kv_bits`).
    drafter_kv_bits: Option<Vec<u8>>,
    /// Cumulative speculative-decode counters (drafted / accepted /
    /// verify passes / tokens emitted by verify rows).
    spec_counters: SpecCounters,
    pending: VecDeque<Pending<T>>,
    active: Vec<Active<T>>,
    shared_tokens: u64,
    /// Requests cancelled on disconnect (pending or in flight),
    /// cumulative over the engine's life.
    cancelled_total: u64,
    /// Opt-in flight recorder (`enable_trace`). `None` costs one branch
    /// per emission site and allocates nothing; enabled or not, the
    /// tracer only observes — tokens stay bit-identical (pinned by
    /// `rust/tests/batch_decode.rs`).
    tracer: Option<StepTracer>,
    /// Steps executed (trace events stamp with this).
    steps: u64,
    /// Next request id handed out by `submit`.
    next_rid: u64,
}

impl<T> BatchEngine<T> {
    /// An engine decoding up to `slots` concurrent sequences of `cfg`'s
    /// geometry.
    pub fn new(cfg: &ModelConfig, slots: usize) -> Self {
        Self::with_kv_bits(cfg, slots, None)
    }

    /// An engine whose target pool stores each layer's K/V at the given
    /// width (4/8/16 bits per element, `None` = all-f32). The plan
    /// usually comes from `allocate::allocate_kv_bits` over NSDS layer
    /// scores; all-16 is bit-identical to `new`.
    pub fn with_kv_bits(cfg: &ModelConfig, slots: usize,
                        kv_bits: Option<Vec<u8>>) -> Self {
        assert!(slots > 0, "BatchEngine needs at least one slot");
        let pool = match &kv_bits {
            Some(bits) => {
                KvCachePool::for_model_with_bits(cfg, slots, bits)
            }
            None => KvCachePool::for_model(cfg, slots),
        };
        BatchEngine {
            cfg: cfg.clone(),
            pool,
            drafter_pool: None,
            drafter_kv_bits: None,
            spec_counters: SpecCounters::default(),
            pending: VecDeque::new(),
            active: Vec::new(),
            shared_tokens: 0,
            cancelled_total: 0,
            tracer: None,
            steps: 0,
            next_rid: 0,
        }
    }

    /// Store the drafter pool's K/V at these per-layer widths (e.g.
    /// all-4-bit: draft tokens are disposable guesses, verified exactly
    /// against the target, so narrow drafter KV trades only acceptance
    /// rate — never output tokens — for memory). Must be called before
    /// the first speculative step; the drafter pool is built lazily and
    /// its precision is fixed at that point.
    pub fn set_drafter_kv_bits(&mut self, kv_bits: Option<Vec<u8>>) {
        assert!(
            self.drafter_pool.is_none(),
            "drafter pool already built; set drafter kv_bits before \
             the first speculative step"
        );
        self.drafter_kv_bits = kv_bits;
    }

    /// Start recording step events into a fresh ring of `capacity`
    /// events (all storage allocated here, none on the hot path).
    /// Replaces any previous tracer.
    pub fn enable_trace(&mut self, capacity: usize) {
        self.tracer = Some(StepTracer::new(capacity));
    }

    /// Stop tracing, returning the recorder for inspection.
    pub fn disable_trace(&mut self) -> Option<StepTracer> {
        self.tracer.take()
    }

    /// The flight recorder, when tracing is enabled.
    pub fn tracer(&self) -> Option<&StepTracer> {
        self.tracer.as_ref()
    }

    /// Steps executed so far (idle no-op calls don't count).
    pub fn steps(&self) -> u64 {
        self.steps
    }

    #[inline]
    fn trace(&mut self, step: u64, ev: Ev) {
        if let Some(t) = self.tracer.as_mut() {
            t.push(TraceEvent { step, ev });
        }
    }

    /// The engine's paged cache pool (read-only: page/sharing state for
    /// stats and tests).
    pub fn pool(&self) -> &KvCachePool {
        &self.pool
    }

    /// Prompt tokens admitted by shared-prefix page reference instead
    /// of prefill, cumulative over the engine's life.
    pub fn shared_prefix_tokens(&self) -> u64 {
        self.shared_tokens
    }

    /// Cumulative speculative-decode counters (zero if no request ever
    /// ran a verify pass).
    pub fn spec_counters(&self) -> SpecCounters {
        self.spec_counters
    }

    /// Requests cancelled because their receiver disconnected (failed
    /// `GenSink::emit` or a false `is_connected` probe), cumulative
    /// over the engine's life. Counts pending and in-flight requests
    /// alike; none of them produce a `Generation`.
    pub fn cancelled_total(&self) -> u64 {
        self.cancelled_total
    }

    /// The drafter's paged cache pool, if any speculative step has run
    /// (read-only: page accounting for stats and tests).
    pub fn drafter_pool(&self) -> Option<&KvCachePool> {
        self.drafter_pool.as_ref()
    }

    /// Validate a prompt without submitting it (the server routes a bad
    /// prompt's error to its reply channel instead of poisoning the
    /// shared batch).
    pub fn check(&self, prompt: &[i32]) -> Result<()> {
        ensure!(!prompt.is_empty(), "generate: empty prompt");
        let v = self.cfg.vocab;
        ensure!(prompt.iter().all(|&t| t >= 0 && (t as usize) < v),
                "generate: prompt token out of range (vocab {v})");
        Ok(())
    }

    /// Queue a request. It is admitted into a cache slot by a later
    /// `step` as capacity frees up. On a rejected prompt the tag comes
    /// back with the error, so the server can fail that request's reply
    /// channel rather than silently dropping it. Accepted requests get
    /// the engine's next request id (monotone from 0 in submit order) —
    /// the identity trace timelines are keyed by.
    pub fn submit(&mut self, tag: T, prompt: Vec<i32>, gc: GenConfig)
        -> Result<(), (T, anyhow::Error)> {
        if let Err(e) = self.check(&prompt) {
            return Err((tag, e));
        }
        // Speculative decoding is greedy-only: acceptance is exact
        // because argmax over bit-identical verify rows IS the decode
        // the target would have run. Sampled (rejection-sampling)
        // acceptance is a follow-up flag, not silently approximated.
        if let Some(SpecDecode { k }) = gc.spec {
            if k == 0 {
                return Err((tag, anyhow::anyhow!(
                    "generate: spec.k must be at least 1")));
            }
            if gc.sampling != Sampling::Greedy {
                return Err((tag, anyhow::anyhow!(
                    "generate: speculative decoding requires greedy \
                     sampling (exact acceptance); sampled acceptance \
                     is not implemented")));
            }
        }
        let rid = self.next_rid;
        self.next_rid += 1;
        self.pending.push_back(Pending {
            tag,
            rid,
            prompt,
            gc,
            t_submit: Instant::now(),
        });
        Ok(())
    }

    /// No requests pending or in flight.
    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.active.is_empty()
    }

    /// Requests submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.pending.len() + self.active.len()
    }

    pub fn slots(&self) -> usize {
        self.pool.max_slots()
    }

    /// One engine step: admit pending requests, push one prefill chunk
    /// per still-prefilling sequence, batch-decode one token per
    /// decoding sequence, sample, retire. Returns the requests that
    /// finished this step (possibly empty). A no-op returning `[]` when
    /// idle. Requests that opted into speculative decoding run plain
    /// here (no drafter) — use `step_spec` to supply one.
    pub fn step(&mut self, exec: &dyn Executor, entry: &ModelEntry,
                model: ModelRef) -> Result<Vec<(T, Generation)>>
    where
        T: GenSink,
    {
        self.step_spec(exec, entry, model, None)
    }

    /// `step` with an optional drafter variant. Sequences that opted
    /// in (`GenConfig::spec`), are past their prompt, and whose ring
    /// still fits a verify window run SPECULATIVELY this step: the
    /// drafter proposes k tokens (one batched drafter decode per
    /// depth, shared across all spec sequences), the target scores
    /// the already-sampled next token plus all k drafts in ONE
    /// multi-row `verify_chunk` pass, the longest agreeing prefix
    /// (plus the bonus token of the last accepted row) commits
    /// through the same `consume_row` path as plain decode, and both
    /// pools roll back to the committed position with `truncate`.
    /// Everything else — prefilling sequences, non-spec requests,
    /// spec sequences whose drafter is still catching up or whose
    /// ring entered the eviction regime — takes the plain path in the
    /// same step. Greedy acceptance is EXACT: verify rows are pinned
    /// bit-identical to per-token decode, so committed tokens match
    /// target-only decode bit for bit (pinned by
    /// `rust/tests/spec_decode.rs`); with `drafter == None` this is
    /// `step` verbatim.
    pub fn step_spec(&mut self, exec: &dyn Executor, entry: &ModelEntry,
                     target: ModelRef, drafter: Option<ModelRef>)
                     -> Result<Vec<(T, Generation)>>
    where
        T: GenSink,
    {
        let step_no = self.steps;
        // Cancel-on-disconnect sweep, once per step: a pending request
        // whose receiver is gone drops here (it holds no slot); an
        // in-flight one is marked and does NO work this step — no spec
        // engagement, no prefill chunk, no decode row — then retires in
        // the retire phase below, freeing its target (and drafter) slot
        // through the same refcount-correct paths as completion. A
        // receiver that vanishes mid-step instead fails a token `emit`,
        // which sets the same flag (see `consume_row`); either way the
        // slot is free for admission by the NEXT step.
        let mut gone: Vec<u64> = Vec::new();
        self.pending.retain(|p| {
            if p.tag.is_connected() {
                true
            } else {
                gone.push(p.rid);
                false
            }
        });
        for rid in gone {
            self.cancelled_total += 1;
            self.trace(step_no, Ev::Cancel { rid, slot: None });
        }
        for a in &mut self.active {
            if !a.cancelled && !a.tag.is_connected() {
                a.cancelled = true;
            }
        }
        // Admit pending requests into free slots. Per-request cache
        // capacity mirrors the single-sequence policy: `gc.cap`, or
        // prompt + max_new (exact decode, no ring eviction) when 0.
        //
        // Admission is prefix-aware: a prompt sharing a tokenized
        // prefix with a resident sequence admits by referencing that
        // sequence's pages (`admit_shared`, copy-on-write) and starts
        // prefilling at the first un-shared position. When a resident
        // donor has committed (prompt + sampled) a common prefix of at
        // least one full page that it has not finished APPENDING yet,
        // the request is DEFERRED (kept pending, in order): the donor
        // appends a whole chunk per step while prefilling (one position
        // per step once decoding), so a step or two of waiting turns
        // the whole prefix into referenced pages instead of re-prefill
        // — and the deferred sharer's own un-shared tail then admits as
        // one chunked prefill instead of per-step tokens.
        // Progress is guaranteed — the appended prefix grows every step
        // until it covers the committed one, and a retired donor simply
        // drops out of consideration next step. Sub-page overlaps never
        // defer (they admit at once, sharing whatever is resident).
        // Sharing never changes outputs: shared rows are bit-identical
        // to what the request's own prefill would append (see the
        // determinism note below).
        let cow0 = self.pool.cow_splits();
        let mut deferred: Vec<Pending<T>> = Vec::new();
        while self.pool.free_count() > 0 {
            let Some(p) = self.pending.pop_front() else { break };
            let cap = if p.gc.cap > 0 {
                p.gc.cap
            } else {
                p.prompt.len() + p.gc.max_new
            }
            .max(1);
            // Shareable length: leave at least the last prompt token to
            // feed (its logits seed sampling) and fit the new ring.
            let limit = (p.prompt.len() - 1).min(cap);
            let mut best: Option<(usize, usize)> = None; // (slot, now)
            let mut best_later = 0usize;
            for a in &self.active {
                // A wrapped donor has evicted its own prefix.
                if self.pool.pos(a.slot) > self.pool.capacity(a.slot) {
                    continue;
                }
                let committed = common_prefix(
                    &p.prompt, &a.prompt, &a.tokens,
                    limit.min(self.pool.capacity(a.slot)));
                let now = committed.min(a.fed);
                best_later = best_later.max(committed);
                if now > best.map_or(0, |(_, s)| s) {
                    best = Some((a.slot, now));
                }
            }
            let now = best.map_or(0, |(_, s)| s);
            if best_later >= PAGE_SIZE && best_later > now {
                let rid = p.rid;
                deferred.push(p);
                self.trace(step_no, Ev::Defer {
                    rid,
                    committed: best_later,
                });
                continue;
            }
            let (slot, shared) = match best {
                Some((donor, s)) if s > 0 => {
                    let slot = self
                        .pool
                        .admit_shared(cap, donor, s)
                        .expect("free slot checked");
                    (slot, s)
                }
                _ => (self.pool.admit(cap).expect("free slot checked"),
                      0),
            };
            self.shared_tokens += shared as u64;
            let prompt_len = p.prompt.len();
            let rng = Rng::new(p.gc.seed);
            let spec = if p.gc.spec.is_some() {
                SpecSlot::Pending
            } else {
                SpecSlot::Off
            };
            self.active.push(Active {
                tag: p.tag,
                rid: p.rid,
                slot,
                prompt: p.prompt,
                gc: p.gc,
                rng,
                spec,
                fed: shared,
                tokens: Vec::new(),
                t_submit: p.t_submit,
                t_prefill_done: None,
                prefill_work_ns: 0,
                ttft_ns: 0,
                finished: None,
                cancelled: false,
            });
            let rid = self.active.last().expect("just pushed").rid;
            self.trace(step_no, Ev::Admit {
                rid,
                slot,
                prompt: prompt_len,
                shared,
            });
        }
        // Deferred requests keep their original queue position.
        for p in deferred.into_iter().rev() {
            self.pending.push_front(p);
        }
        if self.active.is_empty() {
            return Ok(Vec::new());
        }
        self.steps += 1;

        // Speculative phase setup: decide, per opted-in sequence, what
        // this step does — engage a drafter slot, catch the drafter up
        // one chunk, fall back to plain decode for good, or draft+verify
        // now. `spec_mask[i]` marks active sequences taken OUT of the
        // plain decode batch below.
        let mut spec_mask = vec![false; self.active.len()];
        let mut spec_now: Vec<usize> = Vec::new();
        if let Some(dm) = drafter {
            for i in 0..self.active.len() {
                let Some(SpecDecode { k }) = self.active[i].gc.spec
                else {
                    continue;
                };
                if self.active[i].cancelled
                    || self.active[i].spec == SpecSlot::Off
                    || self.active[i].fed + 1 < self.active[i].prompt.len()
                {
                    continue; // cancelled, disabled, or still prefilling
                }
                let slot = self.active[i].slot;
                let cap = self.pool.capacity(slot);
                if self.active[i].fed + k + 1 > cap {
                    // The verify window would wrap the ring, where
                    // rollback is impossible (`KvCachePool::truncate`
                    // refuses); `fed` only grows, so this is permanent
                    // — the sequence decodes plain from here on.
                    if let SpecSlot::On { dslot, .. } =
                        self.active[i].spec
                    {
                        self.drafter_pool
                            .as_mut()
                            .expect("On implies drafter pool")
                            .retire(dslot);
                    }
                    self.active[i].spec = SpecSlot::Off;
                    continue;
                }
                if self.active[i].spec == SpecSlot::Pending {
                    // First eligible step with a drafter present:
                    // mirror the sequence into the drafter pool. The
                    // pool has one slot per target slot and `On`
                    // states map 1:1, so admission cannot fail.
                    let cfg = &self.cfg;
                    let slots = self.pool.max_slots();
                    let dbits = self.drafter_kv_bits.as_deref();
                    let dpool = self.drafter_pool.get_or_insert_with(
                        || match dbits {
                            Some(bits) => {
                                KvCachePool::for_model_with_bits(
                                    cfg, slots, bits)
                            }
                            None => KvCachePool::for_model(cfg, slots),
                        });
                    let dslot = dpool
                        .admit(cap)
                        .expect("drafter pool mirrors target slots");
                    self.active[i].spec =
                        SpecSlot::On { dslot, dfed: 0 };
                }
                let SpecSlot::On { dslot, dfed } = self.active[i].spec
                else {
                    unreachable!("engaged above")
                };
                let fed = self.active[i].fed;
                if dfed + 1 < fed {
                    // Catch-up: a freshly engaged drafter replays the
                    // committed stream in aligned chunks, one per step
                    // (the same pacing as prompt prefill), while the
                    // sequence keeps decoding plain. The gap shrinks
                    // by a chunk minus one token per step, so drafting
                    // starts after a handful of steps even against
                    // long prompts.
                    let n = chunk_len(dfed, fed - dfed, cap);
                    let a = &self.active[i];
                    let toks: Vec<i32> = (dfed..dfed + n)
                        .map(|p| stream_token(&a.prompt, &a.tokens, p))
                        .collect();
                    let dpool = self
                        .drafter_pool
                        .as_mut()
                        .expect("On implies drafter pool");
                    dm.prefill_chunk(exec, entry, dpool, dslot,
                                     &toks)?;
                    if let SpecSlot::On { dfed, .. } =
                        &mut self.active[i].spec
                    {
                        *dfed += n;
                    }
                    continue;
                }
                spec_mask[i] = true;
                spec_now.push(i);
            }
        }

        // Split the step's work BEFORE anything mutates: multi-token
        // prompt windows get a dedicated prefill chunk; everything else
        // — decoders AND any sequence with exactly ONE prompt token
        // left — rides the shared decode batch. A lone final token has
        // no multi-row amortization to gain from a chunk call and no
        // TTFT to win (one step either way), but a dedicated call would
        // cost it a full weight stream of its own; in the shared batch
        // it shares the step's weight reads like any decode row. (This
        // is also every shared-prefix sharer whose un-shared tail is a
        // single token — the common identical-prompt case.) A sequence
        // whose chunk completes its prompt this step samples its first
        // token from the chunk's last logits row and joins the decode
        // batch next step — the same cadence the per-token flow had.
        let decoding: Vec<usize> = self
            .active
            .iter()
            .enumerate()
            .filter(|(i, a)| {
                a.fed + 1 >= a.prompt.len() && !spec_mask[*i]
                    && !a.cancelled
            })
            .map(|(i, _)| i)
            .collect();
        // (active index, prompt offset, chunk length); `a.fed` is the
        // slot's cache position, so it also picks the chunk alignment.
        let prefills: Vec<(usize, usize, usize)> = self
            .active
            .iter()
            .enumerate()
            .filter(|(_, a)| a.fed + 1 < a.prompt.len() && !a.cancelled)
            .map(|(i, a)| {
                let cap = self.pool.capacity(a.slot);
                let n =
                    chunk_len(a.fed, a.prompt.len() - a.fed, cap);
                (i, a.fed, n)
            })
            .collect();

        // Chunked prefill: ONE aligned chunk per still-prefilling
        // sequence per step — a long prompt advances a whole window per
        // step (instead of one token) while in-flight decoders still
        // get exactly one batched step below, so prefill never stalls
        // them for more than a chunk's worth of work.
        // Ring rows recycled (evicted in place) this step: a position
        // appended at `pos >= cap` overwrites the row holding
        // `pos - cap`.
        let mut recycled = 0usize;
        for (i, from, n) in prefills {
            let slot = self.active[i].slot;
            let t0 = Instant::now();
            let logits = target.prefill_chunk(
                exec, entry, &mut self.pool, slot,
                &self.active[i].prompt[from..from + n])?;
            let a = &mut self.active[i];
            a.prefill_work_ns += t0.elapsed().as_nanos() as u64;
            a.fed += n;
            let rid = a.rid;
            if a.fed >= a.prompt.len() {
                // First sample comes from the chunk's last row — the
                // same logits the last prompt token's decode step would
                // have returned (rows are bit-identical).
                a.consume_row(logits.row(n - 1), true);
            }
            recycled +=
                (from + n).saturating_sub(self.pool.capacity(slot)
                                          .max(from));
            self.trace(step_no, Ev::PrefillChunk {
                rid,
                slot,
                pos: from,
                len: n,
            });
        }

        // One token per batch rider — decoders feed their previous
        // sample, a rider finishing its prompt feeds its last prompt
        // token — in one batched decode.
        if !decoding.is_empty() {
            let batch: Vec<(usize, i32)> = decoding
                .iter()
                .map(|&i| {
                    let a = &self.active[i];
                    (a.slot, stream_token(&a.prompt, &a.tokens, a.fed))
                })
                .collect();
            let logits = target.decode_batch(exec, entry,
                                             &mut self.pool, &batch)?;
            let v = self.cfg.vocab;
            for (ri, &i) in decoding.iter().enumerate() {
                let a = &mut self.active[i];
                // The appended position was `fed`; past the ring
                // capacity it recycled the oldest row in place.
                if a.fed >= self.pool.capacity(a.slot) {
                    recycled += 1;
                }
                a.fed += 1;
                a.consume_row(&logits.data()[ri * v..(ri + 1) * v],
                              a.fed == a.prompt.len());
            }
            if self.tracer.is_some() {
                let mut mask = 0u64;
                for &(slot, _) in &batch {
                    if slot < 64 {
                        mask |= 1u64 << slot;
                    }
                }
                self.trace(step_no, Ev::Decode {
                    batch: batch.len(),
                    slots_mask: mask,
                });
            }
        }

        // Speculative draft loop: one batched DRAFTER decode per draft
        // depth, shared across every spec sequence (the drafter-side
        // mirror of continuous batching — a cheap variant's weight
        // stream amortizes over all drafting sequences). Each sequence
        // first burns its ≤1-token lag on committed stream tokens,
        // then feeds back its own argmax samples until it holds k
        // drafts: after consuming token index p, the drafter's argmax
        // is its guess for stream position p + 1, which is a draft
        // only once p >= fed (positions up to `fed` are already
        // committed — the target sampled stream[fed] last step).
        let mut drafts: Vec<Vec<i32>> = vec![Vec::new(); spec_now.len()];
        if let Some(dm) = drafter {
            loop {
                // (spec_now index, (drafter slot, token to feed))
                let mut feeds: Vec<(usize, (usize, i32))> = Vec::new();
                for (si, &i) in spec_now.iter().enumerate() {
                    let a = &self.active[i];
                    let SpecDecode { k } =
                        a.gc.spec.expect("spec sequence");
                    let SpecSlot::On { dslot, dfed } = a.spec else {
                        unreachable!("spec_now holds engaged slots")
                    };
                    if dfed >= a.fed + k {
                        continue; // k drafts ready
                    }
                    let tok = if dfed <= a.fed {
                        stream_token(&a.prompt, &a.tokens, dfed)
                    } else {
                        drafts[si][dfed - a.fed - 1]
                    };
                    feeds.push((si, (dslot, tok)));
                }
                if feeds.is_empty() {
                    break;
                }
                let batch: Vec<(usize, i32)> =
                    feeds.iter().map(|&(_, p)| p).collect();
                let dpool = self
                    .drafter_pool
                    .as_mut()
                    .expect("spec sequences imply drafter pool");
                let logits =
                    dm.decode_batch(exec, entry, dpool, &batch)?;
                let v = self.cfg.vocab;
                for (ri, &(si, _)) in feeds.iter().enumerate() {
                    let a = &mut self.active[spec_now[si]];
                    let fed = a.fed;
                    let SpecSlot::On { dfed, .. } = &mut a.spec else {
                        unreachable!("spec_now holds engaged slots")
                    };
                    *dfed += 1;
                    if *dfed > fed {
                        drafts[si].push(argmax(
                            &logits.data()[ri * v..(ri + 1) * v]));
                    }
                }
            }
        }

        // Verify + exact greedy acceptance, one multi-row TARGET pass
        // per spec sequence: score the already-sampled next token plus
        // all k drafts in a single `verify_chunk` (rows bit-identical
        // to per-token decode), then commit rows through `consume_row`
        // — the SAME body plain decode uses, so stop/TTFT/max_new
        // semantics cannot drift — as long as each committed token
        // agrees with the draft that fed the next row. Both pools then
        // roll back to the committed boundary.
        let mut spec_events: Vec<Ev> = Vec::new();
        for (si, &i) in spec_now.iter().enumerate() {
            let k = drafts[si].len();
            let f = self.active[i].fed;
            let slot = self.active[i].slot;
            let rid = self.active[i].rid;
            let mut window = Vec::with_capacity(k + 1);
            {
                let a = &self.active[i];
                window.push(stream_token(&a.prompt, &a.tokens, f));
                window.extend_from_slice(&drafts[si]);
            }
            let logits = target.verify_chunk(
                exec, entry, &mut self.pool, slot, &window)?;
            let a = &mut self.active[i];
            let t0 = a.tokens.len();
            let mut c = 0usize; // verify rows consumed
            for r in 0..=k {
                // Row r is the logits after consuming window[r]; its
                // argmax commits stream position f + r + 1. Row 0 can
                // be the last prompt token (TTFT stamps here, exactly
                // like the decode-batch rider path).
                a.consume_row(logits.row(r),
                              f + r + 1 == a.prompt.len());
                c += 1;
                if a.finished.is_some() || a.cancelled {
                    break; // stop/max_new/disconnect: rest is unused
                }
                if r < k && a.tokens[t0 + r] != drafts[si][r] {
                    break; // divergence: rows past r fed a wrong token
                }
            }
            // Commit: the target keeps the c consumed positions and
            // discards the speculative tail; the drafter rewinds to
            // the committed boundary (capped at f + k — on full
            // acceptance it is exactly one token behind the target,
            // which the next draft loop's first feed repays).
            self.pool.truncate(slot, f + c);
            let committed = self.active[i].tokens.len() - t0;
            let accepted = (0..k.min(committed))
                .filter(|&j| {
                    self.active[i].tokens[t0 + j] == drafts[si][j]
                })
                .count();
            self.active[i].fed = f + c;
            let dkeep = (f + c).min(f + k);
            let SpecSlot::On { dslot, .. } = self.active[i].spec else {
                unreachable!("spec_now holds engaged slots")
            };
            self.drafter_pool
                .as_mut()
                .expect("spec sequences imply drafter pool")
                .truncate(dslot, dkeep);
            if let SpecSlot::On { dfed, .. } = &mut self.active[i].spec
            {
                *dfed = dkeep;
            }
            self.spec_counters.drafted += k as u64;
            self.spec_counters.accepted += accepted as u64;
            self.spec_counters.verify_steps += 1;
            self.spec_counters.emitted += committed as u64;
            spec_events.push(Ev::Draft { rid, slot, k });
            spec_events.push(Ev::Verify {
                rid,
                slot,
                drafted: k,
                accepted,
            });
        }
        for ev in spec_events {
            self.trace(step_no, ev);
        }

        let cow = self.pool.cow_splits() - cow0;
        if cow > 0 {
            self.trace(step_no, Ev::CowSplit { n: cow });
        }
        if recycled > 0 {
            self.trace(step_no, Ev::Recycle { rows: recycled });
        }

        // Retire finished AND cancelled sequences, freeing their slots.
        // A sequence that both finished and lost its receiver on the
        // final token retires as finished (the tokens are complete; the
        // caller sees the closed stream); a cancelled one retires
        // through the same pool paths but builds no `Generation` — it
        // may still be prefilling, so `t_prefill_done` can be unset.
        let mut done = Vec::new();
        let mut keep = Vec::with_capacity(self.active.len());
        for a in std::mem::take(&mut self.active) {
            match a.finished {
                None if a.cancelled => {
                    self.pool.retire(a.slot);
                    if let SpecSlot::On { dslot, .. } = a.spec {
                        self.drafter_pool
                            .as_mut()
                            .expect("On implies drafter pool")
                            .retire(dslot);
                    }
                    self.cancelled_total += 1;
                    self.trace(step_no, Ev::Cancel {
                        rid: a.rid,
                        slot: Some(a.slot),
                    });
                }
                None => keep.push(a),
                Some(stopped) => {
                    self.pool.retire(a.slot);
                    if let SpecSlot::On { dslot, .. } = a.spec {
                        self.drafter_pool
                            .as_mut()
                            .expect("On implies drafter pool")
                            .retire(dslot);
                    }
                    self.trace(step_no, Ev::Retire {
                        rid: a.rid,
                        slot: a.slot,
                        gen_tokens: a.tokens.len(),
                    });
                    let t_pre =
                        a.t_prefill_done.expect("set at prefill end");
                    let gen = Generation {
                        stats: GenStats {
                            prompt_tokens: a.prompt.len(),
                            gen_tokens: a.tokens.len(),
                            prefill_ns: a.prefill_work_ns,
                            ttft_ns: a.ttft_ns,
                            decode_ns: t_pre.elapsed().as_nanos()
                                as u64,
                        },
                        tokens: a.tokens,
                        stopped,
                    };
                    // Terminal stream event: the sink gets its own
                    // copy; the batch result below goes back to the
                    // caller regardless (a failed emit just means the
                    // receiver is already gone).
                    a.tag.emit(GenEvent::Done(gen.clone()));
                    done.push((a.tag, gen));
                }
            }
        }
        self.active = keep;
        Ok(done)
    }

    /// Abort every pending and in-flight request, freeing all slots,
    /// and return their tags — the server fails their reply channels
    /// loudly when a fatal error ends the serve loop.
    pub fn abort_all(&mut self) -> Vec<T> {
        let mut tags: Vec<T> =
            self.pending.drain(..).map(|p| p.tag).collect();
        for a in self.active.drain(..) {
            self.pool.retire(a.slot);
            if let SpecSlot::On { dslot, .. } = a.spec {
                self.drafter_pool
                    .as_mut()
                    .expect("On implies drafter pool")
                    .retire(dslot);
            }
            tags.push(a.tag);
        }
        tags
    }

    /// Step until every submitted request has finished.
    pub fn run(&mut self, exec: &dyn Executor, entry: &ModelEntry,
               model: ModelRef) -> Result<Vec<(T, Generation)>>
    where
        T: GenSink,
    {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step(exec, entry, model)?);
        }
        Ok(out)
    }

    /// `run` in speculative mode: step with a drafter until every
    /// submitted request has finished.
    pub fn run_spec(&mut self, exec: &dyn Executor, entry: &ModelEntry,
                    target: ModelRef, drafter: Option<ModelRef>)
                    -> Result<Vec<(T, Generation)>>
    where
        T: GenSink,
    {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step_spec(exec, entry, target, drafter)?);
        }
        Ok(out)
    }
}

/// Run a set of requests through one continuous-batching engine with up
/// to `slots` concurrent sequences; results come back in request order.
/// Each request's output is identical to what `generate` returns for it
/// alone (see `BatchEngine` on determinism) — batching changes
/// throughput, never tokens.
pub fn generate_batch(exec: &dyn Executor, entry: &ModelEntry,
                      model: ModelRef, reqs: &[(Vec<i32>, GenConfig)],
                      slots: usize) -> Result<Vec<Generation>> {
    let mut engine: BatchEngine<usize> = BatchEngine::with_kv_bits(
        &entry.config, slots.max(1), entry.kv_bits.clone());
    for (i, (prompt, gc)) in reqs.iter().enumerate() {
        engine
            .submit(i, prompt.clone(), gc.clone())
            .map_err(|(_, e)| e)?;
    }
    let mut done = engine.run(exec, entry, model)?;
    debug_assert_eq!(done.len(), reqs.len());
    done.sort_unstable_by_key(|(i, _)| *i);
    Ok(done.into_iter().map(|(_, g)| g).collect())
}

/// `generate_batch` with a drafter variant: requests whose `GenConfig`
/// opts into speculative decoding draft through `drafter` and verify
/// through `target`; the rest decode plain in the same engine. Greedy
/// outputs are bit-identical to `generate_batch` with `target` alone —
/// the drafter changes how many target passes the tokens cost, never
/// the tokens (pinned by `rust/tests/spec_decode.rs`).
pub fn generate_batch_spec(exec: &dyn Executor, entry: &ModelEntry,
                           target: ModelRef, drafter: ModelRef,
                           reqs: &[(Vec<i32>, GenConfig)], slots: usize)
                           -> Result<Vec<Generation>> {
    let mut engine: BatchEngine<usize> = BatchEngine::with_kv_bits(
        &entry.config, slots.max(1), entry.kv_bits.clone());
    for (i, (prompt, gc)) in reqs.iter().enumerate() {
        engine
            .submit(i, prompt.clone(), gc.clone())
            .map_err(|(_, e)| e)?;
    }
    let mut done = engine.run_spec(exec, entry, target, Some(drafter))?;
    debug_assert_eq!(done.len(), reqs.len());
    done.sort_unstable_by_key(|(i, _)| *i);
    Ok(done.into_iter().map(|(_, g)| g).collect())
}

/// Generate up to `gc.max_new` tokens after `prompt` through any
/// executor's KV-cached batched decode path — the B=1 case of
/// `generate_batch`: the prompt prefills in aligned chunks into a fresh
/// cache slot, then the decode loop samples and feeds back until a stop
/// condition.
pub fn generate(exec: &dyn Executor, entry: &ModelEntry, model: ModelRef,
                prompt: &[i32], gc: &GenConfig) -> Result<Generation> {
    let reqs = [(prompt.to_vec(), gc.clone())];
    let mut out = generate_batch(exec, entry, model, &reqs, 1)?;
    Ok(out.pop().expect("one request in, one generation out"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_ties_pick_lowest_id() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn top1_equals_greedy() {
        let logits = vec![0.1f32, 2.0, -0.5, 1.9];
        let mut rng = Rng::new(9);
        for _ in 0..10 {
            let s = Sampling::TopK { k: 1, temperature: 1.0 };
            assert_eq!(sample(&logits, &s, &mut rng), 1);
        }
    }

    #[test]
    fn topk_only_emits_topk_tokens() {
        let logits = vec![5.0f32, 4.0, -10.0, 3.0, -20.0];
        let mut rng = Rng::new(11);
        let s = Sampling::TopK { k: 3, temperature: 1.0 };
        for _ in 0..200 {
            let t = sample(&logits, &s, &mut rng);
            assert!(matches!(t, 0 | 1 | 3), "sampled non-top-k token {t}");
        }
    }

    #[test]
    fn low_temperature_concentrates_on_argmax() {
        let logits = vec![1.0f32, 1.5, 0.5, 1.4];
        let mut rng = Rng::new(13);
        let s = Sampling::TopK { k: 4, temperature: 1e-4 };
        for _ in 0..50 {
            assert_eq!(sample(&logits, &s, &mut rng), 1);
        }
    }

    #[test]
    fn chunk_lengths_align_to_pages_and_respect_caps() {
        // Aligned start, plenty remaining: a full two-page chunk.
        assert_eq!(chunk_len(0, 1000, 1000), PREFILL_CHUNK);
        // Chunk boundaries land on PAGE_SIZE-aligned positions: a
        // misaligned start (e.g. a shared-prefix tail) realigns first.
        let n = chunk_len(PAGE_SIZE + 5, 1000, 1000);
        assert_eq!((PAGE_SIZE + 5 + n) % PAGE_SIZE, 0);
        assert!(n <= PREFILL_CHUNK);
        // The final chunk takes exactly what remains, aligned or not.
        assert_eq!(chunk_len(3, 7, 1000), 7);
        // A tiny ring bounds the chunk (overlong prompts evict); a
        // page boundary inside the bound still ends the chunk there.
        assert_eq!(chunk_len(0, 1000, 5), 5);
        assert_eq!(chunk_len(12, 1000, 5), 4);
        // Walking any prompt always terminates with aligned interior
        // boundaries.
        let (mut pos, mut rem) = (PAGE_SIZE - 1, 3 * PAGE_SIZE + 7);
        while rem > 0 {
            let n = chunk_len(pos, rem, 2 * PAGE_SIZE + 3);
            assert!(n >= 1 && n <= rem && n <= 2 * PAGE_SIZE + 3);
            if n < rem && n < PREFILL_CHUNK {
                assert_eq!((pos + n) % PAGE_SIZE, 0,
                           "interior chunk at pos {pos} not aligned");
            }
            pos += n;
            rem -= n;
        }
    }

    #[test]
    fn submit_gates_spec_requests() {
        let cfg = ModelConfig::test_config();
        let mut e: BatchEngine<usize> = BatchEngine::new(&cfg, 1);
        // Sampled acceptance is not implemented: spec + TopK rejects.
        let gc = GenConfig {
            sampling: Sampling::TopK { k: 4, temperature: 1.0 },
            spec: Some(SpecDecode { k: 4 }),
            ..GenConfig::default()
        };
        assert!(e.submit(0, vec![1, 2], gc).is_err());
        // A zero-token draft window is meaningless.
        let gc = GenConfig {
            spec: Some(SpecDecode { k: 0 }),
            ..GenConfig::default()
        };
        assert!(e.submit(1, vec![1, 2], gc).is_err());
        // Greedy spec is accepted (Greedy is the default sampling).
        let gc = GenConfig {
            spec: Some(SpecDecode { k: 4 }),
            ..GenConfig::default()
        };
        assert!(e.submit(2, vec![1, 2], gc).is_ok());
        assert_eq!(e.in_flight(), 1);
        assert_eq!(e.spec_counters(), SpecCounters::default());
        assert!(e.drafter_pool().is_none(), "allocated lazily");
    }

    #[test]
    fn spec_counter_ratios() {
        let c = SpecCounters {
            drafted: 8,
            accepted: 6,
            verify_steps: 2,
            emitted: 8,
        };
        assert!((c.tokens_per_verify() - 4.0).abs() < 1e-12);
        assert!((c.accept_rate() - 0.75).abs() < 1e-12);
        assert_eq!(SpecCounters::default().tokens_per_verify(), 0.0);
        assert_eq!(SpecCounters::default().accept_rate(), 0.0);
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let logits = vec![0.3f32, 0.1, 0.2, 0.35, 0.05];
        let s = Sampling::TopK { k: 4, temperature: 0.8 };
        let seq = |seed: u64| -> Vec<i32> {
            let mut rng = Rng::new(seed);
            (0..32).map(|_| sample(&logits, &s, &mut rng)).collect()
        };
        assert_eq!(seq(42), seq(42));
        // Different seeds should (for this spread) disagree somewhere.
        assert_ne!(seq(42), seq(43));
    }
}
