//! `NativeEngine`: a pure-Rust llama-style forward pass (RMSNorm + RoPE +
//! GQA + SwiGLU) executing directly over `model::Weights`, or over packed
//! 2/4-bit codes via the fused dequant-matmul in `infer::qmat`. Semantics
//! mirror `python/compile/model.py` exactly (same eps, RoPE convention,
//! GQA head mapping and causal softmax), so the same `.tz` weights score
//! identically whichever executor runs them.
//!
//! Parallelism: batch rows are independent end-to-end, so the engine
//! fans one sequence per `util::pool` worker; all per-sequence math is
//! single-threaded to avoid nested pools.

use anyhow::{ensure, Result};

use super::cache::{KvCache, KvCachePool, LayerKv};
use super::qmat::{fused_gemm_small, fused_matmul, fused_vecmat,
                  PackedMatrix, QMat, QuantizedModel};
use super::{Executor, Probes};
use crate::model::{ModelConfig, Weights};
use crate::runtime::ModelEntry;
use crate::tensor::matmul::matmul;
use crate::tensor::Tensor;
use crate::util::pool::{chunk_ranges, default_workers, parallel_map,
                        workers_for};

const RMS_EPS: f32 = 1e-5;
const ROPE_BASE: f32 = 10000.0;

/// Pure-Rust executor; needs no artifacts, no XLA, no Python.
pub struct NativeEngine {
    pub workers: usize,
}

impl NativeEngine {
    pub fn new() -> Self {
        NativeEngine { workers: default_workers() }
    }

    pub fn with_workers(workers: usize) -> Self {
        NativeEngine { workers: workers.max(1) }
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        NativeEngine::new()
    }
}

impl Executor for NativeEngine {
    fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    fn forward(&self, entry: &ModelEntry, tokens: &[i32], batch: usize,
               weights: &Weights) -> Result<Tensor> {
        // Workers go to the per-sequence batch split in `run_batch`;
        // kernel-level splits stay off (workers=1) to avoid nesting
        // thread pools.
        let prep = prepare_dense(&entry.config, weights, 1);
        let (logits, _) =
            run_batch(&prep, tokens, batch, self.workers, false)?;
        Ok(logits)
    }

    fn forward_packed(&self, entry: &ModelEntry, tokens: &[i32],
                      batch: usize, model: &QuantizedModel)
                      -> Result<Tensor> {
        let prep = prepare_packed(&entry.config, model, 1)?;
        let (logits, _) =
            run_batch(&prep, tokens, batch, self.workers, false)?;
        Ok(logits)
    }

    fn probe(&self, entry: &ModelEntry, tokens: &[i32], batch: usize,
             weights: &Weights) -> Result<Probes> {
        let prep = prepare_dense(&entry.config, weights, 1);
        let (_, probes) =
            run_batch(&prep, tokens, batch, self.workers, true)?;
        Ok(probes.expect("collect=true returns probes"))
    }

    fn supports_decode(&self) -> bool {
        true
    }

    fn decode_step(&self, entry: &ModelEntry, cache: &mut KvCache,
                   token: i32, weights: &Weights) -> Result<Tensor> {
        // Borrowing prepare: per-step setup is O(layers) views, no weight
        // copies, so the per-token cost stays prefix- AND weight-copy-free.
        let prep = prepare_dense_ref(&entry.config, weights,
                                     self.workers);
        decode_with(&prep, cache, token)
    }

    fn decode_step_packed(&self, entry: &ModelEntry, cache: &mut KvCache,
                          token: i32, model: &QuantizedModel)
                          -> Result<Tensor> {
        let prep = prepare_packed(&entry.config, model, self.workers)?;
        decode_with(&prep, cache, token)
    }

    fn decode_batch(&self, entry: &ModelEntry, pool: &mut KvCachePool,
                    active: &[(usize, i32)], weights: &Weights)
                    -> Result<Tensor> {
        let prep = prepare_dense_ref(&entry.config, weights,
                                     self.workers);
        decode_batch_with(&prep, pool, active)
    }

    fn decode_batch_packed(&self, entry: &ModelEntry,
                           pool: &mut KvCachePool,
                           active: &[(usize, i32)],
                           model: &QuantizedModel) -> Result<Tensor> {
        let prep = prepare_packed(&entry.config, model, self.workers)?;
        decode_batch_with(&prep, pool, active)
    }

    fn prefill_chunk(&self, entry: &ModelEntry, pool: &mut KvCachePool,
                     slot: usize, tokens: &[i32], weights: &Weights)
                     -> Result<Tensor> {
        let prep = prepare_dense_ref(&entry.config, weights,
                                     self.workers);
        prefill_chunk_with(&prep, pool, slot, tokens)
    }

    fn prefill_chunk_packed(&self, entry: &ModelEntry,
                            pool: &mut KvCachePool, slot: usize,
                            tokens: &[i32], model: &QuantizedModel)
                            -> Result<Tensor> {
        let prep = prepare_packed(&entry.config, model, self.workers)?;
        prefill_chunk_with(&prep, pool, slot, tokens)
    }
}

/// One projection operand: dense f32 (owned slice, borrowed from a
/// quantized model's fallback store, or a borrowed layer of the stacked
/// [L, K, N] store) or packed codes (fused path).
enum PMat<'a> {
    Dense(Tensor),
    DenseRef(&'a Tensor),
    /// Layer `l` of a stacked [L, K, N] weight, without copying it out —
    /// the zero-copy prepare used by the per-token decode path.
    Stacked(&'a Tensor, usize),
    Packed(&'a PackedMatrix),
}

impl PMat<'_> {
    /// `x [rows, K] @ W [K, N]`. `workers` is a budget, not a demand:
    /// the fused kernels gate it through `pool::workers_for`, so small
    /// calls (decode-step projections) stay single-threaded and only
    /// prefill-sized GEMMs pay a spawn.
    fn apply(&self, x: &Tensor, workers: usize) -> Tensor {
        match self {
            PMat::Dense(w) => matmul(x, w),
            PMat::DenseRef(w) => matmul(x, w),
            PMat::Stacked(t, l) => stacked_matmul(x, t, *l, workers),
            PMat::Packed(p) => {
                // All three kernels are bit-identical per row; the split
                // picks the blocking that fits the input's shape.
                if x.rows() == 1 {
                    Tensor::new(fused_vecmat(x.data(), p), vec![1, p.n])
                } else if x.rows() <= DECODE_BATCH_ROWS {
                    fused_gemm_small(x, p, workers)
                } else {
                    fused_matmul(x, p, workers)
                }
            }
        }
    }
}

/// Row-count threshold under which the packed path uses the small-batch
/// `fused_gemm_small` (one weight-row decode shared by all rows) instead
/// of the K-panel `fused_matmul`. Decode batches live well under this;
/// prefill chunks can exceed it and take the K-panel kernel — all three
/// kernels are per-row bit-identical, so the split never changes logits.
const DECODE_BATCH_ROWS: usize = 16;

/// `x [M, K] @ stacked[l] [K, N]` over a borrowed slice of a [L, K, N]
/// tensor. Plain ikj loop with k ascending — the same accumulation order
/// as `tensor::matmul`'s K panels, so results are bit-identical to a
/// matmul against the copied-out layer. Output rows are independent, so
/// big (prefill-sized) calls split rows across `workers`; the
/// `pool::workers_for` gate keeps decode-sized calls single-threaded.
fn stacked_matmul(x: &Tensor, stacked: &Tensor, l: usize,
                  workers: usize) -> Tensor {
    let dims = stacked.dims();
    debug_assert_eq!(dims.len(), 3, "stacked weight must be [L, K, N]");
    let (k, n) = (dims[1], dims[2]);
    let m = x.rows();
    assert_eq!(x.cols(), k, "stacked_matmul: x cols {} != K {k}", x.cols());
    let wd = &stacked.data()[l * k * n..(l + 1) * k * n];
    let xd = x.data();
    let rows = |r0: usize, r1: usize| -> Vec<f32> {
        let mut out = vec![0.0f32; (r1 - r0) * n];
        for i in r0..r1 {
            let xrow = &xd[i * k..(i + 1) * k];
            let orow = &mut out[(i - r0) * n..(i - r0 + 1) * n];
            for (kk, &aik) in xrow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let wrow = &wd[kk * n..(kk + 1) * n];
                for (o, wv) in orow.iter_mut().zip(wrow) {
                    *o += aik * wv;
                }
            }
        }
        out
    };
    let workers = workers_for(workers, m * k * n).clamp(1, m.max(1));
    if workers == 1 {
        return Tensor::new(rows(0, m), vec![m, n]);
    }
    let ranges = chunk_ranges(m, workers);
    let chunks = parallel_map(ranges.len(), ranges.len(), |i| {
        let (r0, r1) = ranges[i];
        rows(r0, r1)
    });
    let mut out = Vec::with_capacity(m * n);
    for c in chunks {
        out.extend_from_slice(&c);
    }
    Tensor::new(out, vec![m, n])
}

struct PLayer<'a> {
    ln1: Tensor,
    ln2: Tensor,
    wq: PMat<'a>,
    wk: PMat<'a>,
    wv: PMat<'a>,
    wo: PMat<'a>,
    wgate: PMat<'a>,
    wup: PMat<'a>,
    wdown: PMat<'a>,
}

/// Per-forward view: layer matrices sliced out of the stacked weight
/// store once, shared read-only across the batch workers.
///
/// The dense path copies each projection out of the stacked tensor once
/// per `forward` call (same order of work as the PJRT path's per-call
/// host→device buffer uploads). A per-weight-set cache would need
/// identity tracking across `&Weights` calls; revisit if the prepare
/// step ever shows up in profiles.
struct Prepared<'a> {
    cfg: &'a ModelConfig,
    embed: &'a Tensor,
    unembed: &'a Tensor,
    lnf: &'a Tensor,
    layers: Vec<PLayer<'a>>,
    /// Kernel-level worker budget for this prepared view's projections
    /// and attention splits. 1 on the `forward`/`probe` path, where the
    /// engine's workers are already spent on the per-sequence batch
    /// split (no nested pools); the engine's worker count on the
    /// decode / prefill paths, gated per call by `pool::workers_for`.
    workers: usize,
}

fn prepare_dense<'a>(cfg: &'a ModelConfig, w: &'a Weights,
                     workers: usize) -> Prepared<'a> {
    let layers = (0..cfg.n_layers)
        .map(|l| PLayer {
            ln1: w.get("ln1").slice0(l),
            ln2: w.get("ln2").slice0(l),
            wq: PMat::Dense(w.layer_matrix("wq", l)),
            wk: PMat::Dense(w.layer_matrix("wk", l)),
            wv: PMat::Dense(w.layer_matrix("wv", l)),
            wo: PMat::Dense(w.layer_matrix("wo", l)),
            wgate: PMat::Dense(w.layer_matrix("wgate", l)),
            wup: PMat::Dense(w.layer_matrix("wup", l)),
            wdown: PMat::Dense(w.layer_matrix("wdown", l)),
        })
        .collect();
    Prepared {
        cfg,
        embed: w.get("embed"),
        unembed: w.get("unembed"),
        lnf: w.get("lnf"),
        layers,
        workers: workers.max(1),
    }
}

/// Borrowing variant of `prepare_dense` for the per-token decode path:
/// projections are `PMat::Stacked` views into the stacked store (only the
/// tiny per-layer norm gains are copied), so building it costs O(layers)
/// per step instead of O(parameters).
fn prepare_dense_ref<'a>(cfg: &'a ModelConfig, w: &'a Weights,
                         workers: usize) -> Prepared<'a> {
    let layers = (0..cfg.n_layers)
        .map(|l| PLayer {
            ln1: w.get("ln1").slice0(l),
            ln2: w.get("ln2").slice0(l),
            wq: PMat::Stacked(w.get("wq"), l),
            wk: PMat::Stacked(w.get("wk"), l),
            wv: PMat::Stacked(w.get("wv"), l),
            wo: PMat::Stacked(w.get("wo"), l),
            wgate: PMat::Stacked(w.get("wgate"), l),
            wup: PMat::Stacked(w.get("wup"), l),
            wdown: PMat::Stacked(w.get("wdown"), l),
        })
        .collect();
    Prepared {
        cfg,
        embed: w.get("embed"),
        unembed: w.get("unembed"),
        lnf: w.get("lnf"),
        layers,
        workers: workers.max(1),
    }
}

fn prepare_packed<'a>(cfg: &'a ModelConfig, qm: &'a QuantizedModel,
                      workers: usize) -> Result<Prepared<'a>> {
    let w = &qm.weights;
    ensure!(qm.mats.len() == cfg.n_layers,
            "quantized model has {} layers but config '{}' expects {} — \
             was it quantized for a different model?",
            qm.mats.len(), cfg.name, cfg.n_layers);
    let pick = |l: usize, name: &'static str| -> Result<PMat<'a>> {
        match qm.mats[l].get(name) {
            Some(QMat::Packed(p)) => Ok(PMat::Packed(p)),
            Some(QMat::Dense(t)) => Ok(PMat::DenseRef(t)),
            // A malformed QuantizedModel must surface as a serving error,
            // not abort the server (DESIGN.md "Packed serving format").
            None => anyhow::bail!(
                "quantized model for '{}' is missing projection '{name}' \
                 at layer {l} (have: {:?})",
                cfg.name,
                qm.mats[l].keys().collect::<Vec<_>>()),
        }
    };
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for l in 0..cfg.n_layers {
        layers.push(PLayer {
            ln1: w.get("ln1").slice0(l),
            ln2: w.get("ln2").slice0(l),
            wq: pick(l, "wq")?,
            wk: pick(l, "wk")?,
            wv: pick(l, "wv")?,
            wo: pick(l, "wo")?,
            wgate: pick(l, "wgate")?,
            wup: pick(l, "wup")?,
            wdown: pick(l, "wdown")?,
        });
    }
    Ok(Prepared {
        cfg,
        embed: w.get("embed"),
        unembed: w.get("unembed"),
        lnf: w.get("lnf"),
        layers,
        workers: workers.max(1),
    })
}

/// Per-sequence probe activations (row-major [s, X] buffers).
struct SeqProbes {
    resid_in: Vec<Vec<f32>>,
    final_resid: Vec<f32>,
    x_ln1: Vec<Vec<f32>>,
    x_ln2: Vec<Vec<f32>>,
    attn_ctx: Vec<Vec<f32>>,
    ffn_mid: Vec<Vec<f32>>,
}

/// Run a token batch; returns logits [B, S, V] and, when `collect`,
/// per-layer activations stitched to the PJRT probe row order
/// (row = b·S + s).
fn run_batch(prep: &Prepared, tokens: &[i32], batch: usize,
             workers: usize, collect: bool)
             -> Result<(Tensor, Option<Probes>)> {
    let cfg = prep.cfg;
    let s = cfg.seq;
    let v = cfg.vocab;
    ensure!(tokens.len() == batch * s,
            "tokens {} != batch {batch} x seq {s}", tokens.len());
    ensure!(tokens.iter().all(|&t| t >= 0 && (t as usize) < v),
            "token id out of range (vocab {v})");

    let outs: Vec<(Vec<f32>, Option<SeqProbes>)> =
        parallel_map(batch, workers, |bi| {
            forward_seq(prep, &tokens[bi * s..(bi + 1) * s], collect)
        });

    let mut logits = Vec::with_capacity(batch * s * v);
    for (l, _) in &outs {
        logits.extend_from_slice(l);
    }
    let logits = Tensor::new(logits, vec![batch, s, v]);

    if !collect {
        return Ok((logits, None));
    }
    let nl = cfg.n_layers;
    let d = cfg.d_model;
    let hd = cfg.n_heads * cfg.d_head;
    let f = cfg.d_ffn;
    let per_layer = |get: fn(&SeqProbes, usize) -> &[f32],
                     cols: usize| -> Vec<Tensor> {
        (0..nl).map(|l| cat_batch(&outs, cols, l, get)).collect()
    };
    let probes = Probes {
        logits: logits.clone(),
        resid_in: per_layer(|p, l| &p.resid_in[l], d),
        final_resid: cat_batch(&outs, d, 0, |p, _| &p.final_resid),
        x_ln1: per_layer(|p, l| &p.x_ln1[l], d),
        x_ln2: per_layer(|p, l| &p.x_ln2[l], d),
        attn_ctx: per_layer(|p, l| &p.attn_ctx[l], hd),
        ffn_mid: per_layer(|p, l| &p.ffn_mid[l], f),
    };
    Ok((logits, Some(probes)))
}

/// Concatenate one per-sequence activation across the batch into a
/// [batch·s, cols] tensor. `get` selects the buffer (layer index `l`
/// is ignored by whole-model activations).
fn cat_batch(outs: &[(Vec<f32>, Option<SeqProbes>)], cols: usize,
             l: usize, get: fn(&SeqProbes, usize) -> &[f32]) -> Tensor {
    let mut data = Vec::new();
    for (_, p) in outs {
        data.extend_from_slice(get(p.as_ref().unwrap(), l));
    }
    let rows = data.len() / cols;
    Tensor::new(data, vec![rows, cols])
}

/// Full forward for one sequence: returns row-major logits [s·v].
fn forward_seq(prep: &Prepared, tokens: &[i32], collect: bool)
    -> (Vec<f32>, Option<SeqProbes>) {
    let cfg = prep.cfg;
    let (s, d) = (cfg.seq, cfg.d_model);
    let (nh, nkv, dh) = (cfg.n_heads, cfg.n_kv, cfg.d_head);
    let half = dh / 2;

    // RoPE tables, shared by q and k at every layer.
    let (rope_cos, rope_sin) = rope_tables(0, s, half);

    // h = embed[tokens]  [s, d]
    let mut h = Tensor::zeros(vec![s, d]);
    for (si, &t) in tokens.iter().enumerate() {
        h.row_mut(si).copy_from_slice(prep.embed.row(t as usize));
    }

    let mut probes = collect.then(|| SeqProbes {
        resid_in: Vec::with_capacity(cfg.n_layers),
        final_resid: Vec::new(),
        x_ln1: Vec::with_capacity(cfg.n_layers),
        x_ln2: Vec::with_capacity(cfg.n_layers),
        attn_ctx: Vec::with_capacity(cfg.n_layers),
        ffn_mid: Vec::with_capacity(cfg.n_layers),
    });

    for layer in &prep.layers {
        if let Some(p) = probes.as_mut() {
            p.resid_in.push(h.data().to_vec());
        }
        // Attention block.
        let wk = prep.workers;
        let x1 = rmsnorm(&h, &layer.ln1);
        let mut q = layer.wq.apply(&x1, wk); // [s, nh·dh]
        let mut km = layer.wk.apply(&x1, wk); // [s, nkv·dh]
        let vm = layer.wv.apply(&x1, wk); // [s, nkv·dh]
        rope(&mut q, nh, dh, &rope_cos, &rope_sin);
        rope(&mut km, nkv, dh, &rope_cos, &rope_sin);
        let ctx = attention(&q, &km, &vm, nh, nkv, dh);
        let attn_out = layer.wo.apply(&ctx, wk);
        h = h.add(&attn_out);
        // FFN block (SwiGLU).
        let x2 = rmsnorm(&h, &layer.ln2);
        let gate = layer.wgate.apply(&x2, wk);
        let up = layer.wup.apply(&x2, wk);
        let mut mid = gate;
        for (g, u) in mid.data_mut().iter_mut().zip(up.data()) {
            *g = silu(*g) * u;
        }
        let down = layer.wdown.apply(&mid, wk);
        if let Some(p) = probes.as_mut() {
            p.x_ln1.push(x1.data().to_vec());
            p.x_ln2.push(x2.data().to_vec());
            p.attn_ctx.push(ctx.data().to_vec());
            p.ffn_mid.push(mid.data().to_vec());
        }
        h = h.add(&down);
    }

    if let Some(p) = probes.as_mut() {
        p.final_resid = h.data().to_vec();
    }
    let hf = rmsnorm(&h, prep.lnf);
    let logits = matmul(&hf, prep.unembed);
    (logits.into_data(), probes)
}

/// cos/sin rows for absolute positions `start..start + len` (one row of
/// `half` frequencies per position). The full forward uses `start = 0`;
/// the decode path asks for one row per active sequence at its cache
/// position (`rope_tables_at`), with bit-identical float math.
fn rope_tables(start: usize, len: usize, half: usize)
    -> (Vec<f32>, Vec<f32>) {
    let positions: Vec<usize> = (start..start + len).collect();
    rope_tables_at(&positions, half)
}

/// cos/sin rows for arbitrary absolute positions, one row per entry —
/// the batched decode step's sequences each sit at their own position.
fn rope_tables_at(positions: &[usize], half: usize)
    -> (Vec<f32>, Vec<f32>) {
    let len = positions.len();
    let mut cos = vec![0.0f32; len * half];
    let mut sin = vec![0.0f32; len * half];
    for (si, &p) in positions.iter().enumerate() {
        for j in 0..half {
            let inv = ROPE_BASE.powf(-(j as f32) / half as f32);
            let ang = p as f32 * inv;
            cos[si * half + j] = ang.cos();
            sin[si * half + j] = ang.sin();
        }
    }
    (cos, sin)
}

/// Row-wise RMSNorm: `x · rsqrt(mean(x²) + eps) · g`.
fn rmsnorm(x: &Tensor, g: &Tensor) -> Tensor {
    let (rows, d) = (x.rows(), x.cols());
    let gd = g.data();
    debug_assert_eq!(gd.len(), d);
    let mut out = vec![0.0f32; rows * d];
    for r in 0..rows {
        let row = x.row(r);
        let ms: f32 =
            row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        let orow = &mut out[r * d..(r + 1) * d];
        for c in 0..d {
            orow[c] = row[c] * inv * gd[c];
        }
    }
    Tensor::new(out, vec![rows, d])
}

/// In-place rotary embedding over `[s, heads·dh]` (half-split
/// convention, matching `model.rope`).
fn rope(x: &mut Tensor, heads: usize, dh: usize, cos: &[f32],
        sin: &[f32]) {
    let s = x.rows();
    let half = dh / 2;
    let w = heads * dh;
    let xd = x.data_mut();
    for si in 0..s {
        let crow = &cos[si * half..(si + 1) * half];
        let srow = &sin[si * half..(si + 1) * half];
        for hi in 0..heads {
            let base = si * w + hi * dh;
            for j in 0..half {
                let a = xd[base + j];
                let b = xd[base + half + j];
                xd[base + j] = a * crow[j] - b * srow[j];
                xd[base + half + j] = a * srow[j] + b * crow[j];
            }
        }
    }
}

/// Causal GQA attention: q [s, nh·dh], k/v [s, nkv·dh] -> ctx [s, nh·dh].
/// Query head `hi` attends with kv head `hi·nkv/nh` — identical to the
/// reference `hi / (nh/nkv)` grouping whenever nkv divides nh (every zoo
/// model), and well-defined for a non-divisible tail: the first
/// `nh mod nkv` kv heads serve one extra query head.
fn attention(q: &Tensor, k: &Tensor, v: &Tensor, nh: usize, nkv: usize,
             dh: usize) -> Tensor {
    let s = q.rows();
    let scale = 1.0 / (dh as f32).sqrt();
    let (qw, kw) = (nh * dh, nkv * dh);
    let (qd, kd, vd) = (q.data(), k.data(), v.data());
    let mut ctx = vec![0.0f32; s * qw];
    let mut scores = vec![0.0f32; s];
    for hi in 0..nh {
        let kv = hi * nkv / nh;
        for i in 0..s {
            let qrow = &qd[i * qw + hi * dh..i * qw + (hi + 1) * dh];
            // Scores over the causal window j <= i.
            let mut mx = f32::NEG_INFINITY;
            for j in 0..=i {
                let krow = &kd[j * kw + kv * dh..j * kw + (kv + 1) * dh];
                let dot: f32 = qrow
                    .iter()
                    .zip(krow)
                    .map(|(a, b)| a * b)
                    .sum();
                let sc = dot * scale;
                scores[j] = sc;
                mx = mx.max(sc);
            }
            let mut denom = 0.0f32;
            for sc in scores.iter_mut().take(i + 1) {
                *sc = (*sc - mx).exp();
                denom += *sc;
            }
            let inv = 1.0 / denom;
            let crow = &mut ctx[i * qw + hi * dh..i * qw + (hi + 1) * dh];
            for j in 0..=i {
                let wgt = scores[j] * inv;
                let vrow = &vd[j * kw + kv * dh..j * kw + (kv + 1) * dh];
                for (c, vv) in crow.iter_mut().zip(vrow) {
                    *c += wgt * vv;
                }
            }
        }
    }
    Tensor::new(ctx, vec![s, qw])
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Single-query causal GQA attention over a KV cache window: q [nh·dh],
/// `kv` the paged layer view (K/V rows gathered through the slot's
/// block table), `rows` the window's ring rows oldest → newest
/// (chronological, so the score/weight accumulation order matches the
/// full-sequence `attention` and results agree to fp rounding). Same
/// head mapping as `attention`. Page-table lookups are hoisted out of
/// the per-head loops: one row locator per window row.
///
/// Precision dispatch happens ONCE per call on the layer's storage
/// width: f32 layers take the pre-quantization loops verbatim (the
/// bit-identity contract), quantized layers fuse dequant into the QK
/// dot and V accumulation — the hot loop streams 1-byte (int8) or
/// ½-byte (int4) codes plus one (scale, zero) pair per row-segment and
/// never materializes f32 K/V rows, which is the whole bandwidth win.
fn decode_attention(q: &[f32], kv: &LayerKv, rows: &[usize],
                    nh: usize, nkv: usize, dh: usize) -> Vec<f32> {
    let scale = 1.0 / (dh as f32).sqrt();
    let offs: Vec<usize> = rows.iter().map(|&r| kv.offset(r)).collect();
    match kv.bits() {
        16 => decode_attention_f32(q, kv, &offs, nh, nkv, dh, scale),
        bits => {
            decode_attention_quant(q, kv, &offs, nh, nkv, dh, scale,
                                   bits)
        }
    }
}

/// The raw-f32 arm: exactly the pre-quantization float operations in
/// the same order (pinned bit-identical by `rust/tests/kv_quant.rs`).
fn decode_attention_f32(q: &[f32], kv: &LayerKv, offs: &[usize],
                        nh: usize, nkv: usize, dh: usize, scale: f32)
                        -> Vec<f32> {
    let mut ctx = vec![0.0f32; nh * dh];
    let mut scores = vec![0.0f32; offs.len()];
    for hi in 0..nh {
        let kvh = hi * nkv / nh;
        let qrow = &q[hi * dh..(hi + 1) * dh];
        let mut mx = f32::NEG_INFINITY;
        for (j, &off) in offs.iter().enumerate() {
            let krow = &kv.k_at(off)[kvh * dh..(kvh + 1) * dh];
            let dot: f32 =
                qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
            let sc = dot * scale;
            scores[j] = sc;
            mx = mx.max(sc);
        }
        let mut denom = 0.0f32;
        for sc in scores.iter_mut() {
            *sc = (*sc - mx).exp();
            denom += *sc;
        }
        let inv = 1.0 / denom;
        let crow = &mut ctx[hi * dh..(hi + 1) * dh];
        for (j, &off) in offs.iter().enumerate() {
            let wgt = scores[j] * inv;
            let vrow = &kv.v_at(off)[kvh * dh..(kvh + 1) * dh];
            for (c, vv) in crow.iter_mut().zip(vrow) {
                *c += wgt * vv;
            }
        }
    }
    ctx
}

/// The quantized arm, scale-multiply style (the PR 7 LUT family's
/// algebra without a table): with `x̂ = s·(c − z)` per row-segment,
///
///   QK:  q·k̂ = s·(Σ qᵢ·cᵢ) − s·z·(Σ qᵢ)   — Σ qᵢ hoisted per head,
///   V:   ctx += p·v̂ = (p·s)·cⱼ − (p·s·z)   — two fused constants,
///
/// so the inner loops touch only integer codes; scales enter once per
/// (row, head) segment. Int4 unpacks two codes per byte in place.
#[allow(clippy::too_many_arguments)]
fn decode_attention_quant(q: &[f32], kv: &LayerKv, offs: &[usize],
                          nh: usize, nkv: usize, dh: usize, scale: f32,
                          bits: u8) -> Vec<f32> {
    let mut ctx = vec![0.0f32; nh * dh];
    let mut scores = vec![0.0f32; offs.len()];
    for hi in 0..nh {
        let kvh = hi * nkv / nh;
        let qrow = &q[hi * dh..(hi + 1) * dh];
        let qsum: f32 = qrow.iter().sum();
        let mut mx = f32::NEG_INFINITY;
        for (j, &off) in offs.iter().enumerate() {
            let (s, z) = kv.k_meta(off, kvh);
            let codes = kv.k_codes(off, kvh);
            let mut cdot = 0.0f32;
            if bits == 8 {
                for (a, &c) in qrow.iter().zip(codes) {
                    cdot += a * c as f32;
                }
            } else {
                for (i, &b) in codes.iter().enumerate() {
                    cdot += qrow[2 * i] * (b & 0xf) as f32
                        + qrow[2 * i + 1] * (b >> 4) as f32;
                }
            }
            let sc = s * (cdot - z * qsum) * scale;
            scores[j] = sc;
            mx = mx.max(sc);
        }
        let mut denom = 0.0f32;
        for sc in scores.iter_mut() {
            *sc = (*sc - mx).exp();
            denom += *sc;
        }
        let inv = 1.0 / denom;
        let crow = &mut ctx[hi * dh..(hi + 1) * dh];
        for (j, &off) in offs.iter().enumerate() {
            let wgt = scores[j] * inv;
            let (s, z) = kv.v_meta(off, kvh);
            let codes = kv.v_codes(off, kvh);
            let a = wgt * s;
            let b0 = a * z;
            if bits == 8 {
                for (c, &cc) in crow.iter_mut().zip(codes) {
                    *c += a * cc as f32 - b0;
                }
            } else {
                for (i, &byte) in codes.iter().enumerate() {
                    crow[2 * i] += a * (byte & 0xf) as f32 - b0;
                    crow[2 * i + 1] += a * (byte >> 4) as f32 - b0;
                }
            }
        }
    }
    ctx
}

/// Shared transformer stack for the KV-cached paths (`decode_batch_with`
/// and `prefill_chunk_with`): takes the embedded input rows `h`, runs
/// every layer — rmsnorm → shared q/k/v projections → RoPE at the
/// caller's per-row tables → a caller-supplied append+attend pass (the
/// ONLY place the two data flows differ: which slot each row appends to
/// and which ring window it attends over, `fill_ctx(l, q, k, v) -> ctx`)
/// → output projection → SwiGLU FFN — then the final norm + unembed.
/// One body means a change to the forward math cannot silently split
/// the "prefill rows bit-identical to decode rows" contract.
fn kv_forward(prep: &Prepared, mut h: Tensor, cos: &[f32], sin: &[f32],
              mut fill_ctx: impl FnMut(usize, &Tensor, &Tensor, &Tensor)
                  -> Vec<f32>) -> Tensor {
    let cfg = prep.cfg;
    let (nh, nkv, dh) = (cfg.n_heads, cfg.n_kv, cfg.d_head);
    let rows = h.rows();
    let qw = nh * dh;
    for (l, layer) in prep.layers.iter().enumerate() {
        // Attention block: shared projections, per-row append+attend.
        let wk = prep.workers;
        let x1 = rmsnorm(&h, &layer.ln1);
        let mut q = layer.wq.apply(&x1, wk); // [rows, nh·dh]
        let mut km = layer.wk.apply(&x1, wk); // [rows, nkv·dh]
        let vm = layer.wv.apply(&x1, wk); // [rows, nkv·dh]
        rope(&mut q, nh, dh, cos, sin);
        rope(&mut km, nkv, dh, cos, sin);
        let ctx = Tensor::new(fill_ctx(l, &q, &km, &vm),
                              vec![rows, qw]);
        let attn_out = layer.wo.apply(&ctx, wk);
        h = h.add(&attn_out);
        // FFN block (SwiGLU).
        let x2 = rmsnorm(&h, &layer.ln2);
        let gate = layer.wgate.apply(&x2, wk);
        let up = layer.wup.apply(&x2, wk);
        let mut mid = gate;
        for (g, u) in mid.data_mut().iter_mut().zip(up.data()) {
            *g = silu(*g) * u;
        }
        let down = layer.wdown.apply(&mid, wk);
        h = h.add(&down);
    }
    let hf = rmsnorm(&h, prep.lnf);
    matmul(&hf, prep.unembed)
}

/// One KV-cached decode step over a prepared (dense-ref or packed) model
/// — the B=1 case of `decode_batch_with` over the cache's one-slot pool.
/// Returns next-token logits [vocab].
fn decode_with(prep: &Prepared, cache: &mut KvCache, token: i32)
    -> Result<Tensor> {
    let v = prep.cfg.vocab;
    let logits = decode_batch_with(prep, cache.pool_mut(), &[(0, token)])?;
    Ok(logits.reshape(vec![v]))
}

/// One batched KV-cached decode step over a prepared (dense-ref or
/// packed) model: every `(slot, token)` pair in `active` consumes one
/// token at that slot's position. The batch shares each projection —
/// one (fused-dequant) GEMM applies the weights to all rows, so a packed
/// weight group is decoded once per step instead of once per sequence —
/// while RoPE phases, K/V appends and the attention window stay strictly
/// per-slot. Row math is identical to the single-sequence step (same
/// kernels, k-ascending accumulation), so row `i` of the result is
/// bit-identical to running `decode_step` on slot `active[i].0` alone.
/// All slots advance after the last layer. Returns logits
/// [active.len(), vocab], rows in `active` order.
fn decode_batch_with(prep: &Prepared, pool: &mut KvCachePool,
                     active: &[(usize, i32)]) -> Result<Tensor> {
    let cfg = prep.cfg;
    let d = cfg.d_model;
    let (nh, nkv, dh) = (cfg.n_heads, cfg.n_kv, cfg.d_head);
    let half = dh / 2;
    let m = active.len();
    ensure!(m > 0, "decode_batch: empty step");
    ensure!(pool.matches(cfg),
            "KV cache pool geometry does not match model '{}' \
             (layers {} kv {} dh {})",
            cfg.name, cfg.n_layers, nkv, dh);
    for (i, &(slot, token)) in active.iter().enumerate() {
        ensure!(token >= 0 && (token as usize) < cfg.vocab,
                "token id {token} out of range (vocab {})", cfg.vocab);
        ensure!(pool.is_active(slot),
                "decode_batch: slot {slot} is not admitted");
        ensure!(!active[..i].iter().any(|&(s, _)| s == slot),
                "decode_batch: slot {slot} appears twice in one step");
    }

    // Per-sequence RoPE rows (each slot sits at its own position) and
    // attention windows (each slot's ring row for the current token is
    // written by `append` below before any layer attends).
    let positions: Vec<usize> =
        active.iter().map(|&(s, _)| pool.pos(s)).collect();
    let (cos, sin) = rope_tables_at(&positions, half);
    let windows: Vec<Vec<usize>> =
        active.iter().map(|&(s, _)| pool.window_rows(s)).collect();

    // h = embed[tokens]  [m, d]
    let mut h = Tensor::zeros(vec![m, d]);
    for (ri, &(_, token)) in active.iter().enumerate() {
        h.row_mut(ri).copy_from_slice(prep.embed.row(token as usize));
    }

    let qw = nh * dh;
    let logits = kv_forward(prep, h, &cos, &sin, |l, q, km, vm| {
        // Each row appends to its own slot, then attends over its own
        // ring window (the just-written row included).
        let mut ctx = vec![0.0f32; m * qw];
        for (ri, &(slot, _)) in active.iter().enumerate() {
            pool.append(slot, l, km.row(ri), vm.row(ri));
            let view = pool.layer_view(l, slot);
            let c = decode_attention(q.row(ri), &view, &windows[ri],
                                     nh, nkv, dh);
            ctx[ri * qw..(ri + 1) * qw].copy_from_slice(&c);
        }
        ctx
    });
    for &(slot, _) in active {
        pool.advance(slot);
    }
    Ok(logits)
}

/// Chunked prefill over a prepared (dense-ref or packed) model: consume
/// a whole window of `tokens` for ONE slot at its current position —
/// every projection runs as one multi-row (fused-dequant) GEMM over the
/// chunk instead of one single-row kernel per token, and K/V rows land
/// in the slot's pages in bulk. Causality INSIDE the chunk is per-row
/// attention windows: chunk row `i` (absolute position `pos + i`)
/// attends over positions `pos + i + 1 - cap ..= pos + i`, which
/// includes the chunk's own earlier rows. Row math reuses the decode
/// step's kernels verbatim (row-independent, k-ascending accumulation),
/// so row `i` is BIT-IDENTICAL to feeding `tokens[i]` through
/// `decode_batch` at that position — chunking changes wall clock, never
/// bits (pinned by `rust/tests/prefill_equivalence.rs`).
///
/// Page writes: the chunk's blocks are mapped — and copy-on-write
/// privatized — up front via `alloc_range`, then each layer bulk-appends
/// its K/V rows. In the exact regime (`pos + n <= cap`) the whole layer
/// appends before any row attends: no chunk write lands on a ring row an
/// earlier chunk row's window still reads. Past `cap` (an
/// eviction-inducing overlong prompt) that no longer holds — the write
/// for chunk row `j` recycles the block holding position `pos + j - cap`,
/// which rows `i` in `(j - cap, j)` still read — so the evicting regime
/// interleaves append→attend per row, preserving the per-token order
/// (identical results in both regimes; the split is purely about when
/// overwrites become visible).
///
/// The slot advances by the whole chunk after the last layer. Returns
/// logits `[tokens.len(), vocab]`, row `i` for position `pos + i`; the
/// caller samples from the last row when the chunk ends the prompt.
/// `tokens.len()` must not exceed the slot's ring capacity (a longer
/// chunk would overwrite its own rows — callers split at `cap`).
fn prefill_chunk_with(prep: &Prepared, pool: &mut KvCachePool,
                      slot: usize, tokens: &[i32]) -> Result<Tensor> {
    let cfg = prep.cfg;
    let d = cfg.d_model;
    let (nh, nkv, dh) = (cfg.n_heads, cfg.n_kv, cfg.d_head);
    let half = dh / 2;
    let n = tokens.len();
    ensure!(n > 0, "prefill_chunk: empty chunk");
    ensure!(pool.matches(cfg),
            "KV cache pool geometry does not match model '{}' \
             (layers {} kv {} dh {})",
            cfg.name, cfg.n_layers, nkv, dh);
    ensure!(pool.is_active(slot),
            "prefill_chunk: slot {slot} is not admitted");
    let cap = pool.capacity(slot);
    ensure!(n <= cap,
            "prefill_chunk: chunk of {n} tokens exceeds slot {slot}'s \
             ring capacity {cap} — split the chunk");
    for &t in tokens {
        ensure!(t >= 0 && (t as usize) < cfg.vocab,
                "token id {t} out of range (vocab {})", cfg.vocab);
    }

    let pos = pool.pos(slot);
    let positions: Vec<usize> = (pos..pos + n).collect();
    let (cos, sin) = rope_tables_at(&positions, half);
    let windows: Vec<Vec<usize>> = positions
        .iter()
        .map(|&p| pool.window_rows_at(slot, p))
        .collect();
    // Map (and CoW-privatize) every block the chunk writes, up front.
    pool.alloc_range(slot, n);
    let bulk = pos + n <= cap; // see the regime note above

    // h = embed[tokens]  [n, d]
    let mut h = Tensor::zeros(vec![n, d]);
    for (ri, &t) in tokens.iter().enumerate() {
        h.row_mut(ri).copy_from_slice(prep.embed.row(t as usize));
    }

    let qw = nh * dh;
    let logits = kv_forward(prep, h, &cos, &sin, |l, q, km, vm| {
        // Whole-chunk bulk append when safe, per-row interleave in the
        // evicting regime (see the regime note above); attention is
        // per-row over that row's own causal window either way.
        let mut ctx = vec![0.0f32; n * qw];
        if bulk {
            // After the bulk append, chunk rows attend over disjoint
            // read-only windows — row-independent, so the chunk's
            // attention splits across the prepared worker budget.
            // (The evicting branch below interleaves append→attend and
            // MUST stay sequential.) `parallel_map` returns rows in
            // index order, so splitting never reorders or changes bits.
            pool.append_rows(slot, l, km.data(), vm.data());
            let view = pool.layer_view(l, slot);
            let rows = parallel_map(n, prep.workers, |i| {
                decode_attention(q.row(i), &view, &windows[i],
                                 nh, nkv, dh)
            });
            for (i, c) in rows.iter().enumerate() {
                ctx[i * qw..(i + 1) * qw].copy_from_slice(c);
            }
        } else {
            for i in 0..n {
                pool.append_row_ahead(slot, l, i, km.row(i), vm.row(i));
                let view = pool.layer_view(l, slot);
                let c = decode_attention(q.row(i), &view, &windows[i],
                                         nh, nkv, dh);
                ctx[i * qw..(i + 1) * qw].copy_from_slice(&c);
            }
        }
        ctx
    });
    pool.advance_by(slot, n);
    Ok(logits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_entry() -> ModelEntry {
        ModelEntry::synthetic(ModelConfig::test_config())
    }

    #[test]
    fn rmsnorm_unit_rows() {
        // A row of equal values x: ms = x², out = x/√(x²+eps)·g ≈ sign·g.
        let x = Tensor::new(vec![3.0; 4], vec![1, 4]);
        let g = Tensor::new(vec![1.0, 2.0, 0.5, 1.0], vec![4]);
        let y = rmsnorm(&x, &g);
        for (yv, gv) in y.data().iter().zip(g.data()) {
            assert!((yv - gv).abs() < 1e-4, "{yv} vs {gv}");
        }
    }

    #[test]
    fn rope_preserves_pair_norm_and_fixes_pos0() {
        let mut rng = Rng::new(50);
        let dh = 8;
        let mut x = Tensor::randn(vec![4, dh], &mut rng);
        let orig = x.clone();
        let half = dh / 2;
        let mut cos = vec![0.0f32; 4 * half];
        let mut sin = vec![0.0f32; 4 * half];
        for si in 0..4 {
            for j in 0..half {
                let inv = ROPE_BASE.powf(-(j as f32) / half as f32);
                cos[si * half + j] = (si as f32 * inv).cos();
                sin[si * half + j] = (si as f32 * inv).sin();
            }
        }
        rope(&mut x, 1, dh, &cos, &sin);
        // Position 0: identity rotation.
        assert_eq!(x.row(0), orig.row(0));
        // Rotations preserve each (j, j+half) pair norm.
        for si in 0..4 {
            for j in 0..half {
                let n0 = orig.at(si, j).powi(2)
                    + orig.at(si, j + half).powi(2);
                let n1 =
                    x.at(si, j).powi(2) + x.at(si, j + half).powi(2);
                assert!((n0 - n1).abs() < 1e-4, "{n0} vs {n1}");
            }
        }
    }

    #[test]
    fn attention_constant_values_pass_through() {
        // If every v row equals the same vector, softmax weights (which
        // sum to 1) must return exactly that vector for every query.
        let mut rng = Rng::new(51);
        let (s, nh, nkv, dh) = (5, 2, 1, 4);
        let q = Tensor::randn(vec![s, nh * dh], &mut rng);
        let k = Tensor::randn(vec![s, nkv * dh], &mut rng);
        let vconst: Vec<f32> = (0..nkv * dh).map(|i| i as f32).collect();
        let mut v = Tensor::zeros(vec![s, nkv * dh]);
        for r in 0..s {
            v.row_mut(r).copy_from_slice(&vconst);
        }
        let ctx = attention(&q, &k, &v, nh, nkv, dh);
        for r in 0..s {
            for hi in 0..nh {
                for j in 0..dh {
                    assert!((ctx.at(r, hi * dh + j) - vconst[j]).abs()
                            < 1e-5);
                }
            }
        }
    }

    #[test]
    fn forward_is_causal() {
        // Changing the last token must not change earlier logits.
        let entry = tiny_entry();
        let cfg = &entry.config;
        let mut rng = Rng::new(52);
        let w = Weights::synth(cfg, &mut rng, &[], &[]);
        let e = NativeEngine::with_workers(1);
        let s = cfg.seq;
        let mut a: Vec<i32> =
            (0..s).map(|i| (i % cfg.vocab) as i32).collect();
        let la = e.forward(&entry, &a, 1, &w).unwrap();
        a[s - 1] = (a[s - 1] + 1) % cfg.vocab as i32;
        let lb = e.forward(&entry, &a, 1, &w).unwrap();
        let v = cfg.vocab;
        let prefix = (s - 1) * v;
        assert_eq!(la.data()[..prefix], lb.data()[..prefix]);
        assert_ne!(la.data()[prefix..], lb.data()[prefix..]);
    }

    #[test]
    fn forward_deterministic_and_worker_invariant() {
        let entry = tiny_entry();
        let cfg = &entry.config;
        let mut rng = Rng::new(53);
        let w = Weights::synth(cfg, &mut rng, &[], &[]);
        let tokens: Vec<i32> = (0..3 * cfg.seq)
            .map(|i| ((i * 7) % cfg.vocab) as i32)
            .collect();
        let l1 = NativeEngine::with_workers(1)
            .forward(&entry, &tokens, 3, &w)
            .unwrap();
        let l4 = NativeEngine::with_workers(4)
            .forward(&entry, &tokens, 3, &w)
            .unwrap();
        assert_eq!(l1, l4);
        assert_eq!(l1.dims(), &[3, cfg.seq, cfg.vocab]);
        assert!(l1.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn forward_rejects_bad_tokens() {
        let entry = tiny_entry();
        let cfg = &entry.config;
        let mut rng = Rng::new(54);
        let w = Weights::synth(cfg, &mut rng, &[], &[]);
        let e = NativeEngine::with_workers(1);
        let bad = vec![cfg.vocab as i32; cfg.seq];
        assert!(e.forward(&entry, &bad, 1, &w).is_err());
        assert!(e.forward(&entry, &[0i32; 3], 1, &w).is_err());
    }

    #[test]
    fn stacked_matmul_matches_copied_layer_matmul() {
        let mut rng = Rng::new(56);
        let stacked = Tensor::randn(vec![3, 10, 7], &mut rng);
        let x = Tensor::randn(vec![4, 10], &mut rng);
        for l in 0..3 {
            let a = stacked_matmul(&x, &stacked, l, 1 + rng.below(3));
            let b = matmul(&x, &stacked.slice0(l));
            assert_eq!(a, b, "layer {l}"); // bit-identical by design
        }
    }

    #[test]
    fn decode_attention_matches_full_attention_last_row() {
        let mut rng = Rng::new(57);
        let (s, nh, nkv, dh) = (6, 4, 2, 4);
        let q = Tensor::randn(vec![s, nh * dh], &mut rng);
        let k = Tensor::randn(vec![s, nkv * dh], &mut rng);
        let v = Tensor::randn(vec![s, nkv * dh], &mut rng);
        let full = attention(&q, &k, &v, nh, nkv, dh);
        // Ring rows == positions when cap >= s and no wrap; the paged
        // view gathers them back out of the arena.
        let mut pool = KvCachePool::new(1, nkv, dh, 1);
        let slot = pool.admit(s).unwrap();
        for j in 0..s {
            pool.append(slot, 0, k.row(j), v.row(j));
            pool.advance(slot);
        }
        let rows: Vec<usize> = (0..s).collect();
        let view = pool.layer_view(0, slot);
        let dec = decode_attention(&q.data()[(s - 1) * nh * dh..],
                                   &view, &rows, nh, nkv, dh);
        for (a, b) in dec.iter().zip(full.row(s - 1)) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn decode_steps_match_forward_logits() {
        let entry = tiny_entry();
        let cfg = entry.config.clone();
        let mut rng = Rng::new(58);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let e = NativeEngine::with_workers(1);
        let tokens: Vec<i32> = (0..cfg.seq)
            .map(|_| rng.below(cfg.vocab) as i32)
            .collect();
        let full = e.forward(&entry, &tokens, 1, &w).unwrap();
        let mut cache = KvCache::for_model(&cfg, cfg.seq);
        for (si, &t) in tokens.iter().enumerate() {
            let step = e.decode_step(&entry, &mut cache, t, &w).unwrap();
            assert_eq!(step.dims(), &[cfg.vocab]);
            let frow = &full.data()[si * cfg.vocab..(si + 1) * cfg.vocab];
            let mx = step
                .data()
                .iter()
                .zip(frow)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(mx < 1e-4, "pos {si}: max abs diff {mx}");
        }
        assert_eq!(cache.pos(), cfg.seq);
    }

    #[test]
    fn decode_step_validates_token_and_cache() {
        let entry = tiny_entry();
        let cfg = entry.config.clone();
        let mut rng = Rng::new(59);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let e = NativeEngine::with_workers(1);
        let mut cache = KvCache::for_model(&cfg, cfg.seq);
        assert!(e
            .decode_step(&entry, &mut cache, cfg.vocab as i32, &w)
            .is_err());
        let mut wrong = KvCache::new(cfg.n_layers + 1, cfg.n_kv,
                                     cfg.d_head, cfg.seq);
        assert!(e.decode_step(&entry, &mut wrong, 0, &w).is_err());
        assert!(e.supports_decode());
    }

    #[test]
    fn decode_batch_rows_match_single_steps() {
        // Three sequences decoded as one batch must produce, row for
        // row, the logits of three independent single-sequence decodes.
        let entry = tiny_entry();
        let cfg = entry.config.clone();
        let mut rng = Rng::new(60);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let e = NativeEngine::with_workers(1);
        let streams: Vec<Vec<i32>> = (0..3)
            .map(|_| {
                (0..cfg.seq)
                    .map(|_| rng.below(cfg.vocab) as i32)
                    .collect()
            })
            .collect();
        // Sequential reference.
        let mut seq_logits: Vec<Vec<Tensor>> = Vec::new();
        for s in &streams {
            let mut cache = KvCache::for_model(&cfg, cfg.seq);
            seq_logits.push(
                s.iter()
                    .map(|&t| {
                        e.decode_step(&entry, &mut cache, t, &w).unwrap()
                    })
                    .collect(),
            );
        }
        // Batched.
        let mut pool = KvCachePool::for_model(&cfg, 3);
        let slots: Vec<usize> =
            (0..3).map(|_| pool.admit(cfg.seq).unwrap()).collect();
        for step in 0..cfg.seq {
            let active: Vec<(usize, i32)> = slots
                .iter()
                .zip(&streams)
                .map(|(&slot, s)| (slot, s[step]))
                .collect();
            let logits =
                e.decode_batch(&entry, &mut pool, &active, &w).unwrap();
            assert_eq!(logits.dims(), &[3, cfg.vocab]);
            for (ri, per_seq) in seq_logits.iter().enumerate() {
                assert_eq!(logits.row(ri), per_seq[step].data(),
                           "row {ri} step {step} diverged");
            }
        }
    }

    #[test]
    fn decode_batch_validates_slots_and_tokens() {
        let entry = tiny_entry();
        let cfg = entry.config.clone();
        let mut rng = Rng::new(61);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let e = NativeEngine::with_workers(1);
        let mut pool = KvCachePool::for_model(&cfg, 2);
        let s0 = pool.admit(cfg.seq).unwrap();
        // Empty step.
        assert!(e.decode_batch(&entry, &mut pool, &[], &w).is_err());
        // Unadmitted slot.
        assert!(e
            .decode_batch(&entry, &mut pool, &[(s0 + 1, 0)], &w)
            .is_err());
        // Duplicate slot in one step.
        assert!(e
            .decode_batch(&entry, &mut pool, &[(s0, 0), (s0, 1)], &w)
            .is_err());
        // Out-of-range token.
        assert!(e
            .decode_batch(&entry, &mut pool, &[(s0, cfg.vocab as i32)],
                          &w)
            .is_err());
        // Geometry mismatch.
        let mut wrong = KvCachePool::new(cfg.n_layers + 1, cfg.n_kv,
                                         cfg.d_head, 1);
        wrong.admit(cfg.seq).unwrap();
        assert!(e.decode_batch(&entry, &mut wrong, &[(0, 0)], &w)
            .is_err());
        // A failed step must not advance any slot.
        assert_eq!(pool.pos(s0), 0);
    }

    #[test]
    fn prefill_chunk_rows_match_per_token_decode_exactly() {
        // One chunk covering a whole prompt must reproduce, bit for
        // bit, the per-token decode logits AND leave a cache that
        // decodes the continuation identically.
        let entry = tiny_entry();
        let cfg = entry.config.clone();
        let mut rng = Rng::new(63);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let e = NativeEngine::with_workers(1);
        let tokens: Vec<i32> = (0..cfg.seq + 2)
            .map(|_| rng.below(cfg.vocab) as i32)
            .collect();
        let cap = tokens.len() + 2;
        let split = cfg.seq; // prompt prefix; the rest decodes after
        let mut ref_pool = KvCachePool::for_model(&cfg, 1);
        let rs = ref_pool.admit(cap).unwrap();
        let mut ref_rows = Vec::new();
        for &t in &tokens {
            let l = e
                .decode_batch(&entry, &mut ref_pool, &[(rs, t)], &w)
                .unwrap();
            ref_rows.push(l.into_data());
        }
        let mut pool = KvCachePool::for_model(&cfg, 1);
        let s = pool.admit(cap).unwrap();
        let chunk = e
            .prefill_chunk(&entry, &mut pool, s, &tokens[..split], &w)
            .unwrap();
        assert_eq!(chunk.dims(), &[split, cfg.vocab]);
        for (i, r) in ref_rows.iter().enumerate().take(split) {
            assert_eq!(chunk.row(i), r.as_slice(),
                       "chunk row {i} diverged from per-token decode");
        }
        assert_eq!(pool.pos(s), split);
        for (i, &t) in tokens.iter().enumerate().skip(split) {
            let l = e
                .decode_batch(&entry, &mut pool, &[(s, t)], &w)
                .unwrap();
            assert_eq!(l.data(), ref_rows[i].as_slice(),
                       "post-chunk decode step {i} diverged");
        }
        pool.check_page_accounting().unwrap();
    }

    #[test]
    fn verify_chunk_rows_match_decode_and_roll_back_exactly() {
        // The speculative verify contract end to end: all window rows
        // bit-identical to per-token decode, then a partial-acceptance
        // rollback (`truncate`) after which the sequence decodes on
        // exactly as if the rejected tail had never been appended.
        let entry = tiny_entry();
        let cfg = entry.config.clone();
        let mut rng = Rng::new(71);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let e = NativeEngine::with_workers(1);
        let tokens: Vec<i32> = (0..cfg.seq + 4)
            .map(|_| rng.below(cfg.vocab) as i32)
            .collect();
        let cap = tokens.len() + 2;
        let split = 3; // committed prefix before the verify window
        let kwin = 5; // verify window width (next token + 4 "drafts")
        let accept = 2; // rows kept; the other 3 roll back
        let mut ref_pool = KvCachePool::for_model(&cfg, 1);
        let rs = ref_pool.admit(cap).unwrap();
        let mut ref_rows = Vec::new();
        for &t in &tokens {
            let l = e
                .decode_batch(&entry, &mut ref_pool, &[(rs, t)], &w)
                .unwrap();
            ref_rows.push(l.into_data());
        }
        let mut pool = KvCachePool::for_model(&cfg, 1);
        let s = pool.admit(cap).unwrap();
        for &t in &tokens[..split] {
            e.decode_batch(&entry, &mut pool, &[(s, t)], &w).unwrap();
        }
        let win = e
            .verify_chunk(&entry, &mut pool, s,
                          &tokens[split..split + kwin], &w)
            .unwrap();
        assert_eq!(win.dims(), &[kwin, cfg.vocab]);
        for i in 0..kwin {
            assert_eq!(win.row(i), ref_rows[split + i].as_slice(),
                       "verify row {i} diverged from per-token decode");
        }
        // Partial acceptance: keep `accept` rows, rewind the rest.
        pool.truncate(s, split + accept);
        assert_eq!(pool.pos(s), split + accept);
        pool.check_page_accounting().unwrap();
        // Decoding on from the rollback point reproduces the reference
        // stream bit for bit — the speculative tail left no residue.
        for (i, &t) in tokens.iter().enumerate().skip(split + accept) {
            let l = e
                .decode_batch(&entry, &mut pool, &[(s, t)], &w)
                .unwrap();
            assert_eq!(l.data(), ref_rows[i].as_slice(),
                       "post-rollback decode step {i} diverged");
        }
        pool.check_page_accounting().unwrap();
        // The no-wrap guard: a window that would overrun the ring is
        // rejected BEFORE any mutation (rollback would be unsound).
        let mut small = KvCachePool::for_model(&cfg, 1);
        let ss = small.admit(kwin - 1).unwrap();
        assert!(e.verify_chunk(&entry, &mut small, ss,
                               &tokens[..kwin], &w).is_err());
        assert_eq!(small.pos(ss), 0, "rejected verify must not mutate");
    }

    #[test]
    fn prefill_chunk_validates_before_mutating() {
        let entry = tiny_entry();
        let cfg = entry.config.clone();
        let mut rng = Rng::new(64);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let e = NativeEngine::with_workers(1);
        let mut pool = KvCachePool::for_model(&cfg, 2);
        let s = pool.admit(4).unwrap();
        // Empty chunk.
        assert!(e.prefill_chunk(&entry, &mut pool, s, &[], &w).is_err());
        // Unadmitted slot.
        assert!(e
            .prefill_chunk(&entry, &mut pool, s + 1, &[0], &w)
            .is_err());
        // Out-of-range token.
        assert!(e
            .prefill_chunk(&entry, &mut pool, s,
                           &[cfg.vocab as i32], &w)
            .is_err());
        // Chunk longer than the slot's ring.
        assert!(e
            .prefill_chunk(&entry, &mut pool, s, &[0; 5], &w)
            .is_err());
        // Geometry mismatch.
        let mut wrong = KvCachePool::new(cfg.n_layers + 1, cfg.n_kv,
                                         cfg.d_head, 1);
        wrong.admit(4).unwrap();
        assert!(e.prefill_chunk(&entry, &mut wrong, 0, &[0], &w)
            .is_err());
        // No failed call advanced the slot or touched a page.
        assert_eq!(pool.pos(s), 0);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn malformed_quantized_model_errors_instead_of_panicking() {
        use crate::quant::Backend;
        let entry = tiny_entry();
        let cfg = entry.config.clone();
        let mut rng = Rng::new(62);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let mut qm = QuantizedModel::quantize(
            &cfg, &w, &vec![4u8; cfg.n_layers], 8, Backend::Rtn, None, 1);
        qm.mats[1].remove("wo");
        let e = NativeEngine::with_workers(1);
        let tokens = vec![0i32; cfg.seq];
        let err = e
            .forward_packed(&entry, &tokens, 1, &qm)
            .unwrap_err()
            .to_string();
        assert!(err.contains("missing projection 'wo' at layer 1"),
                "unexpected error: {err}");
        let mut cache = KvCache::for_model(&cfg, cfg.seq);
        assert!(e
            .decode_step_packed(&entry, &mut cache, 0, &qm)
            .is_err());
        // Wrong layer count is also an error, not a panic.
        qm.mats.pop();
        assert!(e.forward_packed(&entry, &tokens, 1, &qm).is_err());
    }

    #[test]
    fn probe_shapes_match_config() {
        let entry = tiny_entry();
        let cfg = &entry.config;
        let mut rng = Rng::new(55);
        let w = Weights::synth(cfg, &mut rng, &[], &[]);
        let e = NativeEngine::with_workers(2);
        let b = 2;
        let tokens: Vec<i32> = (0..b * cfg.seq)
            .map(|i| ((i * 3) % cfg.vocab) as i32)
            .collect();
        let p = e.probe(&entry, &tokens, b, &w).unwrap();
        let rows = b * cfg.seq;
        assert_eq!(p.resid_in.len(), cfg.n_layers);
        assert_eq!(p.resid_in[0].dims(), &[rows, cfg.d_model]);
        assert_eq!(p.final_resid.dims(), &[rows, cfg.d_model]);
        assert_eq!(p.x_ln1[0].dims(), &[rows, cfg.d_model]);
        assert_eq!(p.attn_ctx[0].dims(),
                   &[rows, cfg.n_heads * cfg.d_head]);
        assert_eq!(p.ffn_mid[0].dims(), &[rows, cfg.d_ffn]);
        assert_eq!(p.logits.dims(), &[b, cfg.seq, cfg.vocab]);
        // resid_in[0] is the embedding of the tokens.
        for (si, &t) in tokens.iter().enumerate() {
            assert_eq!(p.resid_in[0].row(si),
                       w.get("embed").row(t as usize));
        }
    }

    /// The prefill worker budget (kernel splits + bulk-regime parallel
    /// attention) must never change logits — rows are computed
    /// independently and stitched in index order.
    #[test]
    fn prefill_chunk_is_worker_invariant() {
        let entry = tiny_entry();
        let cfg = &entry.config;
        let mut rng = Rng::new(58);
        let w = Weights::synth(cfg, &mut rng, &[], &[]);
        let tokens: Vec<i32> = (0..10)
            .map(|i| ((i * 7) % cfg.vocab) as i32)
            .collect();
        let run = |workers: usize| {
            let e = NativeEngine::with_workers(workers);
            let mut pool = KvCachePool::for_model(cfg, 1);
            let s = pool.admit(tokens.len()).unwrap();
            e.prefill_chunk(&entry, &mut pool, s, &tokens, &w).unwrap()
        };
        assert_eq!(run(1), run(4),
                   "prefill logits changed with worker count");
    }
}
