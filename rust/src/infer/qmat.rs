//! Packed quantized matrices + the fused dequant-matmul kernel — the
//! native serving format. Codes stay in the 2/4-bit `quant::pack` layout
//! end to end; dequantization happens inside the matmul's cache-blocked
//! K panels, so the full f32 weight matrix is never materialized (unlike
//! the unpack-then-`tensor::matmul` baseline the benches compare against).

use std::collections::BTreeMap;

use crate::model::{ModelConfig, Weights, QUANT_WEIGHTS, WEIGHT_NAMES};
use crate::quant::{self, pack, Backend, HessianMap, QuantSpec, QuantizedMatrix};
use crate::tensor::Tensor;
use crate::util::pool::parallel_map;

/// One [K, N] weight in the packed serving layout: 2/4-bit codes packed
/// along K (`quant::pack`) plus per-(group, column) f32 scale/zero.
#[derive(Clone, Debug)]
pub struct PackedMatrix {
    pub k: usize,
    pub n: usize,
    pub bits: u8,
    pub group: usize,
    /// u8 [K·bits/8, N], little-endian sub-bytes along K.
    pub packed: Vec<u8>,
    /// f32 [K/group, N].
    pub scale: Vec<f32>,
    /// f32 [K/group, N].
    pub zero: Vec<f32>,
}

impl PackedMatrix {
    /// Pack an (unpacked-code) quantized matrix into the serving layout.
    pub fn from_quantized(q: &QuantizedMatrix) -> Self {
        PackedMatrix {
            k: q.k,
            n: q.n,
            bits: q.spec.bits,
            group: q.spec.group,
            packed: pack::pack(&q.codes, q.k, q.n, q.spec.bits),
            scale: q.scale.clone(),
            zero: q.zero.clone(),
        }
    }

    /// Total serving bytes (codes + scale/zero metadata).
    pub fn bytes(&self) -> usize {
        self.packed.len() + (self.scale.len() + self.zero.len()) * 4
    }

    /// Materialize the full f32 weight (tests / fallback paths only —
    /// the fused matmul never calls this). Delegates to the one
    /// group-affine dequant implementation in `quant`.
    pub fn dequantize(&self) -> Tensor {
        QuantizedMatrix {
            spec: QuantSpec::new(self.bits, self.group),
            codes: pack::unpack(&self.packed, self.k, self.n, self.bits),
            k: self.k,
            n: self.n,
            scale: self.scale.clone(),
            zero: self.zero.clone(),
        }
        .dequantize()
    }
}

/// K-panel height of the fused kernel (matches `tensor::matmul`'s
/// blocking so the two paths accumulate in the same order).
const BK: usize = 64;

/// Decode coordinates of packed weight row `kk`, shared by every fused
/// kernel: (packed byte row, sub-byte shift, scale row, zero row). The
/// kernels' per-row bit-identity contract depends on them all reading
/// the layout identically — keep this the single source of truth.
#[inline]
fn row_decode(pm: &PackedMatrix, kk: usize)
    -> (&[u8], u32, &[f32], &[f32]) {
    let bits = pm.bits as usize;
    let per = 8 / bits;
    let n = pm.n;
    let byte_row = kk / per;
    let shift = (bits * (kk % per)) as u32;
    let gr = kk / pm.group;
    (
        &pm.packed[byte_row * n..byte_row * n + n],
        shift,
        &pm.scale[gr * n..gr * n + n],
        &pm.zero[gr * n..gr * n + n],
    )
}

/// Fused dequant-matmul: `x [M, K] @ dequant(pm) -> [M, N]` without ever
/// materializing the f32 weight. Each K panel of `BK` rows is decoded
/// once into a small cache-resident buffer and reused across all M rows;
/// rows of `x` are split across `workers` threads via `util::pool`.
pub fn fused_matmul(x: &Tensor, pm: &PackedMatrix, workers: usize)
    -> Tensor {
    let (m, k) = (x.rows(), x.cols());
    assert_eq!(k, pm.k, "fused_matmul: x cols {k} != packed K {}", pm.k);
    let n = pm.n;
    let workers = workers.clamp(1, m.max(1));
    if workers == 1 {
        let data = fused_rows(x.data(), 0, m, pm);
        return Tensor::new(data, vec![m, n]);
    }
    // Contiguous row blocks, one per worker; each decodes its own panels.
    let per = m.div_ceil(workers);
    let ranges: Vec<(usize, usize)> = (0..workers)
        .map(|w| (w * per, ((w + 1) * per).min(m)))
        .filter(|(a, b)| a < b)
        .collect();
    let chunks = parallel_map(ranges.len(), ranges.len(), |i| {
        let (r0, r1) = ranges[i];
        fused_rows(x.data(), r0, r1, pm)
    });
    let mut data = Vec::with_capacity(m * n);
    for c in chunks {
        data.extend_from_slice(&c);
    }
    Tensor::new(data, vec![m, n])
}

/// Fused kernel body for output rows `r0..r1`.
fn fused_rows(xd: &[f32], r0: usize, r1: usize, pm: &PackedMatrix)
    -> Vec<f32> {
    let (k, n) = (pm.k, pm.n);
    let mask = (1u8 << pm.bits) - 1;
    let rows = r1 - r0;
    let mut out = vec![0.0f32; rows * n];
    let panel_rows = BK.min(k);
    let mut panel = vec![0.0f32; panel_rows * n];
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + BK).min(k);
        // Decode this K panel once: panel[kk-k0] = s·(code − z).
        for kk in k0..k1 {
            let (brow, shift, srow, zrow) = row_decode(pm, kk);
            let prow = &mut panel[(kk - k0) * n..(kk - k0 + 1) * n];
            for c in 0..n {
                let code = (brow[c] >> shift) & mask;
                prow[c] = srow[c] * (code as f32 - zrow[c]);
            }
        }
        // Accumulate the panel into every output row (ikj order).
        for i in r0..r1 {
            let xrow = &xd[i * k..(i + 1) * k];
            let orow = &mut out[(i - r0) * n..(i - r0 + 1) * n];
            for kk in k0..k1 {
                let aik = xrow[kk];
                let prow = &panel[(kk - k0) * n..(kk - k0 + 1) * n];
                for (o, p) in orow.iter_mut().zip(prow) {
                    *o += aik * p;
                }
            }
        }
        k0 = k1;
    }
    out
}

/// Single-row fused dequant-dot: `x [K] @ dequant(pm) -> [N]`, the
/// decode-path kernel. Skips the K-panel staging buffer entirely (for one
/// row there is no reuse to amortize it) and accumulates k-ascending with
/// the same `s·(code − z)` grouping as `fused_rows`, so the result is
/// bit-identical to `fused_matmul` on a [1, K] input.
pub fn fused_vecmat(x: &[f32], pm: &PackedMatrix) -> Vec<f32> {
    let (k, n) = (pm.k, pm.n);
    assert_eq!(x.len(), k, "fused_vecmat: x len {} != packed K {k}",
               x.len());
    let mask = (1u8 << pm.bits) - 1;
    let mut out = vec![0.0f32; n];
    for (kk, &a) in x.iter().enumerate() {
        if a == 0.0 {
            continue;
        }
        let (brow, shift, srow, zrow) = row_decode(pm, kk);
        for c in 0..n {
            let code = (brow[c] >> shift) & mask;
            out[c] += a * (srow[c] * (code as f32 - zrow[c]));
        }
    }
    out
}

/// Small-batch fused dequant-GEMM — the continuous-batching decode
/// kernel: `x [M, K] @ dequant(pm) -> [M, N]`, decoding each packed
/// weight row ONCE per call and applying it to every row of `x`, so the
/// per-token dequant + weight traffic of a decode step is divided by the
/// number of concurrently active sequences. (Running `fused_vecmat` per
/// sequence decodes the same weights M times.)
///
/// Unlike `fused_matmul` there is no K-panel staging buffer: one
/// dequantized weight row (`[N]` floats) stays cache-resident while it is
/// accumulated into all M output rows — the right blocking for the small
/// M (≤ ~16) of a decode batch, where a BK×N panel would evict the
/// output rows. Accumulation is k-ascending per output row with the same
/// `s·(code − z)` grouping, so each row is bit-identical to
/// `fused_vecmat` on that row (and to `fused_matmul`).
pub fn fused_gemm_small(x: &Tensor, pm: &PackedMatrix) -> Tensor {
    let (m, k) = (x.rows(), x.cols());
    assert_eq!(k, pm.k, "fused_gemm_small: x cols {k} != packed K {}",
               pm.k);
    let n = pm.n;
    let mask = (1u8 << pm.bits) - 1;
    let xd = x.data();
    let mut out = vec![0.0f32; m * n];
    let mut wrow = vec![0.0f32; n];
    for kk in 0..k {
        // Skip the decode when no row consumes this weight row (mirrors
        // the zero-skip in `fused_vecmat`, which never decodes it).
        if xd[kk..].iter().step_by(k).all(|&a| a == 0.0) {
            continue;
        }
        let (brow, shift, srow, zrow) = row_decode(pm, kk);
        // Dequantize weight row kk once...
        for c in 0..n {
            let code = (brow[c] >> shift) & mask;
            wrow[c] = srow[c] * (code as f32 - zrow[c]);
        }
        // ...and apply it to every active row.
        for i in 0..m {
            let a = xd[i * k + kk];
            if a == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, w) in orow.iter_mut().zip(&wrow) {
                *o += a * w;
            }
        }
    }
    Tensor::new(out, vec![m, n])
}

/// One projection of a quantized model: packed when the bit width has a
/// serving layout (2/4-bit), dense f32 fallback otherwise.
#[derive(Clone, Debug)]
pub enum QMat {
    Packed(PackedMatrix),
    Dense(Tensor),
}

impl QMat {
    pub fn bytes(&self) -> usize {
        match self {
            QMat::Packed(p) => p.bytes(),
            QMat::Dense(t) => t.len() * 4,
        }
    }
}

/// A full model in the native packed serving format: FP embeddings /
/// norms / unembed (standard practice — they are never quantized) plus
/// one `QMat` per (layer, projection). This is what the coordinator's
/// server deploys when it swaps in a quantized variant.
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    /// Only the non-quantized tensors (embed, unembed, lnf, ln1, ln2) —
    /// the dense f32 projections are NOT retained, so deploying a packed
    /// variant really does shrink resident weight memory.
    pub weights: Weights,
    /// Per layer: projection name -> packed/dense matrix.
    pub mats: Vec<BTreeMap<&'static str, QMat>>,
    /// The bit allocation this model was quantized at.
    pub bits: Vec<u8>,
}

impl QuantizedModel {
    /// Quantize every projection at the allocated bit widths and pack the
    /// 2/4-bit codes for fused serving. Mirrors `quant::quantize_model`
    /// but keeps codes packed instead of dequantizing back to f32.
    pub fn quantize(cfg: &ModelConfig, w: &Weights, bits: &[u8],
                    group: usize, backend: Backend,
                    hessians: Option<&HessianMap>, workers: usize)
                    -> Self {
        assert_eq!(bits.len(), cfg.n_layers);
        let jobs: Vec<(usize, &'static str)> = (0..cfg.n_layers)
            .flat_map(|l| QUANT_WEIGHTS.iter().map(move |n| (l, *n)))
            .collect();
        let done: Vec<(usize, &'static str, QMat)> =
            parallel_map(jobs.len(), workers, |j| {
                let (l, name) = jobs[j];
                let m = w.layer_matrix(name, l);
                let g = quant::fit_group(m.rows(), group);
                let spec = QuantSpec::new(bits[l], g);
                let h = hessians
                    .and_then(|hm| hm.get(&(l, name.to_string())));
                let q = quant::quantize_matrix(&m, spec, backend, h);
                let qm = if matches!(bits[l], 2 | 4) {
                    QMat::Packed(PackedMatrix::from_quantized(&q))
                } else {
                    QMat::Dense(q.dequantize())
                };
                (l, name, qm)
            });
        let mut mats: Vec<BTreeMap<&'static str, QMat>> =
            (0..cfg.n_layers).map(|_| BTreeMap::new()).collect();
        for (l, name, qm) in done {
            mats[l].insert(name, qm);
        }
        // Keep only the never-quantized tensors; the dense projections
        // must not stay resident alongside their packed codes.
        let mut tensors = std::collections::BTreeMap::new();
        for name in WEIGHT_NAMES {
            if !QUANT_WEIGHTS.contains(&name) {
                tensors.insert(name.to_string(), w.get(name).clone());
            }
        }
        QuantizedModel {
            weights: Weights { tensors },
            mats,
            bits: bits.to_vec(),
        }
    }

    /// Serving bytes of the quantized projections (codes + metadata).
    pub fn packed_bytes(&self) -> usize {
        self.mats
            .iter()
            .map(|layer| layer.values().map(QMat::bytes).sum::<usize>())
            .sum()
    }

    /// Fake-quant weight set (every projection dequantized back to f32
    /// and restacked to [L, K, N]), e.g. for scoring through an executor
    /// that cannot serve packed codes, or for testing fused-vs-dense
    /// parity.
    pub fn dequantized_weights(&self) -> Weights {
        let mut out = self.weights.clone();
        let nl = self.mats.len();
        for name in QUANT_WEIGHTS {
            let mut stacked: Option<Tensor> = None;
            for (l, layer) in self.mats.iter().enumerate() {
                let t = match &layer[name] {
                    QMat::Packed(p) => p.dequantize(),
                    QMat::Dense(t) => t.clone(),
                };
                let s = stacked.get_or_insert_with(|| {
                    Tensor::zeros(vec![nl, t.rows(), t.cols()])
                });
                s.set_slice0(l, &t);
            }
            out.tensors.insert(
                name.to_string(),
                stacked.expect("quantized model has no layers"),
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::quant::rtn;
    use crate::tensor::matmul::matmul;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn packed_dequantize_matches_unpacked() {
        let mut rng = Rng::new(40);
        let w = Tensor::randn(vec![32, 12], &mut rng);
        let q = rtn::quantize(&w, QuantSpec::new(4, 8));
        let pm = PackedMatrix::from_quantized(&q);
        let a = q.dequantize();
        let b = pm.dequantize();
        assert_eq!(a, b);
        assert_eq!(pm.bytes(),
                   pack::packed_bytes(32, 12, 4, 8));
    }

    #[test]
    fn fused_matches_unpack_then_matmul() {
        check("fused == unpack+matmul", 25, |rng| {
            let bits = if rng.f64() < 0.5 { 2u8 } else { 4u8 };
            let k = 8 * (1 + rng.below(20));
            let n = 1 + rng.below(24);
            let m = 1 + rng.below(12);
            let g = quant::fit_group(k, 8 * (1 + rng.below(4)));
            let w = Tensor::randn(vec![k, n], rng);
            let x = Tensor::randn(vec![m, k], rng);
            let q = rtn::quantize(&w, QuantSpec::new(bits, g));
            let pm = PackedMatrix::from_quantized(&q);
            let workers = 1 + rng.below(3);
            let fused = fused_matmul(&x, &pm, workers);
            let reference = matmul(&x, &pm.dequantize());
            let err = fused.sub(&reference).frob_norm()
                / reference.frob_norm().max(1e-6);
            prop_ensure!(err < 1e-5, "rel err {err} (bits {bits})");
            Ok(())
        });
    }

    #[test]
    fn fused_vecmat_matches_fused_matmul_row() {
        check("fused_vecmat == fused_matmul[1,K]", 20, |rng| {
            let bits = if rng.f64() < 0.5 { 2u8 } else { 4u8 };
            let k = 8 * (1 + rng.below(16));
            let n = 1 + rng.below(20);
            let g = quant::fit_group(k, 8 * (1 + rng.below(4)));
            let w = Tensor::randn(vec![k, n], rng);
            let mut x = Tensor::randn(vec![1, k], rng);
            x.data_mut()[rng.below(k)] = 0.0; // exercise the zero skip
            let q = rtn::quantize(&w, QuantSpec::new(bits, g));
            let pm = PackedMatrix::from_quantized(&q);
            let vec_out = fused_vecmat(x.data(), &pm);
            let mat_out = fused_matmul(&x, &pm, 1);
            prop_ensure!(vec_out == mat_out.data(),
                         "vecmat diverged from fused_matmul \
                          ({k}x{n}@{bits}b g={g})");
            Ok(())
        });
    }

    #[test]
    fn fused_gemm_small_matches_fused_matmul_exactly() {
        check("fused_gemm_small == fused_matmul", 20, |rng| {
            let bits = if rng.f64() < 0.5 { 2u8 } else { 4u8 };
            let k = 8 * (1 + rng.below(16));
            let n = 1 + rng.below(20);
            let m = 1 + rng.below(8); // the small-batch decode regime
            let g = quant::fit_group(k, 8 * (1 + rng.below(4)));
            let w = Tensor::randn(vec![k, n], rng);
            let mut x = Tensor::randn(vec![m, k], rng);
            // Exercise both skips: a zero coefficient in one row, and a
            // weight row no row consumes (whole column of x zeroed).
            x.data_mut()[rng.below(m * k)] = 0.0;
            let dead_k = rng.below(k);
            for i in 0..m {
                x.data_mut()[i * k + dead_k] = 0.0;
            }
            let small = fused_gemm_small(&x, &pm_of(&w, bits, g));
            let pm = pm_of(&w, bits, g);
            let full = fused_matmul(&x, &pm, 1);
            prop_ensure!(small == full,
                         "small-batch GEMM diverged from fused_matmul \
                          ({m}x{k}x{n}@{bits}b g={g})");
            // Per-row bit-identity with the single-row kernel.
            for i in 0..m {
                let row = fused_vecmat(x.row(i), &pm);
                prop_ensure!(row.as_slice() == small.row(i),
                             "row {i} diverged from fused_vecmat");
            }
            Ok(())
        });
    }

    fn pm_of(w: &Tensor, bits: u8, g: usize) -> PackedMatrix {
        PackedMatrix::from_quantized(&rtn::quantize(
            w, QuantSpec::new(bits, g)))
    }

    #[test]
    fn fused_single_row_matches_dot() {
        let mut rng = Rng::new(41);
        let w = Tensor::randn(vec![16, 4], &mut rng);
        let q = rtn::quantize(&w, QuantSpec::new(4, 8));
        let pm = PackedMatrix::from_quantized(&q);
        let x = Tensor::randn(vec![1, 16], &mut rng);
        let y = fused_matmul(&x, &pm, 1);
        let d = pm.dequantize();
        for c in 0..4 {
            let manual: f32 =
                (0..16).map(|r| x.at(0, r) * d.at(r, c)).sum();
            assert!((y.at(0, c) - manual).abs() < 1e-4);
        }
    }

    #[test]
    fn quantized_model_roundtrip_matches_quantize_model() {
        let cfg = ModelConfig::test_config();
        let mut rng = Rng::new(42);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let bits = vec![4u8, 2, 4];
        let qm = QuantizedModel::quantize(&cfg, &w, &bits, 8,
                                          Backend::Rtn, None, 2);
        let dq = qm.dequantized_weights();
        let reference = quant::quantize_model(&cfg, &w, &bits, 8,
                                              Backend::Rtn, None, 1);
        for name in QUANT_WEIGHTS {
            assert_eq!(dq.get(name), reference.get(name), "{name}");
        }
        // Non-quantized tensors untouched; packed model is smaller.
        assert_eq!(dq.get("embed"), w.get("embed"));
        let fp_bytes: usize = (0..cfg.n_layers)
            .map(|l| {
                QUANT_WEIGHTS
                    .iter()
                    .map(|n| w.layer_matrix(n, l).len() * 4)
                    .sum::<usize>()
            })
            .sum();
        assert!(qm.packed_bytes() * 3 < fp_bytes,
                "packed {} vs fp {fp_bytes}", qm.packed_bytes());
    }
}
