//! Packed quantized matrices + the fused dequant-matmul kernels — the
//! native serving format. Codes stay in the 2/4-bit `quant::pack` layout
//! end to end; dequantization happens inside cache-blocked K×N tiles
//! through a per-(group, column) lookup table, so the full f32 weight
//! matrix is never materialized (unlike the unpack-then-`tensor::matmul`
//! baseline the benches compare against).
//!
//! Kernel family contract (see DESIGN.md "Fused kernel family"): for the
//! same `x` row, `fused_vecmat`, `fused_gemm_small` and `fused_matmul`
//! produce bit-identical outputs. Normative semantics per output
//! element: sum `a_k * (s·(code_k − z))` over k ascending, skipping
//! every term whose activation `a_k == 0.0` — ALL kernels skip, so a
//! zero activation can never turn a nonfinite dequantized weight into a
//! NaN in one kernel but not another.

use std::collections::BTreeMap;

use crate::model::{ModelConfig, Weights, QUANT_WEIGHTS, WEIGHT_NAMES};
use crate::quant::{self, pack, Backend, HessianMap, QuantSpec, QuantizedMatrix};
use crate::tensor::Tensor;
use crate::util::pool::{chunk_ranges, parallel_map, workers_for};

/// One [K, N] weight in the packed serving layout: 2/4-bit codes packed
/// along K (`quant::pack`) plus per-(group, column) f32 scale/zero.
#[derive(Clone, Debug)]
pub struct PackedMatrix {
    pub k: usize,
    pub n: usize,
    pub bits: u8,
    pub group: usize,
    /// u8 [K·bits/8, N], little-endian sub-bytes along K.
    pub packed: Vec<u8>,
    /// f32 [ceil(K/group), N].
    pub scale: Vec<f32>,
    /// f32 [ceil(K/group), N].
    pub zero: Vec<f32>,
}

impl PackedMatrix {
    /// Pack an (unpacked-code) quantized matrix into the serving layout.
    pub fn from_quantized(q: &QuantizedMatrix) -> Self {
        PackedMatrix {
            k: q.k,
            n: q.n,
            bits: q.spec.bits,
            group: q.spec.group,
            packed: pack::pack(&q.codes, q.k, q.n, q.spec.bits),
            scale: q.scale.clone(),
            zero: q.zero.clone(),
        }
    }

    /// Total serving bytes (codes + scale/zero metadata).
    pub fn bytes(&self) -> usize {
        self.packed.len() + (self.scale.len() + self.zero.len()) * 4
    }

    /// Materialize the full f32 weight (tests / fallback paths only —
    /// the fused matmul never calls this). Delegates to the one
    /// group-affine dequant implementation in `quant`.
    pub fn dequantize(&self) -> Tensor {
        QuantizedMatrix {
            spec: QuantSpec::new(self.bits, self.group),
            codes: pack::unpack(&self.packed, self.k, self.n, self.bits),
            k: self.k,
            n: self.n,
            scale: self.scale.clone(),
            zero: self.zero.clone(),
        }
        .dequantize()
    }
}

/// K-panel height of `fused_matmul` (matches `tensor::matmul`'s blocking
/// so the two paths accumulate in the same k order).
const BK: usize = 64;

/// Column-tile width shared by all three kernels. A BK×NB f32 panel is
/// 16 KB and a NB×16 LUT tile is 4 KB — both L1-resident, which is the
/// point: the dequant table and the staged panel must not evict the
/// output rows they feed.
const NB: usize = 64;

/// Inner accumulation unroll width. `chunks_exact(UNROLL)` hands the
/// compiler fixed-size blocks of independent mul-adds it can lift to
/// 8-lane SIMD without `std::simd`; the scalar remainder preserves
/// per-element op order, so unrolling never changes bits.
const UNROLL: usize = 8;

/// Decode coordinates of packed weight row `kk`, shared by every fused
/// kernel: (packed byte row, sub-byte shift, scale row, zero row). The
/// kernels' per-row bit-identity contract depends on them all reading
/// the layout identically — keep this the single source of truth.
#[inline]
fn row_decode(pm: &PackedMatrix, kk: usize)
    -> (&[u8], u32, &[f32], &[f32]) {
    let bits = pm.bits as usize;
    let per = 8 / bits;
    let n = pm.n;
    let byte_row = kk / per;
    let shift = (bits * (kk % per)) as u32;
    let gr = kk / pm.group;
    (
        &pm.packed[byte_row * n..byte_row * n + n],
        shift,
        &pm.scale[gr * n..gr * n + n],
        &pm.zero[gr * n..gr * n + n],
    )
}

/// Fill the dequant lookup table for one (group, column tile):
/// `lut[j*LW + code] = s_j · (code − z_j)` for tile column j. `LW` is
/// the table width `1 << bits` (4 or 16). The expression is the exact
/// one the scalar kernels used per element, evaluated once per code
/// instead of once per weight — same two f32 ops, so every value read
/// out of the table is bit-identical to computing it inline.
fn fill_lut<const LW: usize>(srow: &[f32], zrow: &[f32], lut: &mut [f32]) {
    for ((s, z), l) in
        srow.iter().zip(zrow).zip(lut.chunks_exact_mut(LW)) {
        for (code, e) in l.iter_mut().enumerate() {
            *e = *s * (code as f32 - *z);
        }
    }
}

/// Decode one packed byte row (tile slice) through the LUT into `wrow`.
#[inline]
fn gather_row<const LW: usize>(bytes: &[u8], shift: u32, lut: &[f32],
                               wrow: &mut [f32]) {
    let mask = (LW - 1) as u8;
    for (j, (w, &byte)) in wrow.iter_mut().zip(bytes).enumerate() {
        *w = lut[j * LW + ((byte >> shift) & mask) as usize];
    }
}

/// `out[j] += a · lut[j·LW + code_j]` over a tile — the single-row
/// kernel's inner loop, gathering straight from the LUT (with one x row
/// there is no reuse to amortize a staged f32 panel).
#[inline]
fn gather_axpy<const LW: usize>(a: f32, bytes: &[u8], shift: u32,
                                lut: &[f32], out: &mut [f32]) {
    let mask = (LW - 1) as u8;
    let mut oc = out.chunks_exact_mut(UNROLL);
    let mut bc = bytes.chunks_exact(UNROLL);
    let mut j = 0;
    for (ob, bb) in (&mut oc).zip(&mut bc) {
        for (u, (o, &byte)) in ob.iter_mut().zip(bb).enumerate() {
            let code = ((byte >> shift) & mask) as usize;
            *o += a * lut[(j + u) * LW + code];
        }
        j += UNROLL;
    }
    for (u, (o, &byte)) in oc
        .into_remainder()
        .iter_mut()
        .zip(bc.remainder())
        .enumerate() {
        let code = ((byte >> shift) & mask) as usize;
        *o += a * lut[(j + u) * LW + code];
    }
}

/// `out[j] += a · w[j]` over a tile, 8-wide unrolled. Same per-element
/// multiply-add in the same order as the scalar loop — the blocking
/// only changes instruction scheduling, never bits.
#[inline]
fn axpy(a: f32, w: &[f32], out: &mut [f32]) {
    let mut oc = out.chunks_exact_mut(UNROLL);
    let mut wc = w.chunks_exact(UNROLL);
    for (ob, wb) in (&mut oc).zip(&mut wc) {
        for (o, &wv) in ob.iter_mut().zip(wb) {
            *o += a * wv;
        }
    }
    for (o, &wv) in oc.into_remainder().iter_mut().zip(wc.remainder()) {
        *o += a * wv;
    }
}

/// Fused dequant-matmul: `x [M, K] @ dequant(pm) -> [M, N]` without ever
/// materializing the f32 weight. For each column tile, each K panel of
/// `BK` rows is decoded once through the LUT into a cache-resident f32
/// panel and reused across all M rows; rows of `x` are split across
/// `workers` threads via `util::pool` when the call is big enough to
/// pay for the spawn (`pool::workers_for`).
pub fn fused_matmul(x: &Tensor, pm: &PackedMatrix, workers: usize)
    -> Tensor {
    let (m, k) = (x.rows(), x.cols());
    assert_eq!(k, pm.k, "fused_matmul: x cols {k} != packed K {}", pm.k);
    let n = pm.n;
    let xd = x.data();
    let run = |r0: usize, r1: usize| match pm.bits {
        2 => fused_rows::<4>(xd, r0, r1, pm),
        4 => fused_rows::<16>(xd, r0, r1, pm),
        b => panic!("fused_matmul: no packed kernel for {b}-bit"),
    };
    let workers = workers_for(workers, m * k * n).clamp(1, m.max(1));
    if workers == 1 {
        return Tensor::new(run(0, m), vec![m, n]);
    }
    // Contiguous row blocks, one per worker; each decodes its own panels.
    let ranges = chunk_ranges(m, workers);
    let chunks = parallel_map(ranges.len(), ranges.len(), |i| {
        let (r0, r1) = ranges[i];
        run(r0, r1)
    });
    let mut data = Vec::with_capacity(m * n);
    for c in chunks {
        data.extend_from_slice(&c);
    }
    Tensor::new(data, vec![m, n])
}

/// Fused kernel body for output rows `r0..r1`: column tiles outermost,
/// BK-row K panels within a tile, LUT rebuilt on group change. Per
/// output element the k loop still ascends 0..K (tiles partition
/// columns, panels partition k in order), so tiling is bit-invariant.
fn fused_rows<const LW: usize>(xd: &[f32], r0: usize, r1: usize,
                               pm: &PackedMatrix) -> Vec<f32> {
    let (k, n) = (pm.k, pm.n);
    let rows = r1 - r0;
    let mut out = vec![0.0f32; rows * n];
    let mut lut = vec![0.0f32; NB * LW];
    let mut panel = vec![0.0f32; BK.min(k) * NB.min(n)];
    for t in 0..n.div_ceil(NB) {
        let c0 = t * NB;
        let c1 = (c0 + NB).min(n);
        let tw = c1 - c0;
        let lutt = &mut lut[..tw * LW];
        let mut cur_gr = usize::MAX;
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + BK).min(k);
            // Decode this K panel's tile once: panel[kk-k0] row =
            // s·(code − z) via the LUT.
            for kk in k0..k1 {
                let (brow, shift, srow, zrow) = row_decode(pm, kk);
                let gr = kk / pm.group;
                if gr != cur_gr {
                    fill_lut::<LW>(&srow[c0..c1], &zrow[c0..c1], lutt);
                    cur_gr = gr;
                }
                gather_row::<LW>(&brow[c0..c1], shift, lutt,
                                 &mut panel[(kk - k0) * tw
                                            ..(kk - k0 + 1) * tw]);
            }
            // Accumulate the panel into every output row (ikj order),
            // skipping zero activations like the rest of the family.
            for i in r0..r1 {
                let xrow = &xd[i * k..(i + 1) * k];
                let ob = (i - r0) * n;
                let orow = &mut out[ob + c0..ob + c1];
                for kk in k0..k1 {
                    let a = xrow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    axpy(a, &panel[(kk - k0) * tw..(kk - k0 + 1) * tw],
                         orow);
                }
            }
            k0 = k1;
        }
    }
    out
}

/// Single-row fused dequant-dot: `x [K] @ dequant(pm) -> [N]`, the
/// decode-path kernel. Skips the K-panel staging buffer entirely (for
/// one row there is no reuse to amortize it) and gathers straight from
/// the per-(group, tile) LUT, k-ascending per element with the same
/// `s·(code − z)` values and the same zero-skip as `fused_rows`, so the
/// result is bit-identical to `fused_matmul` on a [1, K] input. Dead
/// groups (all activations zero) never pay the LUT build.
pub fn fused_vecmat(x: &[f32], pm: &PackedMatrix) -> Vec<f32> {
    let k = pm.k;
    assert_eq!(x.len(), k, "fused_vecmat: x len {} != packed K {k}",
               x.len());
    match pm.bits {
        2 => vecmat_impl::<4>(x, pm),
        4 => vecmat_impl::<16>(x, pm),
        b => panic!("fused_vecmat: no packed kernel for {b}-bit"),
    }
}

fn vecmat_impl<const LW: usize>(x: &[f32], pm: &PackedMatrix)
    -> Vec<f32> {
    let (k, n) = (pm.k, pm.n);
    let group = pm.group;
    let mut out = vec![0.0f32; n];
    let mut lut = vec![0.0f32; NB.min(n) * LW];
    // One contiguous liveness pass over x, reused by every column tile.
    let glive: Vec<bool> = x
        .chunks(group)
        .map(|g| g.iter().any(|&a| a != 0.0))
        .collect();
    for t in 0..n.div_ceil(NB) {
        let c0 = t * NB;
        let c1 = (c0 + NB).min(n);
        let lutt = &mut lut[..(c1 - c0) * LW];
        for (gr, &live) in glive.iter().enumerate() {
            if !live {
                continue;
            }
            let g0 = gr * group;
            let g1 = (g0 + group).min(k);
            let (_, _, srow, zrow) = row_decode(pm, g0);
            fill_lut::<LW>(&srow[c0..c1], &zrow[c0..c1], lutt);
            for (kk, &a) in x.iter().enumerate().take(g1).skip(g0) {
                if a == 0.0 {
                    continue;
                }
                let (brow, shift, _, _) = row_decode(pm, kk);
                gather_axpy::<LW>(a, &brow[c0..c1], shift, lutt,
                                  &mut out[c0..c1]);
            }
        }
    }
    out
}

/// Small-batch fused dequant-GEMM — the continuous-batching decode
/// kernel: `x [M, K] @ dequant(pm) -> [M, N]`, decoding each packed
/// weight row ONCE per call and applying it to every row of `x`, so the
/// per-token dequant + weight traffic of a decode step is divided by the
/// number of concurrently active sequences. (Running `fused_vecmat` per
/// sequence decodes the same weights M times.)
///
/// Unlike `fused_matmul` there is no K-panel staging buffer: one
/// dequantized weight-row tile (≤ NB floats) stays cache-resident while
/// it is accumulated into all M output rows — the right blocking for the
/// small M (≤ ~16) of a decode batch, where a BK×N panel would evict the
/// output rows. Accumulation is k-ascending per output row with the same
/// `s·(code − z)` values and the same zero-skip, so each row is
/// bit-identical to `fused_vecmat` on that row (and to `fused_matmul`).
///
/// Dead weight rows (no x row consumes them) are skipped via a per-k
/// liveness mask built in ONE contiguous pass over `x` up front — not
/// by re-scanning x with a stride-K walk per weight row. Column tiles
/// are independent, so large-N calls split tiles across `workers`
/// (splitting rows instead would decode every weight row once per
/// worker, defeating the kernel's point).
pub fn fused_gemm_small(x: &Tensor, pm: &PackedMatrix, workers: usize)
    -> Tensor {
    let (m, k) = (x.rows(), x.cols());
    assert_eq!(k, pm.k, "fused_gemm_small: x cols {k} != packed K {}",
               pm.k);
    let n = pm.n;
    if m == 0 || n == 0 {
        return Tensor::new(vec![0.0; m * n], vec![m, n]);
    }
    let xd = x.data();
    // Per-k liveness in one pass over x's rows (contiguous loads).
    let mut live = vec![false; k];
    for row in xd.chunks_exact(k) {
        for (lv, &a) in live.iter_mut().zip(row) {
            *lv |= a != 0.0;
        }
    }
    let run = |t0: usize, t1: usize| match pm.bits {
        2 => gemm_small_tiles::<4>(xd, m, &live, pm, t0, t1),
        4 => gemm_small_tiles::<16>(xd, m, &live, pm, t0, t1),
        b => panic!("fused_gemm_small: no packed kernel for {b}-bit"),
    };
    let tiles = n.div_ceil(NB);
    let workers = workers_for(workers, m * k * n).clamp(1, tiles);
    if workers == 1 {
        return Tensor::new(run(0, tiles), vec![m, n]);
    }
    let ranges = chunk_ranges(tiles, workers);
    let blocks = parallel_map(ranges.len(), ranges.len(), |w| {
        let (t0, t1) = ranges[w];
        run(t0, t1)
    });
    // Stitch each worker's [M, cw] column block into the [M, N] output.
    let mut out = vec![0.0f32; m * n];
    for (w, block) in blocks.iter().enumerate() {
        let c0 = ranges[w].0 * NB;
        let cw = block.len() / m;
        for i in 0..m {
            out[i * n + c0..i * n + c0 + cw]
                .copy_from_slice(&block[i * cw..(i + 1) * cw]);
        }
    }
    Tensor::new(out, vec![m, n])
}

/// `fused_gemm_small` body for column tiles `t0..t1`: returns the
/// [M, cols(t0..t1)] output block. The LUT is rebuilt lazily on group
/// change, so a fully dead group never pays the build.
fn gemm_small_tiles<const LW: usize>(xd: &[f32], m: usize, live: &[bool],
                                     pm: &PackedMatrix, t0: usize,
                                     t1: usize) -> Vec<f32> {
    let (k, n) = (pm.k, pm.n);
    let c_base = t0 * NB;
    let c_end = (t1 * NB).min(n);
    let cw = c_end - c_base;
    let mut out = vec![0.0f32; m * cw];
    let mut lut = vec![0.0f32; NB.min(n) * LW];
    let mut wrow = vec![0.0f32; NB.min(n)];
    for t in t0..t1 {
        let c0 = t * NB;
        let c1 = (c0 + NB).min(n);
        let tw = c1 - c0;
        let lutt = &mut lut[..tw * LW];
        let wt = &mut wrow[..tw];
        let mut cur_gr = usize::MAX;
        for (kk, &alive) in live.iter().enumerate() {
            if !alive {
                continue;
            }
            let (brow, shift, srow, zrow) = row_decode(pm, kk);
            let gr = kk / pm.group;
            if gr != cur_gr {
                fill_lut::<LW>(&srow[c0..c1], &zrow[c0..c1], lutt);
                cur_gr = gr;
            }
            // Dequantize weight row kk's tile once...
            gather_row::<LW>(&brow[c0..c1], shift, lutt, wt);
            // ...and apply it to every active row.
            for i in 0..m {
                let a = xd[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                let ob = i * cw + (c0 - c_base);
                axpy(a, wt, &mut out[ob..ob + tw]);
            }
        }
    }
    out
}

/// One projection of a quantized model: packed when the bit width has a
/// serving layout (2/4-bit), dense f32 fallback otherwise.
#[derive(Clone, Debug)]
pub enum QMat {
    Packed(PackedMatrix),
    Dense(Tensor),
}

impl QMat {
    pub fn bytes(&self) -> usize {
        match self {
            QMat::Packed(p) => p.bytes(),
            QMat::Dense(t) => t.len() * 4,
        }
    }
}

/// A full model in the native packed serving format: FP embeddings /
/// norms / unembed (standard practice — they are never quantized) plus
/// one `QMat` per (layer, projection). This is what the coordinator's
/// server deploys when it swaps in a quantized variant.
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    /// Only the non-quantized tensors (embed, unembed, lnf, ln1, ln2) —
    /// the dense f32 projections are NOT retained, so deploying a packed
    /// variant really does shrink resident weight memory.
    pub weights: Weights,
    /// Per layer: projection name -> packed/dense matrix.
    pub mats: Vec<BTreeMap<&'static str, QMat>>,
    /// The bit allocation this model was quantized at.
    pub bits: Vec<u8>,
}

impl QuantizedModel {
    /// Quantize every projection at the allocated bit widths and pack the
    /// 2/4-bit codes for fused serving. Mirrors `quant::quantize_model`
    /// but keeps codes packed instead of dequantizing back to f32.
    pub fn quantize(cfg: &ModelConfig, w: &Weights, bits: &[u8],
                    group: usize, backend: Backend,
                    hessians: Option<&HessianMap>, workers: usize)
                    -> Self {
        assert_eq!(bits.len(), cfg.n_layers);
        let jobs: Vec<(usize, &'static str)> = (0..cfg.n_layers)
            .flat_map(|l| QUANT_WEIGHTS.iter().map(move |n| (l, *n)))
            .collect();
        let done: Vec<(usize, &'static str, QMat)> =
            parallel_map(jobs.len(), workers, |j| {
                let (l, name) = jobs[j];
                let m = w.layer_matrix(name, l);
                let g = quant::fit_group(m.rows(), group);
                let spec = QuantSpec::new(bits[l], g);
                let h = hessians
                    .and_then(|hm| hm.get(&(l, name.to_string())));
                let q = quant::quantize_matrix(&m, spec, backend, h);
                let qm = if matches!(bits[l], 2 | 4) {
                    QMat::Packed(PackedMatrix::from_quantized(&q))
                } else {
                    QMat::Dense(q.dequantize())
                };
                (l, name, qm)
            });
        let mut mats: Vec<BTreeMap<&'static str, QMat>> =
            (0..cfg.n_layers).map(|_| BTreeMap::new()).collect();
        for (l, name, qm) in done {
            mats[l].insert(name, qm);
        }
        // Keep only the never-quantized tensors; the dense projections
        // must not stay resident alongside their packed codes.
        let mut tensors = std::collections::BTreeMap::new();
        for name in WEIGHT_NAMES {
            if !QUANT_WEIGHTS.contains(&name) {
                tensors.insert(name.to_string(), w.get(name).clone());
            }
        }
        QuantizedModel {
            weights: Weights { tensors },
            mats,
            bits: bits.to_vec(),
        }
    }

    /// Serving bytes of the quantized projections (codes + metadata).
    pub fn packed_bytes(&self) -> usize {
        self.mats
            .iter()
            .map(|layer| layer.values().map(QMat::bytes).sum::<usize>())
            .sum()
    }

    /// Fake-quant weight set (every projection dequantized back to f32
    /// and restacked to [L, K, N]), e.g. for scoring through an executor
    /// that cannot serve packed codes, or for testing fused-vs-dense
    /// parity.
    pub fn dequantized_weights(&self) -> Weights {
        let mut out = self.weights.clone();
        let nl = self.mats.len();
        for name in QUANT_WEIGHTS {
            let mut stacked: Option<Tensor> = None;
            for (l, layer) in self.mats.iter().enumerate() {
                let t = match &layer[name] {
                    QMat::Packed(p) => p.dequantize(),
                    QMat::Dense(t) => t.clone(),
                };
                let s = stacked.get_or_insert_with(|| {
                    Tensor::zeros(vec![nl, t.rows(), t.cols()])
                });
                s.set_slice0(l, &t);
            }
            out.tensors.insert(
                name.to_string(),
                stacked.expect("quantized model has no layers"),
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::quant::rtn;
    use crate::tensor::matmul::matmul;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    /// Scalar oracle for the kernel family's normative semantics: per
    /// output element, sum `a · (s·(code − z))` over k ascending,
    /// skipping `a == 0.0`, decoding through `row_decode`. Deliberately
    /// naive — no LUT, no tiles, no unrolling.
    fn oracle(xd: &[f32], m: usize, pm: &PackedMatrix) -> Vec<f32> {
        let (k, n) = (pm.k, pm.n);
        let mask = (1u8 << pm.bits) - 1;
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let a = xd[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                let (brow, shift, srow, zrow) = row_decode(pm, kk);
                for c in 0..n {
                    let code = (brow[c] >> shift) & mask;
                    out[i * n + c] +=
                        a * (srow[c] * (code as f32 - zrow[c]));
                }
            }
        }
        out
    }

    /// True bitwise equality — unlike `==` on f32 slices it
    /// distinguishes -0.0 from +0.0 and treats equal NaN bits as equal.
    fn bits_eq(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    /// Run all three kernels against the scalar oracle, bitwise.
    fn ensure_family_matches_oracle(x: &Tensor, pm: &PackedMatrix,
                                    ctx: &str) -> Result<(), String> {
        let m = x.rows();
        let want = oracle(x.data(), m, pm);
        let full = fused_matmul(x, pm, 1);
        prop_ensure!(bits_eq(full.data(), &want),
                     "fused_matmul != oracle ({ctx})");
        let small = fused_gemm_small(x, pm, 1);
        prop_ensure!(bits_eq(small.data(), &want),
                     "fused_gemm_small != oracle ({ctx})");
        for i in 0..m {
            let row = fused_vecmat(x.row(i), pm);
            prop_ensure!(bits_eq(&row, &want[i * pm.n..(i + 1) * pm.n]),
                         "fused_vecmat row {i} != oracle ({ctx})");
        }
        Ok(())
    }

    #[test]
    fn packed_dequantize_matches_unpacked() {
        let mut rng = Rng::new(40);
        let w = Tensor::randn(vec![32, 12], &mut rng);
        let q = rtn::quantize(&w, QuantSpec::new(4, 8));
        let pm = PackedMatrix::from_quantized(&q);
        let a = q.dequantize();
        let b = pm.dequantize();
        assert_eq!(a, b);
        assert_eq!(pm.bytes(),
                   pack::packed_bytes(32, 12, 4, 8));
    }

    #[test]
    fn fused_matches_unpack_then_matmul() {
        check("fused == unpack+matmul", 25, |rng| {
            let bits = if rng.f64() < 0.5 { 2u8 } else { 4u8 };
            let k = 8 * (1 + rng.below(20));
            let n = 1 + rng.below(24);
            let m = 1 + rng.below(12);
            let g = quant::fit_group(k, 8 * (1 + rng.below(4)));
            let w = Tensor::randn(vec![k, n], rng);
            let x = Tensor::randn(vec![m, k], rng);
            let q = rtn::quantize(&w, QuantSpec::new(bits, g));
            let pm = PackedMatrix::from_quantized(&q);
            let workers = 1 + rng.below(3);
            let fused = fused_matmul(&x, &pm, workers);
            let reference = matmul(&x, &pm.dequantize());
            let err = fused.sub(&reference).frob_norm()
                / reference.frob_norm().max(1e-6);
            prop_ensure!(err < 1e-5, "rel err {err} (bits {bits})");
            Ok(())
        });
    }

    #[test]
    fn fused_vecmat_matches_fused_matmul_row() {
        check("fused_vecmat == fused_matmul[1,K]", 20, |rng| {
            let bits = if rng.f64() < 0.5 { 2u8 } else { 4u8 };
            let k = 8 * (1 + rng.below(16));
            let n = 1 + rng.below(20);
            let g = quant::fit_group(k, 8 * (1 + rng.below(4)));
            let w = Tensor::randn(vec![k, n], rng);
            let mut x = Tensor::randn(vec![1, k], rng);
            x.data_mut()[rng.below(k)] = 0.0; // exercise the zero skip
            let q = rtn::quantize(&w, QuantSpec::new(bits, g));
            let pm = PackedMatrix::from_quantized(&q);
            let vec_out = fused_vecmat(x.data(), &pm);
            let mat_out = fused_matmul(&x, &pm, 1);
            prop_ensure!(bits_eq(&vec_out, mat_out.data()),
                         "vecmat diverged from fused_matmul \
                          ({k}x{n}@{bits}b g={g})");
            Ok(())
        });
    }

    #[test]
    fn fused_gemm_small_matches_fused_matmul_exactly() {
        check("fused_gemm_small == fused_matmul", 20, |rng| {
            let bits = if rng.f64() < 0.5 { 2u8 } else { 4u8 };
            let k = 8 * (1 + rng.below(16));
            let n = 1 + rng.below(20);
            let m = 1 + rng.below(8); // the small-batch decode regime
            let g = quant::fit_group(k, 8 * (1 + rng.below(4)));
            let w = Tensor::randn(vec![k, n], rng);
            let mut x = Tensor::randn(vec![m, k], rng);
            // Exercise both skips: a zero coefficient in one row, and a
            // weight row no row consumes (whole column of x zeroed).
            x.data_mut()[rng.below(m * k)] = 0.0;
            let dead_k = rng.below(k);
            for i in 0..m {
                x.data_mut()[i * k + dead_k] = 0.0;
            }
            let pm = pm_of(&w, bits, g);
            let small = fused_gemm_small(&x, &pm, 1 + rng.below(3));
            let full = fused_matmul(&x, &pm, 1);
            prop_ensure!(bits_eq(small.data(), full.data()),
                         "small-batch GEMM diverged from fused_matmul \
                          ({m}x{k}x{n}@{bits}b g={g})");
            // Per-row bit-identity with the single-row kernel.
            for i in 0..m {
                let row = fused_vecmat(x.row(i), &pm);
                prop_ensure!(bits_eq(&row, small.row(i)),
                             "row {i} diverged from fused_vecmat");
            }
            Ok(())
        });
    }

    fn pm_of(w: &Tensor, bits: u8, g: usize) -> PackedMatrix {
        PackedMatrix::from_quantized(&rtn::quantize(
            w, QuantSpec::new(bits, g)))
    }

    /// Build a PackedMatrix directly from raw codes + metadata, without
    /// going through `rtn` — the only way to get ragged tail groups
    /// (`fit_group` always returns a divisor of K) or nonfinite scales.
    fn pm_raw(rng: &mut Rng, k: usize, n: usize, bits: u8,
              group: usize) -> PackedMatrix {
        let codes: Vec<u8> = (0..k * n)
            .map(|_| rng.below(1 << bits) as u8)
            .collect();
        let gs = k.div_ceil(group);
        PackedMatrix {
            k,
            n,
            bits,
            group,
            packed: pack::pack(&codes, k, n, bits),
            scale: (0..gs * n).map(|_| 0.1 + rng.f32()).collect(),
            zero: (0..gs * n)
                .map(|_| rng.below(1 << bits) as f32)
                .collect(),
        }
    }

    /// Plant structured zeros into x: random scattered zeros, one fully
    /// dead k column, and a `-0.0` (must behave exactly like `+0.0`).
    fn plant_zeros(x: &mut Tensor, rng: &mut Rng) {
        let (m, k) = (x.rows(), x.cols());
        let xd = x.data_mut();
        for _ in 0..1 + m * k / 4 {
            xd[rng.below(m * k)] = 0.0;
        }
        let dead_k = rng.below(k);
        for i in 0..m {
            xd[i * k + dead_k] = 0.0;
        }
        xd[rng.below(m * k)] = -0.0;
    }

    /// Tentpole regression sweep: every edge shape the tiled/unrolled
    /// rewrite introduced — N below the unroll width, N=1, N straddling
    /// the NB tile boundary, K off the BK panel boundary, ragged tail
    /// groups, both LUT widths — bitwise against the scalar oracle.
    #[test]
    fn kernel_family_matches_scalar_oracle_on_edge_shapes() {
        // K values keep k % (8/bits) == 0 for both bit widths.
        const KS: [usize; 7] = [4, 8, 20, 64, 68, 100, 128];
        const NS: [usize; 9] = [1, 3, 7, 8, 9, 63, 64, 65, 130];
        const MS: [usize; 5] = [1, 2, 5, 16, 17];
        check("kernel family == oracle (edge shapes)", 60, |rng| {
            let bits = if rng.f64() < 0.5 { 2u8 } else { 4u8 };
            let k = KS[rng.below(KS.len())];
            let n = NS[rng.below(NS.len())];
            let m = MS[rng.below(MS.len())];
            // Deliberately allow groups that do NOT divide K (ragged
            // tail group) — pm_raw builds the layout by hand.
            let group = [3, 8, 16, 64][rng.below(4)].min(k);
            let pm = pm_raw(rng, k, n, bits, group);
            let mut x = Tensor::randn(vec![m, k], rng);
            plant_zeros(&mut x, rng);
            ensure_family_matches_oracle(
                &x, &pm,
                &format!("{m}x{k}x{n}@{bits}b g={group}"))
        });
    }

    /// Headline bugfix pin: uniform zero-skip across the family. A zero
    /// activation must contribute NOTHING — not `0 · w` — in every
    /// kernel, so a nonfinite dequantized weight (inf scale) behind a
    /// zero activation can never produce a NaN in one kernel and a
    /// finite value in another, and an all-zero row is exactly +0.0.
    #[test]
    fn zero_skip_is_uniform_across_the_kernel_family() {
        let mut rng = Rng::new(47);
        let (k, n, group) = (8usize, 4usize, 4usize);
        let mut pm = pm_raw(&mut rng, k, n, 4, group);
        // Group 0, column 1 dequantizes to +inf: scale inf, codes 3,
        // zero 1 -> inf · (3 − 1) = +inf for kk in 0..4.
        pm.zero = vec![1.0; pm.zero.len()];
        pm.scale[n] = -2.0; // group 1 stays finite, incl. negatives
        pm.scale[1] = f32::INFINITY;
        pm.packed = pack::pack(&vec![3u8; k * n], k, n, 4);
        // row 0: all zeros. row 1: zeros over the inf group (kk 0..4,
        // incl. a -0.0), finite values elsewhere. row 2: fully nonzero.
        let x = Tensor::new(
            vec![
                0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, //
                0.0, -0.0, 0.0, 0.0, 1.5, -2.0, 0.25, 3.0, //
                1.0, 2.0, 1.0, 0.5, -0.5, 1.0, 2.0, -2.0,
            ],
            vec![3, k],
        );
        let want = oracle(x.data(), 3, &pm);
        let full = fused_matmul(&x, &pm, 1);
        let small = fused_gemm_small(&x, &pm, 1);
        assert!(bits_eq(full.data(), &want), "fused_matmul != oracle");
        assert!(bits_eq(small.data(), &want),
                "fused_gemm_small != oracle");
        for i in 0..3 {
            let row = fused_vecmat(x.row(i), &pm);
            assert!(bits_eq(&row, &want[i * n..(i + 1) * n]),
                    "fused_vecmat row {i} != oracle");
        }
        // All-zero row: exactly +0.0 bits, never -0.0 or NaN.
        for (c, v) in full.data()[..n].iter().enumerate() {
            assert_eq!(v.to_bits(), 0, "row 0 col {c} not +0.0: {v}");
        }
        // Zeros over the inf group: finite result (a non-skipping
        // kernel would compute 0 · inf = NaN here).
        for (c, v) in full.data()[n..2 * n].iter().enumerate() {
            assert!(v.is_finite(), "row 1 col {c} nonfinite: {v}");
        }
        // Nonzero activation against the inf weight: +inf, uniformly.
        assert_eq!(full.data()[2 * n + 1], f32::INFINITY);
    }

    /// Ragged tail group (K not a multiple of group): the last scale /
    /// zero row covers fewer than `group` weight rows. `fit_group` never
    /// produces this, so build the layout by hand for both LUT widths.
    #[test]
    fn ragged_tail_groups_match_the_scalar_oracle() {
        check("ragged tail groups == oracle", 16, |rng| {
            let bits = if rng.f64() < 0.5 { 2u8 } else { 4u8 };
            let (k, n, group) = (20, 6, 8); // 3 groups: 8 + 8 + 4
            let pm = pm_raw(rng, k, n, bits, group);
            let mut x = Tensor::randn(vec![3, k], rng);
            plant_zeros(&mut x, rng);
            ensure_family_matches_oracle(&x, &pm, "ragged 20/8")
        });
    }

    /// Worker splits are bit-invariant: fused_matmul's row split and
    /// fused_gemm_small's column-tile split. The shape is sized past
    /// `pool::MIN_PAR_WORK` so the parallel path actually runs.
    #[test]
    fn worker_splits_are_bitwise_invariant() {
        let mut rng = Rng::new(48);
        let (m, k, n) = (8, 256, 600); // 8·256·600 ≈ 1.2M > MIN_PAR_WORK
        let w = Tensor::randn(vec![k, n], &mut rng);
        let pm = pm_of(&w, 4, 64);
        let mut x = Tensor::randn(vec![m, k], &mut rng);
        plant_zeros(&mut x, &mut rng);
        let small1 = fused_gemm_small(&x, &pm, 1);
        let small4 = fused_gemm_small(&x, &pm, 4);
        assert!(bits_eq(small1.data(), small4.data()),
                "gemm_small column split changed bits");
        let full1 = fused_matmul(&x, &pm, 1);
        let full3 = fused_matmul(&x, &pm, 3);
        assert!(bits_eq(full1.data(), full3.data()),
                "fused_matmul row split changed bits");
        assert!(bits_eq(small1.data(), full1.data()),
                "gemm_small diverged from fused_matmul");
    }

    #[test]
    fn fused_single_row_matches_dot() {
        let mut rng = Rng::new(41);
        let w = Tensor::randn(vec![16, 4], &mut rng);
        let q = rtn::quantize(&w, QuantSpec::new(4, 8));
        let pm = PackedMatrix::from_quantized(&q);
        let x = Tensor::randn(vec![1, 16], &mut rng);
        let y = fused_matmul(&x, &pm, 1);
        let d = pm.dequantize();
        for c in 0..4 {
            let manual: f32 =
                (0..16).map(|r| x.at(0, r) * d.at(r, c)).sum();
            assert!((y.at(0, c) - manual).abs() < 1e-4);
        }
    }

    #[test]
    fn quantized_model_roundtrip_matches_quantize_model() {
        let cfg = ModelConfig::test_config();
        let mut rng = Rng::new(42);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let bits = vec![4u8, 2, 4];
        let qm = QuantizedModel::quantize(&cfg, &w, &bits, 8,
                                          Backend::Rtn, None, 2);
        let dq = qm.dequantized_weights();
        let reference = quant::quantize_model(&cfg, &w, &bits, 8,
                                              Backend::Rtn, None, 1);
        for name in QUANT_WEIGHTS {
            assert_eq!(dq.get(name), reference.get(name), "{name}");
        }
        // Non-quantized tensors untouched; packed model is smaller.
        assert_eq!(dq.get("embed"), w.get("embed"));
        let fp_bytes: usize = (0..cfg.n_layers)
            .map(|l| {
                QUANT_WEIGHTS
                    .iter()
                    .map(|n| w.layer_matrix(n, l).len() * 4)
                    .sum::<usize>()
            })
            .sum();
        assert!(qm.packed_bytes() * 3 < fp_bytes,
                "packed {} vs fp {fp_bytes}", qm.packed_bytes());
    }
}
