//! Backend-agnostic inference: the `Executor` trait every serving /
//! eval / calibration path runs through, plus the pure-Rust
//! `NativeEngine` (dense + fused packed forward) that is the default
//! executor. The PJRT/XLA engine (`runtime::Engine`, behind the
//! off-by-default `xla` cargo feature) implements the same trait, so
//! the coordinator, eval harness and server are executor-generic.
//! See DESIGN.md "Executor trait".

pub mod cache;
pub mod generate;
pub mod native;
pub mod qmat;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::model::Weights;
use crate::runtime::ModelEntry;
use crate::tensor::Tensor;

pub use cache::{KvCache, KvCachePool, LayerKv, PAGE_SIZE};
pub use generate::{generate, generate_batch, generate_batch_spec,
                   BatchEngine, GenConfig, GenEvent, GenSink, GenStats,
                   Generation, Sampling, SpecCounters, SpecDecode,
                   StopReason, PREFILL_CHUNK};
pub use native::NativeEngine;
pub use qmat::{fused_gemm_small, fused_matmul, fused_vecmat,
               PackedMatrix, QMat, QuantizedModel};

/// Calibration activations from one probe batch, in the layout the
/// baselines consume: per-layer `[B·S, X]` row matrices (row = b·S + s).
pub struct Probes {
    /// Logits [B, S, V] of the same forward.
    pub logits: Tensor,
    /// Residual-stream input of each layer: [L] × [B·S, D].
    pub resid_in: Vec<Tensor>,
    /// Final residual (pre-lnf): [B·S, D].
    pub final_resid: Tensor,
    /// RMSNorm'd attention inputs: [L] × [B·S, D].
    pub x_ln1: Vec<Tensor>,
    /// RMSNorm'd FFN inputs: [L] × [B·S, D].
    pub x_ln2: Vec<Tensor>,
    /// Attention context (inputs to wo): [L] × [B·S, H·dh].
    pub attn_ctx: Vec<Tensor>,
    /// FFN intermediates (inputs to wdown): [L] × [B·S, F].
    pub ffn_mid: Vec<Tensor>,
}

/// A model-forward backend. `forward` is the one required capability;
/// packed serving and calibration probes/grads are optional (executors
/// without them return a descriptive error).
pub trait Executor {
    fn platform(&self) -> String;

    /// tokens i32 [batch·seq] → logits f32 [batch, seq, vocab].
    fn forward(&self, entry: &ModelEntry, tokens: &[i32], batch: usize,
               weights: &Weights) -> Result<Tensor>;

    /// Forward over packed 2/4-bit codes (fused dequant-matmul), without
    /// dequantizing to a full weight set first.
    fn forward_packed(&self, entry: &ModelEntry, tokens: &[i32],
                      batch: usize, model: &QuantizedModel)
                      -> Result<Tensor> {
        let _ = (entry, tokens, batch, model);
        anyhow::bail!("{}: packed serving not supported", self.platform())
    }

    /// Forward + per-layer calibration activations.
    fn probe(&self, entry: &ModelEntry, tokens: &[i32], batch: usize,
             weights: &Weights) -> Result<Probes> {
        let _ = (entry, tokens, batch, weights);
        anyhow::bail!("{}: probe collection not supported",
                      self.platform())
    }

    /// Whether `grads` is implemented. Callers use this to distinguish
    /// "capability absent" (degrade gracefully) from a genuine failure
    /// of a supporting executor (propagate).
    fn supports_grads(&self) -> bool {
        false
    }

    /// Loss gradients w.r.t. the 7 stacked quantizable weights (LLM-MQ).
    fn grads(&self, entry: &ModelEntry, tokens: &[i32], batch: usize,
             weights: &Weights) -> Result<BTreeMap<String, Tensor>> {
        let _ = (entry, tokens, batch, weights);
        anyhow::bail!("{}: gradient collection not supported (enable \
                       the `xla` feature for the grad artifact)",
                      self.platform())
    }

    /// Whether the KV-cached decode family (`decode_step`,
    /// `decode_batch`, `prefill_chunk` and their packed variants) is
    /// implemented (optional capability, like packed serving).
    fn supports_decode(&self) -> bool {
        false
    }

    /// KV-cached incremental decode, dense weights: consume ONE token at
    /// the cache's current position, append its K/V rows to every layer
    /// of `cache`, advance it, and return the next-token logits as a 1-D
    /// `[vocab]` tensor. Per-token cost must not depend on the prefix
    /// length. Contract details in DESIGN.md "Incremental decoding".
    fn decode_step(&self, entry: &ModelEntry, cache: &mut KvCache,
                   token: i32, weights: &Weights) -> Result<Tensor> {
        let _ = (entry, cache, token, weights);
        anyhow::bail!("{}: incremental decode not supported",
                      self.platform())
    }

    /// `decode_step` over packed 2/4-bit codes (fused dequant-matmul on
    /// single-row inputs), without materializing f32 weights.
    fn decode_step_packed(&self, entry: &ModelEntry, cache: &mut KvCache,
                          token: i32, model: &QuantizedModel)
                          -> Result<Tensor> {
        let _ = (entry, cache, token, model);
        anyhow::bail!("{}: packed incremental decode not supported",
                      self.platform())
    }

    /// Batched KV-cached decode over a multi-sequence cache pool: each
    /// `(slot, token)` pair consumes ONE token at that slot's position
    /// (a slot may appear at most once per step), appends its K/V rows,
    /// and advances the slot. Returns logits `[active.len(), vocab]`,
    /// rows in `active` order. Row `i` must equal what `decode_step` on
    /// slot `active[i].0` alone would return — `decode_step` is the B=1
    /// case. The decode capability is one family: an executor claiming
    /// `supports_decode` must implement this alongside `decode_step`,
    /// since the whole generation stack (`generate`, `generate_batch`,
    /// the server scheduler) routes through it. Contract details in
    /// DESIGN.md "Continuous batching".
    fn decode_batch(&self, entry: &ModelEntry, pool: &mut KvCachePool,
                    active: &[(usize, i32)], weights: &Weights)
                    -> Result<Tensor> {
        let _ = (entry, pool, active, weights);
        anyhow::bail!("{}: batched incremental decode not supported",
                      self.platform())
    }

    /// `decode_batch` over packed 2/4-bit codes. The native engine's
    /// fused small-batch GEMM dequantizes each weight group once per
    /// step and applies it to all active rows, dividing per-token weight
    /// traffic by the batch size — the continuous-batching win on
    /// weight-bandwidth-bound low-bit decode.
    fn decode_batch_packed(&self, entry: &ModelEntry,
                           pool: &mut KvCachePool,
                           active: &[(usize, i32)],
                           model: &QuantizedModel) -> Result<Tensor> {
        let _ = (entry, pool, active, model);
        anyhow::bail!("{}: packed batched decode not supported",
                      self.platform())
    }

    /// Chunked prefill: consume a whole window of prompt `tokens` for
    /// ONE slot at its current position — multi-row projections, causal
    /// attention inside the chunk, bulk K/V page writes — and advance
    /// the slot by the chunk length. Returns logits
    /// `[tokens.len(), vocab]`; row `i` MUST be bit-identical to what
    /// feeding `tokens[i]` through `decode_batch` at that position
    /// would return (chunking changes wall clock, never bits — pinned
    /// by `rust/tests/prefill_equivalence.rs`). The chunk may not
    /// exceed the slot's ring capacity; callers split longer prompts
    /// (overlong prompts prefill through the evicting regime chunk by
    /// chunk). Part of the decode capability family
    /// (`supports_decode`): the generation stack feeds every prompt
    /// through this path before joining the decode batch.
    fn prefill_chunk(&self, entry: &ModelEntry, pool: &mut KvCachePool,
                     slot: usize, tokens: &[i32], weights: &Weights)
                     -> Result<Tensor> {
        let _ = (entry, pool, slot, tokens, weights);
        anyhow::bail!("{}: chunked prefill not supported",
                      self.platform())
    }

    /// `prefill_chunk` over packed 2/4-bit codes: each projection is one
    /// fused dequant-GEMM over the whole chunk, so a packed weight group
    /// is decoded once per chunk instead of once per prompt token —
    /// the prefill-side counterpart of `decode_batch_packed`'s
    /// amortization.
    fn prefill_chunk_packed(&self, entry: &ModelEntry,
                            pool: &mut KvCachePool, slot: usize,
                            tokens: &[i32], model: &QuantizedModel)
                            -> Result<Tensor> {
        let _ = (entry, pool, slot, tokens, model);
        anyhow::bail!("{}: packed chunked prefill not supported",
                      self.platform())
    }

    /// Speculative verify: score a window of candidate tokens for ONE
    /// slot in a single multi-row pass and return all
    /// `[tokens.len(), vocab]` logit rows. This IS `prefill_chunk` —
    /// whose rows are already pinned bit-identical to per-token decode
    /// — so row `i` is exactly the logits greedy decode would produce
    /// after committing `tokens[..=i]`; greedy acceptance against
    /// these rows is therefore exact, not approximate. The K/V rows
    /// the pass appends are provisional: the caller inspects the
    /// rows, accepts the longest agreeing prefix, and rolls the slot
    /// back with `KvCachePool::truncate`. Truncate only operates on
    /// an unwrapped ring, so the window must fit inside it — enforced
    /// here (the one contract difference from `prefill_chunk`, which
    /// happily evicts) so rollback is always sound. Spec-mode
    /// schedulers gate eligibility on the same bound.
    fn verify_chunk(&self, entry: &ModelEntry, pool: &mut KvCachePool,
                    slot: usize, tokens: &[i32], weights: &Weights)
                    -> Result<Tensor> {
        let (pos, cap) = (pool.pos(slot), pool.capacity(slot));
        anyhow::ensure!(pos + tokens.len() <= cap,
                        "verify_chunk: {}-token window at position \
                         {pos} overruns slot {slot}'s ring (cap {cap}) \
                         — rollback would cross a wrap",
                        tokens.len());
        self.prefill_chunk(entry, pool, slot, tokens, weights)
    }

    /// `verify_chunk` over packed 2/4-bit codes (the fused dequant-GEMM
    /// `prefill_chunk_packed` path, same no-wrap guard).
    fn verify_chunk_packed(&self, entry: &ModelEntry,
                           pool: &mut KvCachePool, slot: usize,
                           tokens: &[i32], model: &QuantizedModel)
                           -> Result<Tensor> {
        let (pos, cap) = (pool.pos(slot), pool.capacity(slot));
        anyhow::ensure!(pos + tokens.len() <= cap,
                        "verify_chunk: {}-token window at position \
                         {pos} overruns slot {slot}'s ring (cap {cap}) \
                         — rollback would cross a wrap",
                        tokens.len());
        self.prefill_chunk_packed(entry, pool, slot, tokens, model)
    }
}

/// A borrowed deployable weight variant: the generation loop and the
/// serve loop dispatch through this to the dense or fused-packed decode
/// path without owning the weights.
#[derive(Clone, Copy)]
pub enum ModelRef<'a> {
    Dense(&'a Weights),
    Packed(&'a QuantizedModel),
}

impl ModelRef<'_> {
    pub fn decode_step(&self, exec: &dyn Executor, entry: &ModelEntry,
                       cache: &mut KvCache, token: i32) -> Result<Tensor> {
        match self {
            ModelRef::Dense(w) => {
                exec.decode_step(entry, cache, token, w)
            }
            ModelRef::Packed(qm) => {
                exec.decode_step_packed(entry, cache, token, qm)
            }
        }
    }

    /// Batched decode of the same variant over a multi-sequence cache
    /// pool (see `Executor::decode_batch`).
    pub fn decode_batch(&self, exec: &dyn Executor, entry: &ModelEntry,
                        pool: &mut KvCachePool, active: &[(usize, i32)])
                        -> Result<Tensor> {
        match self {
            ModelRef::Dense(w) => {
                exec.decode_batch(entry, pool, active, w)
            }
            ModelRef::Packed(qm) => {
                exec.decode_batch_packed(entry, pool, active, qm)
            }
        }
    }

    /// Chunked prefill of the same variant into one slot's pages (see
    /// `Executor::prefill_chunk`).
    pub fn prefill_chunk(&self, exec: &dyn Executor, entry: &ModelEntry,
                         pool: &mut KvCachePool, slot: usize,
                         tokens: &[i32]) -> Result<Tensor> {
        match self {
            ModelRef::Dense(w) => {
                exec.prefill_chunk(entry, pool, slot, tokens, w)
            }
            ModelRef::Packed(qm) => {
                exec.prefill_chunk_packed(entry, pool, slot, tokens, qm)
            }
        }
    }

    /// Speculative multi-row verify of the same variant (see
    /// `Executor::verify_chunk`): all `tokens.len()` logit rows in one
    /// pass, provisional K/V the caller rolls back with `truncate`.
    pub fn verify_chunk(&self, exec: &dyn Executor, entry: &ModelEntry,
                        pool: &mut KvCachePool, slot: usize,
                        tokens: &[i32]) -> Result<Tensor> {
        match self {
            ModelRef::Dense(w) => {
                exec.verify_chunk(entry, pool, slot, tokens, w)
            }
            ModelRef::Packed(qm) => {
                exec.verify_chunk_packed(entry, pool, slot, tokens, qm)
            }
        }
    }

    /// Full-sequence forward of the same variant (prefill / scoring).
    pub fn forward(&self, exec: &dyn Executor, entry: &ModelEntry,
                   tokens: &[i32], batch: usize) -> Result<Tensor> {
        match self {
            ModelRef::Dense(w) => exec.forward(entry, tokens, batch, w),
            ModelRef::Packed(qm) => {
                exec.forward_packed(entry, tokens, batch, qm)
            }
        }
    }
}

/// The process default executor: PJRT when the `xla` feature is enabled
/// (unless `NSDS_EXECUTOR=native`), the native engine otherwise.
/// `dir` is the artifacts directory the PJRT engine compiles from.
pub fn default_executor(dir: &Path, workers: usize)
    -> Result<Box<dyn Executor>> {
    #[cfg(feature = "xla")]
    {
        if std::env::var("NSDS_EXECUTOR").as_deref() != Ok("native") {
            return Ok(Box::new(crate::runtime::Engine::cpu(dir)?));
        }
    }
    #[cfg(not(feature = "xla"))]
    let _ = dir;
    Ok(Box::new(NativeEngine::with_workers(workers)))
}
