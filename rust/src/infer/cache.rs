//! KV caches for incremental (autoregressive) decoding: a multi-sequence
//! `KvCachePool` for continuous-batching decode, plus the single-sequence
//! `KvCache` wrapper (one permanently-admitted pool slot) the B=1 paths
//! keep using.
//!
//! One pool slot holds, for every layer, a ring buffer of the roped K and
//! raw V rows of the tokens decoded so far, in the GQA head layout
//! (`n_kv · d_head` columns — query heads share their group's KV rows, so
//! the cache stores `n_kv` heads, not `n_heads`). `decode_batch` appends
//! each active sequence's K/V to every layer and attends over that slot's
//! window, which is what makes per-token cost independent of the prefix
//! length (the full-sequence `forward` recomputes the whole prefix every
//! call).
//!
//! Slots are independent: each has its own position, its own ring
//! capacity (fixed at `admit`), and its own eviction. While a slot's
//! `pos < cap` it is exact: attention sees every previous token of that
//! sequence and incremental decode matches the full forward bit-for-bit
//! (see `rust/tests/decode_equivalence.rs` and
//! `rust/tests/batch_decode.rs`). Once `pos` reaches `cap` the ring wraps
//! and the oldest entries are evicted — sliding-window attention over the
//! last `cap` positions (keys keep their absolute RoPE phases, the
//! StreamingLLM-style regime without sink tokens).
//!
//! Admission/retirement (`admit` / `retire`) reuse slot indices through a
//! free list, so a long-running batch scheduler keeps stable slot ids as
//! sequences join and leave mid-stream.

use crate::model::ModelConfig;

/// Ring-buffered K/V rows for all layers of ONE decoding sequence.
#[derive(Clone, Debug)]
struct SlotCache {
    cap: usize,
    /// Absolute position of the NEXT token to be decoded (== number of
    /// tokens fully appended so far).
    pos: usize,
    /// Per layer: roped keys, [cap, nkv·dh] ring (row = position % cap).
    k: Vec<Vec<f32>>,
    /// Per layer: values, same layout.
    v: Vec<Vec<f32>>,
}

/// Multi-sequence KV cache: up to `max_slots` concurrently active
/// sequences sharing one GQA layout, each with an independent ring.
#[derive(Clone, Debug)]
pub struct KvCachePool {
    n_layers: usize,
    nkv: usize,
    dh: usize,
    slots: Vec<Option<SlotCache>>,
}

impl KvCachePool {
    pub fn new(n_layers: usize, nkv: usize, dh: usize,
               max_slots: usize) -> Self {
        assert!(n_layers > 0 && nkv > 0 && dh > 0);
        assert!(max_slots > 0, "KvCachePool needs at least one slot");
        KvCachePool {
            n_layers,
            nkv,
            dh,
            slots: (0..max_slots).map(|_| None).collect(),
        }
    }

    /// Pool sized for a model config's KV geometry.
    pub fn for_model(cfg: &ModelConfig, max_slots: usize) -> Self {
        KvCachePool::new(cfg.n_layers, cfg.n_kv, cfg.d_head, max_slots)
    }

    /// Whether this pool was laid out for `cfg`'s KV geometry.
    pub fn matches(&self, cfg: &ModelConfig) -> bool {
        self.n_layers == cfg.n_layers
            && self.nkv == cfg.n_kv
            && self.dh == cfg.d_head
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn max_slots(&self) -> usize {
        self.slots.len()
    }

    /// Number of currently admitted sequences.
    pub fn active_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn free_count(&self) -> usize {
        self.max_slots() - self.active_count()
    }

    pub fn is_active(&self, slot: usize) -> bool {
        slot < self.slots.len() && self.slots[slot].is_some()
    }

    /// Admit a new sequence with ring capacity `cap`: returns its slot id,
    /// or `None` when every slot is occupied (the scheduler keeps the
    /// request pending and admits it when a sequence retires).
    pub fn admit(&mut self, cap: usize) -> Option<usize> {
        assert!(cap > 0, "slot capacity must be positive");
        let slot = self.slots.iter().position(|s| s.is_none())?;
        let w = cap * self.nkv * self.dh;
        self.slots[slot] = Some(SlotCache {
            cap,
            pos: 0,
            k: (0..self.n_layers).map(|_| vec![0.0; w]).collect(),
            v: (0..self.n_layers).map(|_| vec![0.0; w]).collect(),
        });
        Some(slot)
    }

    /// Retire a finished sequence, freeing its slot for the next
    /// admission. The other slots are untouched — no positions shift.
    pub fn retire(&mut self, slot: usize) {
        assert!(self.is_active(slot), "retire of inactive slot {slot}");
        self.slots[slot] = None;
    }

    fn slot(&self, slot: usize) -> &SlotCache {
        self.slots
            .get(slot)
            .and_then(|s| s.as_ref())
            .unwrap_or_else(|| panic!("inactive slot {slot}"))
    }

    fn slot_mut(&mut self, slot: usize) -> &mut SlotCache {
        self.slots
            .get_mut(slot)
            .and_then(|s| s.as_mut())
            .unwrap_or_else(|| panic!("inactive slot {slot}"))
    }

    /// Absolute position of the slot's next token (RoPE phase of the
    /// token the next decode step will consume).
    pub fn pos(&self, slot: usize) -> usize {
        self.slot(slot).pos
    }

    /// Ring capacity the slot was admitted with.
    pub fn capacity(&self, slot: usize) -> usize {
        self.slot(slot).cap
    }

    /// Reset a slot to an empty sequence (buffers are reused, not zeroed
    /// — every ring row is overwritten before attention can read it).
    pub fn reset(&mut self, slot: usize) {
        self.slot_mut(slot).pos = 0;
    }

    /// Write the current token's K/V rows for layer `l` into the slot's
    /// ring row for its position. Called once per layer per step;
    /// `advance` commits the position after the last layer.
    pub fn append(&mut self, slot: usize, l: usize, krow: &[f32],
                  vrow: &[f32]) {
        let w = self.nkv * self.dh;
        debug_assert_eq!(krow.len(), w, "k row width");
        debug_assert_eq!(vrow.len(), w, "v row width");
        let s = self.slot_mut(slot);
        let row = s.pos % s.cap;
        s.k[l][row * w..(row + 1) * w].copy_from_slice(krow);
        s.v[l][row * w..(row + 1) * w].copy_from_slice(vrow);
    }

    /// Commit the slot's current step: the next `append`/`window_rows`
    /// refer to the following position.
    pub fn advance(&mut self, slot: usize) {
        self.slot_mut(slot).pos += 1;
    }

    /// Raw (k, v) ring buffers of layer `l` for a slot
    /// ([cap, nkv·dh] row-major).
    pub fn layer(&self, l: usize, slot: usize) -> (&[f32], &[f32]) {
        let s = self.slot(slot);
        (&s.k[l], &s.v[l])
    }

    /// Ring rows the slot's current step's attention reads, oldest →
    /// newest, INCLUDING the row of the token being decoded (append
    /// first, then attend — causal attention sees itself). Identical for
    /// every layer of a step, so callers compute it once per slot.
    pub fn window_rows(&self, slot: usize) -> Vec<usize> {
        let s = self.slot(slot);
        let hi = s.pos; // current token's logical position (inclusive)
        let lo = (hi + 1).saturating_sub(s.cap);
        (lo..=hi).map(|p| p % s.cap).collect()
    }

    /// Bytes resident in the active slots' K/V buffers.
    pub fn bytes(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .map(|s| self.n_layers * 2 * s.cap * self.nkv * self.dh * 4)
            .sum()
    }
}

/// Single-sequence KV cache: one permanently-admitted slot of a
/// `KvCachePool`. This is the B=1 view the `decode_step` paths and the
/// benches use; `decode_step` itself runs as a one-row `decode_batch`.
#[derive(Clone, Debug)]
pub struct KvCache {
    pool: KvCachePool,
}

impl KvCache {
    pub fn new(n_layers: usize, nkv: usize, dh: usize, cap: usize) -> Self {
        assert!(cap > 0, "KvCache capacity must be positive");
        let mut pool = KvCachePool::new(n_layers, nkv, dh, 1);
        pool.admit(cap).expect("fresh pool has a free slot");
        KvCache { pool }
    }

    /// Cache sized for a model config with an explicit context capacity
    /// (use `cfg.seq` to mirror the full-forward context window).
    pub fn for_model(cfg: &ModelConfig, cap: usize) -> Self {
        KvCache::new(cfg.n_layers, cfg.n_kv, cfg.d_head, cap)
    }

    /// Whether this cache was laid out for `cfg`'s KV geometry.
    pub fn matches(&self, cfg: &ModelConfig) -> bool {
        self.pool.matches(cfg)
    }

    pub fn n_layers(&self) -> usize {
        self.pool.n_layers()
    }

    /// Absolute position of the next token (RoPE phase of the token the
    /// next `decode_step` will consume).
    pub fn pos(&self) -> usize {
        self.pool.pos(0)
    }

    pub fn capacity(&self) -> usize {
        self.pool.capacity(0)
    }

    /// Reset to an empty cache (buffers are reused, not zeroed — every
    /// slot is overwritten before attention can read it).
    pub fn clear(&mut self) {
        self.pool.reset(0);
    }

    /// Write the current token's K/V rows for layer `l` into the ring
    /// slot for `pos`. Called once per layer per step; `advance` commits
    /// the position after the last layer.
    pub fn append(&mut self, l: usize, krow: &[f32], vrow: &[f32]) {
        self.pool.append(0, l, krow, vrow);
    }

    /// Commit the current step: the next `append`/`step_slots` refer to
    /// the following position.
    pub fn advance(&mut self) {
        self.pool.advance(0);
    }

    /// Raw (k, v) ring buffers of layer `l` ([cap, nkv·dh] row-major).
    pub fn layer(&self, l: usize) -> (&[f32], &[f32]) {
        self.pool.layer(l, 0)
    }

    /// Ring slots the current step's attention reads, oldest → newest,
    /// INCLUDING the slot of the token being decoded. See
    /// `KvCachePool::window_rows`.
    pub fn step_slots(&self) -> Vec<usize> {
        self.pool.window_rows(0)
    }

    /// Bytes resident in this cache's K/V buffers.
    pub fn bytes(&self) -> usize {
        self.pool.bytes()
    }

    /// The underlying one-slot pool (the sequence lives in slot 0) — how
    /// `decode_step` routes through the batched decode path.
    pub fn pool_mut(&mut self) -> &mut KvCachePool {
        &mut self.pool
    }

    pub fn pool(&self) -> &KvCachePool {
        &self.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> KvCache {
        KvCache::new(2, 2, 4, 4)
    }

    #[test]
    fn append_advance_and_slots() {
        let mut c = tiny();
        assert_eq!(c.pos(), 0);
        assert_eq!(c.step_slots(), vec![0]);
        let krow: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let vrow: Vec<f32> = (0..8).map(|i| -(i as f32)).collect();
        c.append(0, &krow, &vrow);
        c.append(1, &krow, &vrow);
        c.advance();
        assert_eq!(c.pos(), 1);
        assert_eq!(c.step_slots(), vec![0, 1]);
        let (k0, v0) = c.layer(0);
        assert_eq!(&k0[..8], krow.as_slice());
        assert_eq!(&v0[..8], vrow.as_slice());
    }

    #[test]
    fn ring_wraps_and_window_saturates() {
        let mut c = tiny();
        for p in 0..6 {
            let row = vec![p as f32; 8];
            c.append(0, &row, &row);
            c.append(1, &row, &row);
            c.advance();
        }
        // pos=6: window is the last cap=4 logical positions 3,4,5,6 —
        // slot order 3, 0, 1, 2.
        assert_eq!(c.step_slots(), vec![3, 0, 1, 2]);
        // Slot 0 holds position 4 (4 % 4 == 0), overwriting position 0.
        let (k0, _) = c.layer(0);
        assert_eq!(k0[0], 4.0);
    }

    #[test]
    fn clear_resets_position() {
        let mut c = tiny();
        c.append(0, &[1.0; 8], &[1.0; 8]);
        c.advance();
        c.clear();
        assert_eq!(c.pos(), 0);
        assert_eq!(c.step_slots(), vec![0]);
    }

    #[test]
    fn matches_config_geometry() {
        let cfg = ModelConfig::test_config();
        let c = KvCache::for_model(&cfg, cfg.seq);
        assert!(c.matches(&cfg));
        assert_eq!(c.n_layers(), cfg.n_layers);
        assert_eq!(c.capacity(), cfg.seq);
        assert!(c.bytes() > 0);
        let other = KvCache::new(cfg.n_layers, cfg.n_kv + 1, cfg.d_head,
                                 cfg.seq);
        assert!(!other.matches(&cfg));
    }

    #[test]
    fn clone_is_independent() {
        let mut a = tiny();
        a.append(0, &[2.0; 8], &[2.0; 8]);
        a.advance();
        let b = a.clone();
        a.append(0, &[9.0; 8], &[9.0; 8]);
        a.advance();
        assert_eq!(b.pos(), 1);
        assert_eq!(a.pos(), 2);
        assert_eq!(b.layer(0).0[8], 0.0); // slot 1 untouched in the clone
    }

    #[test]
    fn pool_admit_retire_reuses_slots() {
        let mut p = KvCachePool::new(2, 2, 4, 3);
        assert_eq!(p.max_slots(), 3);
        assert_eq!(p.active_count(), 0);
        let a = p.admit(4).unwrap();
        let b = p.admit(6).unwrap();
        let c = p.admit(2).unwrap();
        assert_eq!((a, b, c), (0, 1, 2));
        assert!(p.admit(4).is_none(), "pool full");
        assert_eq!(p.free_count(), 0);
        // Heterogeneous per-slot capacities.
        assert_eq!(p.capacity(b), 6);
        assert_eq!(p.capacity(c), 2);
        p.retire(b);
        assert!(!p.is_active(b));
        assert_eq!(p.free_count(), 1);
        // The freed index is reused; survivors are untouched.
        let d = p.admit(8).unwrap();
        assert_eq!(d, b);
        assert_eq!(p.pos(d), 0);
        assert_eq!(p.capacity(d), 8);
        assert!(p.is_active(a) && p.is_active(c));
    }

    #[test]
    fn pool_slots_are_independent() {
        let mut p = KvCachePool::new(1, 2, 4, 2);
        let a = p.admit(4).unwrap();
        let b = p.admit(4).unwrap();
        for i in 0..3 {
            p.append(a, 0, &[i as f32; 8], &[i as f32; 8]);
            p.advance(a);
        }
        p.append(b, 0, &[9.0; 8], &[9.0; 8]);
        p.advance(b);
        assert_eq!(p.pos(a), 3);
        assert_eq!(p.pos(b), 1);
        assert_eq!(p.window_rows(a), vec![0, 1, 2, 3]);
        assert_eq!(p.window_rows(b), vec![0, 1]);
        let (ka, _) = p.layer(0, a);
        let (kb, _) = p.layer(0, b);
        assert_eq!(ka[8], 1.0);
        assert_eq!(kb[0], 9.0);
    }

    #[test]
    fn pool_per_slot_ring_eviction() {
        let mut p = KvCachePool::new(1, 1, 2, 2);
        let small = p.admit(2).unwrap(); // evicts past 2 tokens
        let big = p.admit(8).unwrap(); // exact for the whole stream
        for i in 0..5 {
            for s in [small, big] {
                p.append(s, 0, &[i as f32; 2], &[i as f32; 2]);
                p.advance(s);
            }
        }
        // Small slot: window is the last 2 positions (4, 5-to-be).
        assert_eq!(p.window_rows(small).len(), 2);
        // Big slot: still exact, all 6 positions visible.
        assert_eq!(p.window_rows(big), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    #[should_panic(expected = "inactive slot")]
    fn pool_rejects_inactive_slot_access() {
        let p = KvCachePool::new(1, 1, 2, 2);
        let _ = p.pos(0);
    }
}
