//! KV caches for incremental (autoregressive) decoding: a multi-sequence
//! **paged** `KvCachePool` for continuous-batching decode, plus the
//! single-sequence `KvCache` wrapper (one permanently-admitted pool
//! slot) the B=1 paths keep using.
//!
//! Storage is a pool-global page arena, not per-slot buffers. A **page**
//! holds `PAGE_SIZE` positions of roped-K and raw-V rows for EVERY layer
//! in the GQA head layout (`n_kv · d_head` columns — query heads share
//! their group's KV rows, so the cache stores `n_kv` heads, not
//! `n_heads`). Each slot maps its logical ring rows to pages through a
//! **block table**; pages are allocated lazily on first write, so
//! resident memory scales with the tokens a sequence actually holds, not
//! with the worst-case capacity it was admitted with. Freed pages go to
//! a free list and are recycled across slots. Chunked prefill writes in
//! bulk: `alloc_range` maps (and copy-on-write privatizes) a window's
//! blocks up front, `append_rows` lands whole page segments per layer,
//! and `advance_by` commits the window as one position jump.
//!
//! Pages are **reference counted** and shared copy-on-write:
//! `admit_shared` admits a new sequence whose prompt prefix is already
//! resident in a donor slot by referencing the donor's pages (full pages
//! by refcount bump, the partial tail page by copy), and the first
//! divergent `append` into a shared page copies it first — so identical
//! prompt prefixes are prefilled once and resident once, however many
//! sequences extend them.
//!
//! Logical semantics are unchanged from the contiguous pool: each slot
//! has its own position and its own ring capacity (fixed at `admit`,
//! heterogeneous caps coexist). While a slot's `pos < cap` it is exact:
//! attention sees every previous token of that sequence and incremental
//! decode matches the full forward bit-for-bit (see
//! `rust/tests/decode_equivalence.rs` and `rust/tests/batch_decode.rs`).
//! Once `pos` reaches `cap` the ring wraps and the oldest entries are
//! evicted — sliding-window attention over the last `cap` positions
//! (keys keep their absolute RoPE phases, the StreamingLLM-style regime
//! without sink tokens) — implemented as block recycle: the wrapped ring
//! row overwrites its block in place (copy-on-write first if the block
//! is shared), so eviction never grows the arena.
//!
//! Admission/retirement (`admit` / `retire`) reuse slot indices through
//! a free list, so a long-running batch scheduler keeps stable slot ids
//! as sequences join and leave mid-stream.
//!
//! Storage is **precision-polymorphic per layer**: each layer's K/V rows
//! live as raw f32 (bit-identical compatibility mode, the default), or
//! as int8 / int4 codes with one affine (scale, zero) pair per
//! **row-segment** — per (page, layer, in-page row, kv head), i.e. one
//! `d_head`-wide span. Quantization happens ONCE on append; attention
//! dequantizes on the fly (`infer::native::decode_attention`), so the
//! decode hot loop moves 4–8× fewer bytes per window row. The
//! granularity is deliberately page-local: every page of a layer is
//! self-contained (codes + its own scales), so CoW sharing, `truncate`,
//! ring recycle and the whole-page copies behind `admit_shared` and
//! `writable_block` are precision-agnostic — they copy pages, never
//! re-quantize. Per-layer widths come from the NSDS sensitivity scores
//! via `allocate::allocate_kv_bits`; see DESIGN.md "Quantized KV cache".

use crate::model::ModelConfig;

/// Affine quantization parameters for one row-segment (`d_head` values):
/// `value ≈ scale · (code − zero)`, the same convention
/// `infer::qmat::PackedMatrix` uses for weights. A constant segment
/// (including all-zero rows) round-trips exactly: scale 1, zero −min,
/// every code 0 — so zero K/V rows stay exactly zero under quantization.
#[inline]
pub(crate) fn kv_qparams(seg: &[f32], levels: f32) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in seg {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if !(hi > lo) {
        return (1.0, -lo);
    }
    let s = (hi - lo) / levels;
    (s, -lo / s)
}

/// Encode one value against `kv_qparams` output (codes clamp to
/// `[0, levels]`, so out-of-range inputs cannot wrap).
#[inline]
pub(crate) fn kv_encode(x: f32, s: f32, z: f32, levels: f32) -> u8 {
    (x / s + z).round().clamp(0.0, levels) as u8
}

/// Positions per page. The trade: a smaller page wastes less on short
/// sequences (a slot's minimum footprint is one page) and copies less on
/// a copy-on-write fault, a larger one keeps block tables shorter and
/// lets shared prefixes share more of their length (only FULL pages are
/// shared by reference; the partial tail is copied). 16 positions keeps
/// the per-slot minimum small against the tiny eval shapes while
/// matching the block size block-table serving systems typically use.
pub const PAGE_SIZE: usize = 16;

/// Per-slot state: ring geometry plus the block table mapping logical
/// ring rows to arena pages.
#[derive(Clone, Debug)]
struct SlotCache {
    cap: usize,
    /// Absolute position of the NEXT token to be decoded (== number of
    /// tokens fully appended so far).
    pos: usize,
    /// Entry `b` backs ring rows `[b·PAGE_SIZE, (b+1)·PAGE_SIZE)`;
    /// `None` until the slot first writes into that block.
    table: Vec<Option<usize>>,
}

/// Multi-sequence paged KV cache: up to `max_slots` concurrently active
/// sequences sharing one GQA layout and ONE page arena, each with an
/// independent logical ring mapped through its block table.
#[derive(Clone, Debug)]
pub struct KvCachePool {
    n_layers: usize,
    nkv: usize,
    dh: usize,
    /// Per-layer storage width: 16 (raw f32), 8 or 4 (quantized codes).
    kv_bits: Vec<u8>,
    slots: Vec<Option<SlotCache>>,
    /// Page arena, f32 layers, keys: page `p`, f32 layer `l`, in-page
    /// row `r` lives at `p·f32_page_words + f32_off[l] + r·w .. +w`,
    /// `w = nkv·dh`. With every layer at 16 bits this is exactly the
    /// pre-quantization all-f32 layout.
    k: Vec<f32>,
    /// Page arena, f32 layers, values: same layout.
    v: Vec<f32>,
    /// Code arena, quantized layers, keys: page `p`, quantized layer
    /// `l`, in-page row `r` lives at `p·code_page_bytes + code_off[l] +
    /// r·rb .. +rb`, `rb = w` bytes (int8) or `w/2` (int4, two codes
    /// per byte, even index in the low nibble).
    kq: Vec<u8>,
    /// Code arena, quantized layers, values: same layout.
    vq: Vec<u8>,
    /// Row-segment scales, keys: one f32 per (page, quantized layer,
    /// in-page row, kv head), at `p·meta_page_words + meta_off[l] +
    /// r·nkv + h`.
    ks: Vec<f32>,
    /// Row-segment zeros, keys: same layout.
    kz: Vec<f32>,
    /// Row-segment scales / zeros, values: same layout.
    vs: Vec<f32>,
    vz: Vec<f32>,
    /// Word offset of each f32 layer's rows inside a page's f32 region
    /// (`usize::MAX` for quantized layers).
    f32_off: Vec<usize>,
    /// Byte offset of each quantized layer's code rows inside a page's
    /// code region (`usize::MAX` for f32 layers).
    code_off: Vec<usize>,
    /// Word offset of each quantized layer's (scale, zero) metadata
    /// inside a page's metadata region (`usize::MAX` for f32 layers).
    meta_off: Vec<usize>,
    /// f32 words one page occupies in EACH of `k` and `v`.
    f32_page_words: usize,
    /// Code bytes one page occupies in EACH of `kq` and `vq`.
    code_page_bytes: usize,
    /// Metadata words one page occupies in EACH of `ks`/`kz`/`vs`/`vz`.
    meta_page_words: usize,
    /// Per-page reference counts; 0 ⇔ the page is on the free list.
    refcount: Vec<u32>,
    free: Vec<usize>,
    /// Copy-on-write page splits performed over the pool's lifetime
    /// (monotone; the engine's tracer emits per-step deltas).
    cow_splits: u64,
}

/// Read-only view of one layer of one slot's K/V: resolves logical ring
/// rows through the slot's block table into the shared page arena. This
/// is what `decode_batch` gathers attention reads through — rows of one
/// window may live on non-adjacent pages (and on pages shared with
/// other slots).
pub struct LayerKv<'a> {
    table: &'a [Option<usize>],
    l: usize,
    w: usize,
    nkv: usize,
    dh: usize,
    repr: LayerRepr<'a>,
}

/// Storage of one layer inside the page arenas: raw f32, or quantized
/// codes plus per-row-segment (scale, zero) metadata. Per-layer offsets
/// and page strides are folded in at view construction so the per-row
/// accessors do one multiply-add each.
enum LayerRepr<'a> {
    F32 {
        k: &'a [f32],
        v: &'a [f32],
        /// f32 words per page across all f32 layers.
        stride: usize,
        /// This layer's word offset inside a page's f32 region.
        base: usize,
    },
    Quant {
        /// 8 or 4.
        bits: u8,
        kq: &'a [u8],
        vq: &'a [u8],
        ks: &'a [f32],
        kz: &'a [f32],
        vs: &'a [f32],
        vz: &'a [f32],
        /// Code bytes per page across all quantized layers.
        cstride: usize,
        /// This layer's byte offset inside a page's code region.
        cbase: usize,
        /// Code bytes per row of this layer (`w` or `w/2`).
        rb: usize,
        /// Metadata words per page across all quantized layers.
        mstride: usize,
        /// This layer's word offset inside a page's metadata region.
        mbase: usize,
    },
}

impl<'a> LayerKv<'a> {
    /// Storage width of this layer: 16 (f32), 8 or 4.
    #[inline]
    pub fn bits(&self) -> u8 {
        match &self.repr {
            LayerRepr::F32 { .. } => 16,
            LayerRepr::Quant { bits, .. } => *bits,
        }
    }

    /// Row locator of a ring row: `page · PAGE_SIZE + in-page row`, the
    /// precision-independent handle every accessor below takes. Hoist
    /// per-row locators out of per-head attention loops with this.
    #[inline]
    pub fn offset(&self, ring_row: usize) -> usize {
        let page = self.table[ring_row / PAGE_SIZE].unwrap_or_else(|| {
            panic!("attention read of unwritten ring row {ring_row} \
                    (layer {})", self.l)
        });
        page * PAGE_SIZE + ring_row % PAGE_SIZE
    }

    /// K row (`nkv·dh` wide) at an `offset()` locator (f32 layers only).
    #[inline]
    pub fn k_at(&self, loc: usize) -> &'a [f32] {
        let LayerRepr::F32 { k, stride, base, .. } = &self.repr else {
            panic!("f32 read of quantized layer {}", self.l)
        };
        let off = loc / PAGE_SIZE * stride + base
            + loc % PAGE_SIZE * self.w;
        &k[off..off + self.w]
    }

    /// V row (`nkv·dh` wide) at an `offset()` locator (f32 layers only).
    #[inline]
    pub fn v_at(&self, loc: usize) -> &'a [f32] {
        let LayerRepr::F32 { v, stride, base, .. } = &self.repr else {
            panic!("f32 read of quantized layer {}", self.l)
        };
        let off = loc / PAGE_SIZE * stride + base
            + loc % PAGE_SIZE * self.w;
        &v[off..off + self.w]
    }

    /// K codes of kv head `h` at an `offset()` locator: `dh` bytes
    /// (int8) or `dh/2` packed bytes (int4, even index low nibble).
    #[inline]
    pub fn k_codes(&self, loc: usize, h: usize) -> &'a [u8] {
        let LayerRepr::Quant { kq, cstride, cbase, rb, .. } =
            &self.repr
        else {
            panic!("code read of f32 layer {}", self.l)
        };
        let hb = rb / self.nkv;
        let off = loc / PAGE_SIZE * cstride + cbase
            + loc % PAGE_SIZE * rb + h * hb;
        &kq[off..off + hb]
    }

    /// V codes of kv head `h` at an `offset()` locator.
    #[inline]
    pub fn v_codes(&self, loc: usize, h: usize) -> &'a [u8] {
        let LayerRepr::Quant { vq, cstride, cbase, rb, .. } =
            &self.repr
        else {
            panic!("code read of f32 layer {}", self.l)
        };
        let hb = rb / self.nkv;
        let off = loc / PAGE_SIZE * cstride + cbase
            + loc % PAGE_SIZE * rb + h * hb;
        &vq[off..off + hb]
    }

    /// (scale, zero) of the K row-segment of kv head `h` at a locator.
    #[inline]
    pub fn k_meta(&self, loc: usize, h: usize) -> (f32, f32) {
        let LayerRepr::Quant { ks, kz, mstride, mbase, .. } =
            &self.repr
        else {
            panic!("metadata read of f32 layer {}", self.l)
        };
        let idx = loc / PAGE_SIZE * mstride + mbase
            + loc % PAGE_SIZE * self.nkv + h;
        (ks[idx], kz[idx])
    }

    /// (scale, zero) of the V row-segment of kv head `h` at a locator.
    #[inline]
    pub fn v_meta(&self, loc: usize, h: usize) -> (f32, f32) {
        let LayerRepr::Quant { vs, vz, mstride, mbase, .. } =
            &self.repr
        else {
            panic!("metadata read of f32 layer {}", self.l)
        };
        let idx = loc / PAGE_SIZE * mstride + mbase
            + loc % PAGE_SIZE * self.nkv + h;
        (vs[idx], vz[idx])
    }

    /// K row of a logical ring row (f32 layers only — quantized layers
    /// read through `k_codes`/`k_meta` or `k_row_dequant`).
    #[inline]
    pub fn k_row(&self, ring_row: usize) -> &'a [f32] {
        self.k_at(self.offset(ring_row))
    }

    /// V row of a logical ring row (f32 layers only).
    #[inline]
    pub fn v_row(&self, ring_row: usize) -> &'a [f32] {
        self.v_at(self.offset(ring_row))
    }

    /// K row of a logical ring row, dequantized — works at any width
    /// (f32 layers copy). Test/eval hook, NOT the attention read path:
    /// `decode_attention` fuses dequant into its dot/accumulate loops
    /// instead of materializing rows.
    pub fn k_row_dequant(&self, ring_row: usize) -> Vec<f32> {
        self.row_dequant(ring_row, true)
    }

    /// V row of a logical ring row, dequantized (see `k_row_dequant`).
    pub fn v_row_dequant(&self, ring_row: usize) -> Vec<f32> {
        self.row_dequant(ring_row, false)
    }

    fn row_dequant(&self, ring_row: usize, keys: bool) -> Vec<f32> {
        let loc = self.offset(ring_row);
        match &self.repr {
            LayerRepr::F32 { .. } => if keys {
                self.k_at(loc).to_vec()
            } else {
                self.v_at(loc).to_vec()
            },
            LayerRepr::Quant { bits, .. } => {
                let mut out = Vec::with_capacity(self.w);
                for h in 0..self.nkv {
                    let (codes, (s, z)) = if keys {
                        (self.k_codes(loc, h), self.k_meta(loc, h))
                    } else {
                        (self.v_codes(loc, h), self.v_meta(loc, h))
                    };
                    if *bits == 8 {
                        for &c in codes {
                            out.push(s * (c as f32 - z));
                        }
                    } else {
                        for &b in codes {
                            out.push(s * ((b & 0xf) as f32 - z));
                            out.push(s * ((b >> 4) as f32 - z));
                        }
                    }
                }
                out
            }
        }
    }
}

impl KvCachePool {
    /// All-f32 pool — the bit-identical compatibility mode every
    /// pre-quantization caller gets by default.
    pub fn new(n_layers: usize, nkv: usize, dh: usize,
               max_slots: usize) -> Self {
        KvCachePool::with_kv_bits(n_layers, nkv, dh, max_slots,
                                  &vec![16u8; n_layers])
    }

    /// Pool with per-layer storage widths: `kv_bits[l]` ∈ {4, 8, 16},
    /// 16 meaning raw f32. Int4 packs two codes per byte along each
    /// `d_head` segment, so it requires an even `d_head`.
    pub fn with_kv_bits(n_layers: usize, nkv: usize, dh: usize,
                        max_slots: usize, kv_bits: &[u8]) -> Self {
        assert!(n_layers > 0 && nkv > 0 && dh > 0);
        assert!(max_slots > 0, "KvCachePool needs at least one slot");
        assert_eq!(kv_bits.len(), n_layers,
                   "kv_bits must name every layer ({} != {n_layers})",
                   kv_bits.len());
        let w = nkv * dh;
        let mut f32_off = vec![usize::MAX; n_layers];
        let mut code_off = vec![usize::MAX; n_layers];
        let mut meta_off = vec![usize::MAX; n_layers];
        let (mut fw, mut cb, mut mw) = (0usize, 0usize, 0usize);
        for (l, &b) in kv_bits.iter().enumerate() {
            match b {
                16 => {
                    f32_off[l] = fw;
                    fw += PAGE_SIZE * w;
                }
                8 | 4 => {
                    assert!(b == 8 || dh % 2 == 0,
                            "int4 KV packs two codes per byte along \
                             d_head, which must be even (got {dh})");
                    code_off[l] = cb;
                    cb += PAGE_SIZE * if b == 8 { w } else { w / 2 };
                    meta_off[l] = mw;
                    mw += PAGE_SIZE * nkv;
                }
                _ => panic!("kv_bits[{l}] = {b}: KV layers store 4, 8 \
                             or 16 (f32) bits"),
            }
        }
        KvCachePool {
            n_layers,
            nkv,
            dh,
            kv_bits: kv_bits.to_vec(),
            slots: (0..max_slots).map(|_| None).collect(),
            k: Vec::new(),
            v: Vec::new(),
            kq: Vec::new(),
            vq: Vec::new(),
            ks: Vec::new(),
            kz: Vec::new(),
            vs: Vec::new(),
            vz: Vec::new(),
            f32_off,
            code_off,
            meta_off,
            f32_page_words: fw,
            code_page_bytes: cb,
            meta_page_words: mw,
            refcount: Vec::new(),
            free: Vec::new(),
            cow_splits: 0,
        }
    }

    /// Pool sized for a model config's KV geometry (all-f32 storage).
    pub fn for_model(cfg: &ModelConfig, max_slots: usize) -> Self {
        KvCachePool::new(cfg.n_layers, cfg.n_kv, cfg.d_head, max_slots)
    }

    /// Pool sized for a model config with per-layer KV storage widths
    /// (see `with_kv_bits`; typically `allocate::allocate_kv_bits`
    /// output over the NSDS layer scores).
    pub fn for_model_with_bits(cfg: &ModelConfig, max_slots: usize,
                               kv_bits: &[u8]) -> Self {
        KvCachePool::with_kv_bits(cfg.n_layers, cfg.n_kv, cfg.d_head,
                                  max_slots, kv_bits)
    }

    /// Per-layer KV storage widths (16 = f32).
    pub fn kv_bits(&self) -> &[u8] {
        &self.kv_bits
    }

    /// Storage width of one layer (16 = f32).
    pub fn layer_bits(&self, l: usize) -> u8 {
        self.kv_bits[l]
    }

    /// Whether this pool was laid out for `cfg`'s KV geometry.
    pub fn matches(&self, cfg: &ModelConfig) -> bool {
        self.n_layers == cfg.n_layers
            && self.nkv == cfg.n_kv
            && self.dh == cfg.d_head
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn max_slots(&self) -> usize {
        self.slots.len()
    }

    /// Number of currently admitted sequences.
    pub fn active_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn free_count(&self) -> usize {
        self.max_slots() - self.active_count()
    }

    pub fn is_active(&self, slot: usize) -> bool {
        slot < self.slots.len() && self.slots[slot].is_some()
    }

    fn kv_width(&self) -> usize {
        self.nkv * self.dh
    }

    /// Bytes one page occupies across ALL arenas (K + V, f32 + codes +
    /// row-segment metadata) — the unit `bytes()` reports in. Fixed at
    /// construction by the per-layer `kv_bits` plan, so the resident-
    /// bytes ratio between two precision plans is exactly the ratio of
    /// their `page_bytes()`.
    pub fn page_bytes(&self) -> usize {
        2 * (self.f32_page_words * 4 + self.code_page_bytes
             + 2 * self.meta_page_words * 4)
    }

    /// Pages ever allocated in the arena (in use + on the free list).
    pub fn page_count(&self) -> usize {
        self.refcount.len()
    }

    /// Pages currently referenced by at least one block table.
    pub fn pages_in_use(&self) -> usize {
        self.refcount.len() - self.free.len()
    }

    fn alloc_page(&mut self) -> usize {
        if let Some(p) = self.free.pop() {
            self.refcount[p] = 1;
            return p;
        }
        let p = self.refcount.len();
        self.refcount.push(1);
        let n = p + 1;
        self.k.resize(n * self.f32_page_words, 0.0);
        self.v.resize(n * self.f32_page_words, 0.0);
        self.kq.resize(n * self.code_page_bytes, 0);
        self.vq.resize(n * self.code_page_bytes, 0);
        self.ks.resize(n * self.meta_page_words, 0.0);
        self.kz.resize(n * self.meta_page_words, 0.0);
        self.vs.resize(n * self.meta_page_words, 0.0);
        self.vz.resize(n * self.meta_page_words, 0.0);
        p
    }

    /// Whole-page copy across every arena (f32 rows, codes, and
    /// row-segment metadata move together, so a copied page is
    /// self-contained at any mix of layer widths). The one primitive
    /// behind `admit_shared`'s tail copy and `writable_block`'s
    /// copy-on-write split — precision never re-enters those paths.
    fn copy_page(&mut self, src: usize, dst: usize) {
        let fw = self.f32_page_words;
        if fw > 0 {
            self.k.copy_within(src * fw..(src + 1) * fw, dst * fw);
            self.v.copy_within(src * fw..(src + 1) * fw, dst * fw);
        }
        let cb = self.code_page_bytes;
        if cb > 0 {
            self.kq.copy_within(src * cb..(src + 1) * cb, dst * cb);
            self.vq.copy_within(src * cb..(src + 1) * cb, dst * cb);
        }
        let mw = self.meta_page_words;
        if mw > 0 {
            self.ks.copy_within(src * mw..(src + 1) * mw, dst * mw);
            self.kz.copy_within(src * mw..(src + 1) * mw, dst * mw);
            self.vs.copy_within(src * mw..(src + 1) * mw, dst * mw);
            self.vz.copy_within(src * mw..(src + 1) * mw, dst * mw);
        }
    }

    fn release_page(&mut self, page: usize) {
        debug_assert!(self.refcount[page] > 0, "double free of page {page}");
        self.refcount[page] -= 1;
        if self.refcount[page] == 0 {
            self.free.push(page);
        }
    }

    /// Admit a new sequence with ring capacity `cap`: returns its slot
    /// id, or `None` when every slot is occupied (the scheduler keeps
    /// the request pending and admits it when a sequence retires). No
    /// pages are allocated until the sequence appends — memory follows
    /// tokens actually held, not the admitted capacity.
    pub fn admit(&mut self, cap: usize) -> Option<usize> {
        assert!(cap > 0,
                "admit: slot capacity must be positive (pool: {}/{} \
                 slots active, {} pages in use)",
                self.active_count(), self.max_slots(), self.pages_in_use());
        let slot = self.slots.iter().position(|s| s.is_none())?;
        let blocks = cap.div_ceil(PAGE_SIZE);
        self.slots[slot] = Some(SlotCache {
            cap,
            pos: 0,
            table: vec![None; blocks],
        });
        Some(slot)
    }

    /// Admit a new sequence whose first `shared` positions are already
    /// resident in `donor` (same tokens at the same absolute positions,
    /// so the roped K rows are valid verbatim): full pages of the shared
    /// prefix are referenced (refcount bump, copy-on-write on the first
    /// divergent append), the partial tail page is copied, and the new
    /// slot starts at `pos == shared` — only the un-shared remainder
    /// needs prefilling. `shared == 0` degrades to a plain `admit`.
    ///
    /// The donor must still hold those positions exactly: `shared` may
    /// not exceed the donor's appended position count, the donor's ring
    /// must not have wrapped (wrapping evicts the prefix), and `shared`
    /// must fit the new slot's own capacity.
    pub fn admit_shared(&mut self, cap: usize, donor: usize,
                        shared: usize) -> Option<usize> {
        if shared == 0 {
            return self.admit(cap);
        }
        assert!(cap > 0,
                "admit_shared: slot capacity must be positive (pool: \
                 {}/{} slots active)",
                self.active_count(), self.max_slots());
        assert!(self.is_active(donor),
                "admit_shared: donor slot {donor} is not admitted \
                 (pool: {}/{} slots active)",
                self.active_count(), self.max_slots());
        let (dpos, dcap) = (self.pos(donor), self.capacity(donor));
        assert!(shared <= dpos,
                "admit_shared: donor slot {donor} holds {dpos} \
                 positions, cannot share {shared}");
        assert!(dpos <= dcap,
                "admit_shared: donor slot {donor} wrapped its ring \
                 (pos {dpos} > cap {dcap}) — its prefix is evicted");
        assert!(shared <= cap,
                "admit_shared: shared prefix {shared} exceeds the new \
                 slot's capacity {cap}");
        let slot = self.slots.iter().position(|s| s.is_none())?;
        let donor_table =
            self.slots[donor].as_ref().expect("checked active")
                .table.clone();
        let full = shared / PAGE_SIZE;
        let tail = shared % PAGE_SIZE;
        let blocks = cap.div_ceil(PAGE_SIZE);
        let mut table = vec![None; blocks];
        for (b, entry) in table.iter_mut().enumerate().take(full) {
            let page = donor_table[b]
                .expect("donor block below pos must be mapped");
            self.refcount[page] += 1;
            *entry = Some(page);
        }
        if tail > 0 {
            let src = donor_table[full]
                .expect("donor tail block below pos must be mapped");
            let dst = self.alloc_page();
            // Whole-page copy: the rows past `tail` carry donor data
            // the new slot overwrites before it can ever read them
            // (attention windows stop at `pos`).
            self.copy_page(src, dst);
            table[full] = Some(dst);
        }
        self.slots[slot] = Some(SlotCache { cap, pos: shared, table });
        Some(slot)
    }

    /// Retire a finished sequence, freeing its slot for the next
    /// admission and releasing its pages (a page shared with a survivor
    /// stays resident until its last holder retires). The other slots
    /// are untouched — no positions shift.
    pub fn retire(&mut self, slot: usize) {
        assert!(self.is_active(slot),
                "retire of inactive slot {slot} (pool: {}/{} slots \
                 active, {} pages in use)",
                self.active_count(), self.max_slots(), self.pages_in_use());
        let table = self.slots[slot].take().expect("checked active").table;
        for page in table.into_iter().flatten() {
            self.release_page(page);
        }
    }

    fn slot(&self, slot: usize) -> &SlotCache {
        if slot >= self.slots.len() {
            panic!("slot {slot} out of range (pool has {} slots)",
                   self.slots.len());
        }
        match &self.slots[slot] {
            Some(s) => s,
            None => panic!(
                "slot {slot} is not admitted (pool: {}/{} slots active, \
                 {} pages in use)",
                self.active_count(), self.max_slots(),
                self.pages_in_use()),
        }
    }

    fn slot_mut(&mut self, slot: usize) -> &mut SlotCache {
        if slot >= self.slots.len() {
            panic!("slot {slot} out of range (pool has {} slots)",
                   self.slots.len());
        }
        if self.slots[slot].is_none() {
            panic!("slot {slot} is not admitted (pool: {}/{} slots \
                    active, {} pages in use)",
                   self.active_count(), self.max_slots(),
                   self.pages_in_use());
        }
        self.slots[slot].as_mut().expect("checked above")
    }

    /// Absolute position of the slot's next token (RoPE phase of the
    /// token the next decode step will consume).
    pub fn pos(&self, slot: usize) -> usize {
        self.slot(slot).pos
    }

    /// Ring capacity the slot was admitted with.
    pub fn capacity(&self, slot: usize) -> usize {
        self.slot(slot).cap
    }

    /// Reset a slot to an empty sequence, releasing its pages back to
    /// the free list (page buffers are recycled pool-wide, not zeroed —
    /// every row is overwritten before attention can read it).
    pub fn reset(&mut self, slot: usize) {
        let s = self.slot_mut(slot);
        s.pos = 0;
        let pages: Vec<usize> =
            s.table.iter_mut().filter_map(|e| e.take()).collect();
        for p in pages {
            self.release_page(p);
        }
    }

    /// Roll a slot back to `new_pos`, discarding the K/V rows appended
    /// for positions `new_pos..pos` and releasing every tail page that
    /// no longer backs a live row. Refcount-correct across
    /// copy-on-write shares: a released page that another slot still
    /// references just drops this slot's reference (the other holders
    /// keep it resident); only the last holder frees it. The block
    /// holding `new_pos`'s partial tail stays mapped — its low rows
    /// are live, and the dead high rows are overwritten (through the
    /// CoW check) before anything can read them, exactly like a fresh
    /// append. This is the speculative-decode rollback primitive, and
    /// the only way a slot shrinks without a full `reset`.
    ///
    /// Only the unwrapped regime can roll back: once `pos > cap` the
    /// ring has recycled rows in place, so the data a rewound position
    /// would need is already overwritten — truncating across a wrap
    /// would leave attention windows reading rows that belong to other
    /// positions. Callers keep speculative windows inside the ring
    /// (`pos + window <= cap`) precisely so this precondition holds;
    /// violating it panics rather than corrupting the sequence.
    pub fn truncate(&mut self, slot: usize, new_pos: usize) {
        let (pos, cap) = {
            let s = self.slot(slot);
            (s.pos, s.cap)
        };
        if new_pos == pos {
            return;
        }
        assert!(new_pos < pos,
                "truncate: new_pos {new_pos} is past slot {slot}'s \
                 position {pos}");
        assert!(pos <= cap,
                "truncate: slot {slot} wrapped its ring (pos {pos} > \
                 cap {cap}) — the rewound rows were recycled in place \
                 and cannot be restored");
        // Blocks whose every ring row is at or past `new_pos` hold only
        // discarded data: unmap them, then drop their references.
        let first_dead = new_pos.div_ceil(PAGE_SIZE);
        let dead: Vec<usize> = {
            let s = self.slot_mut(slot);
            s.pos = new_pos;
            s.table
                .iter_mut()
                .skip(first_dead)
                .filter_map(|e| e.take())
                .collect()
        };
        for p in dead {
            self.release_page(p);
        }
    }

    /// Page backing `block` of `slot`, private to the slot: allocated on
    /// first write, copied on write while shared (refcount > 1) — the
    /// copy-on-write point for shared prefix pages and the recycle point
    /// for ring eviction (a wrapped row overwrites its block in place).
    fn writable_block(&mut self, slot: usize, block: usize) -> usize {
        let current = self.slot(slot).table[block];
        match current {
            None => {
                let p = self.alloc_page();
                self.slot_mut(slot).table[block] = Some(p);
                p
            }
            Some(p) if self.refcount[p] > 1 => {
                // First divergent write into a shared page.
                self.cow_splits += 1;
                let q = self.alloc_page();
                self.copy_page(p, q);
                self.release_page(p); // other holders keep the original
                self.slot_mut(slot).table[block] = Some(q);
                q
            }
            Some(p) => p,
        }
    }

    /// Write the current token's K/V rows for layer `l` into the slot's
    /// ring row for its position. Called once per layer per step;
    /// `advance` commits the position after the last layer.
    pub fn append(&mut self, slot: usize, l: usize, krow: &[f32],
                  vrow: &[f32]) {
        self.append_row_ahead(slot, l, 0, krow, vrow);
    }

    /// Write one K/V row for layer `l` at `ahead` positions past the
    /// slot's current (uncommitted) position — `ahead == 0` is `append`.
    /// The evicting-regime chunked prefill uses this to keep the
    /// per-token append→attend interleaving while the chunk's position
    /// commit stays a single `advance_by` after the last layer.
    pub fn append_row_ahead(&mut self, slot: usize, l: usize,
                            ahead: usize, krow: &[f32], vrow: &[f32]) {
        let w = self.kv_width();
        debug_assert_eq!(krow.len(), w, "k row width");
        debug_assert_eq!(vrow.len(), w, "v row width");
        let row = {
            let s = self.slot(slot);
            debug_assert!(ahead < s.cap,
                          "append_row_ahead past the ring capacity");
            (s.pos + ahead) % s.cap
        };
        let page = self.writable_block(slot, row / PAGE_SIZE);
        self.write_row(page, row % PAGE_SIZE, l, krow, vrow);
    }

    /// Land one K/V row in page `page`, in-page row `r`, at layer `l`'s
    /// storage width: f32 layers copy verbatim (bit-identical to the
    /// pre-quantization arena); quantized layers encode each kv head's
    /// `d_head` segment against fresh (scale, zero) affine parameters —
    /// the ONE quantization site, shared by the per-token and bulk
    /// append paths.
    fn write_row(&mut self, page: usize, r: usize, l: usize,
                 krow: &[f32], vrow: &[f32]) {
        let (w, dh, nkv) = (self.kv_width(), self.dh, self.nkv);
        match self.kv_bits[l] {
            16 => {
                let off =
                    page * self.f32_page_words + self.f32_off[l] + r * w;
                self.k[off..off + w].copy_from_slice(krow);
                self.v[off..off + w].copy_from_slice(vrow);
            }
            bits => {
                let levels = if bits == 8 { 255.0 } else { 15.0 };
                let rb = if bits == 8 { w } else { w / 2 };
                let hb = rb / nkv;
                let coff = page * self.code_page_bytes
                    + self.code_off[l] + r * rb;
                let moff = page * self.meta_page_words
                    + self.meta_off[l] + r * nkv;
                for h in 0..nkv {
                    let kseg = &krow[h * dh..(h + 1) * dh];
                    let vseg = &vrow[h * dh..(h + 1) * dh];
                    let (sk, zk) = kv_qparams(kseg, levels);
                    let (sv, zv) = kv_qparams(vseg, levels);
                    self.ks[moff + h] = sk;
                    self.kz[moff + h] = zk;
                    self.vs[moff + h] = sv;
                    self.vz[moff + h] = zv;
                    let at = coff + h * hb;
                    if bits == 8 {
                        for (i, &x) in kseg.iter().enumerate() {
                            self.kq[at + i] =
                                kv_encode(x, sk, zk, levels);
                        }
                        for (i, &x) in vseg.iter().enumerate() {
                            self.vq[at + i] =
                                kv_encode(x, sv, zv, levels);
                        }
                    } else {
                        for i in 0..hb {
                            self.kq[at + i] =
                                kv_encode(kseg[2 * i], sk, zk, levels)
                                | (kv_encode(kseg[2 * i + 1], sk, zk,
                                             levels) << 4);
                            self.vq[at + i] =
                                kv_encode(vseg[2 * i], sv, zv, levels)
                                | (kv_encode(vseg[2 * i + 1], sv, zv,
                                             levels) << 4);
                        }
                    }
                }
            }
        }
    }

    /// Map — and privatize — every block the slot's next `n` positions
    /// will write, up front: unmapped blocks allocate, and blocks shared
    /// with another slot copy-on-write NOW (the range overwrites them;
    /// other holders keep the original, so a donor's rows are never
    /// touched). Chunked prefill calls this once per chunk so page
    /// allocation and copy-on-write faults happen before any compute,
    /// and the per-layer appends then land in private, pre-mapped pages.
    /// `n` must fit the ring — a longer range would overwrite its own
    /// rows.
    pub fn alloc_range(&mut self, slot: usize, n: usize) {
        assert!(n > 0, "alloc_range: empty range for slot {slot}");
        let (pos, cap) = {
            let s = self.slot(slot);
            (s.pos, s.cap)
        };
        assert!(n <= cap,
                "alloc_range: {n} positions exceed slot {slot}'s ring \
                 capacity {cap}");
        let end = pos + n;
        let mut q = pos;
        while q < end {
            let row = q % cap;
            self.writable_block(slot, row / PAGE_SIZE);
            // Jump to the next block boundary or the ring wrap,
            // whichever comes first.
            let step = (PAGE_SIZE - row % PAGE_SIZE).min(cap - row);
            q += step.min(end - q);
        }
    }

    /// Bulk append: write `krows.len() / width` consecutive positions of
    /// layer `l` starting at the slot's current position, in one call —
    /// one block-table lookup and one `copy_from_slice` per touched page
    /// segment instead of per row. Does NOT advance the position
    /// (`advance_by` commits after the last layer, mirroring
    /// `append`/`advance`). Writes route through the copy-on-write
    /// check, so pre-mapping with `alloc_range` is an optimization, not
    /// a requirement. Caller contract: nothing may read a ring row this
    /// range overwrites between this call and the commit — in the
    /// evicting regime (`pos + rows > cap`) chunked prefill therefore
    /// uses `append_row_ahead` per row instead (see
    /// `Executor::prefill_chunk`).
    pub fn append_rows(&mut self, slot: usize, l: usize, krows: &[f32],
                       vrows: &[f32]) {
        let w = self.kv_width();
        assert_eq!(krows.len(), vrows.len(),
                   "append_rows: k/v length mismatch");
        assert!(!krows.is_empty() && krows.len() % w == 0,
                "append_rows: rows must be non-empty multiples of the \
                 kv width {w} (got {})", krows.len());
        let rows = krows.len() / w;
        let (pos, cap) = {
            let s = self.slot(slot);
            (s.pos, s.cap)
        };
        assert!(rows <= cap,
                "append_rows: {rows} rows exceed slot {slot}'s ring \
                 capacity {cap}");
        let mut done = 0usize;
        while done < rows {
            let row = (pos + done) % cap;
            let in_page = row % PAGE_SIZE;
            // Longest run of positions contiguous in this page: stops at
            // the page boundary, the ring wrap, or the end of the input.
            let seg = (PAGE_SIZE - in_page)
                .min(cap - row)
                .min(rows - done);
            let page = self.writable_block(slot, row / PAGE_SIZE);
            if self.kv_bits[l] == 16 {
                let off = page * self.f32_page_words + self.f32_off[l]
                    + in_page * w;
                self.k[off..off + seg * w].copy_from_slice(
                    &krows[done * w..(done + seg) * w]);
                self.v[off..off + seg * w].copy_from_slice(
                    &vrows[done * w..(done + seg) * w]);
            } else {
                // Quantized layers encode per row-segment either way;
                // the bulk win here is one block-table lookup (and CoW
                // check) per page segment instead of per row.
                for i in 0..seg {
                    let at = (done + i) * w;
                    self.write_row(page, in_page + i, l,
                                   &krows[at..at + w],
                                   &vrows[at..at + w]);
                }
            }
            done += seg;
        }
    }

    /// Commit the slot's current step: the next `append`/`window_rows`
    /// refer to the following position.
    pub fn advance(&mut self, slot: usize) {
        self.slot_mut(slot).pos += 1;
    }

    /// Commit `n` positions at once — the chunked-prefill counterpart of
    /// `advance`, called once after the last layer's bulk append.
    pub fn advance_by(&mut self, slot: usize, n: usize) {
        self.slot_mut(slot).pos += n;
    }

    /// View of layer `l`'s K/V for a slot, gathering through its block
    /// table (see `LayerKv`).
    pub fn layer_view(&self, l: usize, slot: usize) -> LayerKv<'_> {
        debug_assert!(l < self.n_layers, "layer {l} out of range");
        let s = self.slot(slot);
        let w = self.kv_width();
        let repr = match self.kv_bits[l] {
            16 => LayerRepr::F32 {
                k: &self.k,
                v: &self.v,
                stride: self.f32_page_words,
                base: self.f32_off[l],
            },
            bits => LayerRepr::Quant {
                bits,
                kq: &self.kq,
                vq: &self.vq,
                ks: &self.ks,
                kz: &self.kz,
                vs: &self.vs,
                vz: &self.vz,
                cstride: self.code_page_bytes,
                cbase: self.code_off[l],
                rb: if bits == 8 { w } else { w / 2 },
                mstride: self.meta_page_words,
                mbase: self.meta_off[l],
            },
        };
        LayerKv {
            table: &s.table,
            l,
            w,
            nkv: self.nkv,
            dh: self.dh,
            repr,
        }
    }

    /// Ring rows the slot's current step's attention reads, oldest →
    /// newest, INCLUDING the row of the token being decoded (append
    /// first, then attend — causal attention sees itself). Identical for
    /// every layer of a step, so callers compute it once per slot.
    pub fn window_rows(&self, slot: usize) -> Vec<usize> {
        self.window_rows_at(slot, self.slot(slot).pos)
    }

    /// Ring rows attention reads for a token at absolute position `pos`
    /// of this slot (oldest → newest, including `pos` itself — the
    /// causal window inside a chunk). `window_rows` is the
    /// current-position case; chunked prefill asks for every chunk row's
    /// window up front, before any append.
    pub fn window_rows_at(&self, slot: usize, pos: usize) -> Vec<usize> {
        let cap = self.slot(slot).cap;
        let lo = (pos + 1).saturating_sub(cap);
        (lo..=pos).map(|p| p % cap).collect()
    }

    /// Number of the slot's mapped pages currently shared with another
    /// slot (refcount > 1) — the copy-on-write observable the
    /// shared-prefix tests assert on.
    pub fn shared_page_count(&self, slot: usize) -> usize {
        self.slot(slot)
            .table
            .iter()
            .flatten()
            .filter(|&&p| self.refcount[p] > 1)
            .count()
    }

    /// Copy-on-write page splits performed since construction: each is
    /// one `writable_block` hit on a page with refcount > 1 (a sharer
    /// diverging from its donor, or an evicting ring wrapping into a
    /// still-shared block). Monotone — telemetry takes deltas.
    pub fn cow_splits(&self) -> u64 {
        self.cow_splits
    }

    /// Bytes resident in referenced K/V pages — codes and row-segment
    /// metadata included, so quantized layers report their true (4–8×
    /// smaller) footprint. Pages on the free list are excluded: they
    /// are reusable arena capacity, not sequence state. Compare
    /// `contiguous_bytes`.
    pub fn bytes(&self) -> usize {
        self.pages_in_use() * self.page_bytes()
    }

    /// Bytes the pre-paging contiguous all-f32 layout would hold
    /// resident for the currently admitted slots (every slot
    /// pre-allocated at its full capacity) — the memory-over-allocation
    /// baseline the paged bench section reports against, deliberately
    /// f32 so a quantized pool's ratio shows both savings.
    pub fn contiguous_bytes(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .map(|s| self.n_layers * 2 * s.cap * self.kv_width() * 4)
            .sum()
    }

    /// Block-accounting invariant, checked exhaustively: every page is
    /// referenced by block tables exactly `refcount` times, and sits on
    /// the free list exactly once iff its refcount is 0 — no leaks, no
    /// double frees, no dangling references. Test hook for the paged
    /// property suite; O(pages + mapped blocks).
    pub fn check_page_accounting(&self) -> Result<(), String> {
        let mut refs = vec![0u32; self.refcount.len()];
        for (si, s) in self.slots.iter().enumerate() {
            let Some(s) = s else { continue };
            for (b, page) in s.table.iter().enumerate() {
                if let Some(p) = *page {
                    if p >= refs.len() {
                        return Err(format!(
                            "slot {si} block {b} maps unknown page {p}"));
                    }
                    refs[p] += 1;
                }
            }
        }
        let mut on_free = vec![0u32; self.refcount.len()];
        for &p in &self.free {
            if p >= on_free.len() {
                return Err(format!("free list holds unknown page {p}"));
            }
            on_free[p] += 1;
        }
        for p in 0..self.refcount.len() {
            if refs[p] != self.refcount[p] {
                return Err(format!(
                    "page {p}: refcount {} but {} block-table references",
                    self.refcount[p], refs[p]));
            }
            let want = u32::from(self.refcount[p] == 0);
            if on_free[p] != want {
                return Err(format!(
                    "page {p}: refcount {} but on the free list {} \
                     times",
                    self.refcount[p], on_free[p]));
            }
        }
        Ok(())
    }
}

/// Single-sequence KV cache: one permanently-admitted slot of a
/// `KvCachePool`. This is the B=1 view the `decode_step` paths and the
/// benches use; `decode_step` itself runs as a one-row `decode_batch`.
#[derive(Clone, Debug)]
pub struct KvCache {
    pool: KvCachePool,
}

impl KvCache {
    pub fn new(n_layers: usize, nkv: usize, dh: usize, cap: usize) -> Self {
        assert!(cap > 0, "KvCache capacity must be positive");
        let mut pool = KvCachePool::new(n_layers, nkv, dh, 1);
        pool.admit(cap).expect("fresh pool has a free slot");
        KvCache { pool }
    }

    /// Cache sized for a model config with an explicit context capacity
    /// (use `cfg.seq` to mirror the full-forward context window).
    pub fn for_model(cfg: &ModelConfig, cap: usize) -> Self {
        KvCache::new(cfg.n_layers, cfg.n_kv, cfg.d_head, cap)
    }

    /// Whether this cache was laid out for `cfg`'s KV geometry.
    pub fn matches(&self, cfg: &ModelConfig) -> bool {
        self.pool.matches(cfg)
    }

    pub fn n_layers(&self) -> usize {
        self.pool.n_layers()
    }

    /// Absolute position of the next token (RoPE phase of the token the
    /// next `decode_step` will consume).
    pub fn pos(&self) -> usize {
        self.pool.pos(0)
    }

    pub fn capacity(&self) -> usize {
        self.pool.capacity(0)
    }

    /// Reset to an empty cache (pages return to the pool's free list
    /// and are recycled, not zeroed — every row is overwritten before
    /// attention can read it).
    pub fn clear(&mut self) {
        self.pool.reset(0);
    }

    /// Write the current token's K/V rows for layer `l` into the ring
    /// row for `pos`. Called once per layer per step; `advance` commits
    /// the position after the last layer.
    pub fn append(&mut self, l: usize, krow: &[f32], vrow: &[f32]) {
        self.pool.append(0, l, krow, vrow);
    }

    /// Commit the current step: the next `append`/`step_slots` refer to
    /// the following position.
    pub fn advance(&mut self) {
        self.pool.advance(0);
    }

    /// View of layer `l`'s K/V, gathered through the block table.
    pub fn layer_view(&self, l: usize) -> LayerKv<'_> {
        self.pool.layer_view(l, 0)
    }

    /// Ring rows the current step's attention reads, oldest → newest,
    /// INCLUDING the row of the token being decoded. See
    /// `KvCachePool::window_rows`.
    pub fn step_slots(&self) -> Vec<usize> {
        self.pool.window_rows(0)
    }

    /// Bytes resident in this cache's referenced K/V pages.
    pub fn bytes(&self) -> usize {
        self.pool.bytes()
    }

    /// The underlying one-slot pool (the sequence lives in slot 0) — how
    /// `decode_step` routes through the batched decode path.
    pub fn pool_mut(&mut self) -> &mut KvCachePool {
        &mut self.pool
    }

    pub fn pool(&self) -> &KvCachePool {
        &self.pool
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> KvCache {
        KvCache::new(2, 2, 4, 4)
    }

    #[test]
    fn append_advance_and_slots() {
        let mut c = tiny();
        assert_eq!(c.pos(), 0);
        assert_eq!(c.step_slots(), vec![0]);
        let krow: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let vrow: Vec<f32> = (0..8).map(|i| -(i as f32)).collect();
        c.append(0, &krow, &vrow);
        c.append(1, &krow, &vrow);
        c.advance();
        assert_eq!(c.pos(), 1);
        assert_eq!(c.step_slots(), vec![0, 1]);
        for l in 0..2 {
            let view = c.layer_view(l);
            assert_eq!(view.k_row(0), krow.as_slice(), "layer {l}");
            assert_eq!(view.v_row(0), vrow.as_slice(), "layer {l}");
        }
    }

    #[test]
    fn ring_wraps_and_window_saturates() {
        let mut c = tiny();
        for p in 0..6 {
            let row = vec![p as f32; 8];
            c.append(0, &row, &row);
            c.append(1, &row, &row);
            c.advance();
        }
        // pos=6: window is the last cap=4 logical positions 3,4,5,6 —
        // ring-row order 3, 0, 1, 2.
        assert_eq!(c.step_slots(), vec![3, 0, 1, 2]);
        // Ring row 0 holds position 4 (4 % 4 == 0), overwriting
        // position 0 — eviction recycled the block in place, so the
        // cache still occupies a single page.
        assert_eq!(c.layer_view(0).k_row(0)[0], 4.0);
        assert_eq!(c.pool().pages_in_use(), 1);
        c.pool().check_page_accounting().unwrap();
    }

    #[test]
    fn clear_resets_position_and_releases_pages() {
        let mut c = tiny();
        c.append(0, &[1.0; 8], &[1.0; 8]);
        c.advance();
        assert_eq!(c.pool().pages_in_use(), 1);
        c.clear();
        assert_eq!(c.pos(), 0);
        assert_eq!(c.step_slots(), vec![0]);
        assert_eq!(c.pool().pages_in_use(), 0);
        assert_eq!(c.bytes(), 0);
        c.pool().check_page_accounting().unwrap();
    }

    #[test]
    fn matches_config_geometry() {
        let cfg = ModelConfig::test_config();
        let mut c = KvCache::for_model(&cfg, cfg.seq);
        assert!(c.matches(&cfg));
        assert_eq!(c.n_layers(), cfg.n_layers);
        assert_eq!(c.capacity(), cfg.seq);
        // Paged: admission alone holds no memory; the first append
        // makes one page resident.
        assert_eq!(c.bytes(), 0);
        let w = cfg.n_kv * cfg.d_head;
        for l in 0..cfg.n_layers {
            c.append(l, &vec![0.5; w], &vec![0.5; w]);
        }
        c.advance();
        assert!(c.bytes() > 0);
        let other = KvCache::new(cfg.n_layers, cfg.n_kv + 1, cfg.d_head,
                                 cfg.seq);
        assert!(!other.matches(&cfg));
    }

    #[test]
    fn clone_is_independent() {
        let mut a = tiny();
        a.append(0, &[2.0; 8], &[2.0; 8]);
        a.advance();
        let b = a.clone();
        a.append(0, &[9.0; 8], &[9.0; 8]);
        a.advance();
        assert_eq!(b.pos(), 1);
        assert_eq!(a.pos(), 2);
        // The clone deep-copies the arena: a's second append is not
        // visible through b's view of row 0 (nor anywhere else in b).
        assert_eq!(b.layer_view(0).k_row(0)[0], 2.0);
        assert_eq!(a.layer_view(0).k_row(1)[0], 9.0);
    }

    #[test]
    fn pool_admit_retire_reuses_slots() {
        let mut p = KvCachePool::new(2, 2, 4, 3);
        assert_eq!(p.max_slots(), 3);
        assert_eq!(p.active_count(), 0);
        let a = p.admit(4).unwrap();
        let b = p.admit(6).unwrap();
        let c = p.admit(2).unwrap();
        assert_eq!((a, b, c), (0, 1, 2));
        assert!(p.admit(4).is_none(), "pool full");
        assert_eq!(p.free_count(), 0);
        // Heterogeneous per-slot capacities.
        assert_eq!(p.capacity(b), 6);
        assert_eq!(p.capacity(c), 2);
        p.retire(b);
        assert!(!p.is_active(b));
        assert_eq!(p.free_count(), 1);
        // The freed index is reused; survivors are untouched.
        let d = p.admit(8).unwrap();
        assert_eq!(d, b);
        assert_eq!(p.pos(d), 0);
        assert_eq!(p.capacity(d), 8);
        assert!(p.is_active(a) && p.is_active(c));
        p.check_page_accounting().unwrap();
    }

    #[test]
    fn pool_slots_are_independent() {
        let mut p = KvCachePool::new(1, 2, 4, 2);
        let a = p.admit(4).unwrap();
        let b = p.admit(4).unwrap();
        for i in 0..3 {
            p.append(a, 0, &[i as f32; 8], &[i as f32; 8]);
            p.advance(a);
        }
        p.append(b, 0, &[9.0; 8], &[9.0; 8]);
        p.advance(b);
        assert_eq!(p.pos(a), 3);
        assert_eq!(p.pos(b), 1);
        assert_eq!(p.window_rows(a), vec![0, 1, 2, 3]);
        assert_eq!(p.window_rows(b), vec![0, 1]);
        assert_eq!(p.layer_view(0, a).k_row(1)[0], 1.0);
        assert_eq!(p.layer_view(0, b).k_row(0)[0], 9.0);
        // Two independent (unshared) slots occupy two distinct pages.
        assert_eq!(p.pages_in_use(), 2);
        assert_eq!(p.shared_page_count(a), 0);
        p.check_page_accounting().unwrap();
    }

    #[test]
    fn pool_per_slot_ring_eviction() {
        let mut p = KvCachePool::new(1, 1, 2, 2);
        let small = p.admit(2).unwrap(); // evicts past 2 tokens
        let big = p.admit(8).unwrap(); // exact for the whole stream
        for i in 0..5 {
            for s in [small, big] {
                p.append(s, 0, &[i as f32; 2], &[i as f32; 2]);
                p.advance(s);
            }
        }
        // Small slot: window is the last 2 positions (4, 5-to-be).
        assert_eq!(p.window_rows(small).len(), 2);
        // Big slot: still exact, all 6 positions visible.
        assert_eq!(p.window_rows(big), vec![0, 1, 2, 3, 4, 5]);
        // Eviction recycles the small slot's block in place: the pool
        // still holds one page per slot.
        assert_eq!(p.pages_in_use(), 2);
        p.check_page_accounting().unwrap();
    }

    #[test]
    fn pages_allocate_lazily_and_follow_tokens_held() {
        // cap spans 4 pages, but memory follows appends, page by page.
        let mut p = KvCachePool::new(2, 1, 2, 1);
        let s = p.admit(4 * PAGE_SIZE).unwrap();
        assert_eq!(p.bytes(), 0);
        assert!(p.contiguous_bytes() > 0, "contiguous pre-allocates");
        for i in 0..PAGE_SIZE + 1 {
            for l in 0..2 {
                p.append(s, l, &[i as f32; 2], &[i as f32; 2]);
            }
            p.advance(s);
        }
        // PAGE_SIZE + 1 positions touch exactly two pages.
        assert_eq!(p.pages_in_use(), 2);
        assert!(p.bytes() < p.contiguous_bytes());
        p.retire(s);
        assert_eq!(p.pages_in_use(), 0);
        p.check_page_accounting().unwrap();
    }

    #[test]
    fn shared_prefix_pages_and_copy_on_write() {
        let mut p = KvCachePool::new(1, 1, 2, 2);
        let cap = 2 * PAGE_SIZE;
        let a = p.admit(cap).unwrap();
        // Donor holds PAGE_SIZE + 2 positions: one full page + a tail.
        let held = PAGE_SIZE + 2;
        for i in 0..held {
            p.append(a, 0, &[i as f32; 2], &[-(i as f32); 2]);
            p.advance(a);
        }
        assert_eq!(p.pages_in_use(), 2);
        // Share the whole resident prefix: the full page is referenced,
        // the 2-row tail is copied into a fresh page.
        let b = p.admit_shared(cap, a, held).unwrap();
        assert_eq!(p.pos(b), held);
        assert_eq!(p.pages_in_use(), 3); // 1 shared + donor tail + copy
        assert_eq!(p.shared_page_count(a), 1);
        assert_eq!(p.shared_page_count(b), 1);
        p.check_page_accounting().unwrap();
        // Both views read identical prefix rows (same page for block 0).
        for r in 0..held {
            assert_eq!(p.layer_view(0, a).k_row(r),
                       p.layer_view(0, b).k_row(r), "row {r}");
        }
        // b appends through its own tail page: no copy-on-write yet.
        p.append(b, 0, &[99.0; 2], &[99.0; 2]);
        p.advance(b);
        assert_eq!(p.shared_page_count(a), 1);
        // Fill b to capacity, then one more: the ring wraps into the
        // SHARED block 0 — first divergent write, copy-on-write.
        for _ in held + 1..cap {
            p.append(b, 0, &[0.5; 2], &[0.5; 2]);
            p.advance(b);
        }
        p.append(b, 0, &[7.0; 2], &[7.0; 2]);
        p.advance(b);
        assert_eq!(p.shared_page_count(a), 0, "page was copied");
        assert_eq!(p.shared_page_count(b), 0);
        assert_eq!(p.cow_splits(), 1, "exactly one CoW split counted");
        // Donor's row 0 is untouched; b's row 0 holds the new write.
        assert_eq!(p.layer_view(0, a).k_row(0)[0], 0.0);
        assert_eq!(p.layer_view(0, b).k_row(0)[0], 7.0);
        p.check_page_accounting().unwrap();
        // Retiring the donor keeps b's referenced pages alive.
        p.retire(a);
        assert!(p.check_page_accounting().is_ok());
        p.retire(b);
        assert_eq!(p.pages_in_use(), 0);
    }

    #[test]
    fn retired_donor_pages_survive_for_sharer() {
        let mut p = KvCachePool::new(1, 1, 2, 2);
        let a = p.admit(PAGE_SIZE).unwrap();
        for i in 0..PAGE_SIZE {
            p.append(a, 0, &[i as f32; 2], &[i as f32; 2]);
            p.advance(a);
        }
        let b = p.admit_shared(PAGE_SIZE, a, PAGE_SIZE).unwrap();
        p.retire(a);
        p.check_page_accounting().unwrap();
        // The shared page now belongs to b alone.
        assert_eq!(p.pages_in_use(), 1);
        assert_eq!(p.shared_page_count(b), 0);
        assert_eq!(p.layer_view(0, b).k_row(3)[0], 3.0);
    }

    /// Distinct per-(position, layer, salt) row so bulk/per-token
    /// comparisons catch any misplaced write (`salt` separates K from V).
    fn row_of(pos: usize, l: usize, salt: usize, w: usize) -> Vec<f32> {
        (0..w)
            .map(|c| (pos * 1000 + l * 100 + salt * 10 + c) as f32)
            .collect()
    }

    #[test]
    fn alloc_range_premaps_pages_up_front() {
        let mut p = KvCachePool::new(2, 1, 2, 1);
        let s = p.admit(3 * PAGE_SIZE).unwrap();
        assert_eq!(p.pages_in_use(), 0);
        // A range spanning one full page plus a partial second maps
        // both pages before any append.
        p.alloc_range(s, PAGE_SIZE + 3);
        assert_eq!(p.pages_in_use(), 2);
        p.check_page_accounting().unwrap();
        // Re-mapping the same range is a no-op.
        p.alloc_range(s, PAGE_SIZE + 3);
        assert_eq!(p.pages_in_use(), 2);
        p.check_page_accounting().unwrap();
    }

    #[test]
    fn bulk_append_matches_per_token_appends() {
        // Same writes through append/advance and through
        // alloc_range/append_rows/advance_by must leave bit-identical
        // rows — including a second chunk that wraps the ring (the
        // segment copy crosses the page boundary AND the ring wrap).
        let (layers, w, cap) = (2, 2, PAGE_SIZE + 4);
        let mut a = KvCachePool::new(layers, 1, w, 1);
        let mut b = KvCachePool::new(layers, 1, w, 1);
        let sa = a.admit(cap).unwrap();
        let sb = b.admit(cap).unwrap();
        let chunks = [PAGE_SIZE + 1, 5]; // second chunk wraps past cap
        let mut pos = 0usize;
        for &n in &chunks {
            for l in 0..layers {
                let mut ks = Vec::new();
                let mut vs = Vec::new();
                for i in 0..n {
                    ks.extend(row_of(pos + i, l, 0, w));
                    vs.extend(row_of(pos + i, l, 1, w));
                }
                b.append_rows(sb, l, &ks, &vs);
            }
            b.advance_by(sb, n);
            for i in 0..n {
                for l in 0..layers {
                    a.append(sa, l, &row_of(pos + i, l, 0, w),
                             &row_of(pos + i, l, 1, w));
                }
                a.advance(sa);
            }
            pos += n;
        }
        assert_eq!(a.pos(sa), b.pos(sb));
        for l in 0..layers {
            for r in 0..cap {
                assert_eq!(a.layer_view(l, sa).k_row(r),
                           b.layer_view(l, sb).k_row(r),
                           "k layer {l} row {r}");
                assert_eq!(a.layer_view(l, sa).v_row(r),
                           b.layer_view(l, sb).v_row(r),
                           "v layer {l} row {r}");
            }
        }
        a.check_page_accounting().unwrap();
        b.check_page_accounting().unwrap();
    }

    #[test]
    fn alloc_range_copies_shared_blocks_and_leaves_donor_intact() {
        // A sharer whose ring wraps back into the shared page: the
        // up-front alloc_range must copy-on-write that block (donor
        // keeps its rows) BEFORE any bulk append lands.
        let mut p = KvCachePool::new(1, 1, 2, 2);
        let a = p.admit(PAGE_SIZE).unwrap();
        for i in 0..PAGE_SIZE {
            p.append(a, 0, &[i as f32; 2], &[i as f32; 2]);
            p.advance(a);
        }
        let b = p.admit_shared(PAGE_SIZE, a, PAGE_SIZE).unwrap();
        assert_eq!(p.shared_page_count(a), 1);
        assert_eq!(p.pages_in_use(), 1);
        // b's next 3 positions wrap into the shared block 0.
        p.alloc_range(b, 3);
        assert_eq!(p.shared_page_count(a), 0, "block must be copied");
        assert_eq!(p.pages_in_use(), 2);
        p.check_page_accounting().unwrap();
        p.append_rows(b, 0, &[99.0; 6], &[99.0; 6]);
        p.advance_by(b, 3);
        // Donor rows untouched; sharer's copy holds the new rows and
        // still reads the un-overwritten prefix verbatim.
        assert_eq!(p.layer_view(0, a).k_row(0)[0], 0.0);
        assert_eq!(p.layer_view(0, b).k_row(0)[0], 99.0);
        assert_eq!(p.layer_view(0, b).k_row(3)[0], 3.0);
        p.check_page_accounting().unwrap();
    }

    #[test]
    fn window_rows_at_matches_window_rows() {
        let mut p = KvCachePool::new(1, 1, 2, 1);
        let s = p.admit(4).unwrap();
        for i in 0..6 {
            assert_eq!(p.window_rows(s), p.window_rows_at(s, i));
            p.append(s, 0, &[0.0; 2], &[0.0; 2]);
            p.advance(s);
        }
        // Future positions: the windows chunked prefill asks for.
        assert_eq!(p.window_rows_at(s, 7), vec![0, 1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "exceed slot")]
    fn append_rows_rejects_ranges_longer_than_the_ring() {
        let mut p = KvCachePool::new(1, 1, 2, 1);
        let s = p.admit(2).unwrap();
        p.append_rows(s, 0, &[0.0; 6], &[0.0; 6]); // 3 rows, cap 2
    }

    #[test]
    #[should_panic(expected = "exceed slot")]
    fn alloc_range_rejects_ranges_longer_than_the_ring() {
        let mut p = KvCachePool::new(1, 1, 2, 1);
        let s = p.admit(2).unwrap();
        p.alloc_range(s, 3);
    }

    #[test]
    #[should_panic(expected = "not admitted")]
    fn pool_rejects_inactive_slot_access() {
        let p = KvCachePool::new(1, 1, 2, 2);
        let _ = p.pos(0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn pool_rejects_out_of_range_slot_access() {
        let p = KvCachePool::new(1, 1, 2, 2);
        let _ = p.pos(5);
    }

    #[test]
    #[should_panic(expected = "capacity must be positive")]
    fn pool_rejects_zero_capacity_admit() {
        let mut p = KvCachePool::new(1, 1, 2, 2);
        let _ = p.admit(0);
    }

    #[test]
    #[should_panic(expected = "cannot share")]
    fn admit_shared_rejects_overlong_prefix() {
        let mut p = KvCachePool::new(1, 1, 2, 2);
        let a = p.admit(8).unwrap();
        p.append(a, 0, &[1.0; 2], &[1.0; 2]);
        p.advance(a);
        let _ = p.admit_shared(8, a, 2); // donor holds only 1 position
    }

    #[test]
    fn truncate_releases_tail_pages_and_keeps_live_rows() {
        let mut p = KvCachePool::new(1, 1, 2, 1);
        let s = p.admit(3 * PAGE_SIZE).unwrap();
        let held = 2 * PAGE_SIZE + 3;
        for i in 0..held {
            p.append(s, 0, &row_of(i, 0, 0, 2), &row_of(i, 0, 1, 2));
            p.advance(s);
        }
        assert_eq!(p.pages_in_use(), 3);
        // Roll back into the middle of block 1: block 2's rows are all
        // dead, so its page is released; block 1 keeps its live prefix.
        let keep = PAGE_SIZE + 2;
        p.truncate(s, keep);
        assert_eq!(p.pos(s), keep);
        assert_eq!(p.pages_in_use(), 2);
        p.check_page_accounting().unwrap();
        for r in 0..keep {
            assert_eq!(p.layer_view(0, s).k_row(r),
                       row_of(r, 0, 0, 2).as_slice(), "k row {r}");
            assert_eq!(p.layer_view(0, s).v_row(r),
                       row_of(r, 0, 1, 2).as_slice(), "v row {r}");
        }
        // Truncating to the current position is a no-op.
        p.truncate(s, keep);
        assert_eq!(p.pos(s), keep);
        // Appends resume from the rewound position, remapping the
        // released block on demand; old and new rows read back exactly.
        for i in keep..2 * PAGE_SIZE + 1 {
            p.append(s, 0, &row_of(i, 0, 2, 2), &row_of(i, 0, 3, 2));
            p.advance(s);
        }
        assert_eq!(p.pages_in_use(), 3);
        assert_eq!(p.layer_view(0, s).k_row(keep - 1),
                   row_of(keep - 1, 0, 0, 2).as_slice());
        assert_eq!(p.layer_view(0, s).k_row(keep),
                   row_of(keep, 0, 2, 2).as_slice());
        p.check_page_accounting().unwrap();
        // Rewinding to zero leaves no live row: every page goes back
        // to the free list, exactly like `reset`.
        p.truncate(s, 0);
        assert_eq!(p.pos(s), 0);
        assert_eq!(p.pages_in_use(), 0);
        p.check_page_accounting().unwrap();
    }

    #[test]
    fn truncate_drops_only_this_slots_page_references() {
        let mut p = KvCachePool::new(1, 1, 2, 2);
        let cap = 2 * PAGE_SIZE;
        let a = p.admit(cap).unwrap();
        for i in 0..cap {
            p.append(a, 0, &row_of(i, 0, 0, 2), &row_of(i, 0, 1, 2));
            p.advance(a);
        }
        // Page-aligned share: both donor blocks are referenced, no
        // tail copy is needed.
        let b = p.admit_shared(cap, a, cap).unwrap();
        assert_eq!(p.pages_in_use(), 2);
        assert_eq!(p.shared_page_count(a), 2);
        // The sharer rolls back past block 1: only ITS reference drops
        // — the donor keeps the page and every row in it.
        p.truncate(b, PAGE_SIZE);
        assert_eq!(p.pages_in_use(), 2);
        assert_eq!(p.shared_page_count(a), 1);
        assert_eq!(p.shared_page_count(b), 1);
        p.check_page_accounting().unwrap();
        for r in 0..cap {
            assert_eq!(p.layer_view(0, a).k_row(r),
                       row_of(r, 0, 0, 2).as_slice(), "donor row {r}");
        }
        // The sharer regrows through its own writes: block 1 remaps to
        // a fresh page while the donor's copy stays untouched.
        p.append(b, 0, &[7.0; 2], &[7.0; 2]);
        p.advance(b);
        assert_eq!(p.pages_in_use(), 3);
        assert_eq!(p.layer_view(0, b).k_row(PAGE_SIZE), [7.0; 2]);
        assert_eq!(p.layer_view(0, a).k_row(PAGE_SIZE),
                   row_of(PAGE_SIZE, 0, 0, 2).as_slice());
        p.check_page_accounting().unwrap();
        // Donor rewinds to zero: its references die, but the sharer's
        // view of the still-shared block 0 survives verbatim.
        p.truncate(a, 0);
        assert_eq!(p.pos(a), 0);
        assert_eq!(p.pages_in_use(), 2);
        assert_eq!(p.layer_view(0, b).k_row(0),
                   row_of(0, 0, 0, 2).as_slice());
        p.check_page_accounting().unwrap();
        p.retire(a);
        p.retire(b);
        assert_eq!(p.pages_in_use(), 0);
    }

    /// Random append / truncate / share / retire interleavings: the
    /// page-accounting invariants must hold after every operation, and
    /// every live row must read back the exact value written — across
    /// rollbacks, regrowth, and CoW shares whose donors rewind.
    #[test]
    fn truncate_accounting_survives_random_interleavings() {
        let mut state = 0x2545f4914f6cdd1du64;
        let mut rand = move |m: usize| -> usize {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            (state >> 33) as usize % m
        };
        let mut p = KvCachePool::new(1, 1, 2, 4);
        // Mirror of expected state: (slot, cap, per-row base value).
        // Appends never pass cap, so no slot ever wraps and every
        // mirrored row stays resident.
        let mut live: Vec<(usize, usize, Vec<f32>)> = Vec::new();
        let mut next_val = 1.0f32;
        for step in 0..400 {
            match rand(5) {
                0 if !live.is_empty() => {
                    let i = rand(live.len());
                    let (s, cap, rows) = &mut live[i];
                    if rows.len() < *cap {
                        let val = next_val;
                        next_val += 1.0;
                        p.append(*s, 0, &[val; 2], &[val + 0.5; 2]);
                        p.advance(*s);
                        rows.push(val);
                    }
                }
                1 if !live.is_empty() => {
                    let i = rand(live.len());
                    if !live[i].2.is_empty() {
                        let new_pos = rand(live[i].2.len() + 1);
                        let (s, _, rows) = &mut live[i];
                        p.truncate(*s, new_pos);
                        rows.truncate(new_pos);
                    }
                }
                2 => {
                    let cap = 1 + rand(3 * PAGE_SIZE);
                    if let Some(s) = p.admit(cap) {
                        live.push((s, cap, Vec::new()));
                    }
                }
                3 if !live.is_empty() => {
                    let i = rand(live.len());
                    let (donor, rows) = (live[i].0, live[i].2.clone());
                    if !rows.is_empty() {
                        let prefix = 1 + rand(rows.len());
                        let cap = prefix + rand(2 * PAGE_SIZE);
                        if let Some(s) = p.admit_shared(cap, donor,
                                                        prefix) {
                            live.push((s, cap,
                                       rows[..prefix].to_vec()));
                        }
                    }
                }
                _ if !live.is_empty() => {
                    let i = rand(live.len());
                    let (s, _, _) = live.swap_remove(i);
                    p.retire(s);
                }
                _ => {}
            }
            p.check_page_accounting()
                .unwrap_or_else(|e| panic!("step {step}: {e}"));
        }
        for (s, _, rows) in &live {
            assert_eq!(p.pos(*s), rows.len(), "slot {s} position");
            for (r, &val) in rows.iter().enumerate() {
                assert_eq!(p.layer_view(0, *s).k_row(r), [val; 2],
                           "slot {s} k row {r}");
                assert_eq!(p.layer_view(0, *s).v_row(r),
                           [val + 0.5; 2], "slot {s} v row {r}");
            }
        }
        for (s, _, _) in live {
            p.retire(s);
        }
        assert_eq!(p.pages_in_use(), 0);
        p.check_page_accounting().unwrap();
    }

    #[test]
    #[should_panic(expected = "wrapped its ring")]
    fn truncate_rejects_wrapped_slots() {
        let mut p = KvCachePool::new(1, 1, 2, 1);
        let s = p.admit(2).unwrap();
        for i in 0..3 {
            p.append(s, 0, &[i as f32; 2], &[i as f32; 2]);
            p.advance(s);
        }
        p.truncate(s, 1); // pos 3 > cap 2: row 1 was recycled in place
    }

    #[test]
    #[should_panic(expected = "is past")]
    fn truncate_rejects_forward_positions() {
        let mut p = KvCachePool::new(1, 1, 2, 1);
        let s = p.admit(4).unwrap();
        p.append(s, 0, &[0.0; 2], &[0.0; 2]);
        p.advance(s);
        p.truncate(s, 3);
    }

    #[test]
    fn qparams_roundtrip_bound_and_degenerate_segments() {
        // Affine params reconstruct within half a quantization step.
        for levels in [255.0f32, 15.0] {
            let seg = [-1.25f32, 0.5, 3.0, -0.125, 2.75, 0.0];
            let (s, z) = kv_qparams(&seg, levels);
            for &x in &seg {
                let c = kv_encode(x, s, z, levels);
                let xhat = s * (c as f32 - z);
                assert!((x - xhat).abs() <= s * 0.5 + 1e-6,
                        "levels {levels}: {x} -> {xhat} (step {s})");
            }
            // Endpoints hit the first and last codes.
            assert_eq!(kv_encode(-1.25, s, z, levels), 0);
            assert_eq!(kv_encode(3.0, s, z, levels), levels as u8);
        }
        // Constant segments (zero rows included) round-trip EXACTLY:
        // scale 1, zero −min, every code 0.
        for c0 in [0.0f32, -7.5, 42.0] {
            let (s, z) = kv_qparams(&[c0; 4], 15.0);
            let c = kv_encode(c0, s, z, 15.0);
            assert_eq!(c, 0);
            assert_eq!(s * (c as f32 - z), c0);
        }
    }

    #[test]
    fn quantized_rows_read_back_within_step_and_shrink_bytes() {
        let (nkv, dh) = (2usize, 32);
        let w = nkv * dh;
        let rows: Vec<Vec<f32>> = (0..PAGE_SIZE)
            .map(|r| (0..w)
                .map(|i| ((r * w + i) as f32 * 0.37).sin() * 3.0)
                .collect())
            .collect();
        let mut byte_sizes = Vec::new();
        for bits in [16u8, 8, 4] {
            let mut p =
                KvCachePool::with_kv_bits(1, nkv, dh, 1, &[bits]);
            let s = p.admit(PAGE_SIZE).unwrap();
            for row in &rows {
                p.append(s, 0, row, row);
                p.advance(s);
            }
            let view = p.layer_view(0, s);
            assert_eq!(view.bits(), bits);
            let tol = match bits {
                16 => 0.0,
                8 => 6.0 / 255.0 * 0.5 + 1e-6, // range ≤ 6, half step
                _ => 6.0 / 15.0 * 0.5 + 1e-6,
            };
            for (r, row) in rows.iter().enumerate() {
                let back = view.k_row_dequant(r);
                let vback = view.v_row_dequant(r);
                for i in 0..w {
                    assert!((back[i] - row[i]).abs() <= tol,
                            "bits {bits} row {r} col {i}: {} vs {}",
                            back[i], row[i]);
                    assert_eq!(back[i], vback[i]);
                }
            }
            byte_sizes.push(p.bytes());
        }
        // One page resident each. At dh = 32 the per-segment (scale,
        // zero) overhead leaves f32/kv8 = 4·dh/(dh+8) = 3.2× and
        // f32/kv4 = 8·dh/(dh+16) ≈ 5.3×.
        assert!(byte_sizes[0] >= 3 * byte_sizes[1],
                "f32 {} vs kv8 {}", byte_sizes[0], byte_sizes[1]);
        assert!(byte_sizes[1] > byte_sizes[2],
                "kv8 {} vs kv4 {}", byte_sizes[1], byte_sizes[2]);
    }

    #[test]
    fn quantized_pages_share_cow_and_truncate_like_f32() {
        // The donor/sharer/CoW/rollback machinery is precision-agnostic:
        // a shared quantized page reads back identically from both
        // slots, and a divergent write splits only the writer's copy.
        let (nkv, dh) = (1usize, 4);
        let mut p = KvCachePool::with_kv_bits(1, nkv, dh, 2, &[4]);
        let a = p.admit(2 * PAGE_SIZE).unwrap();
        for r in 0..PAGE_SIZE + 4 {
            let row = vec![r as f32 * 0.5 - 3.0; 4];
            p.append(a, 0, &row, &row);
            p.advance(a);
        }
        let b = p.admit_shared(2 * PAGE_SIZE, a, PAGE_SIZE).unwrap();
        assert_eq!(p.shared_page_count(b), 1);
        for r in 0..PAGE_SIZE {
            assert_eq!(p.layer_view(0, a).k_row_dequant(r),
                       p.layer_view(0, b).k_row_dequant(r), "row {r}");
        }
        // The sharer's continuation lands in its own fresh block; the
        // donor's row at the same ring position stays untouched.
        p.append(b, 0, &[9.0; 4], &[9.0; 4]);
        p.advance(b);
        assert_eq!(p.layer_view(0, a).k_row_dequant(PAGE_SIZE),
                   vec![(PAGE_SIZE as f32) * 0.5 - 3.0; 4]);
        assert_eq!(p.layer_view(0, b).k_row_dequant(PAGE_SIZE),
                   vec![9.0; 4]);
        // Rollback releases the sharer's dead tail page, donor intact.
        p.truncate(b, 2);
        p.check_page_accounting().unwrap();
        assert_eq!(p.layer_view(0, b).k_row_dequant(1),
                   p.layer_view(0, a).k_row_dequant(1));
        p.retire(a);
        p.retire(b);
        assert_eq!(p.pages_in_use(), 0);
        p.check_page_accounting().unwrap();
    }

    #[test]
    #[should_panic(expected = "4, 8 or 16")]
    fn rejects_unsupported_kv_bits() {
        KvCachePool::with_kv_bits(1, 1, 2, 1, &[2]);
    }

    #[test]
    #[should_panic(expected = "must be even")]
    fn rejects_int4_with_odd_head_dim() {
        KvCachePool::with_kv_bits(1, 1, 3, 1, &[4]);
    }

    #[test]
    #[should_panic(expected = "f32 read of quantized layer")]
    fn f32_row_view_of_quantized_layer_panics() {
        let mut p = KvCachePool::with_kv_bits(1, 1, 2, 1, &[8]);
        let s = p.admit(4).unwrap();
        p.append(s, 0, &[1.0, 2.0], &[3.0, 4.0]);
        p.advance(s);
        let _ = p.layer_view(0, s).k_row(0);
    }
}
