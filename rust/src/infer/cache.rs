//! Per-request KV cache for incremental (autoregressive) decoding.
//!
//! One `KvCache` holds, for every layer, a ring buffer of the roped K and
//! raw V rows of the tokens decoded so far, in the GQA head layout
//! (`n_kv · d_head` columns — query heads share their group's KV rows, so
//! the cache stores `n_kv` heads, not `n_heads`). `decode_step` appends
//! the current token's K/V to every layer and attends over the window,
//! which is what makes per-token cost independent of the prefix length
//! (the full-sequence `forward` recomputes the whole prefix every call).
//!
//! Capacity is fixed at construction. While `pos < cap` the cache is
//! exact: attention sees every previous token and incremental decode
//! matches the full forward bit-for-bit (see
//! `rust/tests/decode_equivalence.rs`). Once `pos` reaches `cap` the ring
//! wraps and the oldest entries are evicted — sliding-window attention
//! over the last `cap` positions (keys keep their absolute RoPE phases,
//! the StreamingLLM-style regime without sink tokens).

use crate::model::ModelConfig;

/// Ring-buffered K/V rows for all layers of one decoding request.
#[derive(Clone, Debug)]
pub struct KvCache {
    nkv: usize,
    dh: usize,
    cap: usize,
    /// Absolute position of the NEXT token to be decoded (== number of
    /// tokens fully appended so far).
    pos: usize,
    /// Per layer: roped keys, [cap, nkv·dh] ring (row = position % cap).
    k: Vec<Vec<f32>>,
    /// Per layer: values, same layout.
    v: Vec<Vec<f32>>,
}

impl KvCache {
    pub fn new(n_layers: usize, nkv: usize, dh: usize, cap: usize) -> Self {
        assert!(cap > 0, "KvCache capacity must be positive");
        assert!(n_layers > 0 && nkv > 0 && dh > 0);
        let w = cap * nkv * dh;
        KvCache {
            nkv,
            dh,
            cap,
            pos: 0,
            k: (0..n_layers).map(|_| vec![0.0; w]).collect(),
            v: (0..n_layers).map(|_| vec![0.0; w]).collect(),
        }
    }

    /// Cache sized for a model config with an explicit context capacity
    /// (use `cfg.seq` to mirror the full-forward context window).
    pub fn for_model(cfg: &ModelConfig, cap: usize) -> Self {
        KvCache::new(cfg.n_layers, cfg.n_kv, cfg.d_head, cap)
    }

    /// Whether this cache was laid out for `cfg`'s KV geometry.
    pub fn matches(&self, cfg: &ModelConfig) -> bool {
        self.k.len() == cfg.n_layers
            && self.nkv == cfg.n_kv
            && self.dh == cfg.d_head
    }

    pub fn n_layers(&self) -> usize {
        self.k.len()
    }

    /// Absolute position of the next token (RoPE phase of the token the
    /// next `decode_step` will consume).
    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Reset to an empty cache (buffers are reused, not zeroed — every
    /// slot is overwritten before attention can read it).
    pub fn clear(&mut self) {
        self.pos = 0;
    }

    /// Write the current token's K/V rows for layer `l` into the ring
    /// slot for `pos`. Called once per layer per step; `advance` commits
    /// the position after the last layer.
    pub fn append(&mut self, l: usize, krow: &[f32], vrow: &[f32]) {
        let w = self.nkv * self.dh;
        debug_assert_eq!(krow.len(), w, "k row width");
        debug_assert_eq!(vrow.len(), w, "v row width");
        let slot = self.pos % self.cap;
        self.k[l][slot * w..(slot + 1) * w].copy_from_slice(krow);
        self.v[l][slot * w..(slot + 1) * w].copy_from_slice(vrow);
    }

    /// Commit the current step: the next `append`/`step_slots` refer to
    /// the following position.
    pub fn advance(&mut self) {
        self.pos += 1;
    }

    /// Raw (k, v) ring buffers of layer `l` ([cap, nkv·dh] row-major).
    pub fn layer(&self, l: usize) -> (&[f32], &[f32]) {
        (&self.k[l], &self.v[l])
    }

    /// Ring slots the current step's attention reads, oldest → newest,
    /// INCLUDING the slot of the token being decoded (append first, then
    /// attend — causal attention sees itself). Identical for every layer
    /// of a step, so callers compute it once.
    pub fn step_slots(&self) -> Vec<usize> {
        let hi = self.pos; // current token's logical position (inclusive)
        let lo = (hi + 1).saturating_sub(self.cap);
        (lo..=hi).map(|p| p % self.cap).collect()
    }

    /// Bytes resident in this cache's K/V buffers.
    pub fn bytes(&self) -> usize {
        self.k.len() * 2 * self.cap * self.nkv * self.dh * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> KvCache {
        KvCache::new(2, 2, 4, 4)
    }

    #[test]
    fn append_advance_and_slots() {
        let mut c = tiny();
        assert_eq!(c.pos(), 0);
        assert_eq!(c.step_slots(), vec![0]);
        let krow: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let vrow: Vec<f32> = (0..8).map(|i| -(i as f32)).collect();
        c.append(0, &krow, &vrow);
        c.append(1, &krow, &vrow);
        c.advance();
        assert_eq!(c.pos(), 1);
        assert_eq!(c.step_slots(), vec![0, 1]);
        let (k0, v0) = c.layer(0);
        assert_eq!(&k0[..8], krow.as_slice());
        assert_eq!(&v0[..8], vrow.as_slice());
    }

    #[test]
    fn ring_wraps_and_window_saturates() {
        let mut c = tiny();
        for p in 0..6 {
            let row = vec![p as f32; 8];
            c.append(0, &row, &row);
            c.append(1, &row, &row);
            c.advance();
        }
        // pos=6: window is the last cap=4 logical positions 3,4,5,6 —
        // slot order 3, 0, 1, 2.
        assert_eq!(c.step_slots(), vec![3, 0, 1, 2]);
        // Slot 0 holds position 4 (4 % 4 == 0), overwriting position 0.
        let (k0, _) = c.layer(0);
        assert_eq!(k0[0], 4.0);
    }

    #[test]
    fn clear_resets_position() {
        let mut c = tiny();
        c.append(0, &[1.0; 8], &[1.0; 8]);
        c.advance();
        c.clear();
        assert_eq!(c.pos(), 0);
        assert_eq!(c.step_slots(), vec![0]);
    }

    #[test]
    fn matches_config_geometry() {
        let cfg = ModelConfig::test_config();
        let c = KvCache::for_model(&cfg, cfg.seq);
        assert!(c.matches(&cfg));
        assert_eq!(c.n_layers(), cfg.n_layers);
        assert_eq!(c.capacity(), cfg.seq);
        assert!(c.bytes() > 0);
        let other = KvCache::new(cfg.n_layers, cfg.n_kv + 1, cfg.d_head,
                                 cfg.seq);
        assert!(!other.matches(&cfg));
    }

    #[test]
    fn clone_is_independent() {
        let mut a = tiny();
        a.append(0, &[2.0; 8], &[2.0; 8]);
        a.advance();
        let b = a.clone();
        a.append(0, &[9.0; 8], &[9.0; 8]);
        a.advance();
        assert_eq!(b.pos(), 1);
        assert_eq!(a.pos(), 2);
        assert_eq!(b.layer(0).0[8], 0.0); // slot 1 untouched in the clone
    }
}
