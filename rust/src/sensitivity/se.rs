//! Structural Expressiveness (paper §2.2, Eqs. 6–9 + Appendices D.3–D.5).
//!
//! Base form (Eq. 7):  𝓔_base = ‖σ‖₁ · exp(H(σ)) on the top-90 %-energy
//! spectrum. Role-aware form reweights each singular value before Eq. 7:
//!   * Detectors  — Detection Specificity β_DS = log1p(ReLU(κ(input vec)))
//!     (Eq. 8 + the robust sub-linear transform of App. D.4); for QK the
//!     raw factor is the PRODUCT κ(query-side)·κ(key-side) (App. D.5).
//!   * Writers    — Writing Density β_WD = ‖W_Uᵀ u_i‖₁ (Eq. 9, logit lens)
//!     with W_U pre-truncated to its top-90 % subspace (App. D.3).

use crate::model::decompose::{CompKind, Component, Role};
use crate::tensor::matmul::vecmat;
use crate::tensor::stats::{excess_kurtosis, spectral_entropy};
use crate::tensor::svd::{svd, Svd};
use crate::tensor::Tensor;

/// Eq. 7 on a (possibly reweighted) spectrum.
pub fn base_expressiveness(sigma: &[f64]) -> f64 {
    let l1: f64 = sigma.iter().sum();
    let h = spectral_entropy(sigma);
    l1 * h.exp()
}

/// App. D.4: β = log(1 + ReLU(x)) — kills flat/uniform detectors
/// (κ < 0 ⇒ 0) and rewards sharp ones sub-linearly.
pub fn sublinear(x: f64) -> f64 {
    (1.0 + x.max(0.0)).ln()
}

/// Pre-truncate the unembedding matrix to its top-90 % SVD subspace
/// (App. D.3: "filter out vocabulary noise"). Returns the reconstructed
/// [D, V] matrix.
pub fn truncated_unembed(wu: &Tensor, energy_frac: f64) -> Tensor {
    let s = svd(wu);
    let r = s.energy_rank(energy_frac);
    s.truncate(r).reconstruct()
}

/// Role-aware SE (Eq. 7 after σᵢ ← σᵢ·βᵢ). `s` must already be truncated to
/// the top-90 % spectrum; `wu_trunc` is the pre-truncated unembedding.
pub fn role_aware_expressiveness(c: &Component, s: &Svd, wu_trunc: &Tensor)
    -> f64 {
    let mut sigma = Vec::with_capacity(s.sigma.len());
    match c.kind.role() {
        Role::Detector => {
            let inputs = c.input_vectors(s);
            // QK interacts on both sides (App. D.5): κ(query)·κ(key).
            let both = c.kind == CompKind::Qk;
            let outputs = c.output_vectors(s);
            for (i, &sv) in s.sigma.iter().enumerate() {
                let k_in = excess_kurtosis(&inputs.col(i));
                let raw = if both {
                    k_in * excess_kurtosis(&outputs.col(i))
                } else {
                    k_in
                };
                sigma.push(sv * sublinear(raw));
            }
        }
        Role::Writer => {
            let outputs = c.output_vectors(s); // columns in R^{d_model}
            for (i, &sv) in s.sigma.iter().enumerate() {
                let u_i = outputs.col(i);
                let proj = vecmat(&u_i, wu_trunc); // u_iᵀ W_U ∈ R^V
                let l1: f64 =
                    proj.iter().map(|x| x.abs() as f64).sum();
                sigma.push(sv * l1);
            }
        }
    }
    base_expressiveness(&sigma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::decompose::{CompKind, Component};
    use crate::util::rng::Rng;

    #[test]
    fn base_rewards_rich_spectra() {
        // Flat spectrum (high entropy) beats a spiked one of equal L1 mass.
        let flat = vec![1.0; 8];
        let mut spiked = vec![0.0; 8];
        spiked[0] = 8.0;
        assert!(base_expressiveness(&flat) > base_expressiveness(&spiked));
    }

    #[test]
    fn base_scales_with_magnitude() {
        let s = vec![3.0, 2.0, 1.0];
        let s2: Vec<f64> = s.iter().map(|x| x * 2.0).collect();
        let r = base_expressiveness(&s2) / base_expressiveness(&s);
        assert!((r - 2.0).abs() < 1e-12, "ratio {r}");
    }

    #[test]
    fn sublinear_clamps_and_grows() {
        assert_eq!(sublinear(-5.0), 0.0);
        assert_eq!(sublinear(0.0), 0.0);
        assert!(sublinear(10.0) > sublinear(1.0));
        assert!(sublinear(1000.0) < 1000.0); // sub-linear
    }

    #[test]
    fn truncated_unembed_reduces_rank() {
        let mut rng = Rng::new(3);
        // Construct a [8, 32] matrix with a dominant direction + noise.
        let mut wu = Tensor::randn(vec![8, 32], &mut rng).scale(0.05);
        let u = rng.normal_vec(8);
        let v = rng.normal_vec(32);
        for i in 0..8 {
            for j in 0..32 {
                let val = wu.at(i, j) + 4.0 * u[i] as f32 * v[j] as f32;
                wu.set(i, j, val);
            }
        }
        let t = truncated_unembed(&wu, 0.9);
        assert_eq!(t.dims(), wu.dims());
        let s_t = svd(&t);
        let s_w = svd(&wu);
        // Truncation keeps the head of the spectrum, kills the tail.
        assert!((s_t.sigma[0] - s_w.sigma[0]).abs() / s_w.sigma[0] < 1e-3);
        assert!(s_t.sigma[5] < s_w.sigma[5] + 1e-6);
    }

    #[test]
    fn writer_beta_tracks_unembed_alignment() {
        // A writer whose output direction aligns with W_U's row space gets
        // a higher SE than one writing into W_U's null space.
        let d = 8;
        let v = 16;
        // W_U maps only the first 4 residual dims to logits.
        let mut wu = Tensor::zeros(vec![d, v]);
        for i in 0..4 {
            for j in 0..v {
                wu.set(i, j, if (i + j) % 2 == 0 { 1.0 } else { -1.0 });
            }
        }
        let make_writer = |aligned: bool| {
            // rank-2 matrix writing into dims {0,1} or {6,7}.
            let mut m = Tensor::zeros(vec![d, d]);
            let off = if aligned { 0 } else { 6 };
            m.set(0, off, 2.0);
            m.set(1, off + 1, 1.5);
            Component { kind: CompKind::Ov, layer: 0, head: 0, matrix: m }
        };
        let score = |c: &Component| {
            let s = svd(&c.matrix);
            let s = s.truncate(s.energy_rank(0.999));
            role_aware_expressiveness(c, &s, &wu)
        };
        let hi = score(&make_writer(true));
        let lo = score(&make_writer(false));
        assert!(hi > lo * 10.0, "aligned {hi} vs null-space {lo}");
    }
}
