//! Numerical Vulnerability (paper §2.2, Eq. 5): excess kurtosis of the
//! flattened component weights. Heavy-tailed components stretch the
//! quantization range and degrade hardest under low-bit quantization.

use crate::tensor::stats::excess_kurtosis;
use crate::tensor::Tensor;

/// NV score of one component matrix.
pub fn numerical_vulnerability(w: &Tensor) -> f64 {
    excess_kurtosis(w.data())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn outlier_injection_raises_nv() {
        let mut rng = Rng::new(1);
        let base = Tensor::randn(vec![32, 32], &mut rng);
        let nv0 = numerical_vulnerability(&base);
        let mut spiked = base.clone();
        for i in 0..10 {
            spiked.data_mut()[i * 97] *= 30.0;
        }
        let nv1 = numerical_vulnerability(&spiked);
        assert!(nv1 > nv0 + 5.0, "nv0={nv0} nv1={nv1}");
    }

    #[test]
    fn constant_matrix_zero() {
        let t = Tensor::new(vec![3.0; 64], vec![8, 8]);
        assert_eq!(numerical_vulnerability(&t), 0.0);
    }
}
