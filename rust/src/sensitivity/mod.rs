//! NSDS sensitivity estimation (paper §2.2–2.3): Numerical Vulnerability,
//! role-aware Structural Expressiveness, and the full layer-scoring
//! pipeline with ablation switches.

pub mod nv;
pub mod se;

use std::collections::BTreeMap;

use crate::aggregate::{mad_sigmoid, plain_z, soft_or, soft_or2};
use crate::model::decompose::{decompose_layer, CompKind};
use crate::model::{ModelConfig, Weights};
use crate::tensor::svd::svd;
use crate::tensor::Tensor;
use crate::util::pool::parallel_map;

/// Ablation variants (Fig. 4 / Fig. 8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ablation {
    /// The full NSDS metric.
    Full,
    /// w/o NV — structural term only.
    NoNv,
    /// w/o SE — numerical term only.
    NoSe,
    /// w/o β_DS & β_WD — raw singular values in Eq. 7.
    NoBeta,
    /// w/o MAD-Sigmoid & Soft-OR — plain z-score + arithmetic mean.
    NoAgg,
}

#[derive(Clone, Copy, Debug)]
pub struct NsdsOptions {
    /// SVD truncation energy (paper App. D.3; default 0.90).
    pub energy_frac: f64,
    pub ablation: Ablation,
    /// Worker threads for the per-layer scoring loop.
    pub workers: usize,
}

impl Default for NsdsOptions {
    fn default() -> Self {
        NsdsOptions {
            energy_frac: 0.90,
            ablation: Ablation::Full,
            workers: crate::util::pool::default_workers(),
        }
    }
}

/// Raw per-(layer, component-type) scores: NV and SE, with QK/OV averaged
/// across heads (paper §3.1 "computed per head and then averaged").
#[derive(Clone, Debug)]
pub struct RawScores {
    pub n_layers: usize,
    /// [kind][layer] raw NV (excess kurtosis).
    pub nv: BTreeMap<CompKind, Vec<f64>>,
    /// [kind][layer] raw SE (role-aware spectral capacity).
    pub se: BTreeMap<CompKind, Vec<f64>>,
}

/// Compute raw NV/SE scores for every layer and component type.
pub fn raw_scores(cfg: &ModelConfig, w: &Weights, opts: &NsdsOptions)
    -> RawScores {
    // Pre-compute the truncated unembedding subspace once (App. D.3).
    let wu_trunc = se::truncated_unembed(w.get("unembed"), opts.energy_frac);

    let per_layer: Vec<BTreeMap<CompKind, (f64, f64)>> =
        parallel_map(cfg.n_layers, opts.workers, |l| {
            score_layer(cfg, w, l, &wu_trunc, opts)
        });

    let mut nv = BTreeMap::new();
    let mut se_m = BTreeMap::new();
    for kind in CompKind::ALL {
        let nv_col: Vec<f64> =
            per_layer.iter().map(|m| m[&kind].0).collect();
        let se_col: Vec<f64> =
            per_layer.iter().map(|m| m[&kind].1).collect();
        nv.insert(kind, nv_col);
        se_m.insert(kind, se_col);
    }
    RawScores { n_layers: cfg.n_layers, nv, se: se_m }
}

/// One layer: decompose, score every component, average QK/OV over heads.
fn score_layer(cfg: &ModelConfig, w: &Weights, l: usize, wu_trunc: &Tensor,
               opts: &NsdsOptions) -> BTreeMap<CompKind, (f64, f64)> {
    let comps = decompose_layer(cfg, w, l);
    let mut acc: BTreeMap<CompKind, (f64, f64, usize)> = BTreeMap::new();
    for c in &comps {
        let nv = nv::numerical_vulnerability(&c.matrix);
        let s = svd(&c.matrix);
        let s = s.truncate(s.energy_rank(opts.energy_frac));
        let se = if opts.ablation == Ablation::NoBeta {
            se::base_expressiveness(&s.sigma)
        } else {
            se::role_aware_expressiveness(c, &s, wu_trunc)
        };
        let e = acc.entry(c.kind).or_insert((0.0, 0.0, 0));
        e.0 += nv;
        e.1 += se;
        e.2 += 1;
    }
    acc.into_iter()
        .map(|(k, (nv, se, n))| (k, (nv / n as f64, se / n as f64)))
        .collect()
}

/// Full NSDS layer scores (paper Algorithm 1 phases 1–2).
/// Returns one sensitivity score per layer, higher = more sensitive.
pub fn nsds_layer_scores(cfg: &ModelConfig, w: &Weights,
                         opts: &NsdsOptions) -> Vec<f64> {
    let raw = raw_scores(cfg, w, opts);
    aggregate_scores(&raw, opts.ablation)
}

/// Phase 2: normalize per component type across layers, Soft-OR within the
/// layer, merge NV and SE. Separated from `raw_scores` so ablations and the
/// Fig. 7 heatmap can reuse the expensive raw computation.
pub fn aggregate_scores(raw: &RawScores, ablation: Ablation) -> Vec<f64> {
    let l = raw.n_layers;
    match ablation {
        Ablation::NoAgg => {
            // Plain z-normalization + arithmetic-mean aggregation.
            let mut total = vec![0.0f64; l];
            let mut terms = 0usize;
            for kind in CompKind::ALL {
                for src in [&raw.nv[&kind], &raw.se[&kind]] {
                    let z = plain_z(src);
                    for (t, zi) in total.iter_mut().zip(z) {
                        *t += zi;
                    }
                    terms += 1;
                }
            }
            return total.into_iter().map(|t| t / terms as f64).collect();
        }
        _ => {}
    }
    // MAD-Sigmoid per component type (pooled across layers).
    let mut p_nv: BTreeMap<CompKind, Vec<f64>> = BTreeMap::new();
    let mut p_se: BTreeMap<CompKind, Vec<f64>> = BTreeMap::new();
    for kind in CompKind::ALL {
        p_nv.insert(kind, mad_sigmoid(&raw.nv[&kind]));
        p_se.insert(kind, mad_sigmoid(&raw.se[&kind]));
    }
    (0..l)
        .map(|li| {
            let nv_ps: Vec<f64> =
                CompKind::ALL.iter().map(|k| p_nv[k][li]).collect();
            let se_ps: Vec<f64> =
                CompKind::ALL.iter().map(|k| p_se[k][li]).collect();
            let s_nv = soft_or(&nv_ps);
            let s_se = soft_or(&se_ps);
            match ablation {
                Ablation::NoNv => s_se,
                Ablation::NoSe => s_nv,
                _ => soft_or2(s_nv, s_se),
            }
        })
        .collect()
}

/// Layer-wise S_NV and S_SE separately (Fig. 1 / Fig. 7 exhibits).
pub fn nv_se_layer_scores(raw: &RawScores) -> (Vec<f64>, Vec<f64>) {
    let l = raw.n_layers;
    let mut p_nv: BTreeMap<CompKind, Vec<f64>> = BTreeMap::new();
    let mut p_se: BTreeMap<CompKind, Vec<f64>> = BTreeMap::new();
    for kind in CompKind::ALL {
        p_nv.insert(kind, mad_sigmoid(&raw.nv[&kind]));
        p_se.insert(kind, mad_sigmoid(&raw.se[&kind]));
    }
    let nv = (0..l)
        .map(|li| {
            soft_or(&CompKind::ALL.iter().map(|k| p_nv[k][li])
                .collect::<Vec<_>>())
        })
        .collect();
    let se = (0..l)
        .map(|li| {
            soft_or(&CompKind::ALL.iter().map(|k| p_se[k][li])
                .collect::<Vec<_>>())
        })
        .collect();
    (nv, se)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn test_setup() -> (ModelConfig, Weights) {
        let cfg = ModelConfig::test_config();
        let mut rng = Rng::new(7);
        // Layer 2 heavy-tailed, layer 0 low-rank-reduced.
        let w = Weights::synth(&cfg, &mut rng, &[0.0, 0.0, 4.0],
                               &[0.3, 1.0, 1.0]);
        (cfg, w)
    }

    #[test]
    fn scores_have_layer_shape_and_are_finite() {
        let (cfg, w) = test_setup();
        let scores = nsds_layer_scores(&cfg, &w, &NsdsOptions::default());
        assert_eq!(scores.len(), cfg.n_layers);
        for s in &scores {
            assert!(s.is_finite() && (0.0..=1.0).contains(s), "{s}");
        }
    }

    #[test]
    fn heavy_tail_layer_ranks_high_on_nv() {
        let (cfg, w) = test_setup();
        let raw = raw_scores(&cfg, &w, &NsdsOptions::default());
        let (nv, _) = nv_se_layer_scores(&raw);
        let max_l = nv
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(max_l, 2, "nv scores {nv:?}");
    }

    #[test]
    fn ablations_change_scores() {
        let (cfg, w) = test_setup();
        let base = nsds_layer_scores(&cfg, &w, &NsdsOptions::default());
        for ab in [Ablation::NoNv, Ablation::NoSe, Ablation::NoBeta,
                   Ablation::NoAgg] {
            let opts = NsdsOptions { ablation: ab, ..Default::default() };
            let alt = nsds_layer_scores(&cfg, &w, &opts);
            assert_eq!(alt.len(), base.len());
            let diff: f64 = base
                .iter()
                .zip(&alt)
                .map(|(a, b)| (a - b).abs())
                .sum();
            assert!(diff > 1e-9, "ablation {ab:?} had no effect");
        }
    }

    #[test]
    fn deterministic() {
        let (cfg, w) = test_setup();
        let a = nsds_layer_scores(&cfg, &w, &NsdsOptions::default());
        let b = nsds_layer_scores(&cfg, &w, &NsdsOptions::default());
        assert_eq!(a, b);
    }
}
