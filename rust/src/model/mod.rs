//! Model substrate: configs, the weight store (loaded from `.tz`
//! artifacts), and synthetic weight generation for unit tests.
//!
//! The weight layout mirrors `python/compile/model.py` exactly — stacked
//! per-layer tensors in the fixed `WEIGHT_NAMES` order that the AOT HLO
//! executables take as runtime arguments.

pub mod decompose;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::tz;

/// Argument order of every model HLO executable (after the tokens arg).
pub const WEIGHT_NAMES: [&str; 12] = [
    "embed", "unembed", "lnf", "wq", "wk", "wv", "wo", "wgate", "wup",
    "wdown", "ln1", "ln2",
];

/// The stacked 2-D projection weights that get quantized, layer by layer.
pub const QUANT_WEIGHTS: [&str; 7] =
    ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"];

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    pub n_layers: usize,
    pub seq: usize,
}

impl ModelConfig {
    pub fn from_json(name: &str, j: &Json) -> Result<Self> {
        let g = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .with_context(|| format!("config key {k}"))
        };
        Ok(ModelConfig {
            name: name.to_string(),
            vocab: g("vocab")?,
            d_model: g("d_model")?,
            n_heads: g("n_heads")?,
            n_kv: g("n_kv")?,
            d_head: g("d_head")?,
            d_ffn: g("d_ffn")?,
            n_layers: g("n_layers")?,
            seq: g("seq")?,
        })
    }

    /// The llama-s shape from the build-time model zoo, for synthetic
    /// (artifact-less) serving demos and benches — keep in sync with
    /// `python/compile/model.py MODEL_ZOO`.
    pub fn llama_s_synth() -> Self {
        ModelConfig {
            name: "llama-s-synth".into(),
            vocab: 256,
            d_model: 64,
            n_heads: 4,
            n_kv: 2,
            d_head: 16,
            d_ffn: 192,
            n_layers: 8,
            seq: 64,
        }
    }

    /// Tiny config for unit tests (no artifacts needed).
    pub fn test_config() -> Self {
        ModelConfig {
            name: "test".into(),
            vocab: 32,
            d_model: 16,
            n_heads: 4,
            n_kv: 2,
            d_head: 4,
            d_ffn: 24,
            n_layers: 3,
            seq: 16,
        }
    }

    pub fn weight_dims(&self, name: &str) -> Vec<usize> {
        let hd = self.n_heads * self.d_head;
        let kvd = self.n_kv * self.d_head;
        let l = self.n_layers;
        match name {
            "embed" => vec![self.vocab, self.d_model],
            "unembed" => vec![self.d_model, self.vocab],
            "lnf" => vec![self.d_model],
            "wq" => vec![l, self.d_model, hd],
            "wk" => vec![l, self.d_model, kvd],
            "wv" => vec![l, self.d_model, kvd],
            "wo" => vec![l, hd, self.d_model],
            "wgate" => vec![l, self.d_model, self.d_ffn],
            "wup" => vec![l, self.d_model, self.d_ffn],
            "wdown" => vec![l, self.d_ffn, self.d_model],
            "ln1" | "ln2" => vec![l, self.d_model],
            _ => panic!("unknown weight {name}"),
        }
    }

    pub fn param_count(&self) -> usize {
        WEIGHT_NAMES
            .iter()
            .map(|n| self.weight_dims(n).iter().product::<usize>())
            .sum()
    }
}

/// All weights of one model, keyed by name, in the shared layout.
#[derive(Clone, Debug)]
pub struct Weights {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn load(path: &Path, cfg: &ModelConfig) -> Result<Self> {
        let raw = tz::read_tz(path)?;
        let mut tensors = BTreeMap::new();
        for name in WEIGHT_NAMES {
            let t = raw
                .get(name)
                .with_context(|| format!("{path:?} missing {name}"))?
                .as_f32()?
                .clone();
            let want = cfg.weight_dims(name);
            if t.dims() != want.as_slice() {
                bail!("{name}: dims {:?} != expected {:?}", t.dims(), want);
            }
            tensors.insert(name.to_string(), t);
        }
        Ok(Weights { tensors })
    }

    pub fn get(&self, name: &str) -> &Tensor {
        &self.tensors[name]
    }

    /// 2-D weight of layer `l` (slices the stacked tensor).
    pub fn layer_matrix(&self, name: &str, l: usize) -> Tensor {
        self.tensors[name].slice0(l)
    }

    pub fn set_layer_matrix(&mut self, name: &str, l: usize, m: &Tensor) {
        self.tensors.get_mut(name).unwrap().set_slice0(l, m);
    }

    /// Ordered tensor list for feeding the PJRT executable.
    pub fn ordered(&self) -> Vec<&Tensor> {
        WEIGHT_NAMES.iter().map(|n| &self.tensors[*n]).collect()
    }

    /// Synthetic weights for tests: gaussian with per-layer structure knobs.
    /// `tail_boost[l]` mixes in heavy-tailed noise (raises kurtosis);
    /// `rank_frac[l]` < 1 projects FFN weights onto a low-rank subspace
    /// (lowers structural expressiveness). Both default-safe with empty
    /// slices.
    pub fn synth(
        cfg: &ModelConfig,
        rng: &mut Rng,
        tail_boost: &[f64],
        rank_frac: &[f64],
    ) -> Self {
        let mut tensors = BTreeMap::new();
        for name in WEIGHT_NAMES {
            let dims = cfg.weight_dims(name);
            let n: usize = dims.iter().product();
            let mut t = if name.starts_with("ln") {
                Tensor::new(vec![1.0; n], dims.clone())
            } else {
                let std = 0.05f32;
                Tensor::new(
                    (0..n).map(|_| std * rng.normal_f32()).collect(),
                    dims.clone(),
                )
            };
            // Layer-structured modifications for the stacked projections.
            if QUANT_WEIGHTS.contains(&name) {
                for l in 0..cfg.n_layers {
                    let mut m = t.slice0(l);
                    if let Some(&tb) = tail_boost.get(l) {
                        if tb > 0.0 {
                            // Student-t-ish: scale a random subset up.
                            let k = (m.len() as f64 * 0.01).max(1.0) as usize;
                            for _ in 0..k {
                                let i = rng.below(m.len());
                                m.data_mut()[i] *= (1.0 + tb * 8.0) as f32;
                            }
                        }
                    }
                    if let Some(&rf) = rank_frac.get(l) {
                        if rf < 1.0 && m.dims().len() == 2 {
                            m = low_rank_project(&m, rf, rng);
                        }
                    }
                    t.set_slice0(l, &m);
                }
            }
            tensors.insert(name.to_string(), t);
        }
        Weights { tensors }
    }
}

/// Project a matrix onto a random subspace of relative rank `frac`.
fn low_rank_project(m: &Tensor, frac: f64, rng: &mut Rng) -> Tensor {
    let (rows, cols) = (m.rows(), m.cols());
    let r = ((rows.min(cols) as f64 * frac) as usize).max(1);
    // B = R (rows x r) @ Rᵀ M with R orthonormal-ish gaussian — cheap rank-r.
    let rmat = Tensor::new(rng.normal_vec(rows * r), vec![rows, r])
        .scale(1.0 / (rows as f32).sqrt());
    let proj = crate::tensor::matmul::matmul(&rmat.transpose(), m); // [r, cols]
    crate::tensor::matmul::matmul(&rmat, &proj)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_dims_consistent() {
        let c = ModelConfig::test_config();
        assert_eq!(c.weight_dims("wq"), vec![3, 16, 16]);
        assert_eq!(c.weight_dims("wk"), vec![3, 16, 8]);
        assert_eq!(c.weight_dims("wdown"), vec![3, 24, 16]);
        assert!(c.param_count() > 0);
    }

    #[test]
    fn synth_layer_roundtrip() {
        let c = ModelConfig::test_config();
        let mut rng = Rng::new(0);
        let mut w = Weights::synth(&c, &mut rng, &[], &[]);
        let m = w.layer_matrix("wq", 1);
        assert_eq!(m.dims(), &[16, 16]);
        let m2 = m.scale(2.0);
        w.set_layer_matrix("wq", 1, &m2);
        assert_eq!(w.layer_matrix("wq", 1), m2);
        // other layers untouched
        assert_eq!(w.layer_matrix("wq", 0).dims(), &[16, 16]);
    }

    #[test]
    fn synth_tail_boost_raises_kurtosis() {
        let c = ModelConfig::test_config();
        let mut rng = Rng::new(0);
        let tb = vec![0.0, 0.0, 3.0];
        let w = Weights::synth(&c, &mut rng, &tb, &[]);
        let k0 = crate::tensor::stats::excess_kurtosis(
            w.layer_matrix("wup", 0).data(),
        );
        let k2 = crate::tensor::stats::excess_kurtosis(
            w.layer_matrix("wup", 2).data(),
        );
        assert!(k2 > k0 + 1.0, "k0={k0} k2={k2}");
    }

    #[test]
    fn ordered_matches_weight_names() {
        let c = ModelConfig::test_config();
        let mut rng = Rng::new(0);
        let w = Weights::synth(&c, &mut rng, &[], &[]);
        let o = w.ordered();
        assert_eq!(o.len(), WEIGHT_NAMES.len());
        assert_eq!(o[0].dims(), c.weight_dims("embed").as_slice());
    }
}
