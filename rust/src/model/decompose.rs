//! Mechanistic decomposition of a layer into operational components
//! (paper §2.1, Appendices C & D).
//!
//! Per layer the component set is 𝒞 = {QK, OV, up, gate, down}:
//!   * `W_QK^(h) = W_Q^(h) · W_K^(kv(h))ᵀ`  (Detector) — per attention head,
//!     with the GQA key head broadcast over its query group (App. D.2);
//!   * `W_OV^(h) = W_V^(kv(h)) · W_O^(h)`    (Writer)  — `W_O` split into
//!     per-head row blocks (App. C);
//!   * `W_up`, `W_gate` (Detectors), `W_down` (Writer) from the SwiGLU FFN
//!     (App. D.1: the gate is an "informational valve" ⇒ Detector).
//!
//! Convention note: weights are stored for the row-vector convention
//! `y = x · W` (input dim first). The paper writes column-vector algebra;
//! its "input singular vectors V" are our `Svd.u` columns and its "output
//! singular vectors U" are our `Svd.v` columns. `Component::input_vectors`
//! / `output_vectors` below resolve that once so no caller can mix it up.

use crate::tensor::matmul::matmul;
use crate::tensor::svd::Svd;
use crate::tensor::Tensor;

use super::{ModelConfig, Weights};

/// Operational role (paper §2.1): Detectors compute attention / activation
/// patterns; Writers move information into the residual stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Detector,
    Writer,
}

/// Component type — MAD-Sigmoid normalization pools raw scores per type
/// across layers (paper Eq. 10), so the type is part of the identity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum CompKind {
    Qk,
    Ov,
    Up,
    Gate,
    Down,
}

impl CompKind {
    pub const ALL: [CompKind; 5] =
        [CompKind::Qk, CompKind::Ov, CompKind::Up, CompKind::Gate,
         CompKind::Down];

    pub fn role(self) -> Role {
        match self {
            CompKind::Qk | CompKind::Up | CompKind::Gate => Role::Detector,
            CompKind::Ov | CompKind::Down => Role::Writer,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            CompKind::Qk => "QK",
            CompKind::Ov => "OV",
            CompKind::Up => "up",
            CompKind::Gate => "gate",
            CompKind::Down => "down",
        }
    }
}

/// One concrete weight component of one layer (one head for QK/OV).
#[derive(Clone, Debug)]
pub struct Component {
    pub kind: CompKind,
    pub layer: usize,
    /// Head index for QK/OV; 0 for FFN components.
    pub head: usize,
    /// The component matrix, row-vector convention [in_dim, out_dim].
    pub matrix: Tensor,
}

impl Component {
    /// Paper's "input singular vectors V" (detection side): columns live in
    /// the input space. With `y = x·W` and `W = UΣVᵀ` (our Svd), the input
    /// directions are `u_i` (∈ R^in).
    pub fn input_vectors<'a>(&self, s: &'a Svd) -> &'a Tensor {
        let _ = self;
        &s.u
    }

    /// Paper's "output singular vectors U" (writing side): columns live in
    /// the output (residual-stream) space — our `v_i` (∈ R^out).
    pub fn output_vectors<'a>(&self, s: &'a Svd) -> &'a Tensor {
        let _ = self;
        &s.v
    }
}

/// Decompose layer `l` into its component list (QK/OV per head + 3 FFN).
pub fn decompose_layer(cfg: &ModelConfig, w: &Weights, l: usize)
    -> Vec<Component> {
    let mut out = Vec::new();
    let dh = cfg.d_head;
    let group = cfg.n_heads / cfg.n_kv; // query heads per kv head
    let wq = w.layer_matrix("wq", l); // [D, H*dh]
    let wk = w.layer_matrix("wk", l); // [D, KV*dh]
    let wv = w.layer_matrix("wv", l); // [D, KV*dh]
    let wo = w.layer_matrix("wo", l); // [H*dh, D]
    for h in 0..cfg.n_heads {
        let kv = h / group;
        let wq_h = wq.cols_range(h * dh, (h + 1) * dh); // [D, dh]
        let wk_h = wk.cols_range(kv * dh, (kv + 1) * dh); // [D, dh]
        let wv_h = wv.cols_range(kv * dh, (kv + 1) * dh); // [D, dh]
        let wo_h = wo.rows_range(h * dh, (h + 1) * dh); // [dh, D]
        // W_QK^(h) = W_Q^(h) W_K^(h)T : [D, D]
        let wqk = matmul(&wq_h, &wk_h.transpose());
        // W_OV^(h) = W_V^(h) W_O^(h) : [D, D]
        let wov = matmul(&wv_h, &wo_h);
        out.push(Component { kind: CompKind::Qk, layer: l, head: h,
                             matrix: wqk });
        out.push(Component { kind: CompKind::Ov, layer: l, head: h,
                             matrix: wov });
    }
    out.push(Component { kind: CompKind::Up, layer: l, head: 0,
                         matrix: w.layer_matrix("wup", l) });
    out.push(Component { kind: CompKind::Gate, layer: l, head: 0,
                         matrix: w.layer_matrix("wgate", l) });
    out.push(Component { kind: CompKind::Down, layer: l, head: 0,
                         matrix: w.layer_matrix("wdown", l) });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn component_counts_and_shapes() {
        let cfg = ModelConfig::test_config(); // H=4, KV=2, D=16, F=24
        let mut rng = Rng::new(1);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let comps = decompose_layer(&cfg, &w, 0);
        // 4 QK + 4 OV + up + gate + down
        assert_eq!(comps.len(), 4 + 4 + 3);
        for c in &comps {
            match c.kind {
                CompKind::Qk | CompKind::Ov => {
                    assert_eq!(c.matrix.dims(), &[16, 16]);
                }
                CompKind::Up | CompKind::Gate => {
                    assert_eq!(c.matrix.dims(), &[16, 24]);
                }
                CompKind::Down => assert_eq!(c.matrix.dims(), &[24, 16]),
            }
        }
    }

    #[test]
    fn roles_match_paper() {
        assert_eq!(CompKind::Qk.role(), Role::Detector);
        assert_eq!(CompKind::Gate.role(), Role::Detector);
        assert_eq!(CompKind::Up.role(), Role::Detector);
        assert_eq!(CompKind::Ov.role(), Role::Writer);
        assert_eq!(CompKind::Down.role(), Role::Writer);
    }

    #[test]
    fn gqa_broadcast_shares_kv_heads() {
        // With H=4, KV=2: heads 0,1 share kv0; heads 2,3 share kv1.
        let cfg = ModelConfig::test_config();
        let mut rng = Rng::new(2);
        let mut w = Weights::synth(&cfg, &mut rng, &[], &[]);
        // Make wq identical for heads 0 and 1 -> their QK must then be
        // identical (same kv head), but differ from head 2's.
        let mut wq = w.layer_matrix("wq", 0);
        let dh = cfg.d_head;
        for r in 0..wq.rows() {
            for c in 0..dh {
                let v = wq.at(r, c);
                wq.set(r, dh + c, v);
            }
        }
        w.set_layer_matrix("wq", 0, &wq);
        let comps = decompose_layer(&cfg, &w, 0);
        let qk: Vec<&Component> =
            comps.iter().filter(|c| c.kind == CompKind::Qk).collect();
        let d01 = qk[0].matrix.sub(&qk[1].matrix).frob_norm();
        let d02 = qk[0].matrix.sub(&qk[2].matrix).frob_norm();
        assert!(d01 < 1e-6, "heads sharing kv+q must match: {d01}");
        assert!(d02 > 1e-3, "distinct heads should differ");
    }

    #[test]
    fn attention_equivalence_sum_of_heads() {
        // Σ_h W_Q^h W_K^hT must equal W_Q W_Kᵀ when H == KV (no GQA).
        let mut cfg = ModelConfig::test_config();
        cfg.n_kv = cfg.n_heads;
        let mut rng = Rng::new(3);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let comps = decompose_layer(&cfg, &w, 1);
        let wq = w.layer_matrix("wq", 1);
        let wk = w.layer_matrix("wk", 1);
        let full = matmul(&wq, &wk.transpose());
        let mut sum = Tensor::zeros(vec![cfg.d_model, cfg.d_model]);
        for c in comps.iter().filter(|c| c.kind == CompKind::Qk) {
            sum = sum.add(&c.matrix);
        }
        let err = sum.sub(&full).frob_norm() / full.frob_norm();
        assert!(err < 1e-5, "per-head QK decomposition broken: {err}");
    }

    #[test]
    fn ov_equivalence_sum_of_heads() {
        // Σ_h W_V^h W_O^h == W_V W_O when H == KV.
        let mut cfg = ModelConfig::test_config();
        cfg.n_kv = cfg.n_heads;
        let mut rng = Rng::new(4);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let comps = decompose_layer(&cfg, &w, 2);
        let wv = w.layer_matrix("wv", 2);
        let wo = w.layer_matrix("wo", 2);
        let full = matmul(&wv, &wo);
        let mut sum = Tensor::zeros(vec![cfg.d_model, cfg.d_model]);
        for c in comps.iter().filter(|c| c.kind == CompKind::Ov) {
            sum = sum.add(&c.matrix);
        }
        let err = sum.sub(&full).frob_norm() / full.frob_norm();
        assert!(err < 1e-5, "per-head OV decomposition broken: {err}");
    }
}
