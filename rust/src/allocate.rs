//! Data-free layer-wise bit allocation (paper §2.3 + Algorithm 1 phase 3).
//!
//! Given a target average-bit budget b̄ ∈ [2,4] and per-layer sensitivity
//! scores, allocate 4-bit to the L₄ = round((b̄−2)/2·L) most sensitive
//! layers and 2-bit to the rest (equal-sized-layer assumption; our zoo's
//! layers are exactly equal-sized so the budget is met exactly).

/// Per-layer bit widths from sensitivity scores (higher = more sensitive).
pub fn allocate_bits(scores: &[f64], budget: f64) -> Vec<u8> {
    let l = scores.len();
    let rho = ((budget - 2.0) / 2.0).clamp(0.0, 1.0);
    let l4 = (rho * l as f64).round() as usize;
    allocate_top_k(scores, l4)
}

/// Give 4-bit to the `l4` highest-scoring layers, 2-bit elsewhere.
/// Ties broken by layer index (earlier layer wins) for determinism.
pub fn allocate_top_k(scores: &[f64], l4: usize) -> Vec<u8> {
    let l = scores.len();
    let mut order: Vec<usize> = (0..l).collect();
    order.sort_by(|&a, &b| {
        scores[b].total_cmp(&scores[a]).then(a.cmp(&b))
    });
    let mut bits = vec![2u8; l];
    for &i in order.iter().take(l4.min(l)) {
        bits[i] = 4;
    }
    bits
}

/// Achieved average bits (equal-sized layers).
pub fn average_bits(bits: &[u8]) -> f64 {
    if bits.is_empty() {
        return 0.0;
    }
    bits.iter().map(|&b| b as f64).sum::<f64>() / bits.len() as f64
}

/// Variant used by the KurtBoost baseline: some layers are *forced* to
/// 4-bit (detected outliers) before filling the rest by score order under
/// the same budget.
pub fn allocate_with_priority(scores: &[f64], budget: f64,
                              forced: &[usize]) -> Vec<u8> {
    let l = scores.len();
    let rho = ((budget - 2.0) / 2.0).clamp(0.0, 1.0);
    let l4 = (rho * l as f64).round() as usize;
    let mut bits = vec![2u8; l];
    let mut remaining = l4;
    for &i in forced {
        if remaining == 0 {
            break;
        }
        if i < l && bits[i] == 2 {
            bits[i] = 4;
            remaining -= 1;
        }
    }
    let mut order: Vec<usize> = (0..l).collect();
    order.sort_by(|&a, &b| {
        scores[b].total_cmp(&scores[a]).then(a.cmp(&b))
    });
    for &i in &order {
        if remaining == 0 {
            break;
        }
        if bits[i] == 2 {
            bits[i] = 4;
            remaining -= 1;
        }
    }
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::util::prop::check;

    #[test]
    fn budget_exact_at_3_bits() {
        let scores = vec![0.9, 0.1, 0.5, 0.7, 0.2, 0.8, 0.3, 0.4];
        let bits = allocate_bits(&scores, 3.0);
        assert_eq!(average_bits(&bits), 3.0);
        // The four highest scores (0.9, 0.8, 0.7, 0.5) get 4-bit.
        assert_eq!(bits, vec![4, 2, 4, 4, 2, 4, 2, 2]);
    }

    #[test]
    fn extreme_budgets() {
        let scores = vec![0.5; 6];
        assert_eq!(allocate_bits(&scores, 2.0), vec![2; 6]);
        assert_eq!(allocate_bits(&scores, 4.0), vec![4; 6]);
    }

    #[test]
    fn budget_rounding_property() {
        check("budget within half-step", 40, |rng| {
            let l = 2 + rng.below(40);
            let scores: Vec<f64> = (0..l).map(|_| rng.f64()).collect();
            let budget = 2.0 + 2.0 * rng.f64();
            let bits = allocate_bits(&scores, budget);
            let avg = average_bits(&bits);
            // round() ⇒ achieved average within one layer's worth of budget
            prop_ensure!(
                (avg - budget).abs() <= 1.0 / l as f64 + 1e-9,
                "avg {avg} vs budget {budget} (L={l})"
            );
            // Monotone: every 4-bit layer scores >= every 2-bit layer.
            let min4 = bits
                .iter()
                .zip(&scores)
                .filter(|(b, _)| **b == 4)
                .map(|(_, s)| *s)
                .fold(f64::INFINITY, f64::min);
            let max2 = bits
                .iter()
                .zip(&scores)
                .filter(|(b, _)| **b == 2)
                .map(|(_, s)| *s)
                .fold(f64::NEG_INFINITY, f64::max);
            prop_ensure!(min4 >= max2 - 1e-12, "ranking violated");
            Ok(())
        });
    }

    #[test]
    fn priority_respected_under_budget() {
        let scores = vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6];
        // budget 3.0 -> 3 layers at 4-bit; force layer 0 (lowest score).
        let bits = allocate_with_priority(&scores, 3.0, &[0]);
        assert_eq!(bits[0], 4);
        assert_eq!(bits.iter().filter(|&&b| b == 4).count(), 3);
        // remaining two picks are the top scorers 5 and 4.
        assert_eq!(bits[5], 4);
        assert_eq!(bits[4], 4);
    }
}
