//! Data-free layer-wise bit allocation (paper §2.3 + Algorithm 1 phase 3).
//!
//! Given a target average-bit budget b̄ ∈ [2,4] and per-layer sensitivity
//! scores, allocate 4-bit to the L₄ = round((b̄−2)/2·L) most sensitive
//! layers and 2-bit to the rest (equal-sized-layer assumption; our zoo's
//! layers are exactly equal-sized so the budget is met exactly).

/// Per-layer bit widths from sensitivity scores (higher = more sensitive).
pub fn allocate_bits(scores: &[f64], budget: f64) -> Vec<u8> {
    let l = scores.len();
    let rho = ((budget - 2.0) / 2.0).clamp(0.0, 1.0);
    let l4 = (rho * l as f64).round() as usize;
    allocate_top_k(scores, l4)
}

/// Give 4-bit to the `l4` highest-scoring layers, 2-bit elsewhere.
/// Ties broken by layer index (earlier layer wins) for determinism.
pub fn allocate_top_k(scores: &[f64], l4: usize) -> Vec<u8> {
    let l = scores.len();
    let mut order: Vec<usize> = (0..l).collect();
    order.sort_by(|&a, &b| {
        scores[b].total_cmp(&scores[a]).then(a.cmp(&b))
    });
    let mut bits = vec![2u8; l];
    for &i in order.iter().take(l4.min(l)) {
        bits[i] = 4;
    }
    bits
}

/// Achieved average bits (equal-sized layers).
pub fn average_bits(bits: &[u8]) -> f64 {
    if bits.is_empty() {
        return 0.0;
    }
    bits.iter().map(|&b| b as f64).sum::<f64>() / bits.len() as f64
}

/// Per-layer KV-cache bit widths over {4, 8, 16(f32)} from the same
/// sensitivity scores, under an average-bit budget b̄ ∈ [4, 16].
///
/// Same equal-sized-layer greedy as `allocate_bits`, but with three
/// tiers: every layer starts at 4-bit, and the budget surplus
/// `(b̄ − 4)·L` is spent in score order — first upgrading the most
/// sensitive layers 4 → 8 (4 units each), then, with what remains,
/// 8 → 16 (8 units each, again most sensitive first). Two passes keep
/// the allocation monotone in score: a layer is never wider than any
/// higher-scoring layer. Ties break by layer index (earlier wins), as
/// everywhere else in this module.
pub fn allocate_kv_bits(scores: &[f64], budget: f64) -> Vec<u8> {
    let l = scores.len();
    let mut order: Vec<usize> = (0..l).collect();
    order.sort_by(|&a, &b| {
        scores[b].total_cmp(&scores[a]).then(a.cmp(&b))
    });
    let budget = budget.clamp(4.0, 16.0);
    let mut extra = ((budget - 4.0) * l as f64).round() as i64;
    let mut bits = vec![4u8; l];
    for &i in &order {
        if extra < 4 {
            break;
        }
        bits[i] = 8;
        extra -= 4;
    }
    for &i in &order {
        if extra < 8 {
            break;
        }
        if bits[i] == 8 {
            bits[i] = 16;
            extra -= 8;
        }
    }
    bits
}

/// Variant used by the KurtBoost baseline: some layers are *forced* to
/// 4-bit (detected outliers) before filling the rest by score order under
/// the same budget.
pub fn allocate_with_priority(scores: &[f64], budget: f64,
                              forced: &[usize]) -> Vec<u8> {
    let l = scores.len();
    let rho = ((budget - 2.0) / 2.0).clamp(0.0, 1.0);
    let l4 = (rho * l as f64).round() as usize;
    let mut bits = vec![2u8; l];
    let mut remaining = l4;
    for &i in forced {
        if remaining == 0 {
            break;
        }
        if i < l && bits[i] == 2 {
            bits[i] = 4;
            remaining -= 1;
        }
    }
    let mut order: Vec<usize> = (0..l).collect();
    order.sort_by(|&a, &b| {
        scores[b].total_cmp(&scores[a]).then(a.cmp(&b))
    });
    for &i in &order {
        if remaining == 0 {
            break;
        }
        if bits[i] == 2 {
            bits[i] = 4;
            remaining -= 1;
        }
    }
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::util::prop::check;

    #[test]
    fn budget_exact_at_3_bits() {
        let scores = vec![0.9, 0.1, 0.5, 0.7, 0.2, 0.8, 0.3, 0.4];
        let bits = allocate_bits(&scores, 3.0);
        assert_eq!(average_bits(&bits), 3.0);
        // The four highest scores (0.9, 0.8, 0.7, 0.5) get 4-bit.
        assert_eq!(bits, vec![4, 2, 4, 4, 2, 4, 2, 2]);
    }

    #[test]
    fn extreme_budgets() {
        let scores = vec![0.5; 6];
        assert_eq!(allocate_bits(&scores, 2.0), vec![2; 6]);
        assert_eq!(allocate_bits(&scores, 4.0), vec![4; 6]);
    }

    #[test]
    fn budget_rounding_property() {
        check("budget within half-step", 40, |rng| {
            let l = 2 + rng.below(40);
            let scores: Vec<f64> = (0..l).map(|_| rng.f64()).collect();
            let budget = 2.0 + 2.0 * rng.f64();
            let bits = allocate_bits(&scores, budget);
            let avg = average_bits(&bits);
            // round() ⇒ achieved average within one layer's worth of budget
            prop_ensure!(
                (avg - budget).abs() <= 1.0 / l as f64 + 1e-9,
                "avg {avg} vs budget {budget} (L={l})"
            );
            // Monotone: every 4-bit layer scores >= every 2-bit layer.
            let min4 = bits
                .iter()
                .zip(&scores)
                .filter(|(b, _)| **b == 4)
                .map(|(_, s)| *s)
                .fold(f64::INFINITY, f64::min);
            let max2 = bits
                .iter()
                .zip(&scores)
                .filter(|(b, _)| **b == 2)
                .map(|(_, s)| *s)
                .fold(f64::NEG_INFINITY, f64::max);
            prop_ensure!(min4 >= max2 - 1e-12, "ranking violated");
            Ok(())
        });
    }

    #[test]
    fn kv_bits_extreme_and_intermediate_budgets() {
        let scores = vec![0.9, 0.1, 0.5, 0.7];
        assert_eq!(allocate_kv_bits(&scores, 4.0), vec![4; 4]);
        assert_eq!(allocate_kv_bits(&scores, 8.0), vec![8; 4]);
        assert_eq!(allocate_kv_bits(&scores, 16.0), vec![16; 4]);
        // b̄ = 7: surplus 12 units = three 4→8 upgrades, to the three
        // highest scores (0.9, 0.7, 0.5).
        assert_eq!(allocate_kv_bits(&scores, 7.0), vec![8, 4, 8, 8]);
        // b̄ = 10: surplus 24 = four 4→8 (16) + one 8→16 (8), the
        // widest going to the top score.
        assert_eq!(allocate_kv_bits(&scores, 10.0), vec![16, 8, 8, 8]);
    }

    #[test]
    fn kv_bits_budget_and_monotonicity_property() {
        check("kv budget within step, score-monotone", 40, |rng| {
            let l = 1 + rng.below(40);
            let scores: Vec<f64> = (0..l).map(|_| rng.f64()).collect();
            let budget = 4.0 + 12.0 * rng.f64();
            let bits = allocate_kv_bits(&scores, budget);
            prop_ensure!(
                bits.iter().all(|b| [4, 8, 16].contains(b)),
                "tier outside {{4,8,16}}"
            );
            let avg = average_bits(&bits);
            // Greedy upgrades never overshoot and stop within one
            // 8→16 upgrade (8 units / L) of the rounded budget.
            prop_ensure!(
                avg <= budget + 0.5 / l as f64 + 1e-9,
                "avg {avg} overshoots budget {budget} (L={l})"
            );
            prop_ensure!(
                avg >= budget - 8.0 / l as f64 - 0.5 / l as f64 - 1e-9,
                "avg {avg} undershoots budget {budget} (L={l})"
            );
            // Monotone: wider storage never goes to a lower score
            // than narrower storage (ties aside).
            for i in 0..l {
                for j in 0..l {
                    if bits[i] > bits[j] {
                        prop_ensure!(
                            scores[i] >= scores[j] - 1e-12,
                            "layer {i} ({}b) outranks {j} ({}b) \
                             with lower score",
                            bits[i],
                            bits[j]
                        );
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn priority_respected_under_budget() {
        let scores = vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6];
        // budget 3.0 -> 3 layers at 4-bit; force layer 0 (lowest score).
        let bits = allocate_with_priority(&scores, 3.0, &[0]);
        assert_eq!(bits[0], 4);
        assert_eq!(bits.iter().filter(|&&b| b == 4).count(), 3);
        // remaining two picks are the top scorers 5 and 4.
        assert_eq!(bits[5], 4);
        assert_eq!(bits[4], 4);
    }
}
