//! Serving front-end: a dynamic batcher over the weight-swappable
//! executor — the vLLM-router-shaped piece of the L3 coordinator.
//!
//! Requests arrive on a bounded queue from any number of client threads;
//! the *engine thread* (PJRT handles are not `Send`; the native engine
//! keeps the same discipline) runs `serve`. NLL requests (token windows
//! wanting scores) are packed into the executor's fixed [batch, seq]
//! shape (padding the tail). Generation requests all flow through ONE
//! shared continuous-batching scheduler (`infer::BatchEngine`) per
//! deployed model: each serve-loop iteration admits queued prompts into
//! free KV-cache slots, pushes one chunked-prefill window per
//! still-prefilling prompt (whole prompt windows per step — the
//! time-to-first-token lever for long prompts; `gen_latency` reports
//! per-request prefill work and TTFT), and advances every in-flight
//! generation by one batched decode step, so concurrent generations
//! share each weight read (one fused dequant per group per step on the
//! packed path) instead of fanning whole generations across pool
//! workers. Admission is prefix-aware over the paged KV pool: a request
//! whose prompt shares a tokenized prefix with a resident sequence
//! references the resident pages copy-on-write and only chunk-prefills
//! the tail (the `serve.gen.shared_prefix_tokens` counter counts the
//! prefill work saved). Serving metrics — counters, gauges, and
//! latency histograms — record into the queue's `MetricsRegistry`
//! (see `ServerQueue`); snapshot it for the JSON export or the human
//! summary.
//!
//! Generation replies STREAM: each request's channel carries one
//! `GenEvent::Token` per committed token as the scheduler commits it
//! (bit-identical to the batch result — same `consume_row` path),
//! terminated by `GenEvent::Done` with the finished `Generation` (or
//! `GenEvent::Failed`). `Client::generate` drains the stream and keeps
//! its one-shot signature; `Client::generate_streaming` exposes the
//! events. Dropping the receiver (`GenEvents`) CANCELS the request:
//! the engine notices the dead sink — a failed token send, or the
//! liveness flag the receiver's `Drop` clears, which catches
//! disconnects during prefill when no tokens flow — and retires the
//! request's KV slot (target and drafter pools both) at the end of
//! the step that notices, tracing a rid-stamped `Ev::Cancel` and
//! counting `serve.gen.cancelled`. No reply-channel failure is
//! silently ignored: undeliverable terminal replies count into
//! `serve.dropped_replies`.
//!
//! Scheduler intake is bounded (about two batches of generations), so
//! excess requests stay in the bounded queue.
//! Backpressure: submitters block while the queue holds `max_queue`
//! WORK messages (control messages — swap/stop barriers — never count
//! against work capacity).
//!
//! Weight swap is a queued control message, so deploying a new quantized
//! variant is ordered with respect to in-flight requests and requires NO
//! recompilation: a swap first *drains* the scheduler (generations
//! submitted before it finish on the old variant; no admission straddles
//! the swap), then applies — zero downtime, and every request runs on
//! one consistent variant. Variants deploy either as dense f32 weights
//! or as a packed 2/4-bit `QuantizedModel`, which the native executor
//! serves via the fused dequant-matmul without ever materializing f32
//! weights.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::eval::ppl::batch_nll;
use crate::infer::{BatchEngine, Executor, GenConfig, GenEvent, GenSink,
                   Generation, ModelRef, QuantizedModel, SpecCounters};
use crate::model::Weights;
use crate::runtime::ModelEntry;
use crate::telemetry::registry::{Counter, Histogram, MetricsRegistry};

/// A deployable weight variant: dense f32 or packed 2/4-bit codes.
pub enum ServedWeights {
    Dense(Weights),
    Packed(QuantizedModel),
}

impl ServedWeights {
    fn forward(&self, exec: &dyn Executor, entry: &ModelEntry,
               tokens: &[i32], batch: usize)
               -> Result<crate::tensor::Tensor> {
        match self {
            ServedWeights::Dense(w) => {
                exec.forward(entry, tokens, batch, w)
            }
            ServedWeights::Packed(qm) => {
                exec.forward_packed(entry, tokens, batch, qm)
            }
        }
    }

    /// Borrowed dispatch handle for the decode/generation paths.
    pub fn model_ref(&self) -> ModelRef<'_> {
        match self {
            ServedWeights::Dense(w) => ModelRef::Dense(w),
            ServedWeights::Packed(qm) => ModelRef::Packed(qm),
        }
    }
}

/// What a swap deploys: the serving TARGET plus an optional cheaper
/// drafter variant (typically the coordinator's 2-bit artifact of the
/// SAME weights) for speculative decoding. Target and drafter always
/// travel together through one drain barrier, so the pair is
/// consistent: no request ever drafts against one deployment and
/// verifies against another, and the drafter pool never holds KV from
/// a stale variant (the barrier guarantees the engine is idle — no
/// drafter slots exist — at the moment of the swap).
pub struct Deployment {
    pub target: ServedWeights,
    /// `None` serves plain; spec-opted requests decode one token per
    /// target pass until a drafter is deployed.
    pub drafter: Option<ServedWeights>,
}

enum Msg {
    Infer(Request),
    Generate(GenRequest),
    Swap(Box<Deployment>),
    Stop,
}

struct Request {
    tokens: Vec<i32>,
    reply: std::sync::mpsc::Sender<(f64, usize)>,
}

/// One queued generation request (KV-cached autoregressive decode on the
/// currently deployed variant).
struct GenRequest {
    prompt: Vec<i32>,
    cfg: GenConfig,
    reply: GenStream,
}

/// The sending half of one generation's event stream — the per-request
/// tag the shared scheduler carries (`BatchEngine<GenStream>`). `emit`
/// failing (receiver dropped) latches `open` to false, and the
/// receiver's `Drop` clears the same flag directly, so the engine's
/// once-per-step `is_connected` probe catches disconnects even while
/// the request is still pending or prefilling and no tokens flow.
pub struct GenStream {
    tx: std::sync::mpsc::Sender<GenEvent>,
    open: Arc<AtomicBool>,
}

impl GenSink for GenStream {
    fn emit(&self, ev: GenEvent) -> bool {
        if !self.open.load(Ordering::Acquire) {
            return false;
        }
        if self.tx.send(ev).is_err() {
            self.open.store(false, Ordering::Release);
            return false;
        }
        true
    }

    fn is_connected(&self) -> bool {
        self.open.load(Ordering::Acquire)
    }
}

/// The receiving half of one generation's event stream: per-token
/// `GenEvent`s as the scheduler commits them, terminated by `Done` (the
/// finished `Generation`, identical to what `Client::generate` returns)
/// or `Failed`. Dropping this handle CANCELS the generation — the serve
/// scheduler retires its KV slot at the end of the step that notices
/// the disconnect instead of decoding to completion.
pub struct GenEvents {
    rx: std::sync::mpsc::Receiver<GenEvent>,
    open: Arc<AtomicBool>,
}

impl GenEvents {
    /// Block for the next event; `None` once the stream is exhausted
    /// (after a terminal event, or if the server dropped the request).
    pub fn next_event(&self) -> Option<GenEvent> {
        self.rx.recv().ok()
    }

    /// Drain the stream to its terminal event and return the finished
    /// generation — exactly `Client::generate`'s behavior.
    pub fn wait(self) -> Result<Generation> {
        loop {
            match self.rx.recv() {
                Ok(GenEvent::Token { .. }) => continue,
                Ok(GenEvent::Done(g)) => return Ok(g),
                Ok(GenEvent::Failed(e)) => {
                    return Err(anyhow::anyhow!(e));
                }
                Err(_) => {
                    return Err(anyhow::anyhow!(
                        "server dropped request"));
                }
            }
        }
    }
}

impl Iterator for GenEvents {
    type Item = GenEvent;

    fn next(&mut self) -> Option<GenEvent> {
        self.rx.recv().ok()
    }
}

impl Drop for GenEvents {
    /// Disconnect signal: clearing the shared flag is what lets the
    /// serve scheduler cancel a request that has not emitted anything
    /// yet (pending or mid-prefill) — a failed send alone could not
    /// tell it.
    fn drop(&mut self) {
        self.open.store(false, Ordering::Release);
    }
}

/// Shared queue + telemetry between clients and the engine thread.
///
/// Serving metrics live in a `MetricsRegistry` (one per queue by
/// default, so concurrent servers in one process never mix samples;
/// pass a shared registry to `with_registry` to aggregate). The serve
/// loop records through pre-registered handles — relaxed atomics, no
/// locks or allocation per request — and the legacy accessor methods
/// (`stats`, `gen_stats`, `gen_shared`, `gen_latency`) are thin views
/// over the same cells. Registered metrics:
///
/// * `serve.nll.requests` / `serve.nll.batches` /
///   `serve.nll.padded_rows` — counters for the padded-forward path.
/// * `serve.gen.requests` / `serve.gen.tokens` — counters over
///   finished generations.
/// * `serve.gen.shared_prefix_tokens` — monotone counter: prompt
///   tokens admitted by shared-prefix page reference instead of
///   prefill (`KvCachePool::admit_shared`). Published as per-step
///   deltas against the engine's lifetime total, so it stays correct
///   across `swap_deployment` engine rebuilds and across serve calls
///   sharing one registry (the same delta discipline as
///   `serve.gen.spec.*`).
/// * `serve.gen.cancelled` — counter: generation requests cancelled
///   because their receiver disconnected (the engine freed their KV
///   slots without finishing; same delta discipline).
/// * `serve.dropped_replies` — counter: terminal replies (finished
///   generation, NLL result, or failure notice) whose receiver was
///   already gone — silent client loss made observable.
/// * `serve.gen.prefill_ns` / `serve.gen.ttft_ns` /
///   `serve.gen.decode_ns` — histograms over finished generations,
///   recording each request's `GenStats` nanosecond fields verbatim
///   (same integers, no float round trip — the histogram quantiles and
///   per-request ground truth never disagree beyond one bucket).
/// * `serve.gen.spec.drafted` / `serve.gen.spec.accepted` /
///   `serve.gen.spec.emitted` / `serve.gen.spec.verify_steps` —
///   monotone counters mirroring the engine's cumulative
///   speculative-decode totals (`BatchEngine::spec_counters`): draft
///   tokens proposed, drafts committed by exact greedy agreement,
///   tokens emitted by verify rows, and multi-row verify passes run.
///   The serve loop publishes per-step deltas, so rate math over
///   successive registry snapshots is well-defined (and totals
///   aggregate correctly when several serve calls share a registry).
///   All zero unless a drafter is deployed and requests opt in via
///   `GenConfig::spec`.
/// * `serve.engine.step_ns` — histogram of scheduler step wall time.
pub struct ServerQueue {
    queue: Mutex<VecDeque<Msg>>,
    cv: Condvar,
    max_queue: usize,
    stopped: AtomicBool,
    registry: Arc<MetricsRegistry>,
    served: Counter,
    batches: Counter,
    padded_rows: Counter,
    gen_served: Counter,
    gen_tokens: Counter,
    gen_shared_tokens: Counter,
    gen_cancelled: Counter,
    dropped_replies: Counter,
    gen_spec_drafted: Counter,
    gen_spec_accepted: Counter,
    gen_spec_emitted: Counter,
    gen_spec_verify_steps: Counter,
    gen_prefill: Histogram,
    gen_ttft: Histogram,
    gen_decode: Histogram,
    step_ns: Histogram,
}

impl ServerQueue {
    /// A queue with its own private metrics registry.
    pub fn new(max_queue: usize) -> Arc<Self> {
        ServerQueue::with_registry(max_queue, MetricsRegistry::new())
    }

    /// A queue recording into `registry` (e.g. `MetricsRegistry::
    /// global()` to aggregate every server in the process). Handles are
    /// resolved here, once — the serve loop never touches the registry
    /// lock.
    pub fn with_registry(max_queue: usize,
                         registry: Arc<MetricsRegistry>) -> Arc<Self> {
        Arc::new(ServerQueue {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            max_queue,
            stopped: AtomicBool::new(false),
            served: registry.counter("serve.nll.requests"),
            batches: registry.counter("serve.nll.batches"),
            padded_rows: registry.counter("serve.nll.padded_rows"),
            gen_served: registry.counter("serve.gen.requests"),
            gen_tokens: registry.counter("serve.gen.tokens"),
            gen_shared_tokens:
                registry.counter("serve.gen.shared_prefix_tokens"),
            gen_cancelled: registry.counter("serve.gen.cancelled"),
            dropped_replies:
                registry.counter("serve.dropped_replies"),
            gen_spec_drafted:
                registry.counter("serve.gen.spec.drafted"),
            gen_spec_accepted:
                registry.counter("serve.gen.spec.accepted"),
            gen_spec_emitted:
                registry.counter("serve.gen.spec.emitted"),
            gen_spec_verify_steps:
                registry.counter("serve.gen.spec.verify_steps"),
            gen_prefill: registry.histogram("serve.gen.prefill_ns"),
            gen_ttft: registry.histogram("serve.gen.ttft_ns"),
            gen_decode: registry.histogram("serve.gen.decode_ns"),
            step_ns: registry.histogram("serve.engine.step_ns"),
            registry,
        })
    }

    /// The registry this queue records into — snapshot it for the JSON
    /// export or `telemetry::render_summary`.
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.registry
    }

    fn push(&self, msg: Msg) {
        let mut q = self.queue.lock().unwrap();
        // Control messages bypass backpressure; work messages respect it
        // (and stop waiting if the server shuts down underneath them).
        // The wait gates on the number of queued WORK messages, not the
        // raw queue length: Swap/Stop barriers sitting in the queue
        // must not shrink effective work capacity (a barrier-heavy
        // caller could otherwise wedge submitters against a queue
        // "full" of control messages). O(queue) per wake is fine — the
        // queue is bounded by max_queue work messages plus however
        // many barriers, both small.
        let work = |q: &VecDeque<Msg>| {
            q.iter()
                .filter(|m| {
                    matches!(m, Msg::Infer(_) | Msg::Generate(_))
                })
                .count()
        };
        if matches!(msg, Msg::Infer(_) | Msg::Generate(_)) {
            while work(&q) >= self.max_queue
                && !self.stopped.load(Ordering::Acquire)
            {
                q = self.cv.wait(q).unwrap();
            }
            // A stopped server never drains the queue again: dropping
            // the message here closes its reply channel, so the caller's
            // recv fails loudly ("server dropped request") instead of
            // hanging — the submit-side `stopped` check can race with
            // the serve loop's (fatal-error) shutdown.
            if self.stopped.load(Ordering::Acquire) {
                return;
            }
        }
        q.push_back(msg);
        drop(q);
        self.cv.notify_all();
    }

    /// (NLL requests served, batches run, padded rows) — thin view over
    /// the `serve.nll.*` counters.
    pub fn stats(&self) -> (u64, u64, u64) {
        (self.served.get(), self.batches.get(),
         self.padded_rows.get())
    }

    /// (generation requests served, total new tokens emitted) — thin
    /// view over the `serve.gen.*` counters.
    pub fn gen_stats(&self) -> (u64, u64) {
        (self.gen_served.get(), self.gen_tokens.get())
    }

    /// Prompt tokens the scheduler admitted by referencing resident
    /// prefix pages instead of prefilling them — thin view over the
    /// `serve.gen.shared_prefix_tokens` counter.
    pub fn gen_shared(&self) -> u64 {
        self.gen_shared_tokens.get()
    }

    /// Generation requests cancelled on client disconnect — thin view
    /// over the `serve.gen.cancelled` counter.
    pub fn gen_cancelled(&self) -> u64 {
        self.gen_cancelled.get()
    }

    /// Terminal replies whose receiver was already gone — thin view
    /// over the `serve.dropped_replies` counter.
    pub fn dropped_replies(&self) -> u64 {
        self.dropped_replies.get()
    }

    /// (cumulative per-request prefill seconds, cumulative
    /// time-to-first-token seconds) over finished generations — the
    /// `serve.gen.prefill_ns`/`serve.gen.ttft_ns` histogram SUMS (exact
    /// integer nanosecond totals; bucketing only coarsens quantiles) —
    /// divide by `gen_stats().0` for per-request averages. Prefill
    /// counts only each request's own chunked-prefill work; TTFT spans
    /// scheduler submission → first sampled token, queueing/deferral
    /// included.
    pub fn gen_latency(&self) -> (f64, f64) {
        (self.gen_prefill.sum() as f64 / 1e9,
         self.gen_ttft.sum() as f64 / 1e9)
    }

    /// Cumulative speculative-decode counters — thin view over the
    /// `serve.gen.spec.*` counters (all zero without a deployed
    /// drafter or spec-opted requests).
    pub fn gen_spec(&self) -> SpecCounters {
        SpecCounters {
            drafted: self.gen_spec_drafted.get(),
            accepted: self.gen_spec_accepted.get(),
            verify_steps: self.gen_spec_verify_steps.get(),
            emitted: self.gen_spec_emitted.get(),
        }
    }
}

/// Client handle (clone freely across threads).
#[derive(Clone)]
pub struct Client {
    q: Arc<ServerQueue>,
    seq: usize,
}

impl Client {
    pub fn new(q: Arc<ServerQueue>, seq: usize) -> Self {
        Client { q, seq }
    }

    /// Submit one sequence; blocks under backpressure. Returns the reply
    /// channel for (sum NLL over next-token predictions, count).
    pub fn submit(&self, tokens: Vec<i32>)
        -> Result<std::sync::mpsc::Receiver<(f64, usize)>> {
        anyhow::ensure!(tokens.len() == self.seq,
                        "request must be exactly seq={} tokens", self.seq);
        anyhow::ensure!(!self.q.stopped.load(Ordering::Acquire),
                        "server stopped");
        let (tx, rx) = std::sync::mpsc::channel();
        self.q.push(Msg::Infer(Request { tokens, reply: tx }));
        Ok(rx)
    }

    /// Submit and wait.
    pub fn nll(&self, tokens: Vec<i32>) -> Result<(f64, usize)> {
        let rx = self.submit(tokens)?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))
    }

    /// Submit one generation request (prompt of ANY length — generation
    /// is KV-cached, not bound to the server's [batch, seq] shape);
    /// blocks under backpressure. Returns the event stream: one
    /// `GenEvent::Token` per committed token, terminated by `Done` or
    /// `Failed`. Dropping the stream cancels the request and frees its
    /// KV slot (see `GenEvents`).
    pub fn submit_generate(&self, prompt: Vec<i32>, cfg: GenConfig)
        -> Result<GenEvents> {
        anyhow::ensure!(!prompt.is_empty(), "empty generation prompt");
        anyhow::ensure!(!self.q.stopped.load(Ordering::Acquire),
                        "server stopped");
        let (tx, rx) = std::sync::mpsc::channel();
        let open = Arc::new(AtomicBool::new(true));
        self.q.push(Msg::Generate(GenRequest {
            prompt,
            cfg,
            reply: GenStream { tx, open: open.clone() },
        }));
        Ok(GenEvents { rx, open })
    }

    /// Submit a generation request and stream it: tokens arrive as the
    /// scheduler commits them (bit-identical to what `generate` would
    /// return), and dropping the stream cancels the request. Alias of
    /// `submit_generate`, named for discoverability next to `generate`.
    pub fn generate_streaming(&self, prompt: Vec<i32>, cfg: GenConfig)
        -> Result<GenEvents> {
        self.submit_generate(prompt, cfg)
    }

    /// Submit a generation request and wait for the finished generation
    /// (drains the event stream internally).
    pub fn generate(&self, prompt: Vec<i32>, cfg: GenConfig)
        -> Result<Generation> {
        self.submit_generate(prompt, cfg)?.wait()
    }

    /// Queue a zero-downtime dense weight swap (ordered with
    /// inference). Clears any deployed drafter: a deployment is the
    /// (target, drafter) PAIR, and swapping only the target would
    /// leave a drafter from a different variant set.
    pub fn swap_weights(&self, w: Weights) {
        self.swap_deployment(ServedWeights::Dense(w), None);
    }

    /// Queue a zero-downtime swap to a packed quantized variant, served
    /// through the fused dequant-matmul path. Clears any deployed
    /// drafter (see `swap_weights`).
    pub fn swap_packed(&self, qm: QuantizedModel) {
        self.swap_deployment(ServedWeights::Packed(qm), None);
    }

    /// Queue a zero-downtime swap of the whole deployment: the serving
    /// target plus an optional drafter variant for speculative
    /// decoding (typically the 2-bit artifact of the same weights,
    /// with a 4-bit or dense target). The pair applies atomically
    /// behind the swap's drain barrier, so drafting and verification
    /// always run against one consistent deployment.
    pub fn swap_deployment(&self, target: ServedWeights,
                           drafter: Option<ServedWeights>) {
        self.q.push(Msg::Swap(Box::new(Deployment { target, drafter })));
    }

    /// Ask the serve loop to exit once the queue drains to this message.
    pub fn stop(&self) {
        self.q.push(Msg::Stop);
    }
}

/// Run the batching serve loop on the thread that owns the executor.
/// Returns when a `Stop` message is consumed and all earlier work has
/// drained.
///
/// NLL requests execute as padded [batch, seq] forwards on this thread.
/// Generation requests feed ONE shared `BatchEngine` scheduler (up to
/// `batch` concurrent sequences): each loop iteration drains the queue
/// into the scheduler and advances it by one batched decode step, so
/// requests admit into free slots and retire without stalling the rest —
/// continuous batching, not request-level fan-out. Outputs are
/// independent of co-batching (see `BatchEngine` on determinism), so a
/// served generation is identical to a direct `generate` call.
///
/// `Swap`/`Stop` are ordered barriers: on either, the loop stops
/// consuming messages, drains the scheduler's in-flight batch (and the
/// already-collected NLL rows), then applies the swap (or returns). The
/// executor stays `Sync` for API compatibility with callers that spawn
/// the serve thread; the PJRT engine (not `Sync`, and without a decode
/// path) keeps using the single-threaded `forward` flow via `Pipeline`.
pub fn serve(exec: &(dyn Executor + Sync), entry: &ModelEntry,
             batch: usize, weights: ServedWeights, q: &ServerQueue)
             -> Result<()> {
    serve_with_drafter(exec, entry, batch, weights, None, q)
}

/// `serve` with an optional drafter variant deployed from the start:
/// generation requests that opt in (`GenConfig::spec`) draft through
/// it and verify on the target in multi-row passes (see
/// `BatchEngine::step_spec`; greedy outputs stay bit-identical to
/// plain serving). Later `swap_deployment` messages replace target and
/// drafter together behind the usual drain barrier.
pub fn serve_with_drafter(exec: &(dyn Executor + Sync),
                          entry: &ModelEntry, batch: usize,
                          weights: ServedWeights,
                          drafter: Option<ServedWeights>,
                          q: &ServerQueue) -> Result<()> {
    let mut engine: BatchEngine<GenStream> = BatchEngine::with_kv_bits(
        &entry.config, batch.max(1), entry.kv_bits.clone());
    let res =
        serve_loop(exec, entry, batch, weights, drafter, q, &mut engine);
    if let Err(e) = &res {
        // Fatal engine/forward error (e.g. a malformed variant was
        // swapped in): fail every scheduled generation loudly, drop the
        // queued messages (closing their reply channels), and mark the
        // server stopped so new submissions error instead of hanging on
        // replies that will never come.
        for reply in engine.abort_all() {
            if !reply.emit(GenEvent::Failed(format!(
                "server failed: {e:#}")))
            {
                q.dropped_replies.inc();
            }
        }
        q.stopped.store(true, Ordering::Release);
        q.queue.lock().unwrap().clear();
        q.cv.notify_all();
    }
    res
}

fn serve_loop(exec: &(dyn Executor + Sync), entry: &ModelEntry,
              batch: usize, mut weights: ServedWeights,
              mut drafter: Option<ServedWeights>,
              q: &ServerQueue, engine: &mut BatchEngine<GenStream>)
              -> Result<()> {
    let seq = entry.config.seq;
    let v = entry.config.vocab;
    let mut stopping = false;
    // Engine totals already published to the monotone counters by THIS
    // loop: the engine reports lifetime totals (it outlives weight
    // swaps), so each step adds only the delta since the last
    // publication. Starts at the engine's current totals so a resumed
    // engine doesn't double-count. Same discipline for spec counters,
    // shared-prefix tokens, and cancellations.
    let mut spec_seen = engine.spec_counters();
    let mut shared_seen = engine.shared_prefix_tokens();
    let mut cancel_seen = engine.cancelled_total();
    loop {
        // Collect up to `batch` NLL rows and feed the scheduler; handle
        // control messages inline. Messages the loop cannot take yet are
        // DEFERRED — put back at the queue head in their original order —
        // so: throttled generations don't starve NLL rows queued behind
        // them, and a Swap/Stop barrier simply stays at the head (nothing
        // past it is consumed) until the scheduler has drained.
        let mut reqs: Vec<Request> = Vec::with_capacity(batch);
        {
            let mut guard = q.queue.lock().unwrap();
            // Block only when there is truly nothing to do.
            while guard.is_empty() && engine.is_idle() && !stopping {
                guard = q.cv.wait(guard).unwrap();
            }
            // Generation intake is bounded: at most one batch in flight
            // plus one batch queued inside the scheduler; the rest stay
            // in the bounded ServerQueue so `max_queue` backpressure
            // engages for generation traffic too. (`in_flight` cannot
            // shrink during this drain, so deferred generations keep
            // their relative order.)
            let gen_cap = 2 * engine.slots();
            let mut deferred: VecDeque<Msg> = VecDeque::new();
            if !stopping {
                while reqs.len() < batch {
                    match guard.pop_front() {
                        Some(Msg::Infer(r)) => reqs.push(r),
                        Some(Msg::Generate(g)) => {
                            if engine.in_flight() >= gen_cap {
                                deferred.push_back(Msg::Generate(g));
                                continue;
                            }
                            // A bad prompt fails ITS request, not the
                            // shared batch: submit hands the reply tag
                            // back with the error.
                            if let Err((reply, e)) = engine.submit(
                                g.reply, g.prompt, g.cfg)
                            {
                                if !reply.emit(GenEvent::Failed(
                                    format!("{e:#}")))
                                {
                                    q.dropped_replies.inc();
                                }
                            }
                        }
                        Some(Msg::Swap(w)) => {
                            // Applies only once everything submitted
                            // before it has drained; otherwise it is a
                            // barrier and intake stops here.
                            if reqs.is_empty()
                                && engine.is_idle()
                                && deferred.is_empty()
                            {
                                let d = *w;
                                weights = d.target;
                                drafter = d.drafter;
                            } else {
                                deferred.push_back(Msg::Swap(w));
                                break;
                            }
                        }
                        Some(Msg::Stop) => {
                            // Same barrier rule: deferred generations
                            // were submitted before the Stop and must
                            // still run.
                            if deferred.is_empty() {
                                stopping = true;
                            } else {
                                deferred.push_back(Msg::Stop);
                            }
                            break;
                        }
                        None => break,
                    }
                }
            }
            while let Some(m) = deferred.pop_back() {
                guard.push_front(m);
            }
        }
        q.cv.notify_all(); // wake submitters blocked on backpressure

        // One scheduler step: admit pending prompts into free slots,
        // batch-decode one token for every in-flight generation, retire
        // finished sequences.
        if !engine.is_idle() {
            let t0 = Instant::now();
            let done = engine.step_spec(
                exec, entry, weights.model_ref(),
                drafter.as_ref().map(|d| d.model_ref()))?;
            q.step_ns.record(t0.elapsed().as_nanos() as u64);
            let shared = engine.shared_prefix_tokens();
            q.gen_shared_tokens.add(shared - shared_seen);
            shared_seen = shared;
            let cancelled = engine.cancelled_total();
            q.gen_cancelled.add(cancelled - cancel_seen);
            cancel_seen = cancelled;
            let sc = engine.spec_counters();
            q.gen_spec_drafted.add(sc.drafted - spec_seen.drafted);
            q.gen_spec_accepted.add(sc.accepted - spec_seen.accepted);
            q.gen_spec_emitted.add(sc.emitted - spec_seen.emitted);
            q.gen_spec_verify_steps
                .add(sc.verify_steps - spec_seen.verify_steps);
            spec_seen = sc;
            for (reply, gen) in done {
                q.gen_served.inc();
                q.gen_tokens.add(gen.tokens.len() as u64);
                // The GenStats nanosecond fields verbatim — no
                // seconds→nanos round trip anywhere in the path.
                q.gen_prefill.record(gen.stats.prefill_ns);
                q.gen_ttft.record(gen.stats.ttft_ns);
                q.gen_decode.record(gen.stats.decode_ns);
                // The engine already emitted `Done` through the
                // stream; a closed stream here means the receiver
                // vanished between its last token and retirement —
                // the finished generation was undeliverable.
                if !reply.is_connected() {
                    q.dropped_replies.inc();
                }
            }
        }

        if !reqs.is_empty() {
            let rows = reqs.len();
            let mut tokens = vec![0i32; batch * seq];
            for (i, r) in reqs.iter().enumerate() {
                tokens[i * seq..(i + 1) * seq].copy_from_slice(&r.tokens);
            }
            let logits =
                weights.forward(exec, entry, &tokens, batch)?;
            q.batches.inc();
            q.padded_rows.add((batch - rows) as u64);
            for (i, r) in reqs.into_iter().enumerate() {
                let row = crate::tensor::Tensor::new(
                    logits.data()[i * seq * v..(i + 1) * seq * v].to_vec(),
                    vec![1, seq, v],
                );
                let res = batch_nll(&row, &r.tokens, 1, seq);
                q.served.inc();
                if r.reply.send(res).is_err() {
                    q.dropped_replies.inc();
                }
            }
        }

        // Stop completes once the scheduler has drained (a deferred
        // Swap barrier re-applies itself from the queue head instead).
        if stopping && engine.is_idle() {
            q.stopped.store(true, Ordering::Release);
            // Messages that slipped in behind the Stop will never be
            // drained; dropping them closes their reply channels so
            // waiting clients fail instead of hanging.
            q.queue.lock().unwrap().clear();
            q.cv.notify_all();
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_backpressure_blocks_then_releases() {
        let q = ServerQueue::new(2);
        let c = Client::new(q.clone(), 4);
        let _r1 = c.submit(vec![0; 4]).unwrap();
        let _r2 = c.submit(vec![0; 4]).unwrap();
        // Third submit must block until the consumer drains one.
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            let c2 = Client::new(q2, 4);
            c2.submit(vec![1; 4]).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!t.is_finished(), "submit should be blocked");
        // Drain one message.
        {
            let mut g = q.queue.lock().unwrap();
            g.pop_front();
        }
        q.cv.notify_all();
        t.join().unwrap();
        assert_eq!(q.queue.lock().unwrap().len(), 2);
    }

    #[test]
    fn control_messages_bypass_backpressure() {
        let q = ServerQueue::new(1);
        let c = Client::new(q.clone(), 4);
        let _r = c.submit(vec![0; 4]).unwrap();
        c.stop(); // must not block even though the queue is "full"
        assert_eq!(q.queue.lock().unwrap().len(), 2);
    }

    #[test]
    fn backpressure_ignores_queued_control_messages() {
        // max_queue = 1: two queued barriers would have wedged this
        // submit forever when backpressure gated on raw queue length.
        let q = ServerQueue::new(1);
        let c = Client::new(q.clone(), 4);
        c.stop();
        c.stop();
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            let c2 = Client::new(q2, 4);
            c2.submit(vec![0; 4]).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(t.is_finished(),
                "submit must not block behind control barriers");
        t.join().unwrap();
        // One work message now queued: the NEXT submit blocks until it
        // drains — control messages changed nothing about work capacity.
        let q3 = q.clone();
        let t2 = std::thread::spawn(move || {
            let c3 = Client::new(q3, 4);
            c3.submit(vec![1; 4]).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!t2.is_finished(), "work capacity still enforced");
        {
            let mut g = q.queue.lock().unwrap();
            let pos = g
                .iter()
                .position(|m| matches!(m, Msg::Infer(_)))
                .expect("queued work message");
            g.remove(pos);
        }
        q.cv.notify_all();
        t2.join().unwrap();
    }

    #[test]
    fn dropping_gen_events_clears_the_open_flag() {
        let q = ServerQueue::new(4);
        let c = Client::new(q.clone(), 4);
        let ev = c.submit_generate(vec![1, 2, 3], GenConfig::default())
            .unwrap();
        let stream = {
            let mut g = q.queue.lock().unwrap();
            match g.pop_front() {
                Some(Msg::Generate(gr)) => gr.reply,
                _ => panic!("expected queued generation"),
            }
        };
        assert!(stream.is_connected());
        assert!(stream.emit(GenEvent::Token { token: 7, pos: 0 }));
        drop(ev);
        // The receiver's Drop cleared the shared flag: the engine's
        // once-per-step probe sees the disconnect without sending.
        assert!(!stream.is_connected());
        assert!(!stream.emit(GenEvent::Token { token: 8, pos: 1 }));
    }
}
