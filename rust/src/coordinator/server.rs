//! Serving front-end: a dynamic batcher over the weight-swappable
//! executor — the vLLM-router-shaped piece of the L3 coordinator.
//!
//! Requests (token windows wanting NLL scores) arrive on a bounded queue
//! from any number of client threads; the *engine thread* (PJRT handles
//! are not `Send`; the native engine keeps the same discipline) runs
//! `serve`, packing requests into the executor's fixed [batch, seq]
//! shape (padding the tail), executing, and resolving per-request
//! replies. Backpressure: submitters block while the queue is at
//! `max_queue`.
//!
//! Weight swap is a queued control message, so deploying a new quantized
//! variant is ordered with respect to in-flight requests and requires NO
//! recompilation. Variants deploy either as dense f32 weights or as a
//! packed 2/4-bit `QuantizedModel`, which the native executor serves via
//! the fused dequant-matmul without ever materializing f32 weights.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::eval::ppl::batch_nll;
use crate::infer::{generate, Executor, GenConfig, Generation, ModelRef,
                   QuantizedModel};
use crate::model::Weights;
use crate::runtime::ModelEntry;
use crate::util::pool::parallel_map;

/// A deployable weight variant: dense f32 or packed 2/4-bit codes.
pub enum ServedWeights {
    Dense(Weights),
    Packed(QuantizedModel),
}

impl ServedWeights {
    fn forward(&self, exec: &dyn Executor, entry: &ModelEntry,
               tokens: &[i32], batch: usize)
               -> Result<crate::tensor::Tensor> {
        match self {
            ServedWeights::Dense(w) => {
                exec.forward(entry, tokens, batch, w)
            }
            ServedWeights::Packed(qm) => {
                exec.forward_packed(entry, tokens, batch, qm)
            }
        }
    }

    /// Borrowed dispatch handle for the decode/generation paths.
    pub fn model_ref(&self) -> ModelRef<'_> {
        match self {
            ServedWeights::Dense(w) => ModelRef::Dense(w),
            ServedWeights::Packed(qm) => ModelRef::Packed(qm),
        }
    }
}

enum Msg {
    Infer(Request),
    Generate(GenRequest),
    Swap(Box<ServedWeights>),
    Stop,
}

struct Request {
    tokens: Vec<i32>,
    reply: std::sync::mpsc::Sender<(f64, usize)>,
}

/// One queued generation request (KV-cached autoregressive decode on the
/// currently deployed variant).
struct GenRequest {
    prompt: Vec<i32>,
    cfg: GenConfig,
    reply: std::sync::mpsc::Sender<Result<Generation>>,
}

/// Shared queue + stats between clients and the engine thread.
pub struct ServerQueue {
    queue: Mutex<VecDeque<Msg>>,
    cv: Condvar,
    max_queue: usize,
    stopped: AtomicBool,
    pub served: AtomicU64,
    pub batches: AtomicU64,
    pub padded_rows: AtomicU64,
    pub gen_served: AtomicU64,
    pub gen_tokens: AtomicU64,
}

impl ServerQueue {
    pub fn new(max_queue: usize) -> Arc<Self> {
        Arc::new(ServerQueue {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            max_queue,
            stopped: AtomicBool::new(false),
            served: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            padded_rows: AtomicU64::new(0),
            gen_served: AtomicU64::new(0),
            gen_tokens: AtomicU64::new(0),
        })
    }

    fn push(&self, msg: Msg) {
        let mut q = self.queue.lock().unwrap();
        // Control messages bypass backpressure; work messages respect it.
        if matches!(msg, Msg::Infer(_) | Msg::Generate(_)) {
            while q.len() >= self.max_queue {
                q = self.cv.wait(q).unwrap();
            }
        }
        q.push_back(msg);
        drop(q);
        self.cv.notify_all();
    }

    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.served.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.padded_rows.load(Ordering::Relaxed),
        )
    }

    /// (generation requests served, total new tokens emitted).
    pub fn gen_stats(&self) -> (u64, u64) {
        (
            self.gen_served.load(Ordering::Relaxed),
            self.gen_tokens.load(Ordering::Relaxed),
        )
    }
}

/// Client handle (clone freely across threads).
#[derive(Clone)]
pub struct Client {
    q: Arc<ServerQueue>,
    seq: usize,
}

impl Client {
    pub fn new(q: Arc<ServerQueue>, seq: usize) -> Self {
        Client { q, seq }
    }

    /// Submit one sequence; blocks under backpressure. Returns the reply
    /// channel for (sum NLL over next-token predictions, count).
    pub fn submit(&self, tokens: Vec<i32>)
        -> Result<std::sync::mpsc::Receiver<(f64, usize)>> {
        anyhow::ensure!(tokens.len() == self.seq,
                        "request must be exactly seq={} tokens", self.seq);
        anyhow::ensure!(!self.q.stopped.load(Ordering::Acquire),
                        "server stopped");
        let (tx, rx) = std::sync::mpsc::channel();
        self.q.push(Msg::Infer(Request { tokens, reply: tx }));
        Ok(rx)
    }

    /// Submit and wait.
    pub fn nll(&self, tokens: Vec<i32>) -> Result<(f64, usize)> {
        let rx = self.submit(tokens)?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))
    }

    /// Submit one generation request (prompt of ANY length — generation
    /// is KV-cached, not bound to the server's [batch, seq] shape);
    /// blocks under backpressure. Returns the reply channel.
    pub fn submit_generate(&self, prompt: Vec<i32>, cfg: GenConfig)
        -> Result<std::sync::mpsc::Receiver<Result<Generation>>> {
        anyhow::ensure!(!prompt.is_empty(), "empty generation prompt");
        anyhow::ensure!(!self.q.stopped.load(Ordering::Acquire),
                        "server stopped");
        let (tx, rx) = std::sync::mpsc::channel();
        self.q.push(Msg::Generate(GenRequest { prompt, cfg, reply: tx }));
        Ok(rx)
    }

    /// Submit a generation request and wait for the finished generation.
    pub fn generate(&self, prompt: Vec<i32>, cfg: GenConfig)
        -> Result<Generation> {
        let rx = self.submit_generate(prompt, cfg)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("server dropped request"))?
    }

    /// Queue a zero-downtime dense weight swap (ordered with inference).
    pub fn swap_weights(&self, w: Weights) {
        self.q.push(Msg::Swap(Box::new(ServedWeights::Dense(w))));
    }

    /// Queue a zero-downtime swap to a packed quantized variant, served
    /// through the fused dequant-matmul path.
    pub fn swap_packed(&self, qm: QuantizedModel) {
        self.q.push(Msg::Swap(Box::new(ServedWeights::Packed(qm))));
    }

    /// Ask the serve loop to exit once the queue drains to this message.
    pub fn stop(&self) {
        self.q.push(Msg::Stop);
    }
}

/// Run the batching serve loop on the thread that owns the executor.
/// Returns when a `Stop` message is consumed.
///
/// NLL requests execute as padded [batch, seq] forwards on this thread;
/// generation requests run KV-cached decode loops fanned across
/// `util::pool` workers (up to `batch` concurrent generations, each with
/// its own cache), which is why the executor must be `Sync` — the native
/// engine is; the PJRT engine (not `Sync`, and without a decode path)
/// keeps using the single-threaded `forward` flow via `Pipeline`.
pub fn serve(exec: &(dyn Executor + Sync), entry: &ModelEntry,
             batch: usize, mut weights: ServedWeights, q: &ServerQueue)
             -> Result<()> {
    let seq = entry.config.seq;
    let v = entry.config.vocab;
    loop {
        // Collect up to `batch` of each work kind; handle control
        // messages inline (they are ordered barriers: a Swap applies only
        // between flushed batches, so every drained request runs on one
        // consistent variant).
        let mut reqs: Vec<Request> = Vec::with_capacity(batch);
        let mut gens: Vec<GenRequest> = Vec::new();
        let mut stop = false;
        {
            let mut guard = q.queue.lock().unwrap();
            while guard.is_empty() {
                guard = q.cv.wait(guard).unwrap();
            }
            while reqs.len() < batch && gens.len() < batch {
                match guard.pop_front() {
                    Some(Msg::Infer(r)) => reqs.push(r),
                    Some(Msg::Generate(g)) => gens.push(g),
                    Some(Msg::Swap(w)) => {
                        if reqs.is_empty() && gens.is_empty() {
                            weights = *w;
                        } else {
                            // Keep ordering: put it back, flush batch first.
                            guard.push_front(Msg::Swap(w));
                            break;
                        }
                    }
                    Some(Msg::Stop) => {
                        stop = true;
                        break;
                    }
                    None => break,
                }
            }
        }
        q.cv.notify_all(); // wake submitters blocked on backpressure
        if !gens.is_empty() {
            let results = parallel_map(gens.len(), batch.max(1), |i| {
                generate(exec, entry, weights.model_ref(),
                         &gens[i].prompt, &gens[i].cfg)
            });
            for (g, res) in gens.into_iter().zip(results) {
                if let Ok(r) = &res {
                    q.gen_served.fetch_add(1, Ordering::Relaxed);
                    q.gen_tokens.fetch_add(r.tokens.len() as u64,
                                           Ordering::Relaxed);
                }
                let _ = g.reply.send(res);
            }
        }
        if !reqs.is_empty() {
            let rows = reqs.len();
            let mut tokens = vec![0i32; batch * seq];
            for (i, r) in reqs.iter().enumerate() {
                tokens[i * seq..(i + 1) * seq].copy_from_slice(&r.tokens);
            }
            let logits =
                weights.forward(exec, entry, &tokens, batch)?;
            q.batches.fetch_add(1, Ordering::Relaxed);
            q.padded_rows
                .fetch_add((batch - rows) as u64, Ordering::Relaxed);
            for (i, r) in reqs.into_iter().enumerate() {
                let row = crate::tensor::Tensor::new(
                    logits.data()[i * seq * v..(i + 1) * seq * v].to_vec(),
                    vec![1, seq, v],
                );
                let res = batch_nll(&row, &r.tokens, 1, seq);
                q.served.fetch_add(1, Ordering::Relaxed);
                let _ = r.reply.send(res);
            }
        }
        if stop {
            q.stopped.store(true, Ordering::Release);
            q.cv.notify_all();
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_backpressure_blocks_then_releases() {
        let q = ServerQueue::new(2);
        let c = Client::new(q.clone(), 4);
        let _r1 = c.submit(vec![0; 4]).unwrap();
        let _r2 = c.submit(vec![0; 4]).unwrap();
        // Third submit must block until the consumer drains one.
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            let c2 = Client::new(q2, 4);
            c2.submit(vec![1; 4]).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!t.is_finished(), "submit should be blocked");
        // Drain one message.
        {
            let mut g = q.queue.lock().unwrap();
            g.pop_front();
        }
        q.cv.notify_all();
        t.join().unwrap();
        assert_eq!(q.queue.lock().unwrap().len(), 2);
    }

    #[test]
    fn control_messages_bypass_backpressure() {
        let q = ServerQueue::new(1);
        let c = Client::new(q.clone(), 4);
        let _r = c.submit(vec![0; 4]).unwrap();
        c.stop(); // must not block even though the queue is "full"
        assert_eq!(q.queue.lock().unwrap().len(), 2);
    }
}
