//! Calibration data collection (the paper's "128 sequences from Pile" →
//! our train-corpus sample; DESIGN.md "Substitutions").
//!
//! One probe-artifact pass per model yields every activation the
//! calibration-based baselines and GPTQ need; one grad-artifact pass
//! yields the loss gradients for LLM-MQ. Collected once and cached by the
//! coordinator — the quantization experiments themselves stay data-free
//! for NSDS and the calibration-free baselines.

use anyhow::Result;

use crate::model::Weights;
use crate::quant::HessianMap;
use crate::runtime::{Engine, Input, Manifest, ModelEntry};
use crate::tensor::Tensor;

/// Activations + gradients for one model, from `n_batches` probe batches.
#[derive(Clone, Debug)]
pub struct Calibration {
    /// Residual-stream inputs per layer (+ the final residual as the last
    /// entry): [L+1] tensors of [rows, D].
    pub resid: Vec<Tensor>,
    /// RMSNorm'd attention inputs (inputs to wq/wk/wv): [L] × [rows, D].
    pub x_ln1: Vec<Tensor>,
    /// RMSNorm'd FFN inputs (inputs to wgate/wup): [L] × [rows, D].
    pub x_ln2: Vec<Tensor>,
    /// Attention context (inputs to wo): [L] × [rows, H·dh].
    pub attn_ctx: Vec<Tensor>,
    /// FFN intermediates (inputs to wdown): [L] × [rows, F].
    pub ffn_mid: Vec<Tensor>,
    /// Loss gradients w.r.t. each stacked quantizable weight.
    pub grads: std::collections::BTreeMap<String, Tensor>,
    /// Calibration loss (diagnostic).
    pub loss: f64,
}

/// Reorder a probe output [L, B, S, X] into per-layer [B·S, X] tensors.
fn split_layers(t: &Tensor) -> Vec<Tensor> {
    let l = t.dims()[0];
    let rows = t.dims()[1] * t.dims()[2];
    let x = t.dims()[3];
    (0..l)
        .map(|li| t.slice0(li).reshape(vec![rows, x]))
        .collect()
}

/// Append rows of `src` onto `dst` (both [_, X]).
fn append_rows(dst: &mut Tensor, src: &Tensor) {
    assert_eq!(dst.cols(), src.cols());
    let mut data = std::mem::replace(dst, Tensor::zeros(vec![0, 0]))
        .into_data();
    data.extend_from_slice(src.data());
    let cols = src.cols();
    let rows = data.len() / cols;
    *dst = Tensor::new(data, vec![rows, cols]);
}

/// Collect calibration activations + gradients.
/// `n_batches` probe batches of [eval_batch, seq] from the train corpus.
pub fn collect(engine: &Engine, man: &Manifest, entry: &ModelEntry,
               weights: &Weights, train: &[i32], n_batches: usize)
               -> Result<Calibration> {
    let b = man.eval_batch;
    let s = entry.config.seq;
    let l = entry.config.n_layers;
    let per = b * s;

    let mut resid: Vec<Tensor> = Vec::new();
    let mut x_ln1: Vec<Tensor> = Vec::new();
    let mut x_ln2: Vec<Tensor> = Vec::new();
    let mut attn_ctx: Vec<Tensor> = Vec::new();
    let mut ffn_mid: Vec<Tensor> = Vec::new();

    let ordered = weights.ordered();
    for i in 0..n_batches {
        let chunk = &train[i * per..(i + 1) * per];
        let mut inputs: Vec<Input> = Vec::with_capacity(13);
        inputs.push(Input::I32(chunk, vec![b, s]));
        for t in &ordered {
            inputs.push(Input::F32(t));
        }
        let out = engine.execute(&entry.hlo_probe, &inputs)?;
        // (logits, resid_in [L,B,S,D], final_resid, x_ln1, x_ln2,
        //  attn_ctx, ffn_mid)
        let r_in = split_layers(&out[1]);
        let fin = out[2].clone().reshape(vec![per, entry.config.d_model]);
        let l1 = split_layers(&out[3]);
        let l2 = split_layers(&out[4]);
        let ctx = split_layers(&out[5]);
        let mid = split_layers(&out[6]);
        if i == 0 {
            resid = r_in;
            resid.push(fin);
            x_ln1 = l1;
            x_ln2 = l2;
            attn_ctx = ctx;
            ffn_mid = mid;
        } else {
            for (d, sx) in resid.iter_mut().zip(
                r_in.iter().chain(std::iter::once(&fin))) {
                append_rows(d, sx);
            }
            for (d, sx) in x_ln1.iter_mut().zip(&l1) {
                append_rows(d, sx);
            }
            for (d, sx) in x_ln2.iter_mut().zip(&l2) {
                append_rows(d, sx);
            }
            for (d, sx) in attn_ctx.iter_mut().zip(&ctx) {
                append_rows(d, sx);
            }
            for (d, sx) in ffn_mid.iter_mut().zip(&mid) {
                append_rows(d, sx);
            }
        }
    }
    assert_eq!(resid.len(), l + 1);

    // Gradients: one grad-artifact batch (averaging more adds little for
    // a first-order saliency proxy).
    let chunk = &train[0..per];
    let mut inputs: Vec<Input> = Vec::with_capacity(13);
    inputs.push(Input::I32(chunk, vec![b, s]));
    for t in &ordered {
        inputs.push(Input::F32(t));
    }
    let gout = engine.execute(&entry.hlo_grad, &inputs)?;
    let loss = gout[0].data()[0] as f64;
    let mut grads = std::collections::BTreeMap::new();
    for (i, name) in crate::model::QUANT_WEIGHTS.iter().enumerate() {
        grads.insert(name.to_string(), gout[i + 1].clone());
    }

    Ok(Calibration { resid, x_ln1, x_ln2, attn_ctx, ffn_mid, grads, loss })
}

impl Calibration {
    /// Input activations feeding projection `name` at layer `l`.
    pub fn inputs_for(&self, name: &str, l: usize) -> &Tensor {
        match name {
            "wq" | "wk" | "wv" => &self.x_ln1[l],
            "wo" => &self.attn_ctx[l],
            "wgate" | "wup" => &self.x_ln2[l],
            "wdown" => &self.ffn_mid[l],
            _ => panic!("no calibration inputs for {name}"),
        }
    }

    /// GPTQ Hessians for every (layer, projection).
    pub fn hessians(&self, n_layers: usize) -> HessianMap {
        let mut map = HessianMap::new();
        for l in 0..n_layers {
            for name in crate::model::QUANT_WEIGHTS {
                let x = self.inputs_for(name, l);
                map.insert(
                    (l, name.to_string()),
                    crate::quant::gptq::hessian_from_inputs(x),
                );
            }
        }
        map
    }

    /// Row-subsampled copy of a [rows, X] activation (for SVD-heavy
    /// baselines like LieQ).
    pub fn subsample(x: &Tensor, max_rows: usize) -> Tensor {
        let rows = x.rows();
        if rows <= max_rows {
            return x.clone();
        }
        let stride = rows / max_rows;
        let mut out = Vec::with_capacity(max_rows * x.cols());
        for r in 0..max_rows {
            out.extend_from_slice(x.row(r * stride));
        }
        Tensor::new(out, vec![max_rows, x.cols()])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_layers_shapes() {
        let t = Tensor::new((0..2 * 3 * 4 * 5).map(|x| x as f32).collect(),
                            vec![2, 3, 4, 5]);
        let v = split_layers(&t);
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].dims(), &[12, 5]);
        assert_eq!(v[1].at(0, 0), 60.0);
    }

    #[test]
    fn append_rows_concatenates() {
        let mut a = Tensor::new(vec![1.0, 2.0], vec![1, 2]);
        let b = Tensor::new(vec![3.0, 4.0, 5.0, 6.0], vec![2, 2]);
        append_rows(&mut a, &b);
        assert_eq!(a.dims(), &[3, 2]);
        assert_eq!(a.data(), &[1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn subsample_strides() {
        let x = Tensor::new((0..20).map(|v| v as f32).collect(), vec![10, 2]);
        let s = Calibration::subsample(&x, 5);
        assert_eq!(s.dims(), &[5, 2]);
        assert_eq!(s.at(1, 0), 4.0); // stride 2
    }
}
