//! Calibration data collection (the paper's "128 sequences from Pile" →
//! our train-corpus sample; DESIGN.md "Substitutions").
//!
//! Probe batches run through ANY `infer::Executor` (native or PJRT) and
//! yield every activation the calibration-based baselines and GPTQ need;
//! a grad pass yields the loss gradients for LLM-MQ. Gradients are an
//! optional executor capability (the native engine has no reverse mode
//! yet), so `grads` is `None` when the executor cannot provide them —
//! the quantization experiments themselves stay data-free for NSDS and
//! the calibration-free baselines either way.

use anyhow::Result;

use crate::eval::ppl::batch_nll;
use crate::infer::Executor;
use crate::model::Weights;
use crate::quant::HessianMap;
use crate::runtime::{Manifest, ModelEntry};
use crate::tensor::Tensor;

/// Activations + gradients for one model, from `n_batches` probe batches.
#[derive(Clone, Debug)]
pub struct Calibration {
    /// Residual-stream inputs per layer (+ the final residual as the last
    /// entry): [L+1] tensors of [rows, D].
    pub resid: Vec<Tensor>,
    /// RMSNorm'd attention inputs (inputs to wq/wk/wv): [L] × [rows, D].
    pub x_ln1: Vec<Tensor>,
    /// RMSNorm'd FFN inputs (inputs to wgate/wup): [L] × [rows, D].
    pub x_ln2: Vec<Tensor>,
    /// Attention context (inputs to wo): [L] × [rows, H·dh].
    pub attn_ctx: Vec<Tensor>,
    /// FFN intermediates (inputs to wdown): [L] × [rows, F].
    pub ffn_mid: Vec<Tensor>,
    /// Loss gradients w.r.t. each stacked quantizable weight; `None`
    /// when the executor cannot collect gradients (LLM-MQ unavailable).
    pub grads: Option<std::collections::BTreeMap<String, Tensor>>,
    /// Calibration loss (mean next-token NLL of batch 0; diagnostic).
    pub loss: f64,
}

/// Append rows of `src` onto `dst` (both [_, X]).
fn append_rows(dst: &mut Tensor, src: &Tensor) {
    assert_eq!(dst.cols(), src.cols());
    let mut data = std::mem::replace(dst, Tensor::zeros(vec![0, 0]))
        .into_data();
    data.extend_from_slice(src.data());
    let cols = src.cols();
    let rows = data.len() / cols;
    *dst = Tensor::new(data, vec![rows, cols]);
}

/// Collect calibration activations + gradients.
/// `n_batches` probe batches of [eval_batch, seq] from the train corpus.
pub fn collect(exec: &dyn Executor, man: &Manifest, entry: &ModelEntry,
               weights: &Weights, train: &[i32], n_batches: usize)
               -> Result<Calibration> {
    let b = man.eval_batch;
    let s = entry.config.seq;
    let l = entry.config.n_layers;
    let per = b * s;

    let mut resid: Vec<Tensor> = Vec::new();
    let mut x_ln1: Vec<Tensor> = Vec::new();
    let mut x_ln2: Vec<Tensor> = Vec::new();
    let mut attn_ctx: Vec<Tensor> = Vec::new();
    let mut ffn_mid: Vec<Tensor> = Vec::new();
    let mut loss = 0.0f64;

    for i in 0..n_batches {
        let chunk = &train[i * per..(i + 1) * per];
        let p = exec.probe(entry, chunk, b, weights)?;
        if i == 0 {
            let (nll, count) = batch_nll(&p.logits, chunk, b, s);
            loss = nll / count.max(1) as f64;
            resid = p.resid_in;
            resid.push(p.final_resid);
            x_ln1 = p.x_ln1;
            x_ln2 = p.x_ln2;
            attn_ctx = p.attn_ctx;
            ffn_mid = p.ffn_mid;
        } else {
            for (d, sx) in resid.iter_mut().zip(
                p.resid_in.iter()
                    .chain(std::iter::once(&p.final_resid))) {
                append_rows(d, sx);
            }
            for (d, sx) in x_ln1.iter_mut().zip(&p.x_ln1) {
                append_rows(d, sx);
            }
            for (d, sx) in x_ln2.iter_mut().zip(&p.x_ln2) {
                append_rows(d, sx);
            }
            for (d, sx) in attn_ctx.iter_mut().zip(&p.attn_ctx) {
                append_rows(d, sx);
            }
            for (d, sx) in ffn_mid.iter_mut().zip(&p.ffn_mid) {
                append_rows(d, sx);
            }
        }
    }
    assert_eq!(resid.len(), l + 1);

    // Gradients: one grad batch (averaging more adds little for a
    // first-order saliency proxy). Optional executor capability — but a
    // grad failure on a SUPPORTING executor (e.g. corrupt grad
    // artifact) is a real error and propagates.
    let grads = if exec.supports_grads() {
        Some(exec.grads(entry, &train[0..per], b, weights)?)
    } else {
        eprintln!("[calib] {} collects no gradients; LLM-MQ scoring \
                   disabled", exec.platform());
        None
    };

    Ok(Calibration { resid, x_ln1, x_ln2, attn_ctx, ffn_mid, grads, loss })
}

impl Calibration {
    /// Input activations feeding projection `name` at layer `l`.
    pub fn inputs_for(&self, name: &str, l: usize) -> &Tensor {
        match name {
            "wq" | "wk" | "wv" => &self.x_ln1[l],
            "wo" => &self.attn_ctx[l],
            "wgate" | "wup" => &self.x_ln2[l],
            "wdown" => &self.ffn_mid[l],
            _ => panic!("no calibration inputs for {name}"),
        }
    }

    /// GPTQ Hessians for every (layer, projection).
    pub fn hessians(&self, n_layers: usize) -> HessianMap {
        let mut map = HessianMap::new();
        for l in 0..n_layers {
            for name in crate::model::QUANT_WEIGHTS {
                let x = self.inputs_for(name, l);
                map.insert(
                    (l, name.to_string()),
                    crate::quant::gptq::hessian_from_inputs(x),
                );
            }
        }
        map
    }

    /// Row-subsampled copy of a [rows, X] activation (for SVD-heavy
    /// baselines like LieQ).
    pub fn subsample(x: &Tensor, max_rows: usize) -> Tensor {
        let rows = x.rows();
        if rows <= max_rows {
            return x.clone();
        }
        let stride = rows / max_rows;
        let mut out = Vec::with_capacity(max_rows * x.cols());
        for r in 0..max_rows {
            out.extend_from_slice(x.row(r * stride));
        }
        Tensor::new(out, vec![max_rows, x.cols()])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::NativeEngine;
    use crate::model::ModelConfig;
    use crate::util::rng::Rng;

    #[test]
    fn append_rows_concatenates() {
        let mut a = Tensor::new(vec![1.0, 2.0], vec![1, 2]);
        let b = Tensor::new(vec![3.0, 4.0, 5.0, 6.0], vec![2, 2]);
        append_rows(&mut a, &b);
        assert_eq!(a.dims(), &[3, 2]);
        assert_eq!(a.data(), &[1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn subsample_strides() {
        let x = Tensor::new((0..20).map(|v| v as f32).collect(), vec![10, 2]);
        let s = Calibration::subsample(&x, 5);
        assert_eq!(s.dims(), &[5, 2]);
        assert_eq!(s.at(1, 0), 4.0); // stride 2
    }

    /// End-to-end collect through the native executor on a synthetic
    /// model: shapes line up and grads degrade to None gracefully.
    #[test]
    fn collect_native_shapes_and_optional_grads() {
        let cfg = ModelConfig::test_config();
        let entry = ModelEntry::synthetic(cfg.clone());
        let mut rng = Rng::new(60);
        let w = Weights::synth(&cfg, &mut rng, &[], &[]);
        let exec = NativeEngine::with_workers(2);
        let man = Manifest {
            dir: std::path::PathBuf::from("."),
            eval_batch: 2,
            models: vec![],
            tasks_file: String::new(),
            tasks: vec![],
            corpus_file: String::new(),
            kernels: vec![],
        };
        let n_batches = 3;
        let train: Vec<i32> = (0..n_batches * man.eval_batch * cfg.seq)
            .map(|i| ((i * 5) % cfg.vocab) as i32)
            .collect();
        let c = collect(&exec, &man, &entry, &w, &train, n_batches)
            .unwrap();
        let rows = n_batches * man.eval_batch * cfg.seq;
        assert_eq!(c.resid.len(), cfg.n_layers + 1);
        assert_eq!(c.resid[0].dims(), &[rows, cfg.d_model]);
        assert_eq!(c.x_ln1[0].dims(), &[rows, cfg.d_model]);
        assert_eq!(c.attn_ctx[0].dims(),
                   &[rows, cfg.n_heads * cfg.d_head]);
        assert_eq!(c.ffn_mid[0].dims(), &[rows, cfg.d_ffn]);
        assert!(c.grads.is_none(), "native engine has no grads yet");
        assert!(c.loss.is_finite() && c.loss > 0.0);
    }
}
