//! L3 coordinator: the deployable pipeline tying everything together.
//!
//! `Pipeline` owns an `infer::Executor` (native by default; PJRT behind
//! the `xla` feature), the artifact manifest, and per-model caches (FP
//! weights, init weights, calibration activations, method scores).
//! Experiment drivers (`report::paper`) ask it for (method × model ×
//! budget × backend) runs; it scores layers in parallel worker threads,
//! quantizes, and evaluates THROUGH the executor.

pub mod calib;
pub mod http;
pub mod server;

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use crate::baselines::{self, Method};
use crate::eval::{evaluate, EvalOptions, EvalResult};
use crate::infer::{default_executor, Executor, QuantizedModel};
use crate::model::Weights;
use crate::quant::{Backend, HessianMap, DEFAULT_GROUP};
use crate::runtime::{Manifest, ModelEntry};
use crate::sensitivity::Ablation;
use crate::util::pool::default_workers;

/// Number of probe batches for calibration (≈ eval_batch × seq × N tokens;
/// the paper samples 128 × 2048 from Pile — scaled to our corpus).
pub const CALIB_BATCHES: usize = 4;

pub struct Pipeline {
    pub engine: Box<dyn Executor>,
    pub man: Manifest,
    pub workers: usize,
    weights: Mutex<HashMap<String, Weights>>,
    init_weights: Mutex<HashMap<String, Weights>>,
    calib: Mutex<HashMap<String, std::sync::Arc<calib::Calibration>>>,
    scores: Mutex<HashMap<(String, String), Vec<f64>>>,
    hessians: Mutex<HashMap<String, std::sync::Arc<HessianMap>>>,
    fp_eval: Mutex<HashMap<String, EvalResult>>,
}

impl Pipeline {
    /// Pipeline over the default executor (native engine, or PJRT when
    /// the `xla` feature is enabled — see `infer::default_executor`).
    pub fn new() -> Result<Self> {
        let dir = Manifest::default_dir();
        let workers = default_workers();
        let engine = default_executor(&dir, workers)?;
        Self::with_engine(engine)
    }

    /// Pipeline over an explicit executor.
    pub fn with_engine(engine: Box<dyn Executor>) -> Result<Self> {
        let dir = Manifest::default_dir();
        let man = Manifest::load(&dir)?;
        Ok(Pipeline {
            engine,
            man,
            workers: default_workers(),
            weights: Mutex::new(HashMap::new()),
            init_weights: Mutex::new(HashMap::new()),
            calib: Mutex::new(HashMap::new()),
            scores: Mutex::new(HashMap::new()),
            hessians: Mutex::new(HashMap::new()),
            fp_eval: Mutex::new(HashMap::new()),
        })
    }

    /// The executor every forward goes through.
    pub fn exec(&self) -> &dyn Executor {
        self.engine.as_ref()
    }

    pub fn entry(&self, model: &str) -> Result<&ModelEntry> {
        self.man.model(model)
    }

    /// FP (trained) weights, cached.
    pub fn weights(&self, model: &str) -> Result<Weights> {
        let mut cache = self.weights.lock().unwrap();
        if let Some(w) = cache.get(model) {
            return Ok(w.clone());
        }
        let entry = self.man.model(model)?;
        let w = Weights::load(&self.man.dir.join(&entry.weights_file),
                              &entry.config)?;
        cache.insert(model.to_string(), w.clone());
        Ok(w)
    }

    /// Untrained init weights (LieQ), cached.
    pub fn init_weights(&self, model: &str) -> Result<Weights> {
        let mut cache = self.init_weights.lock().unwrap();
        if let Some(w) = cache.get(model) {
            return Ok(w.clone());
        }
        let entry = self.man.model(model)?;
        let w = Weights::load(
            &self.man.dir.join(&entry.init_weights_file), &entry.config)?;
        cache.insert(model.to_string(), w.clone());
        Ok(w)
    }

    /// Calibration activations + grads (probe/grad artifacts), cached.
    pub fn calibration(&self, model: &str)
        -> Result<std::sync::Arc<calib::Calibration>> {
        {
            let cache = self.calib.lock().unwrap();
            if let Some(c) = cache.get(model) {
                return Ok(c.clone());
            }
        }
        let entry = self.man.model(model)?;
        let w = self.weights(model)?;
        let corpora = crate::eval::ppl::load_corpora(&self.man)?;
        let t0 = Instant::now();
        let c = calib::collect(self.exec(), &self.man, entry, &w,
                               &corpora.train, CALIB_BATCHES)?;
        eprintln!("[calib] {model}: {} batches in {:.2}s (loss {:.3})",
                  CALIB_BATCHES, t0.elapsed().as_secs_f64(), c.loss);
        let arc = std::sync::Arc::new(c);
        self.calib.lock().unwrap().insert(model.to_string(), arc.clone());
        Ok(arc)
    }

    /// GPTQ Hessians, cached per model.
    pub fn hessians(&self, model: &str)
        -> Result<std::sync::Arc<HessianMap>> {
        {
            let cache = self.hessians.lock().unwrap();
            if let Some(h) = cache.get(model) {
                return Ok(h.clone());
            }
        }
        let entry = self.man.model(model)?;
        let c = self.calibration(model)?;
        let h = std::sync::Arc::new(c.hessians(entry.config.n_layers));
        self.hessians.lock().unwrap().insert(model.to_string(), h.clone());
        Ok(h)
    }

    /// Layer sensitivity scores for a method, cached per (method, model).
    pub fn scores(&self, method: Method, model: &str) -> Result<Vec<f64>> {
        let key = (method.label().to_string(), model.to_string());
        {
            let cache = self.scores.lock().unwrap();
            if let Some(s) = cache.get(&key) {
                return Ok(s.clone());
            }
        }
        let entry = self.man.model(model)?;
        let w = self.weights(model)?;
        let calib = if method.needs_calibration() {
            Some(self.calibration(model)?)
        } else {
            None
        };
        // Central capability guard: a clean error beats the panic the
        // scorer would otherwise hit on grad-less executors.
        if matches!(method, Method::LlmMq)
            && calib.as_ref().is_some_and(|c| c.grads.is_none())
        {
            anyhow::bail!(
                "LLM-MQ needs loss gradients, which the {} executor \
                 does not collect (build with --features xla)",
                self.exec().platform());
        }
        let init = if matches!(method, Method::LieQ) {
            Some(self.init_weights(model)?)
        } else {
            None
        };
        let t0 = Instant::now();
        let s = baselines::layer_scores(
            method, &entry.config, &w, calib.as_deref(), init.as_ref(),
            self.workers);
        eprintln!("[score] {} on {model}: {:.2}s", method.label(),
                  t0.elapsed().as_secs_f64());
        self.scores.lock().unwrap().insert(key, s.clone());
        Ok(s)
    }

    /// Bit allocation for (method, model, budget).
    pub fn allocate(&self, method: Method, model: &str, budget: f64)
        -> Result<Vec<u8>> {
        let entry = self.man.model(model)?;
        if method == Method::KurtBoost {
            // KurtBoost's outlier-priority rule needs the raw pieces.
            let w = self.weights(model)?;
            return Ok(baselines::allocate(
                method, &entry.config, &w, None, None, budget,
                self.workers));
        }
        let scores = self.scores(method, model)?;
        Ok(crate::allocate::allocate_bits(&scores, budget))
    }

    /// Shared quantization inputs: model entry, FP weights, and (for
    /// GPTQ only) the calibration Hessians.
    fn quant_inputs(&self, model: &str, backend: Backend)
        -> Result<(&ModelEntry, Weights,
                   Option<std::sync::Arc<HessianMap>>)> {
        let entry = self.man.model(model)?;
        let w = self.weights(model)?;
        let hess = if backend == Backend::Gptq {
            Some(self.hessians(model)?)
        } else {
            None
        };
        Ok((entry, w, hess))
    }

    /// Quantize the model at an allocation with a backend.
    pub fn quantize(&self, model: &str, bits: &[u8], backend: Backend)
        -> Result<Weights> {
        let (entry, w, hess) = self.quant_inputs(model, backend)?;
        Ok(crate::quant::quantize_model(
            &entry.config, &w, bits, DEFAULT_GROUP, backend,
            hess.as_deref(), self.workers))
    }

    /// Quantize into the packed serving format (fused dequant-matmul
    /// path of the native executor; see `infer::QuantizedModel`).
    pub fn quantize_packed(&self, model: &str, bits: &[u8],
                           backend: Backend) -> Result<QuantizedModel> {
        let (entry, w, hess) = self.quant_inputs(model, backend)?;
        Ok(QuantizedModel::quantize(
            &entry.config, &w, bits, DEFAULT_GROUP, backend,
            hess.as_deref(), self.workers))
    }

    /// Evaluate a weight variant (PPL + all tasks) through the executor.
    pub fn eval(&self, model: &str, weights: &Weights, opts: &EvalOptions)
        -> Result<EvalResult> {
        let entry = self.man.model(model)?;
        evaluate(self.exec(), &self.man, entry, weights, opts)
    }

    /// FP16-reference evaluation, cached (every table reports it).
    pub fn eval_fp(&self, model: &str, opts: &EvalOptions)
        -> Result<EvalResult> {
        {
            let cache = self.fp_eval.lock().unwrap();
            if let Some(r) = cache.get(model) {
                return Ok(r.clone());
            }
        }
        let w = self.weights(model)?;
        let r = self.eval(model, &w, opts)?;
        self.fp_eval.lock().unwrap().insert(model.to_string(), r.clone());
        Ok(r)
    }

    /// One full experimental run: method → allocation → quantize → eval.
    pub fn run(&self, method: Method, model: &str, budget: f64,
               backend: Backend, opts: &EvalOptions) -> Result<RunResult> {
        let t0 = Instant::now();
        let bits = self.allocate(method, model, budget)?;
        let qw = self.quantize(model, &bits, backend)?;
        let t_quant = t0.elapsed().as_secs_f64();
        let eval = self.eval(model, &qw, opts)?;
        eprintln!(
            "[run] {} {model} b̄={budget} {}: quant {:.1}s eval {:.1}s \
             avg-acc {:.2} avg-ppl {:.3}",
            method.label(), backend.label(), t_quant,
            t0.elapsed().as_secs_f64() - t_quant, eval.avg_acc(),
            eval.avg_ppl());
        Ok(RunResult { bits, eval })
    }

    /// SliM-LLM run (group-wise, no layer ranking).
    pub fn run_slim(&self, model: &str, budget: f64, opts: &EvalOptions)
        -> Result<RunResult> {
        let entry = self.man.model(model)?;
        let w = self.weights(model)?;
        let c = self.calibration(model)?;
        let qw = crate::baselines::slimllm::quantize_model(
            &entry.config, &w, &c, budget, DEFAULT_GROUP);
        let eval = self.eval(model, &qw, opts)?;
        Ok(RunResult { bits: vec![], eval })
    }

    /// NSDS ablation helper.
    pub fn nsds(ablation: Ablation) -> Method {
        Method::Nsds(ablation)
    }
}

#[derive(Clone, Debug)]
pub struct RunResult {
    pub bits: Vec<u8>,
    pub eval: EvalResult,
}
