//! HTTP/JSON + SSE facade over the serving coordinator — the piece
//! that turns the library into a service, with ZERO new dependencies
//! (std `TcpListener`, `util::json`; serde/hyper are unreachable
//! offline, see DESIGN.md "Environment deviations").
//!
//! Endpoints:
//!
//! | route               | method | reply                               |
//! |---------------------|--------|-------------------------------------|
//! | `/v1/generate`      | POST   | `text/event-stream`, one SSE frame  |
//! |                     |        | per committed token, terminated by  |
//! |                     |        | a `done` (or `error`) frame         |
//! | `/metrics`          | GET    | `telemetry::snapshot_to_json` of    |
//! |                     |        | the queue's registry                |
//! | `/healthz`          | GET    | `200 ok`                            |
//!
//! The generate response streams with `Connection: close` and no
//! Content-Length — each token flushes as its own SSE frame the moment
//! the scheduler commits it, so time-to-first-byte tracks the engine's
//! TTFT instead of the full generation. Client disconnect is wired to
//! the cancel path end to end: a failed frame write drops the
//! request's `GenEvents` receiver, whose `Drop` clears the stream's
//! liveness flag, and the serve scheduler retires the KV slot (target
//! and drafter pools both) at the end of the step that notices — a
//! dead curl frees its decode slot within one step instead of decoding
//! to completion.
//!
//! One OS thread per connection, plus one accept thread. That is the
//! right shape here: concurrency is bounded by the engine's KV slots
//! and the bounded `ServerQueue` (backpressure blocks the connection
//! thread, not the serve loop), so connection count stays small and an
//! async runtime would buy nothing for the cost of a dependency.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::server::{Client, ServerQueue};
use crate::infer::{GenConfig, GenEvent, Sampling, SpecDecode,
                   StopReason};
use crate::telemetry::snapshot_to_json;
use crate::util::json::Json;

/// Largest accepted `POST /v1/generate` body. Prompts are token-id
/// arrays (~8 bytes/token as text), so this bounds prompts around
/// 100k tokens — far past any KV capacity — while keeping a hostile
/// Content-Length from allocating unbounded memory.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// One parsed SSE frame: `(event name, data payload)`.
pub type SseFrame = (String, Json);

/// Serialize one generation event as an SSE frame (`event:` +
/// `data:` + blank line). Inverse of `parse_sse` (round-trip pinned
/// by `rust/tests/http_serve.rs`).
pub fn sse_frame(ev: &GenEvent) -> String {
    let (name, data) = event_to_json(ev);
    format!("event: {name}\ndata: {data}\n\n")
}

/// `(event name, JSON payload)` for one generation event — the wire
/// schema of the `/v1/generate` stream.
pub fn event_to_json(ev: &GenEvent) -> (&'static str, Json) {
    fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect())
    }
    match ev {
        GenEvent::Token { token, pos } => ("token", obj(vec![
            ("token", Json::Num(*token as f64)),
            ("pos", Json::Num(*pos as f64)),
        ])),
        GenEvent::Done(g) => {
            let tokens = Json::Arr(
                g.tokens.iter().map(|t| Json::Num(*t as f64)).collect());
            let stopped = match g.stopped {
                StopReason::MaxNew => Json::Str("max_new".into()),
                StopReason::StopToken(t) => {
                    Json::Str(format!("stop_token:{t}"))
                }
            };
            ("done", obj(vec![
                ("tokens", tokens),
                ("stopped", stopped),
                ("prompt_tokens",
                 Json::Num(g.stats.prompt_tokens as f64)),
                ("gen_tokens", Json::Num(g.stats.gen_tokens as f64)),
                ("prefill_ns", Json::Num(g.stats.prefill_ns as f64)),
                ("ttft_ns", Json::Num(g.stats.ttft_ns as f64)),
                ("decode_ns", Json::Num(g.stats.decode_ns as f64)),
            ]))
        }
        GenEvent::Failed(e) => ("error", obj(vec![
            ("error", Json::Str(e.clone())),
        ])),
    }
}

/// Parse a concatenation of SSE frames back into `(event, data)`
/// pairs. Tolerates the frame subset `sse_frame` emits (single-line
/// `data:`), which is all this server ever sends.
pub fn parse_sse(stream: &str) -> Result<Vec<SseFrame>, String> {
    let mut out = Vec::new();
    for frame in stream.split("\n\n").filter(|f| !f.trim().is_empty()) {
        let mut name = None;
        let mut data = None;
        for line in frame.lines() {
            if let Some(v) = line.strip_prefix("event: ") {
                name = Some(v.to_string());
            } else if let Some(v) = line.strip_prefix("data: ") {
                data = Some(Json::parse(v)?);
            } else {
                return Err(format!("unexpected SSE line: {line:?}"));
            }
        }
        match (name, data) {
            (Some(n), Some(d)) => out.push((n, d)),
            _ => return Err(format!("incomplete SSE frame: {frame:?}")),
        }
    }
    Ok(out)
}

/// Parse a `POST /v1/generate` JSON body into (prompt, config).
///
/// Schema: `prompt` (required, array of token ids); optional
/// `max_new`, `seed`, `stop` (array of token ids), `spec_k` (enables
/// speculative decoding), and `temperature`/`top_k` (either one
/// switches sampling from greedy to top-k; the other defaults to
/// `top_k=40` / `temperature=1.0`).
pub fn parse_gen_request(j: &Json)
    -> Result<(Vec<i32>, GenConfig), String> {
    let prompt = j.get("prompt").and_then(Json::as_arr).ok_or(
        "missing required field \"prompt\" (array of token ids)")?;
    let mut tokens = Vec::with_capacity(prompt.len());
    for t in prompt {
        tokens.push(t.as_f64()
            .ok_or("\"prompt\" entries must be numbers")? as i32);
    }
    let num = |key: &str| -> Result<Option<f64>, String> {
        match j.get(key) {
            None => Ok(None),
            Some(v) => v.as_f64().map(Some)
                .ok_or(format!("\"{key}\" must be a number")),
        }
    };
    let mut cfg = GenConfig::default();
    if let Some(n) = num("max_new")? {
        cfg.max_new = n as usize;
    }
    if let Some(n) = num("seed")? {
        cfg.seed = n as u64;
    }
    if let Some(stop) = j.get("stop") {
        let arr = stop.as_arr()
            .ok_or("\"stop\" must be an array of token ids")?;
        cfg.stop = arr.iter()
            .map(|t| t.as_f64().map(|n| n as i32)
                .ok_or("\"stop\" entries must be numbers".to_string()))
            .collect::<Result<_, _>>()?;
    }
    let temperature = num("temperature")?;
    let top_k = num("top_k")?;
    if temperature.is_some() || top_k.is_some() {
        cfg.sampling = Sampling::TopK {
            k: top_k.map(|k| k as usize).unwrap_or(40),
            temperature: temperature.unwrap_or(1.0) as f32,
        };
    }
    if let Some(k) = num("spec_k")? {
        cfg.spec = Some(SpecDecode { k: (k as usize).max(1) });
    }
    Ok((tokens, cfg))
}

/// The running HTTP front end: an accept-loop thread plus one thread
/// per live connection, all speaking to the serve loop through a
/// cloned `Client`. `shutdown` (or drop) stops accepting; streams in
/// flight finish or cancel on their own disconnects.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving requests against `client`/`queue`. The serve
    /// loop itself must be running on its own thread (`serve` /
    /// `serve_with_drafter`) for generations to make progress.
    pub fn bind(addr: &str, client: Client, queue: Arc<ServerQueue>)
        -> Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let accept = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if flag.load(Ordering::Acquire) {
                    break;
                }
                let Ok(conn) = conn else { continue };
                let client = client.clone();
                let queue = queue.clone();
                std::thread::spawn(move || {
                    // Connection errors (reset, parse failure) only
                    // affect this connection; cancellation of any
                    // in-flight generation rides the GenEvents drop.
                    let _ = handle_conn(conn, &client, &queue);
                });
            }
        });
        Ok(HttpServer { addr, stop, accept: Some(accept) })
    }

    /// The bound address (port resolved when binding `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting connections and join the accept thread.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Release);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Read one HTTP/1.1 request: `(method, path, body)`. Only what this
/// server needs — no chunked bodies, no keep-alive (every response
/// closes the connection).
fn read_request(reader: &mut BufReader<TcpStream>)
    -> std::io::Result<(String, String, String)> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            break;
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h
            .to_ascii_lowercase()
            .strip_prefix("content-length:")
            .map(str::trim)
        {
            content_len = v.parse().unwrap_or(0);
        }
    }
    if content_len > MAX_BODY_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData, "body too large"));
    }
    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body)?;
    let body = String::from_utf8(body).map_err(|_| {
        std::io::Error::new(std::io::ErrorKind::InvalidData,
                            "body not utf-8")
    })?;
    Ok((method, path, body))
}

fn respond(s: &mut TcpStream, status: &str, ctype: &str, body: &str)
    -> std::io::Result<()> {
    write!(
        s,
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len())?;
    s.flush()
}

fn respond_error(s: &mut TcpStream, status: &str, msg: &str)
    -> std::io::Result<()> {
    let body = Json::Obj(
        [("error".to_string(), Json::Str(msg.to_string()))]
            .into_iter()
            .collect());
    respond(s, status, "application/json", &body.to_string())
}

fn handle_conn(stream: TcpStream, client: &Client,
               queue: &Arc<ServerQueue>) -> std::io::Result<()> {
    // A stalled or hostile client must not pin the reader thread
    // forever; streaming writes below clear the limit implicitly by
    // failing, which cancels the generation.
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let (method, path, body) = read_request(&mut reader)?;
    match (method.as_str(), path.as_str()) {
        ("GET", "/healthz") => {
            respond(&mut stream, "200 OK", "text/plain", "ok\n")
        }
        ("GET", "/metrics") => {
            let snap = queue.metrics().snapshot();
            respond(&mut stream, "200 OK", "application/json",
                    &snapshot_to_json(&snap).to_string())
        }
        ("POST", "/v1/generate") => {
            let parsed = Json::parse(&body)
                .and_then(|j| parse_gen_request(&j));
            let (prompt, cfg) = match parsed {
                Ok(p) => p,
                Err(e) => {
                    return respond_error(&mut stream,
                                         "400 Bad Request", &e);
                }
            };
            // Backpressure blocks HERE (this connection's thread),
            // never the serve loop.
            let events = match client.generate_streaming(prompt, cfg) {
                Ok(ev) => ev,
                Err(e) => {
                    return respond_error(
                        &mut stream, "503 Service Unavailable",
                        &format!("{e:#}"));
                }
            };
            write!(
                stream,
                "HTTP/1.1 200 OK\r\n\
                 Content-Type: text/event-stream\r\n\
                 Cache-Control: no-cache\r\n\
                 Connection: close\r\n\r\n")?;
            stream.flush()?;
            for ev in events {
                let terminal = matches!(
                    ev, GenEvent::Done(_) | GenEvent::Failed(_));
                let frame = sse_frame(&ev);
                if stream
                    .write_all(frame.as_bytes())
                    .and_then(|_| stream.flush())
                    .is_err()
                {
                    // Receiver gone: breaking drops `events`, whose
                    // Drop clears the liveness flag — the scheduler
                    // cancels the request and frees its KV slot at
                    // the end of the step that notices.
                    break;
                }
                if terminal {
                    break;
                }
            }
            Ok(())
        }
        _ => respond_error(&mut stream, "404 Not Found",
                           &format!("no route for {method} {path}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::{GenStats, Generation};

    #[test]
    fn sse_round_trips_every_event_kind() {
        let evs = vec![
            GenEvent::Token { token: 42, pos: 0 },
            GenEvent::Token { token: -1, pos: 1 },
            GenEvent::Done(Generation {
                tokens: vec![42, -1],
                stats: GenStats {
                    prompt_tokens: 3,
                    gen_tokens: 2,
                    prefill_ns: 123,
                    ttft_ns: 456,
                    decode_ns: 789,
                },
                stopped: StopReason::StopToken(-1),
            }),
            GenEvent::Failed("bad prompt: \"x\"\nline2".into()),
        ];
        let wire: String = evs.iter().map(sse_frame).collect();
        let frames = parse_sse(&wire).unwrap();
        assert_eq!(frames.len(), 4);
        assert_eq!(frames[0].0, "token");
        assert_eq!(frames[0].1.get("token").unwrap().as_f64(),
                   Some(42.0));
        assert_eq!(frames[1].1.get("token").unwrap().as_f64(),
                   Some(-1.0));
        assert_eq!(frames[1].1.get("pos").unwrap().as_usize(), Some(1));
        assert_eq!(frames[2].0, "done");
        assert_eq!(frames[2].1.get("stopped").unwrap().as_str(),
                   Some("stop_token:-1"));
        assert_eq!(
            frames[2].1.get("tokens").unwrap().idx(1).unwrap().as_f64(),
            Some(-1.0));
        assert_eq!(frames[2].1.get("decode_ns").unwrap().as_f64(),
                   Some(789.0));
        assert_eq!(frames[3].0, "error");
        // Newline inside the error must survive JSON escaping — an
        // unescaped newline would split the data: line and break SSE.
        assert_eq!(frames[3].1.get("error").unwrap().as_str(),
                   Some("bad prompt: \"x\"\nline2"));
    }

    #[test]
    fn gen_request_parses_full_schema() {
        let j = Json::parse(
            r#"{"prompt": [1, 2, 3], "max_new": 7, "seed": 9,
                "temperature": 0.5, "top_k": 3, "stop": [0],
                "spec_k": 4}"#).unwrap();
        let (prompt, cfg) = parse_gen_request(&j).unwrap();
        assert_eq!(prompt, vec![1, 2, 3]);
        assert_eq!(cfg.max_new, 7);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.stop, vec![0]);
        assert_eq!(cfg.sampling,
                   Sampling::TopK { k: 3, temperature: 0.5 });
        assert_eq!(cfg.spec, Some(SpecDecode { k: 4 }));
    }

    #[test]
    fn gen_request_defaults_and_greedy() {
        let j = Json::parse(r#"{"prompt": [5]}"#).unwrap();
        let (prompt, cfg) = parse_gen_request(&j).unwrap();
        assert_eq!(prompt, vec![5]);
        assert_eq!(cfg.sampling, Sampling::Greedy);
        assert_eq!(cfg.spec, None);
        let d = GenConfig::default();
        assert_eq!(cfg.max_new, d.max_new);
        assert_eq!(cfg.seed, d.seed);
    }

    #[test]
    fn gen_request_rejects_bad_shapes() {
        for bad in [
            r#"{}"#,
            r#"{"prompt": 3}"#,
            r#"{"prompt": ["a"]}"#,
            r#"{"prompt": [1], "max_new": "x"}"#,
            r#"{"prompt": [1], "stop": 0}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(parse_gen_request(&j).is_err(), "accepted: {bad}");
        }
    }
}
