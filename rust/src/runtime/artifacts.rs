//! Artifact registry: parses `artifacts/manifest.json` into typed entries
//! (model configs, file names, task metadata, corpus info).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::model::ModelConfig;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub config: ModelConfig,
    pub params: usize,
    pub weights_file: String,
    pub init_weights_file: String,
    pub hlo_fwd: String,
    pub hlo_probe: String,
    pub hlo_grad: String,
    /// (step, loss) pairs from build-time training.
    pub train_log: Vec<(usize, f64)>,
    /// Per-layer KV-cache storage widths (4/8/16 bits per element),
    /// typically from `allocate::allocate_kv_bits` over NSDS layer
    /// scores. `None` (and the manifest default) means all-f32 KV —
    /// the bit-identical compatibility mode.
    pub kv_bits: Option<Vec<u8>>,
}

impl ModelEntry {
    /// Entry for a synthetic (artifact-less) model served by the native
    /// executor: config-only, no weight files or HLO artifacts.
    pub fn synthetic(config: ModelConfig) -> Self {
        let name = config.name.clone();
        let params = config.param_count();
        ModelEntry {
            name,
            config,
            params,
            weights_file: String::new(),
            init_weights_file: String::new(),
            hlo_fwd: String::new(),
            hlo_probe: String::new(),
            hlo_grad: String::new(),
            train_log: Vec::new(),
            kv_bits: None,
        }
    }

    /// Same entry with a per-layer KV bit-width plan attached; engines
    /// built from this entry store K/V pages at these widths.
    pub fn with_kv_bits(mut self, kv_bits: Vec<u8>) -> Self {
        assert_eq!(
            kv_bits.len(),
            self.config.n_layers,
            "kv_bits length must match n_layers"
        );
        self.kv_bits = Some(kv_bits);
        self
    }
}

#[derive(Clone, Debug)]
pub struct TaskMeta {
    pub name: String,
    pub k: usize,
    pub n: usize,
}

#[derive(Clone, Debug)]
pub struct KernelEntry {
    pub file: String,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub group: usize,
    pub bits: u8,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub eval_batch: usize,
    pub models: Vec<ModelEntry>,
    pub tasks_file: String,
    pub tasks: Vec<TaskMeta>,
    pub corpus_file: String,
    pub kernels: Vec<KernelEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read {dir:?}/manifest.json — run \
                                      `make artifacts` first"))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let eval_batch = j
            .get("eval_batch")
            .and_then(Json::as_usize)
            .context("eval_batch")?;

        let mut models = Vec::new();
        for (name, m) in j.get("models").and_then(Json::as_obj)
            .context("models")? {
            let config = ModelConfig::from_json(
                name,
                m.get("config").context("config")?,
            )?;
            let gs = |k: &str| -> Result<String> {
                Ok(m.path(&["hlo", k])
                    .and_then(Json::as_str)
                    .with_context(|| format!("hlo.{k}"))?
                    .to_string())
            };
            let train_log = m
                .get("train_log")
                .and_then(Json::as_arr)
                .unwrap_or(&[])
                .iter()
                .filter_map(|p| {
                    Some((
                        p.idx(0)?.as_usize()?,
                        p.idx(1)?.as_f64()?,
                    ))
                })
                .collect();
            models.push(ModelEntry {
                name: name.clone(),
                config,
                params: m.get("params").and_then(Json::as_usize)
                    .unwrap_or(0),
                weights_file: m
                    .get("weights")
                    .and_then(Json::as_str)
                    .context("weights")?
                    .to_string(),
                init_weights_file: m
                    .get("init_weights")
                    .and_then(Json::as_str)
                    .context("init_weights")?
                    .to_string(),
                hlo_fwd: gs("fwd")?,
                hlo_probe: gs("probe")?,
                hlo_grad: gs("grad")?,
                train_log,
                kv_bits: m
                    .get("kv_bits")
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter()
                            .filter_map(|b| Some(b.as_usize()? as u8))
                            .collect()
                    }),
            });
        }

        let tasks = j
            .path(&["tasks", "list"])
            .and_then(Json::as_arr)
            .context("tasks.list")?
            .iter()
            .map(|t| {
                Ok(TaskMeta {
                    name: t
                        .get("name")
                        .and_then(Json::as_str)
                        .context("task name")?
                        .to_string(),
                    k: t.get("k").and_then(Json::as_usize).context("k")?,
                    n: t.get("n").and_then(Json::as_usize).context("n")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let kernels = j
            .get("kernels")
            .and_then(Json::as_obj)
            .map(|m| {
                m.values()
                    .filter_map(|k| {
                        Some(KernelEntry {
                            file: k.get("file")?.as_str()?.to_string(),
                            m: k.get("m").and_then(Json::as_usize)
                                .unwrap_or(0),
                            k: k.get("k")?.as_usize()?,
                            n: k.get("n")?.as_usize()?,
                            group: k.get("group")?.as_usize()?,
                            bits: k.get("bits")?.as_usize()? as u8,
                        })
                    })
                    .collect()
            })
            .unwrap_or_default();

        Ok(Manifest {
            dir: dir.to_path_buf(),
            eval_batch,
            models,
            tasks_file: j
                .path(&["tasks", "file"])
                .and_then(Json::as_str)
                .context("tasks.file")?
                .to_string(),
            tasks,
            corpus_file: j
                .path(&["corpus", "file"])
                .and_then(Json::as_str)
                .context("corpus.file")?
                .to_string(),
            kernels,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .with_context(|| {
                let have: Vec<&str> =
                    self.models.iter().map(|m| m.name.as_str()).collect();
                format!("model '{name}' not in manifest (have {have:?})")
            })
    }

    /// Default artifacts dir: $NSDS_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("NSDS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Parses the real manifest when artifacts exist (skips otherwise so
    /// `cargo test` works pre-`make artifacts`).
    #[test]
    fn parses_real_manifest_if_present() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.eval_batch > 0);
        assert!(!m.models.is_empty());
        for e in &m.models {
            assert!(e.config.n_layers > 0);
            assert!(dir.join(&e.hlo_fwd).exists());
            assert!(dir.join(&e.weights_file).exists());
        }
        assert_eq!(m.tasks.len(), 6);
    }
}
