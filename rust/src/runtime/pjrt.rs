//! PJRT engine: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path.
//! Python never runs here — the binary is self-contained once
//! `make artifacts` has been built.
//!
//! Design: one `Engine` per process (owns the PJRT CPU client), one
//! compiled `Executable` per artifact, cached by name. Implements
//! `infer::Executor` (forward / probe / grads) over the fwd / probe /
//! grad executables of each model entry.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::artifacts::ModelEntry;
use crate::infer::{Executor, Probes};
use crate::model::Weights;
use crate::tensor::Tensor;

/// Process-wide PJRT engine + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Engine {
    /// Create a CPU engine rooted at the artifacts directory.
    pub fn cpu(artifacts_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt client: {e:?}"))?;
        Ok(Engine {
            client,
            dir: artifacts_dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Load + compile an HLO-text artifact (cached by file name).
    pub fn load(&self, file: &str) -> Result<()> {
        let mut cache = self.cache.lock().unwrap();
        if cache.contains_key(file) {
            return Ok(());
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {file}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {file}: {e:?}"))?;
        cache.insert(file.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with the given inputs. Outputs are the elements
    /// of the module's result tuple (aot.py lowers with return_tuple=True).
    ///
    /// Inputs go through explicit `PjRtBuffer`s + `execute_b` rather than
    /// the crate's literal-taking `execute`: the latter leaks its
    /// internally-created device buffers (~input-bytes per call, OOM after
    /// a few thousand batches — see EXPERIMENTS.md §Perf).
    pub fn execute(&self, file: &str, inputs: &[Input]) -> Result<Vec<Tensor>> {
        self.load(file)?;
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(file).unwrap();
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|i| i.to_buffer(&self.client))
            .collect::<Result<_>>()?;
        let out = exe
            .execute_b::<xla::PjRtBuffer>(&bufs)
            .map_err(|e| anyhow::anyhow!("execute {file}: {e:?}"))?;
        let result = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {file}: {e:?}"))?;
        let tuple = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {file}: {e:?}"))?;
        tuple
            .into_iter()
            .map(literal_to_tensor)
            .collect::<Result<Vec<_>>>()
    }

    /// tokens + ordered weights, the input convention of every model
    /// executable.
    fn model_inputs<'a>(&self, tokens: &'a [i32], batch: usize,
                        seq: usize, ordered: &'a [&'a Tensor])
                        -> Vec<Input<'a>> {
        let mut inputs: Vec<Input> = Vec::with_capacity(13);
        inputs.push(Input::I32(tokens, vec![batch, seq]));
        for t in ordered {
            inputs.push(Input::F32(t));
        }
        inputs
    }
}

impl Executor for Engine {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn forward(&self, entry: &ModelEntry, tokens: &[i32], batch: usize,
               weights: &Weights) -> Result<Tensor> {
        let seq = entry.config.seq;
        anyhow::ensure!(tokens.len() == batch * seq,
                        "tokens {} != batch {batch} x seq {seq}",
                        tokens.len());
        let ordered = weights.ordered();
        let inputs = self.model_inputs(tokens, batch, seq, &ordered);
        let mut out = self.execute(&entry.hlo_fwd, &inputs)?;
        Ok(out.remove(0))
    }

    fn probe(&self, entry: &ModelEntry, tokens: &[i32], batch: usize,
             weights: &Weights) -> Result<Probes> {
        let seq = entry.config.seq;
        anyhow::ensure!(tokens.len() == batch * seq,
                        "tokens {} != batch {batch} x seq {seq}",
                        tokens.len());
        let ordered = weights.ordered();
        let inputs = self.model_inputs(tokens, batch, seq, &ordered);
        let out = self.execute(&entry.hlo_probe, &inputs)?;
        // (logits, resid_in [L,B,S,D], final_resid, x_ln1, x_ln2,
        //  attn_ctx, ffn_mid)
        let rows = batch * seq;
        let d = entry.config.d_model;
        Ok(Probes {
            logits: out[0].clone(),
            resid_in: split_layers(&out[1]),
            final_resid: out[2].clone().reshape(vec![rows, d]),
            x_ln1: split_layers(&out[3]),
            x_ln2: split_layers(&out[4]),
            attn_ctx: split_layers(&out[5]),
            ffn_mid: split_layers(&out[6]),
        })
    }

    fn supports_grads(&self) -> bool {
        true
    }

    fn grads(&self, entry: &ModelEntry, tokens: &[i32], batch: usize,
             weights: &Weights)
             -> Result<std::collections::BTreeMap<String, Tensor>> {
        let seq = entry.config.seq;
        let ordered = weights.ordered();
        let inputs = self.model_inputs(tokens, batch, seq, &ordered);
        let gout = self.execute(&entry.hlo_grad, &inputs)?;
        let mut grads = std::collections::BTreeMap::new();
        for (i, name) in crate::model::QUANT_WEIGHTS.iter().enumerate() {
            grads.insert(name.to_string(), gout[i + 1].clone());
        }
        Ok(grads)
    }
}

/// Reorder a probe output [L, B, S, X] into per-layer [B·S, X] tensors.
fn split_layers(t: &Tensor) -> Vec<Tensor> {
    let l = t.dims()[0];
    let rows = t.dims()[1] * t.dims()[2];
    let x = t.dims()[3];
    (0..l)
        .map(|li| t.slice0(li).reshape(vec![rows, x]))
        .collect()
}

/// A runtime input: f32 tensor, i32 tokens, or u8 packed codes.
pub enum Input<'a> {
    F32(&'a Tensor),
    I32(&'a [i32], Vec<usize>),
    U8(&'a [u8], Vec<usize>),
}

impl Input<'_> {
    fn to_buffer(&self, client: &xla::PjRtClient)
        -> Result<xla::PjRtBuffer> {
        match self {
            Input::F32(t) => client
                .buffer_from_host_buffer(t.data(), t.dims(), None)
                .map_err(|e| anyhow::anyhow!("f32 buffer: {e:?}")),
            Input::I32(data, dims) => client
                .buffer_from_host_buffer(data, dims, None)
                .map_err(|e| anyhow::anyhow!("i32 buffer: {e:?}")),
            Input::U8(data, dims) => client
                .buffer_from_host_buffer(data, dims, None)
                .map_err(|e| anyhow::anyhow!("u8 buffer: {e:?}")),
        }
    }
}

fn literal_to_tensor(lit: xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data: Vec<f32> = match shape.ty() {
        xla::ElementType::F32 => lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))?,
        xla::ElementType::S32 => lit
            .to_vec::<i32>()
            .map_err(|e| anyhow::anyhow!("to_vec i32: {e:?}"))?
            .into_iter()
            .map(|x| x as f32)
            .collect(),
        xla::ElementType::U8 => lit
            .to_vec::<u8>()
            .map_err(|e| anyhow::anyhow!("to_vec u8: {e:?}"))?
            .into_iter()
            .map(|x| x as f32)
            .collect(),
        other => anyhow::bail!("unsupported output dtype {other:?}"),
    };
    Ok(Tensor::new(data, dims))
}

#[cfg(test)]
mod tests {
    //! Integration tests live in rust/tests/ (they need artifacts); here we
    //! only check engine construction degrades gracefully.
    use super::*;

    #[test]
    fn engine_builds_on_cpu() {
        let e = Engine::cpu(Path::new("/nonexistent")).unwrap();
        assert_eq!(e.platform(), "cpu");
        assert!(e.load("missing.hlo.txt").is_err());
    }
}
