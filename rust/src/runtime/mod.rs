//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path.
//! Python never runs here — the binary is self-contained once
//! `make artifacts` has been built.
//!
//! Design: one `Engine` per process (owns the PJRT CPU client), one
//! compiled `Executable` per artifact, cached by name. Model weights are
//! *runtime inputs* of every model executable, so a single compiled
//! forward serves every quantized weight variant the coordinator produces
//! (the weight-swappable-executor pattern; see DESIGN.md).

pub mod artifacts;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::tensor::Tensor;

pub use artifacts::{Manifest, ModelEntry};

/// Process-wide PJRT engine + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Engine {
    /// Create a CPU engine rooted at the artifacts directory.
    pub fn cpu(artifacts_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt client: {e:?}"))?;
        Ok(Engine {
            client,
            dir: artifacts_dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Load + compile an HLO-text artifact (cached by file name).
    pub fn load(&self, file: &str) -> Result<()> {
        let mut cache = self.cache.lock().unwrap();
        if cache.contains_key(file) {
            return Ok(());
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {file}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {file}: {e:?}"))?;
        cache.insert(file.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with the given inputs. Outputs are the elements
    /// of the module's result tuple (aot.py lowers with return_tuple=True).
    ///
    /// Inputs go through explicit `PjRtBuffer`s + `execute_b` rather than
    /// the crate's literal-taking `execute`: the latter leaks its
    /// internally-created device buffers (~input-bytes per call, OOM after
    /// a few thousand batches — see EXPERIMENTS.md §Perf).
    pub fn execute(&self, file: &str, inputs: &[Input]) -> Result<Vec<Tensor>> {
        self.load(file)?;
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(file).unwrap();
        let bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|i| i.to_buffer(&self.client))
            .collect::<Result<_>>()?;
        let out = exe
            .execute_b::<xla::PjRtBuffer>(&bufs)
            .map_err(|e| anyhow::anyhow!("execute {file}: {e:?}"))?;
        let result = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {file}: {e:?}"))?;
        let tuple = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {file}: {e:?}"))?;
        tuple
            .into_iter()
            .map(literal_to_tensor)
            .collect::<Result<Vec<_>>>()
    }
}

/// A runtime input: f32 tensor, i32 tokens, or u8 packed codes.
pub enum Input<'a> {
    F32(&'a Tensor),
    I32(&'a [i32], Vec<usize>),
    U8(&'a [u8], Vec<usize>),
}

impl Input<'_> {
    fn to_buffer(&self, client: &xla::PjRtClient)
        -> Result<xla::PjRtBuffer> {
        match self {
            Input::F32(t) => client
                .buffer_from_host_buffer(t.data(), t.dims(), None)
                .map_err(|e| anyhow::anyhow!("f32 buffer: {e:?}")),
            Input::I32(data, dims) => client
                .buffer_from_host_buffer(data, dims, None)
                .map_err(|e| anyhow::anyhow!("i32 buffer: {e:?}")),
            Input::U8(data, dims) => client
                .buffer_from_host_buffer(data, dims, None)
                .map_err(|e| anyhow::anyhow!("u8 buffer: {e:?}")),
        }
    }
}

fn literal_to_tensor(lit: xla::Literal) -> Result<Tensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data: Vec<f32> = match shape.ty() {
        xla::ElementType::F32 => lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))?,
        xla::ElementType::S32 => lit
            .to_vec::<i32>()
            .map_err(|e| anyhow::anyhow!("to_vec i32: {e:?}"))?
            .into_iter()
            .map(|x| x as f32)
            .collect(),
        xla::ElementType::U8 => lit
            .to_vec::<u8>()
            .map_err(|e| anyhow::anyhow!("to_vec u8: {e:?}"))?
            .into_iter()
            .map(|x| x as f32)
            .collect(),
        other => anyhow::bail!("unsupported output dtype {other:?}"),
    };
    Ok(Tensor::new(data, dims))
}

/// Convenience: run a model forward (`fwd_<model>.hlo.txt`) on one token
/// batch with the given weight set. Returns logits [B, S, V].
pub fn run_forward(engine: &Engine, entry: &ModelEntry, tokens: &[i32],
                   batch: usize, weights: &crate::model::Weights)
                   -> Result<Tensor> {
    let seq = entry.config.seq;
    assert_eq!(tokens.len(), batch * seq);
    let mut inputs: Vec<Input> = Vec::with_capacity(13);
    inputs.push(Input::I32(tokens, vec![batch, seq]));
    let ordered = weights.ordered();
    for t in &ordered {
        inputs.push(Input::F32(t));
    }
    let mut out = engine.execute(&entry.hlo_fwd, &inputs)?;
    Ok(out.remove(0))
}

#[cfg(test)]
mod tests {
    //! Integration tests live in rust/tests/ (they need artifacts); here we
    //! only check engine construction degrades gracefully.
    use super::*;

    #[test]
    fn engine_builds_on_cpu() {
        let e = Engine::cpu(Path::new("/nonexistent")).unwrap();
        assert_eq!(e.platform(), "cpu");
        assert!(e.load("missing.hlo.txt").is_err());
    }
}
