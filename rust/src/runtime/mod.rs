//! Runtime layer: the artifact registry (always available) and the
//! PJRT/XLA executor over AOT HLO artifacts (behind the off-by-default
//! `xla` cargo feature — the bindings crate is not fetchable offline;
//! see DESIGN.md "Environment deviations").
//!
//! Execution itself is backend-agnostic: every hot path goes through
//! `infer::Executor`, implemented here by the PJRT `Engine` and by
//! `infer::NativeEngine` (the default). Model weights are *runtime
//! inputs* of every forward, so one engine serves every quantized
//! weight variant the coordinator produces (the weight-swappable
//! executor pattern; see DESIGN.md).

pub mod artifacts;

#[cfg(feature = "xla")]
pub mod pjrt;

use anyhow::Result;

use crate::infer::Executor;
use crate::model::Weights;
use crate::tensor::Tensor;

pub use artifacts::{Manifest, ModelEntry};

#[cfg(feature = "xla")]
pub use pjrt::{Engine, Input};

/// Convenience: run a model forward on one token batch with the given
/// weight set through any executor. Returns logits [B, S, V].
pub fn run_forward(exec: &dyn Executor, entry: &ModelEntry,
                   tokens: &[i32], batch: usize, weights: &Weights)
                   -> Result<Tensor> {
    exec.forward(entry, tokens, batch, weights)
}
