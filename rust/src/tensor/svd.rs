//! One-sided Jacobi SVD — the workhorse of the Structural-Expressiveness
//! metric (paper §2.2) and the LieQ baseline.
//!
//! Why Jacobi: no LAPACK offline; one-sided Jacobi is compact (~100 lines),
//! unconditionally stable, and delivers full U, σ, V to f32 accuracy in a
//! handful of sweeps for the ≤ a-few-hundred-dimension matrices this
//! project decomposes (components are d_model×d_model per head or
//! d_model×d_ffn). Cost is O(sweeps · m · n²) with n the smaller side —
//! profiled and optimized in EXPERIMENTS.md §Perf (it dominates scoring).

use super::Tensor;

/// Thin SVD: `a ≈ u · diag(sigma) · vᵀ`, singular values descending.
#[derive(Clone, Debug)]
pub struct Svd {
    /// [m, r] left singular vectors (columns).
    pub u: Tensor,
    /// r singular values, descending, f64.
    pub sigma: Vec<f64>,
    /// [n, r] right singular vectors (columns).
    pub v: Tensor,
}

impl Svd {
    /// Reconstruct `u · diag(sigma) · vᵀ` (tests / truncation).
    pub fn reconstruct(&self) -> Tensor {
        let m = self.u.rows();
        let n = self.v.rows();
        let r = self.sigma.len();
        let mut out = Tensor::zeros(vec![m, n]);
        for k in 0..r {
            let s = self.sigma[k] as f32;
            for i in 0..m {
                let uik = self.u.at(i, k) * s;
                if uik == 0.0 {
                    continue;
                }
                let row = out.row_mut(i);
                for (j, rv) in row.iter_mut().enumerate() {
                    *rv += uik * self.v.at(j, k);
                }
            }
        }
        out
    }

    /// Rank that cumulatively captures `frac` of the total energy (Σσ²) —
    /// the paper's Top-90 %-variance truncation (App. D.3). Keeps ≥ 1.
    pub fn energy_rank(&self, frac: f64) -> usize {
        let total: f64 = self.sigma.iter().map(|s| s * s).sum();
        if total <= 0.0 {
            return 1;
        }
        let mut acc = 0.0;
        for (i, s) in self.sigma.iter().enumerate() {
            acc += s * s;
            if acc >= frac * total {
                return i + 1;
            }
        }
        self.sigma.len()
    }

    /// Truncate to the leading `r` components.
    pub fn truncate(&self, r: usize) -> Svd {
        let r = r.clamp(1, self.sigma.len());
        Svd {
            u: self.u.cols_range(0, r),
            sigma: self.sigma[..r].to_vec(),
            v: self.v.cols_range(0, r),
        }
    }
}

/// One-sided Jacobi on A [m,n] with m ≥ n (internally transposes if not).
pub fn svd(a: &Tensor) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    if m < n {
        // Aᵀ = U Σ Vᵀ  =>  A = V Σ Uᵀ
        let s = svd(&a.transpose());
        return Svd { u: s.v, sigma: s.sigma, v: s.u };
    }
    // Work on column-major copies of A's columns for cache-friendly pair ops.
    let mut cols: Vec<Vec<f32>> = (0..n).map(|j| a.col(j)).collect();
    // V accumulator, column-major.
    let mut v: Vec<Vec<f32>> = (0..n)
        .map(|j| {
            let mut e = vec![0.0f32; n];
            e[j] = 1.0;
            e
        })
        .collect();

    // Convergence: a sweep that applies no rotation means every column
    // pair is orthogonal to within eps (relative) — done. The previous
    // absolute `off < 1e-12` criterion never fired on f32-scaled data and
    // forced all 60 sweeps (~8× slower; see EXPERIMENTS.md §Perf).
    let eps = 1e-7f64;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut rotations = 0usize;
        for i in 0..n {
            for j in (i + 1)..n {
                let (ci, cj) = split_two(&mut cols, i, j);
                let mut app = 0.0f64;
                let mut aqq = 0.0f64;
                let mut apq = 0.0f64;
                for (x, y) in ci.iter().zip(cj.iter()) {
                    app += (*x as f64) * (*x as f64);
                    aqq += (*y as f64) * (*y as f64);
                    apq += (*x as f64) * (*y as f64);
                }
                if apq.abs() <= eps * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                rotations += 1;
                // Jacobi rotation zeroing the (p,q) entry of AᵀA.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                for (x, y) in ci.iter_mut().zip(cj.iter_mut()) {
                    let xi = *x;
                    let yi = *y;
                    *x = cf * xi - sf * yi;
                    *y = sf * xi + cf * yi;
                }
                let (vi, vj) = split_two(&mut v, i, j);
                for (x, y) in vi.iter_mut().zip(vj.iter_mut()) {
                    let xi = *x;
                    let yi = *y;
                    *x = cf * xi - sf * yi;
                    *y = sf * xi + cf * yi;
                }
            }
        }
        if rotations == 0 {
            break;
        }
    }

    // Extract σ and normalize U columns; sort descending.
    let mut order: Vec<(f64, usize)> = cols
        .iter()
        .enumerate()
        .map(|(j, c)| {
            let s = c.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
                .sqrt();
            (s, j)
        })
        .collect();
    order.sort_by(|a, b| b.0.total_cmp(&a.0));

    let mut u = Tensor::zeros(vec![m, n]);
    let mut vm = Tensor::zeros(vec![n, n]);
    let mut sigma = Vec::with_capacity(n);
    for (k, (s, j)) in order.iter().enumerate() {
        sigma.push(*s);
        let inv = if *s > 1e-30 { (1.0 / s) as f32 } else { 0.0 };
        for r in 0..m {
            u.set(r, k, cols[*j][r] * inv);
        }
        for r in 0..n {
            vm.set(r, k, v[*j][r]);
        }
    }
    Svd { u, sigma, v: vm }
}

/// Borrow two distinct elements of a Vec mutably.
fn split_two<T>(v: &mut [T], i: usize, j: usize) -> (&mut T, &mut T) {
    debug_assert!(i < j);
    let (lo, hi) = v.split_at_mut(j);
    (&mut lo[i], &mut hi[0])
}

/// Singular values only (cheaper call sites that don't need U/V).
pub fn singular_values(a: &Tensor) -> Vec<f64> {
    svd(a).sigma
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::tensor::matmul::matmul;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn ortho_err(t: &Tensor) -> f64 {
        // ‖TᵀT − I‖∞ over columns.
        let g = matmul(&t.transpose(), t);
        let n = g.rows();
        let mut e = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                let target = if i == j { 1.0 } else { 0.0 };
                e = e.max((g.at(i, j) as f64 - target).abs());
            }
        }
        e
    }

    #[test]
    fn reconstruction_and_orthogonality() {
        check("svd reconstruct", 12, |rng| {
            let m = 2 + rng.below(40);
            let n = 2 + rng.below(40);
            let a = Tensor::randn(vec![m, n], rng);
            let s = svd(&a);
            let rec = s.reconstruct();
            let rel = a.sub(&rec).frob_norm() as f64 / a.frob_norm() as f64;
            prop_ensure!(rel < 5e-5, "reconstruction rel err {rel} ({m}x{n})");
            prop_ensure!(ortho_err(&s.u) < 5e-4, "U not orthogonal");
            prop_ensure!(ortho_err(&s.v) < 5e-4, "V not orthogonal");
            // descending
            for w in s.sigma.windows(2) {
                prop_ensure!(w[0] >= w[1] - 1e-9, "sigma not sorted");
            }
            Ok(())
        });
    }

    #[test]
    fn known_diagonal() {
        // diag(3, 2, 1) has exactly those singular values.
        let mut a = Tensor::zeros(vec![3, 3]);
        a.set(0, 0, 3.0);
        a.set(1, 1, 2.0);
        a.set(2, 2, 1.0);
        let s = svd(&a);
        assert!((s.sigma[0] - 3.0).abs() < 1e-6);
        assert!((s.sigma[1] - 2.0).abs() < 1e-6);
        assert!((s.sigma[2] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn low_rank_detected() {
        // Rank-2 matrix: outer products of two vector pairs.
        let mut rng = Rng::new(42);
        let u1 = rng.normal_vec(20);
        let v1 = rng.normal_vec(15);
        let u2 = rng.normal_vec(20);
        let v2 = rng.normal_vec(15);
        let mut a = Tensor::zeros(vec![20, 15]);
        for i in 0..20 {
            for j in 0..15 {
                a.set(i, j, 3.0 * u1[i] * v1[j] + 0.5 * u2[i] * v2[j]);
            }
        }
        let s = svd(&a);
        assert!(s.sigma[1] > 1e-3);
        assert!(s.sigma[2] < 1e-3, "rank-2 leak: {}", s.sigma[2]);
    }

    #[test]
    fn energy_rank_truncation() {
        let s = Svd {
            u: Tensor::zeros(vec![4, 4]),
            sigma: vec![10.0, 1.0, 0.1, 0.01],
            v: Tensor::zeros(vec![4, 4]),
        };
        // energies: 100, 1, .01, .0001 -> rank 1 already covers >90%
        assert_eq!(s.energy_rank(0.90), 1);
        assert_eq!(s.energy_rank(0.9999), 2);
        assert_eq!(s.energy_rank(1.0), 4);
    }

    #[test]
    fn wide_matrix_transposes() {
        let mut rng = Rng::new(17);
        let a = Tensor::randn(vec![5, 30], &mut rng);
        let s = svd(&a);
        assert_eq!(s.u.dims(), &[5, 5]);
        assert_eq!(s.v.dims(), &[30, 5]);
        let rel =
            a.sub(&s.reconstruct()).frob_norm() as f64 / a.frob_norm() as f64;
        assert!(rel < 5e-5, "{rel}");
    }
}
