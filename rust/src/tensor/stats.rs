//! Robust statistics used by the sensitivity metrics and aggregation:
//! moments (excess kurtosis — paper Eq. 5), median / MAD (Eq. 10),
//! Shannon entropy of a spectrum (Eq. 6), softmax entropy (EWQ baseline),
//! z-score machinery (ZD / KurtBoost baselines).
//!
//! All accumulation is in f64: kurtosis is a 4th-moment statistic and f32
//! accumulators visibly bias it for the >100k-element FFN matrices.

/// Mean of a slice (f64 accumulation).
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mu = mean(xs);
    xs.iter().map(|&x| (x as f64 - mu).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Excess kurtosis (paper Eq. 5): E[(w-μ)⁴] / E[(w-μ)²]² − 3.
pub fn excess_kurtosis(xs: &[f32]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let mu = mean(xs);
    let (mut m2, mut m4) = (0.0f64, 0.0f64);
    for &x in xs {
        let c = x as f64 - mu;
        let c2 = c * c;
        m2 += c2;
        m4 += c2 * c2;
    }
    let n = xs.len() as f64;
    m2 /= n;
    m4 /= n;
    if m2 <= 1e-24 {
        return 0.0;
    }
    m4 / (m2 * m2) - 3.0
}

/// Raw (non-excess) kurtosis — the KurtBoost baseline uses this directly.
pub fn raw_kurtosis(xs: &[f32]) -> f64 {
    excess_kurtosis(xs) + 3.0
}

/// Median (copies + sorts; slices here are small or called off hot path).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Median absolute deviation: Median(|x − Median(x)|). (Paper Eq. 10.)
pub fn mad(xs: &[f64]) -> f64 {
    let med = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    median(&dev)
}

/// Shannon entropy of a normalized distribution p (natural log).
/// Zero entries contribute 0 (lim p→0 of p·ln p).
pub fn entropy(p: &[f64]) -> f64 {
    -p.iter()
        .filter(|&&x| x > 0.0)
        .map(|&x| x * x.ln())
        .sum::<f64>()
}

/// Spectral entropy (paper Eq. 6): normalize singular values to a
/// distribution, return its Shannon entropy.
pub fn spectral_entropy(sigma: &[f64]) -> f64 {
    let s: f64 = sigma.iter().sum();
    if s <= 0.0 {
        return 0.0;
    }
    let p: Vec<f64> = sigma.iter().map(|x| x / s).collect();
    entropy(&p)
}

/// Softmax-entropy of a weight vector (EWQ baseline, paper Eq. 18),
/// computed stably (max subtraction) with the paper's +ε inside the log.
pub fn softmax_entropy(xs: &[f32], eps: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mx = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
    let mut z = 0.0f64;
    for &x in xs {
        z += ((x as f64) - mx).exp();
    }
    let mut h = 0.0f64;
    for &x in xs {
        let p = ((x as f64) - mx).exp() / z;
        h -= p * (p + eps).ln();
    }
    h
}

/// Standard deviation (population).
pub fn std_dev(xs: &[f32]) -> f64 {
    variance(xs).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn kurtosis_gaussian_near_zero() {
        let mut rng = Rng::new(11);
        let xs = rng.normal_vec(400_000);
        let k = excess_kurtosis(&xs);
        assert!(k.abs() < 0.08, "gaussian excess kurtosis {k}");
    }

    #[test]
    fn kurtosis_heavy_tail_positive() {
        // Laplace via difference of exponentials: excess kurtosis = 3.
        let mut rng = Rng::new(12);
        let xs: Vec<f32> = (0..200_000)
            .map(|_| {
                let u: f64 = rng.f64().max(1e-12);
                let v: f64 = rng.f64().max(1e-12);
                (-u.ln() + v.ln()) as f32
            })
            .collect();
        let k = excess_kurtosis(&xs);
        assert!((k - 3.0).abs() < 0.4, "laplace excess kurtosis {k}");
    }

    #[test]
    fn kurtosis_uniform_negative() {
        let mut rng = Rng::new(13);
        let xs: Vec<f32> = (0..100_000).map(|_| rng.f32()).collect();
        let k = excess_kurtosis(&xs);
        assert!((k + 1.2).abs() < 0.1, "uniform excess kurtosis {k}");
    }

    #[test]
    fn median_mad_hand_cases() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        // xs = [1,2,3,4,100]: med=3, |dev|=[2,1,0,1,97] -> mad=1
        assert_eq!(mad(&[1.0, 2.0, 3.0, 4.0, 100.0]), 1.0);
    }

    #[test]
    fn mad_robust_to_outliers() {
        check("mad-robust", 10, |rng| {
            let mut xs: Vec<f64> = (0..101).map(|_| rng.normal()).collect();
            let m0 = mad(&xs);
            xs[0] = 1e9; // one wild outlier
            let m1 = mad(&xs);
            prop_ensure!((m0 - m1).abs() < 0.5, "mad moved {m0} -> {m1}");
            Ok(())
        });
    }

    #[test]
    fn entropy_bounds() {
        // Uniform over k has entropy ln k; point mass has 0.
        let k = 8;
        let p = vec![1.0 / k as f64; k];
        assert!((entropy(&p) - (k as f64).ln()).abs() < 1e-12);
        let mut q = vec![0.0; k];
        q[3] = 1.0;
        assert_eq!(entropy(&q), 0.0);
    }

    #[test]
    fn spectral_entropy_scale_invariant() {
        check("spec-ent scale inv", 10, |rng| {
            let s: Vec<f64> = (0..12).map(|_| rng.f64() + 0.01).collect();
            let s2: Vec<f64> = s.iter().map(|x| x * 7.5).collect();
            let d = (spectral_entropy(&s) - spectral_entropy(&s2)).abs();
            prop_ensure!(d < 1e-12, "not scale invariant: {d}");
            Ok(())
        });
    }

    #[test]
    fn softmax_entropy_uniform_max() {
        let xs = vec![0.5f32; 64];
        let h = softmax_entropy(&xs, 0.0);
        assert!((h - 64f64.ln()).abs() < 1e-6, "{h}");
        // peaked distribution has lower entropy
        let mut ys = vec![0.0f32; 64];
        ys[0] = 20.0;
        assert!(softmax_entropy(&ys, 0.0) < 0.1);
    }
}
