//! Dense f32 tensor substrate: the numeric foundation every higher module
//! (sensitivity, quantization, baselines, eval) builds on.
//!
//! Deliberately small: row-major `Vec<f32>` + dims, 2-D matrix views,
//! blocked matmul, one-sided Jacobi SVD, robust statistics. No external
//! linear-algebra crates are reachable offline, so this *is* the BLAS/LAPACK
//! of the project — correctness is pinned by unit + property tests
//! (reconstruction errors, orthogonality, agreement with hand computations).

pub mod matmul;
pub mod linalg;
pub mod stats;
pub mod svd;

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    data: Vec<f32>,
    dims: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, dims: Vec<usize>) -> Self {
        assert_eq!(
            data.len(),
            dims.iter().product::<usize>(),
            "data/dims mismatch: {} vs {:?}",
            data.len(),
            dims
        );
        Tensor { data, dims }
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Tensor { data: vec![0.0; n], dims }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { data: vec![v], dims: vec![] }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with new dims (same element count).
    pub fn reshape(mut self, dims: Vec<usize>) -> Self {
        assert_eq!(self.data.len(), dims.iter().product::<usize>());
        self.dims = dims;
        self
    }

    /// 2-D accessors -------------------------------------------------------
    pub fn rows(&self) -> usize {
        assert_eq!(self.dims.len(), 2, "not a matrix: {:?}", self.dims);
        self.dims[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.dims.len(), 2, "not a matrix: {:?}", self.dims);
        self.dims[1]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.dims[1] + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        let w = self.dims[1];
        self.data[r * w + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let w = self.dims[1];
        &self.data[r * w..(r + 1) * w]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let w = self.dims[1];
        &mut self.data[r * w..(r + 1) * w]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        let (m, n) = (self.dims[0], self.dims[1]);
        (0..m).map(|r| self.data[r * n + c]).collect()
    }

    /// Slice the leading axis: `t[i]` for a `[L, ...]` stacked tensor.
    pub fn slice0(&self, i: usize) -> Tensor {
        assert!(!self.dims.is_empty() && i < self.dims[0]);
        let inner: usize = self.dims[1..].iter().product();
        Tensor::new(
            self.data[i * inner..(i + 1) * inner].to_vec(),
            self.dims[1..].to_vec(),
        )
    }

    /// Write a slice back into the leading axis.
    pub fn set_slice0(&mut self, i: usize, t: &Tensor) {
        let inner: usize = self.dims[1..].iter().product();
        assert_eq!(t.len(), inner);
        self.data[i * inner..(i + 1) * inner].copy_from_slice(t.data());
    }

    pub fn transpose(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; m * n];
        for r in 0..m {
            for c in 0..n {
                out[c * m + r] = self.data[r * n + c];
            }
        }
        Tensor::new(out, vec![n, m])
    }

    /// Columns `c0..c1` as a new matrix.
    pub fn cols_range(&self, c0: usize, c1: usize) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        assert!(c0 <= c1 && c1 <= n);
        let w = c1 - c0;
        let mut out = Vec::with_capacity(m * w);
        for r in 0..m {
            out.extend_from_slice(&self.data[r * n + c0..r * n + c1]);
        }
        Tensor::new(out, vec![m, w])
    }

    /// Rows `r0..r1` as a new matrix.
    pub fn rows_range(&self, r0: usize, r1: usize) -> Tensor {
        let n = self.cols();
        assert!(r0 <= r1 && r1 <= self.rows());
        Tensor::new(self.data[r0 * n..r1 * n].to_vec(), vec![r1 - r0, n])
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor::new(self.data.iter().map(|&x| f(x)).collect(),
                    self.dims.clone())
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.dims, other.dims);
        Tensor::new(
            self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
            self.dims.clone(),
        )
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.dims, other.dims);
        Tensor::new(
            self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
            self.dims.clone(),
        )
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    /// Random-normal tensor (test/model-zoo helper).
    pub fn randn(dims: Vec<usize>, rng: &mut crate::util::rng::Rng) -> Self {
        let n = dims.iter().product();
        Tensor::new(rng.normal_vec(n), dims)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn basic_accessors() {
        let t = Tensor::new(vec![1., 2., 3., 4., 5., 6.], vec![2, 3]);
        assert_eq!(t.at(0, 2), 3.0);
        assert_eq!(t.at(1, 0), 4.0);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.col(1), vec![2., 5.]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(vec![7, 5], &mut rng);
        assert_eq!(t.transpose().transpose(), t);
    }

    #[test]
    fn slice0_roundtrip() {
        let t = Tensor::new((0..24).map(|x| x as f32).collect(), vec![2, 3, 4]);
        let s1 = t.slice0(1);
        assert_eq!(s1.dims(), &[3, 4]);
        assert_eq!(s1.data()[0], 12.0);
        let mut t2 = t.clone();
        t2.set_slice0(0, &s1);
        assert_eq!(t2.slice0(0), s1);
    }

    #[test]
    fn ranges() {
        let t = Tensor::new((0..12).map(|x| x as f32).collect(), vec![3, 4]);
        let c = t.cols_range(1, 3);
        assert_eq!(c.dims(), &[3, 2]);
        assert_eq!(c.data(), &[1., 2., 5., 6., 9., 10.]);
        let r = t.rows_range(1, 2);
        assert_eq!(r.data(), &[4., 5., 6., 7.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![1.0; 5], vec![2, 3]);
    }
}
