//! Cholesky machinery for the GPTQ backend (Hessian inverse) — f64
//! internally: quantization error feedback is sensitive to the
//! conditioning of Xᵀ X.

use super::Tensor;

/// Cholesky factor L (lower) of a symmetric positive-definite matrix.
/// Returns None if the matrix is not PD (caller should raise damping).
pub fn cholesky(a: &Tensor) -> Option<Tensor> {
    let n = a.rows();
    assert_eq!(n, a.cols());
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j) as f64;
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(Tensor::new(l.into_iter().map(|x| x as f32).collect(),
                     vec![n, n]))
}

/// Inverse of an SPD matrix via Cholesky: A⁻¹ = L⁻ᵀ L⁻¹.
pub fn spd_inverse(a: &Tensor) -> Option<Tensor> {
    let n = a.rows();
    let l = cholesky(a)?;
    // Invert L (lower triangular) by forward substitution, in f64.
    let ld: Vec<f64> = l.data().iter().map(|&x| x as f64).collect();
    let mut linv = vec![0.0f64; n * n];
    for j in 0..n {
        linv[j * n + j] = 1.0 / ld[j * n + j];
        for i in (j + 1)..n {
            let mut s = 0.0;
            for k in j..i {
                s += ld[i * n + k] * linv[k * n + j];
            }
            linv[i * n + j] = -s / ld[i * n + i];
        }
    }
    // A⁻¹ = Linvᵀ · Linv.
    let mut inv = vec![0.0f64; n * n];
    for i in 0..n {
        for j in i..n {
            let mut s = 0.0;
            // (Linvᵀ Linv)[i,j] = Σ_k Linv[k,i]·Linv[k,j]; Linv lower ⇒
            // k ≥ max(i, j).
            for k in j.max(i)..n {
                s += linv[k * n + i] * linv[k * n + j];
            }
            inv[i * n + j] = s;
            inv[j * n + i] = s;
        }
    }
    Some(Tensor::new(inv.into_iter().map(|x| x as f32).collect(),
                     vec![n, n]))
}

/// Upper-triangular Cholesky factor U of an SPD matrix (A = Uᵀ U) — the
/// form GPTQ consumes for its error-propagation row updates.
pub fn cholesky_upper(a: &Tensor) -> Option<Tensor> {
    cholesky(a).map(|l| l.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::tensor::matmul::{gram, matmul};
    use crate::util::prop::check;

    fn spd(rng: &mut crate::util::rng::Rng, n: usize) -> Tensor {
        let a = Tensor::randn(vec![n + 3, n], rng);
        let mut g = gram(&a);
        for i in 0..n {
            let v = g.at(i, i) + 0.1;
            g.set(i, i, v);
        }
        g
    }

    #[test]
    fn cholesky_reconstructs() {
        check("cholesky LLt", 15, |rng| {
            let n = 2 + rng.below(24);
            let a = spd(rng, n);
            let l = cholesky(&a).ok_or("not PD")?;
            let rec = matmul(&l, &l.transpose());
            let err = rec.sub(&a).frob_norm() / a.frob_norm();
            prop_ensure!(err < 1e-4, "rel err {err}");
            Ok(())
        });
    }

    #[test]
    fn inverse_is_inverse() {
        check("spd inverse", 15, |rng| {
            let n = 2 + rng.below(20);
            let a = spd(rng, n);
            let inv = spd_inverse(&a).ok_or("not PD")?;
            let prod = matmul(&a, &inv);
            let mut err = 0.0f32;
            for i in 0..n {
                for j in 0..n {
                    let t = if i == j { 1.0 } else { 0.0 };
                    err = err.max((prod.at(i, j) - t).abs());
                }
            }
            prop_ensure!(err < 5e-3, "‖AA⁻¹−I‖∞ = {err}");
            Ok(())
        });
    }

    #[test]
    fn non_pd_rejected() {
        let mut a = Tensor::zeros(vec![2, 2]);
        a.set(0, 0, 1.0);
        a.set(1, 1, -1.0);
        assert!(cholesky(&a).is_none());
    }
}
