//! Blocked matmul + friends. This is the L3-side GEMM used by quantization
//! backends (GPTQ Hessians, error propagation), calibration baselines and
//! the component decomposition (per-head `W_Q W_Kᵀ` products).
//!
//! Layout strategy: i-k-j loop order with the inner j loop over contiguous
//! rows of B, which vectorizes well and avoids strided access entirely —
//! the classic "ikj" kernel. Blocking keeps the active B panel in cache
//! for the larger Hessian-sized products.

use super::Tensor;

/// C = A @ B, A [m,k], B [k,n].
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul shape mismatch {:?} @ {:?}", a.dims(), b.dims());
    let mut c = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    const BK: usize = 64;
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for i in 0..m {
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let aik = ad[i * k + kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &bd[kk * n..(kk + 1) * n];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += aik * bv;
                }
            }
        }
    }
    Tensor::new(c, vec![m, n])
}

/// C = Aᵀ @ A (Gram matrix, used for GPTQ Hessians), A [m,k] -> C [k,k].
/// Exploits symmetry: computes the upper triangle and mirrors.
pub fn gram(a: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let ad = a.data();
    let mut c = vec![0.0f32; k * k];
    for r in 0..m {
        let row = &ad[r * k..(r + 1) * k];
        for i in 0..k {
            let v = row[i];
            if v == 0.0 {
                continue;
            }
            let crow = &mut c[i * k..(i + 1) * k];
            for j in i..k {
                crow[j] += v * row[j];
            }
        }
    }
    for i in 0..k {
        for j in 0..i {
            c[i * k + j] = c[j * k + i];
        }
    }
    Tensor::new(c, vec![k, k])
}

/// y = x @ W for a single row vector x [k], W [k,n].
pub fn vecmat(x: &[f32], w: &Tensor) -> Vec<f32> {
    let (k, n) = (w.rows(), w.cols());
    assert_eq!(x.len(), k);
    let wd = w.data();
    let mut y = vec![0.0f32; n];
    for (kk, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let row = &wd[kk * n..(kk + 1) * n];
        for (yv, wv) in y.iter_mut().zip(row) {
            *yv += xv * wv;
        }
    }
    y
}

/// Dot product.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.rows(), a.cols());
        let n = b.cols();
        let mut c = Tensor::zeros(vec![m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.at(i, kk) * b.at(kk, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matches_naive() {
        check("matmul==naive", 20, |rng| {
            let m = 1 + rng.below(20);
            let k = 1 + rng.below(90);
            let n = 1 + rng.below(20);
            let a = Tensor::randn(vec![m, k], rng);
            let b = Tensor::randn(vec![k, n], rng);
            let c1 = matmul(&a, &b);
            let c2 = naive(&a, &b);
            let err = c1.sub(&c2).frob_norm() / c2.frob_norm().max(1e-6);
            prop_ensure!(err < 1e-5, "rel err {err}");
            Ok(())
        });
    }

    #[test]
    fn gram_matches_matmul() {
        check("gram==AtA", 10, |rng| {
            let m = 1 + rng.below(40);
            let k = 1 + rng.below(30);
            let a = Tensor::randn(vec![m, k], rng);
            let g1 = gram(&a);
            let g2 = matmul(&a.transpose(), &a);
            let err = g1.sub(&g2).frob_norm() / g2.frob_norm().max(1e-6);
            prop_ensure!(err < 1e-5, "rel err {err}");
            Ok(())
        });
    }

    #[test]
    fn vecmat_matches() {
        let mut rng = Rng::new(5);
        let w = Tensor::randn(vec![8, 6], &mut rng);
        let x: Vec<f32> = rng.normal_vec(8);
        let y = vecmat(&x, &w);
        let xm = Tensor::new(x, vec![1, 8]);
        let ym = matmul(&xm, &w);
        for (a, b) in y.iter().zip(ym.data()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn identity() {
        let mut eye = Tensor::zeros(vec![4, 4]);
        for i in 0..4 {
            eye.set(i, i, 1.0);
        }
        let mut rng = Rng::new(2);
        let a = Tensor::randn(vec![4, 4], &mut rng);
        assert!(matmul(&a, &eye).sub(&a).frob_norm() < 1e-6);
    }
}
