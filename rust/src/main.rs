//! `nsds` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   info                          artifact + model zoo summary
//!   score    --model M [--method X]   per-layer sensitivity scores
//!   quantize --model M [--budget B] [--method X] [--backend B]
//!                                 allocate + quantize + evaluate one run
//!   eval     --model M            FP reference evaluation
//!   sweep    --model M [--fast]   budget sweep for one model
//!   paper    <table1|table2|fig1|fig3|fig4|fig5|fig6|fig7|all> [--fast]
//!                                 regenerate a paper exhibit
//!   serve-demo                    native fused 2/4-bit serving demo
//!                                 (synthetic model; needs NO artifacts)
//!
//! (clap is unreachable offline; argument parsing is hand-rolled — see
//! DESIGN.md "Environment deviations".)

use anyhow::{bail, Result};

use nsds::baselines::Method;
use nsds::coordinator::Pipeline;
use nsds::eval::EvalOptions;
use nsds::quant::Backend;
use nsds::sensitivity::Ablation;

struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Self {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let argv: Vec<String> = std::env::args().skip(1).collect();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let val = if i + 1 < argv.len()
                    && !argv[i + 1].starts_with("--")
                {
                    i += 1;
                    argv[i].clone()
                } else {
                    "true".to_string()
                };
                flags.insert(name.to_string(), val);
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args { positional, flags }
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    fn model(&self) -> &str {
        self.get("model").unwrap_or("llama-s")
    }

    fn budget(&self) -> f64 {
        self.get("budget").and_then(|s| s.parse().ok()).unwrap_or(3.0)
    }

    fn backend(&self) -> Result<Backend> {
        Ok(match self.get("backend").unwrap_or("hqq") {
            "hqq" => Backend::Hqq,
            "gptq" => Backend::Gptq,
            "rtn" => Backend::Rtn,
            other => bail!("unknown backend {other}"),
        })
    }

    fn method(&self) -> Result<Method> {
        Ok(match self.get("method").unwrap_or("nsds") {
            "nsds" => Method::Nsds(Ablation::Full),
            "mse" => Method::Mse,
            "ewq" => Method::Ewq,
            "zd" => Method::Zd,
            "kurtboost" => Method::KurtBoost,
            "lim" => Method::Lim,
            "lsaq" => Method::Lsaq,
            "llm-mq" => Method::LlmMq,
            "lieq" => Method::LieQ,
            other => bail!("unknown method {other}"),
        })
    }

    fn eval_opts(&self) -> EvalOptions {
        if self.get("fast").is_some() {
            EvalOptions::fast()
        } else {
            EvalOptions::default()
        }
    }
}

fn main() -> Result<()> {
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => info(),
        "score" => score(&args),
        "quantize" => quantize(&args),
        "eval" => eval_fp(&args),
        "sweep" => sweep(&args),
        "paper" => paper(&args),
        "search-vs-criterion" => search_vs_criterion(&args),
        "serve-demo" => serve_demo(),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
nsds — data-free layer-wise mixed-precision quantization (paper repro)

USAGE: nsds <command> [flags]

COMMANDS:
  info                              artifact + model zoo summary
  score    --model M [--method X]   per-layer sensitivity scores
  quantize --model M [--budget B] [--method X] [--backend hqq|gptq|rtn]
  eval     --model M                FP reference evaluation
  sweep    --model M [--fast]       budget sweep (one model, all methods)
  paper    <exhibit> [--fast]       table1 table2 fig1 fig3 fig4 fig5
                                    fig6 fig7 | all
  serve-demo                        native fused 2/4-bit serving demo
                                    (synthetic model, no artifacts)
  search-vs-criterion --model M     greedy search-based LMPQ vs NSDS

METHODS: nsds mse ewq zd kurtboost lim lsaq llm-mq lieq
";

fn info() -> Result<()> {
    let p = Pipeline::new()?;
    println!("platform: {}", p.engine.platform());
    println!("artifacts: {:?}", p.man.dir);
    println!("eval batch: {}", p.man.eval_batch);
    for m in &p.man.models {
        let c = &m.config;
        let final_loss =
            m.train_log.last().map(|(_, l)| *l).unwrap_or(f64::NAN);
        println!(
            "  {:8} L={:2} d={} H={}/{} ffn={} vocab={} params={} \
             train-loss={:.3}",
            m.name, c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ffn,
            c.vocab, m.params, final_loss);
    }
    for t in &p.man.tasks {
        println!("  task {:12} k={} n={}", t.name, t.k, t.n);
    }
    Ok(())
}

fn score(args: &Args) -> Result<()> {
    let p = Pipeline::new()?;
    let method = args.method()?;
    let model = args.model();
    let scores = p.scores(method, model)?;
    let bits = p.allocate(method, model, args.budget())?;
    println!("{} scores on {model} (b̄={}):", method.label(),
             args.budget());
    for (l, (s, b)) in scores.iter().zip(&bits).enumerate() {
        println!("  layer {l:2}  score {s:>9.4}  -> {b}-bit  {}",
                 "#".repeat((s.abs() * 30.0).min(60.0) as usize));
    }
    Ok(())
}

fn quantize(args: &Args) -> Result<()> {
    let p = Pipeline::new()?;
    let r = p.run(args.method()?, args.model(), args.budget(),
                  args.backend()?, &args.eval_opts())?;
    println!("allocation: {:?}", r.bits);
    print_eval(&r.eval);
    Ok(())
}

fn eval_fp(args: &Args) -> Result<()> {
    let p = Pipeline::new()?;
    let r = p.eval_fp(args.model(), &args.eval_opts())?;
    print_eval(&r);
    Ok(())
}

fn print_eval(r: &nsds::eval::EvalResult) {
    for (name, ppl) in &r.ppl {
        println!("  ppl  {name:16} {ppl:.3}");
    }
    for (name, acc) in &r.acc {
        println!("  acc  {name:16} {acc:.2}%");
    }
    println!("  avg acc {:.2}%   avg ppl {:.3}", r.avg_acc(), r.avg_ppl());
}

fn sweep(args: &Args) -> Result<()> {
    let p = Pipeline::new()?;
    let model = args.model();
    let opts = args.eval_opts();
    println!("budget sweep on {model}:");
    for method in Method::table1() {
        for b in [2.25, 2.5, 2.75, 3.0, 3.5] {
            let r = p.run(method, model, b, Backend::Hqq, &opts)?;
            println!("  {:10} b̄={b:<5} avg-acc {:6.2}%  avg-ppl {:8.3}",
                     method.label(), r.eval.avg_acc(), r.eval.avg_ppl());
        }
    }
    Ok(())
}

fn paper(args: &Args) -> Result<()> {
    let p = Pipeline::new()?;
    let opts = args.eval_opts();
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    use nsds::report::paper as ex;
    let t0 = std::time::Instant::now();
    match which {
        "table1" => ex::table1(&p, &opts)?,
        "table2" => ex::table2(&p, &opts)?,
        "fig1" => ex::fig1(&p, &opts)?,
        "fig3" => ex::fig3(&p, &EvalOptions::fast())?,
        "fig4" => ex::fig4(&p, &opts)?,
        "fig5" => ex::fig5(&p, &opts)?,
        "fig6" => ex::fig6(&p, &opts)?,
        "fig7" => ex::fig7(&p)?,
        "all" => {
            ex::table1(&p, &opts)?;
            ex::table2(&p, &opts)?;
            ex::fig1(&p, &opts)?;
            ex::fig3(&p, &EvalOptions::fast())?;
            ex::fig4(&p, &opts)?;
            ex::fig5(&p, &opts)?;
            ex::fig6(&p, &opts)?;
            ex::fig7(&p)?;
        }
        other => bail!("unknown exhibit {other}"),
    }
    eprintln!("[paper {which}] total {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

/// Search-based vs criterion-based LMPQ (the paper's intro trade-off):
/// greedy ΔPPL search needs O(L²) quantize+eval probes; NSDS needs zero.
fn search_vs_criterion(args: &Args) -> Result<()> {
    let p = Pipeline::new()?;
    let model = args.model();
    let budget = args.budget();
    let opts = args.eval_opts();
    let t0 = std::time::Instant::now();
    let sr = nsds::baselines::search::greedy_allocate(
        &p, model, budget, Backend::Hqq, 6)?;
    let t_search = t0.elapsed().as_secs_f64();
    let search_eval = {
        let qw = p.quantize(model, &sr.bits, Backend::Hqq)?;
        p.eval(model, &qw, &opts)?
    };
    let t1 = std::time::Instant::now();
    let r = p.run(Method::Nsds(Ablation::Full), model, budget,
                  Backend::Hqq, &opts)?;
    let t_nsds = t1.elapsed().as_secs_f64();
    println!("greedy search: bits {:?}", sr.bits);
    println!("  {} probe evals, {t_search:.1}s;  avg acc {:.2}%  avg ppl \
              {:.3}", sr.evals, search_eval.avg_acc(),
             search_eval.avg_ppl());
    println!("  ppl curve during search: {:?}",
             sr.curve.iter().map(|x| (x * 1000.0).round() / 1000.0)
                 .collect::<Vec<_>>());
    println!("NSDS (criterion): bits {:?}", r.bits);
    println!("  0 probe evals, {t_nsds:.1}s total;  avg acc {:.2}%  \
              avg ppl {:.3}", r.eval.avg_acc(), r.eval.avg_ppl());
    Ok(())
}

/// Serving-path demo, fully self-contained (no artifacts, no XLA): a
/// synthetic llama-s-shaped model is quantized into the packed 2/4-bit
/// serving format and deployed through `coordinator::server::serve` over
/// the native executor — dense FP32 first, then a zero-downtime swap to
/// the fused packed variant mid-stream. Reports NLL parity, memory
/// savings and per-request latency.
fn serve_demo() -> Result<()> {
    use nsds::coordinator::server::{serve, Client, ServedWeights,
                                    ServerQueue};
    use nsds::infer::{NativeEngine, QuantizedModel};
    use nsds::model::{ModelConfig, Weights, QUANT_WEIGHTS};
    use nsds::quant::{Backend, DEFAULT_GROUP};
    use nsds::runtime::ModelEntry;
    use nsds::util::rng::Rng;

    // The llama-s shape from the model zoo (synthetic weights).
    let cfg = ModelConfig::llama_s_synth();
    let entry = ModelEntry::synthetic(cfg.clone());
    let mut rng = Rng::new(123);
    let fp = Weights::synth(&cfg, &mut rng, &[], &[]);
    let bits: Vec<u8> =
        (0..cfg.n_layers).map(|l| if l % 2 == 0 { 4 } else { 2 }).collect();
    let qm = QuantizedModel::quantize(
        &cfg, &fp, &bits, DEFAULT_GROUP, Backend::Hqq, None,
        nsds::util::pool::default_workers());
    let fp_bytes: usize = (0..cfg.n_layers)
        .map(|l| {
            QUANT_WEIGHTS
                .iter()
                .map(|n| fp.layer_matrix(n, l).len() * 4)
                .sum::<usize>()
        })
        .sum();
    println!("model {}: {} params, allocation {bits:?}", cfg.name,
             entry.params);
    println!("block weights: {:.1} KiB fp32 -> {:.1} KiB packed \
              ({:.1}x smaller)",
             fp_bytes as f64 / 1024.0,
             qm.packed_bytes() as f64 / 1024.0,
             fp_bytes as f64 / qm.packed_bytes() as f64);

    let batch = 4;
    let seq = cfg.seq;
    let n_requests = 32;
    let queue = ServerQueue::new(batch * 4);
    let client = Client::new(queue.clone(), seq);
    let vocab = cfg.vocab as i32;
    let qm_for_swap = qm.clone();
    let handle = std::thread::spawn(move || -> Result<Vec<f64>> {
        let mut rng = Rng::new(7);
        let mut nlls = Vec::new();
        for r in 0..n_requests {
            if r == n_requests / 2 {
                println!("[client] deploying packed 2/4-bit variant \
                          (request #{r}) — fused dequant-matmul path");
                client.swap_packed(qm_for_swap.clone());
            }
            let toks: Vec<i32> =
                (0..seq).map(|_| rng.below(vocab as usize) as i32)
                    .collect();
            let (nll, n) = client.nll(toks)?;
            nlls.push(nll / n as f64);
        }
        client.stop();
        Ok(nlls)
    });

    let exec = NativeEngine::new();
    let t0 = std::time::Instant::now();
    serve(&exec, &entry, batch, ServedWeights::Dense(fp.clone()),
          &queue)?;
    let dt = t0.elapsed().as_secs_f64();
    let nlls = handle.join().unwrap()?;

    let (served, batches, padded) = queue.stats();
    let half = nlls.len() / 2;
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
    println!("served {served} requests in {batches} batches \
              ({padded} padded rows) over {dt:.2}s");
    println!("mean NLL  fp32 {:.4}  packed {:.4}  (random tokens: \
              both ≈ ln V = {:.4})",
             mean(&nlls[..half]), mean(&nlls[half..]),
             (cfg.vocab as f64).ln());

    // Cross-check the fused path against dequantize-then-dense forward.
    let toks: Vec<i32> =
        (0..batch * seq).map(|i| (i % cfg.vocab) as i32).collect();
    use nsds::infer::Executor;
    let fused = exec.forward_packed(&entry, &toks, batch, &qm)?;
    let dense = exec.forward(&entry, &toks, batch,
                             &qm.dequantized_weights())?;
    let err = fused.sub(&dense).frob_norm()
        / dense.frob_norm().max(1e-9);
    println!("fused vs dequant-dense logits rel-err {err:.2e}");
    anyhow::ensure!(err < 1e-4, "fused/dense mismatch");
    println!("serve-demo OK");
    Ok(())
}
